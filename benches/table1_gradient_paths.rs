//! Table 1 + Figure 6 (runtime columns): wall-clock to reach loss < 1e-4
//! for the four gradient-path variants over rollout lengths n ∈ {1, 10, 100}
//! (plus the paper's n=100 @ lr=1e-3 column at reduced iteration budget).
//!
//! Expected shape (paper): `none` cheapest per step at small n; `Adv` best
//! wall-clock at large n; `P`-only ≈ `Adv+P` in steps but slower per step.

use pict::adjoint::GradientPaths;
use pict::coordinator::experiments::{gradient_path_ablation, GradPathCfg};
use pict::util::bench::{print_table, write_report};
use pict::util::json::Json;

fn main() {
    let variants =
        [GradientPaths::FULL, GradientPaths::P, GradientPaths::ADV, GradientPaths::NONE];
    let cases: [(usize, f64, usize); 4] =
        [(1, 0.02, 60), (10, 0.04, 60), (100, 0.04, 60), (100, 0.004, 240)];

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for paths in variants {
        let mut row = vec![paths.label().to_string()];
        for (n, lr, iters) in cases {
            let cfg =
                GradPathCfg { n_steps: n, lr, opt_iters: iters, paths, ..Default::default() };
            let r = gradient_path_ablation(&cfg);
            let cell = if r.diverged {
                "diverged".to_string()
            } else {
                match r.time_to_target {
                    Some(t) => format!("{t:.3}s"),
                    None => format!(
                        ">{:.2}s (L={:.1e})",
                        r.times.last().unwrap(),
                        r.losses.last().unwrap()
                    ),
                }
            };
            json_rows.push(Json::obj(vec![
                ("paths", Json::Str(paths.label().into())),
                ("n", Json::Num(n as f64)),
                ("lr", Json::Num(lr)),
                (
                    "time_to_target_s",
                    match r.time_to_target {
                        Some(t) => Json::Num(t),
                        None => Json::Null,
                    },
                ),
                ("final_loss", Json::Num(*r.losses.last().unwrap_or(&f64::NAN))),
                ("diverged", Json::Bool(r.diverged)),
                ("final_theta", Json::Num(r.final_theta)),
            ]));
            row.push(cell);
        }
        rows.push(row);
    }
    print_table(
        "Table 1 — wall clock to loss < 1e-4 [s]",
        &["paths", "n=1", "n=10", "n=100", "n=100 low-lr"],
        &rows,
    );
    println!("\npaper (authors' GPU, s): Adv+P 1.08/6.85/63.2/674 | P 0.69/6.71/157/1611 | Adv 0.78/5.48/52.1/552 | none 0.52/4.39/-/-");
    write_report("table1_gradient_paths", &[], vec![("rows", Json::Arr(json_rows))])
        .expect("bench report must be written durably");
}
