//! Figures 8/9/10 + B.21 — backward-facing step: corrector vs No-Model MSE
//! over horizons, wall skin-friction sign change (reattachment), and the
//! reattachment-length-vs-Re validation curve.

use pict::adjoint::GradientPaths;
use pict::coordinator::experiments::corrector2d::*;
use pict::fvm;
use pict::mesh::{field, gen};
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};
use pict::util::bench::{print_table, write_report};
use pict::util::json::Json;

/// Reattachment length: last downstream x where bottom-wall Cf < 0.
fn reattachment_length(solver: &PisoSolver, state: &State, cfg: &gen::BfsCfg) -> f64 {
    let mesh = &solver.mesh;
    let b2 = &mesh.blocks[2]; // lower downstream block
    let mut xr = 0.0;
    for i in 0..b2.shape[0] {
        let cell = b2.offset + b2.lidx(i, 0, 0);
        let u = state.u.comp[0][cell];
        let y = mesh.centers[cell][1];
        let dudy = u / y; // one-sided at the wall
        if dudy < 0.0 {
            xr = mesh.centers[cell][0] / cfg.s;
        }
    }
    xr
}

fn main() {
    // --- Fig B.21: reattachment length vs Re (forward-only validation) ---
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for re in [100.0, 200.0, 400.0] {
        let cfg = gen::BfsCfg {
            nx_in: 6,
            nx_down: 32,
            ny_up: 8,
            ny_low: 6,
            l_down: 20.0,
            ..Default::default()
        };
        let mesh = gen::bfs(&cfg);
        let nu = 2.0 * cfg.h * cfg.u_bulk / re;
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.05, target_cfl: Some(0.7), use_ilu: true, ..Default::default() },
            nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        let src = pict::mesh::VectorField::zeros(solver.mesh.ncells);
        solver.run(&mut state, &src, 400);
        let xr = reattachment_length(&solver, &state, &cfg);
        rows.push(vec![format!("{re}"), format!("{xr:.2}")]);
        jrows.push(Json::obj(vec![("re", Json::Num(re)), ("xr_over_s", Json::Num(xr))]));
    }
    print_table("Fig B.21 — reattachment length x_r/s vs Re", &["Re", "x_r/s"], &rows);
    println!("paper shape: x_r/s grows with Re in the laminar regime (Armaly)");

    // --- Fig 9: corrector vs No-Model on a coarse BFS ---
    let coarse_bfs = gen::BfsCfg {
        nx_in: 4,
        nx_down: 16,
        ny_up: 6,
        ny_low: 4,
        l_down: 15.0,
        ..Default::default()
    };
    let fine_bfs = gen::BfsCfg {
        nx_in: 8,
        nx_down: 32,
        ny_up: 12,
        ny_low: 8,
        l_down: 15.0,
        ..Default::default()
    };
    let re = 300.0;
    let nu = 2.0 * coarse_bfs.h * coarse_bfs.u_bulk / re;
    let coarse_mesh = gen::bfs(&coarse_bfs);
    let cfg = Corrector2dCfg {
        t_ratio: 2,
        n_frames: 40,
        fine_warmup: 120,
        curriculum: vec![3, 5],
        opt_steps_per_stage: 40,
        lr: 2e-3,
        paths: GradientPaths::NONE,
        lambda_div: 1e-3,
        output_scale: 0.1,
        seed: 0xBF5,
        ..Default::default()
    };
    let mk = |mesh: pict::mesh::Mesh, dt: f64| {
        PisoSolver::new(
            mesh,
            PisoConfig { dt, use_ilu: true, ..Default::default() },
            nu,
            ExecCtx::from_env(),
        )
    };
    let mut fine = mk(gen::bfs(&fine_bfs), 0.04);
    let mut fstate = State::zeros(&fine.mesh);
    let frames = make_reference_frames(&mut fine, &mut fstate, &coarse_mesh, &cfg);
    let mut coarse = mk(coarse_mesh.clone(), 0.08);
    let (net, _) = train_corrector2d(&mut coarse, &frames, &cfg);
    let cps = [10usize, 20, 35];
    let mut s1 = mk(coarse_mesh.clone(), 0.08);
    let base = evaluate_corrector(&mut s1, None, cfg.output_scale, &frames, &cps);
    let mut s2 = mk(coarse_mesh.clone(), 0.08);
    let nn = evaluate_corrector(&mut s2, Some(&net), cfg.output_scale, &frames, &cps);
    let mut rows = Vec::new();
    for ((step, mb, _), (_, mn, _)) in base.iter().zip(&nn) {
        rows.push(vec![
            format!("{step}"),
            format!("{mb:.3e}"),
            format!("{mn:.3e}"),
            format!("{:.1}x", mb / mn),
        ]);
        jrows.push(Json::obj(vec![
            ("step", Json::Num(*step as f64)),
            ("mse_no_model", Json::Num(*mb)),
            ("mse_nn", Json::Num(*mn)),
        ]));
    }
    print_table(
        "Fig 9 — BFS avg-u MSE vs horizon",
        &["step", "No-Model", "NN", "improvement"],
        &rows,
    );
    println!("paper shape: ~110x improvement at the longest horizon (6000 steps, full scale)");

    // --- Fig 10: bottom-wall Cf profile sanity (sign change = reattachment) ---
    let mut s3 = mk(coarse_mesh, 0.08);
    let mut st3 = State::zeros(&s3.mesh);
    st3.u = frames[0].clone();
    let zero = pict::mesh::VectorField::zeros(s3.mesh.ncells);
    s3.run(&mut st3, &zero, 30);
    let b2 = &s3.mesh.blocks[2];
    let cell0 = b2.offset + b2.lidx(0, 0, 0);
    let _ = fvm::pressure_gradient(&s3.mesh, &st3.p);
    let u_nearwall = field::sample_idw(&s3.mesh, &st3.u.comp[0], s3.mesh.centers[cell0]);
    println!("\nFig 10 proxy: near-step bottom-wall u = {u_nearwall:.3e} (recirculation ⇒ negative)");
    write_report("fig9_bfs", &[], vec![("rows", Json::Arr(jrows))])
        .expect("bench report must be written durably");
}
