//! §5.4 runtime comparison: coarse+NN hybrid vs the fine reference solve at
//! matched accuracy (the paper's PICT+NN-vs-OpenFOAM/medium-res comparison,
//! self-substituted per DESIGN.md §5), plus the xla-vs-native engine
//! comparison for the AOT hot path and the solver-fraction profile.

use pict::coordinator::experiments::tcf_sgs::*;
use pict::piso::State;
use pict::util::bench::{print_table, write_report, Bench};
use pict::util::timer;

fn main() {
    // --- coarse+NN vs fine channel at matched statistics accuracy ---
    let cfg = TcfSgsCfg { coarse_n: [8, 8, 4], ..Default::default() };
    let target = reference_statistics(&cfg, [12, 14, 6], 120);
    let result = train_tcf_sgs(&cfg, &target);
    let steps = 60;
    let bench = Bench::new(0, 1);
    let r_nn = bench.run("coarse + learned SGS (60 steps)", || {
        eval_sgs(&cfg, Some(&result.net), &target, steps)
    });
    let r_base = bench.run("coarse no-SGS     (60 steps)", || {
        eval_sgs(&cfg, None, &target, steps)
    });
    // the fine reference: same horizon at the reference resolution (timing
    // only — a zero-weight target keeps the loss hook grid-agnostic)
    let fine_cfg = TcfSgsCfg { coarse_n: [12, 14, 6], dt: cfg.dt * 0.5, ..cfg.clone() };
    let dummy_target = pict::train::StatsTarget {
        mean: [vec![], vec![], vec![]],
        stress: [vec![], vec![], vec![], vec![]],
        w_mean: [0.0; 3],
        w_stress: [0.0; 4],
    };
    let r_fine = bench.run("fine reference    (120 steps)", || {
        eval_sgs(&fine_cfg, None, &dummy_target, steps * 2)
    });
    let acc_nn = eval_sgs(&cfg, Some(&result.net), &target, steps);
    let acc_no = eval_sgs(&cfg, None, &target, steps);
    let tail = |v: &[f64]| v[v.len() - 10..].iter().sum::<f64>() / 10.0;
    let rows = vec![
        vec![
            "coarse+NN".into(),
            format!("{:.2}s", r_nn.mean_s),
            format!("{:.3e}", tail(&acc_nn)),
        ],
        vec![
            "coarse no-SGS".into(),
            format!("{:.2}s", r_base.mean_s),
            format!("{:.3e}", tail(&acc_no)),
        ],
        vec!["fine reference".into(), format!("{:.2}s", r_fine.mean_s), "~0 (is the target)".into()],
    ];
    print_table("§5.4 — wall clock vs statistics error", &["config", "time", "stats err"], &rows);
    println!(
        "speedup of coarse+NN over fine: {:.1}x at {:.1}x lower error than coarse-no-model",
        r_fine.mean_s / r_nn.mean_s,
        tail(&acc_no) / tail(&acc_nn)
    );
    println!("paper: 40x over OpenFOAM at 36% lower aggregate error (full scale)");

    // --- AOT engine: xla piso_step2d vs native step at the E4 shape ---
    // (requires the off-by-default `pjrt` feature: the runtime module needs
    // the unvendored xla/anyhow crates)
    #[cfg(not(feature = "pjrt"))]
    {
        println!("pjrt feature disabled; skipping xla engine comparison");
        write_report("runtime_5_4", &[r_nn, r_base, r_fine], vec![])
            .expect("bench report must be written durably");
    }
    #[cfg(feature = "pjrt")]
    if let Ok(mut set) =
        pict::runtime::ArtifactSet::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    {
        use pict::mesh::{gen, VectorField};
        use pict::piso::{PisoConfig, PisoSolver};
        let (ny, nx) = (16usize, 18);
        let mesh = gen::periodic_box2d(nx, ny, 1.0, 1.0);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.01, ..Default::default() },
            0.02,
            pict::par::ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).cos() * 0.5;
            state.u.comp[1][i] = (6.28 * c[0]).sin() * 0.3;
        }
        let src = VectorField::zeros(solver.mesh.ncells);
        let rb = bench.run("native PISO step 18x16", || {
            let mut s = state.clone();
            solver.step(&mut s, &src, None)
        });
        let exe = set.get("piso_step2d").expect("artifact");
        // row-major [ny][nx] layout: cell (i,j) -> j*nx + i matches x-fastest
        let pack = |v: &Vec<f64>| v.clone();
        let scal = |x: f64| vec![x];
        let args = vec![
            pack(&state.u.comp[0]),
            pack(&state.u.comp[1]),
            pack(&state.p),
            vec![0.0; nx * ny],
            vec![0.0; nx * ny],
            scal(0.02),
            scal(0.01),
            scal(1.0 / nx as f64),
            scal(1.0 / ny as f64),
        ];
        let rx = bench.run("xla AOT PISO step 18x16", || exe.run_f64(&args).unwrap());
        // cross-validate numerics
        let out = exe.run_f64(&args).unwrap();
        let mut s = state.clone();
        solver.step(&mut s, &src, None);
        let err = pict::util::rel_l2(&out[0], &s.u.comp[0]);
        println!("xla-vs-native u relative L2: {err:.2e} (AOT artifact reproduces the native step)");
        assert!(err < 1e-5, "cross-engine mismatch {err}");
        write_report(
            "runtime_5_4",
            &[rb, rx, r_nn, r_base, r_fine],
            vec![("xla_native_rel_l2", pict::util::json::Json::Num(err))],
        )
        .expect("bench report must be written durably");
    } else {
        println!("artifacts not built; skipping xla engine comparison (run `make artifacts`)");
        write_report("runtime_5_4", &[r_nn, r_base, r_fine], vec![])
            .expect("bench report must be written durably");
    }

    // --- solver fraction profile (the paper's 70-90% linear-solve claim) ---
    timer::set_profiling(true);
    timer::reset_profile();
    let mut s2 = coarse_solver(&cfg);
    let mut st2 = State::zeros(&s2.mesh);
    st2.u = perturbed_channel_init(&s2.mesh, cfg.l[1], 0.4, 3);
    let src = forcing_field(&s2.mesh, cfg.forcing);
    s2.run(&mut st2, &src, 30);
    println!("\nPISO step profile:\n{}", timer::profile_table());
    timer::set_profiling(false);
}
