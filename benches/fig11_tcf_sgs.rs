//! Figures 11/12/13 + Table B.5 — TCF SGS: train the statistics-only SGS
//! corrector, then compare no-SGS / Smagorinsky / learned on (i) the
//! per-frame statistics loss over a long rollout (Fig 13), (ii) the mean +
//! Reynolds-stress profiles (Fig 11), (iii) aggregated Λ_MSE (Table B.5),
//! and (iv) energy-budget production/dissipation shapes (Fig 12).

use pict::coordinator::experiments::tcf_sgs::*;
use pict::stats;
use pict::util::bench::{print_table, write_report};
use pict::util::json::Json;

fn main() {
    let cfg = TcfSgsCfg { coarse_n: [8, 8, 4], ..Default::default() };
    println!("building reference statistics (fine channel)...");
    let target = reference_statistics(&cfg, [12, 14, 6], 160);
    println!("training SGS corrector ({} steps)...", cfg.opt_steps);
    let result = train_tcf_sgs(&cfg, &target);

    // Fig 13: per-frame stats loss over a rollout ~2x the training horizon
    let steps = 80;
    let no_sgs = eval_sgs(&cfg, None, &target, steps);
    let smag = eval_smagorinsky(&cfg, &target, steps, 0.1);
    let learned = eval_sgs(&cfg, Some(&result.net), &target, steps);
    let tail = |v: &[f64]| v[v.len() - 20..].iter().sum::<f64>() / 20.0;
    let rows = vec![
        vec!["no SGS".into(), format!("{:.3e}", no_sgs[0]), format!("{:.3e}", tail(&no_sgs))],
        vec!["SMAG".into(), format!("{:.3e}", smag[0]), format!("{:.3e}", tail(&smag))],
        vec![
            "CNN SGS (ours)".into(),
            format!("{:.3e}", learned[0]),
            format!("{:.3e}", tail(&learned)),
        ],
    ];
    print_table(
        "Fig 13 — per-frame statistics loss (initial / long-rollout tail)",
        &["model", "first frame", "tail (beyond training horizon)"],
        &rows,
    );
    println!("paper shape: learned ≈ 2 orders better than no-SGS/SMAG at full scale, stable 50x beyond the training horizon");

    // Table B.5: per-statistic aggregated error of the learned model vs the
    // no-SGS run (Λ_MSE roles of PICT+CNN vs OpenFOAM)
    let agg = |losses: &[f64]| tail(losses);
    let rows = vec![
        vec!["Λ (stats loss, tail)".into(), format!("{:.3e}", agg(&learned)), format!("{:.3e}", agg(&no_sgs)), format!("{:.3e}", agg(&smag))],
    ];
    print_table(
        "Table B.5 (scaled) — aggregate statistics error",
        &["metric", "PICT+CNN SGS", "no SGS", "SMAG"],
        &rows,
    );

    // Fig 12 proxy: production/dissipation budget signs from a short frame set
    let mut solver = coarse_solver(&cfg);
    let mut state = pict::piso::State::zeros(&solver.mesh);
    state.u = perturbed_channel_init(&solver.mesh, cfg.l[1], 0.4, 1);
    let src = forcing_field(&solver.mesh, cfg.forcing);
    solver.run(&mut state, &src, 40);
    let mut frames = Vec::new();
    for _ in 0..10 {
        solver.step(&mut state, &src, None);
        frames.push((state.u.clone(), state.p.clone()));
    }
    let budgets = stats::energy_budgets(&solver.mesh, &frames, cfg.nu);
    let mid = budgets.y.len() / 2;
    println!(
        "\nFig 12 proxy: production[{mid}] = {:.3e}, dissipation[{mid}] = {:.3e} (dissipation ≥ 0)",
        budgets.production[mid], budgets.dissipation[mid]
    );
    // Ablation (DESIGN.md): the eq.-11 divergence gradient modification —
    // train a shorter run with and without it and compare rollout tails
    let abl_base = TcfSgsCfg { coarse_n: [8, 8, 4], opt_steps: 60, ..Default::default() };
    let abl_off = TcfSgsCfg { lambda_div: 0.0, ..abl_base.clone() };
    let r_on = train_tcf_sgs(&abl_base, &target);
    let r_off = train_tcf_sgs(&abl_off, &target);
    let e_on = eval_sgs(&abl_base, Some(&r_on.net), &target, 60);
    let e_off = eval_sgs(&abl_off, Some(&r_off.net), &target, 60);
    println!(
        "\nAblation eq.11 (divergence gradient modification, SHORT 60-step training): tail with = {:.3e}, without = {:.3e}",
        tail(&e_on), tail(&e_off)
    );
    println!("(at this scale/budget the rollout effect is within run-to-run noise; the mechanism itself is validated by train::loss::div_modification_targets_divergent_part)");

    write_report(
        "fig11_tcf_sgs",
        &[],
        vec![
            ("fig13_no_sgs", Json::arr_f64(&no_sgs)),
            ("fig13_smag", Json::arr_f64(&smag)),
            ("fig13_learned", Json::arr_f64(&learned)),
            ("train_losses", Json::arr_f64(&result.train_losses)),
            ("ablation_div_mod_on", Json::arr_f64(&e_on)),
            ("ablation_div_mod_off", Json::arr_f64(&e_off)),
        ],
    )
    .expect("bench report must be written durably");
}
