//! Mixed-precision Krylov hot path vs the all-f64 baseline: f32-storage /
//! f64-accumulation SpMV against the f64 kernel, iterative-refinement CG
//! against plain f64 CG on pressure-solve systems, and the end-to-end PISO
//! step at `Precision::Mixed` vs `Precision::F64` — each at 1 and 4 pool
//! workers, 32x32 up to 128x128. Also times the cross-step mirror refresh
//! (values-only renarrowing) against a from-scratch `Csr32::from_f64` to
//! pin the amortization claim. Emits `reports/BENCH_mixed_precision.json`.

use pict::coordinator::scenario::{LidDrivenCavity, Scenario};
use pict::fvm;
use pict::linsolve::{cg, refined_cg, Jacobi, Precision, SolveOpts};
use pict::mesh::gen;
use pict::par::ExecCtx;
use pict::sparse::Csr32;
use pict::util::bench::{print_table, write_report, Bench, BenchResult};
use pict::util::json::Json;

fn pressure_matrix(n: usize) -> pict::sparse::Csr {
    let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
    let a_inv = vec![1.0; mesh.ncells];
    let mut m = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&ExecCtx::serial(), &mesh, &a_inv, &mut m);
    m
}

/// A consistent, mean-free RHS shaped like a divergence field.
fn mean_free_rhs(n: usize) -> Vec<f64> {
    let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
    let mut rhs: Vec<f64> = mesh
        .centers
        .iter()
        .map(|c| (7.1 * c[0]).sin() * (3.3 * c[1]).cos())
        .collect();
    let mean = rhs.iter().sum::<f64>() / rhs.len() as f64;
    rhs.iter_mut().for_each(|v| *v -= mean);
    rhs
}

fn main() {
    let bench = Bench::new(2, 10);
    let mut all: Vec<BenchResult> = Vec::new();
    let mut jrows = Vec::new();

    // --- SpMV: f64 CSR vs f32-storage mirror (f64 accumulation) ---
    let mut spmv_rows = Vec::new();
    for n in [32usize, 64, 128] {
        let a = pressure_matrix(n);
        let a32 = Csr32::from_f64(&a);
        let x: Vec<f64> = (0..a.n).map(|i| ((i * 31 % 97) as f64) * 0.01 - 0.5).collect();
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut y = vec![0.0; a.n];
        let mut y32 = vec![0.0f32; a.n];
        let reps = (4_000_000 / a.nnz()).max(1);
        for t in [1usize, 4] {
            let ctx = ExecCtx::with_threads(t);
            let r64 = bench.run(&format!("spmv f64 {n}x{n} x{t} (x{reps})"), || {
                for _ in 0..reps {
                    ctx.matvec_chunks(&a, &x, &mut y, t);
                    std::hint::black_box(&y);
                }
            });
            let r32 = bench.run(&format!("spmv f32-storage {n}x{n} x{t} (x{reps})"), || {
                for _ in 0..reps {
                    ctx.matvec32_chunks(&a32, &x32, &mut y32, t);
                    std::hint::black_box(&y32);
                }
            });
            let speedup = r64.mean_s / r32.mean_s;
            spmv_rows.push(vec![
                format!("{n}x{n}"),
                format!("{t}"),
                format!("{:.1}us", r64.mean_s / reps as f64 * 1e6),
                format!("{:.1}us", r32.mean_s / reps as f64 * 1e6),
                format!("{speedup:.2}x"),
            ]);
            jrows.push(Json::obj(vec![
                ("kernel", Json::Str("spmv".to_string())),
                ("n", Json::Num(n as f64)),
                ("threads", Json::Num(t as f64)),
                ("f64_s", Json::Num(r64.mean_s)),
                ("mixed_s", Json::Num(r32.mean_s)),
                ("mixed_speedup", Json::Num(speedup)),
            ]));
            all.push(r64);
            all.push(r32);
        }
    }
    print_table(
        "SpMV: f32-storage/f64-accumulation vs f64 (pressure matrix, per matvec)",
        &["system", "threads", "f64", "mixed", "speedup"],
        &spmv_rows,
    );

    // --- CG: plain f64 vs iterative refinement, same f64 tolerance ---
    let mut cg_rows = Vec::new();
    for n in [32usize, 64, 128] {
        let a = pressure_matrix(n);
        let a32 = Csr32::from_f64(&a);
        let rhs = mean_free_rhs(n);
        let precond = Jacobi::new(&a);
        let opts = SolveOpts { tol: 1e-8, max_iter: 4000, ..Default::default() };
        let mixed_opts = SolveOpts { precision: Precision::Mixed, ..opts };
        let mut x = vec![0.0; a.n];
        for t in [1usize, 4] {
            let ctx = ExecCtx::with_threads(t);
            let r64 = bench.run(&format!("cg f64 {n}x{n} x{t}"), || {
                x.iter_mut().for_each(|v| *v = 0.0);
                let st = cg(&ctx, &a, &rhs, &mut x, &precond, true, opts);
                assert!(st.converged, "f64 CG must converge on the pressure system");
            });
            let rmx = bench.run(&format!("cg mixed {n}x{n} x{t}"), || {
                x.iter_mut().for_each(|v| *v = 0.0);
                let st = refined_cg(&ctx, &a, &a32, &rhs, &mut x, &precond, true, mixed_opts);
                assert!(st.converged, "mixed CG must converge to the same f64 tolerance");
            });
            let speedup = r64.mean_s / rmx.mean_s;
            cg_rows.push(vec![
                format!("{n}x{n}"),
                format!("{t}"),
                format!("{:.3}ms", r64.mean_s * 1e3),
                format!("{:.3}ms", rmx.mean_s * 1e3),
                format!("{speedup:.2}x"),
            ]);
            jrows.push(Json::obj(vec![
                ("kernel", Json::Str("cg".to_string())),
                ("n", Json::Num(n as f64)),
                ("threads", Json::Num(t as f64)),
                ("f64_s", Json::Num(r64.mean_s)),
                ("mixed_s", Json::Num(rmx.mean_s)),
                ("mixed_speedup", Json::Num(speedup)),
            ]));
            all.push(r64);
            all.push(rmx);
        }
    }
    print_table(
        "CG to tol=1e-8: f64 vs mixed iterative refinement",
        &["system", "threads", "f64", "mixed", "speedup"],
        &cg_rows,
    );

    // --- end-to-end PISO step: Precision::F64 vs Precision::Mixed ---
    let step_bench = Bench::new(1, 5);
    let steps_per_sample = 2usize;
    let mut step_rows = Vec::new();
    for n in [32usize, 64, 128] {
        for t in [1usize, 4] {
            let mut mean = [0.0f64; 2];
            for (slot, precision) in [Precision::F64, Precision::Mixed].into_iter().enumerate() {
                let mut run = LidDrivenCavity { n, re: 100.0, ..Default::default() }.build();
                run.solver.ctx = ExecCtx::with_threads(t);
                run.solver.cfg.precision = precision;
                let label = if precision.is_mixed() { "mixed" } else { "f64" };
                let mut state = run.state;
                let r = step_bench.run(&format!("step {label} cavity {n}x{n} x{t}"), || {
                    let st = run.solver.run(&mut state, &run.source, steps_per_sample);
                    std::hint::black_box(st);
                });
                mean[slot] = r.mean_s;
                all.push(r);
            }
            let speedup = mean[0] / mean[1];
            step_rows.push(vec![
                format!("{n}x{n}"),
                format!("{t}"),
                format!("{:.2}ms", mean[0] / steps_per_sample as f64 * 1e3),
                format!("{:.2}ms", mean[1] / steps_per_sample as f64 * 1e3),
                format!("{speedup:.2}x"),
            ]);
            jrows.push(Json::obj(vec![
                ("kernel", Json::Str("step".to_string())),
                ("n", Json::Num(n as f64)),
                ("threads", Json::Num(t as f64)),
                ("f64_s", Json::Num(mean[0])),
                ("mixed_s", Json::Num(mean[1])),
                ("mixed_speedup", Json::Num(speedup)),
            ]));
        }
    }
    print_table(
        "PISO step (lid-driven cavity, per step): Precision::F64 vs Precision::Mixed",
        &["system", "threads", "f64", "mixed", "speedup"],
        &step_rows,
    );

    // --- cross-step amortization: values-only refresh vs full rebuild ---
    let mut refresh_rows = Vec::new();
    for n in [64usize, 128] {
        let a = pressure_matrix(n);
        let mut mirror = Csr32::from_f64(&a);
        let reps = 200usize;
        let r_new = bench.run(&format!("mirror from_f64 {n}x{n} (x{reps})"), || {
            for _ in 0..reps {
                std::hint::black_box(Csr32::from_f64(&a));
            }
        });
        let r_refresh = bench.run(&format!("mirror refresh {n}x{n} (x{reps})"), || {
            for _ in 0..reps {
                mirror.refresh(&a);
                std::hint::black_box(&mirror);
            }
        });
        let speedup = r_new.mean_s / r_refresh.mean_s;
        refresh_rows.push(vec![
            format!("{n}x{n}"),
            format!("{:.1}us", r_new.mean_s / reps as f64 * 1e6),
            format!("{:.1}us", r_refresh.mean_s / reps as f64 * 1e6),
            format!("{speedup:.2}x"),
        ]);
        jrows.push(Json::obj(vec![
            ("kernel", Json::Str("mirror_refresh".to_string())),
            ("n", Json::Num(n as f64)),
            ("from_f64_s", Json::Num(r_new.mean_s)),
            ("refresh_s", Json::Num(r_refresh.mean_s)),
            ("refresh_speedup", Json::Num(speedup)),
        ]));
        all.push(r_new);
        all.push(r_refresh);
    }
    print_table(
        "Csr32 mirror: from-scratch rebuild vs values-only refresh (per call)",
        &["system", "from_f64", "refresh", "speedup"],
        &refresh_rows,
    );

    write_report("BENCH_mixed_precision", &all, vec![("rows", Json::Arr(jrows))])
        .expect("bench report must be written durably");
}
