//! Figure 6 (loss curves) + Figures C.22/C.23: loss-vs-iteration curves of
//! the gradient-path ablation, and the direct lid-velocity / viscosity /
//! joint optimizations on the lid-driven cavity.

use pict::adjoint::GradientPaths;
use pict::coordinator::experiments::{
    gradient_path_ablation, optimize_cavity_params, CavityOptCfg, GradPathCfg,
};
use pict::util::bench::write_report;
use pict::util::json::Json;

fn main() {
    // Fig 6: loss curves per variant at n = 10
    let mut curves = Vec::new();
    for paths in
        [GradientPaths::FULL, GradientPaths::P, GradientPaths::ADV, GradientPaths::NONE]
    {
        let cfg = GradPathCfg {
            n_steps: 10,
            lr: 0.04,
            opt_iters: 40,
            paths,
            ..Default::default()
        };
        let r = gradient_path_ablation(&cfg);
        println!(
            "fig6 n=10 {:<6} loss {:.3e} -> {:.3e} ({} iters, {:.2}s)",
            r.label,
            r.losses[0],
            r.losses.last().unwrap(),
            r.losses.len(),
            r.times.last().unwrap()
        );
        curves.push(Json::obj(vec![
            ("paths", Json::Str(r.label.into())),
            ("losses", Json::arr_f64(&r.losses)),
            ("times", Json::arr_f64(&r.times)),
        ]));
    }

    // Fig C.22: lid velocity and viscosity optimizations (n=8/steps=6 —
    // the configuration the default learning rates are calibrated for)
    let small = CavityOptCfg { n: 8, steps: 6, ..Default::default() };
    let lid = optimize_cavity_params(&CavityOptCfg { opt_iters: 60, ..small.clone() });
    println!(
        "C.22 lid: 1.0 -> {:.4} (target 0.2), loss {:.2e} -> {:.2e}",
        lid.lid_history.last().unwrap(),
        lid.losses[0],
        lid.final_loss
    );
    let visc = optimize_cavity_params(&CavityOptCfg {
        opt_lid: false,
        opt_nu: true,
        opt_iters: 80,
        lid: (0.5, 0.5, 0.0),
        ..small.clone()
    });
    println!(
        "C.22 nu: 5e-3 -> {:.5} (target 1e-3), loss {:.2e} -> {:.2e}",
        visc.nu_history.last().unwrap(),
        visc.losses[0],
        visc.final_loss
    );
    // Fig C.23: joint optimization — converges to SOME low-loss combination
    let joint = optimize_cavity_params(&CavityOptCfg {
        opt_lid: true,
        opt_nu: true,
        opt_iters: 100,
        // gentler rates: the joint landscape is a degenerate valley (C.23)
        lid: (0.5, 0.2, 4.0),
        nu: (3e-3, 1e-3, 5e-5),
        ..small
    });
    println!(
        "C.23 joint: lid {:.3} nu {:.5}, loss {:.2e} -> {:.2e} (non-unique minimum, paper C.23)",
        joint.lid_history.last().unwrap(),
        joint.nu_history.last().unwrap(),
        joint.losses[0],
        joint.final_loss
    );
    write_report(
        "fig6_optimization",
        &[],
        vec![
            ("fig6_curves", Json::Arr(curves)),
            ("lid_final", Json::Num(*lid.lid_history.last().unwrap())),
            ("nu_final", Json::Num(*visc.nu_history.last().unwrap())),
            ("joint_final_loss", Json::Num(joint.final_loss)),
        ],
    )
    .expect("bench report must be written durably");
}
