//! Parallel-execution scaling: the persistent worker pool vs the old
//! spawn-per-call scoped threads vs serial, on pressure-solve-sized systems
//! (the dominant cost per PISO step) from 32×32 up, plus the batched
//! scenario runner vs sequential execution. Chunk counts are pinned per
//! measurement via the `*_chunks` / `with_threads` entry points, so the
//! comparison is independent of `PICT_THREADS`. Emits
//! `reports/BENCH_par_pool.json` (pool vs spawn) and
//! `reports/par_scaling.json` (everything).

use pict::coordinator::scenario::{cavity_reynolds_sweep, BatchRunner};
use pict::fvm;
use pict::mesh::gen;
use pict::par::{spawn, ExecCtx};
use pict::util::bench::{print_table, write_report, Bench, BenchResult};
use pict::util::json::Json;

fn pressure_matrix(n: usize) -> pict::sparse::Csr {
    let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
    let a_inv = vec![1.0; mesh.ncells];
    let mut m = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&ExecCtx::serial(), &mesh, &a_inv, &mut m);
    m
}

fn main() {
    let bench = Bench::new(2, 10);
    let mut all: Vec<BenchResult> = Vec::new();
    let mut rows = Vec::new();
    let mut pool_rows = Vec::new();
    let mut jrows = Vec::new();
    let ctx = ExecCtx::with_threads(8);

    // --- SpMV scaling: serial vs spawn-per-call vs persistent pool ---
    for n in [32usize, 64, 128, 256] {
        let a = pressure_matrix(n);
        let x: Vec<f64> = (0..a.n).map(|i| ((i * 31 % 97) as f64) * 0.01 - 0.5).collect();
        let mut y = vec![0.0; a.n];
        // repeat the kernel inside each sample so timings are well above
        // clock resolution (a single small matvec is ~µs)
        let reps = (4_000_000 / a.nnz()).max(1);
        let r_serial = bench.run(&format!("matvec serial {n}x{n} (x{reps})"), || {
            for _ in 0..reps {
                a.matvec(&x, &mut y);
                std::hint::black_box(&y);
            }
        });
        let mut row = vec![format!("{n}x{n}"), format!("{:.3}ms", r_serial.mean_s * 1e3)];
        let mut speed4_pool = 0.0;
        for t in [2usize, 4, 8] {
            let r_spawn = bench.run(&format!("matvec spawn x{t} {n}x{n} (x{reps})"), || {
                for _ in 0..reps {
                    spawn::matvec_partitioned(&a, &x, &mut y, t);
                    std::hint::black_box(&y);
                }
            });
            let r_pool = bench.run(&format!("matvec pool x{t} {n}x{n} (x{reps})"), || {
                for _ in 0..reps {
                    ctx.matvec_chunks(&a, &x, &mut y, t);
                    std::hint::black_box(&y);
                }
            });
            let speedup_pool = r_serial.mean_s / r_pool.mean_s;
            let pool_vs_spawn = r_spawn.mean_s / r_pool.mean_s;
            if t == 4 {
                speed4_pool = speedup_pool;
            }
            row.push(format!("{speedup_pool:.2}x"));
            pool_rows.push(vec![
                format!("{n}x{n}"),
                format!("{t}"),
                format!("{:.1}us", r_spawn.mean_s / reps as f64 * 1e6),
                format!("{:.1}us", r_pool.mean_s / reps as f64 * 1e6),
                format!("{pool_vs_spawn:.2}x"),
            ]);
            jrows.push(Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("threads", Json::Num(t as f64)),
                ("serial_s", Json::Num(r_serial.mean_s)),
                ("spawn_s", Json::Num(r_spawn.mean_s)),
                ("pool_s", Json::Num(r_pool.mean_s)),
                ("pool_speedup_vs_serial", Json::Num(speedup_pool)),
                ("pool_speedup_vs_spawn", Json::Num(pool_vs_spawn)),
            ]));
            all.push(r_spawn);
            all.push(r_pool);
        }
        all.push(r_serial);
        rows.push(row);
        // correctness note: the pool kernel is bit-for-bit serial
        let mut y_ref = vec![0.0; a.n];
        a.matvec(&x, &mut y_ref);
        ctx.matvec_chunks(&a, &x, &mut y, 4);
        assert_eq!(y, y_ref, "pool matvec must be bit-for-bit serial");
        println!("  {n}x{n}: pool 4-chunk speedup vs serial {speed4_pool:.2}x");
    }
    print_table(
        "persistent-pool matvec speedup vs serial (pressure matrix)",
        &["system", "serial", "2T", "4T", "8T"],
        &rows,
    );
    print_table(
        "persistent pool vs spawn-per-call (per matvec)",
        &["system", "threads", "spawn", "pool", "pool/spawn"],
        &pool_rows,
    );
    write_report("BENCH_par_pool", &all, vec![("rows", Json::Arr(jrows.clone()))])
        .expect("bench report must be written durably");

    // --- batch runner: cavity Re sweep, sequential vs one shared pool ---
    let res = [50.0, 100.0, 200.0, 400.0];
    let steps = 30;
    let t0 = std::time::Instant::now();
    let seq = BatchRunner::new(steps).with_threads(1).run(&cavity_reynolds_sweep(24, &res));
    let t_seq = t0.elapsed().as_secs_f64();
    let nt = pict::par::env_threads().max(2);
    let t1 = std::time::Instant::now();
    let par_results =
        BatchRunner::new(steps).with_threads(nt).run(&cavity_reynolds_sweep(24, &res));
    let t_par = t1.elapsed().as_secs_f64();
    assert_eq!(seq.len(), par_results.len());
    for (a, b) in seq.iter().zip(&par_results) {
        assert_eq!(a.state.step, b.state.step);
    }
    println!(
        "\nbatch cavity Re sweep ({} scenarios x {steps} steps): sequential {t_seq:.2}s, \
         {nt}-worker shared pool {t_par:.2}s ({:.2}x)",
        res.len(),
        t_seq / t_par.max(1e-9)
    );
    jrows.push(Json::obj(vec![
        ("batch_seq_s", Json::Num(t_seq)),
        ("batch_par_s", Json::Num(t_par)),
        ("batch_threads", Json::Num(nt as f64)),
    ]));
    write_report("par_scaling", &all, vec![("rows", Json::Arr(jrows))])
        .expect("bench report must be written durably");
}
