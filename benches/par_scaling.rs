//! Parallel-execution scaling: row-partitioned SpMV vs the serial kernel on
//! pressure-solve-sized systems (the dominant cost per PISO step), and the
//! batched scenario runner vs sequential execution. Thread counts are pinned
//! per measurement via the `*_partitioned` / `with_threads` entry points, so
//! the comparison is independent of `PICT_THREADS`.

use pict::coordinator::scenario::{cavity_reynolds_sweep, BatchRunner};
use pict::fvm;
use pict::mesh::gen;
use pict::par;
use pict::util::bench::{print_table, write_report, Bench, BenchResult};
use pict::util::json::Json;

fn pressure_matrix(n: usize) -> pict::sparse::Csr {
    let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
    let a_inv = vec![1.0; mesh.ncells];
    let mut m = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&mesh, &a_inv, &mut m);
    m
}

fn main() {
    let bench = Bench::new(2, 10);
    let mut all: Vec<BenchResult> = Vec::new();
    let mut rows = Vec::new();
    let mut jrows = Vec::new();

    // --- SpMV scaling: serial vs partitioned at 1/2/4/8 chunks ---
    for n in [64usize, 128, 256] {
        let a = pressure_matrix(n);
        let x: Vec<f64> = (0..a.n).map(|i| ((i * 31 % 97) as f64) * 0.01 - 0.5).collect();
        let mut y = vec![0.0; a.n];
        // repeat the kernel inside each sample so timings are well above
        // clock resolution (a single small matvec is ~µs)
        let reps = (4_000_000 / a.nnz()).max(1);
        let r_serial = bench.run(&format!("matvec serial {n}x{n} (x{reps})"), || {
            for _ in 0..reps {
                a.matvec(&x, &mut y);
                std::hint::black_box(&y);
            }
        });
        let mut row = vec![format!("{n}x{n}"), format!("{:.3}ms", r_serial.mean_s * 1e3)];
        let mut speed4 = 0.0;
        for t in [2usize, 4, 8] {
            let r_par = bench.run(&format!("matvec par x{t} {n}x{n} (x{reps})"), || {
                for _ in 0..reps {
                    par::matvec_partitioned(&a, &x, &mut y, t);
                    std::hint::black_box(&y);
                }
            });
            let speedup = r_serial.mean_s / r_par.mean_s;
            if t == 4 {
                speed4 = speedup;
            }
            row.push(format!("{speedup:.2}x"));
            jrows.push(Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("threads", Json::Num(t as f64)),
                ("serial_s", Json::Num(r_serial.mean_s)),
                ("par_s", Json::Num(r_par.mean_s)),
                ("speedup", Json::Num(speedup)),
            ]));
            all.push(r_par);
        }
        all.push(r_serial);
        rows.push(row);
        // correctness note: the partitioned kernel is bit-for-bit serial
        let mut y_ref = vec![0.0; a.n];
        a.matvec(&x, &mut y_ref);
        par::matvec_partitioned(&a, &x, &mut y, 4);
        assert_eq!(y, y_ref, "partitioned matvec must be bit-for-bit serial");
        println!("  {n}x{n}: 4-thread speedup {speed4:.2}x (cores: {})", par::num_threads());
    }
    print_table(
        "parallel matvec speedup vs serial (pressure matrix)",
        &["system", "serial", "2T", "4T", "8T"],
        &rows,
    );

    // --- batch runner: cavity Re sweep, sequential vs pooled ---
    let res = [50.0, 100.0, 200.0, 400.0];
    let steps = 30;
    let t0 = std::time::Instant::now();
    let seq = BatchRunner::new(steps).with_threads(1).run(&cavity_reynolds_sweep(24, &res));
    let t_seq = t0.elapsed().as_secs_f64();
    let nt = par::num_threads().max(2);
    let t1 = std::time::Instant::now();
    let par_results =
        BatchRunner::new(steps).with_threads(nt).run(&cavity_reynolds_sweep(24, &res));
    let t_par = t1.elapsed().as_secs_f64();
    assert_eq!(seq.len(), par_results.len());
    for (a, b) in seq.iter().zip(&par_results) {
        assert_eq!(a.state.step, b.state.step);
    }
    println!(
        "\nbatch cavity Re sweep ({} scenarios x {steps} steps): sequential {t_seq:.2}s, \
         {nt}-thread {t_par:.2}s ({:.2}x)",
        res.len(),
        t_seq / t_par.max(1e-9)
    );
    jrows.push(Json::obj(vec![
        ("batch_seq_s", Json::Num(t_seq)),
        ("batch_par_s", Json::Num(t_par)),
        ("batch_threads", Json::Num(nt as f64)),
    ]));
    write_report("par_scaling", &all, vec![("rows", Json::Arr(jrows))]);
}
