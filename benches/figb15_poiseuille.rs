//! Figure B.15 — plane Poiseuille convergence: u-profiles vs the analytic
//! solution for increasing resolution, uniform vs wall-refined vs distorted
//! grids. Also Figure 3/B.16: lid-driven cavity centerline profiles vs the
//! Ghia reference across resolutions. Setups come from the scenario
//! registry (`coordinator::scenario`).

use pict::coordinator::references::{GHIA_RE100_U, GHIA_RE100_V};
use pict::coordinator::scenario::{LidDrivenCavity, Poiseuille, Scenario};
use pict::mesh::field;
use pict::util::bench::{print_table, write_report};
use pict::util::json::Json;

fn main() {
    // --- B.15: Poiseuille max error vs resolution ---
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for (ny, refined) in [(8, false), (16, false), (32, false), (16, true), (32, true)] {
        let scenario = Poiseuille { ny, refined, ..Default::default() };
        let mut run = scenario.build();
        run.solver.run(&mut run.state, &run.source, 40);
        let mut max_err = 0.0f64;
        for (cell, c) in run.solver.mesh.centers.iter().enumerate() {
            let exact = 0.5 * c[1] * (1.0 - c[1]);
            max_err = max_err.max((run.state.u.comp[0][cell] - exact).abs());
        }
        rows.push(vec![
            format!("{ny}{}", if refined { " refined" } else { "" }),
            format!("{:.2e}", max_err),
            format!("{:.2}%", 100.0 * max_err / 0.125),
        ]);
        jrows.push(Json::obj(vec![
            ("ny", Json::Num(ny as f64)),
            ("refined", Json::Bool(refined)),
            ("max_err", Json::Num(max_err)),
        ]));
    }
    print_table("Fig B.15 — Poiseuille max error vs analytic", &["grid", "max err", "rel"], &rows);

    // --- Fig 3 / B.16: cavity Re=100 profiles vs Ghia across resolutions ---
    let mut rows = Vec::new();
    for n in [16usize, 32] {
        let scenario = LidDrivenCavity { n, ..Default::default() };
        let mut run = scenario.build();
        run.solver.run(&mut run.state, &run.source, 1200);
        let mut worst_u = 0.0f64;
        for (y, u_ref) in GHIA_RE100_U {
            let u = field::sample_idw(&run.solver.mesh, &run.state.u.comp[0], [0.5, y, 0.5]);
            worst_u = worst_u.max((u - u_ref).abs());
        }
        let mut worst_v = 0.0f64;
        for (x, v_ref) in GHIA_RE100_V {
            let v = field::sample_idw(&run.solver.mesh, &run.state.u.comp[1], [x, 0.5, 0.5]);
            worst_v = worst_v.max((v - v_ref).abs());
        }
        rows.push(vec![format!("{n}x{n}"), format!("{worst_u:.3}"), format!("{worst_v:.3}")]);
        jrows.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("worst_u_err", Json::Num(worst_u)),
            ("worst_v_err", Json::Num(worst_v)),
        ]));
    }
    print_table(
        "Fig B.16 — cavity Re=100 centerline error vs Ghia (converges with resolution)",
        &["grid", "max |u-u_ghia|", "max |v-v_ghia|"],
        &rows,
    );
    write_report("figb15_poiseuille", &[], vec![("rows", Json::Arr(jrows))])
        .expect("bench report must be written durably");
}
