//! Tape-memory bench: peak resident fields, recompute counts, and backward
//! wall-time of the rollout tape under Full vs uniform-Checkpoint vs
//! binomial Revolve strategies (PR-4/PR-9 acceptance numbers: ≥ 4× peak
//! reduction at n = 64 / every = 8; revolve(8) strictly below ckpt(8) peak
//! with ≤ 2n re-steps; bit-for-bit equal gradients everywhere). Writes
//! `reports/BENCH_tape_checkpoint.json`.
//!
//! `PICT_TAPE_SMOKE=1` runs the single-repetition CI smoke mode (same
//! asserts, fewer timing repetitions).

use pict::adjoint::{GradientPaths, RolloutGrads, Tape, TapeStrategy};
use pict::coordinator::scenario::{Scenario, ScenarioRun, TaylorGreen};
use pict::mesh::VectorField;
use pict::piso::State;
use pict::util::bench::{print_table, write_report, Bench};
use pict::util::json::Json;
use std::time::Instant;

const N_STEPS: usize = 64;

fn terminal_ke(ncells: usize) -> impl FnMut(usize, &State) -> (VectorField, Vec<f64>) {
    move |step, st| {
        let mut du = VectorField::zeros(ncells);
        if step + 1 == N_STEPS {
            for c in 0..2 {
                for i in 0..ncells {
                    du.comp[c][i] = 2.0 * st.u.comp[c][i];
                }
            }
        }
        (du, vec![0.0; ncells])
    }
}

struct Sample {
    label: String,
    resident: usize,
    peak: usize,
    resteps: usize,
    record_s: f64,
    backward_s: f64,
    grads: RolloutGrads,
}

fn measure(scen: &TaylorGreen, strategy: TapeStrategy) -> Sample {
    let ScenarioRun { mut solver, mut state, source, .. } = scen.build();
    let ncells = solver.mesh.ncells;
    let t0 = Instant::now();
    let tape =
        Tape::record(&mut solver, &mut state, N_STEPS, strategy, |_, _| source.clone());
    let record_s = t0.elapsed().as_secs_f64();
    let resident = tape.resident_f64();
    let t1 = Instant::now();
    let (grads, stats) = tape.backward_with_stats(
        &mut solver,
        GradientPaths::FULL,
        |_, _| source.clone(),
        terminal_ke(ncells),
    );
    Sample {
        label: strategy.label(),
        resident,
        peak: stats.peak_resident_f64,
        resteps: stats.replayed_steps,
        record_s,
        backward_s: t1.elapsed().as_secs_f64(),
        grads,
    }
}

fn main() {
    let smoke = std::env::var("PICT_TAPE_SMOKE").is_ok();
    let scen = TaylorGreen { n: 20, nu: 0.01, dt: 0.01 };
    let strategies = [
        TapeStrategy::Full,
        TapeStrategy::Checkpoint { every: 4 },
        TapeStrategy::Checkpoint { every: 8 },
        TapeStrategy::Checkpoint { every: 16 },
        TapeStrategy::Revolve { snapshots: 4 },
        TapeStrategy::Revolve { snapshots: 8 },
    ];
    println!(
        "tape memory: {} x {N_STEPS} steps, backward with full gradient paths{}",
        scen.label(),
        if smoke { " [smoke]" } else { "" }
    );

    let samples: Vec<Sample> = strategies.iter().map(|&s| measure(&scen, s)).collect();
    let full = &samples[0];

    // every strategy must deliver the full tape's gradients, bit-for-bit
    for s in &samples[1..] {
        assert_eq!(s.grads.du0, full.grads.du0, "{}: du0 differs from full", s.label);
        assert_eq!(s.grads.dnu, full.grads.dnu, "{}: dnu differs from full", s.label);
    }
    // acceptance (PR-4): >= 4x peak-field reduction at every = 8
    let ckpt8 = &samples[2];
    assert!(
        ckpt8.peak * 4 <= full.peak,
        "ckpt(8) peak {} vs full {} — below the 4x acceptance bar",
        ckpt8.peak,
        full.peak
    );
    let reduction = full.peak as f64 / ckpt8.peak as f64;
    // acceptance (PR-9): under the same budget of 8 resident slots, the
    // binomial schedule's peak is strictly below uniform checkpointing's,
    // at a bounded recompute price (<= 2 extra forward passes)
    let rev8 = &samples[5];
    assert!(
        rev8.peak < ckpt8.peak,
        "revolve(8) peak {} must be strictly below ckpt(8) peak {}",
        rev8.peak,
        ckpt8.peak
    );
    assert!(
        rev8.resteps <= 2 * N_STEPS,
        "revolve(8) re-stepped {} times, over the 2n = {} budget",
        rev8.resteps,
        2 * N_STEPS
    );

    let rows: Vec<Vec<String>> = samples
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                format!("{}", s.resident),
                format!("{}", s.peak),
                format!("{:.1}x", full.peak as f64 / s.peak as f64),
                format!("{}", s.resteps),
                format!("{:.3}s", s.record_s),
                format!("{:.3}s", s.backward_s),
            ]
        })
        .collect();
    print_table(
        "rollout tape memory (f64 counts)",
        &["strategy", "resident", "peak", "vs full", "resteps", "record", "backward"],
        &rows,
    );
    println!("ckpt(8) peak reduction: {reduction:.1}x (acceptance >= 4x)");
    println!(
        "revolve(8) peak: {} ({:.1}x vs full), {} re-steps (budget {})",
        rev8.peak,
        full.peak as f64 / rev8.peak as f64,
        rev8.resteps,
        2 * N_STEPS
    );

    // repeatable wall-time samples for the report
    let bench = Bench::new(0, if smoke { 1 } else { 2 });
    let mut results = Vec::new();
    for &strategy in &strategies {
        results.push(bench.run(&format!("record+backward {}", strategy.label()), || {
            measure(&scen, strategy).backward_s
        }));
    }
    let memory = Json::Arr(
        samples
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("strategy", Json::Str(s.label.clone())),
                    ("resident_f64", Json::Num(s.resident as f64)),
                    ("peak_f64", Json::Num(s.peak as f64)),
                    ("replayed_steps", Json::Num(s.resteps as f64)),
                    ("record_s", Json::Num(s.record_s)),
                    ("backward_s", Json::Num(s.backward_s)),
                ])
            })
            .collect(),
    );
    write_report(
        "BENCH_tape_checkpoint",
        &results,
        vec![
            ("n_steps", Json::Num(N_STEPS as f64)),
            ("scenario", Json::Str(scen.label())),
            ("memory", memory),
            ("ckpt8_peak_reduction_x", Json::Num(reduction)),
            ("revolve8_peak_f64", Json::Num(rev8.peak as f64)),
            ("revolve8_replayed_steps", Json::Num(rev8.resteps as f64)),
        ],
    )
    .expect("bench report must be written durably");
}
