//! Table 2/3 + Figure 7 — vortex-street corrector: train NN_short and
//! NN_long (differing only in final unroll length, as the paper's NN_8 vs
//! NN_16) and compare vorticity correlation + MSE against No-Model at
//! several forward horizons. Expected shape: both NNs beat No-Model; the
//! longer unroll wins at long horizons.

use pict::adjoint::GradientPaths;
use pict::coordinator::experiments::corrector2d::*;
use pict::mesh::gen;
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};
use pict::util::bench::{print_table, write_report};
use pict::util::json::Json;

fn main() {
    let vs = gen::VortexStreetCfg { nx: [6, 4, 10], ny: [6, 4, 6], ..Default::default() };
    let fine_cfg =
        gen::VortexStreetCfg { nx: [12, 8, 20], ny: [12, 8, 12], ..Default::default() };
    let nu = vs.u_in * vs.obs_h / 400.0;
    let coarse_mesh = gen::vortex_street(&vs);
    let mk = |mesh: pict::mesh::Mesh, dt: f64| {
        PisoSolver::new(
            mesh,
            PisoConfig { dt, use_ilu: true, ..Default::default() },
            nu,
            ExecCtx::from_env(),
        )
    };
    let base_cfg = Corrector2dCfg {
        t_ratio: 2,
        n_frames: 50,
        fine_warmup: 100,
        opt_steps_per_stage: 50,
        lr: 2e-3,
        paths: GradientPaths::NONE,
        lambda_div: 1e-3,
        output_scale: 0.1,
        seed: 0xC0DE,
        curriculum: vec![],
        ..Default::default()
    };
    let mut fine = mk(gen::vortex_street(&fine_cfg), 0.04);
    let mut fs = State::zeros(&fine.mesh);
    let frames = make_reference_frames(&mut fine, &mut fs, &coarse_mesh, &base_cfg);

    // NN_short (unroll 3) vs NN_long (curriculum 3 -> 6), matched opt steps
    let cfg_short =
        Corrector2dCfg { curriculum: vec![3, 3], ..base_cfg.clone() };
    let cfg_long = Corrector2dCfg { curriculum: vec![3, 6], ..base_cfg.clone() };
    let mut cs = mk(coarse_mesh.clone(), 0.08);
    let (net_short, _) = train_corrector2d(&mut cs, &frames, &cfg_short);
    let mut cl = mk(coarse_mesh.clone(), 0.08);
    let (net_long, _) = train_corrector2d(&mut cl, &frames, &cfg_long);

    let cps = [10usize, 25, 45];
    let eval = |net: Option<&pict::nn::Cnn>| {
        let mut s = mk(coarse_mesh.clone(), 0.08);
        evaluate_corrector(&mut s, net, base_cfg.output_scale, &frames, &cps)
    };
    let rows_data = [
        ("No-Model", eval(None)),
        ("NN_short", eval(Some(&net_short))),
        ("NN_long", eval(Some(&net_long))),
    ];
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for (name, data) in &rows_data {
        let mut row = vec![name.to_string()];
        for (step, mse, corr) in data {
            row.push(format!("corr {corr:.3} / mse {mse:.2e}"));
            jrows.push(Json::obj(vec![
                ("model", Json::Str(name.to_string())),
                ("step", Json::Num(*step as f64)),
                ("mse", Json::Num(*mse)),
                ("vorticity_corr", Json::Num(*corr)),
            ]));
        }
        rows.push(row);
    }
    print_table(
        "Table 3 — vorticity correlation / MSE vs horizon",
        &["model", "step 10", "step 25", "step 45"],
        &rows,
    );
    println!("\npaper shape: NN_16 > NN_8 > No-Model in corr; ~10-20x lower MSE at the longest horizon");
    write_report("table3_vortex_street", &[], vec![("rows", Json::Arr(jrows))])
        .expect("bench report must be written durably");
}
