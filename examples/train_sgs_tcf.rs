//! END-TO-END DRIVER (paper §5.3, the headline learning result): train the
//! statistics-only SGS corrector for the coarse channel and show it beating
//! the no-SGS and Smagorinsky baselines on a rollout beyond the training
//! horizon. This exercises every layer: mesh/FVM/PISO forward, the DtO/OtD
//! adjoint, the multi-block CNN, the statistics losses, and (via
//! `--engine xla` in runtime_5_4) the AOT hot path.

use pict::coordinator::experiments::tcf_sgs::*;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let cfg = TcfSgsCfg {
        coarse_n: [8, 8, 4],
        opt_steps: args.usize_or("opt-steps", 150),
        ..Default::default()
    };
    println!("1/4 building reference statistics from the fine channel...");
    let target = reference_statistics(&cfg, [12, 14, 6], 160);
    println!("2/4 training SGS corrector ({} optimizer steps, J_none paths)...", cfg.opt_steps);
    let result = train_tcf_sgs(&cfg, &target);
    let tl = &result.train_losses;
    println!(
        "    training loss: {:.3e} -> {:.3e}",
        tl[..5.min(tl.len())].iter().sum::<f64>() / 5.0,
        tl[tl.len().saturating_sub(5)..].iter().sum::<f64>() / 5.0
    );
    println!("3/4 evaluating no-SGS / Smagorinsky / learned over a long rollout...");
    let steps = args.usize_or("eval-steps", 80);
    let no_sgs = eval_sgs(&cfg, None, &target, steps);
    let smag = eval_smagorinsky(&cfg, &target, steps, 0.1);
    let learned = eval_sgs(&cfg, Some(&result.net), &target, steps);
    let tail = |v: &[f64]| v[v.len() - 10..].iter().sum::<f64>() / 10.0;
    println!("4/4 results (per-frame statistics loss, tail of the rollout):");
    println!("    no SGS        : {:.4e}", tail(&no_sgs));
    println!("    Smagorinsky   : {:.4e}", tail(&smag));
    println!("    learned (ours): {:.4e}", tail(&learned));
    assert!(tail(&learned) < tail(&no_sgs), "learned model must beat no-SGS");
    println!("\nlearned SGS corrector reproduces the reference statistics — §5.3 shape holds");
}
