//! Quickstart: build a mesh, run a few PISO steps, differentiate through
//! them — the smallest end-to-end tour of the PICT API.

use pict::adjoint::{rollout_backward, GradientPaths, Tape, TapeStrategy};
use pict::mesh::{gen, VectorField};
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};

fn main() {
    // 1. mesh: a periodic 2D box (see mesh::gen for channels, cavities,
    //    multi-block vortex-street and BFS grids)
    let mesh = gen::periodic_box2d(32, 32, 1.0, 1.0);

    // 2. solver: PISO with two pressure correctors, ν = 0.01
    let mut solver = PisoSolver::new(
        mesh,
        PisoConfig { dt: 0.01, ..Default::default() },
        0.01,
        ExecCtx::from_env(),
    );

    // 3. initial state: a Taylor–Green vortex (shared scenario helper)
    let mut state = State::zeros(&solver.mesh);
    state.u = pict::coordinator::scenario::taylor_green_init(&solver.mesh);

    // 4. simulate
    let src = VectorField::zeros(solver.mesh.ncells);
    let stats = solver.run(&mut state, &src, 20);
    println!(
        "20 steps: dt={} max divergence={:.2e} (adv {} iters, p {} iters)",
        stats.dt, stats.max_divergence, stats.adv_iters, stats.p_iters
    );

    // 5. differentiate: gradient of the kinetic energy after 3 more steps
    //    with respect to the current velocity field. TapeStrategy::Full
    //    stores every step; Checkpoint { every } trades one recompute pass
    //    for O(n/k + k) memory; Revolve { snapshots } holds a *fixed*
    //    snapshot budget with a binomial-optimal replay schedule (≤ 2
    //    recompute passes) for long rollouts — bit-for-bit the same
    //    gradients whichever you pick (TapeStrategy::parse maps the CLI
    //    spellings "full" | "uniform:K" | "revolve:S").
    let ncells = solver.mesh.ncells;
    let tape = Tape::record(&mut solver, &mut state, 3, TapeStrategy::Full, |_, _| {
        VectorField::zeros(ncells)
    });
    let g = rollout_backward(
        &mut solver,
        &tape,
        GradientPaths::FULL,
        |_, _| VectorField::zeros(ncells),
        |step, st| {
            let mut du = VectorField::zeros(ncells);
            if step == 2 {
                for c in 0..2 {
                    for i in 0..ncells {
                        du.comp[c][i] = 2.0 * st.u.comp[c][i]; // d(Σu²)/du
                    }
                }
            }
            (du, vec![0.0; ncells])
        },
    );
    let gnorm: f64 =
        (0..2).map(|c| g.du0.comp[c].iter().map(|v| v * v).sum::<f64>()).sum::<f64>().sqrt();
    println!("|dE/du0| = {gnorm:.4e} — gradients flow through the full solver");
    println!("quickstart OK");
}
