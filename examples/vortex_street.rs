//! Vortex street on the 8-block grid-with-hole (paper §5.1 geometry): run
//! the flow past the square obstacle and report shedding diagnostics. Setup
//! comes from the scenario registry (`coordinator::scenario`).

use pict::coordinator::experiments::corrector2d::vorticity;
use pict::coordinator::scenario::{Scenario, VortexStreet};
use pict::mesh::field;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let steps = args.usize_or("steps", 300);
    let scenario = VortexStreet { re: args.f64_or("re", 500.0), ..Default::default() };
    let mut run = scenario.build();
    println!(
        "mesh: {} blocks, {} cells",
        run.solver.mesh.blocks.len(),
        run.solver.mesh.ncells
    );
    // probe behind the obstacle: v-velocity oscillates once shedding starts
    let geo = scenario.geometry();
    let probe = [geo.obs_x + geo.obs_w + 1.5, geo.ly / 2.0, 0.5];
    let mut history = Vec::new();
    for k in 0..steps {
        run.solver.step(&mut run.state, &run.source, None);
        let v = field::sample_idw(&run.solver.mesh, &run.state.u.comp[1], probe);
        history.push(v);
        if k % 50 == 0 {
            let w = vorticity(&run.solver.mesh, &run.state.u);
            let wmax = w.iter().fold(0.0f64, |a, b| a.max(b.abs()));
            println!(
                "step {k}: t={:.1} v(probe)={v:+.4} max|ω|={wmax:.3}",
                run.state.time
            );
        }
    }
    // count zero crossings of the probe signal in the second half
    let half = &history[steps / 2..];
    let crossings = half.windows(2).filter(|w| w[0].signum() != w[1].signum()).count();
    println!("\nprobe zero-crossings in second half: {crossings} (>0 indicates unsteady wake)");
    println!("(run with --steps 600 --re 500 for developed shedding)");
}
