//! Vortex street on the 8-block grid-with-hole (paper §5.1 geometry): run
//! the flow past the square obstacle and report shedding diagnostics.

use pict::coordinator::experiments::corrector2d::vorticity;
use pict::mesh::{field, gen, VectorField};
use pict::piso::{PisoConfig, PisoSolver, State};
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let re = args.f64_or("re", 500.0);
    let steps = args.usize_or("steps", 300);
    let cfg = gen::VortexStreetCfg {
        nx: [8, 6, 16],
        ny: [10, 6, 10],
        ..Default::default()
    };
    let mesh = gen::vortex_street(&cfg);
    println!("mesh: {} blocks, {} cells", mesh.blocks.len(), mesh.ncells);
    let nu = cfg.u_in * cfg.obs_h / re;
    let mut solver = PisoSolver::new(
        mesh,
        PisoConfig { dt: 0.05, target_cfl: Some(0.8), use_ilu: true, ..Default::default() },
        nu,
    );
    let mut state = State::zeros(&solver.mesh);
    // small transverse perturbation to break the symmetry and trigger
    // shedding onset within a short run
    for (i, c) in solver.mesh.centers.iter().enumerate() {
        state.u.comp[1][i] = 0.05 * (1.3 * c[0]).sin() * (0.9 * c[1]).cos();
    }
    let src = VectorField::zeros(solver.mesh.ncells);
    // probe behind the obstacle: v-velocity oscillates once shedding starts
    let probe = [cfg.obs_x + cfg.obs_w + 1.5, cfg.ly / 2.0, 0.5];
    let mut history = Vec::new();
    for k in 0..steps {
        solver.step(&mut state, &src, None);
        let v = field::sample_idw(&solver.mesh, &state.u.comp[1], probe);
        history.push(v);
        if k % 50 == 0 {
            let w = vorticity(&solver.mesh, &state.u);
            let wmax = w.iter().fold(0.0f64, |a, b| a.max(b.abs()));
            println!("step {k}: t={:.1} v(probe)={v:+.4} max|ω|={wmax:.3}", state.time);
        }
    }
    // count zero crossings of the probe signal in the second half
    let half = &history[steps / 2..];
    let crossings = half.windows(2).filter(|w| w[0].signum() != w[1].signum()).count();
    println!("\nprobe zero-crossings in second half: {crossings} (>0 indicates unsteady wake)");
    println!("(run with --steps 600 --re 500 for developed shedding)");
}
