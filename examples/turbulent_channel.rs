//! 3D channel flow statistics (paper Fig 4 at mini scale): run the channel,
//! accumulate online statistics, print the wall-normal profiles and u_τ.
//! Setup comes from the scenario registry (`coordinator::scenario`).

use pict::coordinator::scenario::{Scenario, TurbulentChannel};
use pict::stats::ChannelStats;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let steps = args.usize_or("steps", 300);
    let scenario = TurbulentChannel {
        n: [
            args.usize_or("nx", 12),
            args.usize_or("ny", 12),
            args.usize_or("nz", 6),
        ],
        nu: args.f64_or("nu", 0.004),
        forcing: args.f64_or("forcing", 0.01),
        ..Default::default()
    };
    let ly = scenario.l[1];
    let nu = scenario.nu;
    let mut run = scenario.build();
    // develop
    run.solver.run(&mut run.state, &run.source, steps / 3);
    // accumulate
    let mut stats = ChannelStats::new(&run.solver.mesh, nu);
    for _ in 0..(2 * steps / 3) {
        run.solver.step(&mut run.state, &run.source, None);
        stats.push(&run.solver.mesh, &run.state.u);
    }
    let (um, uu, vv, ww, uv) = stats.profiles();
    let u_tau = stats.u_tau();
    println!("u_tau = {u_tau:.4}, Re_tau ≈ {:.1}", u_tau * (ly / 2.0) / nu);
    println!(
        "\n{:>8} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "y", "U", "u'u'", "v'v'", "w'w'", "u'v'"
    );
    for j in 0..stats.y.len() {
        println!(
            "{:>8.4} {:>9.4} {:>9.5} {:>9.5} {:>9.5} {:>10.6}",
            stats.y[j], um[j], uu[j], vv[j], ww[j], uv[j]
        );
    }
}
