//! 3D channel flow statistics (paper Fig 4 at mini scale): run the channel,
//! accumulate online statistics, print the wall-normal profiles and u_τ.

use pict::coordinator::experiments::tcf_sgs::{forcing_field, perturbed_channel_init};
use pict::mesh::gen;
use pict::piso::{PisoConfig, PisoSolver, State};
use pict::stats::ChannelStats;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let n = [
        args.usize_or("nx", 12),
        args.usize_or("ny", 12),
        args.usize_or("nz", 6),
    ];
    let steps = args.usize_or("steps", 300);
    let nu = args.f64_or("nu", 0.004);
    let forcing = args.f64_or("forcing", 0.01);
    let l = [4.0, 2.0, 2.0];
    let mesh = gen::channel3d(n, l, 1.08);
    let mut solver =
        PisoSolver::new(mesh, PisoConfig { dt: 0.08, ..Default::default() }, nu);
    let mut state = State::zeros(&solver.mesh);
    state.u = perturbed_channel_init(&solver.mesh, l[1], 0.4, 1);
    let src = forcing_field(&solver.mesh, forcing);
    // develop
    solver.run(&mut state, &src, steps / 3);
    // accumulate
    let mut stats = ChannelStats::new(&solver.mesh, nu);
    for _ in 0..(2 * steps / 3) {
        solver.step(&mut state, &src, None);
        stats.push(&solver.mesh, &state.u);
    }
    let (um, uu, vv, ww, uv) = stats.profiles();
    let u_tau = stats.u_tau();
    println!("u_tau = {u_tau:.4}, Re_tau ≈ {:.1}", u_tau * (l[1] / 2.0) / nu);
    println!(
        "\n{:>8} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "y", "U", "u'u'", "v'v'", "w'w'", "u'v'"
    );
    for j in 0..stats.y.len() {
        println!(
            "{:>8.4} {:>9.4} {:>9.5} {:>9.5} {:>9.5} {:>10.6}",
            stats.y[j], um[j], uu[j], vv[j], ww[j], uv[j]
        );
    }
}
