//! Lid-driven cavity validation (paper Fig 3 / B.16): run to steady state
//! and print the u-centerline against the Ghia et al. reference. Setup comes
//! from the scenario registry (`coordinator::scenario`).

use pict::coordinator::references::GHIA_RE100_U;
use pict::coordinator::scenario::{LidDrivenCavity, Scenario};
use pict::mesh::field;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let steps = args.usize_or("steps", 1200);
    let scenario = LidDrivenCavity {
        n: args.usize_or("n", 32),
        re: args.f64_or("re", 100.0),
        refined: args.flag("refined"),
        ..Default::default()
    };
    let mut run = scenario.build();
    for k in 0..steps {
        let st = run.solver.step(&mut run.state, &run.source, None);
        if k % 200 == 0 {
            println!("step {k}: max div {:.2e}", st.max_divergence);
        }
    }
    println!("\n{:>8} {:>10} {:>10} {:>8}", "y", "u(sim)", "u(Ghia)", "err");
    for (y, u_ref) in GHIA_RE100_U {
        let u = field::sample_idw(&run.solver.mesh, &run.state.u.comp[0], [0.5, y, 0.5]);
        println!("{y:>8.4} {u:>10.5} {u_ref:>10.5} {:>8.1e}", (u - u_ref).abs());
    }
}
