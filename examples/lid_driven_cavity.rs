//! Lid-driven cavity validation (paper Fig 3 / B.16): run to steady state
//! and print the u-centerline against the Ghia et al. reference.

use pict::coordinator::references::GHIA_RE100_U;
use pict::mesh::{field, gen, VectorField};
use pict::piso::{PisoConfig, PisoSolver, State};
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let n = args.usize_or("n", 32);
    let re = args.f64_or("re", 100.0);
    let steps = args.usize_or("steps", 1200);
    let mesh = gen::cavity2d(n, 1.0, 1.0, args.flag("refined"));
    let mut solver =
        PisoSolver::new(mesh, PisoConfig { dt: 0.02, ..Default::default() }, 1.0 / re);
    let mut state = State::zeros(&solver.mesh);
    let src = VectorField::zeros(solver.mesh.ncells);
    for k in 0..steps {
        let st = solver.step(&mut state, &src, None);
        if k % 200 == 0 {
            println!("step {k}: max div {:.2e}", st.max_divergence);
        }
    }
    println!("\n{:>8} {:>10} {:>10} {:>8}", "y", "u(sim)", "u(Ghia)", "err");
    for (y, u_ref) in GHIA_RE100_U {
        let u = field::sample_idw(&solver.mesh, &state.u.comp[0], [0.5, y, 0.5]);
        println!("{y:>8.4} {u:>10.5} {u_ref:>10.5} {:>8.1e}", (u - u_ref).abs());
    }
}
