//! Batched scenario execution: advance every registered scenario, then a
//! cavity Reynolds-number sweep, concurrently on the worker pool — the
//! multi-rollout substrate for simulation-coupled training loops.

use pict::coordinator::scenario::{builtin_scenarios, cavity_reynolds_sweep, BatchRunner};
use pict::util::bench::print_table;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let steps = args.usize_or("steps", 20);

    // 1) the full registry in one call
    let scenarios = builtin_scenarios();
    let runner = BatchRunner::new(steps);
    println!(
        "advancing {} registered scenarios x {steps} steps on a {}-worker pool...",
        scenarios.len(),
        runner.threads()
    );
    let t0 = std::time::Instant::now();
    let results = runner.run(&scenarios);
    let wall = t0.elapsed().as_secs_f64();
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{}", r.state.step),
                format!("{:.2e}", r.max_divergence),
                format!("{}", r.adv_iters),
                format!("{}", r.p_iters),
                format!("{:.2}s", r.wall_s),
            ]
        })
        .collect();
    print_table(
        "batch run — all registered scenarios",
        &["scenario", "steps", "max div", "adv iters", "p iters", "wall"],
        &rows,
    );
    let busy: f64 = results.iter().map(|r| r.wall_s).sum();
    println!(
        "aggregate scenario time {busy:.2}s in {wall:.2}s wall ({:.2}x concurrency)",
        busy / wall.max(1e-9)
    );

    // 2) a parameter sweep: the cavity at several Reynolds numbers
    let res = [50.0, 100.0, 200.0, 400.0];
    let n = args.usize_or("n", 24);
    let sweep_steps = args.usize_or("sweep-steps", 150);
    println!("\ncavity Re sweep ({n}x{n}, {sweep_steps} steps each)...");
    let sweep = cavity_reynolds_sweep(n, &res);
    let results = BatchRunner::new(sweep_steps).run(&sweep);
    for r in &results {
        let ke: f64 = r
            .state
            .u
            .comp
            .iter()
            .map(|c| c.iter().map(|v| v * v).sum::<f64>())
            .sum();
        println!(
            "  {:<24} KE={ke:.4e}  max div={:.2e}  p iters={}",
            r.label, r.max_divergence, r.p_iters
        );
    }
}
