//! Batched scenario execution: advance every registered scenario, then a
//! cavity Reynolds-number sweep, concurrently on the worker pool — and
//! finally the gradient-producing variant: record checkpointed tapes for a
//! scenario batch and backpropagate a terminal loss through every rollout
//! in one call (the substrate for simulation-coupled training loops).

use pict::adjoint::{GradientPaths, TapeStrategy};
use pict::coordinator::scenario::{
    builtin_scenarios, cavity_reynolds_sweep, reduce_shared, BatchRunner,
    TerminalKineticEnergy,
};
use pict::util::bench::print_table;
use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    let steps = args.usize_or("steps", 20);

    // 1) the full registry in one call
    let scenarios = builtin_scenarios();
    let runner = BatchRunner::new(steps);
    println!(
        "advancing {} registered scenarios x {steps} steps on a {}-worker pool...",
        scenarios.len(),
        runner.threads()
    );
    let t0 = std::time::Instant::now();
    let results = runner.run(&scenarios);
    let wall = t0.elapsed().as_secs_f64();
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{}", r.state.step),
                format!("{:.2e}", r.max_divergence),
                format!("{}", r.adv_iters),
                format!("{}", r.p_iters),
                format!("{:.2}s", r.wall_s),
            ]
        })
        .collect();
    print_table(
        "batch run — all registered scenarios",
        &["scenario", "steps", "max div", "adv iters", "p iters", "wall"],
        &rows,
    );
    let busy: f64 = results.iter().map(|r| r.wall_s).sum();
    println!(
        "aggregate scenario time {busy:.2}s in {wall:.2}s wall ({:.2}x concurrency)",
        busy / wall.max(1e-9)
    );

    // 2) a parameter sweep: the cavity at several Reynolds numbers
    let res = [50.0, 100.0, 200.0, 400.0];
    let n = args.usize_or("n", 24);
    let sweep_steps = args.usize_or("sweep-steps", 150);
    println!("\ncavity Re sweep ({n}x{n}, {sweep_steps} steps each)...");
    let sweep = cavity_reynolds_sweep(n, &res);
    let results = BatchRunner::new(sweep_steps).run(&sweep);
    for r in &results {
        let ke: f64 = r
            .state
            .u
            .comp
            .iter()
            .map(|c| c.iter().map(|v| v * v).sum::<f64>())
            .sum();
        println!(
            "  {:<24} KE={ke:.4e}  max div={:.2e}  p iters={}",
            r.label, r.max_divergence, r.p_iters
        );
    }

    // 3) the gradient-producing variant: record a checkpointed tape per
    // scenario and backpropagate a terminal kinetic-energy loss through
    // each rollout, all on the same pool
    let grad_steps = args.usize_or("grad-steps", 16).max(1);
    let every = args.usize_or("every", 4).max(1);
    println!("\ngradient batch: cavity sweep x {grad_steps} steps, tape ckpt({every})...");
    let grad_sweep = cavity_reynolds_sweep(args.usize_or("grad-n", 12), &[100.0, 400.0]);
    let runner = BatchRunner::new(grad_steps);
    let loss = TerminalKineticEnergy { final_step: grad_steps - 1 };
    let grads = runner.run_gradients(
        &grad_sweep,
        TapeStrategy::Checkpoint { every },
        GradientPaths::FULL,
        &loss,
    );
    let rows: Vec<Vec<String>> = grads
        .iter()
        .map(|r| {
            let g0: f64 = r
                .grads
                .du0
                .comp
                .iter()
                .map(|c| c.iter().map(|v| v * v).sum::<f64>())
                .sum::<f64>()
                .sqrt();
            vec![
                r.label.clone(),
                format!("{:.3e}", r.loss),
                format!("{g0:.3e}"),
                format!("{:.3e}", r.grads.dnu),
                format!("{}", r.grads.dsource.len()),
                format!("{}", r.peak_resident_f64),
                format!("{:.2}s", r.wall_s),
            ]
        })
        .collect();
    print_table(
        "gradient batch (record + backward per scenario)",
        &["scenario", "loss", "|dL/du0|", "dL/dnu", "dS steps", "peak f64", "wall"],
        &rows,
    );
    let shared = reduce_shared(&grads);
    println!("batch-reduced shared gradients: dnu = {:.4e}", shared.dnu);
}
