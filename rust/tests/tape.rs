//! Checkpointed-tape and batched-gradient integration tests (the PR-4
//! acceptance suite): checkpointed backward == full backward bit-for-bit,
//! peak tape memory reduced ≥ 4× at n=64 / every=8, batched gradients ==
//! sequential single-scenario gradients, and a finite-difference gradcheck
//! of the batch-reduced shared source gradient. Run under PICT_THREADS=1
//! and =4 in CI (the batch paths must be width-independent).

use pict::adjoint::{GradientPaths, Tape, TapeStrategy};
use pict::coordinator::scenario::{
    taylor_green_init, taylor_green_nu_sweep, BatchRunner, Scenario, ScenarioRun,
    TaylorGreen, TerminalKineticEnergy, VortexStreet,
};
use pict::coordinator::reduce_shared;
use pict::mesh::{gen, VectorField};
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};

/// Terminal Σu² cotangent on the last of `n` steps.
fn ke_loss(
    ncells: usize,
    n: usize,
) -> impl FnMut(usize, &State) -> (VectorField, Vec<f64>) {
    move |step, st| {
        let mut du = VectorField::zeros(ncells);
        if step + 1 == n {
            for c in 0..3 {
                for i in 0..ncells {
                    du.comp[c][i] = 2.0 * st.u.comp[c][i];
                }
            }
        }
        (du, vec![0.0; ncells])
    }
}

fn assert_grads_equal(a: &pict::adjoint::RolloutGrads, b: &pict::adjoint::RolloutGrads) {
    assert_eq!(a.du0, b.du0, "du0 differs");
    assert_eq!(a.dp0, b.dp0, "dp0 differs");
    assert_eq!(a.dnu, b.dnu, "dnu differs");
    assert_eq!(a.dsource.len(), b.dsource.len());
    for (t, (x, y)) in a.dsource.iter().zip(&b.dsource).enumerate() {
        assert_eq!(x, y, "dsource[{t}] differs");
    }
    assert_eq!(a.dbc, b.dbc, "dbc differs");
}

/// Checkpointed backward == full backward, bit-for-bit, on the registry
/// Taylor–Green flow with full gradient paths.
#[test]
fn checkpointed_backward_matches_full_on_taylor_green() {
    let scen = TaylorGreen { n: 8, nu: 0.02, dt: 0.02 };
    let n = 10;
    let run_with = |strategy: TapeStrategy| {
        let ScenarioRun { mut solver, mut state, source, .. } = scen.build();
        let ncells = solver.mesh.ncells;
        let tape =
            Tape::record(&mut solver, &mut state, n, strategy, |_, _| source.clone());
        let g = tape.backward(
            &mut solver,
            GradientPaths::FULL,
            |_, _| source.clone(),
            ke_loss(ncells, n),
        );
        (g, state)
    };
    let (g_full, s_full) = run_with(TapeStrategy::Full);
    let (g_chk, s_chk) = run_with(TapeStrategy::Checkpoint { every: 4 });
    assert_eq!(s_full.u, s_chk.u, "forward trajectory must not depend on the tape");
    assert_grads_equal(&g_full, &g_chk);
    // n=10 under a 2-snapshot revolve budget re-advances mid-trajectory
    // during the backward (Restore + Advance before a Sweep), the schedule
    // shape uniform checkpointing never produces
    let (g_rev, s_rev) = run_with(TapeStrategy::Revolve { snapshots: 2 });
    assert_eq!(s_full.u, s_rev.u, "forward trajectory must not depend on the tape");
    assert_grads_equal(&g_full, &g_rev);
}

/// Same equality on a multi-block mesh with advective-outflow boundaries
/// (re-stepping must restore the boundary values the forward saw).
#[test]
fn checkpointed_backward_matches_full_with_outflow_bcs() {
    let scen = VortexStreet {
        nx: [4, 3, 6],
        ny: [4, 3, 4],
        re: 200.0,
        dt: 0.05,
        target_cfl: 0.8,
    };
    let n = 5;
    let run_with = |strategy: TapeStrategy| {
        let ScenarioRun { mut solver, mut state, source, .. } = scen.build();
        let ncells = solver.mesh.ncells;
        let tape =
            Tape::record(&mut solver, &mut state, n, strategy, |_, _| source.clone());
        let g = tape.backward(
            &mut solver,
            GradientPaths::FULL,
            |_, _| source.clone(),
            ke_loss(ncells, n),
        );
        let bc_after = solver.mesh.bc_values.clone();
        (g, bc_after)
    };
    let (g_full, bc_full) = run_with(TapeStrategy::Full);
    let (g_chk, bc_chk) = run_with(TapeStrategy::Checkpoint { every: 2 });
    assert_grads_equal(&g_full, &g_chk);
    // the backward sweep leaves the solver's boundary state where the
    // forward put it, under any strategy
    assert_eq!(bc_full, bc_chk, "backward must not move the boundary state");
    let (g_rev, bc_rev) = run_with(TapeStrategy::Revolve { snapshots: 2 });
    assert_grads_equal(&g_full, &g_rev);
    assert_eq!(bc_full, bc_rev, "backward must not move the boundary state");
}

/// Acceptance: at n = 64 steps with every = 8, the checkpointed sweep's
/// peak resident fields are at least 4x below the full tape's.
#[test]
fn checkpoint_peak_memory_is_4x_below_full_at_n64() {
    let scen = TaylorGreen { n: 8, nu: 0.02, dt: 0.01 };
    let n = 64;
    let run_with = |strategy: TapeStrategy| {
        let ScenarioRun { mut solver, mut state, source, .. } = scen.build();
        let ncells = solver.mesh.ncells;
        let tape =
            Tape::record(&mut solver, &mut state, n, strategy, |_, _| source.clone());
        let resident = tape.resident_f64();
        let (_, stats) = tape.backward_with_stats(
            &mut solver,
            GradientPaths::NONE,
            |_, _| source.clone(),
            ke_loss(ncells, n),
        );
        (resident, stats.peak_resident_f64)
    };
    let (full_resident, full_peak) = run_with(TapeStrategy::Full);
    let (chk_resident, chk_peak) = run_with(TapeStrategy::Checkpoint { every: 8 });
    assert_eq!(full_resident, full_peak, "full tape rematerializes nothing");
    assert!(
        chk_peak * 4 <= full_peak,
        "peak fields: checkpoint {chk_peak} vs full {full_peak} (< 4x reduction)"
    );
    assert!(chk_resident < chk_peak, "checkpoint peak includes the live segment");
}

/// Acceptance: at n = 64 with an 8-snapshot budget, the revolve schedule's
/// backward peak is strictly below uniform every-8 checkpointing's, its
/// gradients stay bit-for-bit equal to the full tape's, and it re-steps at
/// most 2n times (≤ 2 extra forward passes total).
#[test]
fn revolve_beats_uniform_checkpointing_at_n64_s8() {
    let scen = TaylorGreen { n: 8, nu: 0.02, dt: 0.01 };
    let n = 64;
    let run_with = |strategy: TapeStrategy| {
        let ScenarioRun { mut solver, mut state, source, .. } = scen.build();
        let ncells = solver.mesh.ncells;
        let tape =
            Tape::record(&mut solver, &mut state, n, strategy, |_, _| source.clone());
        let (g, stats) = tape.backward_with_stats(
            &mut solver,
            GradientPaths::NONE,
            |_, _| source.clone(),
            ke_loss(ncells, n),
        );
        (g, stats)
    };
    let (g_full, full_stats) = run_with(TapeStrategy::Full);
    let (g_chk, chk_stats) = run_with(TapeStrategy::Checkpoint { every: 8 });
    let (g_rev, rev_stats) = run_with(TapeStrategy::Revolve { snapshots: 8 });
    assert_grads_equal(&g_full, &g_rev);
    assert_grads_equal(&g_full, &g_chk);
    assert_eq!(full_stats.replayed_steps, 0, "full tape rematerializes nothing");
    assert!(
        rev_stats.peak_resident_f64 < chk_stats.peak_resident_f64,
        "revolve peak {} must be strictly below uniform every-8 peak {}",
        rev_stats.peak_resident_f64,
        chk_stats.peak_resident_f64
    );
    assert!(
        rev_stats.replayed_steps <= 2 * n,
        "revolve re-stepped {} times, over the 2n = {} budget",
        rev_stats.replayed_steps,
        2 * n
    );
}

/// A 2-scenario gradient batch (checkpointed, pooled) returns exactly the
/// gradients of the two single-scenario runs (full tape, serial pool).
#[test]
fn batched_gradients_match_sequential_single_scenario_runs() {
    let steps = 4;
    let loss = TerminalKineticEnergy { final_step: steps - 1 };
    let scens = taylor_green_nu_sweep(8, &[0.02, 0.05]);
    let batch = BatchRunner::new(steps).with_threads(2).run_gradients(
        &scens,
        TapeStrategy::Checkpoint { every: 2 },
        GradientPaths::FULL,
        &loss,
    );
    assert_eq!(batch.len(), 2);
    for (i, want_nu) in [0.02, 0.05].iter().enumerate() {
        let single: Vec<Box<dyn Scenario>> =
            vec![Box::new(TaylorGreen { n: 8, nu: *want_nu, ..Default::default() })];
        let got = BatchRunner::new(steps).with_threads(1).run_gradients(
            &single,
            TapeStrategy::Full,
            GradientPaths::FULL,
            &loss,
        );
        assert_eq!(batch[i].label, got[0].label);
        assert_eq!(batch[i].loss, got[0].loss, "loss differs for {}", batch[i].label);
        assert_eq!(batch[i].state.u, got[0].state.u);
        assert_grads_equal(&batch[i].grads, &got[0].grads);
    }
}

/// Scenario with a shared forcing field and tight solver tolerances, for
/// finite-difference validation of the batch-reduced source gradient.
struct ForcedTg {
    nu: f64,
    src: VectorField,
}

const FTG_N: usize = 6;

impl Scenario for ForcedTg {
    fn kind(&self) -> &'static str {
        "forced-tg-test"
    }

    fn label(&self) -> String {
        format!("forced-tg nu={}", self.nu)
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::periodic_box2d(FTG_N, FTG_N, 1.0, 1.0);
        let mut cfg = PisoConfig { dt: 0.04, ..Default::default() };
        cfg.adv_opts.tol = 1e-13;
        cfg.adv_opts.max_iter = 5000;
        cfg.p_opts.tol = 1e-13;
        cfg.p_opts.max_iter = 20000;
        let solver = PisoSolver::new(mesh, cfg, self.nu, ExecCtx::from_env());
        let mut state = State::zeros(&solver.mesh);
        state.u = taylor_green_init(&solver.mesh);
        state.u.scale(0.4);
        ScenarioRun { label: self.label(), solver, state, source: self.src.clone() }
    }
}

/// Gradcheck: the batch-reduced ∂(ΣL_i)/∂S from `reduce_shared` matches
/// central finite differences of the summed loss under a shared constant
/// source perturbation.
#[test]
fn batch_reduced_source_gradient_matches_finite_differences() {
    let steps = 2;
    let ncells = FTG_N * FTG_N;
    let nus = [0.02, 0.04];
    let scens_with = |src: &VectorField| -> Vec<Box<dyn Scenario>> {
        nus.iter()
            .map(|&nu| Box::new(ForcedTg { nu, src: src.clone() }) as Box<dyn Scenario>)
            .collect()
    };
    let mut src0 = VectorField::zeros(ncells);
    for i in 0..ncells {
        src0.comp[0][i] = 0.05 * ((i * 7 % 11) as f64 - 5.0) / 5.0;
        src0.comp[1][i] = 0.03 * ((i * 5 % 13) as f64 - 6.0) / 6.0;
    }

    // analytic: batch record/backward, then the shared reduction; the
    // source is constant over steps, so dL/dS = Σ_t dsource[t]
    let loss = TerminalKineticEnergy { final_step: steps - 1 };
    let results = BatchRunner::new(steps).with_threads(2).run_gradients(
        &scens_with(&src0),
        TapeStrategy::Checkpoint { every: 1 },
        GradientPaths::FULL,
        &loss,
    );
    let shared = reduce_shared(&results);
    let ds = shared.dsource.expect("same-mesh batch");
    assert_eq!(ds.len(), steps);

    // summed forward loss under a given shared source
    let total_loss = |src: &VectorField| -> f64 {
        scens_with(src)
            .iter()
            .map(|s| {
                let ScenarioRun { mut solver, mut state, source, .. } = s.build();
                for _ in 0..steps {
                    solver.step(&mut state, &source, None);
                }
                state
                    .u
                    .comp
                    .iter()
                    .map(|c| c.iter().map(|v| v * v).sum::<f64>())
                    .sum::<f64>()
            })
            .sum()
    };

    let eps = 1e-5;
    for (comp, cell) in [(0usize, 3usize), (0, 17), (1, 8), (1, 30)] {
        let mut up = src0.clone();
        up.comp[comp][cell] += eps;
        let mut dn = src0.clone();
        dn.comp[comp][cell] -= eps;
        let fd = (total_loss(&up) - total_loss(&dn)) / (2.0 * eps);
        let an: f64 = ds.iter().map(|g| g.comp[comp][cell]).sum();
        assert!(
            (fd - an).abs() < 3e-4 * (1.0 + fd.abs()),
            "dS[{comp}][{cell}]: fd {fd} vs batch-reduced adjoint {an}"
        );
    }
}
