//! Gradient validation (paper §4.2): the analytic adjoint of the full PISO
//! step — including both OtD backward linear solves — is compared against
//! central finite differences of the forward solver, the Rust analog of
//! PyTorch's gradcheck. Also checks the rollout chain rule over multiple
//! steps and the lid-velocity / viscosity gradients used by the direct
//! optimization experiments (Appendix C).

use pict::adjoint::{backward_step, rollout_backward, GradientPaths, Tape, TapeStrategy};
use pict::mesh::{gen, Mesh, VectorField};
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State, StepRecord};
use pict::util::rng::Rng;

fn tight_cfg(dt: f64) -> PisoConfig {
    let mut cfg = PisoConfig { dt, ..Default::default() };
    cfg.adv_opts.tol = 1e-13;
    cfg.p_opts.tol = 1e-13;
    cfg.adv_opts.max_iter = 5000;
    cfg.p_opts.max_iter = 20000;
    cfg
}

fn random_state(mesh: &Mesh, seed: u64, amp: f64) -> State {
    let mut rng = Rng::new(seed);
    let mut state = State::zeros(mesh);
    for (i, c) in mesh.centers.iter().enumerate() {
        state.u.comp[0][i] =
            amp * ((6.28 * c[1]).cos() + 0.3 * rng.normal() * 0.1 + 0.2 * (12.5 * c[0]).sin());
        state.u.comp[1][i] = amp * ((6.28 * c[0]).sin() * 0.5 + 0.1 * (9.4 * c[1]).cos());
    }
    state
}

/// Scalar loss with fixed random weights: L = Σ w·u + Σ wp·p.
struct Loss {
    wu: VectorField,
    wp: Vec<f64>,
}

impl Loss {
    fn new(mesh: &Mesh, seed: u64) -> Loss {
        let mut rng = Rng::new(seed);
        let mut wu = VectorField::zeros(mesh.ncells);
        for c in 0..mesh.dim {
            wu.comp[c] = rng.normal_vec(mesh.ncells);
        }
        Loss { wu, wp: rng.normal_vec(mesh.ncells) }
    }

    fn eval(&self, state: &State, dim: usize) -> f64 {
        let mut l = 0.0;
        for c in 0..dim {
            l += self.wu.comp[c].iter().zip(&state.u.comp[c]).map(|(w, u)| w * u).sum::<f64>();
        }
        l += self.wp.iter().zip(&state.p).map(|(w, p)| w * p).sum::<f64>();
        l
    }
}

/// One forward step from a given initial state, returning the loss.
fn forward_loss(
    mesh: &Mesh,
    cfg: &PisoConfig,
    nu: f64,
    u0: &VectorField,
    p0: &[f64],
    src: &VectorField,
    loss: &Loss,
) -> f64 {
    let mut solver = PisoSolver::new(mesh.clone(), cfg.clone(), nu, ExecCtx::from_env());
    let mut state = State::zeros(mesh);
    state.u = u0.clone();
    state.p = p0.to_vec();
    solver.step(&mut state, src, None);
    loss.eval(&state, mesh.dim)
}

/// Full-path gradcheck of a single PISO step w.r.t. u⁰, p⁰, S, and ν on a
/// periodic box (the paper's §4.2 setting).
#[test]
fn single_step_full_gradcheck_periodic() {
    let mesh = gen::periodic_box2d(6, 5, 1.0, 1.0);
    let cfg = tight_cfg(0.05);
    let nu = 0.03;
    let state0 = random_state(&mesh, 1, 0.5);
    let src = {
        let mut s = VectorField::zeros(mesh.ncells);
        let mut rng = Rng::new(5);
        for c in 0..2 {
            s.comp[c] = rng.normal_vec(mesh.ncells).iter().map(|v| 0.1 * v).collect();
        }
        s
    };
    let loss = Loss::new(&mesh, 9);

    // analytic gradients
    let mut solver = PisoSolver::new(mesh.clone(), cfg.clone(), nu, ExecCtx::from_env());
    let mut state = state0.clone();
    let mut rec = StepRecord::empty();
    solver.step(&mut state, &src, Some(&mut rec));
    let grads = backward_step(&solver, &rec, &loss.wu, &loss.wp, GradientPaths::FULL);

    let eps = 1e-5;
    let mut rng = Rng::new(77);
    // u0: probe a handful of random (comp, cell) entries
    for _ in 0..6 {
        let comp = rng.below(2);
        let cell = rng.below(mesh.ncells);
        let mut up = state0.u.clone();
        up.comp[comp][cell] += eps;
        let mut um = state0.u.clone();
        um.comp[comp][cell] -= eps;
        let lp = forward_loss(&mesh, &cfg, nu, &up, &state0.p, &src, &loss);
        let lm = forward_loss(&mesh, &cfg, nu, &um, &state0.p, &src, &loss);
        let fd = (lp - lm) / (2.0 * eps);
        let an = grads.du_n.comp[comp][cell];
        assert!(
            (fd - an).abs() < 2e-4 * (1.0 + fd.abs()),
            "du[{comp}][{cell}]: fd {fd} vs adjoint {an}"
        );
    }
    // p0
    for _ in 0..4 {
        let cell = rng.below(mesh.ncells);
        let mut pp = state0.p.clone();
        pp[cell] += eps;
        let mut pm = state0.p.clone();
        pm[cell] -= eps;
        let lp = forward_loss(&mesh, &cfg, nu, &state0.u, &pp, &src, &loss);
        let lm = forward_loss(&mesh, &cfg, nu, &state0.u, &pm, &src, &loss);
        let fd = (lp - lm) / (2.0 * eps);
        let an = grads.dp_in[cell];
        assert!(
            (fd - an).abs() < 2e-4 * (1.0 + fd.abs()),
            "dp[{cell}]: fd {fd} vs adjoint {an}"
        );
    }
    // source
    for _ in 0..4 {
        let comp = rng.below(2);
        let cell = rng.below(mesh.ncells);
        let mut sp = src.clone();
        sp.comp[comp][cell] += eps;
        let mut sm = src.clone();
        sm.comp[comp][cell] -= eps;
        let lp = forward_loss(&mesh, &cfg, nu, &state0.u, &state0.p, &sp, &loss);
        let lm = forward_loss(&mesh, &cfg, nu, &state0.u, &state0.p, &sm, &loss);
        let fd = (lp - lm) / (2.0 * eps);
        let an = grads.dsource.comp[comp][cell];
        assert!(
            (fd - an).abs() < 2e-4 * (1.0 + fd.abs()),
            "dS[{comp}][{cell}]: fd {fd} vs adjoint {an}"
        );
    }
    // viscosity (uniform scalar)
    {
        let lp = forward_loss(&mesh, &cfg, nu + eps, &state0.u, &state0.p, &src, &loss);
        let lm = forward_loss(&mesh, &cfg, nu - eps, &state0.u, &state0.p, &src, &loss);
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - grads.dnu).abs() < 5e-4 * (1.0 + fd.abs()),
            "dnu: fd {fd} vs adjoint {}",
            grads.dnu
        );
    }
}

/// Gradcheck on a wall-bounded (cavity) mesh, including the lid-velocity
/// gradient (Appendix C.1 optimizes exactly this quantity).
#[test]
fn single_step_gradcheck_cavity_with_lid_gradient() {
    let mesh = gen::cavity2d(6, 1.0, 1.0, false);
    let cfg = tight_cfg(0.05);
    let nu = 0.02;
    let state0 = random_state(&mesh, 2, 0.2);
    let src = VectorField::zeros(mesh.ncells);
    let loss = Loss::new(&mesh, 4);

    let mut solver = PisoSolver::new(mesh.clone(), cfg.clone(), nu, ExecCtx::from_env());
    let mut state = state0.clone();
    let mut rec = StepRecord::empty();
    solver.step(&mut state, &src, Some(&mut rec));
    let grads = backward_step(&solver, &rec, &loss.wu, &loss.wp, GradientPaths::FULL);

    let eps = 1e-5;
    let mut rng = Rng::new(31);
    for _ in 0..5 {
        let comp = rng.below(2);
        let cell = rng.below(mesh.ncells);
        let mut up = state0.u.clone();
        up.comp[comp][cell] += eps;
        let mut um = state0.u.clone();
        um.comp[comp][cell] -= eps;
        let lp = forward_loss(&mesh, &cfg, nu, &up, &state0.p, &src, &loss);
        let lm = forward_loss(&mesh, &cfg, nu, &um, &state0.p, &src, &loss);
        let fd = (lp - lm) / (2.0 * eps);
        let an = grads.du_n.comp[comp][cell];
        assert!(
            (fd - an).abs() < 3e-4 * (1.0 + fd.abs()),
            "du[{comp}][{cell}]: fd {fd} vs adjoint {an}"
        );
    }
    // lid velocity: bc set 3 (top face), x-component of every face cell
    {
        let fd = {
            let run = |lid: f64| {
                let mut mesh2 = mesh.clone();
                for v in mesh2.bc_values[3].vel.iter_mut() {
                    v[0] = lid;
                }
                let mut solver = PisoSolver::new(mesh2.clone(), cfg.clone(), nu, ExecCtx::from_env());
                let mut st = State::zeros(&mesh2);
                st.u = state0.u.clone();
                st.p = state0.p.clone();
                solver.step(&mut st, &src, None);
                loss.eval(&st, 2)
            };
            (run(1.0 + eps) - run(1.0 - eps)) / (2.0 * eps)
        };
        let an: f64 = grads.dbc[3].iter().map(|g| g[0]).sum();
        assert!(
            (fd - an).abs() < 3e-4 * (1.0 + fd.abs()),
            "d(lid): fd {fd} vs adjoint {an}"
        );
    }
}

/// Rollout chain rule: 3-step rollout gradient w.r.t. a scalar scaling of
/// the initial velocity matches finite differences (the §4.2 setup).
#[test]
fn rollout_gradcheck_initial_scale() {
    let mesh = gen::periodic_box2d(8, 6, 1.0, 1.0);
    let cfg = tight_cfg(0.04);
    let nu = 0.02;
    let base = random_state(&mesh, 3, 0.6);
    let ncells = mesh.ncells;
    let loss = Loss::new(&mesh, 8);

    let run = |scale: f64| -> f64 {
        let mut solver = PisoSolver::new(mesh.clone(), cfg.clone(), nu, ExecCtx::from_env());
        let mut state = base.clone();
        state.u.scale(scale);
        let src = VectorField::zeros(ncells);
        solver.run(&mut state, &src, 3);
        loss.eval(&state, 2)
    };

    // analytic: d/dscale = ⟨du0, u_base⟩ at scale=1 (recorded on a
    // checkpointed tape: its backward is bit-for-bit the full tape's)
    let mut solver = PisoSolver::new(mesh.clone(), cfg.clone(), nu, ExecCtx::from_env());
    let mut state = base.clone();
    let tape = Tape::record(
        &mut solver,
        &mut state,
        3,
        TapeStrategy::Checkpoint { every: 2 },
        |_, _| VectorField::zeros(ncells),
    );
    let g = rollout_backward(
        &mut solver,
        &tape,
        GradientPaths::FULL,
        |_, _| VectorField::zeros(ncells),
        |step, _| {
            if step == 2 {
                (loss.wu.clone(), loss.wp.clone())
            } else {
                (VectorField::zeros(ncells), vec![0.0; ncells])
            }
        },
    );
    let an: f64 = (0..2)
        .map(|c| g.du0.comp[c].iter().zip(&base.u.comp[c]).map(|(a, b)| a * b).sum::<f64>())
        .sum();

    let eps = 1e-5;
    let fd = (run(1.0 + eps) - run(1.0 - eps)) / (2.0 * eps);
    assert!(
        (fd - an).abs() < 5e-4 * (1.0 + fd.abs()),
        "rollout: fd {fd} vs adjoint {an}"
    );
}

/// The approximate paths are genuinely different from the full gradient but
/// correlate strongly with it for a short rollout (§4.3's premise).
#[test]
fn approximate_paths_correlate_with_full() {
    let mesh = gen::periodic_box2d(8, 8, 1.0, 1.0);
    let cfg = tight_cfg(0.03);
    let base = random_state(&mesh, 6, 0.8);
    let ncells = mesh.ncells;
    // velocity-only loss: the pressure cotangent flows exclusively through
    // the pressure solve, so including it would make the Adv-vs-full
    // comparison trivially different (the paper's §4.2 task is a velocity
    // loss as well)
    let mut loss = Loss::new(&mesh, 13);
    loss.wp.iter_mut().for_each(|w| *w = 0.0);

    let grad_for = |paths: GradientPaths| -> VectorField {
        let mut solver = PisoSolver::new(mesh.clone(), cfg.clone(), 0.02, ExecCtx::from_env());
        let mut state = base.clone();
        let tape = Tape::record(&mut solver, &mut state, 1, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        let g = rollout_backward(
            &mut solver,
            &tape,
            paths,
            |_, _| VectorField::zeros(ncells),
            |_, _| (loss.wu.clone(), loss.wp.clone()),
        );
        g.du0
    };
    let full = grad_for(GradientPaths::FULL);
    let adv = grad_for(GradientPaths::ADV);
    let none = grad_for(GradientPaths::NONE);

    let corr = |a: &VectorField, b: &VectorField| -> f64 {
        let av: Vec<f64> = a.comp[0].iter().chain(&a.comp[1]).cloned().collect();
        let bv: Vec<f64> = b.comp[0].iter().chain(&b.comp[1]).cloned().collect();
        pict::util::correlation(&av, &bv)
    };
    let c_adv = corr(&full, &adv);
    let c_none = corr(&full, &none);
    assert!(c_adv > 0.9, "Adv vs full correlation {c_adv}");
    assert!(c_none > 0.7, "none vs full correlation {c_none}");
    // and they are not identical (the ablation is real)
    let diff: f64 =
        full.comp[0].iter().zip(&none.comp[0]).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-8, "none path should differ from full");
}
