//! End-to-end learning tests (paper §5, scaled down): the corrector-training
//! harness must reduce the training loss and beat the No-Model baseline on
//! held-out rollouts, and the statistics-only SGS training must reduce the
//! statistics mismatch of the coarse channel.

use pict::adjoint::GradientPaths;
use pict::coordinator::experiments::corrector2d::{
    evaluate_corrector, make_reference_frames, train_corrector2d, Corrector2dCfg,
};
use pict::coordinator::experiments::tcf_sgs::{
    eval_sgs, reference_statistics, train_tcf_sgs, TcfSgsCfg,
};
use pict::mesh::gen;
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};

/// E5-style corrector on a tiny vortex-street: training loss drops and the
/// trained model beats No-Model at the evaluation checkpoints.
#[test]
fn corrector_training_beats_no_model_vortex_street() {
    let vs = gen::VortexStreetCfg {
        nx: [6, 4, 10],
        ny: [6, 4, 6],
        ..Default::default()
    };
    let fine_mesh = gen::vortex_street(&gen::VortexStreetCfg {
        nx: [12, 8, 20],
        ny: [12, 8, 12],
        ..Default::default()
    });
    let coarse_mesh = gen::vortex_street(&vs);
    let nu = vs.u_in * vs.obs_h / 400.0;
    let cfg = Corrector2dCfg {
        t_ratio: 2,
        n_frames: 50,
        fine_warmup: 100,
        curriculum: vec![3, 6],
        opt_steps_per_stage: 50,
        lr: 2e-3,
        paths: GradientPaths::NONE,
        lambda_div: 1e-3,
        output_scale: 0.1,
        seed: 0xC0DE,
        ..Default::default()
    };
    let mut fine = PisoSolver::new(
        fine_mesh,
        PisoConfig { dt: 0.04, use_ilu: true, ..Default::default() },
        nu,
        ExecCtx::from_env(),
    );
    let mut fine_state = State::zeros(&fine.mesh);
    let frames = make_reference_frames(&mut fine, &mut fine_state, &coarse_mesh, &cfg);

    let mut coarse = PisoSolver::new(
        coarse_mesh.clone(),
        PisoConfig { dt: 0.08, use_ilu: true, ..Default::default() },
        nu,
        ExecCtx::from_env(),
    );
    let (net, losses) = train_corrector2d(&mut coarse, &frames, &cfg);
    assert!(losses.iter().all(|l| l.is_finite()), "training stayed stable");

    // evaluation: long rollout (beyond the training unroll) vs both models
    let checkpoints = [25usize, 45];
    let mut s1 = PisoSolver::new(
        coarse_mesh.clone(),
        PisoConfig { dt: 0.08, use_ilu: true, ..Default::default() },
        nu,
        ExecCtx::from_env(),
    );
    let base = evaluate_corrector(&mut s1, None, cfg.output_scale, &frames, &checkpoints);
    let mut s2 = PisoSolver::new(
        coarse_mesh,
        PisoConfig { dt: 0.08, use_ilu: true, ..Default::default() },
        nu,
        ExecCtx::from_env(),
    );
    let nn = evaluate_corrector(&mut s2, Some(&net), cfg.output_scale, &frames, &checkpoints);
    // NN beats baseline in MSE and vorticity correlation at every
    // checkpoint (Table 3 / Fig 7 shape)
    for ((step, mse_base, corr_base), (_, mse_nn, corr_nn)) in base.iter().zip(&nn) {
        assert!(
            mse_nn < mse_base,
            "step {step}: corrected {mse_nn} should beat no-model {mse_base}"
        );
        assert!(
            corr_nn > corr_base,
            "step {step}: corrected corr {corr_nn} vs {corr_base}"
        );
    }
}

/// E7-style SGS training: statistics-only loss decreases during training,
/// and the learned model improves the per-frame statistics mismatch vs no-SGS.
#[test]
fn sgs_training_improves_channel_statistics() {
    let cfg = TcfSgsCfg { coarse_n: [8, 8, 4], ..Default::default() };
    let target = reference_statistics(&cfg, [12, 14, 6], 120);
    let result = train_tcf_sgs(&cfg, &target);
    let early: f64 = result.train_losses[..10].iter().sum::<f64>() / 10.0;
    let late: f64 =
        result.train_losses[result.train_losses.len() - 10..].iter().sum::<f64>() / 10.0;
    assert!(
        late < early,
        "SGS statistics loss should drop: {early} -> {late}"
    );

    let steps = 60;
    let no_sgs = eval_sgs(&cfg, None, &target, steps);
    let learned = eval_sgs(&cfg, Some(&result.net), &target, steps);
    let tail = |v: &[f64]| v[v.len() - 10..].iter().sum::<f64>() / 10.0;
    assert!(
        tail(&learned) < tail(&no_sgs),
        "learned SGS {} should beat no-SGS {}",
        tail(&learned),
        tail(&no_sgs)
    );
}
