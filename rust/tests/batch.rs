//! Integration tests for the scenario registry and the batched runner:
//! several distinct registered scenarios advanced concurrently in one call.

use pict::adjoint::RolloutGrads;
use pict::coordinator::scenario::{
    builtin_scenarios, reduce_shared, scenario_by_kind, taylor_green_nu_sweep, BatchRunner,
    GradBatchResult, LidDrivenCavity, Poiseuille, Scenario, ScenarioRun, TaylorGreen,
    TurbulentChannel, VortexStreet,
};
use pict::mesh::VectorField;
use pict::piso::State;

/// Small variants of every registered scenario family (fast to advance).
fn small_scenarios() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(TaylorGreen { n: 8, ..Default::default() }),
        Box::new(LidDrivenCavity { n: 8, ..Default::default() }),
        Box::new(Poiseuille { nx: 4, ny: 8, ..Default::default() }),
        Box::new(TurbulentChannel { n: [6, 6, 4], ..Default::default() }),
        Box::new(VortexStreet { nx: [4, 3, 6], ny: [4, 3, 4], ..Default::default() }),
    ]
}

#[test]
fn batch_runner_advances_five_distinct_scenarios_concurrently() {
    let scenarios = small_scenarios();
    assert!(scenarios.len() >= 4, "need at least 4 distinct scenarios");
    let mut kinds: Vec<&str> = scenarios.iter().map(|s| s.kind()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    assert_eq!(kinds.len(), scenarios.len(), "scenario kinds must be distinct");

    // one call, one worker per scenario
    let steps = 2;
    let results = BatchRunner::new(steps).with_threads(scenarios.len()).run(&scenarios);

    assert_eq!(results.len(), scenarios.len());
    for (r, s) in results.iter().zip(&scenarios) {
        // results come back in input order, every scenario fully advanced
        assert_eq!(r.label, s.label());
        assert_eq!(r.state.step, steps, "{} did not advance", r.label);
        assert_eq!(r.steps, steps);
        assert!(r.state.time > 0.0);
        assert!(r.p_iters > 0, "{} did no pressure work", r.label);
        assert!(r.max_divergence.is_finite());
        assert!(r.last.dt > 0.0);
    }
}

#[test]
fn batch_results_match_sequential_execution() {
    // the pooled runner must produce the same trajectories as running the
    // same scenarios one at a time: kernel chunking depends only on the
    // context width, and these systems sit below the per-chunk work
    // thresholds, so both runs take bit-identical serial kernel paths
    let steps = 2;
    let pooled = BatchRunner::new(steps).with_threads(4).run(&small_scenarios());
    let sequential = BatchRunner::new(steps).with_threads(1).run(&small_scenarios());
    assert_eq!(pooled.len(), sequential.len());
    for (p, s) in pooled.iter().zip(&sequential) {
        assert_eq!(p.label, s.label);
        assert_eq!(p.state.u, s.state.u, "{}: trajectories diverged", p.label);
        assert_eq!(p.p_iters, s.p_iters);
        assert_eq!(p.adv_iters, s.adv_iters);
    }
}

#[test]
fn builtin_registry_covers_the_paper_workloads() {
    let all = builtin_scenarios();
    assert!(all.len() >= 4);
    for kind in ["taylor-green", "cavity", "poiseuille", "channel", "vortex-street"] {
        assert!(scenario_by_kind(kind).is_some(), "missing scenario kind {kind}");
    }
}

#[test]
fn batch_preserves_input_order_when_pool_is_wider_than_the_batch() {
    // 8 workers racing over 3 scenarios: completion order is whatever the
    // pool's claiming produces, but results must come back by input index
    let scenarios = taylor_green_nu_sweep(8, &[0.05, 0.01, 0.03]);
    let results = BatchRunner::new(1).with_threads(8).run(&scenarios);
    assert_eq!(results.len(), 3);
    for (r, s) in results.iter().zip(&scenarios) {
        assert_eq!(r.label, s.label(), "slot came back out of input order");
    }
}

/// Hand-built gradient result for `reduce_shared` edge-case tests (no
/// solver run needed: the reduction only looks at grads + mesh_fp).
fn synthetic_grad_result(label: &str, mesh_fp: u64, dnu: f64, nsteps: usize, seed: f64) -> GradBatchResult {
    let mut du0 = VectorField::zeros(2);
    du0.comp[0][0] = seed;
    let dsource: Vec<VectorField> = (0..nsteps)
        .map(|t| {
            let mut f = VectorField::zeros(2);
            f.comp[0][1] = seed + t as f64;
            f
        })
        .collect();
    GradBatchResult {
        label: label.to_string(),
        state: State { u: VectorField::zeros(2), p: vec![0.0; 2], time: 0.0, step: nsteps },
        loss: 1.0,
        grads: RolloutGrads { du0, dp0: vec![0.0; 2], dsource, dnu, dbc: Vec::new() },
        mesh_fp,
        peak_resident_f64: 0,
        wall_s: 0.0,
    }
}

#[test]
fn reduce_shared_handles_empty_single_and_mixed_length_batches() {
    // empty input: a zero dnu and no field reductions, not a panic
    let empty = reduce_shared(&[]);
    assert_eq!(empty.dnu, 0.0);
    assert!(empty.dsource.is_none());
    assert!(empty.du0.is_none());

    // single scenario: the reduction is the scenario's own gradients
    let one = [synthetic_grad_result("solo", 7, 0.25, 2, 1.5)];
    let solo = reduce_shared(&one);
    assert_eq!(solo.dnu, 0.25);
    let du0 = solo.du0.expect("single-scenario batch reduces du0");
    assert_eq!(du0, one[0].grads.du0);
    let ds = solo.dsource.expect("single-scenario batch reduces dsource");
    assert_eq!(ds.len(), 2);
    assert_eq!(ds[0], one[0].grads.dsource[0]);

    // same mesh but different rollout lengths: dsource entries would not
    // line up step-for-step, so field reductions must be refused while the
    // scalar dnu still sums
    let mixed = [
        synthetic_grad_result("short", 7, 0.25, 2, 1.5),
        synthetic_grad_result("long", 7, 0.5, 3, 2.5),
    ];
    let shared = reduce_shared(&mixed);
    assert_eq!(shared.dnu, 0.75);
    assert!(shared.dsource.is_none(), "mixed-length batches must not reduce dsource");
    assert!(shared.du0.is_none(), "mixed-length batches must not reduce du0");
}

/// Scenario whose build panics — the "bad config" failure mode, exercised
/// through the public crate surface rather than the unit tests.
struct PanicOnBuild;

impl Scenario for PanicOnBuild {
    fn kind(&self) -> &'static str {
        "panic-on-build"
    }
    fn label(&self) -> String {
        "panic-on-build".to_string()
    }
    fn build(&self) -> ScenarioRun {
        panic!("injected build failure")
    }
}

/// Taylor–Green seeded with a NaN — diverges or trips the debug
/// non-finite guard on the first step.
struct NanSeed;

impl Scenario for NanSeed {
    fn kind(&self) -> &'static str {
        "nan-seed"
    }
    fn label(&self) -> String {
        "nan-seed".to_string()
    }
    fn build(&self) -> ScenarioRun {
        let mut run = TaylorGreen { n: 8, ..Default::default() }.build();
        run.state.u.comp[0][2] = f64::NAN;
        run.label = self.label();
        run
    }
}

#[test]
fn checked_batch_isolates_failures_to_their_own_slots() {
    let scenarios: Vec<Box<dyn Scenario>> = vec![
        Box::new(TaylorGreen { n: 8, ..Default::default() }),
        Box::new(PanicOnBuild),
        Box::new(NanSeed),
        Box::new(LidDrivenCavity { n: 8, ..Default::default() }),
    ];
    let results = BatchRunner::new(2).with_threads(4).run_checked(&scenarios);
    assert_eq!(results.len(), 4);
    for (i, healthy) in [(0usize, true), (1, false), (2, false), (3, true)] {
        assert_eq!(
            results[i].is_ok(),
            healthy,
            "slot {i}: expected {} but got {:?}",
            if healthy { "Ok" } else { "Err" },
            results[i].as_ref().err().map(|e| e.to_string()),
        );
    }
    let trailing = results[3].as_ref().expect("trailing healthy slot completes");
    assert_eq!(trailing.state.step, 2);
    let err = results[1].as_ref().expect_err("panicking slot reports its error");
    assert_eq!(err.label(), "panic-on-build");
    assert!(err.to_string().contains("injected build failure"), "{err}");
}
