//! Integration tests for the scenario registry and the batched runner:
//! several distinct registered scenarios advanced concurrently in one call.

use pict::coordinator::scenario::{
    builtin_scenarios, scenario_by_kind, BatchRunner, LidDrivenCavity, Poiseuille, Scenario,
    TaylorGreen, TurbulentChannel, VortexStreet,
};

/// Small variants of every registered scenario family (fast to advance).
fn small_scenarios() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(TaylorGreen { n: 8, ..Default::default() }),
        Box::new(LidDrivenCavity { n: 8, ..Default::default() }),
        Box::new(Poiseuille { nx: 4, ny: 8, ..Default::default() }),
        Box::new(TurbulentChannel { n: [6, 6, 4], ..Default::default() }),
        Box::new(VortexStreet { nx: [4, 3, 6], ny: [4, 3, 4], ..Default::default() }),
    ]
}

#[test]
fn batch_runner_advances_five_distinct_scenarios_concurrently() {
    let scenarios = small_scenarios();
    assert!(scenarios.len() >= 4, "need at least 4 distinct scenarios");
    let mut kinds: Vec<&str> = scenarios.iter().map(|s| s.kind()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    assert_eq!(kinds.len(), scenarios.len(), "scenario kinds must be distinct");

    // one call, one worker per scenario
    let steps = 2;
    let results = BatchRunner::new(steps).with_threads(scenarios.len()).run(&scenarios);

    assert_eq!(results.len(), scenarios.len());
    for (r, s) in results.iter().zip(&scenarios) {
        // results come back in input order, every scenario fully advanced
        assert_eq!(r.label, s.label());
        assert_eq!(r.state.step, steps, "{} did not advance", r.label);
        assert_eq!(r.steps, steps);
        assert!(r.state.time > 0.0);
        assert!(r.p_iters > 0, "{} did no pressure work", r.label);
        assert!(r.max_divergence.is_finite());
        assert!(r.last.dt > 0.0);
    }
}

#[test]
fn batch_results_match_sequential_execution() {
    // the pooled runner must produce the same trajectories as running the
    // same scenarios one at a time: kernel chunking depends only on the
    // context width, and these systems sit below the per-chunk work
    // thresholds, so both runs take bit-identical serial kernel paths
    let steps = 2;
    let pooled = BatchRunner::new(steps).with_threads(4).run(&small_scenarios());
    let sequential = BatchRunner::new(steps).with_threads(1).run(&small_scenarios());
    assert_eq!(pooled.len(), sequential.len());
    for (p, s) in pooled.iter().zip(&sequential) {
        assert_eq!(p.label, s.label);
        assert_eq!(p.state.u, s.state.u, "{}: trajectories diverged", p.label);
        assert_eq!(p.p_iters, s.p_iters);
        assert_eq!(p.adv_iters, s.adv_iters);
    }
}

#[test]
fn builtin_registry_covers_the_paper_workloads() {
    let all = builtin_scenarios();
    assert!(all.len() >= 4);
    for kind in ["taylor-green", "cavity", "poiseuille", "channel", "vortex-street"] {
        assert!(scenario_by_kind(kind).is_some(), "missing scenario kind {kind}");
    }
}
