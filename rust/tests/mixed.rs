//! Integration contracts of the mixed-precision Krylov path: iterative
//! refinement converges to the same f64 tolerance as the plain solvers and
//! lands on (essentially) the same solution; the mixed path is bit-for-bit
//! reproducible per (thread-width, precision) config; the persistent
//! `Csr32` mirror refreshed after a numeric reassembly equals a
//! from-scratch rebuild; and a full `Precision::Mixed` PISO/batch run
//! stays divergence-free while tracking the f64 trajectory.

use pict::coordinator::scenario::{BatchRunner, LidDrivenCavity, Scenario};
use pict::fvm;
use pict::linsolve::{bicgstab, cg, refined_bicgstab, refined_cg, Jacobi, Precision, SolveOpts};
use pict::mesh::gen;
use pict::par::ExecCtx;
use pict::sparse::{Csr, Csr32};
use pict::util::rng::Rng;

/// The Poiseuille pressure system the batch runner exercises (same
/// construction as tests/par_props.rs): symmetric singular Poisson system
/// with a consistent, mean-free RHS shaped like a divergence field.
fn poiseuille_pressure_system() -> (Csr, Vec<f64>) {
    let mesh = gen::channel2d(6, 16, 1.0, 1.0, 1.12, false);
    let a_inv = vec![1.0; mesh.ncells];
    let mut m = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&ExecCtx::serial(), &mesh, &a_inv, &mut m);
    let mut rhs: Vec<f64> = mesh
        .centers
        .iter()
        .map(|c| (7.1 * c[0]).sin() * (3.3 * c[1]).cos())
        .collect();
    let mean = rhs.iter().sum::<f64>() / rhs.len() as f64;
    rhs.iter_mut().for_each(|v| *v -= mean);
    (m, rhs)
}

/// A larger periodic-box pressure system, sized so the parallel kernels
/// actually partition across a width-4 pool.
fn box_pressure_system(n: usize) -> (Csr, Vec<f64>) {
    let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
    let a_inv = vec![1.0; mesh.ncells];
    let mut m = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&ExecCtx::serial(), &mesh, &a_inv, &mut m);
    let mut rhs: Vec<f64> = mesh
        .centers
        .iter()
        .map(|c| (5.2 * c[0]).cos() * (2.9 * c[1]).sin())
        .collect();
    let mean = rhs.iter().sum::<f64>() / rhs.len() as f64;
    rhs.iter_mut().for_each(|v| *v -= mean);
    (m, rhs)
}

/// Random strictly diagonally dominant (nonsymmetric) matrix — the shape
/// of the advection–diffusion system (same generator as tests/par_props.rs).
fn random_dd(n: usize, rng: &mut Rng) -> Csr {
    let mut trip = Vec::new();
    for r in 0..n {
        let mut offsum = 0.0;
        for c in 0..n {
            if c != r && rng.uniform() < 0.3 {
                let v = rng.normal() * 0.5;
                offsum += v.abs();
                trip.push((r, c, v));
            }
        }
        trip.push((r, r, offsum + 1.0 + rng.uniform()));
    }
    Csr::from_triplets(n, &trip)
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn max_abs(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, v| m.max(v.abs()))
}

#[test]
fn refined_cg_matches_f64_cg_on_poiseuille_pressure() {
    let (a, rhs) = poiseuille_pressure_system();
    let a32 = Csr32::from_f64(&a);
    let precond = Jacobi::new(&a);
    let ctx = ExecCtx::serial();
    let opts = SolveOpts::default();
    let mixed = SolveOpts { precision: Precision::Mixed, ..opts };
    let mut x64 = vec![0.0; a.n];
    let mut xmx = vec![0.0; a.n];
    let st64 = cg(&ctx, &a, &rhs, &mut x64, &precond, true, opts);
    let stmx = refined_cg(&ctx, &a, &a32, &rhs, &mut xmx, &precond, true, mixed);
    assert!(st64.converged, "f64 CG must converge on the pressure system");
    assert!(stmx.converged, "mixed CG must converge to the same f64 tolerance");
    // both residuals are true f64 residuals relative to the same ‖b‖
    assert!(stmx.residual < opts.tol, "mixed residual {} above tol", stmx.residual);
    let scale = max_abs(&x64).max(1e-300);
    let diff = max_abs_diff(&x64, &xmx);
    assert!(diff < 1e-6 * scale, "solutions disagree: rel diff {}", diff / scale);
}

#[test]
fn refined_bicgstab_matches_f64_on_advection_shaped_system() {
    let mut rng = Rng::new(0x51ab);
    let n = 48;
    let a = random_dd(n, &mut rng);
    let a32 = Csr32::from_f64(&a);
    let precond = Jacobi::new(&a);
    let ctx = ExecCtx::serial();
    let rhs: Vec<f64> = (0..n).map(|i| (0.37 * i as f64).sin()).collect();
    let opts = SolveOpts::default();
    let mixed = SolveOpts { precision: Precision::Mixed, ..opts };
    let mut x64 = vec![0.0; n];
    let mut xmx = vec![0.0; n];
    let st64 = bicgstab(&ctx, &a, &rhs, &mut x64, &precond, false, opts);
    let stmx = refined_bicgstab(&ctx, &a, &a32, &rhs, &mut xmx, &precond, false, mixed);
    assert!(st64.converged && stmx.converged);
    assert!(stmx.residual < opts.tol);
    let scale = max_abs(&x64).max(1e-300);
    let diff = max_abs_diff(&x64, &xmx);
    assert!(diff < 1e-6 * scale, "solutions disagree: rel diff {}", diff / scale);
}

#[test]
fn mixed_solve_is_bit_for_bit_reproducible_per_width() {
    let (a, rhs) = box_pressure_system(24);
    let a32 = Csr32::from_f64(&a);
    let precond = Jacobi::new(&a);
    let mixed = SolveOpts { precision: Precision::Mixed, ..SolveOpts::default() };
    for t in [1usize, 4] {
        let ctx = ExecCtx::with_threads(t);
        let mut x1 = vec![0.0; a.n];
        let mut x2 = vec![0.0; a.n];
        let st1 = refined_cg(&ctx, &a, &a32, &rhs, &mut x1, &precond, true, mixed);
        let st2 = refined_cg(&ctx, &a, &a32, &rhs, &mut x2, &precond, true, mixed);
        assert!(st1.converged && st2.converged);
        // identical dispatch ⇒ identical iterates, not merely close
        assert_eq!(x1, x2, "mixed CG must be deterministic at width {t}");
        assert_eq!(st1.iterations, st2.iterations);
        assert_eq!(st1.residual.to_bits(), st2.residual.to_bits());
    }
}

#[test]
fn mirror_refresh_tracks_numeric_reassembly() {
    let mesh = gen::periodic_box2d(12, 12, 1.0, 1.0);
    let ctx = ExecCtx::serial();
    let a_inv = vec![1.0; mesh.ncells];
    let mut a = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&ctx, &mesh, &a_inv, &mut a);
    let mut mirror = Csr32::from_f64(&a);
    // numeric-only refill, as the stepper does each step: same symbolic
    // structure, new values
    let a_inv2: Vec<f64> = (0..mesh.ncells).map(|i| 0.5 + 0.01 * (i % 7) as f64).collect();
    fvm::assemble_pressure(&ctx, &mesh, &a_inv2, &mut a);
    mirror.refresh(&a);
    let rebuilt = Csr32::from_f64(&a);
    assert_eq!(mirror.vals, rebuilt.vals);
    assert_eq!(mirror.col_idx, rebuilt.col_idx);
    assert_eq!(mirror.row_ptr, rebuilt.row_ptr);
}

#[test]
fn mixed_piso_run_tracks_f64_on_cavity() {
    let steps = 3;
    let mut finals = Vec::new();
    for precision in [Precision::F64, Precision::Mixed] {
        let mut run = LidDrivenCavity { n: 16, ..Default::default() }.build();
        run.solver.ctx = ExecCtx::with_threads(2);
        run.solver.cfg.precision = precision;
        let mut state = run.state;
        let stats = run.solver.run(&mut state, &run.source, steps);
        assert!(
            stats.max_divergence < 1e-5,
            "{precision:?} run left divergence {}",
            stats.max_divergence
        );
        finals.push(state);
    }
    assert_eq!(finals[0].step, finals[1].step);
    // every solve converged to the same 1e-8 relative tolerance, so the
    // trajectories stay together to well within solver accuracy
    for d in 0..2 {
        let drift = max_abs_diff(&finals[0].u.comp[d], &finals[1].u.comp[d]);
        assert!(drift < 1e-4, "velocity component {d} drifted by {drift}");
    }
    let pdrift = max_abs_diff(&finals[0].p, &finals[1].p);
    assert!(pdrift < 1e-3, "pressure drifted by {pdrift}");
}

#[test]
fn batch_runner_mixed_override_matches_f64_batch() {
    let scenarios: Vec<Box<dyn Scenario>> = vec![
        Box::new(LidDrivenCavity { n: 12, ..Default::default() }),
        Box::new(LidDrivenCavity { n: 12, re: 400.0, ..Default::default() }),
    ];
    let f64_results = BatchRunner::new(2).with_threads(2).run(&scenarios);
    let runner = BatchRunner::new(2).with_threads(2).with_precision(Precision::Mixed);
    let mixed_results = runner.run(&scenarios);
    assert_eq!(f64_results.len(), mixed_results.len());
    for (r64, rmx) in f64_results.iter().zip(&mixed_results) {
        assert_eq!(r64.label, rmx.label);
        assert_eq!(r64.steps, rmx.steps);
        assert!(rmx.max_divergence < 1e-5, "{}: divergence {}", rmx.label, rmx.max_divergence);
        for d in 0..2 {
            let drift = max_abs_diff(&r64.state.u.comp[d], &rmx.state.u.comp[d]);
            assert!(drift < 1e-4, "{}: velocity drift {drift}", rmx.label);
        }
    }
}
