//! Physical-invariant property tests of the PISO solver: discrete
//! conservation laws and symmetries that must hold for any valid
//! configuration (randomized over grids, viscosities, and initial fields).

use pict::fvm;
use pict::mesh::{gen, VectorField};
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};
use pict::util::prop::Prop;
use pict::util::rng::Rng;

fn random_div_free(mesh: &pict::mesh::Mesh, rng: &mut Rng, modes: usize) -> VectorField {
    // streamfunction superposition => exactly solenoidal continuum field
    let mut u = VectorField::zeros(mesh.ncells);
    let tau = 2.0 * std::f64::consts::PI;
    for _ in 0..modes {
        let (kx, ky) = (1.0 + rng.below(3) as f64, 1.0 + rng.below(3) as f64);
        let amp = rng.range(0.2, 1.0);
        let ph = rng.range(0.0, tau);
        for (i, c) in mesh.centers.iter().enumerate() {
            u.comp[0][i] += amp * ky * (tau * kx * c[0] + ph).cos() * (tau * ky * c[1]).sin();
            u.comp[1][i] -= amp * kx * (tau * kx * c[0] + ph).sin() * (tau * ky * c[1]).cos();
        }
    }
    u
}

/// Total momentum Σ J·u is conserved on a periodic box without forcing, up
/// to the collocated-PISO correction term Σ J·A⁻¹∇p (which does not
/// telescope when diag(C) varies spatially — an inherent property of the
/// scheme, small relative to the momentum scale).
#[test]
fn momentum_conservation_periodic() {
    Prop::new(6, 0x101).check("momentum", |rng, _| {
        let nx = 8 + 4 * rng.below(3);
        let ny = 8 + 4 * rng.below(3);
        let mesh = gen::periodic_box2d(nx, ny, 1.0, 1.0);
        let nu = rng.range(0.001, 0.05);
        let mut cfg = PisoConfig { dt: 0.02, ..Default::default() };
        // conservation is exact up to the Krylov tolerance — tighten it
        cfg.adv_opts.tol = 1e-12;
        cfg.p_opts.tol = 1e-12;
        let mut solver = PisoSolver::new(mesh, cfg, nu, ExecCtx::from_env());
        let mut state = State::zeros(&solver.mesh);
        state.u = random_div_free(&solver.mesh, rng, 2);
        let mom0: f64 = (0..solver.mesh.ncells)
            .map(|i| solver.mesh.jac[i] * state.u.comp[0][i])
            .sum();
        let scale: f64 = (0..solver.mesh.ncells)
            .map(|i| solver.mesh.jac[i] * state.u.comp[0][i].abs())
            .sum();
        let src = VectorField::zeros(solver.mesh.ncells);
        solver.run(&mut state, &src, 5);
        let mom1: f64 = (0..solver.mesh.ncells)
            .map(|i| solver.mesh.jac[i] * state.u.comp[0][i])
            .sum();
        if (mom1 - mom0).abs() > 1e-3 * (1.0 + scale) {
            return Err(format!("momentum drift {mom0} -> {mom1} (scale {scale})"));
        }
        Ok(())
    });
}

/// Kinetic energy decays monotonically for unforced viscous flow.
#[test]
fn energy_decay_unforced() {
    Prop::new(5, 0x202).check("energy", |rng, _| {
        let mesh = gen::periodic_box2d(12, 12, 1.0, 1.0);
        let nu = rng.range(0.005, 0.05);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.01, ..Default::default() },
            nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        state.u = random_div_free(&solver.mesh, rng, 3);
        let src = VectorField::zeros(solver.mesh.ncells);
        let mut e_prev = f64::INFINITY;
        for _ in 0..6 {
            solver.step(&mut state, &src, None);
            let e: f64 = (0..2)
                .map(|c| state.u.comp[c].iter().map(|v| v * v).sum::<f64>())
                .sum();
            if e > e_prev * (1.0 + 1e-9) {
                return Err(format!("energy grew {e_prev} -> {e}"));
            }
            e_prev = e;
        }
        Ok(())
    });
}

/// The dynamics are invariant to a constant shift of the initial pressure
/// (pressure enters only through its gradient).
#[test]
fn pressure_shift_invariance() {
    let mesh = gen::cavity2d(10, 1.0, 1.0, false);
    let mut s1 = PisoSolver::new(mesh.clone(), PisoConfig::default(), 0.01, ExecCtx::from_env());
    let mut s2 = PisoSolver::new(mesh, PisoConfig::default(), 0.01, ExecCtx::from_env());
    let mut a = State::zeros(&s1.mesh);
    let mut b = State::zeros(&s2.mesh);
    b.p.iter_mut().for_each(|p| *p += 37.5);
    let src = VectorField::zeros(s1.mesh.ncells);
    s1.run(&mut a, &src, 4);
    s2.run(&mut b, &src, 4);
    for c in 0..2 {
        for i in 0..s1.mesh.ncells {
            assert!(
                (a.u.comp[c][i] - b.u.comp[c][i]).abs() < 1e-9,
                "velocity differs under pressure shift"
            );
        }
    }
}

/// x-translation equivariance on the periodic box: shifting the initial
/// condition by one cell shifts the solution by one cell.
#[test]
fn translation_equivariance_periodic() {
    let (nx, ny) = (12usize, 10usize);
    let mesh = gen::periodic_box2d(nx, ny, 1.0, 1.0);
    let mut rng = Rng::new(7);
    let u0 = random_div_free(&mesh, &mut rng, 2);
    let shift = |f: &VectorField| -> VectorField {
        let b = &mesh.blocks[0];
        let mut g = VectorField::zeros(mesh.ncells);
        for c in 0..2 {
            for j in 0..ny {
                for i in 0..nx {
                    g.comp[c][b.lidx((i + 1) % nx, j, 0)] = f.comp[c][b.lidx(i, j, 0)];
                }
            }
        }
        g
    };
    let run = |u_init: VectorField| -> VectorField {
        let mut solver = PisoSolver::new(
            mesh.clone(),
            PisoConfig { dt: 0.02, ..Default::default() },
            0.01,
            ExecCtx::from_env(),
        );
        let mut st = State::zeros(&solver.mesh);
        st.u = u_init;
        let src = VectorField::zeros(solver.mesh.ncells);
        solver.run(&mut st, &src, 3);
        st.u
    };
    let a = shift(&run(u0.clone()));
    let b = run(shift(&u0));
    for c in 0..2 {
        for i in 0..mesh.ncells {
            assert!((a.comp[c][i] - b.comp[c][i]).abs() < 1e-7, "not equivariant");
        }
    }
}

/// 3D lid-driven cavity (paper Fig 3/B.17): symmetric in z about the
/// midplane and qualitatively matches the 2D solution on the center slice.
#[test]
fn cavity3d_z_symmetry_and_center_slice() {
    let n = 12;
    let mesh = gen::cavity3d(n, 1.0, 1.0, false);
    let mut solver = PisoSolver::new(
        mesh,
        PisoConfig { dt: 0.03, ..Default::default() },
        0.02, // Re = 50: fast convergence
        ExecCtx::from_env(),
    );
    let mut state = State::zeros(&solver.mesh);
    let src = VectorField::zeros(solver.mesh.ncells);
    solver.run(&mut state, &src, 120);
    let b = &solver.mesh.blocks[0];
    // z-symmetry of u about the midplane
    for j in 0..n {
        for i in 0..n {
            for k in 0..n / 2 {
                let a = state.u.comp[0][b.lidx(i, j, k)];
                let c = state.u.comp[0][b.lidx(i, j, n - 1 - k)];
                assert!((a - c).abs() < 1e-8, "z asymmetry at ({i},{j},{k}): {a} vs {c}");
            }
        }
    }
    // center slice resembles the 2D cavity: negative u low, positive near lid
    let u_low = state.u.comp[0][b.lidx(n / 2, 1, n / 2)];
    let u_top = state.u.comp[0][b.lidx(n / 2, n - 2, n / 2)];
    assert!(u_low < 0.0, "bottom return flow missing: {u_low}");
    assert!(u_top > 0.0, "lid-driven flow missing: {u_top}");
}

/// The divergence-free projection holds after every PISO step (compact
/// operator residual small relative to the velocity-gradient scale).
#[test]
fn per_step_divergence_bounded() {
    Prop::new(4, 0x303).check("div", |rng, _| {
        let mesh = gen::channel2d(10, 10, 1.0, 1.0, 1.1, rng.uniform() < 0.5);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.02, ..Default::default() },
            0.02,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        state.u = random_div_free(&solver.mesh, rng, 2);
        let src = VectorField::zeros(solver.mesh.ncells);
        for _ in 0..4 {
            let stats = solver.step(&mut state, &src, None);
            let umax = state.u.max_abs()[0].max(1e-6);
            if stats.max_divergence > 2.0 * umax * 12.0 {
                return Err(format!("divergence {} too large", stats.max_divergence));
            }
        }
        Ok(())
    });
}

/// Mass conservation through the pressure system: the divergence RHS sums
/// to (near) zero globally on closed domains.
#[test]
fn global_continuity_closed_domain() {
    let mesh = gen::cavity2d(12, 1.0, 1.0, true);
    let mut solver = PisoSolver::new(mesh, PisoConfig::default(), 0.01, ExecCtx::from_env());
    let mut state = State::zeros(&solver.mesh);
    let src = VectorField::zeros(solver.mesh.ncells);
    solver.run(&mut state, &src, 10);
    let div = fvm::divergence_h(&solver.mesh, &state.u, None);
    let net: f64 = div.iter().sum();
    assert!(net.abs() < 1e-9, "net flux {net}");
}
