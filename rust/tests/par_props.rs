//! Property tests for the pool-resident parallel execution substrate and
//! the transpose solve paths, over randomized sparsity patterns
//! (util::prop): the determinism contract (par == serial for
//! row-partitioned kernels), level-scheduled ILU(0) vs the serial
//! triangular solves, and pool-resident Krylov vs serial results.

use pict::linsolve::{bicgstab, cg, Ilu0, Jacobi, Preconditioner, SolveOpts};
use pict::par::ExecCtx;
use pict::sparse::Csr;
use pict::util::prop::Prop;
use pict::util::rng::Rng;

/// Random sparse matrix with a guaranteed nonzero diagonal.
fn random_sparse(n: usize, density: f64, rng: &mut Rng) -> Csr {
    let mut trip = Vec::new();
    for r in 0..n {
        for c in 0..n {
            if rng.uniform() < density {
                trip.push((r, c, rng.normal()));
            }
        }
        trip.push((r, r, 1.0 + rng.uniform()));
    }
    Csr::from_triplets(n, &trip)
}

/// Random strictly diagonally dominant (nonsymmetric) matrix — the shape of
/// the advection–diffusion system.
fn random_dd(n: usize, rng: &mut Rng) -> Csr {
    let mut trip = Vec::new();
    for r in 0..n {
        let mut offsum = 0.0;
        for c in 0..n {
            if c != r && rng.uniform() < 0.3 {
                let v = rng.normal() * 0.5;
                offsum += v.abs();
                trip.push((r, c, v));
            }
        }
        trip.push((r, r, offsum + 1.0 + rng.uniform()));
    }
    Csr::from_triplets(n, &trip)
}

#[test]
fn prop_matvec_transpose_matches_explicit_transpose() {
    Prop::new(24, 0x7151).check("mvT_vs_T", |rng, _| {
        let n = 2 + rng.below(40);
        let a = random_sparse(n, 0.35, rng);
        let x = rng.normal_vec(n);
        let mut y_scatter = vec![0.0; n];
        let mut y_gather = vec![0.0; n];
        a.matvec_transpose(&x, &mut y_scatter);
        a.transpose().matvec(&x, &mut y_gather);
        // both sum contributions in ascending original-row order, so the
        // scatter and gather paths are bit-identical
        if y_scatter != y_gather {
            return Err("scatter Aᵀx != gather (Aᵀ)x".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pool_matvec_bit_for_bit_serial() {
    let ctx = ExecCtx::with_threads(8);
    Prop::new(16, 0xB17F).check("par_matvec", |rng, case| {
        let n = 8 + rng.below(120);
        let a = random_sparse(n, 0.25, rng);
        let x = rng.normal_vec(n);
        let mut y_serial = vec![0.0; n];
        a.matvec(&x, &mut y_serial);
        for nt in [2, 3, 4, 8] {
            let mut y_par = vec![0.0; n];
            ctx.matvec_chunks(&a, &x, &mut y_par, nt);
            if y_par != y_serial {
                return Err(format!("case {case}: nt={nt} differs from serial"));
            }
        }
        // the auto-dispatching entry point must agree as well (it may take
        // either path depending on the work threshold)
        let mut y_auto = vec![0.0; n];
        ctx.matvec(&a, &x, &mut y_auto);
        if y_auto != y_serial {
            return Err("auto-dispatch matvec differs from serial".into());
        }
        Ok(())
    });
}

#[test]
fn pool_matvec_above_threshold_is_bit_for_bit_serial() {
    // large enough that the auto path actually engages the pool
    let mut rng = Rng::new(0xA11C);
    let n = 600;
    let a = random_sparse(n, 0.1, &mut rng);
    assert!(a.nnz() >= 2 * pict::par::MIN_NNZ_PER_THREAD, "nnz {}", a.nnz());
    let x = rng.normal_vec(n);
    let mut y_serial = vec![0.0; n];
    let mut y_par = vec![0.0; n];
    a.matvec(&x, &mut y_serial);
    ExecCtx::with_threads(4).matvec(&a, &x, &mut y_par);
    assert_eq!(y_serial, y_par);
}

#[test]
fn prop_pool_transpose_matches_serial_to_roundoff() {
    let ctx = ExecCtx::with_threads(5);
    Prop::new(12, 0x7A57).check("par_mvT", |rng, _| {
        let n = 8 + rng.below(100);
        let a = random_sparse(n, 0.25, rng);
        let x = rng.normal_vec(n);
        let mut y_serial = vec![0.0; n];
        a.matvec_transpose(&x, &mut y_serial);
        for nt in [2, 5] {
            let mut y_par = vec![0.0; n];
            ctx.matvec_transpose_chunks(&a, &x, &mut y_par, nt);
            for (p, s) in y_par.iter().zip(&y_serial) {
                if (p - s).abs() > 1e-12 * (1.0 + s.abs()) {
                    return Err(format!("nt={nt}: {p} vs {s}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_level_scheduled_ilu0_apply_is_bit_for_bit_serial() {
    // the satellite contract: level-scheduled triangular solves (parallel
    // path forced via min_rows=1) must equal the serial apply exactly on
    // random nonsymmetric systems
    let ctx = ExecCtx::with_threads(4);
    let serial = ExecCtx::serial();
    Prop::new(16, 0x11D0).check("ilu_levels", |rng, case| {
        let n = 10 + rng.below(120);
        let a = random_dd(n, rng);
        let ilu = Ilu0::new(&a);
        let r = rng.normal_vec(n);
        let mut z_serial = vec![0.0; n];
        let mut z_par = vec![0.0; n];
        ilu.apply(&serial, &r, &mut z_serial);
        ilu.apply_min_rows(&ctx, &r, &mut z_par, 1);
        if z_serial != z_par {
            return Err(format!("case {case}: level-scheduled apply differs (n={n})"));
        }
        Ok(())
    });
}

#[test]
fn prop_bicgstab_transpose_solves_nonsymmetric_adjoint() {
    Prop::new(12, 0xADE0).check("bicgstab_T", |rng, _| {
        let n = 5 + rng.below(50);
        let a = random_dd(n, rng);
        let xs = rng.normal_vec(n);
        // b = Aᵀ xs via the scatter kernel; solve in transpose mode
        let mut b = vec![0.0; n];
        a.matvec_transpose(&xs, &mut b);
        let mut x = vec![0.0; n];
        let st = bicgstab(
            &ExecCtx::serial(),
            &a,
            &b,
            &mut x,
            &Jacobi::new(&a.transpose()),
            false,
            SolveOpts { transpose: true, ..Default::default() },
        );
        if !st.converged {
            return Err(format!("n={n}: no convergence, res={}", st.residual));
        }
        let at = a.transpose();
        let bnorm = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        let res = at.residual_norm(&x, &b);
        if res > 1e-6 * (1.0 + bnorm) {
            return Err(format!("Aᵀ residual {res}"));
        }
        Ok(())
    });
}

#[test]
fn cg_transpose_mode_equals_forward_on_symmetric_systems() {
    // CG only applies to symmetric matrices, where Aᵀ x = b IS A x = b; the
    // transpose flag must therefore reuse the fast gather matvec and give
    // the identical iterates.
    let n = 40;
    let mut trip = Vec::new();
    for i in 0..n {
        trip.push((i, i, 2.0));
        if i > 0 {
            trip.push((i, i - 1, -1.0));
        }
        if i + 1 < n {
            trip.push((i, i + 1, -1.0));
        }
    }
    let a = Csr::from_triplets(n, &trip);
    let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.4).sin()).collect();
    let mut x_fwd = vec![0.0; n];
    let mut x_t = vec![0.0; n];
    let id = pict::linsolve::precond::Identity;
    let ctx = ExecCtx::serial();
    let st1 = cg(&ctx, &a, &b, &mut x_fwd, &id, false, SolveOpts::default());
    let st2 = cg(
        &ctx,
        &a,
        &b,
        &mut x_t,
        &id,
        false,
        SolveOpts { transpose: true, ..Default::default() },
    );
    assert!(st1.converged && st2.converged);
    // identical dispatch ⇒ identical iterates, not merely close
    assert_eq!(x_fwd, x_t);
    assert_eq!(st1.iterations, st2.iterations);
}

/// The Poiseuille pressure system the batch runner exercises: small enough
/// that every kernel stays under the parallel thresholds, so pool-resident
/// CG must reproduce the serial (pre-refactor) iterates bit-for-bit.
fn poiseuille_pressure_system() -> (Csr, Vec<f64>) {
    use pict::fvm;
    use pict::mesh::gen;
    let mesh = gen::channel2d(6, 16, 1.0, 1.0, 1.12, false);
    let a_inv = vec![1.0; mesh.ncells];
    let mut m = fvm::pressure_structure(&mesh);
    fvm::assemble_pressure(&ExecCtx::serial(), &mesh, &a_inv, &mut m);
    // a consistent, mean-free RHS shaped like a divergence field
    let mut rhs: Vec<f64> = mesh
        .centers
        .iter()
        .map(|c| (7.1 * c[0]).sin() * (3.3 * c[1]).cos())
        .collect();
    let mean = rhs.iter().sum::<f64>() / rhs.len() as f64;
    rhs.iter_mut().for_each(|v| *v -= mean);
    (m, rhs)
}

#[test]
fn pool_resident_cg_matches_serial_on_poiseuille_pressure() {
    let (m, rhs) = poiseuille_pressure_system();
    let precond = Jacobi::new(&m);
    let mut x_serial = vec![0.0; m.n];
    let mut x_pool = vec![0.0; m.n];
    let st_s = cg(
        &ExecCtx::serial(),
        &m,
        &rhs,
        &mut x_serial,
        &precond,
        true,
        SolveOpts::default(),
    );
    let st_p = cg(
        &ExecCtx::with_threads(4),
        &m,
        &rhs,
        &mut x_pool,
        &precond,
        true,
        SolveOpts::default(),
    );
    assert!(st_s.converged && st_p.converged);
    assert_eq!(x_serial, x_pool, "pool-resident CG must match serial bit-for-bit");
    assert_eq!(st_s.iterations, st_p.iterations);
}

#[test]
fn pool_resident_bicgstab_matches_serial_on_poiseuille_pressure() {
    let (m, rhs) = poiseuille_pressure_system();
    // regularize the singular pressure matrix so BiCGStab has a unique
    // solution (same system both ways, so the comparison still holds)
    let mut a = m.clone();
    for i in 0..a.n {
        let k = a.find(i, i).expect("diag");
        a.vals[k] += 1.0;
    }
    let precond = Ilu0::new(&a);
    let mut x_serial = vec![0.0; a.n];
    let mut x_pool = vec![0.0; a.n];
    let st_s = bicgstab(
        &ExecCtx::serial(),
        &a,
        &rhs,
        &mut x_serial,
        &precond,
        false,
        SolveOpts::default(),
    );
    let st_p = bicgstab(
        &ExecCtx::with_threads(4),
        &a,
        &rhs,
        &mut x_pool,
        &precond,
        false,
        SolveOpts::default(),
    );
    assert!(st_s.converged && st_p.converged);
    assert_eq!(x_serial, x_pool, "pool-resident BiCGStab must match serial bit-for-bit");
    assert_eq!(st_s.iterations, st_p.iterations);
}
