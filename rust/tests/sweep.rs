//! End-to-end sweep tests: sharded execution + merge must be bit-for-bit
//! equal to a single-process batch at the same pool width, resume must skip
//! valid artifacts and recompute damaged ones, and a failing scenario must
//! cost exactly its own slot of its own shard.

use pict::adjoint::{GradientPaths, TapeStrategy};
use pict::coordinator::scenario::{
    reduce_shared, taylor_green_nu_sweep, BatchRunner, Scenario, ScenarioRun, TaylorGreen,
    TerminalKineticEnergy,
};
use pict::coordinator::sweep::{self, ShardOutcome, ShardStatus, SweepEntry, SweepSpec};
use std::path::PathBuf;

const NUS: [f64; 4] = [0.01, 0.02, 0.03, 0.05];

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pict_sweep_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn forward_spec(shards: usize, steps: usize) -> SweepSpec {
    SweepSpec {
        scenarios: taylor_green_nu_sweep(8, &NUS),
        steps,
        shards,
        threads: 2,
        grad: false,
    }
}

fn assert_bits(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a:e} != {b:e}");
}

#[test]
fn two_shard_forward_merge_is_bit_for_bit_single_process() {
    let dir2 = fresh_dir("fwd2");
    let spec2 = forward_spec(2, 2);
    let reports = sweep::run_shards(&spec2, &dir2, None).expect("sweep shards run and write");
    assert_eq!(reports.len(), 2);
    assert!(
        reports.iter().all(|r| r.outcome == ShardOutcome::Computed { failures: 0 }),
        "fresh sweep computes every shard"
    );
    let merged = sweep::merge(&spec2, &dir2).expect("valid shards merge");
    assert_eq!(merged.entries.len(), NUS.len());
    assert_eq!(merged.failures, 0);

    // single-process baseline: the whole grid in one batch, same width
    let baseline = BatchRunner::new(2).with_threads(2).run(&taylor_green_nu_sweep(8, &NUS));
    for (e, b) in merged.entries.iter().zip(&baseline) {
        let r = match e {
            SweepEntry::Forward(r) => r,
            _ => panic!("forward sweep produced a non-forward entry"),
        };
        assert_eq!(r.label, b.label);
        assert_eq!(r.state.u, b.state.u, "{}: velocity differs from single process", r.label);
        for (x, y) in r.state.p.iter().zip(&b.state.p) {
            assert_bits(*x, *y, "pressure");
        }
        assert_bits(r.state.time, b.state.time, "time");
        assert_eq!(r.state.step, b.state.step);
        assert_eq!(r.steps, b.steps);
        assert_eq!(r.adv_iters, b.adv_iters);
        assert_eq!(r.p_iters, b.p_iters);
        assert_bits(r.adv_residual, b.adv_residual, "adv residual");
        assert_bits(r.p_residual, b.p_residual, "pressure residual");
        assert_bits(r.max_divergence, b.max_divergence, "divergence");
        assert_bits(r.last.dt, b.last.dt, "last dt");
    }

    // merged documents are byte-identical regardless of shard count
    let dir1 = fresh_dir("fwd1");
    let spec1 = forward_spec(1, 2);
    sweep::run_shards(&spec1, &dir1, None).expect("one-shard sweep runs");
    let merged1 = sweep::merge(&spec1, &dir1).expect("one-shard sweep merges");
    let out2 = dir2.join("merged.json");
    let out1 = dir1.join("merged.json");
    sweep::write_merged(&spec2, &merged, &out2).expect("merged doc writes");
    sweep::write_merged(&spec1, &merged1, &out1).expect("merged doc writes");
    let bytes2 = std::fs::read(&out2).expect("merged doc reads back");
    let bytes1 = std::fs::read(&out1).expect("merged doc reads back");
    assert_eq!(bytes1, bytes2, "merged bytes must not depend on shard count");

    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir2);
}

#[test]
fn gradient_sweep_merges_states_and_shared_grads_bit_for_bit() {
    let nus = [0.02, 0.05];
    let steps = 2;
    let dir = fresh_dir("grad");
    let spec = SweepSpec {
        scenarios: taylor_green_nu_sweep(8, &nus),
        steps,
        shards: 2,
        threads: 2,
        grad: true,
    };
    sweep::run_shards(&spec, &dir, None).expect("gradient shards run");
    let merged = sweep::merge(&spec, &dir).expect("gradient shards merge");
    assert_eq!(merged.failures, 0);

    // baseline: same grid, one process, same width / loss / tape / paths
    let loss = TerminalKineticEnergy { final_step: steps - 1 };
    let baseline = BatchRunner::new(steps).with_threads(2).run_gradients(
        &taylor_green_nu_sweep(8, &nus),
        TapeStrategy::Full,
        GradientPaths::FULL,
        &loss,
    );
    for (e, b) in merged.entries.iter().zip(&baseline) {
        let g = match e {
            SweepEntry::Gradient(g) => g,
            _ => panic!("gradient sweep produced a non-gradient entry"),
        };
        assert_eq!(g.label, b.label);
        assert_bits(g.loss, b.loss, "loss");
        assert_eq!(g.state.u, b.state.u, "{}: state differs from single process", g.label);
        assert_eq!(g.grads.du0, b.grads.du0, "{}: du0 differs", g.label);
        assert_bits(g.grads.dnu, b.grads.dnu, "dnu");
        assert_eq!(g.grads.dsource.len(), b.grads.dsource.len());
        for (x, y) in g.grads.dsource.iter().zip(&b.grads.dsource) {
            assert_eq!(x, y, "{}: dsource differs", g.label);
        }
        assert_eq!(g.mesh_fp, b.mesh_fp);
    }

    // SharedGrads reduce over the merged list exactly like a single process
    let shared = merged.shared.as_ref().expect("gradient sweep reduces shared grads");
    let want = reduce_shared(&baseline);
    assert_bits(shared.dnu, want.dnu, "shared dnu");
    let du0 = shared.du0.as_ref().expect("same-mesh sweep reduces du0");
    let want_du0 = want.du0.as_ref().expect("same-mesh baseline reduces du0");
    assert_eq!(du0, want_du0, "shared du0 differs from single process");
    let ds = shared.dsource.as_ref().expect("same-mesh sweep reduces dsource");
    let want_ds = want.dsource.as_ref().expect("same-mesh baseline reduces dsource");
    assert_eq!(ds, want_ds, "shared dsource differs from single process");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_skips_valid_shards_and_recomputes_damaged_ones() {
    let dir = fresh_dir("resume");
    let spec = SweepSpec {
        scenarios: taylor_green_nu_sweep(8, &[0.01, 0.02, 0.03]),
        steps: 1,
        shards: 3,
        threads: 2,
        grad: false,
    };
    let first = sweep::run_shards(&spec, &dir, None).expect("initial sweep runs");
    assert!(first.iter().all(|r| matches!(r.outcome, ShardOutcome::Computed { .. })));

    // a clean re-invocation skips everything
    let again = sweep::run_shards(&spec, &dir, None).expect("re-invocation runs");
    assert!(
        again.iter().all(|r| r.outcome == ShardOutcome::Skipped),
        "all-valid sweep must be a no-op"
    );

    // damage the artifacts: delete one, truncate another mid-file
    let baseline = sweep::merge(&spec, &dir).expect("undamaged sweep merges");
    std::fs::remove_file(sweep::shard_path(&dir, 1)).expect("shard 1 artifact removable");
    let victim = sweep::shard_path(&dir, 2);
    let full = std::fs::read(&victim).expect("shard 2 artifact readable");
    std::fs::write(&victim, &full[..full.len() / 2]).expect("shard 2 artifact truncatable");

    let statuses = sweep::sweep_status(&spec, &dir);
    assert_eq!(statuses[0].1, ShardStatus::Valid);
    assert_eq!(statuses[1].1, ShardStatus::Missing);
    assert!(
        matches!(statuses[2].1, ShardStatus::Invalid(_)),
        "truncated artifact must read as invalid, got {:?}",
        statuses[2].1
    );
    // merge refuses a damaged sweep instead of treating it as complete
    assert!(sweep::merge(&spec, &dir).is_err(), "merge must reject missing/truncated shards");

    let resumed = sweep::run_shards(&spec, &dir, None).expect("resume runs");
    assert_eq!(resumed[0].outcome, ShardOutcome::Skipped);
    assert_eq!(resumed[1].outcome, ShardOutcome::Computed { failures: 0 });
    assert_eq!(resumed[2].outcome, ShardOutcome::Computed { failures: 0 });

    // the repaired sweep merges to exactly what the undamaged one did
    let repaired = sweep::merge(&spec, &dir).expect("repaired sweep merges");
    assert_eq!(repaired.entries.len(), baseline.entries.len());
    for (a, b) in repaired.entries.iter().zip(&baseline.entries) {
        let (ra, rb) = match (a, b) {
            (SweepEntry::Forward(ra), SweepEntry::Forward(rb)) => (ra, rb),
            _ => panic!("forward sweep entries changed kind across resume"),
        };
        assert_eq!(ra.label, rb.label);
        assert_eq!(ra.state.u, rb.state.u, "{}: resume changed the result", ra.label);
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// Taylor–Green with a NaN seeded into the initial velocity — diverges (or
/// trips the debug non-finite guard) on the first step.
struct NanSeed;

impl Scenario for NanSeed {
    fn kind(&self) -> &'static str {
        "nan-seed"
    }
    fn label(&self) -> String {
        "nan-seed".to_string()
    }
    fn build(&self) -> ScenarioRun {
        let mut run = TaylorGreen { n: 8, ..Default::default() }.build();
        run.state.u.comp[0][5] = f64::NAN;
        run.label = self.label();
        run
    }
}

#[test]
fn failing_scenario_costs_one_slot_and_its_shard_still_resumes() {
    let dir = fresh_dir("fail");
    let spec = SweepSpec {
        scenarios: vec![
            Box::new(TaylorGreen { n: 8, nu: 0.01, ..Default::default() }),
            Box::new(NanSeed),
            Box::new(TaylorGreen { n: 8, nu: 0.02, ..Default::default() }),
        ],
        steps: 1,
        shards: 2,
        threads: 2,
        grad: false,
    };
    let reports = sweep::run_shards(&spec, &dir, None).expect("sweep with a failing slot runs");
    let failed: usize = reports
        .iter()
        .map(|r| match r.outcome {
            ShardOutcome::Computed { failures } => failures,
            ShardOutcome::Skipped => 0,
        })
        .sum();
    assert_eq!(failed, 1, "exactly the NaN-seeded slot fails");

    let merged = sweep::merge(&spec, &dir).expect("sweep with a failed slot still merges");
    assert_eq!(merged.failures, 1);
    assert_eq!(merged.entries.len(), 3);
    match &merged.entries[1] {
        SweepEntry::Failed { label, error } => {
            assert_eq!(label, "nan-seed");
            assert!(!error.is_empty(), "failure reason must be recorded");
        }
        _ => panic!("the NaN-seeded slot must merge as Failed"),
    }
    for i in [0usize, 2] {
        match &merged.entries[i] {
            SweepEntry::Forward(r) => assert_eq!(r.state.step, 1, "{}: lost its work", r.label),
            _ => panic!("healthy slot {i} must merge as a completed forward result"),
        }
    }

    // a shard containing a failed slot is still a *valid, complete* artifact
    let again = sweep::run_shards(&spec, &dir, None).expect("re-invocation runs");
    assert!(
        again.iter().all(|r| r.outcome == ShardOutcome::Skipped),
        "failed slots are recorded outcomes, not resume work"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
