//! Forward-simulation validation (paper §4.1, Appendix B): plane Poiseuille
//! against the analytic solution, lid-driven cavity against the Ghia et al.
//! reference, and multi-block/BFS smoke runs. These are the integration-level
//! counterparts of the per-module unit tests.

use pict::fvm;
use pict::mesh::{field, gen, VectorField};
use pict::par::ExecCtx;
use pict::piso::{PisoConfig, PisoSolver, State};

/// B.1: Poiseuille flow u(y) = G/(2ν) y(1−y) with G=ν=1 ⇒ u_max = 0.125.
#[test]
fn poiseuille_matches_analytic() {
    for (refined, tol) in [(false, 0.02), (true, 0.02)] {
        let mesh = gen::channel2d(8, 16, 1.0, 1.0, 1.12, refined);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.05, n_correctors: 2, ..Default::default() },
            1.0,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        let mut src = VectorField::zeros(solver.mesh.ncells);
        src.comp[0].iter_mut().for_each(|v| *v = 1.0);
        // steady state: viscous timescale 1/(νπ²) ≈ 0.1 ⇒ t=2 is plenty
        solver.run(&mut state, &src, 40);
        let mut max_err = 0.0f64;
        for (cell, c) in solver.mesh.centers.iter().enumerate() {
            let exact = 0.5 * c[1] * (1.0 - c[1]);
            max_err = max_err.max((state.u.comp[0][cell] - exact).abs());
        }
        assert!(
            max_err < tol * 0.125,
            "refined={refined}: max error {max_err} vs u_max 0.125"
        );
    }
}

/// B.1 (non-orthogonal): Poiseuille on a rotationally distorted grid stays
/// stable and close to the analytic profile.
#[test]
fn poiseuille_on_distorted_grid() {
    // distorted closed cavity won't do; build a mildly distorted channel by
    // reusing the distorted cavity generator with zero lid velocity plus a
    // body force in x — flow between no-slip walls driven by G, with closed
    // ends acting as walls. Instead verify solver stability + symmetry.
    // lid-driven cavity on a distorted grid: must stay stable and roughly
    // match the regular-grid solution (paper: "impacted by the worse mesh
    // quality but still stable and close to the reference").
    let run = |mesh: pict::mesh::Mesh| {
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.02, n_correctors: 2, n_nonorth: 1, ..Default::default() },
            0.01,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        let src = VectorField::zeros(solver.mesh.ncells);
        solver.run(&mut state, &src, 250);
        (solver, state)
    };
    let (sr, str_) = run(gen::cavity2d(16, 1.0, 1.0, false));
    let (sd, std_) = run(gen::distorted_cavity2d(16, 1.0, 1.0, 0.15));
    let m = std_.u.max_abs();
    assert!(m[0].is_finite() && m[0] <= 1.0, "unstable: {m:?}");
    let mut worst = 0.0f64;
    for y in [0.25, 0.5, 0.75] {
        let a = field::sample_idw(&sr.mesh, &str_.u.comp[0], [0.5, y, 0.5]);
        let b = field::sample_idw(&sd.mesh, &std_.u.comp[0], [0.5, y, 0.5]);
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 0.08, "distorted-vs-regular centerline mismatch {worst}");
}

/// B.2: lid-driven cavity Re=100 vs Ghia et al. (1982), coarse-grid
/// tolerance. Reference u on the vertical centerline (y, u).
#[test]
fn cavity_re100_vs_ghia() {
    let ghia_yu: [(f64, f64); 7] = [
        (0.0547, -0.03717),
        (0.1719, -0.10150),
        (0.2813, -0.15662),
        (0.4531, -0.21090),
        (0.6172, -0.13641),
        (0.8516, 0.23151),
        (0.9609, 0.73722),
    ];
    let n = 32;
    let mesh = gen::cavity2d(n, 1.0, 1.0, false);
    let mut solver = PisoSolver::new(
        mesh,
        PisoConfig { dt: 0.02, n_correctors: 2, ..Default::default() },
        0.01, // Re = U L / ν = 100
        ExecCtx::from_env(),
    );
    let mut state = State::zeros(&solver.mesh);
    let src = VectorField::zeros(solver.mesh.ncells);
    solver.run(&mut state, &src, 1500); // t = 30 ≫ L²/ν transient
    let mut worst = 0.0f64;
    for (y, u_ref) in ghia_yu {
        let u = field::sample_idw(&solver.mesh, &state.u.comp[0], [0.5, y, 0.5]);
        worst = worst.max((u - u_ref).abs());
    }
    // 32² collocated central scheme: ≲1% of U on the centerline
    assert!(worst < 0.012, "worst centerline error {worst}");
}

/// Multi-block consistency: a channel split into two connected blocks gives
/// the same Poiseuille solution as the single-block mesh.
#[test]
fn two_block_channel_matches_single_block() {
    let run = |mesh: pict::mesh::Mesh| {
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.05, ..Default::default() },
            1.0,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        let mut src = VectorField::zeros(solver.mesh.ncells);
        src.comp[0].iter_mut().for_each(|v| *v = 1.0);
        solver.run(&mut state, &src, 30);
        (solver, state)
    };
    let (s1, st1) = run(gen::channel2d(8, 8, 2.0, 1.0, 1.0, false));
    let (s2, st2) = run(gen::two_block_channel2d(4, 8, 0));
    // compare u at matching physical points
    for y in [0.1875, 0.4375, 0.8125] {
        let a = field::sample_idw(&s1.mesh, &st1.u.comp[0], [0.9, y, 0.5]);
        let b = field::sample_idw(&s2.mesh, &st2.u.comp[0], [0.9, y, 0.5]);
        assert!((a - b).abs() < 1e-6, "mismatch at y={y}: {a} vs {b}");
    }
}

/// BFS (B.5 geometry, low Re): flow develops, remains bounded, and mass is
/// conserved through the advective outflow.
#[test]
fn bfs_smoke_run_with_outflow() {
    let cfg = gen::BfsCfg {
        nx_in: 6,
        nx_down: 24,
        ny_up: 8,
        ny_low: 6,
        l_down: 15.0,
        ..Default::default()
    };
    let mesh = gen::bfs(&cfg);
    let nu = 2.0 * cfg.h * cfg.u_bulk / 200.0; // Re = 200
    let mut solver = PisoSolver::new(
        mesh,
        PisoConfig { dt: 0.02, target_cfl: Some(0.8), use_ilu: true, ..Default::default() },
        nu,
        ExecCtx::from_env(),
    );
    let mut state = State::zeros(&solver.mesh);
    let src = VectorField::zeros(solver.mesh.ncells);
    for _ in 0..60 {
        let stats = solver.step(&mut state, &src, None);
        assert!(stats.adv_residual < 1e-4, "adv residual {}", stats.adv_residual);
    }
    let m = state.u.max_abs();
    assert!(m[0].is_finite() && m[0] < 5.0, "unstable: {m:?}");
    assert!(m[0] > 0.5, "flow did not develop");
    // global mass balance: net boundary flux ≈ 0 (the divergence RHS sums
    // to ~0 over the domain)
    let div = fvm::divergence_h(&solver.mesh, &state.u, None);
    let net: f64 = div.iter().sum();
    assert!(net.abs() < 1e-6, "net boundary flux {net}");
}

/// Vortex street mesh (B.4): stable shedding-onset run on the 8-block grid.
#[test]
fn vortex_street_smoke_run() {
    let cfg = gen::VortexStreetCfg {
        nx: [6, 4, 12],
        ny: [8, 4, 8],
        ..Default::default()
    };
    let mesh = gen::vortex_street(&cfg);
    let nu = cfg.u_in * cfg.obs_h / 100.0;
    let mut solver = PisoSolver::new(
        mesh,
        PisoConfig { dt: 0.05, target_cfl: Some(0.8), use_ilu: true, ..Default::default() },
        nu,
        ExecCtx::from_env(),
    );
    let mut state = State::zeros(&solver.mesh);
    let src = VectorField::zeros(solver.mesh.ncells);
    for _ in 0..40 {
        solver.step(&mut state, &src, None);
    }
    let m = state.u.max_abs();
    assert!(m[0].is_finite() && m[0] < 10.0, "unstable: {m:?}");
    assert!(m[0] > 0.1, "flow did not develop");
}
