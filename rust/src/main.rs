//! PICT CLI — the deployable entrypoint: runs validations, experiments, and
//! artifact checks. `pict <command> [--options]`; see `pict help`.

use pict::util::cli::Args;

fn main() {
    let args = Args::parse();
    match args.positional.first().map(|s| s.as_str()) {
        Some("gradpaths") => {
            use pict::adjoint::GradientPaths;
            use pict::coordinator::experiments::{gradient_path_ablation, GradPathCfg};
            let n = args.usize_or("n", 10);
            for paths in
                [GradientPaths::FULL, GradientPaths::P, GradientPaths::ADV, GradientPaths::NONE]
            {
                let cfg = GradPathCfg {
                    n_steps: n,
                    lr: args.f64_or("lr", 0.04),
                    opt_iters: args.usize_or("iters", 40),
                    paths,
                    ..Default::default()
                };
                let r = gradient_path_ablation(&cfg);
                println!(
                    "{:<6} loss {:.2e} -> {:.2e}, theta {:.4}, {:.2}s{}",
                    r.label,
                    r.losses[0],
                    r.losses.last().unwrap(),
                    r.final_theta,
                    r.times.last().unwrap(),
                    if r.diverged { " [DIVERGED]" } else { "" }
                );
            }
        }
        #[cfg(feature = "pjrt")]
        Some("artifacts") => {
            let dir = args.get_or("dir", "artifacts");
            match pict::runtime::ArtifactSet::load(&dir) {
                Ok(set) => {
                    println!("artifacts in {dir}:");
                    for m in &set.metas {
                        println!(
                            "  {} ({}): {} inputs, {} outputs",
                            m.entry,
                            m.file,
                            m.inputs.len(),
                            m.outputs.len()
                        );
                    }
                }
                Err(e) => eprintln!("failed to load artifacts: {e}"),
            }
        }
        #[cfg(not(feature = "pjrt"))]
        Some("artifacts") => {
            eprintln!("the PJRT runtime is disabled; rebuild with `--features pjrt`");
        }
        Some("batch") => {
            use pict::coordinator::scenario::{builtin_scenarios, BatchRunner};
            use pict::util::bench::print_table;
            let steps = args.usize_or("steps", 10);
            let threads = args.usize_or("threads", pict::par::env_threads());
            let scenarios = builtin_scenarios();
            let runner = BatchRunner::new(steps).with_threads(threads);
            println!(
                "advancing {} scenarios x {steps} steps on a {}-worker pool...",
                scenarios.len(),
                runner.threads()
            );
            let results = runner.run(&scenarios);
            let rows: Vec<Vec<String>> = results
                .iter()
                .map(|r| {
                    vec![
                        r.label.clone(),
                        format!("{}", r.state.step),
                        format!("{:.3}", r.state.time),
                        format!("{}", r.adv_iters),
                        format!("{}", r.p_iters),
                        format!("{:.2e}", r.max_divergence),
                        format!("{:.2}s", r.wall_s),
                    ]
                })
                .collect();
            print_table(
                "batch run",
                &["scenario", "steps", "t", "adv iters", "p iters", "max div", "wall"],
                &rows,
            );
        }
        Some("cavity") => {
            use pict::coordinator::references::GHIA_RE100_U;
            use pict::mesh::{field, gen, VectorField};
            use pict::piso::{PisoConfig, PisoSolver, State};
            let n = args.usize_or("n", 32);
            let mesh = gen::cavity2d(n, 1.0, 1.0, args.flag("refined"));
            let mut solver = PisoSolver::new(
                mesh,
                PisoConfig { dt: 0.02, ..Default::default() },
                1.0 / args.f64_or("re", 100.0),
            );
            let mut state = State::zeros(&solver.mesh);
            let src = VectorField::zeros(solver.mesh.ncells);
            solver.run(&mut state, &src, args.usize_or("steps", 1200));
            let mut worst = 0.0f64;
            for (y, u_ref) in GHIA_RE100_U {
                let u = field::sample_idw(&solver.mesh, &state.u.comp[0], [0.5, y, 0.5]);
                worst = worst.max((u - u_ref).abs());
            }
            println!("cavity {n}x{n}: worst centerline error vs Ghia = {worst:.4}");
        }
        _ => {
            println!("PICT — differentiable multi-block PISO solver (Rust + JAX + Pallas)");
            println!("commands:");
            println!("  gradpaths [--n 10] [--iters 40] [--lr 0.08]   gradient-path ablation (E4)");
            println!("  cavity [--n 32] [--re 100] [--steps 1200]     lid-driven cavity vs Ghia");
            println!("  batch [--steps 10] [--threads N]              run all registered scenarios on one N-worker pool");
            println!("  artifacts [--dir artifacts]                   list AOT artifacts (needs --features pjrt)");
            println!("env: PICT_THREADS=<n> sizes the worker pool (default: all cores; read per context, never cached)");
            println!("examples: cargo run --release --example quickstart | train_sgs_tcf | ...");
            println!("benches:  cargo bench  (one per paper table/figure — see DESIGN.md)");
        }
    }
}
