//! PICT CLI — the deployable entrypoint: runs validations, experiments, and
//! artifact checks. `pict <command> [--options]`; see `pict help`.

use pict::util::cli::Args;
use std::process::ExitCode;

/// `--precision f64|mixed` shared by `batch` and `train`; `None` means the
/// value was unrecognized (an error has already been printed).
fn parse_precision(args: &Args, cmd: &str) -> Option<pict::linsolve::Precision> {
    match args.get_or("precision", "f64").as_str() {
        "f64" => Some(pict::linsolve::Precision::F64),
        "mixed" => Some(pict::linsolve::Precision::Mixed),
        other => {
            eprintln!("pict {cmd}: unsupported --precision {other} (f64 | mixed)");
            None
        }
    }
}

fn main() -> ExitCode {
    let args = Args::parse();
    // every arm yields an exit code: argument/config errors and failed
    // loads exit nonzero so sweep drivers and CI can trust `$?`
    match args.positional.first().map(|s| s.as_str()) {
        Some("gradpaths") => {
            use pict::adjoint::GradientPaths;
            use pict::coordinator::experiments::{gradient_path_ablation, GradPathCfg};
            let n = args.usize_or("n", 10);
            for paths in
                [GradientPaths::FULL, GradientPaths::P, GradientPaths::ADV, GradientPaths::NONE]
            {
                let cfg = GradPathCfg {
                    n_steps: n,
                    lr: args.f64_or("lr", 0.04),
                    opt_iters: args.usize_or("iters", 40),
                    paths,
                    ..Default::default()
                };
                let r = gradient_path_ablation(&cfg);
                println!(
                    "{:<6} loss {:.2e} -> {:.2e}, theta {:.4}, {:.2}s{}",
                    r.label,
                    r.losses[0],
                    r.losses.last().expect("ablation records one loss per iteration"),
                    r.final_theta,
                    r.times.last().expect("ablation records one time per iteration"),
                    if r.diverged { " [DIVERGED]" } else { "" }
                );
            }
            ExitCode::SUCCESS
        }
        #[cfg(feature = "pjrt")]
        Some("artifacts") => {
            let dir = args.get_or("dir", "artifacts");
            match pict::runtime::ArtifactSet::load(&dir) {
                Ok(set) => {
                    println!("artifacts in {dir}:");
                    for m in &set.metas {
                        println!(
                            "  {} ({}): {} inputs, {} outputs",
                            m.entry,
                            m.file,
                            m.inputs.len(),
                            m.outputs.len()
                        );
                    }
                    ExitCode::SUCCESS
                }
                Err(e) => {
                    eprintln!("failed to load artifacts: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        #[cfg(not(feature = "pjrt"))]
        Some("artifacts") => {
            eprintln!("the PJRT runtime is disabled; rebuild with `--features pjrt`");
            ExitCode::FAILURE
        }
        Some("batch") => {
            use pict::coordinator::scenario::{builtin_scenarios, BatchRunner};
            use pict::util::bench::print_table;
            let steps = args.usize_or("steps", 10);
            let threads = args.usize_or("threads", pict::par::env_threads());
            let Some(precision) = parse_precision(&args, "batch") else {
                return ExitCode::FAILURE;
            };
            let scenarios = builtin_scenarios();
            let runner = BatchRunner::new(steps).with_threads(threads).with_precision(precision);
            println!(
                "advancing {} scenarios x {steps} steps on a {}-worker pool...",
                scenarios.len(),
                runner.threads()
            );
            let results = runner.run(&scenarios);
            let rows: Vec<Vec<String>> = results
                .iter()
                .map(|r| {
                    vec![
                        r.label.clone(),
                        format!("{}", r.state.step),
                        format!("{:.3}", r.state.time),
                        format!("{}", r.adv_iters),
                        format!("{}", r.p_iters),
                        format!("{:.2e}", r.max_divergence),
                        format!("{:.2}s", r.wall_s),
                    ]
                })
                .collect();
            print_table(
                "batch run",
                &["scenario", "steps", "t", "adv iters", "p iters", "max div", "wall"],
                &rows,
            );
            ExitCode::SUCCESS
        }
        Some("train") => {
            use pict::adjoint::{GradientPaths, TapeStrategy};
            use pict::coordinator::engine::{scenario_reference_frames, train_corrector_batch};
            use pict::coordinator::scenario::{
                reduce_shared, BatchRunner, LidDrivenCavity, Scenario, TaylorGreen,
                TerminalKineticEnergy,
            };
            use pict::coordinator::experiments::Corrector2dCfg;
            use pict::util::bench::print_table;

            let kind = args.get_or("kind", "cavity");
            let n = args.usize_or("n", 12);
            let unroll = args.usize_or("steps", 4).max(1);
            let every = args.usize_or("every", 0);
            let threads = args.usize_or("threads", pict::par::env_threads());
            // mixed precision accelerates the *forward* reference frames;
            // gradient batches always solve in f64 (see BatchRunner docs)
            let Some(precision) = parse_precision(&args, "train") else {
                return ExitCode::FAILURE;
            };
            // --schedule full|uniform:K|revolve:S selects the tape memory
            // strategy; --every K is kept as an alias for uniform:K (0 =
            // full) and is ignored when --schedule is given
            let schedule = args.get_or("schedule", "");
            let strategy = if schedule.is_empty() {
                if every == 0 {
                    TapeStrategy::Full
                } else {
                    match TapeStrategy::checkpoint(every) {
                        Ok(s) => s,
                        Err(e) => {
                            eprintln!("pict train: invalid --every {every}: {e}");
                            return ExitCode::FAILURE;
                        }
                    }
                }
            } else {
                match TapeStrategy::parse(&schedule) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("pict train: invalid --schedule {schedule}: {e}");
                        return ExitCode::FAILURE;
                    }
                }
            };
            let params: Vec<f64> = args
                .get_or("params", if kind == "cavity" { "100,400" } else { "0.01,0.03" })
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            if params.is_empty() {
                eprintln!("pict train: --params must be a comma-separated list of numbers");
                return ExitCode::FAILURE;
            }
            // a coarse scenario per parameter (shared mesh across the
            // batch) + its 2x-resolution, half-dt fine counterpart
            let (coarse, fine): (Vec<Box<dyn Scenario>>, Vec<Box<dyn Scenario>>) = match kind
                .as_str()
            {
                "cavity" => params
                    .iter()
                    .map(|&re| {
                        (
                            Box::new(LidDrivenCavity { n, re, ..Default::default() })
                                as Box<dyn Scenario>,
                            Box::new(LidDrivenCavity {
                                n: 2 * n,
                                re,
                                dt: 0.01,
                                ..Default::default()
                            }) as Box<dyn Scenario>,
                        )
                    })
                    .unzip(),
                "taylor-green" => params
                    .iter()
                    .map(|&nu| {
                        (
                            Box::new(TaylorGreen { n, nu, ..Default::default() })
                                as Box<dyn Scenario>,
                            Box::new(TaylorGreen { n: 2 * n, nu, dt: 0.005 })
                                as Box<dyn Scenario>,
                        )
                    })
                    .unzip(),
                other => {
                    eprintln!("pict train: unsupported --kind {other} (cavity | taylor-green)");
                    return ExitCode::FAILURE;
                }
            };
            let labels: Vec<String> = coarse.iter().map(|s| s.label()).collect();

            if args.flag("probe") {
                // gradient probe: record + backward across the batch, no
                // network — reports per-scenario and batch-reduced grads
                let steps = args.usize_or("probe-steps", 16).max(1);
                let runner = BatchRunner::new(steps).with_threads(threads);
                println!(
                    "probing {} scenarios x {steps} steps ({}) on {} workers...",
                    coarse.len(),
                    strategy.label(),
                    runner.threads()
                );
                let loss = TerminalKineticEnergy { final_step: steps - 1 };
                let results =
                    runner.run_gradients(&coarse, strategy, GradientPaths::FULL, &loss);
                let rows: Vec<Vec<String>> = results
                    .iter()
                    .map(|r| {
                        let g0: f64 = r
                            .grads
                            .du0
                            .comp
                            .iter()
                            .map(|c| c.iter().map(|v| v * v).sum::<f64>())
                            .sum::<f64>()
                            .sqrt();
                        vec![
                            r.label.clone(),
                            format!("{:.3e}", r.loss),
                            format!("{g0:.3e}"),
                            format!("{:.3e}", r.grads.dnu),
                            format!("{}", r.peak_resident_f64),
                            format!("{:.2}s", r.wall_s),
                        ]
                    })
                    .collect();
                print_table(
                    "gradient batch",
                    &["scenario", "loss", "|dL/du0|", "dL/dnu", "peak f64", "wall"],
                    &rows,
                );
                let shared = reduce_shared(&results);
                println!("batch-reduced: dnu = {:.4e}", shared.dnu);
                return ExitCode::SUCCESS;
            }

            let cfg = Corrector2dCfg {
                t_ratio: 2,
                n_frames: args.usize_or("frames", 20),
                fine_warmup: args.usize_or("warmup", 10),
                curriculum: vec![unroll],
                opt_steps_per_stage: args.usize_or("iters", 10),
                lr: args.f64_or("lr", 2e-3),
                paths: GradientPaths::NONE,
                lambda_div: 1e-3,
                output_scale: 0.05,
                strategy,
                seed: 0x7121A,
            };
            let runner = BatchRunner::new(0).with_threads(threads).with_precision(precision);
            println!(
                "training one corrector across {} scenarios ({}), unroll {unroll}, tape {} on {} workers",
                labels.len(),
                labels.join(" | "),
                strategy.label(),
                runner.threads()
            );
            println!("generating {} reference frames per scenario...", cfg.n_frames);
            // one coarse mesh per scenario: mixed-mesh batches resample
            // each fine flow onto its own training grid
            let coarse_meshes: Vec<pict::mesh::Mesh> =
                coarse.iter().map(|s| s.build().solver.mesh).collect();
            let frames = scenario_reference_frames(&runner, &fine, &coarse_meshes, &cfg);
            println!("batched training ({} optimizer steps)...", cfg.opt_steps_per_stage);
            let result = train_corrector_batch(&runner, &coarse, &frames, &cfg);
            // an empty loss history means no optimizer step ran (e.g. zero
            // frames or zero iters) — that is an error, not a NaN row
            if result.losses.is_empty() {
                eprintln!(
                    "pict train: no steps run (check --frames/--warmup/--iters); nothing to report"
                );
                return ExitCode::FAILURE;
            }
            let first = result.losses[0];
            let last = result.losses[result.losses.len() - 1];
            println!(
                "batch-mean episode loss {first:.4e} -> {last:.4e} over {} steps ({} params)",
                result.losses.len(),
                result.net.nparams()
            );
            ExitCode::SUCCESS
        }
        Some("cavity") => {
            use pict::coordinator::references::GHIA_RE100_U;
            use pict::coordinator::scenario::{LidDrivenCavity, Scenario};
            use pict::mesh::field;
            let n = args.usize_or("n", 32);
            // build through the scenario registry: it owns the ExecCtx so
            // the CLI never forks its own pool topology
            let run = LidDrivenCavity {
                n,
                re: args.f64_or("re", 100.0),
                refined: args.flag("refined"),
                ..Default::default()
            }
            .build();
            let mut solver = run.solver;
            let mut state = run.state;
            solver.run(&mut state, &run.source, args.usize_or("steps", 1200));
            let mut worst = 0.0f64;
            for (y, u_ref) in GHIA_RE100_U {
                let u = field::sample_idw(&solver.mesh, &state.u.comp[0], [0.5, y, 0.5]);
                worst = worst.max((u - u_ref).abs());
            }
            println!("cavity {n}x{n}: worst centerline error vs Ghia = {worst:.4}");
            ExitCode::SUCCESS
        }
        Some("sweep") => {
            use pict::coordinator::sweep::{self, ShardOutcome, ShardStatus, SweepSpec};
            let action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("run");
            let kind = args.get_or("kind", "cavity");
            let n = args.usize_or("n", 12);
            let steps = args.usize_or("steps", 5);
            let shards = args.usize_or("shards", 2);
            let threads = args.usize_or("threads", pict::par::env_threads());
            let grad = args.flag("grad");
            let dir_s = args.get_or("dir", "reports/sweep");
            let dir = std::path::Path::new(&dir_s);
            let params: Vec<f64> = args
                .get_or(
                    "params",
                    if kind == "cavity" { "50,100,200,400" } else { "0.01,0.02,0.03,0.05" },
                )
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            if params.is_empty() {
                eprintln!("pict sweep: --params must be a comma-separated list of numbers");
                return ExitCode::FAILURE;
            }
            let scenarios = match sweep::grid_for_kind(&kind, n, &params) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("pict sweep: {e}");
                    return ExitCode::FAILURE;
                }
            };
            let spec = SweepSpec { scenarios, steps, shards, threads, grad };
            match action {
                "run" => {
                    // --shard i runs exactly one shard (the N-invocations
                    // mode); omitted, all shards run work-stealing here
                    let only = match args.get("shard") {
                        None => None,
                        Some(s) => match s.parse::<usize>() {
                            Ok(v) => Some(v),
                            Err(_) => {
                                eprintln!("pict sweep: --shard must be a shard index");
                                return ExitCode::FAILURE;
                            }
                        },
                    };
                    println!(
                        "sweep: {} scenarios over {} shards x {} steps ({} mode) on {} workers -> {}",
                        spec.scenarios.len(),
                        spec.shard_ranges().len(),
                        spec.steps,
                        if grad { "gradient" } else { "forward" },
                        spec.threads,
                        dir.display()
                    );
                    match sweep::run_shards(&spec, dir, only) {
                        Ok(reports) => {
                            for r in &reports {
                                match &r.outcome {
                                    ShardOutcome::Skipped => {
                                        println!("shard {:04}: skipped (valid artifact)", r.shard)
                                    }
                                    ShardOutcome::Computed { failures } => println!(
                                        "shard {:04}: computed ({failures} failed slots)",
                                        r.shard
                                    ),
                                }
                            }
                            ExitCode::SUCCESS
                        }
                        Err(e) => {
                            eprintln!("pict sweep run: {e}");
                            ExitCode::FAILURE
                        }
                    }
                }
                "merge" => {
                    let out = args.get_or("out", "reports/sweep-merged.json");
                    match sweep::merge(&spec, dir) {
                        Ok(merged) => {
                            if let Err(e) =
                                sweep::write_merged(&spec, &merged, std::path::Path::new(&out))
                            {
                                eprintln!("pict sweep merge: writing {out}: {e}");
                                return ExitCode::FAILURE;
                            }
                            println!(
                                "merged {} scenarios ({} failed slots) -> {out}",
                                merged.entries.len(),
                                merged.failures
                            );
                            if let Some(shared) = &merged.shared {
                                println!("batch-reduced: dnu = {:.4e}", shared.dnu);
                            }
                            ExitCode::SUCCESS
                        }
                        Err(e) => {
                            eprintln!("pict sweep merge: {e}");
                            ExitCode::FAILURE
                        }
                    }
                }
                "status" => {
                    let statuses = sweep::sweep_status(&spec, dir);
                    let mut valid = 0usize;
                    for (s, st) in &statuses {
                        match st {
                            ShardStatus::Valid => {
                                valid += 1;
                                println!("shard {s:04}: valid");
                            }
                            ShardStatus::Missing => println!("shard {s:04}: missing"),
                            ShardStatus::Invalid(why) => {
                                println!("shard {s:04}: INVALID — {why}")
                            }
                        }
                    }
                    println!("{valid}/{} shards valid under {}", statuses.len(), dir.display());
                    ExitCode::SUCCESS
                }
                other => {
                    eprintln!("pict sweep: unknown action `{other}` (run | merge | status)");
                    ExitCode::FAILURE
                }
            }
        }
        _ => {
            println!("PICT — differentiable multi-block PISO solver (Rust + JAX + Pallas)");
            println!("commands:");
            println!("  gradpaths [--n 10] [--iters 40] [--lr 0.08]   gradient-path ablation (E4)");
            println!("  cavity [--n 32] [--re 100] [--steps 1200]     lid-driven cavity vs Ghia");
            println!("  batch [--steps 10] [--threads N]              run all registered scenarios on one N-worker pool");
            println!("        [--precision mixed]                     f32-storage iterative refinement for the solves");
            println!("  train [--kind cavity] [--params 100,400] [--n 12] [--steps 4]");
            println!("        [--schedule full|uniform:K|revolve:S]   tape memory: eager, every-K checkpoints, or a");
            println!("                                                binomial revolve schedule under S snapshots");
            println!("        [--every K] [--iters 10] [--threads N]  train one corrector across a scenario batch");
            println!("        [--probe [--probe-steps 16]]            record+backward gradient batch only (no network)");
            println!("        [--precision mixed]                     mixed forward frames (adjoint stays f64)");
            println!("  sweep run|merge|status [--kind cavity] [--params 50,100,200,400]");
            println!("        [--n 12] [--steps 5] [--shards 2]       sharded, resumable scenario sweep: one atomic");
            println!("        [--shard i] [--threads N] [--grad]      artifact per shard, valid shards skipped on re-run");
            println!("        [--dir reports/sweep] [--out FILE]      merge folds shards bit-for-bit (states + SharedGrads)");
            println!("  artifacts [--dir artifacts]                   list AOT artifacts (needs --features pjrt)");
            println!("env: PICT_THREADS=<n> sizes the worker pool (default: all cores; read per context, never cached)");
            println!("examples: cargo run --release --example quickstart | train_sgs_tcf | ...");
            println!("benches:  cargo bench  (one per paper table/figure — see DESIGN.md)");
            ExitCode::SUCCESS
        }
    }
}
