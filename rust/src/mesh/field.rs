//! Global fields over a multi-block mesh, plus the sampling / interpolation
//! utilities used for validation profiles and for the coordinate-based
//! downsampling of high-resolution references (paper §5.1).

use super::Mesh;

/// Scalar field: one f64 per global cell.
pub type ScalarField = Vec<f64>;

/// Vector field stored component-major: `comp[c][cell]`.
#[derive(Clone, Debug, PartialEq)]
pub struct VectorField {
    pub comp: [Vec<f64>; 3],
}

impl VectorField {
    pub fn zeros(ncells: usize) -> VectorField {
        VectorField { comp: [vec![0.0; ncells], vec![0.0; ncells], vec![0.0; ncells]] }
    }

    pub fn ncells(&self) -> usize {
        self.comp[0].len()
    }

    #[inline]
    pub fn get(&self, cell: usize) -> [f64; 3] {
        [self.comp[0][cell], self.comp[1][cell], self.comp[2][cell]]
    }

    #[inline]
    pub fn set(&mut self, cell: usize, v: [f64; 3]) {
        for c in 0..3 {
            self.comp[c][cell] = v[c];
        }
    }

    pub fn axpy(&mut self, alpha: f64, other: &VectorField) {
        for c in 0..3 {
            for (a, b) in self.comp[c].iter_mut().zip(&other.comp[c]) {
                *a += alpha * b;
            }
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        for c in 0..3 {
            self.comp[c].iter_mut().for_each(|v| *v *= alpha);
        }
    }

    /// Max |u| per component over all cells.
    pub fn max_abs(&self) -> [f64; 3] {
        let mut m = [0.0f64; 3];
        for c in 0..3 {
            for v in &self.comp[c] {
                m[c] = m[c].max(v.abs());
            }
        }
        m
    }

    /// Flatten to `[comp0..., comp1..., comp2...]` (adjoint/tape interface).
    pub fn flatten(&self, dim: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(dim * self.ncells());
        for c in 0..dim {
            out.extend_from_slice(&self.comp[c]);
        }
        out
    }

    pub fn from_flat(dim: usize, ncells: usize, flat: &[f64]) -> VectorField {
        let mut f = VectorField::zeros(ncells);
        for c in 0..dim {
            f.comp[c].copy_from_slice(&flat[c * ncells..(c + 1) * ncells]);
        }
        f
    }
}

/// Nearest-cell sample of a scalar field at physical point `p`.
pub fn sample_nearest(mesh: &Mesh, field: &[f64], p: [f64; 3]) -> f64 {
    field[nearest_cell(mesh, p)]
}

/// Global id of the cell whose center is nearest to `p`.
pub fn nearest_cell(mesh: &Mesh, p: [f64; 3]) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for b in &mesh.blocks {
        for (l, c) in b.centers.iter().enumerate() {
            let d = (c[0] - p[0]).powi(2) + (c[1] - p[1]).powi(2) + (c[2] - p[2]).powi(2);
            if d < best_d {
                best_d = d;
                best = b.offset + l;
            }
        }
    }
    best
}

/// Inverse-distance-weighted interpolation (k=4 nearest cell centers) of a
/// scalar field at `p` — the coordinate-based resampling used to downsample
/// high-resolution reference data onto coarse grids.
pub fn sample_idw(mesh: &Mesh, field: &[f64], p: [f64; 3]) -> f64 {
    let mut best: [(f64, usize); 4] = [(f64::INFINITY, 0); 4];
    for b in &mesh.blocks {
        for (l, c) in b.centers.iter().enumerate() {
            let d = (c[0] - p[0]).powi(2) + (c[1] - p[1]).powi(2) + (c[2] - p[2]).powi(2);
            if d < best[3].0 {
                best[3] = (d, b.offset + l);
                best.sort_by(|a, b| a.0.total_cmp(&b.0));
            }
        }
    }
    if best[0].0 < 1e-24 {
        return field[best[0].1];
    }
    let mut wsum = 0.0;
    let mut acc = 0.0;
    for (d, idx) in best {
        if d.is_finite() {
            let w = 1.0 / d;
            wsum += w;
            acc += w * field[idx];
        }
    }
    acc / wsum
}

/// Resample `src_field` (on `src`) onto every cell center of `dst` — used to
/// build coarse-grid training references from fine simulations.
pub fn resample(src: &Mesh, src_field: &[f64], dst: &Mesh) -> Vec<f64> {
    let mut out = vec![0.0; dst.ncells];
    for b in &dst.blocks {
        for (l, c) in b.centers.iter().enumerate() {
            out[b.offset + l] = sample_idw(src, src_field, *c);
        }
    }
    out
}

/// Extract a profile of `field` along a line: samples at `npts` points from
/// `a` to `b`, returning (arc positions in `[0,1]`, values).
pub fn line_profile(
    mesh: &Mesh,
    field: &[f64],
    a: [f64; 3],
    b: [f64; 3],
    npts: usize,
) -> (Vec<f64>, Vec<f64>) {
    let mut ts = Vec::with_capacity(npts);
    let mut vs = Vec::with_capacity(npts);
    for i in 0..npts {
        let t = (i as f64 + 0.5) / npts as f64;
        let p = [
            a[0] + t * (b[0] - a[0]),
            a[1] + t * (b[1] - a[1]),
            a[2] + t * (b[2] - a[2]),
        ];
        ts.push(t);
        vs.push(sample_idw(mesh, field, p));
    }
    (ts, vs)
}

#[cfg(test)]
mod tests {
    use super::super::gen;
    use super::*;

    #[test]
    fn vector_field_roundtrip_flatten() {
        let mut f = VectorField::zeros(4);
        f.set(1, [1.0, 2.0, 3.0]);
        f.set(3, [-1.0, 0.5, 0.0]);
        let flat = f.flatten(3);
        let g = VectorField::from_flat(3, 4, &flat);
        assert_eq!(f, g);
    }

    #[test]
    fn nearest_sample_picks_right_cell() {
        let m = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let mut field = vec![0.0; m.ncells];
        // cell centers at 0.125 + i*0.25
        let target = m.gid(0, 2, 1, 0);
        field[target] = 7.0;
        assert_eq!(sample_nearest(&m, &field, [0.63, 0.37, 0.5]), 7.0);
    }

    #[test]
    fn idw_is_exact_on_cell_centers() {
        let m = gen::periodic_box2d(5, 5, 1.0, 1.0);
        let field: Vec<f64> = (0..m.ncells).map(|i| i as f64).collect();
        let c = m.blocks[0].centers[7];
        assert!((sample_idw(&m, &field, c) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn resample_of_linear_field_is_accurate() {
        let fine = gen::periodic_box2d(32, 32, 1.0, 1.0);
        let coarse = gen::periodic_box2d(8, 8, 1.0, 1.0);
        let f: Vec<f64> = fine.blocks[0].centers.iter().map(|c| 2.0 * c[0] + c[1]).collect();
        let r = resample(&fine, &f, &coarse);
        for (l, c) in coarse.blocks[0].centers.iter().enumerate() {
            let expect = 2.0 * c[0] + c[1];
            assert!((r[l] - expect).abs() < 0.05, "{} vs {}", r[l], expect);
        }
    }
}
