//! Boundary conditions (paper Appendix A.4).
//!
//! Each block face carries one `FaceBc`. Dirichlet values live in
//! `Mesh::bc_values` so multiple faces can share a set and so the advective
//! outflow update (A.24) can rewrite them between PISO steps. The pressure
//! condition at Dirichlet-velocity faces is the implicit 0-Neumann of the
//! paper; velocity Neumann faces are zero-gradient.

/// Boundary assigned to one face of a block.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum FaceBc {
    /// Conformal connection to `(block, face)` with identity orientation
    /// (logical axes aligned, matching tangential resolution). A block may
    /// connect to itself on the opposite face — that is a periodic boundary.
    Connection { block: usize, face: usize },
    /// Prescribed velocity on the face; `values` indexes `Mesh::bc_values`.
    Dirichlet { values: usize },
    /// Zero-gradient velocity (and implicit zero-Neumann pressure).
    #[default]
    Neumann,
}

/// A set of per-face-cell Dirichlet velocities (+ optional outflow model).
#[derive(Clone, Debug, PartialEq)]
pub struct BcValues {
    /// One velocity per face cell (face-cell indexing per `Block::face_lidx`).
    pub vel: Vec<[f64; 3]>,
    /// If set, the face is a non-reflecting advective outflow: before each
    /// PISO step the values are advected out with characteristic velocity
    /// `u_m` (A.24) and then rescaled for global mass balance.
    pub advective_outflow: Option<[f64; 3]>,
}

impl BcValues {
    /// Constant velocity over `n` face cells (e.g. moving lid, uniform inflow).
    pub fn constant(n: usize, vel: [f64; 3]) -> BcValues {
        BcValues { vel: vec![vel; n], advective_outflow: None }
    }

    /// No-slip wall.
    pub fn no_slip(n: usize) -> BcValues {
        Self::constant(n, [0.0; 3])
    }

    /// Per-cell profile (e.g. parabolic or Gaussian inflow).
    pub fn profile(vel: Vec<[f64; 3]>) -> BcValues {
        BcValues { vel, advective_outflow: None }
    }

    /// Advective outflow initialised to `vel` with characteristic `u_m`.
    pub fn outflow(n: usize, vel: [f64; 3], um: [f64; 3]) -> BcValues {
        BcValues { vel: vec![vel; n], advective_outflow: Some(um) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let w = BcValues::no_slip(4);
        assert_eq!(w.vel.len(), 4);
        assert!(w.vel.iter().all(|v| *v == [0.0; 3]));
        let o = BcValues::outflow(2, [1.0, 0.0, 0.0], [1.0, 0.0, 0.0]);
        assert!(o.advective_outflow.is_some());
    }

    #[test]
    fn default_is_neumann() {
        assert_eq!(FaceBc::default(), FaceBc::Neumann);
    }
}
