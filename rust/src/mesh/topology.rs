//! Global cell connectivity: for every cell and face direction, either
//! the neighboring global cell id (same block or across a conformal block
//! connection, including periodic self-connections) or a boundary reference.
//! Precomputed once per mesh; the FVM assembly and all gradient operations
//! are written against this table.

use super::block::Block;
use super::{face_axis, face_side, opposite};

/// What lies across a given face of a cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NeighRef {
    /// Interior or block-connected neighbor (global cell id).
    Cell(u32),
    /// Dirichlet boundary: (bc_values index, face-cell index on that face).
    Dirichlet { values: u32, face_cell: u32 },
    /// Zero-gradient boundary.
    Neumann,
}

#[derive(Clone, Debug, Default)]
pub struct Topology {
    /// `neigh[cell][face]` for face in 0..6.
    pub neigh: Vec<[NeighRef; 6]>,
}

impl Topology {
    pub fn build(dim: usize, blocks: &[Block]) -> Topology {
        let ncells: usize = blocks.iter().map(|b| b.ncells()).sum();
        let mut neigh = vec![[NeighRef::Neumann; 6]; ncells];

        for (bi, b) in blocks.iter().enumerate() {
            for k in 0..b.shape[2] {
                for j in 0..b.shape[1] {
                    for i in 0..b.shape[0] {
                        let gid = b.offset + b.lidx(i, j, k);
                        let c = [i, j, k];
                        for face in 0..2 * dim {
                            let ax = face_axis(face);
                            let side = face_side(face);
                            let interior = if side == 0 { c[ax] > 0 } else { c[ax] + 1 < b.shape[ax] };
                            if interior {
                                let mut cc = c;
                                cc[ax] = if side == 0 { c[ax] - 1 } else { c[ax] + 1 };
                                neigh[gid][face] =
                                    NeighRef::Cell((b.offset + b.lidx(cc[0], cc[1], cc[2])) as u32);
                            } else {
                                neigh[gid][face] = resolve_boundary(blocks, bi, face, c);
                            }
                        }
                    }
                }
            }
        }
        Topology { neigh }
    }

    /// Neighbor reference of `cell` across `face`.
    #[inline]
    pub fn at(&self, cell: usize, face: usize) -> NeighRef {
        self.neigh[cell][face]
    }

    /// Diagonal neighbor: step across `face_a` then `face_b`. Returns the
    /// global id only if both steps stay on cells (used by the non-orthogonal
    /// deferred correction, which skips boundary-adjacent diagonals as the
    /// paper does "for clarity").
    pub fn diag(&self, cell: usize, face_a: usize, face_b: usize) -> Option<u32> {
        match self.neigh[cell][face_a] {
            NeighRef::Cell(n1) => match self.neigh[n1 as usize][face_b] {
                NeighRef::Cell(n2) => Some(n2),
                _ => None,
            },
            _ => None,
        }
    }
}

/// Resolve what lies across `face` of boundary cell `c` in block `bi`.
fn resolve_boundary(blocks: &[Block], bi: usize, face: usize, c: [usize; 3]) -> NeighRef {
    let b = &blocks[bi];
    match &b.faces[face] {
        super::FaceBc::Connection { block, face: other_face } => {
            let ob = &blocks[*block];
            let oax = face_axis(*other_face);
            let ax = face_axis(face);
            assert_eq!(oax, ax, "connections must join faces on the same axis");
            assert_eq!(*other_face, opposite(face) , "identity-orientation connection joins opposite faces");
            // matching tangential resolution required
            for a in 0..3 {
                if a != ax {
                    assert_eq!(
                        b.shape[a], ob.shape[a],
                        "conformal connection requires matching resolution on axis {a}"
                    );
                }
            }
            let mut cc = c;
            // entering the other block from its `other_face` side
            cc[ax] = if face_side(*other_face) == 0 { 0 } else { ob.shape[ax] - 1 };
            NeighRef::Cell((ob.offset + ob.lidx(cc[0], cc[1], cc[2])) as u32)
        }
        super::FaceBc::Dirichlet { values } => NeighRef::Dirichlet {
            values: *values as u32,
            face_cell: b.face_lidx(face, c) as u32,
        },
        super::FaceBc::Neumann => NeighRef::Neumann,
    }
}

#[cfg(test)]
mod tests {
    use super::super::gen;
    use super::*;

    #[test]
    fn periodic_box_wraps() {
        let m = gen::periodic_box2d(4, 3, 1.0, 1.0);
        // cell (0,0): -x neighbor is (3,0)
        let gid = m.gid(0, 0, 0, 0);
        let wrap = m.gid(0, 3, 0, 0);
        assert_eq!(m.topo.at(gid, super::super::FACE_XN), NeighRef::Cell(wrap as u32));
        // +y of (1,2) wraps to (1,0)
        let gid2 = m.gid(0, 1, 2, 0);
        let wrap2 = m.gid(0, 1, 0, 0);
        assert_eq!(m.topo.at(gid2, super::super::FACE_YP), NeighRef::Cell(wrap2 as u32));
    }

    #[test]
    fn channel_walls_are_dirichlet() {
        let m = gen::channel2d(6, 4, 2.0, 1.0, 1.0, false);
        let bottom = m.gid(0, 2, 0, 0);
        match m.topo.at(bottom, super::super::FACE_YN) {
            NeighRef::Dirichlet { .. } => {}
            other => panic!("expected Dirichlet wall, got {other:?}"),
        }
        // periodic in x
        let left = m.gid(0, 0, 1, 0);
        assert_eq!(
            m.topo.at(left, super::super::FACE_XN),
            NeighRef::Cell(m.gid(0, 5, 1, 0) as u32)
        );
    }

    #[test]
    fn two_block_connection_is_symmetric() {
        let m = gen::two_block_channel2d(4, 4, 3);
        // block 0 right edge connects to block 1 left edge
        let a = m.gid(0, 3, 1, 0);
        let bidx = m.gid(1, 0, 1, 0);
        assert_eq!(m.topo.at(a, super::super::FACE_XP), NeighRef::Cell(bidx as u32));
        assert_eq!(m.topo.at(bidx, super::super::FACE_XN), NeighRef::Cell(a as u32));
    }

    #[test]
    fn diag_neighbor_interior() {
        let m = gen::periodic_box2d(5, 5, 1.0, 1.0);
        let c = m.gid(0, 2, 2, 0);
        let d = m.topo.diag(c, super::super::FACE_XP, super::super::FACE_YP).unwrap();
        assert_eq!(d as usize, m.gid(0, 3, 3, 0));
    }
}
