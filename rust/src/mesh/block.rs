//! A single structured block: vertex coordinates, per-cell transformation
//! metrics (Appendix A.3.2), and the boundary assigned to each of its faces.

use super::boundary::FaceBc;

/// 3×3 matrix type used for T (T\[j\]\[i\] = ∂ξ_j/∂x_i) and α (α\[j\]\[k\]).
pub type Mat3 = [[f64; 3]; 3];

#[derive(Clone, Debug)]
pub struct Block {
    /// Cells per axis; 2D blocks use shape\[2\] == 1.
    pub shape: [usize; 3],
    /// Global cell offset (assigned by `Mesh::new`).
    pub offset: usize,
    /// Vertex coordinates, (shape+1) per axis, x-fastest ordering.
    pub verts: Vec<[f64; 3]>,
    /// Cell-center coordinates.
    pub centers: Vec<[f64; 3]>,
    /// Per-cell Jacobian determinant J = det(∂x/∂ξ) (cell volume).
    pub jac: Vec<f64>,
    /// Per-cell transform `T[j][i] = ∂ξ_j/∂x_i`.
    pub t: Vec<Mat3>,
    /// Per-cell `α[j][k] = J · Σ_i T_ji T_ki`  (A.10).
    pub alpha: Vec<Mat3>,
    /// Boundary of each face (indexed by the FACE_* constants).
    pub faces: [FaceBc; 6],
    /// True if any cell has non-negligible off-diagonal α (non-orthogonal).
    pub non_orthogonal: bool,
}

impl Block {
    pub fn ncells(&self) -> usize {
        self.shape[0] * self.shape[1] * self.shape[2]
    }

    /// Local linear index of cell (i, j, k), x-fastest.
    #[inline]
    pub fn lidx(&self, i: usize, j: usize, k: usize) -> usize {
        i + self.shape[0] * (j + self.shape[1] * k)
    }

    /// Inverse of `lidx`.
    #[inline]
    pub fn coords(&self, l: usize) -> [usize; 3] {
        let i = l % self.shape[0];
        let j = (l / self.shape[0]) % self.shape[1];
        let k = l / (self.shape[0] * self.shape[1]);
        [i, j, k]
    }

    #[inline]
    fn vidx(&self, i: usize, j: usize, k: usize) -> usize {
        let nvx = self.shape[0] + 1;
        let nvy = self.shape[1] + 1;
        i + nvx * (j + nvy * k)
    }

    /// Number of face cells on `face` (product of the two tangential extents).
    pub fn face_ncells(&self, face: usize) -> usize {
        let ax = super::face_axis(face);
        let mut n = 1;
        for a in 0..3 {
            if a != ax {
                n *= self.shape[a];
            }
        }
        n
    }

    /// Linear face-cell index of the cell (i,j,k) on face `face`: tangential
    /// axes in increasing order, lower axis fastest.
    #[inline]
    pub fn face_lidx(&self, face: usize, c: [usize; 3]) -> usize {
        let ax = super::face_axis(face);
        let tang: Vec<usize> = (0..3).filter(|a| *a != ax).collect();
        c[tang[0]] + self.shape[tang[0]] * c[tang[1]]
    }

    /// Build a block from tensor-product 1D coordinate arrays (rectilinear,
    /// hence orthogonal). `zs` of length 2 gives a 2D block of unit depth.
    pub fn from_coords1d(dim: usize, xs: &[f64], ys: &[f64], zs: &[f64]) -> Block {
        let shape = [xs.len() - 1, ys.len() - 1, zs.len() - 1];
        let mut verts = Vec::with_capacity((shape[0] + 1) * (shape[1] + 1) * (shape[2] + 1));
        for z in zs {
            for y in ys {
                for x in xs {
                    verts.push([*x, *y, *z]);
                }
            }
        }
        Block::from_vertices(dim, shape, verts)
    }

    /// Build a block from explicit vertex positions (supports non-orthogonal
    /// / distorted grids). `verts` are x-fastest over (shape+1) per axis.
    pub fn from_vertices(dim: usize, shape: [usize; 3], verts: Vec<[f64; 3]>) -> Block {
        assert_eq!(
            verts.len(),
            (shape[0] + 1) * (shape[1] + 1) * (shape[2] + 1),
            "vertex count mismatch"
        );
        let ncells = shape[0] * shape[1] * shape[2];
        let mut b = Block {
            shape,
            offset: 0,
            verts,
            centers: vec![[0.0; 3]; ncells],
            jac: vec![0.0; ncells],
            t: vec![[[0.0; 3]; 3]; ncells],
            alpha: vec![[[0.0; 3]; 3]; ncells],
            faces: Default::default(),
            non_orthogonal: false,
        };
        b.compute_metrics(dim);
        b
    }

    /// Compute centers, J, T, α per cell from the corner vertices. For each
    /// cell, ∂x/∂ξ_a is the mean difference of the corner positions across
    /// axis a (exact for (bi/tri)linear cells at the centroid).
    fn compute_metrics(&mut self, dim: usize) {
        let shape = self.shape;
        let mut max_offdiag: f64 = 0.0;
        for k in 0..shape[2] {
            for j in 0..shape[1] {
                for i in 0..shape[0] {
                    let l = self.lidx(i, j, k);
                    // gather the 8 corners (4 in 2D with k extent 1 handled
                    // uniformly since shape[2]=1 gives z-thickness from zs)
                    let c = |di: usize, dj: usize, dk: usize| {
                        self.verts[self.vidx(i + di, j + dj, k + dk)]
                    };
                    let corners = [
                        c(0, 0, 0),
                        c(1, 0, 0),
                        c(0, 1, 0),
                        c(1, 1, 0),
                        c(0, 0, 1),
                        c(1, 0, 1),
                        c(0, 1, 1),
                        c(1, 1, 1),
                    ];
                    let mut center = [0.0; 3];
                    for p in &corners {
                        for a in 0..3 {
                            center[a] += p[a] / 8.0;
                        }
                    }
                    self.centers[l] = center;
                    // dx/dξ columns: average of corner differences per axis
                    let mut dxdxi = [[0.0f64; 3]; 3]; // dxdxi[a][i]: ∂x_i/∂ξ_a
                    for i3 in 0..3 {
                        // ξ_0 (x-logical): corners with di=1 minus di=0
                        dxdxi[0][i3] = (corners[1][i3] + corners[3][i3] + corners[5][i3]
                            + corners[7][i3]
                            - corners[0][i3]
                            - corners[2][i3]
                            - corners[4][i3]
                            - corners[6][i3])
                            / 4.0;
                        dxdxi[1][i3] = (corners[2][i3] + corners[3][i3] + corners[6][i3]
                            + corners[7][i3]
                            - corners[0][i3]
                            - corners[1][i3]
                            - corners[4][i3]
                            - corners[5][i3])
                            / 4.0;
                        dxdxi[2][i3] = (corners[4][i3] + corners[5][i3] + corners[6][i3]
                            + corners[7][i3]
                            - corners[0][i3]
                            - corners[1][i3]
                            - corners[2][i3]
                            - corners[3][i3])
                            / 4.0;
                    }
                    // J = det(∂x/∂ξ)  (dxdxi rows are ∂x/∂ξ_a, i.e. the
                    // transpose of the conventional Jacobian — same det)
                    let det = det3(&dxdxi);
                    assert!(det > 0.0, "negative/zero cell volume at cell {l}");
                    self.jac[l] = det;
                    // T = (∂x/∂ξ)⁻¹ : T[j][i] = ∂ξ_j/∂x_i
                    let inv = inv3(&dxdxi, det);
                    self.t[l] = inv;
                    // α_jk = J Σ_i T_ji T_ki
                    let mut alpha = [[0.0; 3]; 3];
                    for jj in 0..3 {
                        for kk in 0..3 {
                            let mut s = 0.0;
                            for ii in 0..3 {
                                s += inv[jj][ii] * inv[kk][ii];
                            }
                            alpha[jj][kk] = det * s;
                        }
                    }
                    self.alpha[l] = alpha;
                    for jj in 0..dim {
                        for kk in 0..dim {
                            if jj != kk {
                                max_offdiag = max_offdiag
                                    .max(alpha[jj][kk].abs() / alpha[jj][jj].abs().max(1e-300));
                            }
                        }
                    }
                }
            }
        }
        self.non_orthogonal = max_offdiag > 1e-10;
    }
}

fn det3(m: &[[f64; 3]; 3]) -> f64 {
    m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
        - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
        + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
}

/// Inverse of m given det, where m rows are ∂x/∂ξ_a. Returns T with
/// T[j][i] = ∂ξ_j/∂x_i, i.e. (mᵀ)⁻¹ transposed appropriately:
/// since m[a][i] = ∂x_i/∂ξ_a, the matrix M with M[i][a] = m[a][i] satisfies
/// M · T̃ = I where T̃[a][i]... we directly compute T = M⁻¹ giving
/// T[j][i] = ∂ξ_j/∂x_i.
fn inv3(m: &[[f64; 3]; 3], det: f64) -> [[f64; 3]; 3] {
    // M[i][a] = m[a][i]; T = M^{-1} => T[a][i] = cof(M)[i][a] / det
    let mm = |i: usize, a: usize| m[a][i];
    let cof = |i: usize, a: usize| {
        let (i1, i2) = ((i + 1) % 3, (i + 2) % 3);
        let (a1, a2) = ((a + 1) % 3, (a + 2) % 3);
        mm(i1, a1) * mm(i2, a2) - mm(i1, a2) * mm(i2, a1)
    };
    let mut t = [[0.0; 3]; 3];
    for a in 0..3 {
        for i in 0..3 {
            // adj(M)[a][i] = cof(M)[i][a]
            t[a][i] = cof(i, a) / det;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_block_metrics() {
        // 4×2 cells over [0,2]×[0,1]: Δx=0.5, Δy=0.5
        let xs: Vec<f64> = (0..=4).map(|i| i as f64 * 0.5).collect();
        let ys: Vec<f64> = (0..=2).map(|i| i as f64 * 0.5).collect();
        let b = Block::from_coords1d(2, &xs, &ys, &[0.0, 1.0]);
        assert_eq!(b.ncells(), 8);
        for l in 0..b.ncells() {
            assert!((b.jac[l] - 0.25).abs() < 1e-12);
            assert!((b.t[l][0][0] - 2.0).abs() < 1e-12); // ∂ξ/∂x = 1/Δx
            assert!((b.t[l][1][1] - 2.0).abs() < 1e-12);
            assert!(b.t[l][0][1].abs() < 1e-12);
            // α_00 = J * T00² = 0.25*4 = 1
            assert!((b.alpha[l][0][0] - 1.0).abs() < 1e-12);
        }
        assert!(!b.non_orthogonal);
    }

    #[test]
    fn graded_block_jacobian_sums_to_volume() {
        let xs = [0.0, 0.1, 0.3, 0.6, 1.0];
        let ys = [0.0, 0.5, 1.0];
        let b = Block::from_coords1d(2, &xs, &ys, &[0.0, 1.0]);
        let vol: f64 = b.jac.iter().sum();
        assert!((vol - 1.0).abs() < 1e-12);
    }

    #[test]
    fn distorted_block_is_flagged_non_orthogonal() {
        // shear the unit square grid
        let n = 4;
        let mut verts = Vec::new();
        for j in 0..=n {
            for i in 0..=n {
                let x = i as f64 / n as f64;
                let y = j as f64 / n as f64;
                verts.push([x + 0.3 * y, y, 0.0]);
            }
        }
        // add z layer
        let mut v3 = verts.clone();
        for v in v3.iter_mut() {
            v[2] = 1.0;
        }
        verts.extend(v3);
        let b = Block::from_vertices(2, [n, n, 1], verts);
        assert!(b.non_orthogonal);
        // volume of sheared square is unchanged
        assert!((b.jac.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn t_is_inverse_of_dxdxi_3d() {
        let xs = [0.0, 0.25, 0.75, 1.0];
        let ys = [0.0, 0.4, 1.0];
        let zs = [0.0, 0.5, 1.0];
        let b = Block::from_coords1d(3, &xs, &ys, &zs);
        // orthogonal: T diag = 1/Δ per axis of each cell
        let l = b.lidx(1, 0, 1);
        assert!((b.t[l][0][0] - 1.0 / 0.5).abs() < 1e-12);
        assert!((b.t[l][1][1] - 1.0 / 0.4).abs() < 1e-12);
        assert!((b.t[l][2][2] - 1.0 / 0.5).abs() < 1e-12);
    }

    #[test]
    fn face_lidx_covers_all_face_cells() {
        let b = Block::from_coords1d(
            3,
            &[0.0, 1.0, 2.0, 3.0],
            &[0.0, 1.0, 2.0],
            &[0.0, 1.0, 2.0],
        );
        // face on y axis: tangential axes x (3 cells) and z (2 cells)
        assert_eq!(b.face_ncells(super::super::FACE_YP), 6);
        let mut seen = vec![false; 6];
        for k in 0..2 {
            for i in 0..3 {
                let f = b.face_lidx(super::super::FACE_YP, [i, 1, k]);
                seen[f] = true;
            }
        }
        assert!(seen.iter().all(|s| *s));
    }
}
