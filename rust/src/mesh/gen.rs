//! Mesh generators for every scenario in the paper: periodic boxes (gradient
//! validation, §4.2), plane channels (Poiseuille B.1, TCF B.6), lid-driven
//! cavities (B.2), the 3×3-blocks-with-hole vortex-street grid (B.4), the
//! 3-block backward-facing step (B.5), and rotationally distorted grids for
//! the non-orthogonal path (B.1/B.2).

use super::block::Block;
use super::boundary::{BcValues, FaceBc};
use super::{Mesh, FACE_XN, FACE_XP, FACE_YN, FACE_YP, FACE_ZN, FACE_ZP};

/// Uniform 1D coordinates: n cells over [0, l].
pub fn uniform_coords(n: usize, lo: f64, l: f64) -> Vec<f64> {
    (0..=n).map(|i| lo + l * i as f64 / n as f64).collect()
}

/// Symmetric two-sided geometric grading over [lo, lo+l]: spacing shrinks by
/// `ratio` per cell toward both ends (ratio > 1 refines toward the walls).
pub fn graded_coords_both(n: usize, lo: f64, l: f64, ratio: f64) -> Vec<f64> {
    assert!(n >= 2);
    let half = n / 2;
    // spacings from wall to center: d, d*r, d*r^2, ...
    let mut sp = Vec::with_capacity(n);
    for i in 0..half {
        sp.push(ratio.powi(i as i32));
    }
    let mut spacings: Vec<f64> = sp.clone();
    if n % 2 == 1 {
        spacings.push(ratio.powi(half as i32));
    }
    spacings.extend(sp.iter().rev());
    let total: f64 = spacings.iter().sum();
    let mut xs = Vec::with_capacity(n + 1);
    let mut acc = 0.0;
    xs.push(lo);
    for s in &spacings {
        acc += s / total * l;
        xs.push(lo + acc);
    }
    *xs.last_mut().expect("coords start with the pushed lo entry") = lo + l; // avoid fp drift
    xs
}

/// One-sided geometric grading: refinement toward `lo` end if `toward_lo`.
pub fn graded_coords_one(n: usize, lo: f64, l: f64, ratio: f64, toward_lo: bool) -> Vec<f64> {
    let mut spacings: Vec<f64> = (0..n).map(|i| ratio.powi(i as i32)).collect();
    if !toward_lo {
        spacings.reverse();
    }
    let total: f64 = spacings.iter().sum();
    let mut xs = vec![lo];
    let mut acc = 0.0;
    for s in &spacings {
        acc += s / total * l;
        xs.push(lo + acc);
    }
    *xs.last_mut().expect("coords start with the pushed lo entry") = lo + l;
    xs
}

fn periodic_self(block: usize) -> impl Fn(usize) -> FaceBc {
    move |face: usize| FaceBc::Connection { block, face: super::opposite(face) }
}

/// Fully periodic 2D box (the §4.2 gradient-validation domain is 18×16).
pub fn periodic_box2d(nx: usize, ny: usize, lx: f64, ly: f64) -> Mesh {
    let mut b = Block::from_coords1d(
        2,
        &uniform_coords(nx, 0.0, lx),
        &uniform_coords(ny, 0.0, ly),
        &[0.0, 1.0],
    );
    let p = periodic_self(0);
    b.faces = [p(FACE_XN), p(FACE_XP), p(FACE_YN), p(FACE_YP), FaceBc::Neumann, FaceBc::Neumann];
    Mesh::new(2, vec![b], vec![])
}

/// Fully periodic 3D box.
pub fn periodic_box3d(n: [usize; 3], l: [f64; 3]) -> Mesh {
    let mut b = Block::from_coords1d(
        3,
        &uniform_coords(n[0], 0.0, l[0]),
        &uniform_coords(n[1], 0.0, l[1]),
        &uniform_coords(n[2], 0.0, l[2]),
    );
    let p = periodic_self(0);
    b.faces = [p(0), p(1), p(2), p(3), p(4), p(5)];
    Mesh::new(3, vec![b], vec![])
}

/// 2D plane channel: periodic in x, no-slip walls at y=0 and y=ly
/// (Poiseuille, B.1). `wall_ratio > 1` grades the mesh toward the walls.
pub fn channel2d(nx: usize, ny: usize, lx: f64, ly: f64, wall_ratio: f64, refined: bool) -> Mesh {
    let ys = if refined {
        graded_coords_both(ny, 0.0, ly, wall_ratio)
    } else {
        uniform_coords(ny, 0.0, ly)
    };
    let mut b = Block::from_coords1d(2, &uniform_coords(nx, 0.0, lx), &ys, &[0.0, 1.0]);
    let p = periodic_self(0);
    let wall = BcValues::no_slip(b.face_ncells(FACE_YN));
    b.faces = [
        p(FACE_XN),
        p(FACE_XP),
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Dirichlet { values: 1 },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    Mesh::new(2, vec![b], vec![wall.clone(), wall])
}

/// Two-block version of `channel2d` split along x (tests block connections).
pub fn two_block_channel2d(nx_half: usize, ny: usize, _unused: usize) -> Mesh {
    let ys = uniform_coords(ny, 0.0, 1.0);
    let mut b0 = Block::from_coords1d(2, &uniform_coords(nx_half, 0.0, 1.0), &ys, &[0.0, 1.0]);
    let mut b1 = Block::from_coords1d(2, &uniform_coords(nx_half, 1.0, 1.0), &ys, &[0.0, 1.0]);
    let wall_n = b0.face_ncells(FACE_YN);
    b0.faces = [
        FaceBc::Connection { block: 1, face: FACE_XP }, // periodic wrap via b1
        FaceBc::Connection { block: 1, face: FACE_XN },
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Dirichlet { values: 1 },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    b1.faces = [
        FaceBc::Connection { block: 0, face: FACE_XP },
        FaceBc::Connection { block: 0, face: FACE_XN },
        FaceBc::Dirichlet { values: 2 },
        FaceBc::Dirichlet { values: 3 },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    let w = BcValues::no_slip(wall_n);
    Mesh::new(2, vec![b0, b1], vec![w.clone(), w.clone(), w.clone(), w])
}

/// 2D lid-driven cavity: closed box, lid at y=ly moving with `lid_vel` in +x.
pub fn cavity2d(n: usize, l: f64, lid_vel: f64, refined: bool) -> Mesh {
    let coords = if refined {
        graded_coords_both(n, 0.0, l, 1.15)
    } else {
        uniform_coords(n, 0.0, l)
    };
    let mut b = Block::from_coords1d(2, &coords, &coords, &[0.0, 1.0]);
    let nface = n;
    b.faces = [
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Dirichlet { values: 1 },
        FaceBc::Dirichlet { values: 2 },
        FaceBc::Dirichlet { values: 3 }, // lid
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    let wall = BcValues::no_slip(nface);
    let lid = BcValues::constant(nface, [lid_vel, 0.0, 0.0]);
    Mesh::new(2, vec![b], vec![wall.clone(), wall.clone(), wall, lid])
}

/// 3D lid-driven cavity (lid at y=+l moving in +x; z closed no-slip).
pub fn cavity3d(n: usize, l: f64, lid_vel: f64, refined: bool) -> Mesh {
    let coords = if refined {
        graded_coords_both(n, 0.0, l, 1.15)
    } else {
        uniform_coords(n, 0.0, l)
    };
    let mut b = Block::from_coords1d(3, &coords, &coords, &coords);
    let nface = n * n;
    b.faces = [
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Dirichlet { values: 1 },
        FaceBc::Dirichlet { values: 2 },
        FaceBc::Dirichlet { values: 3 }, // lid
        FaceBc::Dirichlet { values: 4 },
        FaceBc::Dirichlet { values: 5 },
    ];
    let wall = BcValues::no_slip(nface);
    let lid = BcValues::constant(nface, [lid_vel, 0.0, 0.0]);
    Mesh::new(
        3,
        vec![b],
        vec![wall.clone(), wall.clone(), wall.clone(), lid, wall.clone(), wall],
    )
}

/// 3D plane channel for TCF (B.6): periodic x and z, no-slip walls ±y,
/// exponential wall refinement with the given base (paper uses 1.095).
pub fn channel3d(n: [usize; 3], l: [f64; 3], refine_base: f64) -> Mesh {
    let xs = uniform_coords(n[0], 0.0, l[0]);
    let ys = if refine_base > 1.0 {
        graded_coords_both(n[1], 0.0, l[1], refine_base)
    } else {
        uniform_coords(n[1], 0.0, l[1])
    };
    let zs = uniform_coords(n[2], 0.0, l[2]);
    let mut b = Block::from_coords1d(3, &xs, &ys, &zs);
    let p = periodic_self(0);
    let nface = n[0] * n[2];
    b.faces = [
        p(FACE_XN),
        p(FACE_XP),
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Dirichlet { values: 1 },
        p(FACE_ZN),
        p(FACE_ZP),
    ];
    let wall = BcValues::no_slip(nface);
    Mesh::new(3, vec![b], vec![wall.clone(), wall])
}

/// Rotationally distorted closed 2D box (B.1/B.2 non-orthogonal validation):
/// vertices are rotated around the domain center by an angle that decays
/// with radius, producing a smooth non-orthogonal grid.
pub fn distorted_cavity2d(n: usize, l: f64, lid_vel: f64, max_angle: f64) -> Mesh {
    let coords = uniform_coords(n, 0.0, l);
    let cx = l / 2.0;
    let sigma = l / 3.0;
    let mut verts = Vec::new();
    for z in [0.0, 1.0] {
        for y in &coords {
            for x in &coords {
                let (dx, dy) = (x - cx, y - cx);
                let r2 = dx * dx + dy * dy;
                let theta = max_angle * (-r2 / (sigma * sigma)).exp();
                let (s, c) = theta.sin_cos();
                verts.push([cx + c * dx - s * dy, cx + s * dx + c * dy, z]);
            }
        }
    }
    let mut b = Block::from_vertices(2, [n, n, 1], verts);
    b.faces = [
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Dirichlet { values: 1 },
        FaceBc::Dirichlet { values: 2 },
        FaceBc::Dirichlet { values: 3 },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    let wall = BcValues::no_slip(n);
    let lid = BcValues::constant(n, [lid_vel, 0.0, 0.0]);
    Mesh::new(2, vec![b], vec![wall.clone(), wall.clone(), wall, lid])
}

/// Parameters for the 2D vortex-street grid (B.4).
pub struct VortexStreetCfg {
    /// Domain length and height (paper: 16 × 8 m).
    pub lx: f64,
    pub ly: f64,
    /// Obstacle leading-edge x and width (paper: 3, 1.5).
    pub obs_x: f64,
    pub obs_w: f64,
    /// Obstacle height y_s, vertically centered.
    pub obs_h: f64,
    /// Cells per x-band (upstream / obstacle / downstream) and
    /// y-band (below / obstacle / above).
    pub nx: [usize; 3],
    pub ny: [usize; 3],
    /// Inflow peak velocity and Gaussian width.
    pub u_in: f64,
    pub sigma: f64,
}

impl Default for VortexStreetCfg {
    fn default() -> Self {
        VortexStreetCfg {
            lx: 16.0,
            ly: 8.0,
            obs_x: 3.0,
            obs_w: 1.5,
            obs_h: 1.0,
            nx: [12, 6, 30],
            ny: [14, 6, 14],
            u_in: 1.0,
            sigma: 0.4,
        }
    }
}

/// 3×3 multi-block grid with the center block removed (the square obstacle).
/// Block layout (bi = col + 3*row internally, hole skipped):
/// ```text
///   row 2 (top):    B5 B6 B7
///   row 1 (mid):    B3 ## B4      (## = obstacle)
///   row 0 (bottom): B0 B1 B2
/// ```
/// Inlet: Gaussian profile at x=0; outlet: advective outflow at x=lx;
/// top/bottom and obstacle faces: no-slip walls.
pub fn vortex_street(cfg: &VortexStreetCfg) -> Mesh {
    let xb = [0.0, cfg.obs_x, cfg.obs_x + cfg.obs_w, cfg.lx];
    let y0 = (cfg.ly - cfg.obs_h) / 2.0;
    let y1 = (cfg.ly + cfg.obs_h) / 2.0;
    let yb = [0.0, y0, y1, cfg.ly];
    // coordinates per band; mild grading toward the obstacle in outer bands
    let xs: Vec<Vec<f64>> = vec![
        graded_coords_one(cfg.nx[0], xb[0], xb[1] - xb[0], 1.06, false),
        uniform_coords(cfg.nx[1], xb[1], xb[2] - xb[1]),
        graded_coords_one(cfg.nx[2], xb[2], xb[3] - xb[2], 1.04, true),
    ];
    let ys: Vec<Vec<f64>> = vec![
        graded_coords_one(cfg.ny[0], yb[0], yb[1] - yb[0], 1.05, false),
        uniform_coords(cfg.ny[1], yb[1], yb[2] - yb[1]),
        graded_coords_one(cfg.ny[2], yb[2], yb[3] - yb[2], 1.05, true),
    ];
    // map (col,row) -> block index (hole at (1,1))
    let id = |col: usize, row: usize| -> Option<usize> {
        match (col, row) {
            (1, 1) => None,
            (c, 0) => Some(c),              // 0,1,2
            (0, 1) => Some(3),
            (2, 1) => Some(4),
            (c, 2) => Some(5 + c),          // 5,6,7
            _ => unreachable!(),
        }
    };
    let mut blocks = Vec::new();
    let mut bc_values: Vec<BcValues> = Vec::new();
    let mut coords_of = Vec::new(); // (col,row) of each block
    for row in 0..3 {
        for col in 0..3 {
            if id(col, row).is_none() {
                continue;
            }
            blocks.push(Block::from_coords1d(2, &xs[col], &ys[row], &[0.0, 1.0]));
            coords_of.push((col, row));
        }
    }
    // assign faces
    for (bi, (col, row)) in coords_of.clone().into_iter().enumerate() {
        let b = &blocks[bi];
        let mut faces: [FaceBc; 6] = Default::default();
        // -x
        faces[FACE_XN] = if col == 0 {
            // inlet: Gaussian profile u(y) centered at domain mid-height
            let mut vel = Vec::new();
            for j in 0..b.shape[1] {
                let yc = 0.5 * (ys[row][j] + ys[row][j + 1]) - cfg.ly / 2.0;
                let u = cfg.u_in
                    * (1.0 / (2.0 * std::f64::consts::PI * cfg.sigma * cfg.sigma).sqrt())
                    * (-yc * yc / (2.0 * cfg.sigma * cfg.sigma)).exp();
                vel.push([u, 0.0, 0.0]);
            }
            bc_values.push(BcValues::profile(vel));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        } else if let Some(nb) = id(col - 1, row) {
            FaceBc::Connection { block: nb, face: FACE_XP }
        } else {
            // obstacle right wall (col=2, row=1 looking left at hole)
            bc_values.push(BcValues::no_slip(b.shape[1]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        };
        // +x
        faces[FACE_XP] = if col == 2 {
            bc_values.push(BcValues::outflow(b.shape[1], [cfg.u_in * 0.4, 0.0, 0.0], [cfg.u_in * 0.4, 0.0, 0.0]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        } else if let Some(nb) = id(col + 1, row) {
            FaceBc::Connection { block: nb, face: FACE_XN }
        } else {
            bc_values.push(BcValues::no_slip(b.shape[1]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        };
        // -y
        faces[FACE_YN] = if row == 0 {
            bc_values.push(BcValues::no_slip(b.shape[0]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        } else if let Some(nb) = id(col, row - 1) {
            FaceBc::Connection { block: nb, face: FACE_YP }
        } else {
            bc_values.push(BcValues::no_slip(b.shape[0]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        };
        // +y
        faces[FACE_YP] = if row == 2 {
            bc_values.push(BcValues::no_slip(b.shape[0]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        } else if let Some(nb) = id(col, row + 1) {
            FaceBc::Connection { block: nb, face: FACE_YN }
        } else {
            bc_values.push(BcValues::no_slip(b.shape[0]));
            FaceBc::Dirichlet { values: bc_values.len() - 1 }
        };
        blocks[bi].faces = faces;
    }
    Mesh::new(2, blocks, bc_values)
}

/// Parameters for the 2D backward-facing step (B.5).
pub struct BfsCfg {
    /// Gap between step and top wall (paper: h = 1).
    pub h: f64,
    /// Step height s (expansion ratio ER = (h+s)/h).
    pub s: f64,
    /// Inlet length (paper: 5h) and downstream length (paper: 35h).
    pub l_in: f64,
    pub l_down: f64,
    /// Cells: inlet x, downstream x, upper y (gap), lower y (step).
    pub nx_in: usize,
    pub nx_down: usize,
    pub ny_up: usize,
    pub ny_low: usize,
    /// Bulk velocity of the parabolic inlet profile.
    pub u_bulk: f64,
}

impl Default for BfsCfg {
    fn default() -> Self {
        BfsCfg {
            h: 1.0,
            s: 0.875,
            l_in: 5.0,
            l_down: 35.0,
            nx_in: 10,
            nx_down: 64,
            ny_up: 12,
            ny_low: 10,
            u_bulk: 1.0,
        }
    }
}

/// 3-block BFS mesh:
/// B0 = inlet channel (above the step), B1 = downstream upper, B2 = downstream lower.
/// Inlet: parabolic Dirichlet; outlet: advective outflow; all other faces no-slip.
pub fn bfs(cfg: &BfsCfg) -> Mesh {
    let y_step = cfg.s;
    let xs_in = graded_coords_one(cfg.nx_in, -cfg.l_in, cfg.l_in, 1.08, false);
    let xs_down = graded_coords_one(cfg.nx_down, 0.0, cfg.l_down, 1.035, true);
    let ys_up = graded_coords_both(cfg.ny_up, y_step, cfg.h, 1.08);
    let ys_low = graded_coords_both(cfg.ny_low, 0.0, cfg.s, 1.08);

    let mut b0 = Block::from_coords1d(2, &xs_in, &ys_up, &[0.0, 1.0]);
    let mut b1 = Block::from_coords1d(2, &xs_down, &ys_up, &[0.0, 1.0]);
    let mut b2 = Block::from_coords1d(2, &xs_down, &ys_low, &[0.0, 1.0]);

    // inlet parabolic profile U = 6 U_b (y'/h)(1 - y'/h), y' measured from step top
    let mut inlet = Vec::new();
    for j in 0..cfg.ny_up {
        let yc = 0.5 * (ys_up[j] + ys_up[j + 1]) - y_step;
        let eta = yc / cfg.h;
        inlet.push([6.0 * cfg.u_bulk * eta * (1.0 - eta), 0.0, 0.0]);
    }
    let mut bc_values = vec![
        BcValues::profile(inlet),                  // 0 inlet
        BcValues::no_slip(cfg.nx_in),              // 1 b0 bottom (step top)
        BcValues::no_slip(cfg.nx_in),              // 2 b0 top
        BcValues::no_slip(cfg.nx_down),            // 3 b1 top
        BcValues::no_slip(cfg.nx_down),            // 4 b2 bottom
        BcValues::no_slip(cfg.ny_low),             // 5 b2 step wall (-x)
    ];
    let out_vel = [cfg.u_bulk, 0.0, 0.0];
    bc_values.push(BcValues::outflow(cfg.ny_up, out_vel, out_vel)); // 6 b1 outlet
    bc_values.push(BcValues::outflow(cfg.ny_low, out_vel, out_vel)); // 7 b2 outlet

    b0.faces = [
        FaceBc::Dirichlet { values: 0 },
        FaceBc::Connection { block: 1, face: FACE_XN },
        FaceBc::Dirichlet { values: 1 },
        FaceBc::Dirichlet { values: 2 },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    b1.faces = [
        FaceBc::Connection { block: 0, face: FACE_XP },
        FaceBc::Dirichlet { values: 6 },
        FaceBc::Connection { block: 2, face: FACE_YP },
        FaceBc::Dirichlet { values: 3 },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    b2.faces = [
        FaceBc::Dirichlet { values: 5 },
        FaceBc::Dirichlet { values: 7 },
        FaceBc::Dirichlet { values: 4 },
        FaceBc::Connection { block: 1, face: FACE_YN },
        FaceBc::Neumann,
        FaceBc::Neumann,
    ];
    Mesh::new(2, vec![b0, b1, b2], bc_values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graded_coords_cover_interval() {
        let xs = graded_coords_both(9, 0.0, 2.0, 1.2);
        assert_eq!(xs.len(), 10);
        assert_eq!(xs[0], 0.0);
        assert_eq!(xs[9], 2.0);
        // spacing near wall smaller than center
        let d0 = xs[1] - xs[0];
        let dc = xs[5] - xs[4];
        assert!(d0 < dc);
        // monotone
        for w in xs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn graded_one_sided_direction() {
        let a = graded_coords_one(8, 0.0, 1.0, 1.3, true);
        assert!(a[1] - a[0] < a[8] - a[7]);
        let b = graded_coords_one(8, 0.0, 1.0, 1.3, false);
        assert!(b[1] - b[0] > b[8] - b[7]);
    }

    #[test]
    fn vortex_street_mesh_is_consistent() {
        let m = vortex_street(&VortexStreetCfg {
            nx: [4, 3, 6],
            ny: [4, 3, 4],
            ..Default::default()
        });
        assert_eq!(m.blocks.len(), 8);
        // every cell's faces resolve without panic; volume > 0
        assert!(m.total_volume() > 0.0);
        // hole: total volume = domain minus obstacle
        let cfg = VortexStreetCfg::default();
        let expect = cfg.lx * cfg.ly - cfg.obs_w * cfg.obs_h;
        assert!((m.total_volume() - expect).abs() < 1e-9, "{}", m.total_volume());
    }

    #[test]
    fn bfs_mesh_volume() {
        let cfg = BfsCfg::default();
        let m = bfs(&cfg);
        let expect = cfg.l_in * cfg.h + cfg.l_down * (cfg.h + cfg.s);
        assert!((m.total_volume() - expect).abs() < 1e-9);
        assert_eq!(m.blocks.len(), 3);
    }

    #[test]
    fn bfs_connection_symmetry() {
        let m = bfs(&BfsCfg { nx_in: 4, nx_down: 8, ny_up: 6, ny_low: 4, ..Default::default() });
        // b1 bottom row connects to b2 top row
        let up = m.gid(1, 3, 0, 0);
        let lo = m.gid(2, 3, 3, 0);
        assert_eq!(m.topo.at(up, FACE_YN), super::super::NeighRef::Cell(lo as u32));
        assert_eq!(m.topo.at(lo, FACE_YP), super::super::NeighRef::Cell(up as u32));
    }

    #[test]
    fn distorted_cavity_is_non_orthogonal_but_valid() {
        let m = distorted_cavity2d(8, 1.0, 1.0, 0.25);
        assert!(m.blocks[0].non_orthogonal);
        assert!((m.total_volume() - 1.0).abs() < 0.05);
        for j in &m.blocks[0].jac {
            assert!(*j > 0.0);
        }
    }

    #[test]
    fn cavity3d_shape() {
        let m = cavity3d(6, 1.0, 1.0, true);
        assert_eq!(m.ncells, 216);
        assert_eq!(m.bc_values.len(), 6);
    }
}
