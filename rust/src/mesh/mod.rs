//! Multi-block structured mesh substrate (paper §2.2, Appendix A.3.2).
//!
//! The domain is split into blocks; each block is a regular grid of
//! quadrilateral (2D) / hexahedral (3D) cells whose vertices may be graded
//! and distorted. Precomputed per-cell transformation metrics `T`, `J`, `α`
//! relate computational space ξ to physical space x. Each block face carries
//! exactly one boundary: a conformal connection to another block face
//! (matching resolution, identity orientation), a prescribed Dirichlet
//! velocity (optionally updated as a non-reflecting advective outflow,
//! A.24), or zero-gradient Neumann.

pub mod block;
pub mod boundary;
pub mod field;
pub mod gen;
pub mod topology;

pub use block::Block;
pub use boundary::{BcValues, FaceBc};
pub use field::{ScalarField, VectorField};
pub use topology::{NeighRef, Topology};

/// Face identifiers: 2*axis + side (side 0 = negative/low, 1 = positive/high).
pub const FACE_XN: usize = 0;
pub const FACE_XP: usize = 1;
pub const FACE_YN: usize = 2;
pub const FACE_YP: usize = 3;
pub const FACE_ZN: usize = 4;
pub const FACE_ZP: usize = 5;

#[inline]
pub fn face_axis(face: usize) -> usize {
    face / 2
}

#[inline]
pub fn face_side(face: usize) -> usize {
    face % 2
}

/// Opposite face on the same axis.
#[inline]
pub fn opposite(face: usize) -> usize {
    face ^ 1
}

/// Sign N_f of the logical face direction: +1 for high faces, −1 for low.
#[inline]
pub fn face_sign(face: usize) -> f64 {
    if face % 2 == 1 {
        1.0
    } else {
        -1.0
    }
}

/// A multi-block mesh with global cell numbering across blocks.
#[derive(Clone, Debug)]
pub struct Mesh {
    pub dim: usize,
    pub blocks: Vec<Block>,
    /// Dirichlet boundary value sets, indexed by `FaceBc::Dirichlet.values`.
    pub bc_values: Vec<BcValues>,
    pub ncells: usize,
    pub topo: Topology,
    /// Denormalized per-global-cell metrics (assembly-friendly views of the
    /// per-block data): Jacobian, transform T, α, and cell centers.
    pub jac: Vec<f64>,
    pub t: Vec<block::Mat3>,
    pub alpha: Vec<block::Mat3>,
    pub centers: Vec<[f64; 3]>,
    /// True if any block is non-orthogonal (enables deferred corrections).
    pub non_orthogonal: bool,
}

impl Mesh {
    /// Assemble a mesh from blocks (which must already carry their face BCs)
    /// and Dirichlet value sets; computes global offsets and the topology.
    pub fn new(dim: usize, mut blocks: Vec<Block>, bc_values: Vec<BcValues>) -> Mesh {
        let mut offset = 0;
        for b in blocks.iter_mut() {
            b.offset = offset;
            offset += b.ncells();
        }
        let topo = Topology::build(dim, &blocks);
        let mut jac = Vec::with_capacity(offset);
        let mut t = Vec::with_capacity(offset);
        let mut alpha = Vec::with_capacity(offset);
        let mut centers = Vec::with_capacity(offset);
        let mut non_orthogonal = false;
        for b in &blocks {
            jac.extend_from_slice(&b.jac);
            t.extend_from_slice(&b.t);
            alpha.extend_from_slice(&b.alpha);
            centers.extend_from_slice(&b.centers);
            non_orthogonal |= b.non_orthogonal;
        }
        Mesh { dim, blocks, bc_values, ncells: offset, topo, jac, t, alpha, centers, non_orthogonal }
    }

    /// Locate the (block, local linear index) of a global cell id.
    pub fn locate(&self, gid: usize) -> (usize, usize) {
        for (bi, b) in self.blocks.iter().enumerate() {
            if gid >= b.offset && gid < b.offset + b.ncells() {
                return (bi, gid - b.offset);
            }
        }
        panic!("cell id {gid} out of range");
    }

    /// Total physical volume (sum of J over all cells).
    pub fn total_volume(&self) -> f64 {
        self.blocks.iter().map(|b| b.jac.iter().sum::<f64>()).sum()
    }

    /// Smallest cell extent in each physical direction (for CFL limits):
    /// estimated as 1/max(|T_ji|) per axis.
    pub fn min_spacing(&self) -> f64 {
        let mut max_t: f64 = 0.0;
        for b in &self.blocks {
            for t in &b.t {
                for row in t.iter().take(self.dim) {
                    for v in row.iter().take(self.dim) {
                        max_t = max_t.max(v.abs());
                    }
                }
            }
        }
        1.0 / max_t.max(1e-300)
    }

    /// Global cell id for block `bi`, local coords (i, j, k).
    #[inline]
    pub fn gid(&self, bi: usize, i: usize, j: usize, k: usize) -> usize {
        let b = &self.blocks[bi];
        b.offset + b.lidx(i, j, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn face_helpers() {
        assert_eq!(face_axis(FACE_YP), 1);
        assert_eq!(face_side(FACE_YP), 1);
        assert_eq!(opposite(FACE_XN), FACE_XP);
        assert_eq!(face_sign(FACE_ZN), -1.0);
        assert_eq!(face_sign(FACE_ZP), 1.0);
    }

    #[test]
    fn mesh_offsets_and_locate() {
        let m = gen::channel2d(8, 4, 2.0, 1.0, 1.0, false);
        assert_eq!(m.ncells, 32);
        let (bi, li) = m.locate(10);
        assert_eq!(bi, 0);
        assert_eq!(li, 10);
    }

    #[test]
    fn total_volume_of_unit_box() {
        let m = gen::periodic_box2d(16, 8, 2.0, 1.0);
        assert!((m.total_volume() - 2.0).abs() < 1e-12);
    }
}
