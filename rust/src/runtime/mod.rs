//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client, and
//! executes them from the Rust hot path. Python is never on the request
//! path — the compiled executables are self-contained.
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact argument.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Manifest entry for one compiled program.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub entry: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("specs must be an array"))?;
    arr.iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("spec missing name"))?
                    .to_string(),
                shape: e
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("spec missing shape"))?
                    .iter()
                    .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                    .collect(),
                dtype: e
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f64 inputs (each a flat row-major buffer matching the
    /// manifest spec). Returns flat f64 buffers per output.
    pub fn run_f64(&self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(
            inputs.len() == self.meta.inputs.len(),
            "expected {} inputs, got {}",
            self.meta.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.meta.inputs) {
            anyhow::ensure!(
                buf.len() == spec.numel(),
                "input {} expects {} elements, got {}",
                spec.name,
                spec.numel(),
                buf.len()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
            let lit = match spec.dtype.as_str() {
                "f64" => xla::Literal::vec1(buf).reshape(&dims)?,
                "f32" => {
                    let v32: Vec<f32> = buf.iter().map(|x| *x as f32).collect();
                    xla::Literal::vec1(&v32).reshape(&dims)?
                }
                other => anyhow::bail!("unsupported dtype {other}"),
            };
            literals.push(lit);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let tuple = result.decompose_tuple()?;
        let mut outs = Vec::with_capacity(tuple.len());
        for (lit, spec) in tuple.into_iter().zip(&self.meta.outputs) {
            let buf: Vec<f64> = match spec.dtype.as_str() {
                "f64" => lit.to_vec::<f64>()?,
                "f32" => lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect(),
                other => anyhow::bail!("unsupported dtype {other}"),
            };
            outs.push(buf);
        }
        Ok(outs)
    }
}

/// The set of artifacts listed in `artifacts/manifest.json`, compiled
/// lazily on first use and cached.
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub metas: Vec<ArtifactMeta>,
    client: xla::PjRtClient,
    compiled: BTreeMap<String, Executable>,
}

impl ArtifactSet {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let metas = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|e| {
                Ok(ArtifactMeta {
                    entry: e
                        .get("entry")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("artifact missing entry"))?
                        .to_string(),
                    file: e
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    inputs: parse_specs(e.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                    outputs: parse_specs(
                        e.get("outputs").ok_or_else(|| anyhow!("no outputs"))?,
                    )?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactSet { dir, metas, client, compiled: BTreeMap::new() })
    }

    pub fn entries(&self) -> Vec<String> {
        self.metas.iter().map(|m| m.entry.clone()).collect()
    }

    /// Compile (once) and return the executable for `entry`.
    pub fn get(&mut self, entry: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(entry) {
            let meta = self
                .metas
                .iter()
                .find(|m| m.entry == entry)
                .ok_or_else(|| anyhow!("unknown artifact entry `{entry}`"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled.insert(entry.to_string(), Executable { meta, exe });
        }
        Ok(self.compiled.get(entry).expect("inserted above when absent"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("manifest.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn manifest_loads_and_lists_entries() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let set = ArtifactSet::load(dir).unwrap();
        let entries = set.entries();
        assert!(entries.iter().any(|e| e == "piso_step2d"), "{entries:?}");
        assert!(entries.iter().any(|e| e == "stencil_matvec2d"));
        assert!(entries.iter().any(|e| e == "cnn_corrector2d"));
    }

    #[test]
    fn stencil_artifact_executes_identity() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut set = ArtifactSet::load(dir).unwrap();
        let exe = set.get("stencil_matvec2d").unwrap();
        let (ny, nx) = (16usize, 18usize);
        // identity stencil: cc = 1, rest 0; padded x
        let mut x_pad = vec![0.0f64; (ny + 2) * (nx + 2)];
        for j in 0..ny + 2 {
            for i in 0..nx + 2 {
                x_pad[j * (nx + 2) + i] = (j * 100 + i) as f64;
            }
        }
        let cc = vec![1.0; ny * nx];
        let z = vec![0.0; ny * nx];
        let out = exe
            .run_f64(&[x_pad.clone(), cc, z.clone(), z.clone(), z.clone(), z])
            .unwrap();
        assert_eq!(out.len(), 1);
        for j in 0..ny {
            for i in 0..nx {
                let want = x_pad[(j + 1) * (nx + 2) + (i + 1)];
                assert_eq!(out[0][j * nx + i], want);
            }
        }
    }
}
