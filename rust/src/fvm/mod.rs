//! Finite-volume discretization of the PISO operators (paper Appendix A.3).
//!
//! Conventions (fixed here, used identically by the adjoint module):
//! - Momentum rows are scaled by 1/J_P, so `C = 1/Δt · I + (adv + diff)/J`
//!   and the RHS is `u^n/Δt + (boundary fluxes)/J + S − ∇p` (A.13). The same
//!   scalar matrix C advects/diffuses every velocity component.
//! - The pressure system is assembled in *negated* volume form
//!   `M = −P` (A.15), making M positive semi-definite for CG; the solve is
//!   `M p = −(∇·h)` which is algebraically identical to `P p = ∇·h`.
//! - Contravariant face fluxes use the collocated interpolation (A.8):
//!   `U_f = ½(U_P + U_F)`, `U_X = J_X · (T_X)_j · u_X`.
//! - Dirichlet faces: advection + diffusion boundary fluxes go to the RHS
//!   (A.13); the one-sided diffusion uses the factor-2 cell metric (A.11).
//!   Pressure is implicit 0-Neumann there. Velocity-Neumann faces are
//!   zero-gradient (u_F := u_P, one-sided flux on the matrix diagonal).

pub mod assemble;
pub mod nonorth;
pub mod pressure;

pub use assemble::{
    assemble_c, boundary_flux_rhs, boundary_flux_rhs_into, c_structure, contravariant,
    contravariant_bc,
};
pub use nonorth::cross_diffusion;
pub use pressure::{
    assemble_pressure, divergence_h, h_field, pressure_gradient, pressure_structure,
};

/// Position of `col` within one CSR row's sorted column slice (the per-row
/// lookup both assembly kernels use); panics if the entry is not in the
/// structure.
#[inline]
pub(crate) fn row_entry(cols: &[u32], row: usize, col: usize) -> usize {
    cols.binary_search(&crate::util::det::index_u32(col))
        .unwrap_or_else(|_| panic!("entry ({row},{col}) not in CSR structure"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{gen, VectorField};

    /// Advection of a constant scalar by a uniform velocity is zero
    /// (telescoping fluxes on a periodic box).
    #[test]
    fn advection_of_constant_is_zero() {
        let m = gen::periodic_box2d(8, 6, 2.0, 1.5);
        let mut u = VectorField::zeros(m.ncells);
        u.comp[0].iter_mut().for_each(|v| *v = 0.7);
        u.comp[1].iter_mut().for_each(|v| *v = -0.3);
        let nu = vec![0.0; m.ncells];
        let mut c = c_structure(&m);
        assemble_c(&crate::par::ExecCtx::serial(), &m, &u, &nu, f64::INFINITY, &mut c);
        // apply to constant field: result must vanish (rows sum to zero,
        // dt=inf removes the temporal term)
        let x = vec![1.0; m.ncells];
        let mut y = vec![0.0; m.ncells];
        c.matvec(&x, &mut y);
        for v in &y {
            assert!(v.abs() < 1e-12, "{v}");
        }
    }

    /// The diffusion operator applied to u = x² + y² equals 2·dim·ν for
    /// interior cells, including on graded and distorted meshes (the latter
    /// exercising the non-orthogonal deferred correction).
    #[test]
    fn diffusion_of_quadratic_is_constant() {
        for (mesh, tol) in [
            (gen::periodic_box2d(12, 10, 1.0, 1.0), 1e-6),
            // graded mesh: the paper's arithmetic face interpolation of
            // ᾱν has O(Δ) truncation on non-uniform spacing — allow ~1%
            (gen::channel2d(10, 12, 1.0, 1.0, 1.15, true), 0.04),
        ] {
            let nu_val = 0.3;
            let nu = vec![nu_val; mesh.ncells];
            let u_zero = VectorField::zeros(mesh.ncells);
            let mut c = c_structure(&mesh);
            assemble_c(&crate::par::ExecCtx::serial(), &mesh, &u_zero, &nu, f64::INFINITY, &mut c);
            let x: Vec<f64> = mesh.centers.iter().map(|c| c[0] * c[0] + c[1] * c[1]).collect();
            let mut y = vec![0.0; mesh.ncells];
            c.matvec(&x, &mut y);
            // C holds −D/J, so −y ≈ ν ∇²u = 4ν for interior cells
            let b = &mesh.blocks[0];
            for k in 0..b.shape[2] {
                for j in 1..b.shape[1] - 1 {
                    for i in 1..b.shape[0] - 1 {
                        let l = b.offset + b.lidx(i, j, k);
                        let lap = -y[l];
                        assert!(
                            (lap - 4.0 * nu_val).abs() < tol * 4.0 * nu_val.max(1e-6),
                            "cell {l}: {lap} vs {}",
                            4.0 * nu_val
                        );
                    }
                }
            }
        }
    }

    /// Same exactness on a distorted (non-orthogonal) mesh once the explicit
    /// cross-diffusion correction is added.
    #[test]
    fn diffusion_with_cross_terms_on_distorted_mesh() {
        let mesh = gen::distorted_cavity2d(12, 1.0, 0.0, 0.18);
        assert!(mesh.non_orthogonal);
        let nu_val = 1.0;
        let nu = vec![nu_val; mesh.ncells];
        let u_zero = VectorField::zeros(mesh.ncells);
        let mut c = c_structure(&mesh);
        assemble_c(&crate::par::ExecCtx::serial(), &mesh, &u_zero, &nu, f64::INFINITY, &mut c);
        let x: Vec<f64> = mesh.centers.iter().map(|c| c[0] * c[0] + c[1] * c[1]).collect();
        let mut y = vec![0.0; mesh.ncells];
        c.matvec(&x, &mut y);
        let cross = cross_diffusion(&mesh, &nu, &x);
        let b = &mesh.blocks[0];
        for j in 2..b.shape[1] - 2 {
            for i in 2..b.shape[0] - 2 {
                let l = b.offset + b.lidx(i, j, 0);
                // lap = (−C·x + cross/J) — both sides per unit volume
                let lap = -y[l] + cross[l] / mesh.jac[l];
                assert!(
                    (lap - 4.0 * nu_val).abs() < 0.25,
                    "cell ({i},{j}): {lap} vs {}",
                    4.0 * nu_val
                );
            }
        }
    }

    /// Divergence of a uniform field vanishes on a periodic box, and matches
    /// the analytic divergence for a linear field.
    #[test]
    fn divergence_accuracy() {
        let m = gen::periodic_box2d(16, 16, 1.0, 1.0);
        let mut h = VectorField::zeros(m.ncells);
        h.comp[0].iter_mut().for_each(|v| *v = 1.0);
        h.comp[1].iter_mut().for_each(|v| *v = -2.0);
        let d = divergence_h(&m, &h, None);
        for v in &d {
            assert!(v.abs() < 1e-12);
        }
    }

    /// Pressure gradient of a linear field is exact (away from boundaries).
    #[test]
    fn gradient_of_linear_pressure() {
        let m = gen::channel2d(8, 8, 1.0, 1.0, 1.0, false);
        let p: Vec<f64> = m.centers.iter().map(|c| 3.0 * c[0] - 2.0 * c[1]).collect();
        let g = pressure_gradient(&m, &p);
        let b = &m.blocks[0];
        // skip the periodic-wrap columns in x (p is not x-periodic here)
        for j in 1..b.shape[1] - 1 {
            for i in 1..b.shape[0] - 1 {
                let l = b.lidx(i, j, 0);
                assert!((g.comp[0][l] - 3.0).abs() < 1e-9, "{}", g.comp[0][l]);
                assert!((g.comp[1][l] + 2.0).abs() < 1e-9, "{}", g.comp[1][l]);
            }
        }
    }

    /// The negated pressure matrix M = −P is symmetric with zero row sums on
    /// a periodic box (pure Neumann analog).
    #[test]
    fn pressure_matrix_symmetric_conservative() {
        let m = gen::periodic_box2d(6, 5, 1.0, 1.0);
        let a_inv = vec![0.5; m.ncells];
        let mut pm = pressure_structure(&m);
        assemble_pressure(&crate::par::ExecCtx::serial(), &m, &a_inv, &mut pm);
        let d = pm.to_dense();
        for r in 0..pm.n {
            let row_sum: f64 = d[r].iter().sum();
            assert!(row_sum.abs() < 1e-12);
            for c in 0..pm.n {
                assert!((d[r][c] - d[c][r]).abs() < 1e-12);
            }
            // diagonal positive (negated form)
            assert!(d[r][r] > 0.0);
        }
    }
}
