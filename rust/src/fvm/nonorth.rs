//! Non-orthogonal deferred correction (paper A.12, A.21–A.22, Appendix
//! A.3.5). Cross-derivative diffusive fluxes `[α_jk s ∂φ/∂ξ_k]_f` (k ≠ j)
//! are evaluated explicitly from the previous iterate and moved to the RHS,
//! keeping the matrix stencil compact. Boundary-adjacent tangential
//! derivatives fall back to one-sided/zero contributions (the paper likewise
//! omits tangential boundary influence).

use crate::mesh::{face_axis, face_sign, Mesh, NeighRef};

/// Tangential derivative ∂φ/∂ξ_k at cell `cell` by central differences with
/// 0-gradient ghosts at boundaries.
#[inline]
fn dphi_dxi(mesh: &Mesh, phi: &[f64], cell: usize, k: usize) -> f64 {
    let hi = match mesh.topo.at(cell, 2 * k + 1) {
        NeighRef::Cell(n) => phi[n as usize],
        _ => phi[cell],
    };
    let lo = match mesh.topo.at(cell, 2 * k) {
        NeighRef::Cell(n) => phi[n as usize],
        _ => phi[cell],
    };
    0.5 * (hi - lo)
}

/// Explicit cross-diffusion flux sum per cell (volume form):
/// `Σ_f N_f Σ_{k≠j} [ᾱ_jk s ∂φ/∂ξ_k]_f`, with the face value interpolated
/// from the two adjacent cells. `s` is the per-cell scale (ν for momentum,
/// A⁻¹ for pressure). The caller adds this to the RHS of the corresponding
/// system (divided by J_P for the 1/J-scaled momentum rows).
pub fn cross_diffusion(mesh: &Mesh, s: &[f64], phi: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; mesh.ncells];
    if !mesh.non_orthogonal {
        return out;
    }
    // per-cell tangential gradient terms β_jk = α_jk s ∂φ/∂ξ_k (k ≠ j)
    // accumulated per axis j, then interpolated to faces
    let mut beta = vec![[0.0f64; 3]; mesh.ncells];
    for cell in 0..mesh.ncells {
        for j in 0..mesh.dim {
            let mut acc = 0.0;
            for k in 0..mesh.dim {
                if k != j {
                    acc += mesh.alpha[cell][j][k] * s[cell] * dphi_dxi(mesh, phi, cell, k);
                }
            }
            beta[cell][j] = acc;
        }
    }
    for cell in 0..mesh.ncells {
        let mut acc = 0.0;
        for face in 0..2 * mesh.dim {
            let j = face_axis(face);
            let nf = face_sign(face);
            if let NeighRef::Cell(nb) = mesh.topo.at(cell, face) {
                acc += nf * 0.5 * (beta[cell][j] + beta[nb as usize][j]);
            }
            // boundary faces: tangential contribution omitted (see module docs)
        }
        out[cell] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;

    #[test]
    fn zero_on_orthogonal_mesh() {
        let m = gen::periodic_box2d(8, 8, 1.0, 1.0);
        let s = vec![1.0; m.ncells];
        let phi: Vec<f64> = m.centers.iter().map(|c| c[0] * c[1]).collect();
        let cross = cross_diffusion(&m, &s, &phi);
        assert!(cross.iter().all(|v| v.abs() < 1e-14));
    }

    #[test]
    fn nonzero_on_distorted_mesh() {
        let m = gen::distorted_cavity2d(10, 1.0, 0.0, 0.2);
        let s = vec![1.0; m.ncells];
        let phi: Vec<f64> = m.centers.iter().map(|c| c[0] * c[0]).collect();
        let cross = cross_diffusion(&m, &s, &phi);
        let max = cross.iter().fold(0.0f64, |a, b| a.max(b.abs()));
        assert!(max > 1e-6, "expected nonzero cross-diffusion, max={max}");
    }
}
