//! Pressure-correction operators (paper A.14–A.20): the (negated) pressure
//! Laplacian M = −P, the divergence of the pseudo-velocity h, the collocated
//! pressure gradient, and h itself.

use crate::mesh::{face_axis, face_sign, Mesh, NeighRef, VectorField};
use crate::par::ExecCtx;
use crate::sparse::Csr;

/// Symbolic structure of the pressure matrix (same stencil as C).
pub fn pressure_structure(mesh: &Mesh) -> Csr {
    super::assemble::c_structure(mesh)
}

/// Fill M = −P (A.15): `M[P][F] = −[ᾱ_jj A⁻¹]_f`, `M[P][P] = +Σ_f […]_f`.
/// Boundary faces (velocity Dirichlet/Neumann ⇒ pressure 0-Neumann) carry no
/// entries. M is symmetric positive semi-definite with the constant
/// nullspace on all-periodic domains.
pub fn assemble_pressure(ctx: &ExecCtx, mesh: &Mesh, a_inv: &[f64], m: &mut Csr) {
    // Row-partitioned across the caller's pool (same disjoint-rows argument
    // as `assemble_c`); per-row arithmetic matches the serial loop exactly.
    let Csr { ref row_ptr, ref col_idx, ref mut vals, .. } = *m;
    ctx.for_each_row(row_ptr, col_idx, vals, |cell, cols, row_vals| {
        row_vals.iter_mut().for_each(|v| *v = 0.0);
        let entry = |col: usize| super::row_entry(cols, cell, col);
        let mut diag = 0.0;
        for face in 0..2 * mesh.dim {
            let ax = face_axis(face);
            if let NeighRef::Cell(nb) = mesh.topo.at(cell, face) {
                let nb = nb as usize;
                let coef = 0.5
                    * (mesh.alpha[cell][ax][ax] * a_inv[cell]
                        + mesh.alpha[nb][ax][ax] * a_inv[nb]);
                row_vals[entry(nb)] += -coef;
                diag += coef;
            }
        }
        row_vals[entry(cell)] += diag;
    });
}

/// Divergence RHS for the pressure system (A.18): per cell,
/// `∇·h = Σ_f N_f [J T_j · h]_f + Σ_b N_b U_b` in volume form, where the
/// boundary flux uses the prescribed Dirichlet velocity (so the corrected
/// field conserves mass through boundaries). `ub_override` substitutes the
/// Dirichlet values (used by the adjoint for VJP probes).
pub fn divergence_h(mesh: &Mesh, h: &VectorField, ub_override: Option<&[[f64; 3]]>) -> Vec<f64> {
    let hc: Vec<[f64; 3]> =
        (0..mesh.ncells).map(|i| super::assemble::contravariant(mesh, h, i)).collect();
    let mut div = vec![0.0; mesh.ncells];
    let mut bc_cursor = 0usize; // flat cursor for ub_override
    for cell in 0..mesh.ncells {
        let mut acc = 0.0;
        for face in 0..2 * mesh.dim {
            let ax = face_axis(face);
            let nf = face_sign(face);
            match mesh.topo.at(cell, face) {
                NeighRef::Cell(nb) => {
                    acc += nf * 0.5 * (hc[cell][ax] + hc[nb as usize][ax]);
                }
                NeighRef::Dirichlet { values, face_cell } => {
                    let ub = match ub_override {
                        Some(o) => {
                            let v = o[bc_cursor];
                            bc_cursor += 1;
                            v
                        }
                        None => mesh.bc_values[values as usize].vel[face_cell as usize],
                    };
                    acc += nf * super::assemble::contravariant_bc(mesh, cell, ub, ax);
                }
                NeighRef::Neumann => {
                    // zero-gradient: flux of the cell value itself
                    acc += nf * hc[cell][ax];
                }
            }
        }
        div[cell] = acc;
    }
    div
}

/// Collocated pressure gradient (A.20): `(∇p)_i = Σ_j T_ji (p_{j+1} − p_{j−1})/2`
/// with 0-Neumann ghosts (`p_ghost = p_P`) at boundaries.
pub fn pressure_gradient(mesh: &Mesh, p: &[f64]) -> VectorField {
    let mut g = VectorField::zeros(mesh.ncells);
    for cell in 0..mesh.ncells {
        let t = &mesh.t[cell];
        for ax in 0..mesh.dim {
            let p_hi = match mesh.topo.at(cell, 2 * ax + 1) {
                NeighRef::Cell(n) => p[n as usize],
                _ => p[cell],
            };
            let p_lo = match mesh.topo.at(cell, 2 * ax) {
                NeighRef::Cell(n) => p[n as usize],
                _ => p[cell],
            };
            let dp = 0.5 * (p_hi - p_lo);
            for i in 0..mesh.dim {
                g.comp[i][cell] += t[ax][i] * dp;
            }
        }
    }
    g
}

/// Pseudo-velocity h (A.17): `h = A⁻¹ (rhs_base − H u*)` where `rhs_base` is
/// the pressure-free momentum RHS (`u^n/Δt + boundary fluxes + S`) and H is
/// the off-diagonal part of C.
pub fn h_field(
    mesh: &Mesh,
    c: &Csr,
    a_inv: &[f64],
    u_star: &VectorField,
    rhs_base: &VectorField,
) -> VectorField {
    let mut h = VectorField::zeros(mesh.ncells);
    for comp in 0..mesh.dim {
        let us = &u_star.comp[comp];
        for cell in 0..mesh.ncells {
            let mut hu = 0.0;
            for k in c.row_ptr[cell]..c.row_ptr[cell + 1] {
                let col = c.col_idx[k] as usize;
                if col != cell {
                    hu += c.vals[k] * us[col];
                }
            }
            h.comp[comp][cell] = a_inv[cell] * (rhs_base.comp[comp][cell] - hu);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;

    #[test]
    fn divergence_of_linear_field() {
        // u = (x, -y): div = 0 analytically; with central fluxes on a
        // periodic box the discrete divergence telescopes exactly except for
        // the wrap faces, so test on interior cells of a channel instead.
        let m = gen::channel2d(12, 12, 1.0, 1.0, 1.0, false);
        let mut u = VectorField::zeros(m.ncells);
        for (i, c) in m.centers.iter().enumerate() {
            u.comp[0][i] = c[0];
            u.comp[1][i] = -c[1];
        }
        let d = divergence_h(&m, &u, None);
        let b = &m.blocks[0];
        for j in 1..b.shape[1] - 1 {
            for i in 1..b.shape[0] - 1 {
                let l = b.lidx(i, j, 0);
                assert!(d[l].abs() / m.jac[l] < 1e-9, "{}", d[l]);
            }
        }
    }

    #[test]
    fn h_equals_ainv_rhs_for_diagonal_c() {
        let m = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let mut c = super::super::c_structure(&m);
        // diagonal-only C
        for cell in 0..m.ncells {
            c.add(cell, cell, 2.0);
        }
        let a_inv: Vec<f64> = vec![0.5; m.ncells];
        let mut u_star = VectorField::zeros(m.ncells);
        u_star.comp[0].iter_mut().for_each(|v| *v = 3.0);
        let mut rhs = VectorField::zeros(m.ncells);
        rhs.comp[0].iter_mut().for_each(|v| *v = 4.0);
        let h = h_field(&m, &c, &a_inv, &u_star, &rhs);
        for v in &h.comp[0] {
            assert!((v - 2.0).abs() < 1e-12); // 0.5 * (4 - 0)
        }
    }

    #[test]
    fn pressure_solve_recovers_divergence_free_field() {
        // project a divergent field: u = ∇φ for φ = sin(2πx)cos(2πy) has
        // nonzero divergence; after one projection u − A⁻¹∇p the divergence
        // must drop substantially.
        use crate::linsolve::{cg, Jacobi, SolveOpts};
        let m = gen::periodic_box2d(24, 24, 1.0, 1.0);
        let tau = 2.0 * std::f64::consts::PI;
        let mut u = VectorField::zeros(m.ncells);
        for (i, c) in m.centers.iter().enumerate() {
            u.comp[0][i] = (tau * c[0]).sin() * (tau * c[1]).cos() + 0.3;
            u.comp[1][i] = (tau * c[0]).cos() * (tau * c[1]).sin();
        }
        let a_inv = vec![1.0; m.ncells];
        let mut pm = pressure_structure(&m);
        assemble_pressure(&ExecCtx::serial(), &m, &a_inv, &mut pm);
        let div0 = divergence_h(&m, &u, None);
        let rhs: Vec<f64> = div0.iter().map(|v| -v).collect();
        let mut p = vec![0.0; m.ncells];
        let ctx = ExecCtx::serial();
        let st = cg(&ctx, &pm, &rhs, &mut p, &Jacobi::new(&pm), true, SolveOpts::default());
        assert!(st.converged);
        let g = pressure_gradient(&m, &p);
        let mut u2 = u.clone();
        u2.axpy(-1.0, &g);
        let div1 = divergence_h(&m, &u2, None);
        let n0: f64 = div0.iter().map(|v| v * v).sum::<f64>().sqrt();
        let n1: f64 = div1.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(n1 < 0.05 * n0, "divergence {n0} -> {n1}");
    }
}
