//! Assembly of the advection–diffusion matrix C and the momentum RHS
//! (paper A.9, A.11, A.13). Rows are 1/J_P-scaled; see `fvm` docs.

use crate::mesh::{face_axis, face_sign, Mesh, NeighRef, VectorField};
use crate::par::ExecCtx;
use crate::sparse::Csr;

/// Contravariant flux components `U^j = J · T_j · u` of one cell.
#[inline]
pub fn contravariant(mesh: &Mesh, u: &VectorField, cell: usize) -> [f64; 3] {
    let t = &mesh.t[cell];
    let j = mesh.jac[cell];
    let uv = u.get(cell);
    let mut out = [0.0; 3];
    for a in 0..mesh.dim {
        out[a] = j * (t[a][0] * uv[0] + t[a][1] * uv[1] + t[a][2] * uv[2]);
    }
    out
}

/// Contravariant flux of a Dirichlet boundary value, evaluated with the
/// adjacent cell's metrics (the paper defines u, T directly on the face; the
/// cell metric is the consistent collocated approximation we use for both
/// assembly and the continuity RHS, preserving discrete mass balance).
#[inline]
pub fn contravariant_bc(mesh: &Mesh, cell: usize, ub: [f64; 3], axis: usize) -> f64 {
    let t = &mesh.t[cell];
    mesh.jac[cell] * (t[axis][0] * ub[0] + t[axis][1] * ub[1] + t[axis][2] * ub[2])
}

/// Symbolic structure of C: diagonal + one entry per interior/connected face.
pub fn c_structure(mesh: &Mesh) -> Csr {
    let mut cols: Vec<Vec<usize>> = vec![Vec::with_capacity(7); mesh.ncells];
    for cell in 0..mesh.ncells {
        cols[cell].push(cell);
        for face in 0..2 * mesh.dim {
            if let NeighRef::Cell(n) = mesh.topo.at(cell, face) {
                cols[cell].push(n as usize);
            }
        }
    }
    Csr::structure_from_columns(&cols)
}

/// Fill C with temporal + advective + diffusive coefficients:
/// `C = I/Δt + (C_adv + C_ν)/J_P`. `u_adv` is the advecting velocity u^n,
/// `nu` the per-cell kinematic viscosity. `dt = f64::INFINITY` drops the
/// temporal term (steady operator, used by tests and by the SIMPLE-like
/// initialization).
pub fn assemble_c(
    ctx: &ExecCtx,
    mesh: &Mesh,
    u_adv: &VectorField,
    nu: &[f64],
    dt: f64,
    c: &mut Csr,
) {
    // precompute contravariant fluxes per cell
    let uc: Vec<[f64; 3]> = (0..mesh.ncells).map(|i| contravariant(mesh, u_adv, i)).collect();
    let inv_dt = if dt.is_finite() { 1.0 / dt } else { 0.0 };

    // Row `cell` of C depends only on that cell's faces, and CSR rows own
    // disjoint value ranges, so assembly is row-partitioned across the
    // caller's pool. The per-row arithmetic (zero, face order, one final
    // diagonal add) matches the previous serial loop exactly, keeping the
    // assembled matrix bit-identical at any context width.
    let Csr { ref row_ptr, ref col_idx, ref mut vals, .. } = *c;
    ctx.for_each_row(row_ptr, col_idx, vals, |cell, cols, row_vals| {
        row_vals.iter_mut().for_each(|v| *v = 0.0);
        let entry = |col: usize| super::row_entry(cols, cell, col);
        let inv_j = 1.0 / mesh.jac[cell];
        let mut diag = inv_dt;
        for face in 0..2 * mesh.dim {
            let ax = face_axis(face);
            let nf = face_sign(face);
            match mesh.topo.at(cell, face) {
                NeighRef::Cell(nb) => {
                    let nb = nb as usize;
                    // advection (A.8/A.9): central interpolation of U^j
                    let uf = 0.5 * (uc[cell][ax] + uc[nb][ax]);
                    let adv = 0.5 * nf * uf * inv_j;
                    // diffusion (A.11): face-interpolated α_jj ν
                    let anu =
                        0.5 * (mesh.alpha[cell][ax][ax] * nu[cell] + mesh.alpha[nb][ax][ax] * nu[nb]);
                    let offd = adv - anu * inv_j;
                    row_vals[entry(nb)] += offd;
                    diag += adv + anu * inv_j;
                }
                NeighRef::Dirichlet { .. } => {
                    // advective boundary flux goes to the RHS (A.13);
                    // one-sided diffusion: 2 α_jj ν at the cell (A.11)
                    diag += 2.0 * mesh.alpha[cell][ax][ax] * nu[cell] * inv_j;
                }
                NeighRef::Neumann => {
                    // zero-gradient: u_f = u_P, flux = N·U_P on the diagonal
                    diag += nf * uc[cell][ax] * inv_j;
                }
            }
        }
        row_vals[entry(cell)] += diag;
    });
}

/// Boundary-flux part of the momentum RHS (A.13):
/// `(1/J_P) Σ_b [u_b (2 α_jj ν − U^j N)]_b` per component. Dirichlet faces
/// only; Neumann faces contribute nothing here (handled on the diagonal).
pub fn boundary_flux_rhs(mesh: &Mesh, nu: &[f64]) -> VectorField {
    let mut out = VectorField::zeros(mesh.ncells);
    boundary_flux_rhs_into(mesh, nu, &mut out);
    out
}

/// In-place variant of [`boundary_flux_rhs`] for callers that reuse a
/// step-persistent scratch field (`out` is zeroed first).
pub fn boundary_flux_rhs_into(mesh: &Mesh, nu: &[f64], out: &mut VectorField) {
    for comp in out.comp.iter_mut() {
        debug_assert_eq!(comp.len(), mesh.ncells);
        comp.iter_mut().for_each(|v| *v = 0.0);
    }
    for cell in 0..mesh.ncells {
        let inv_j = 1.0 / mesh.jac[cell];
        for face in 0..2 * mesh.dim {
            if let NeighRef::Dirichlet { values, face_cell } = mesh.topo.at(cell, face) {
                let ax = face_axis(face);
                let nf = face_sign(face);
                let ub = mesh.bc_values[values as usize].vel[face_cell as usize];
                let ubf = contravariant_bc(mesh, cell, ub, ax);
                let coef = (2.0 * mesh.alpha[cell][ax][ax] * nu[cell] - ubf * nf) * inv_j;
                for comp in 0..mesh.dim {
                    out.comp[comp][cell] += ub[comp] * coef;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;

    #[test]
    fn contravariant_on_uniform_grid() {
        let m = gen::periodic_box2d(4, 4, 2.0, 2.0); // Δ=0.5, J=0.25, T=2
        let mut u = VectorField::zeros(m.ncells);
        u.set(5, [1.0, -2.0, 0.0]);
        let uc = contravariant(&m, &u, 5);
        assert!((uc[0] - 0.25 * 2.0 * 1.0).abs() < 1e-12);
        assert!((uc[1] - 0.25 * 2.0 * -2.0).abs() < 1e-12);
    }

    #[test]
    fn c_row_count_matches_stencil() {
        let m = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let c = c_structure(&m);
        // every row: diag + 4 neighbors
        for r in 0..c.n {
            assert_eq!(c.row_ptr[r + 1] - c.row_ptr[r], 5);
        }
    }

    #[test]
    fn dirichlet_wall_strengthens_diagonal() {
        let m = gen::channel2d(4, 4, 1.0, 1.0, 1.0, false);
        let u = VectorField::zeros(m.ncells);
        let nu = vec![0.1; m.ncells];
        let mut c = c_structure(&m);
        assemble_c(&ExecCtx::serial(), &m, &u, &nu, 1.0, &mut c);
        // wall-adjacent cell has larger diagonal than interior cell
        let wall_cell = m.gid(0, 1, 0, 0);
        let mid_cell = m.gid(0, 1, 1, 0);
        let dw = c.vals[c.find(wall_cell, wall_cell).unwrap()];
        let dm = c.vals[c.find(mid_cell, mid_cell).unwrap()];
        assert!(dw > dm, "{dw} vs {dm}");
    }

    #[test]
    fn moving_lid_enters_rhs() {
        let m = gen::cavity2d(4, 1.0, 2.0, false);
        let nu = vec![0.1; m.ncells];
        let rhs = boundary_flux_rhs(&m, &nu);
        // top row cells see u-momentum from the lid
        let top = m.gid(0, 2, 3, 0);
        assert!(rhs.comp[0][top] > 0.0);
        // bottom row cells see nothing (no-slip u_b = 0)
        let bot = m.gid(0, 2, 0, 0);
        assert_eq!(rhs.comp[0][bot], 0.0);
    }
}
