//! Small self-contained substrates: PRNG, JSON writer, CLI parsing, timing,
//! and a property-testing mini-framework. These exist because the build is
//! fully offline and the usual crates (rand, serde_json, clap, criterion,
//! proptest) are not vendored; each is a focused reimplementation of the
//! subset PICT needs.

pub mod bench;
pub mod cli;
pub mod det;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;

/// Relative L2 error between two slices: `||a-b|| / max(||b||, eps)`.
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num / den.max(1e-300)).sqrt()
}

/// Mean squared error between two slices.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        s += (x - y) * (x - y);
    }
    s / a.len() as f64
}

/// Pearson correlation coefficient of two slices.
pub fn correlation(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_l2_zero_for_identical() {
        let a = [1.0, 2.0, 3.0];
        assert!(rel_l2(&a, &a) < 1e-15);
    }

    #[test]
    fn correlation_of_self_is_one() {
        let a = [0.3, -1.0, 2.5, 4.0];
        assert!((correlation(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn correlation_of_negated_is_minus_one() {
        let a = [0.3, -1.0, 2.5, 4.0];
        let b: Vec<f64> = a.iter().map(|x| -x).collect();
        assert!((correlation(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn mse_simple() {
        assert!((mse(&[1.0, 2.0], &[0.0, 0.0]) - 2.5).abs() < 1e-15);
    }
}
