//! Property-testing mini-framework (proptest is not vendored offline).
//! Generates random cases from a seeded [`Rng`](super::rng::Rng), runs the
//! property, and on failure reports the case index + seed so the exact case
//! reproduces deterministically.

use super::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 32, seed: 0xC0FFEE }
    }
}

impl Prop {
    pub fn new(cases: usize, seed: u64) -> Self {
        Prop { cases, seed }
    }

    /// Run `property(rng, case_index)`; panic with a reproducible message on
    /// the first failing case (property returns Err(description)).
    pub fn check<F>(&self, name: &str, mut property: F)
    where
        F: FnMut(&mut Rng, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            // fresh, addressable stream per case
            let mut rng = Rng::new(self.seed.wrapping_add(case as u64 * 0x9E37));
            if let Err(msg) = property(&mut rng, case) {
                panic!(
                    "property `{name}` failed on case {case} (seed {:#x}): {msg}",
                    self.seed
                );
            }
        }
    }
}

/// Helper: random dimensions in [lo, hi] (inclusive).
pub fn dims_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new(10, 1).check("always_ok", |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn failing_property_panics_with_case() {
        Prop::new(5, 2).check("always_bad", |_, _| Err("nope".into()));
    }

    #[test]
    fn dims_in_respects_bounds() {
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            let d = dims_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&d));
        }
    }
}
