//! Wall-clock timing helpers and a lightweight scoped profiler used by the
//! §Perf pass. The profiler accumulates named section totals so we can report
//! e.g. the fraction of a PISO step spent in linear solves (the paper quotes
//! 70–90 %).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

/// Measure wall-clock seconds of a closure.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

thread_local! {
    static PROFILE: RefCell<BTreeMap<String, (u64, f64)>> = RefCell::new(BTreeMap::new());
    static PROFILE_ON: RefCell<bool> = const { RefCell::new(false) };
}

/// Enable/disable the thread-local profiler.
pub fn set_profiling(on: bool) {
    PROFILE_ON.with(|p| *p.borrow_mut() = on);
}

/// Reset accumulated sections.
pub fn reset_profile() {
    PROFILE.with(|p| p.borrow_mut().clear());
}

/// Accumulate `secs` under `name` (no-op unless profiling is enabled).
pub fn record(name: &str, secs: f64) {
    if !PROFILE_ON.with(|p| *p.borrow()) {
        return;
    }
    PROFILE.with(|p| {
        let mut m = p.borrow_mut();
        let e = m.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    });
}

/// Profile a closure under `name`.
pub fn scoped<T>(name: &str, f: impl FnOnce() -> T) -> T {
    if !PROFILE_ON.with(|p| *p.borrow()) {
        return f();
    }
    let t0 = Instant::now();
    let out = f();
    record(name, t0.elapsed().as_secs_f64());
    out
}

/// Snapshot of `(name, calls, total_secs)` sorted by total time descending.
pub fn profile_report() -> Vec<(String, u64, f64)> {
    let mut rows: Vec<(String, u64, f64)> =
        PROFILE.with(|p| p.borrow().iter().map(|(k, v)| (k.clone(), v.0, v.1)).collect());
    rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    rows
}

/// Render the profile as an aligned text table.
pub fn profile_table() -> String {
    let rows = profile_report();
    let total: f64 = rows.iter().map(|r| r.2).sum();
    let mut s = String::new();
    s.push_str(&format!(
        "{:<28} {:>10} {:>12} {:>7}\n",
        "section", "calls", "total [s]", "%"
    ));
    for (name, calls, secs) in &rows {
        s.push_str(&format!(
            "{:<28} {:>10} {:>12.4} {:>6.1}%\n",
            name,
            calls,
            secs,
            100.0 * secs / total.max(1e-12)
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiler_accumulates() {
        set_profiling(true);
        reset_profile();
        for _ in 0..3 {
            scoped("work", || std::thread::sleep(std::time::Duration::from_millis(1)));
        }
        let rows = profile_report();
        set_profiling(false);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].1, 3);
        assert!(rows[0].2 >= 0.003);
    }

    #[test]
    fn disabled_profiler_is_silent() {
        set_profiling(false);
        reset_profile();
        scoped("hidden", || ());
        assert!(profile_report().is_empty());
    }
}
