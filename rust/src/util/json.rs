//! Minimal JSON value model + writer/parser. The coordinator writes bench
//! reports and the runtime reads the artifact manifest; we only need a small,
//! strict subset of JSON (no comments, UTF-8, f64 numbers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // integral values print as integers, except -0.0 (whose
                    // sign bit `as i64` would drop); everything else uses
                    // Rust's shortest-round-trip exponential formatting, so
                    // every finite f64 survives write -> parse bit-for-bit
                    // (the sweep layer's shard artifacts rely on this)
                    if *x == x.trunc() && x.abs() < 1e15 && !(*x == 0.0 && x.is_sign_negative())
                    {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x:e}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  \"{k}\": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }

    /// Parse a JSON document. Strict enough for our own outputs + manifest.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected , or ] at byte {pos}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected : at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected , or }} at byte {pos}")),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}"));
    }
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let code = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        s.push(char::from_u32(code).unwrap_or('?'));
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            c => {
                // pass through UTF-8 bytes verbatim
                let start = *pos;
                let len = utf8_len(c);
                *pos += len;
                s.push_str(std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?);
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number `{s}`: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("table 1".into())),
            ("values", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string_pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x\ny"}, null], "c": -2.5e3}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-2500.0));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn finite_f64_round_trips_bit_for_bit() {
        // the sweep shard artifacts serialize whole solver states through
        // Json; merge equality is defined bit-for-bit, so the writer must
        // preserve every finite value exactly — including negative zero,
        // subnormals, and values with no short decimal form
        let vals = [
            0.0,
            -0.0,
            1.0,
            -1.5,
            1.0 / 3.0,
            std::f64::consts::PI,
            1e-310,
            5e-324,
            f64::MIN_POSITIVE,
            -2.2250738585072014e-308,
            1e300,
            -9.87654321e-12,
            1e15 + 1.0,
            123456789.123456789,
        ];
        for v in vals {
            let s = Json::Num(v).to_string_pretty();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v:e} wrote as {s}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn escapes_specials() {
        let j = Json::Str("a\"b\\c\nd".into());
        let s = j.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }
}
