//! Tiny clap-like CLI argument parser: `--key value`, `--flag`, and free
//! positional arguments, with typed getters and defaults.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including `argv[0]`).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = parse(&["run", "--steps", "100", "--verbose", "--re=550", "extra"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("re", 0.0), 550.0);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.get_or("engine", "native"), "native");
        assert!(!a.flag("x"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
    }
}
