//! Deterministic PRNG (splitmix64 seeded xoshiro256**) — reproducible across
//! platforms, no external deps. Used for initial perturbations, property
//! tests, and workload generation.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        Rng {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_stays_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
