//! Criterion-style micro/macro benchmark harness (criterion is not vendored).
//! Runs warmup + measured iterations, reports mean / stddev / min, and writes
//! a JSON report under `reports/` so EXPERIMENTS.md tables can be regenerated.

use super::json::Json;
use std::io;
use std::path::{Path, PathBuf};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("std_s", Json::Num(self.std_s)),
            ("min_s", Json::Num(self.min_s)),
            ("max_s", Json::Num(self.max_s)),
        ])
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, iters: 5 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Time `f` over the configured iterations and print a criterion-like line.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = summarize(name, self.iters, &samples);
        println!(
            "bench {:<44} mean {:>10.4} ms  (± {:>8.4} ms, min {:>10.4} ms, n={})",
            res.name,
            res.mean_s * 1e3,
            res.std_s * 1e3,
            res.min_s * 1e3,
            res.iters
        );
        res
    }
}

/// Summary statistics over raw timing samples: mean, *sample* (n−1)
/// standard deviation — the 5-iteration default is nowhere near the
/// population regime, so the /n estimator biased `std_s` low — and a
/// min/max fold seeded from the samples themselves (a `0.0` max seed would
/// be silently wrong if it ever met an all-negative sample set, and read
/// as a real measurement on an empty one).
fn summarize(name: &str, iters: usize, samples: &[f64]) -> BenchResult {
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let std_s = if samples.len() > 1 {
        (samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / (n - 1.0)).sqrt()
    } else {
        0.0
    };
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Durably write `j` to `path`: bytes go to a same-directory temp file that
/// is flushed to disk and atomically renamed over the target, so concurrent
/// readers — and the sweep layer's resume logic — only ever observe either
/// a missing file or a complete document, never a truncated one. The
/// containing directory is created if needed.
pub fn write_json_atomic(path: &Path, j: &Json) -> io::Result<()> {
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    let file_name = path.file_name().and_then(|n| n.to_str()).ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("report path {} has no file name", path.display()),
        )
    })?;
    // pid-qualified temp name: concurrent writers of the same artifact each
    // stage privately and the rename decides last-writer-wins atomically
    let tmp = path.with_file_name(format!(".{file_name}.{}.tmp", std::process::id()));
    let result = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        io::Write::write_all(&mut f, j.to_string_pretty().as_bytes())?;
        io::Write::write_all(&mut f, b"\n")?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Write a collection of results (plus free-form extra fields) to
/// `reports/<file>.json` via [`write_json_atomic`] (temp file + atomic
/// rename), returning the written path. Callers must surface the error:
/// a swallowed write failure leaves a missing or stale report that reads
/// as "this work never ran" — or, for sweep shards, as a completed shard.
pub fn write_report(
    file: &str,
    results: &[BenchResult],
    extra: Vec<(&str, Json)>,
) -> io::Result<PathBuf> {
    let mut fields = vec![(
        "benches",
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    )];
    fields.extend(extra);
    let j = Json::obj(fields);
    let path = PathBuf::from(format!("reports/{file}.json"));
    write_json_atomic(&path, &j)?;
    println!("report written to {}", path.display());
    Ok(path)
}

/// Print a markdown-ish table row-aligned for paper-vs-measured comparisons.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate().take(ncol) {
            s.push_str(&format!("{:<width$} | ", c, width = widths[i]));
        }
        s
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let b = Bench::new(0, 3);
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.iters, 3);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
        assert!(r.std_s.is_finite() && r.std_s >= 0.0);
    }

    #[test]
    fn summary_uses_sample_variance_and_sample_seeded_extrema() {
        let r = summarize("fixed", 3, &[1.0, 2.0, 3.0]);
        assert_eq!(r.mean_s, 2.0);
        // n-1 estimator: var = ((1)^2 + 0 + (1)^2) / 2 = 1.0
        assert_eq!(r.std_s, 1.0);
        assert_eq!(r.min_s, 1.0);
        assert_eq!(r.max_s, 3.0);
        // a single sample has no spread estimate, not a 0/0 NaN
        let one = summarize("one", 1, &[0.25]);
        assert_eq!(one.std_s, 0.0);
        assert_eq!(one.max_s, 0.25);
    }

    #[test]
    fn atomic_json_write_is_whole_file_or_nothing() {
        let dir = std::env::temp_dir().join(format!("pict_bench_atomic_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("r.json");
        write_json_atomic(&path, &Json::obj(vec![("a", Json::Num(1.0))])).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(&first).unwrap().get("a").unwrap().as_f64(), Some(1.0));
        // overwrite goes through the same rename; the old document is fully
        // replaced and no temp file is left behind
        write_json_atomic(&path, &Json::obj(vec![("a", Json::Num(2.0))])).unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(&second).unwrap().get("a").unwrap().as_f64(), Some(2.0));
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1, "temp litter left behind");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
