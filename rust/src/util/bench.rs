//! Criterion-style micro/macro benchmark harness (criterion is not vendored).
//! Runs warmup + measured iterations, reports mean / stddev / min, and writes
//! a JSON report under `reports/` so EXPERIMENTS.md tables can be regenerated.

use super::json::Json;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("std_s", Json::Num(self.std_s)),
            ("min_s", Json::Num(self.min_s)),
            ("max_s", Json::Num(self.max_s)),
        ])
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, iters: 5 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Time `f` over the configured iterations and print a criterion-like line.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().cloned().fold(0.0, f64::max),
        };
        println!(
            "bench {:<44} mean {:>10.4} ms  (± {:>8.4} ms, min {:>10.4} ms, n={})",
            res.name,
            res.mean_s * 1e3,
            res.std_s * 1e3,
            res.min_s * 1e3,
            res.iters
        );
        res
    }
}

/// Write a collection of results (plus free-form extra fields) to
/// `reports/<file>.json`, creating the directory if needed.
pub fn write_report(file: &str, results: &[BenchResult], extra: Vec<(&str, Json)>) {
    let mut fields = vec![(
        "benches",
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    )];
    fields.extend(extra);
    let j = Json::obj(fields);
    let _ = std::fs::create_dir_all("reports");
    let path = format!("reports/{file}.json");
    if std::fs::write(&path, j.to_string_pretty()).is_ok() {
        println!("report written to {path}");
    }
}

/// Print a markdown-ish table row-aligned for paper-vs-measured comparisons.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate().take(ncol) {
            s.push_str(&format!("{:<width$} | ", c, width = widths[i]));
        }
        s
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let b = Bench::new(0, 3);
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.iters, 3);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
    }
}
