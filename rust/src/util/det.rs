//! Blessed deterministic reduction and narrowing helpers.
//!
//! The analyze pass (`cargo run -p xtask -- analyze`) forbids raw float
//! `.sum()` / float-seeded `fold` and lossy `as` casts in the kernel
//! modules (`sparse/`, `linsolve/`, `fvm/`, `adjoint/`): float addition is
//! not associative, so a reduction whose combine order is an
//! iterator-implementation detail can drift between builds, and a silent
//! narrowing cast truncates instead of failing. Kernel code routes those
//! operations through this module (or `ExecCtx::dot` for pooled
//! reductions), where the order is fixed — a serial left fold in index
//! order, the same order `std`'s `Iterator::sum` uses today but guaranteed
//! by contract here rather than by implementation accident.

/// Sum in index order (serial left fold). Deterministic by construction.
pub fn sum(v: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in v {
        acc += x;
    }
    acc
}

/// Sum `f(0) + f(1) + … + f(n-1)` in index order.
pub fn sum_by(n: usize, f: impl Fn(usize) -> f64) -> f64 {
    let mut acc = 0.0;
    for i in 0..n {
        acc += f(i);
    }
    acc
}

/// Mean in index order; 0 for an empty slice.
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    sum(v) / v.len() as f64
}

/// Euclidean norm with the same fixed summation order.
pub fn norm2(v: &[f64]) -> f64 {
    sum_by(v.len(), |i| v[i] * v[i]).sqrt()
}

/// Narrow an index to `u32`, debug-asserting the range instead of silently
/// truncating (CSR column indices are `u32`; a >4G-cell mesh must fail
/// loudly, not corrupt the structure).
#[inline]
pub fn index_u32(i: usize) -> u32 {
    debug_assert!(i <= u32::MAX as usize, "index {i} exceeds u32 range");
    i as u32
}

/// Round an `f64` to `f32` at the blessed mixed-precision boundary.
///
/// The analyze pass confines lossy `as f32` casts in the kernel modules to
/// the precision-boundary files (`sparse/csr32.rs`, `linsolve/refine.rs`);
/// mixed-precision code elsewhere (e.g. the f32 preconditioner applies in
/// `linsolve/precond.rs`) narrows through this helper so every rounding
/// site is named and auditable. Widening back is `f64::from`, which is
/// exact and needs no helper.
#[inline]
pub fn narrow_f32(x: f64) -> f32 {
    x as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_matches_manual_left_fold() {
        let v = [0.1, 0.2, 0.3, 1e16, -1e16, 0.4];
        let mut acc = 0.0;
        for &x in &v {
            acc += x;
        }
        // bit-for-bit, not approximately: the order is the contract
        assert_eq!(sum(&v), acc);
        assert_eq!(sum_by(v.len(), |i| v[i]), acc);
    }

    #[test]
    fn mean_of_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn norm2_simple() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn index_narrowing_roundtrips() {
        assert_eq!(index_u32(0), 0);
        assert_eq!(index_u32(u32::MAX as usize), u32::MAX);
    }
}
