//! f32-storage mirror of [`Csr`] for the mixed-precision Krylov hot path.
//!
//! The inner iterations of an iterative-refinement solve
//! ([`crate::linsolve::refine`]) only ever see a *correction* system whose
//! solution is re-validated against the true f64 residual each outer cycle,
//! so the matrix values can be stored in f32 — halving the memory traffic
//! that dominates SpMV — as long as every accumulation still runs in f64.
//! `Csr32` shares the symbolic structure (`row_ptr`, `col_idx`) with its
//! f64 source by cloning it once ([`Csr32::from_f64`]) and then refreshing
//! values only ([`Csr32::refresh`]) each time the stepper refills the f64
//! matrix, mirroring how the fixed-stencil [`Csr`] itself is assembled
//! once and refilled numerically per step.
//!
//! The SpMV inner loop is fixed-width-chunked (`LANES` f64 accumulators
//! combined in a fixed order, scalar remainder after) so the compiler can
//! auto-vectorize it on stable Rust — no nightly `std::simd` — while the
//! per-row result stays bit-for-bit identical regardless of thread count:
//! the pool's row partitioning ([`crate::par::ExecCtx::matvec32`]) hands
//! each worker whole rows, and each row is reduced in this one fixed order.

use crate::sparse::Csr;
use std::ops::Range;

/// Number of independent f64 accumulators in the chunked SpMV inner loop.
/// Stencil rows carry ~5–7 entries, so 4 lanes get one full chunk per row
/// plus a short remainder; wider would degrade every row to the remainder.
const LANES: usize = 4;

/// f32-valued CSR matrix sharing its symbolic structure with a [`Csr`].
#[derive(Clone, Debug)]
pub struct Csr32 {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr32 {
    /// Clone the symbolic structure of `a` and narrow its values to f32.
    ///
    /// The structure inherits `a`'s validated invariants (`col_idx < n`,
    /// monotone `row_ptr` with `row_ptr[n] == nnz`), which the unchecked
    /// kernels below rely on; callers must rewrite values only via
    /// [`Csr32::refresh`], never the symbolic part.
    pub fn from_f64(a: &Csr) -> Csr32 {
        Csr32 {
            n: a.n,
            row_ptr: a.row_ptr.clone(),
            col_idx: a.col_idx.clone(),
            vals: a.vals.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Values-only refresh from the f64 source: reuses the symbolic
    /// structure cloned at [`Csr32::from_f64`] time, so a stepper can keep
    /// one persistent mirror and renarrow after each numeric reassembly
    /// without reallocating. The source must be the same matrix (same
    /// structure) the mirror was built from.
    pub fn refresh(&mut self, a: &Csr) {
        assert_eq!(self.n, a.n, "Csr32::refresh: dimension changed since from_f64");
        assert_eq!(
            self.vals.len(),
            a.vals.len(),
            "Csr32::refresh: nnz changed since from_f64"
        );
        debug_assert_eq!(self.row_ptr, a.row_ptr);
        debug_assert_eq!(self.col_idx, a.col_idx);
        for (dst, src) in self.vals.iter_mut().zip(&a.vals) {
            *dst = *src as f32;
        }
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// y = A x with f32 storage and f64 accumulation. Serial entry point;
    /// the pooled path is [`crate::par::ExecCtx::matvec32`], which calls
    /// [`Csr32::matvec_rows`] per row-chunk so the per-row arithmetic — and
    /// therefore the result — is bit-for-bit the same at every width.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        self.matvec_rows(x, y, 0..self.n);
    }

    /// Row-range SpMV kernel: computes rows `rows` of `A x` into
    /// `y_chunk` (whose length is `rows.len()`). Each row accumulates in
    /// f64 across `LANES` fixed-order lanes and narrows once at the end.
    pub fn matvec_rows(&self, x: &[f32], y_chunk: &mut [f32], rows: Range<usize>) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y_chunk.len(), rows.len());
        assert!(rows.end <= self.n);
        let last = *self.row_ptr.last().expect("row_ptr has n+1 entries by construction");
        assert_eq!(last, self.col_idx.len());
        for (r, yr) in rows.zip(y_chunk.iter_mut()) {
            *yr = self.row_dot(x, r) as f32;
        }
    }

    /// f64 dot product of row `r` with `x`: `LANES` independent
    /// accumulators over fixed-width chunks (auto-vectorizable on stable),
    /// combined in a fixed order, then a scalar remainder — one canonical
    /// reduction order per row, independent of partitioning.
    #[inline]
    fn row_dot(&self, x: &[f32], r: usize) -> f64 {
        // SAFETY: row_ptr is monotone with last == nnz (asserted by every
        // caller) and col_idx entries are < n — invariants established by
        // the f64 constructors, inherited verbatim by from_f64, and
        // preserved by refresh (values-only). x.len() == n is asserted by
        // the callers before any row is touched.
        unsafe {
            let lo = *self.row_ptr.get_unchecked(r);
            let hi = *self.row_ptr.get_unchecked(r + 1);
            let vals = self.vals.get_unchecked(lo..hi);
            let cols = self.col_idx.get_unchecked(lo..hi);
            let n_full = vals.len() / LANES * LANES;
            let mut lanes = [0.0f64; LANES];
            let mut k = 0;
            while k < n_full {
                for (l, lane) in lanes.iter_mut().enumerate() {
                    *lane += f64::from(*vals.get_unchecked(k + l))
                        * f64::from(*x.get_unchecked(*cols.get_unchecked(k + l) as usize));
                }
                k += LANES;
            }
            let mut acc = 0.0;
            for &lane in &lanes {
                acc += lane;
            }
            for k in n_full..vals.len() {
                acc += f64::from(*vals.get_unchecked(k))
                    * f64::from(*x.get_unchecked(*cols.get_unchecked(k) as usize));
            }
            acc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Csr {
        // a 4x4 with mixed row lengths so both the lane chunk and the
        // scalar remainder paths run
        Csr::from_triplets(
            4,
            &[
                (0, 0, 2.0),
                (0, 1, 1.0),
                (0, 2, -0.5),
                (0, 3, 0.25),
                (1, 1, 3.0),
                (2, 0, 4.0),
                (2, 2, 5.0),
                (2, 3, -1.0),
                (3, 0, 0.125),
                (3, 1, -2.0),
                (3, 2, 1.5),
                (3, 3, 6.0),
            ],
        )
    }

    #[test]
    fn matvec_matches_f64_within_rounding() {
        let a = example();
        let a32 = Csr32::from_f64(&a);
        let x = [1.0, 2.0, 3.0, -1.0];
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut y = [0.0; 4];
        let mut y32 = vec![0.0f32; 4];
        a.matvec(&x, &mut y);
        a32.matvec(&x32, &mut y32);
        for r in 0..4 {
            // all values here are exactly representable in f32, so the
            // f64-accumulated mixed result is exact too
            assert_eq!(f64::from(y32[r]), y[r], "row {r}");
        }
    }

    #[test]
    fn refresh_equals_from_f64_after_value_updates() {
        let mut a = example();
        let mut mirror = Csr32::from_f64(&a);
        for (k, v) in a.vals.iter_mut().enumerate() {
            *v = 0.1 * (k as f64 + 1.0) - 0.7;
        }
        mirror.refresh(&a);
        let fresh = Csr32::from_f64(&a);
        assert_eq!(mirror.vals, fresh.vals);
        assert_eq!(mirror.row_ptr, fresh.row_ptr);
        assert_eq!(mirror.col_idx, fresh.col_idx);
    }

    #[test]
    #[should_panic(expected = "nnz changed")]
    fn refresh_rejects_structure_change() {
        let a = example();
        let mut mirror = Csr32::from_f64(&a);
        let other = Csr::from_triplets(4, &[(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0), (3, 3, 1.0)]);
        mirror.refresh(&other);
    }

    #[test]
    fn matvec_rows_matches_full_matvec() {
        let a32 = Csr32::from_f64(&example());
        let x32 = [0.5f32, -1.5, 2.0, 0.75];
        let mut full = vec![0.0f32; 4];
        a32.matvec(&x32, &mut full);
        let mut lo = vec![0.0f32; 2];
        let mut hi = vec![0.0f32; 2];
        a32.matvec_rows(&x32, &mut lo, 0..2);
        a32.matvec_rows(&x32, &mut hi, 2..4);
        assert_eq!(&full[..2], &lo[..]);
        assert_eq!(&full[2..], &hi[..]);
    }

    #[test]
    fn miri_unchecked_matvec32_stays_in_bounds() {
        // Fast Miri target for the get_unchecked lane loop: every index the
        // unsafe block touches is validated by the f64 constructors whose
        // structure from_f64 inherits, and the result must match a fully
        // checked dense multiply accumulated the same way.
        let a = example();
        let a32 = Csr32::from_f64(&a);
        let x32 = [0.5f32, -1.5, 2.0, 1.0];
        let mut y32 = vec![0.0f32; 4];
        a32.matvec(&x32, &mut y32);
        let dense = a.to_dense();
        for r in 0..4 {
            let mut want = 0.0f64;
            for c in 0..4 {
                want += dense[r][c] * f64::from(x32[c]);
            }
            assert!(
                (f64::from(y32[r]) - want).abs() < 1e-6 * (1.0 + want.abs()),
                "row {r}: {} vs {want}",
                y32[r]
            );
        }
    }
}
