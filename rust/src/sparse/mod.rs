//! Sparse matrix substrate: CSR storage with a fixed symbolic structure that
//! is assembled once per mesh and refilled numerically every PISO step (the
//! paper's cuSparse matrices play the same role). Also provides the
//! transpose-apply needed by the OtD linear-solve adjoints.

pub mod csr;

pub use csr::Csr;
