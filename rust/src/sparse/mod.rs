//! Sparse matrix substrate: CSR storage with a fixed symbolic structure that
//! is assembled once per mesh and refilled numerically every PISO step (the
//! paper's cuSparse matrices play the same role). Also provides the
//! transpose-apply needed by the OtD linear-solve adjoints.

pub mod csr;
pub mod csr32;

pub use csr::Csr;
pub use csr32::Csr32;
