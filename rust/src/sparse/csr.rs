//! Compressed sparse row matrix.
//!
//! PICT's matrices have a fixed stencil structure determined by the mesh
//! (cell + face neighbors), so the symbolic part (`row_ptr`, `col_idx`) is
//! built once and the values are rewritten each step. Rows are kept sorted
//! by column which ILU(0) relies on.

use crate::util::det;

#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Build from triplets (duplicates are summed). O(nnz log nnz).
    ///
    /// Validates every index up front: the `unsafe` fast path in
    /// [`Csr::matvec`] elides bounds checks on the invariant that
    /// `col_idx < n` and `row_ptr` is monotone with `row_ptr[n] == nnz`,
    /// so every constructor asserts it. The fields are `pub` for the
    /// assembly/adjoint hot paths, which rewrite `vals` in place; callers
    /// must not mutate the symbolic part (`row_ptr`, `col_idx`) — doing so
    /// voids the invariant the unchecked kernels rely on.
    pub fn from_triplets(n: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        assert!(n <= u32::MAX as usize, "matrix dim {n} exceeds u32 column index range");
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for &(r, c, v) in triplets {
            assert!(
                r < n && c < n,
                "triplet ({r},{c}) out of bounds for {n}x{n} matrix"
            );
            per_row[r].push((c, v));
        }
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_by_key(|e| e.0);
            let mut i = 0;
            while i < row.len() {
                let c = row[i].0;
                let mut v = row[i].1;
                let mut j = i + 1;
                while j < row.len() && row[j].0 == c {
                    v += row[j].1;
                    j += 1;
                }
                col_idx.push(det::index_u32(c));
                vals.push(v);
                i = j;
            }
            row_ptr.push(col_idx.len());
        }
        Csr { n, row_ptr, col_idx, vals }
    }

    /// Symbolic-only construction: same structure, zero values. Column
    /// indices are validated against `n` (see [`Csr::from_triplets`]).
    pub fn structure_from_columns(columns: &[Vec<usize>]) -> Csr {
        let n = columns.len();
        assert!(n <= u32::MAX as usize, "matrix dim {n} exceeds u32 column index range");
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0);
        for (r, cols) in columns.iter().enumerate() {
            // ALLOC: symbolic construction runs once per mesh, not per step —
            // the scratch copy here is setup cost, not a solver hot path
            let mut sorted = cols.clone();
            sorted.sort_unstable();
            sorted.dedup();
            for c in sorted {
                assert!(c < n, "column {c} in row {r} out of bounds for {n}x{n} structure");
                col_idx.push(det::index_u32(c));
            }
            row_ptr.push(col_idx.len());
        }
        let nnz = col_idx.len();
        Csr { n, row_ptr, col_idx, vals: vec![0.0; nnz] }
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Index of entry (r, c) in `vals`, if present. Binary search in the row.
    #[inline]
    pub fn find(&self, r: usize, c: usize) -> Option<usize> {
        let lo = self.row_ptr[r];
        let hi = self.row_ptr[r + 1];
        let row = &self.col_idx[lo..hi];
        row.binary_search(&det::index_u32(c)).ok().map(|k| lo + k)
    }

    /// Add `v` to entry (r, c); panics if the entry is not in the structure.
    #[inline]
    pub fn add(&mut self, r: usize, c: usize, v: f64) {
        let k = self
            .find(r, c)
            .unwrap_or_else(|| panic!("entry ({r},{c}) not in CSR structure"));
        self.vals[k] += v;
    }

    pub fn zero_values(&mut self) {
        self.vals.iter_mut().for_each(|v| *v = 0.0);
    }

    /// y = A x — the innermost hot loop of every Krylov iteration (§Perf:
    /// bounds checks removed after validation; ~20 % faster on the PISO
    /// pressure solve which dominates step time).
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        let last = *self.row_ptr.last().expect("row_ptr has n+1 entries by construction");
        assert_eq!(last, self.col_idx.len());
        for r in 0..self.n {
            let mut acc = 0.0;
            // SAFETY: row_ptr is monotone with last == nnz (asserted above)
            // and col_idx entries are < n — validated by every constructor
            // (`from_triplets` / `structure_from_columns` assert each index)
            // and relied on here under the documented contract that callers
            // rewrite only `vals`, never the symbolic part.
            unsafe {
                let lo = *self.row_ptr.get_unchecked(r);
                let hi = *self.row_ptr.get_unchecked(r + 1);
                for k in lo..hi {
                    acc += self.vals.get_unchecked(k)
                        * x.get_unchecked(*self.col_idx.get_unchecked(k) as usize);
                }
            }
            y[r] = acc;
        }
    }

    /// y = Aᵀ x (used by the adjoint linear solves).
    pub fn matvec_transpose(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), self.n);
        y.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..self.n {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                y[self.col_idx[k] as usize] += self.vals[k] * xr;
            }
        }
    }

    /// Extract the diagonal.
    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.n)
            .map(|r| self.find(r, r).map(|k| self.vals[k]).unwrap_or(0.0))
            .collect()
    }

    /// Explicit transpose with identical value layout semantics.
    pub fn transpose(&self) -> Csr {
        let mut triplets = Vec::with_capacity(self.nnz());
        for r in 0..self.n {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                triplets.push((self.col_idx[k] as usize, r, self.vals[k]));
            }
        }
        Csr::from_triplets(self.n, &triplets)
    }

    /// Residual ||b - A x||₂.
    pub fn residual_norm(&self, x: &[f64], b: &[f64]) -> f64 {
        let mut ax = vec![0.0; self.n];
        self.matvec(x, &mut ax);
        det::sum_by(self.n, |i| (b[i] - ax[i]) * (b[i] - ax[i])).sqrt()
    }

    /// Dense representation (tests only; O(n²) memory).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n]; self.n];
        for r in 0..self.n {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                d[r][self.col_idx[k] as usize] = self.vals[k];
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    fn example() -> Csr {
        // [2 1 0]
        // [0 3 0]
        // [4 0 5]
        Csr::from_triplets(
            3,
            &[(0, 0, 2.0), (0, 1, 1.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)],
        )
    }

    #[test]
    fn matvec_matches_dense() {
        let a = example();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        a.matvec(&x, &mut y);
        assert_eq!(y, [4.0, 6.0, 19.0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let a = Csr::from_triplets(2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 1.0)]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.vals[0], 3.0);
    }

    #[test]
    fn transpose_apply_matches_explicit_transpose() {
        let a = example();
        let at = a.transpose();
        let x = [0.5, -1.0, 2.0];
        let mut y1 = [0.0; 3];
        let mut y2 = [0.0; 3];
        a.matvec_transpose(&x, &mut y1);
        at.matvec(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn diagonal_extraction() {
        assert_eq!(example().diagonal(), vec![2.0, 3.0, 5.0]);
    }

    #[test]
    fn miri_unchecked_matvec_stays_in_bounds() {
        // Fast Miri target for the get_unchecked hot loop: every index the
        // unsafe block touches is validated by the constructors, and the
        // result must equal a fully checked dense multiply.
        let a = example();
        let x = [0.5, -1.5, 2.0];
        let mut y = [0.0; 3];
        a.matvec(&x, &mut y);
        let dense = a.to_dense();
        for r in 0..3 {
            let want: f64 = (0..3).map(|c| dense[r][c] * x[c]).sum();
            assert!((y[r] - want).abs() < 1e-15, "{} vs {want}", y[r]);
        }
    }

    #[test]
    fn structure_and_add() {
        let mut a = Csr::structure_from_columns(&[vec![0, 1], vec![1], vec![2, 0]]);
        a.add(0, 1, 7.0);
        a.add(2, 0, -1.0);
        assert_eq!(a.find(0, 2), None);
        assert_eq!(a.vals[a.find(0, 1).unwrap()], 7.0);
        assert_eq!(a.vals[a.find(2, 0).unwrap()], -1.0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_triplets_rejects_out_of_range_column() {
        Csr::from_triplets(3, &[(0, 0, 1.0), (1, 3, 2.0)]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_triplets_rejects_out_of_range_row() {
        Csr::from_triplets(2, &[(2, 0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn structure_rejects_out_of_range_column() {
        Csr::structure_from_columns(&[vec![0, 1], vec![5]]);
    }

    #[test]
    fn prop_transpose_transpose_is_identity() {
        Prop::new(16, 0xABCD).check("tt_id", |rng, _| {
            let n = 2 + rng.below(8);
            let mut trip = Vec::new();
            for r in 0..n {
                for c in 0..n {
                    if rng.uniform() < 0.4 {
                        trip.push((r, c, rng.normal()));
                    }
                }
                trip.push((r, r, 1.0 + rng.uniform()));
            }
            let a = Csr::from_triplets(n, &trip);
            let att = a.transpose().transpose();
            if a.to_dense() != att.to_dense() {
                return Err("(Aᵀ)ᵀ != A".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_matvec_linearity() {
        Prop::new(16, 0xBEEF).check("linearity", |rng, _| {
            let n = 2 + rng.below(10);
            let mut trip = Vec::new();
            for r in 0..n {
                for c in 0..n {
                    if rng.uniform() < 0.3 {
                        trip.push((r, c, rng.normal()));
                    }
                }
            }
            let a = Csr::from_triplets(n, &trip);
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let alpha = rng.normal();
            let mut ax = vec![0.0; n];
            let mut ay = vec![0.0; n];
            let mut axy = vec![0.0; n];
            a.matvec(&x, &mut ax);
            a.matvec(&y, &mut ay);
            let xy: Vec<f64> = x.iter().zip(&y).map(|(u, v)| alpha * u + v).collect();
            a.matvec(&xy, &mut axy);
            for i in 0..n {
                let expect = alpha * ax[i] + ay[i];
                if (axy[i] - expect).abs() > 1e-10 * (1.0 + expect.abs()) {
                    return Err(format!("row {i}: {} vs {}", axy[i], expect));
                }
            }
            Ok(())
        });
    }
}
