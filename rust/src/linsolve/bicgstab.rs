//! Preconditioned BiCGStab for the nonsymmetric advection–diffusion system
//! (paper Appendix A.6: BiCGStab + optional ILU(0), enabled case-by-case for
//! strongly graded meshes).

use super::cg::remove_mean;
use super::precond::Preconditioner;
use super::{debug_check_finite, SolveOpts, SolveStats};
use crate::par::ExecCtx;
use crate::sparse::Csr;

/// Solve A x = b (or Aᵀ x = b) with right-preconditioned BiCGStab.
/// `x` holds the initial guess on entry and the solution on exit. Every
/// kernel (SpMV, BLAS-1, preconditioner apply) runs pool-resident on `ctx`.
/// `project_nullspace` deflates the constant vector exactly as `cg` does
/// (mean-free RHS, iterates, and matvec outputs), so all-Neumann pressure
/// systems can be driven through either solver without special-casing.
pub fn bicgstab(
    ctx: &ExecCtx,
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    opts: SolveOpts,
) -> SolveStats {
    let n = a.n;
    // SpMV goes through the worker pool: row-partitioned gather for A x
    // (bit-for-bit equal to serial), scatter-reduce for Aᵀ x.
    let apply = |v: &[f64], out: &mut [f64]| {
        if opts.transpose {
            ctx.matvec_transpose(a, v, out)
        } else {
            ctx.matvec(a, v, out)
        }
    };
    let dot = |a: &[f64], b: &[f64]| ctx.dot(a, b);
    let norm2 = |a: &[f64]| ctx.norm2(a);
    let axpy = |alpha: f64, x: &[f64], y: &mut [f64]| ctx.axpy(alpha, x, y);

    let mut b = b.to_vec();
    if project_nullspace {
        remove_mean(&mut b);
        remove_mean(x);
    }

    let mut r = vec![0.0; n];
    apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    if project_nullspace {
        remove_mean(&mut r);
    }
    let r0 = r.clone();
    let bnorm = norm2(&b).max(1e-300);
    let mut res = norm2(&r) / bnorm;
    debug_check_finite("bicgstab", "rhs b", 0, res, &b);
    debug_check_finite("bicgstab", "residual r", 0, res, &r);
    if res < opts.tol {
        return SolveStats { iterations: 0, residual: res, converged: true };
    }

    let mut rho = 1.0;
    let mut alpha = 1.0;
    let mut omega = 1.0;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut phat = vec![0.0; n];
    let mut shat = vec![0.0; n];
    let mut t = vec![0.0; n];

    for it in 1..=opts.max_iter {
        let rho_new = dot(&r0, &r);
        if rho_new.abs() < 1e-300 {
            return SolveStats { iterations: it, residual: res, converged: false };
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        precond.apply(ctx, &p, &mut phat);
        apply(&phat, &mut v);
        if project_nullspace {
            remove_mean(&mut v);
        }
        let r0v = dot(&r0, &v);
        if r0v.abs() < 1e-300 {
            return SolveStats { iterations: it, residual: res, converged: false };
        }
        alpha = rho / r0v;
        // s = r - alpha v   (reuse r)
        axpy(-alpha, &v, &mut r);
        res = norm2(&r) / bnorm;
        debug_check_finite("bicgstab", "intermediate residual s", it, res, &r);
        if res < opts.tol {
            axpy(alpha, &phat, x);
            if project_nullspace {
                remove_mean(x);
            }
            return SolveStats { iterations: it, residual: res, converged: true };
        }
        precond.apply(ctx, &r, &mut shat);
        apply(&shat, &mut t);
        if project_nullspace {
            remove_mean(&mut t);
        }
        let tt = dot(&t, &t);
        if tt.abs() < 1e-300 {
            axpy(alpha, &phat, x);
            return SolveStats { iterations: it, residual: res, converged: false };
        }
        omega = dot(&t, &r) / tt;
        axpy(alpha, &phat, x);
        axpy(omega, &shat, x);
        axpy(-omega, &t, &mut r);
        res = norm2(&r) / bnorm;
        debug_check_finite("bicgstab", "residual r", it, res, &r);
        if res < opts.tol {
            if project_nullspace {
                remove_mean(x);
            }
            return SolveStats { iterations: it, residual: res, converged: true };
        }
        if omega.abs() < 1e-300 {
            return SolveStats { iterations: it, residual: res, converged: false };
        }
    }
    SolveStats { iterations: opts.max_iter, residual: res, converged: false }
}

#[cfg(test)]
mod tests {
    use super::super::precond::{Identity, Ilu0, Jacobi};
    use super::super::testmat::random_dd;
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn solves_nonsymmetric_dd() {
        let mut rng = crate::util::rng::Rng::new(5);
        let a = random_dd(60, &mut rng);
        let xs = rng.normal_vec(60);
        let mut b = vec![0.0; 60];
        a.matvec(&xs, &mut b);
        let mut x = vec![0.0; 60];
        let st =
            bicgstab(&ExecCtx::serial(), &a, &b, &mut x, &Identity, false, SolveOpts::default());
        assert!(st.converged);
        for (u, v) in x.iter().zip(&xs) {
            assert!((u - v).abs() < 1e-6, "{u} vs {v}");
        }
    }

    #[test]
    fn transpose_mode_solves_at() {
        let mut rng = crate::util::rng::Rng::new(6);
        let a = random_dd(40, &mut rng);
        let xs = rng.normal_vec(40);
        let at = a.transpose();
        let mut b = vec![0.0; 40];
        at.matvec(&xs, &mut b);
        let mut x = vec![0.0; 40];
        let st = bicgstab(
            &ExecCtx::serial(),
            &a,
            &b,
            &mut x,
            &Identity,
            false,
            SolveOpts { transpose: true, ..Default::default() },
        );
        assert!(st.converged);
        for (u, v) in x.iter().zip(&xs) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn nullspace_projection_handles_singular_system() {
        // periodic Laplacian: singular, constant nullspace — the same
        // deflation cg applies must let BiCGStab solve it too
        let n = 32;
        let mut trip = Vec::new();
        for i in 0..n {
            trip.push((i, i, 2.0));
            trip.push((i, (i + 1) % n, -1.0));
            trip.push((i, (i + n - 1) % n, -1.0));
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        // consistent RHS (mean zero)
        let mut b: Vec<f64> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * i as f64 / n as f64).sin())
            .collect();
        let mean = b.iter().sum::<f64>() / n as f64;
        b.iter_mut().for_each(|v| *v -= mean);
        let mut x = vec![0.0; n];
        let st =
            bicgstab(&ExecCtx::serial(), &a, &b, &mut x, &Identity, true, SolveOpts::default());
        assert!(st.converged, "residual {}", st.residual);
        assert!(a.residual_norm(&x, &b) < 1e-8);
        // solution is mean-free
        assert!(x.iter().sum::<f64>().abs() / (n as f64) < 1e-10);
    }

    #[test]
    fn ilu0_accelerates_hard_system() {
        // advection-diffusion-like: strong asymmetry + bad scaling
        let n = 200;
        let mut trip = Vec::new();
        for i in 0..n {
            let h = 1.0 + 20.0 * ((i % 13) as f64 / 13.0);
            trip.push((i, i, 2.0 * h + 1.0));
            if i > 0 {
                trip.push((i, i - 1, -1.5 * h));
            }
            if i + 1 < n {
                trip.push((i, i + 1, -0.5 * h));
            }
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        let b: Vec<f64> = (0..n).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let mut x1 = vec![0.0; n];
        let mut x2 = vec![0.0; n];
        let ctx = ExecCtx::serial();
        let st_j = bicgstab(&ctx, &a, &b, &mut x1, &Jacobi::new(&a), false, SolveOpts::default());
        let st_ilu = bicgstab(&ctx, &a, &b, &mut x2, &Ilu0::new(&a), false, SolveOpts::default());
        assert!(st_ilu.converged);
        assert!(
            st_ilu.iterations <= st_j.iterations,
            "ilu {} vs jacobi {}",
            st_ilu.iterations,
            st_j.iterations
        );
        assert!(a.residual_norm(&x2, &b) < 1e-6);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite")]
    fn debug_guard_trips_on_poisoned_rhs() {
        let mut rng = crate::util::rng::Rng::new(9);
        let a = random_dd(12, &mut rng);
        let mut b = rng.normal_vec(12);
        b[7] = f64::INFINITY;
        let mut x = vec![0.0; 12];
        bicgstab(&ExecCtx::serial(), &a, &b, &mut x, &Identity, false, SolveOpts::default());
    }

    #[test]
    fn prop_bicgstab_random_dd() {
        Prop::new(12, 0xB1C6).check("bicgstab_dd", |rng, _| {
            let n = 5 + rng.below(60);
            let a = random_dd(n, rng);
            let xs = rng.normal_vec(n);
            let mut b = vec![0.0; n];
            a.matvec(&xs, &mut b);
            let mut x = vec![0.0; n];
            let ctx = ExecCtx::serial();
            let st = bicgstab(&ctx, &a, &b, &mut x, &Jacobi::new(&a), false, SolveOpts::default());
            if !st.converged {
                return Err(format!("n={n} res={}", st.residual));
            }
            let res = a.residual_norm(&x, &b);
            if res > 1e-6 * (1.0 + b.iter().map(|v| v * v).sum::<f64>().sqrt()) {
                return Err(format!("residual {res}"));
            }
            Ok(())
        });
    }
}
