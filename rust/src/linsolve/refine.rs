//! Mixed-precision iterative refinement around the Krylov solvers.
//!
//! The classic scheme (Wilkinson refinement, here with Krylov inner
//! solves): keep the iterate `x` and the true residual `r = b - A x` in
//! f64, but solve each *correction* system `A d = r` in f32 storage over a
//! [`Csr32`] mirror — SpMV and BLAS-1 memory traffic halve, which is where
//! PISO's pressure/advection solves spend their time. Every accumulation
//! inside the f32 inner solve still runs in f64
//! ([`ExecCtx::matvec32`]/[`ExecCtx::dot32`]), the correction is rescaled
//! to unit norm so f32's exponent range never clips it, and each outer
//! cycle re-checks the true f64 residual against [`SolveOpts::tol`].
//!
//! Convergence is guaranteed on the same terms as a pure f64 solve: if the
//! outer loop stagnates (f32 inner solves stop reducing the true residual)
//! the driver falls back to the corresponding f64 solver with the full
//! iteration budget, warm-started from the current iterate. Adjoint solves
//! (`opts.transpose`) route straight to f64 — gradcheck tolerances are
//! untouched by the precision knob.
//!
//! Determinism: the inner kernels reuse the pool's deterministic row/chunk
//! partitioning, so a mixed solve is bit-for-bit reproducible per
//! (thread-width, precision) config — the contract tested at
//! `PICT_THREADS=1/4` in `tests/mixed.rs`.
//!
//! This file (with `sparse/csr32.rs`) is the blessed precision boundary:
//! the only non-test code in `sparse/`/`linsolve/` where the analyze pass
//! permits f32↔f64 `as` casts.

use super::cg::remove_mean;
use super::precond::Preconditioner;
use super::{bicgstab, cg, Precision, SolveOpts, SolveStats};
use crate::par::ExecCtx;
use crate::sparse::{Csr, Csr32};

/// Relative residual reduction each f32 inner solve is asked for. Much
/// below ~1e-5 the f32 storage cannot resolve further progress; 1e-4 keeps
/// inner iteration counts low and lets the outer loop do the tightening.
const INNER_TOL: f64 = 1e-4;
/// Outer refinement cycles before the f64 fallback takes over regardless.
const MAX_OUTER: usize = 40;
/// Minimum per-cycle reduction of the true residual; a cycle achieving
/// less counts as stagnant (f32 floor reached, or the system is too ill
/// conditioned for single-precision corrections).
const MIN_REDUCTION: f64 = 0.5;
/// Consecutive stagnant cycles tolerated before falling back to f64.
const MAX_STAGNANT: usize = 2;

/// Mixed-precision CG: f32-storage inner CG over `a32` wrapped in f64
/// iterative refinement on `a`. Same contract as [`cg`] (including
/// `project_nullspace` deflation); `a32` must be the current-values mirror
/// of `a` (see [`Csr32::refresh`]).
#[allow(clippy::too_many_arguments)]
pub fn refined_cg(
    ctx: &ExecCtx,
    a: &Csr,
    a32: &Csr32,
    b: &[f64],
    x: &mut [f64],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    opts: SolveOpts,
) -> SolveStats {
    refined(ctx, a, a32, b, x, precond, project_nullspace, opts, true)
}

/// Mixed-precision BiCGStab: f32-storage inner BiCGStab over `a32` wrapped
/// in f64 iterative refinement on `a`. Same contract as [`bicgstab`].
#[allow(clippy::too_many_arguments)]
pub fn refined_bicgstab(
    ctx: &ExecCtx,
    a: &Csr,
    a32: &Csr32,
    b: &[f64],
    x: &mut [f64],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    opts: SolveOpts,
) -> SolveStats {
    refined(ctx, a, a32, b, x, precond, project_nullspace, opts, false)
}

/// r = b - A x in f64, mean-deflated if requested; returns ‖r‖₂ / bnorm.
fn true_residual(
    ctx: &ExecCtx,
    a: &Csr,
    b: &[f64],
    x: &[f64],
    r: &mut [f64],
    project_nullspace: bool,
    bnorm: f64,
) -> f64 {
    ctx.matvec(a, x, r);
    for (ri, bi) in r.iter_mut().zip(b) {
        *ri = bi - *ri;
    }
    if project_nullspace {
        remove_mean(r);
    }
    ctx.norm2(r) / bnorm
}

#[allow(clippy::too_many_arguments)]
fn refined(
    ctx: &ExecCtx,
    a: &Csr,
    a32: &Csr32,
    b: &[f64],
    x: &mut [f64],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    opts: SolveOpts,
    use_cg: bool,
) -> SolveStats {
    // adjoint solves stay f64 by contract (see module docs)
    if opts.transpose {
        return if use_cg {
            cg(ctx, a, b, x, precond, project_nullspace, opts)
        } else {
            bicgstab(ctx, a, b, x, precond, project_nullspace, opts)
        };
    }
    let n = a.n;
    assert_eq!(a32.n, n, "refine: Csr32 mirror dimension must match the f64 matrix");
    assert_eq!(a32.nnz(), a.nnz(), "refine: Csr32 mirror structure must match the f64 matrix");

    let mut b = b.to_vec();
    if project_nullspace {
        remove_mean(&mut b);
        remove_mean(x);
    }
    let bnorm = ctx.norm2(&b).max(1e-300);
    let mut r = vec![0.0; n];
    let mut res = true_residual(ctx, a, &b, x, &mut r, project_nullspace, bnorm);
    if res < opts.tol {
        return SolveStats { iterations: 0, residual: res, converged: true };
    }

    let mut r32 = vec![0.0f32; n];
    let mut d32 = vec![0.0f32; n];
    let mut total_iters = 0usize;
    let mut stagnant = 0usize;
    for _outer in 0..MAX_OUTER {
        // rescale the correction system to unit RHS norm so the f32 inner
        // solve works at full mantissa, independent of how small the true
        // residual has become
        let rnorm = ctx.norm2(&r).max(1e-300);
        for (ri32, ri) in r32.iter_mut().zip(&r) {
            *ri32 = (ri / rnorm) as f32;
        }
        d32.iter_mut().for_each(|v| *v = 0.0);
        let inner_budget = opts.max_iter.saturating_sub(total_iters).max(1);
        let inner_iters = if use_cg {
            cg32(ctx, a32, &r32, &mut d32, precond, project_nullspace, INNER_TOL, inner_budget)
        } else {
            bicgstab32(
                ctx,
                a32,
                &r32,
                &mut d32,
                precond,
                project_nullspace,
                INNER_TOL,
                inner_budget,
            )
        };
        total_iters += inner_iters.max(1);
        for (xi, di) in x.iter_mut().zip(&d32) {
            *xi += rnorm * f64::from(*di);
        }
        if project_nullspace {
            remove_mean(x);
        }
        let new_res = true_residual(ctx, a, &b, x, &mut r, project_nullspace, bnorm);
        if new_res < opts.tol {
            return SolveStats { iterations: total_iters, residual: new_res, converged: true };
        }
        stagnant = if new_res > MIN_REDUCTION * res { stagnant + 1 } else { 0 };
        res = new_res;
        if stagnant >= MAX_STAGNANT || total_iters >= opts.max_iter {
            break;
        }
    }

    // f64 fallback with the full budget, warm-started from the refined
    // iterate: mixed precision may only ever add iterations, never lose
    // the f64 solver's convergence guarantee.
    let opts64 = SolveOpts { precision: Precision::F64, ..opts };
    let st = if use_cg {
        cg(ctx, a, &b, x, precond, project_nullspace, opts64)
    } else {
        bicgstab(ctx, a, &b, x, precond, project_nullspace, opts64)
    };
    SolveStats {
        iterations: total_iters + st.iterations,
        residual: st.residual,
        converged: st.converged,
    }
}

/// Deflate the constant nullspace component in f32 storage (f64-accumulated
/// mean, elementwise subtraction — deterministic at any width).
fn remove_mean32(v: &mut [f32]) {
    if v.is_empty() {
        return;
    }
    let mut acc = 0.0f64;
    for &x in v.iter() {
        acc += f64::from(x);
    }
    let mean = acc / v.len() as f64;
    for x in v.iter_mut() {
        *x = (f64::from(*x) - mean) as f32;
    }
}

/// f32-storage preconditioned CG (scalars and reductions in f64); returns
/// the iteration count. Structure mirrors [`cg`] exactly — see there for
/// the algorithmic comments.
#[allow(clippy::too_many_arguments)]
fn cg32(
    ctx: &ExecCtx,
    a: &Csr32,
    b: &[f32],
    x: &mut [f32],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    tol: f64,
    max_iter: usize,
) -> usize {
    let n = a.n;
    let mut b = b.to_vec();
    if project_nullspace {
        remove_mean32(&mut b);
        remove_mean32(x);
    }
    let mut r = vec![0.0f32; n];
    ctx.matvec32(a, x, &mut r);
    for (ri, bi) in r.iter_mut().zip(&b) {
        *ri = bi - *ri;
    }
    if project_nullspace {
        remove_mean32(&mut r);
    }
    let bnorm = ctx.norm2_32(&b).max(1e-300);
    let mut z = vec![0.0f32; n];
    precond.apply32(ctx, &r, &mut z);
    let mut p = z.clone();
    let mut rz = ctx.dot32(&r, &z);
    let mut ap = vec![0.0f32; n];
    let mut res = ctx.norm2_32(&r) / bnorm;
    if res < tol {
        return 0;
    }
    for it in 1..=max_iter {
        ctx.matvec32(a, &p, &mut ap);
        if project_nullspace {
            remove_mean32(&mut ap);
        }
        let pap = ctx.dot32(&p, &ap);
        if pap.abs() < 1e-300 {
            return it;
        }
        let alpha = rz / pap;
        ctx.axpy32(alpha, &p, x);
        ctx.axpy32(-alpha, &ap, &mut r);
        res = ctx.norm2_32(&r) / bnorm;
        if res < tol {
            if project_nullspace {
                remove_mean32(x);
            }
            return it;
        }
        precond.apply32(ctx, &r, &mut z);
        let rz_new = ctx.dot32(&r, &z);
        if rz.abs() < 1e-300 {
            return it;
        }
        let beta = rz_new / rz;
        rz = rz_new;
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = (f64::from(*zi) + beta * f64::from(*pi)) as f32;
        }
    }
    max_iter
}

/// f32-storage right-preconditioned BiCGStab (scalars and reductions in
/// f64); returns the iteration count. Structure mirrors [`bicgstab`].
#[allow(clippy::too_many_arguments)]
fn bicgstab32(
    ctx: &ExecCtx,
    a: &Csr32,
    b: &[f32],
    x: &mut [f32],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    tol: f64,
    max_iter: usize,
) -> usize {
    let n = a.n;
    let mut b = b.to_vec();
    if project_nullspace {
        remove_mean32(&mut b);
        remove_mean32(x);
    }
    let mut r = vec![0.0f32; n];
    ctx.matvec32(a, x, &mut r);
    for (ri, bi) in r.iter_mut().zip(&b) {
        *ri = bi - *ri;
    }
    if project_nullspace {
        remove_mean32(&mut r);
    }
    let r0 = r.clone();
    let bnorm = ctx.norm2_32(&b).max(1e-300);
    let mut res = ctx.norm2_32(&r) / bnorm;
    if res < tol {
        return 0;
    }
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![0.0f32; n];
    let mut p = vec![0.0f32; n];
    let mut phat = vec![0.0f32; n];
    let mut shat = vec![0.0f32; n];
    let mut t = vec![0.0f32; n];
    for it in 1..=max_iter {
        let rho_new = ctx.dot32(&r0, &r);
        if rho_new.abs() < 1e-300 {
            return it;
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..n {
            p[i] = (f64::from(r[i]) + beta * (f64::from(p[i]) - omega * f64::from(v[i]))) as f32;
        }
        precond.apply32(ctx, &p, &mut phat);
        ctx.matvec32(a, &phat, &mut v);
        if project_nullspace {
            remove_mean32(&mut v);
        }
        let r0v = ctx.dot32(&r0, &v);
        if r0v.abs() < 1e-300 {
            return it;
        }
        alpha = rho / r0v;
        ctx.axpy32(-alpha, &v, &mut r);
        res = ctx.norm2_32(&r) / bnorm;
        if res < tol {
            ctx.axpy32(alpha, &phat, x);
            if project_nullspace {
                remove_mean32(x);
            }
            return it;
        }
        precond.apply32(ctx, &r, &mut shat);
        ctx.matvec32(a, &shat, &mut t);
        if project_nullspace {
            remove_mean32(&mut t);
        }
        let tt = ctx.dot32(&t, &t);
        if tt.abs() < 1e-300 {
            ctx.axpy32(alpha, &phat, x);
            return it;
        }
        omega = ctx.dot32(&t, &r) / tt;
        ctx.axpy32(alpha, &phat, x);
        ctx.axpy32(omega, &shat, x);
        ctx.axpy32(-omega, &t, &mut r);
        res = ctx.norm2_32(&r) / bnorm;
        if res < tol {
            if project_nullspace {
                remove_mean32(x);
            }
            return it;
        }
        if omega.abs() < 1e-300 {
            return it;
        }
    }
    max_iter
}

#[cfg(test)]
mod tests {
    use super::super::precond::{Identity, Ilu0, Jacobi};
    use super::super::testmat::{poisson1d, random_dd};
    use super::*;

    #[test]
    fn refined_cg_matches_f64_cg_to_tol() {
        let a = poisson1d(80);
        let a32 = Csr32::from_f64(&a);
        let xs: Vec<f64> = (0..80).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut b = vec![0.0; 80];
        a.matvec(&xs, &mut b);
        let ctx = ExecCtx::serial();
        let opts = SolveOpts { precision: Precision::Mixed, ..Default::default() };
        let mut x64 = vec![0.0; 80];
        let mut xm = vec![0.0; 80];
        let st64 = cg(&ctx, &a, &b, &mut x64, &Jacobi::new(&a), false, SolveOpts::default());
        let stm = refined_cg(&ctx, &a, &a32, &b, &mut xm, &Jacobi::new(&a), false, opts);
        assert!(st64.converged && stm.converged, "{} {}", st64.residual, stm.residual);
        // both solved to the same 1e-10 relative residual; solutions agree
        // far beyond f32 resolution because refinement corrects in f64
        for (u, v) in xm.iter().zip(&x64) {
            assert!((u - v).abs() < 1e-7 * (1.0 + v.abs()), "{u} vs {v}");
        }
        assert!(a.residual_norm(&xm, &b) <= a.residual_norm(&x64, &b) * 10.0 + 1e-12);
    }

    #[test]
    fn refined_cg_projects_singular_nullspace() {
        // periodic Laplacian: singular with constant nullspace
        let n = 32;
        let mut trip = Vec::new();
        for i in 0..n {
            trip.push((i, i, 2.0));
            trip.push((i, (i + 1) % n, -1.0));
            trip.push((i, (i + n - 1) % n, -1.0));
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        let a32 = Csr32::from_f64(&a);
        let mut b: Vec<f64> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * i as f64 / n as f64).sin())
            .collect();
        let mean = crate::util::det::mean(&b);
        b.iter_mut().for_each(|v| *v -= mean);
        let mut x = vec![0.0; n];
        let opts = SolveOpts { precision: Precision::Mixed, ..Default::default() };
        let st = refined_cg(&ExecCtx::serial(), &a, &a32, &b, &mut x, &Identity, true, opts);
        assert!(st.converged, "residual {}", st.residual);
        assert!(a.residual_norm(&x, &b) < 1e-8);
        assert!(crate::util::det::mean(&x).abs() < 1e-10);
    }

    #[test]
    fn refined_bicgstab_solves_nonsymmetric_dd() {
        let mut rng = crate::util::rng::Rng::new(0x51);
        let a = random_dd(60, &mut rng);
        let a32 = Csr32::from_f64(&a);
        let xs = rng.normal_vec(60);
        let mut b = vec![0.0; 60];
        a.matvec(&xs, &mut b);
        let mut x = vec![0.0; 60];
        let opts = SolveOpts { precision: Precision::Mixed, ..Default::default() };
        let ctx = ExecCtx::serial();
        let st = refined_bicgstab(&ctx, &a, &a32, &b, &mut x, &Ilu0::new(&a), false, opts);
        assert!(st.converged, "residual {}", st.residual);
        for (u, v) in x.iter().zip(&xs) {
            assert!((u - v).abs() < 1e-6, "{u} vs {v}");
        }
    }

    #[test]
    fn transpose_routes_to_f64_solver() {
        // the adjoint path must behave exactly like the f64 solver
        let mut rng = crate::util::rng::Rng::new(0x52);
        let a = random_dd(40, &mut rng);
        let a32 = Csr32::from_f64(&a);
        let xs = rng.normal_vec(40);
        let at = a.transpose();
        let mut b = vec![0.0; 40];
        at.matvec(&xs, &mut b);
        let opts =
            SolveOpts { transpose: true, precision: Precision::Mixed, ..Default::default() };
        let ctx = ExecCtx::serial();
        let mut x_ref = vec![0.0; 40];
        let mut x_mix = vec![0.0; 40];
        bicgstab(
            &ctx,
            &a,
            &b,
            &mut x_ref,
            &Identity,
            false,
            SolveOpts { transpose: true, ..Default::default() },
        );
        refined_bicgstab(&ctx, &a, &a32, &b, &mut x_mix, &Identity, false, opts);
        assert_eq!(x_ref, x_mix); // bit-for-bit: same f64 code path
    }

    #[test]
    fn stale_mirror_structure_is_rejected() {
        let a = poisson1d(10);
        let a32 = Csr32::from_f64(&poisson1d(12));
        let b = vec![1.0; 10];
        let mut x = vec![0.0; 10];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            refined_cg(
                &ExecCtx::serial(),
                &a,
                &a32,
                &b,
                &mut x,
                &Identity,
                false,
                SolveOpts::default(),
            )
        }));
        assert!(r.is_err(), "mismatched mirror must panic");
    }
}
