//! Linear solver substrate: the paper solves the advection–diffusion system
//! with BiCGStab (+ optional ILU(0) preconditioning) and the pressure system
//! with CG, both via cuBLAS/cuSparse; here they are implemented from scratch
//! over [`Csr`](crate::sparse::Csr), pool-resident on an explicit
//! [`ExecCtx`](crate::par::ExecCtx). The same solvers run the transposed
//! systems for the OtD adjoint (`Aᵀ ∂b = ∂x`).

pub mod bicgstab;
pub mod cg;
pub mod precond;
pub mod refine;

pub use bicgstab::bicgstab;
pub use cg::cg;
pub use precond::{Ilu0, Jacobi, Preconditioner};
pub use refine::{refined_bicgstab, refined_cg};

/// Outcome of an iterative solve.
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Numeric precision of the Krylov hot path (see [`refine`]).
///
/// Determinism is bit-for-bit *per (thread-width, precision) config*: for a
/// fixed width, `F64` and `Mixed` are each reproducible run to run, but they
/// are different arithmetic and do not match each other bitwise — both
/// converge to the same [`SolveOpts::tol`] on the true f64 residual.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full f64 storage end to end (the default; the adjoint always runs
    /// here so gradcheck tolerances are untouched).
    #[default]
    F64,
    /// f32-storage/f64-accumulation inner solves wrapped in iterative
    /// refinement; the outer loop re-checks the true f64 residual and falls
    /// back to the f64 solver on stagnation, so convergence to `tol` is
    /// guaranteed either way.
    Mixed,
}

impl Precision {
    #[inline]
    pub fn is_mixed(self) -> bool {
        self == Precision::Mixed
    }
}

/// Solver configuration shared by CG / BiCGStab.
#[derive(Clone, Copy, Debug)]
pub struct SolveOpts {
    pub tol: f64,
    pub max_iter: usize,
    /// Solve with Aᵀ instead of A (adjoint mode).
    pub transpose: bool,
    /// Storage precision of the Krylov inner loop. `cg`/`bicgstab`
    /// themselves always run f64; callers holding a
    /// [`Csr32`](crate::sparse::Csr32) mirror honor this by dispatching to
    /// the [`refine`] wrappers instead (see `piso::PisoSolver::step`).
    pub precision: Precision,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts { tol: 1e-10, max_iter: 2000, transpose: false, precision: Precision::F64 }
    }
}

/// Debug-build guard against silent NaN/Inf contamination of a Krylov
/// iteration. A single non-finite entry in the RHS or an overflowing
/// iterate otherwise propagates through every dot product and poisons the
/// solution (and, downstream, the adjoint tape) without any solver ever
/// failing — the residual just goes NaN and the `< tol` test is quietly
/// false forever. In debug builds this panics naming the solver, the
/// vector, the iteration, and the current residual; release builds compile
/// it away to nothing.
#[inline]
pub(crate) fn debug_check_finite(solver: &str, what: &str, iteration: usize, residual: f64, v: &[f64]) {
    #[cfg(debug_assertions)]
    if let Some(i) = v.iter().position(|x| !x.is_finite()) {
        panic!(
            "{solver}: non-finite {what}[{i}] = {} at iteration {iteration} \
             (residual {residual:.3e}) — poisoned input or diverging iteration",
            v[i]
        );
    }
    #[cfg(not(debug_assertions))]
    {
        let _ = (solver, what, iteration, residual, v);
    }
}

// BLAS-1 primitives and SpMV come from the caller's
// [`ExecCtx`](crate::par::ExecCtx): both solvers take the context
// explicitly, so the Krylov loop, its preconditioner applies, and every
// reduction run pool-resident on the same persistent workers. Below the
// per-chunk work thresholds the kernels take the serial path, keeping small
// systems bit-identical with earlier serial-only builds.

#[cfg(test)]
pub(crate) mod testmat {
    use crate::sparse::Csr;

    /// 1D Poisson matrix (tridiagonal, SPD): n cells, Dirichlet ends.
    pub fn poisson1d(n: usize) -> Csr {
        let mut trip = Vec::new();
        for i in 0..n {
            trip.push((i, i, 2.0));
            if i > 0 {
                trip.push((i, i - 1, -1.0));
            }
            if i + 1 < n {
                trip.push((i, i + 1, -1.0));
            }
        }
        Csr::from_triplets(n, &trip)
    }

    /// Random strictly diagonally dominant (nonsymmetric) matrix.
    pub fn random_dd(n: usize, rng: &mut crate::util::rng::Rng) -> Csr {
        let mut trip = Vec::new();
        for r in 0..n {
            let mut offsum = 0.0;
            for c in 0..n {
                if c != r && rng.uniform() < 0.3 {
                    let v = rng.normal() * 0.5;
                    offsum += v.abs();
                    trip.push((r, c, v));
                }
            }
            trip.push((r, r, offsum + 1.0 + rng.uniform()));
        }
        Csr::from_triplets(n, &trip)
    }
}
