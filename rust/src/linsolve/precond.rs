//! Preconditioners: identity, Jacobi (diagonal), and ILU(0) — incomplete LU
//! with zero fill-in on the CSR sparsity pattern, matching the paper's
//! cuSparse-based ILU preconditioning for BiCGStab (Appendix A.6).
//!
//! All applies are pool-resident: they take the caller's
//! [`ExecCtx`](crate::par::ExecCtx) so preconditioning runs on the same
//! persistent workers as the surrounding Krylov iteration. Jacobi is
//! elementwise (chunk-partitioned, bit-for-bit serial); the ILU(0)
//! triangular solves are parallelized by *level scheduling*: at
//! factorization time the rows of L (and of U) are grouped into dependency
//! levels, and the apply sweeps level by level with a pool barrier between
//! levels. Rows within a level are independent, and each row accumulates
//! its own entries in the same order as the serial solve, so the
//! level-scheduled apply is bit-for-bit equal to the serial one.

use crate::par::{DisjointMut, ExecCtx, MIN_LEVEL_ROWS_PER_THREAD, MIN_VEC_PER_THREAD};
use crate::sparse::Csr;
use crate::util::det;

pub trait Preconditioner {
    /// z = M⁻¹ r, running on `ctx`'s pool.
    fn apply(&self, ctx: &ExecCtx, r: &[f64], z: &mut [f64]);

    /// f32-storage variant of [`Preconditioner::apply`] for the
    /// mixed-precision inner solves in [`crate::linsolve::refine`]: same
    /// factors, f32 operand storage, per-element/per-row arithmetic in f64
    /// narrowed once on write. Deterministic under the same contract as
    /// `apply` (per thread-width, per precision).
    fn apply32(&self, ctx: &ExecCtx, r: &[f32], z: &mut [f32]);
}

/// No-op preconditioner.
pub struct Identity;

impl Preconditioner for Identity {
    fn apply(&self, _ctx: &ExecCtx, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }

    fn apply32(&self, _ctx: &ExecCtx, r: &[f32], z: &mut [f32]) {
        z.copy_from_slice(r);
    }
}

/// Diagonal (Jacobi) preconditioner. Owns both an f64 inverse diagonal and
/// its f32 mirror so one factorization serves both solve precisions; both
/// refresh in place via [`Jacobi::refresh`] when the matrix values change
/// (the structure — and therefore the diagonal positions — is fixed).
pub struct Jacobi {
    inv_diag: Vec<f64>,
    inv_diag32: Vec<f32>,
}

impl Jacobi {
    pub fn new(a: &Csr) -> Jacobi {
        let mut j = Jacobi { inv_diag: vec![0.0; a.n], inv_diag32: vec![0.0; a.n] };
        j.refresh(a);
        j
    }

    /// Numeric-only refresh from (the same-structured) `a`: rewrites both
    /// precision mirrors in place so steppers can reuse one allocation
    /// across steps.
    pub fn refresh(&mut self, a: &Csr) {
        assert_eq!(self.inv_diag.len(), a.n, "Jacobi::refresh: dimension changed since new");
        for r in 0..a.n {
            let d = a.find(r, r).map(|k| a.vals[k]).unwrap_or(0.0);
            let inv = if d.abs() > 1e-300 { 1.0 / d } else { 1.0 };
            self.inv_diag[r] = inv;
            self.inv_diag32[r] = det::narrow_f32(inv);
        }
    }
}

impl Preconditioner for Jacobi {
    fn apply(&self, ctx: &ExecCtx, r: &[f64], z: &mut [f64]) {
        assert_eq!(r.len(), self.inv_diag.len());
        assert_eq!(z.len(), self.inv_diag.len());
        let inv_diag = &self.inv_diag;
        let zs = DisjointMut::new(z);
        ctx.run_chunks(r.len(), MIN_VEC_PER_THREAD, |_, range| {
            // SAFETY: chunk ranges are disjoint
            let chunk = unsafe { zs.range(range.clone()) };
            for (off, zi) in chunk.iter_mut().enumerate() {
                let i = range.start + off;
                *zi = r[i] * inv_diag[i];
            }
        });
    }

    fn apply32(&self, ctx: &ExecCtx, r: &[f32], z: &mut [f32]) {
        assert_eq!(r.len(), self.inv_diag32.len());
        assert_eq!(z.len(), self.inv_diag32.len());
        let inv_diag32 = &self.inv_diag32;
        let zs = DisjointMut::new(z);
        ctx.run_chunks(r.len(), MIN_VEC_PER_THREAD, |_, range| {
            // SAFETY: chunk ranges are disjoint
            let chunk = unsafe { zs.range(range.clone()) };
            for (off, zi) in chunk.iter_mut().enumerate() {
                let i = range.start + off;
                *zi = det::narrow_f32(f64::from(r[i]) * f64::from(inv_diag32[i]));
            }
        });
    }
}

/// Dependency levels of one triangular factor: rows grouped so every row in
/// level `l` depends only on rows in levels `< l`. `rows[level_ptr[l]..
/// level_ptr[l+1]]` lists level `l`'s rows in ascending order.
struct LevelSchedule {
    rows: Vec<u32>,
    level_ptr: Vec<usize>,
    /// Rows in the widest level — this factor's available parallelism
    /// (cached: the apply fast-path check runs per solve).
    max_rows: usize,
}

impl LevelSchedule {
    /// Build the schedule from a per-row dependency closure: `deps(i)`
    /// yields the entry range of row `i` that references other rows of this
    /// factor, and `order` iterates rows in an order where dependencies
    /// precede dependents (ascending for L, descending for U).
    fn build(
        n: usize,
        order: impl Iterator<Item = usize>,
        deps: impl Fn(usize) -> std::ops::Range<usize>,
        col_idx: &[u32],
    ) -> LevelSchedule {
        let mut level = vec![0u32; n];
        let mut n_levels = 0usize;
        for i in order {
            let mut l = 0u32;
            for k in deps(i) {
                l = l.max(level[col_idx[k] as usize] + 1);
            }
            level[i] = l;
            n_levels = n_levels.max(l as usize + 1);
        }
        if n == 0 {
            return LevelSchedule { rows: Vec::new(), level_ptr: vec![0], max_rows: 0 };
        }
        // counting sort rows by level, ascending row order within a level
        let mut level_ptr = vec![0usize; n_levels + 1];
        for &l in &level {
            level_ptr[l as usize + 1] += 1;
        }
        for l in 0..n_levels {
            level_ptr[l + 1] += level_ptr[l];
        }
        let mut cursor = level_ptr.clone();
        let mut rows = vec![0u32; n];
        for i in 0..n {
            let l = level[i] as usize;
            rows[cursor[l]] = crate::util::det::index_u32(i);
            cursor[l] += 1;
        }
        let max_rows =
            level_ptr.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0);
        LevelSchedule { rows, level_ptr, max_rows }
    }

    fn n_levels(&self) -> usize {
        self.level_ptr.len() - 1
    }

    fn level(&self, l: usize) -> &[u32] {
        &self.rows[self.level_ptr[l]..self.level_ptr[l + 1]]
    }
}

/// ILU(0): L and U share A's sparsity pattern; factorization by the standard
/// IKJ variant restricted to existing entries. Rows must be sorted by column
/// (guaranteed by [`Csr`] construction). The triangular solves of `apply`
/// are level-scheduled (see module docs): level sets are computed once here
/// at factorization time.
pub struct Ilu0 {
    n: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    /// combined LU values: strictly-lower = L (unit diagonal implied),
    /// diagonal + upper = U
    lu: Vec<f64>,
    /// f32 mirror of `lu` for the mixed-precision applies; renarrowed by
    /// [`Ilu0::refactor`] whenever `lu` is.
    lu32: Vec<f32>,
    diag_ptr: Vec<usize>,
    l_sched: LevelSchedule,
    u_sched: LevelSchedule,
}

/// The IKJ ILU(0) numeric factorization restricted to the pattern, over
/// values already copied into `lu`. Split out of [`Ilu0::new`] so
/// [`Ilu0::refactor`] can rerun it against a persistent symbolic structure
/// without reallocating or rebuilding the level schedules.
fn factorize_in_place(
    n: usize,
    row_ptr: &[usize],
    col_idx: &[u32],
    diag_ptr: &[usize],
    lu: &mut [f64],
) {
    for i in 1..n {
        let (lo, hi) = (row_ptr[i], row_ptr[i + 1]);
        for kk in lo..hi {
            let k = col_idx[kk] as usize;
            if k >= i {
                break;
            }
            let pivot = lu[diag_ptr[k]];
            if pivot.abs() < 1e-300 {
                continue;
            }
            let lik = lu[kk] / pivot;
            lu[kk] = lik;
            // subtract lik * U(k, j) for j > k present in row i
            for jj in (diag_ptr[k] + 1)..row_ptr[k + 1] {
                let j = col_idx[jj];
                // find (i, j) in row i via binary search
                if let Ok(pos) = col_idx[lo..hi].binary_search(&j) {
                    lu[lo + pos] -= lik * lu[jj];
                }
            }
        }
    }
}

impl Ilu0 {
    pub fn new(a: &Csr) -> Ilu0 {
        let n = a.n;
        let mut lu = a.vals.clone();
        let row_ptr = a.row_ptr.clone();
        let col_idx = a.col_idx.clone();
        // locate diagonal of each row
        let mut diag_ptr = vec![usize::MAX; n];
        for r in 0..n {
            for k in row_ptr[r]..row_ptr[r + 1] {
                if col_idx[k] as usize == r {
                    diag_ptr[r] = k;
                }
            }
            assert!(diag_ptr[r] != usize::MAX, "ILU0 requires full diagonal (row {r})");
        }
        factorize_in_place(n, &row_ptr, &col_idx, &diag_ptr, &mut lu);
        let mut lu32 = vec![0.0f32; lu.len()];
        for (dst, src) in lu32.iter_mut().zip(&lu) {
            *dst = det::narrow_f32(*src);
        }
        // level sets: L rows depend on their strictly-lower entries, U rows
        // on their strictly-upper entries
        let l_sched =
            LevelSchedule::build(n, 0..n, |i| row_ptr[i]..diag_ptr[i], &col_idx);
        let u_sched = LevelSchedule::build(
            n,
            (0..n).rev(),
            |i| diag_ptr[i] + 1..row_ptr[i + 1],
            &col_idx,
        );
        Ilu0 { n, row_ptr, col_idx, lu, lu32, diag_ptr, l_sched, u_sched }
    }

    /// Numeric-only refactorization from (the same-structured) `a`: copies
    /// the fresh values into the persistent `lu` buffer, reruns the IKJ
    /// elimination, and renarrows the f32 mirror. The symbolic structure,
    /// diagonal pointers, and level schedules are all functions of the
    /// sparsity pattern alone, so they carry over untouched — this is the
    /// cross-step path that replaces a per-step [`Ilu0::new`].
    pub fn refactor(&mut self, a: &Csr) {
        assert_eq!(self.n, a.n, "Ilu0::refactor: dimension changed since new");
        assert_eq!(self.lu.len(), a.vals.len(), "Ilu0::refactor: nnz changed since new");
        debug_assert_eq!(self.row_ptr, a.row_ptr);
        debug_assert_eq!(self.col_idx, a.col_idx);
        self.lu.copy_from_slice(&a.vals);
        factorize_in_place(self.n, &self.row_ptr, &self.col_idx, &self.diag_ptr, &mut self.lu);
        for (dst, src) in self.lu32.iter_mut().zip(&self.lu) {
            *dst = det::narrow_f32(*src);
        }
    }

    /// Longest dependency chains of the two factors (diagnostic: parallel
    /// speedup is bounded by rows / levels).
    pub fn level_counts(&self) -> (usize, usize) {
        (self.l_sched.n_levels(), self.u_sched.n_levels())
    }

    /// The level-scheduled apply with an explicit per-chunk row minimum
    /// (`apply` uses [`MIN_LEVEL_ROWS_PER_THREAD`]; tests and benches pass
    /// smaller values to force the parallel path on small systems).
    pub fn apply_min_rows(&self, ctx: &ExecCtx, r: &[f64], z: &mut [f64], min_rows: usize) {
        assert_eq!(r.len(), self.n);
        assert_eq!(z.len(), self.n);
        let (row_ptr, col_idx, lu, diag_ptr) =
            (&self.row_ptr, &self.col_idx, &self.lu, &self.diag_ptr);
        // Each factor falls back independently to its tight serial sweep
        // when the context is serial or its own widest level cannot feed
        // two chunks (chain-structured banded factors degenerate to one row
        // per level). Per-row arithmetic is identical on both paths, so
        // results are bit-for-bit equal either way (see module docs).
        let width = ctx.width();
        // forward solve L y = r (unit diagonal), y stored in z
        if width <= 1 || self.l_sched.max_rows < 2 * min_rows {
            for i in 0..self.n {
                let mut acc = r[i];
                for k in row_ptr[i]..diag_ptr[i] {
                    acc -= lu[k] * z[col_idx[k] as usize];
                }
                z[i] = acc;
            }
        } else {
            let zs = DisjointMut::new(z);
            for l in 0..self.l_sched.n_levels() {
                let rows = self.l_sched.level(l);
                ctx.run_chunks(rows.len(), min_rows, |_, range| {
                    for &i in &rows[range] {
                        let i = i as usize;
                        let mut acc = r[i];
                        for k in row_ptr[i]..diag_ptr[i] {
                            // SAFETY: reads are of rows in earlier levels,
                            // already finalized; no task in this level
                            // writes them
                            acc -= lu[k] * unsafe { zs.get(col_idx[k] as usize) };
                        }
                        // SAFETY: each row is written by exactly one task
                        unsafe { zs.set(i, acc) };
                    }
                });
            }
        }
        // backward solve U z = y
        if width <= 1 || self.u_sched.max_rows < 2 * min_rows {
            for i in (0..self.n).rev() {
                let mut acc = z[i];
                for k in (diag_ptr[i] + 1)..row_ptr[i + 1] {
                    acc -= lu[k] * z[col_idx[k] as usize];
                }
                let d = lu[diag_ptr[i]];
                z[i] = if d.abs() > 1e-300 { acc / d } else { acc };
            }
        } else {
            let zs = DisjointMut::new(z);
            for l in 0..self.u_sched.n_levels() {
                let rows = self.u_sched.level(l);
                ctx.run_chunks(rows.len(), min_rows, |_, range| {
                    for &i in &rows[range] {
                        let i = i as usize;
                        // SAFETY: same disjointness argument as the L sweep
                        let mut acc = unsafe { zs.get(i) };
                        for k in (diag_ptr[i] + 1)..row_ptr[i + 1] {
                            acc -= lu[k] * unsafe { zs.get(col_idx[k] as usize) };
                        }
                        let d = lu[diag_ptr[i]];
                        // SAFETY: each row is written by exactly one task
                        unsafe { zs.set(i, if d.abs() > 1e-300 { acc / d } else { acc }) };
                    }
                });
            }
        }
    }

    /// f32 twin of [`Ilu0::apply_min_rows`]: the same level-scheduled
    /// sweeps over the `lu32` mirror, accumulating each row in f64 and
    /// narrowing once on write, with the same independent serial fallback
    /// per factor — bit-for-bit equal to its own serial sweep at any width.
    pub fn apply32_min_rows(&self, ctx: &ExecCtx, r: &[f32], z: &mut [f32], min_rows: usize) {
        assert_eq!(r.len(), self.n);
        assert_eq!(z.len(), self.n);
        let (row_ptr, col_idx, lu32, diag_ptr) =
            (&self.row_ptr, &self.col_idx, &self.lu32, &self.diag_ptr);
        let width = ctx.width();
        // forward solve L y = r (unit diagonal), y stored in z
        if width <= 1 || self.l_sched.max_rows < 2 * min_rows {
            for i in 0..self.n {
                let mut acc = f64::from(r[i]);
                for k in row_ptr[i]..diag_ptr[i] {
                    acc -= f64::from(lu32[k]) * f64::from(z[col_idx[k] as usize]);
                }
                z[i] = det::narrow_f32(acc);
            }
        } else {
            let zs = DisjointMut::new(z);
            for l in 0..self.l_sched.n_levels() {
                let rows = self.l_sched.level(l);
                ctx.run_chunks(rows.len(), min_rows, |_, range| {
                    for &i in &rows[range] {
                        let i = i as usize;
                        let mut acc = f64::from(r[i]);
                        for k in row_ptr[i]..diag_ptr[i] {
                            // SAFETY: reads are of rows in earlier levels,
                            // already finalized; no task in this level
                            // writes them
                            acc -= f64::from(lu32[k])
                                * f64::from(unsafe { zs.get(col_idx[k] as usize) });
                        }
                        // SAFETY: each row is written by exactly one task
                        unsafe { zs.set(i, det::narrow_f32(acc)) };
                    }
                });
            }
        }
        // backward solve U z = y
        if width <= 1 || self.u_sched.max_rows < 2 * min_rows {
            for i in (0..self.n).rev() {
                let mut acc = f64::from(z[i]);
                for k in (diag_ptr[i] + 1)..row_ptr[i + 1] {
                    acc -= f64::from(lu32[k]) * f64::from(z[col_idx[k] as usize]);
                }
                let d = f64::from(lu32[diag_ptr[i]]);
                z[i] = det::narrow_f32(if d.abs() > 1e-300 { acc / d } else { acc });
            }
        } else {
            let zs = DisjointMut::new(z);
            for l in 0..self.u_sched.n_levels() {
                let rows = self.u_sched.level(l);
                ctx.run_chunks(rows.len(), min_rows, |_, range| {
                    for &i in &rows[range] {
                        let i = i as usize;
                        // SAFETY: same disjointness argument as the L sweep
                        let mut acc = f64::from(unsafe { zs.get(i) });
                        for k in (diag_ptr[i] + 1)..row_ptr[i + 1] {
                            // SAFETY: reads rows in earlier levels only
                            acc -= f64::from(lu32[k])
                                * f64::from(unsafe { zs.get(col_idx[k] as usize) });
                        }
                        let d = f64::from(lu32[diag_ptr[i]]);
                        let zi = det::narrow_f32(if d.abs() > 1e-300 { acc / d } else { acc });
                        // SAFETY: each row is written by exactly one task
                        unsafe { zs.set(i, zi) };
                    }
                });
            }
        }
    }
}

impl Preconditioner for Ilu0 {
    fn apply(&self, ctx: &ExecCtx, r: &[f64], z: &mut [f64]) {
        self.apply_min_rows(ctx, r, z, MIN_LEVEL_ROWS_PER_THREAD);
    }

    fn apply32(&self, ctx: &ExecCtx, r: &[f32], z: &mut [f32]) {
        self.apply32_min_rows(ctx, r, z, MIN_LEVEL_ROWS_PER_THREAD);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ilu0_exact_for_tridiagonal() {
        // for tridiagonal matrices ILU(0) == full LU, so M⁻¹ A x == x
        let a = crate::linsolve::testmat::poisson1d(30);
        let ilu = Ilu0::new(&a);
        let ctx = ExecCtx::serial();
        let x: Vec<f64> = (0..30).map(|i| (i as f64 * 0.7).sin()).collect();
        let mut ax = vec![0.0; 30];
        a.matvec(&x, &mut ax);
        let mut z = vec![0.0; 30];
        ilu.apply(&ctx, &ax, &mut z);
        for (zi, xi) in z.iter().zip(&x) {
            assert!((zi - xi).abs() < 1e-10, "{zi} vs {xi}");
        }
    }

    #[test]
    fn tridiagonal_levels_are_chains() {
        // every row of a tridiagonal L depends on the previous one: the
        // schedule must degenerate to n levels of one row each
        let a = crate::linsolve::testmat::poisson1d(12);
        let ilu = Ilu0::new(&a);
        assert_eq!(ilu.level_counts(), (12, 12));
    }

    #[test]
    fn diagonal_matrix_is_one_level() {
        let a = crate::sparse::Csr::from_triplets(
            4,
            &[(0, 0, 2.0), (1, 1, 4.0), (2, 2, 8.0), (3, 3, 16.0)],
        );
        let ilu = Ilu0::new(&a);
        assert_eq!(ilu.level_counts(), (1, 1));
        let ctx = ExecCtx::with_threads(3);
        let mut z = vec![0.0; 4];
        ilu.apply_min_rows(&ctx, &[2.0, 4.0, 8.0, 16.0], &mut z, 1);
        assert_eq!(z, vec![1.0; 4]);
    }

    #[test]
    fn level_scheduled_apply_is_bit_for_bit_serial() {
        // 2D Poisson-like pattern: levels are the anti-diagonals, so the
        // parallel path genuinely runs multi-row levels
        let nx = 8;
        let n = nx * nx;
        let mut trip = Vec::new();
        for j in 0..nx {
            for i in 0..nx {
                let c = j * nx + i;
                trip.push((c, c, 4.0 + 0.1 * (c % 5) as f64));
                if i > 0 {
                    trip.push((c, c - 1, -1.0));
                }
                if i + 1 < nx {
                    trip.push((c, c + 1, -1.0));
                }
                if j > 0 {
                    trip.push((c, c - nx, -1.3));
                }
                if j + 1 < nx {
                    trip.push((c, c + nx, -0.7));
                }
            }
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        let ilu = Ilu0::new(&a);
        let (ll, ul) = ilu.level_counts();
        assert!(ll < n && ul < n, "grid stencil must admit parallel levels");
        let r: Vec<f64> = (0..n).map(|i| ((i * 31 % 17) as f64) * 0.3 - 2.0).collect();
        let mut z_serial = vec![0.0; n];
        ilu.apply(&ExecCtx::serial(), &r, &mut z_serial);
        let ctx = ExecCtx::with_threads(4);
        let mut z_par = vec![0.0; n];
        ilu.apply_min_rows(&ctx, &r, &mut z_par, 1);
        assert_eq!(z_serial, z_par);
    }

    #[test]
    fn miri_level_sweep_disjoint_writes_are_sound() {
        // Fast Miri target for the DisjointMut get/set sweeps: a tiny grid
        // whose levels genuinely run multi-row, forced onto the parallel
        // path, checked bit-for-bit against the serial sweep.
        let nx = 3;
        let n = nx * nx;
        let mut trip = Vec::new();
        for j in 0..nx {
            for i in 0..nx {
                let c = j * nx + i;
                trip.push((c, c, 4.0 + 0.1 * (c % 5) as f64));
                if i > 0 {
                    trip.push((c, c - 1, -1.0));
                }
                if i + 1 < nx {
                    trip.push((c, c + 1, -1.0));
                }
                if j > 0 {
                    trip.push((c, c - nx, -1.3));
                }
                if j + 1 < nx {
                    trip.push((c, c + nx, -0.7));
                }
            }
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        let ilu = Ilu0::new(&a);
        let r: Vec<f64> = (0..n).map(|i| ((i * 31 % 17) as f64) * 0.3 - 2.0).collect();
        let mut z_serial = vec![0.0; n];
        ilu.apply(&ExecCtx::serial(), &r, &mut z_serial);
        let ctx = ExecCtx::with_threads(2);
        let mut z_par = vec![0.0; n];
        ilu.apply_min_rows(&ctx, &r, &mut z_par, 1);
        assert_eq!(z_serial, z_par);
    }

    fn grid_matrix(nx: usize) -> Csr {
        let n = nx * nx;
        let mut trip = Vec::new();
        for j in 0..nx {
            for i in 0..nx {
                let c = j * nx + i;
                trip.push((c, c, 4.0 + 0.1 * (c % 5) as f64));
                if i > 0 {
                    trip.push((c, c - 1, -1.0));
                }
                if i + 1 < nx {
                    trip.push((c, c + 1, -1.0));
                }
                if j > 0 {
                    trip.push((c, c - nx, -1.3));
                }
                if j + 1 < nx {
                    trip.push((c, c + nx, -0.7));
                }
            }
        }
        crate::sparse::Csr::from_triplets(n, &trip)
    }

    #[test]
    fn ilu0_refactor_matches_fresh_factorization() {
        let mut a = grid_matrix(6);
        let mut ilu = Ilu0::new(&a);
        for (k, v) in a.vals.iter_mut().enumerate() {
            *v *= 1.0 + 0.01 * (k % 7) as f64;
        }
        ilu.refactor(&a);
        let fresh = Ilu0::new(&a);
        assert_eq!(ilu.lu, fresh.lu); // same elimination, bit-for-bit
        assert_eq!(ilu.lu32, fresh.lu32);
    }

    #[test]
    fn jacobi_refresh_tracks_value_updates() {
        let mut a = grid_matrix(4);
        let mut j = Jacobi::new(&a);
        for v in a.vals.iter_mut() {
            *v *= 2.0;
        }
        j.refresh(&a);
        let fresh = Jacobi::new(&a);
        assert_eq!(j.inv_diag, fresh.inv_diag);
        assert_eq!(j.inv_diag32, fresh.inv_diag32);
    }

    #[test]
    fn apply32_tracks_f64_apply_within_rounding() {
        let a = grid_matrix(8);
        let n = a.n;
        let ctx = ExecCtx::serial();
        let r: Vec<f64> = (0..n).map(|i| ((i * 13 % 29) as f64) * 0.1 - 1.0).collect();
        let r32: Vec<f32> = r.iter().map(|&v| v as f32).collect();
        for p in [&Ilu0::new(&a) as &dyn Preconditioner, &Jacobi::new(&a), &Identity] {
            let mut z = vec![0.0f64; n];
            let mut z32 = vec![0.0f32; n];
            p.apply(&ctx, &r, &mut z);
            p.apply32(&ctx, &r32, &mut z32);
            for i in 0..n {
                assert!(
                    (f64::from(z32[i]) - z[i]).abs() < 1e-5 * (1.0 + z[i].abs()),
                    "i={i}: {} vs {}",
                    z32[i],
                    z[i]
                );
            }
        }
    }

    #[test]
    fn level_scheduled_apply32_is_bit_for_bit_serial() {
        let a = grid_matrix(8);
        let n = a.n;
        let ilu = Ilu0::new(&a);
        let r32: Vec<f32> = (0..n).map(|i| ((i * 31 % 17) as f32) * 0.3 - 2.0).collect();
        let mut z_serial = vec![0.0f32; n];
        ilu.apply32_min_rows(&ExecCtx::serial(), &r32, &mut z_serial, 1);
        let ctx = ExecCtx::with_threads(4);
        let mut z_par = vec![0.0f32; n];
        ilu.apply32_min_rows(&ctx, &r32, &mut z_par, 1);
        assert_eq!(z_serial, z_par);
    }

    #[test]
    fn miri_level_sweep32_disjoint_writes_are_sound() {
        // Fast Miri target for the f32 DisjointMut get/set sweeps, the
        // mirror of miri_level_sweep_disjoint_writes_are_sound.
        let a = grid_matrix(3);
        let n = a.n;
        let ilu = Ilu0::new(&a);
        let r32: Vec<f32> = (0..n).map(|i| ((i * 31 % 17) as f32) * 0.3 - 2.0).collect();
        let mut z_serial = vec![0.0f32; n];
        ilu.apply32_min_rows(&ExecCtx::serial(), &r32, &mut z_serial, 1);
        let ctx = ExecCtx::with_threads(2);
        let mut z_par = vec![0.0f32; n];
        ilu.apply32_min_rows(&ctx, &r32, &mut z_par, 1);
        assert_eq!(z_serial, z_par);
    }

    #[test]
    fn jacobi_inverts_diagonal_matrix() {
        let a = crate::sparse::Csr::from_triplets(3, &[(0, 0, 2.0), (1, 1, 4.0), (2, 2, 8.0)]);
        let j = Jacobi::new(&a);
        let mut z = vec![0.0; 3];
        j.apply(&ExecCtx::with_threads(2), &[2.0, 4.0, 8.0], &mut z);
        assert_eq!(z, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn identity_copies() {
        let mut z = vec![0.0; 2];
        Identity.apply(&ExecCtx::serial(), &[3.0, -1.0], &mut z);
        assert_eq!(z, vec![3.0, -1.0]);
    }
}
