//! Preconditioners: identity, Jacobi (diagonal), and ILU(0) — incomplete LU
//! with zero fill-in on the CSR sparsity pattern, matching the paper's
//! cuSparse-based ILU preconditioning for BiCGStab (Appendix A.6).

use crate::sparse::Csr;

pub trait Preconditioner {
    /// z = M⁻¹ r
    fn apply(&self, r: &[f64], z: &mut [f64]);
}

/// No-op preconditioner.
pub struct Identity;

impl Preconditioner for Identity {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
}

/// Diagonal (Jacobi) preconditioner.
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    pub fn new(a: &Csr) -> Jacobi {
        Jacobi {
            inv_diag: a
                .diagonal()
                .iter()
                .map(|d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 })
                .collect(),
        }
    }
}

impl Preconditioner for Jacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for i in 0..r.len() {
            z[i] = r[i] * self.inv_diag[i];
        }
    }
}

/// ILU(0): L and U share A's sparsity pattern; factorization by the standard
/// IKJ variant restricted to existing entries. Rows must be sorted by column
/// (guaranteed by [`Csr`] construction).
pub struct Ilu0 {
    n: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    /// combined LU values: strictly-lower = L (unit diagonal implied),
    /// diagonal + upper = U
    lu: Vec<f64>,
    diag_ptr: Vec<usize>,
}

impl Ilu0 {
    pub fn new(a: &Csr) -> Ilu0 {
        let n = a.n;
        let mut lu = a.vals.clone();
        let row_ptr = a.row_ptr.clone();
        let col_idx = a.col_idx.clone();
        // locate diagonal of each row
        let mut diag_ptr = vec![usize::MAX; n];
        for r in 0..n {
            for k in row_ptr[r]..row_ptr[r + 1] {
                if col_idx[k] as usize == r {
                    diag_ptr[r] = k;
                }
            }
            assert!(diag_ptr[r] != usize::MAX, "ILU0 requires full diagonal (row {r})");
        }
        // IKJ factorization restricted to the pattern
        for i in 1..n {
            let (lo, hi) = (row_ptr[i], row_ptr[i + 1]);
            for kk in lo..hi {
                let k = col_idx[kk] as usize;
                if k >= i {
                    break;
                }
                let pivot = lu[diag_ptr[k]];
                if pivot.abs() < 1e-300 {
                    continue;
                }
                let lik = lu[kk] / pivot;
                lu[kk] = lik;
                // subtract lik * U(k, j) for j > k present in row i
                for jj in (diag_ptr[k] + 1)..row_ptr[k + 1] {
                    let j = col_idx[jj];
                    // find (i, j) in row i via binary search
                    if let Ok(pos) = col_idx[lo..hi].binary_search(&j) {
                        lu[lo + pos] -= lik * lu[jj];
                    }
                }
            }
        }
        Ilu0 { n, row_ptr, col_idx, lu, diag_ptr }
    }
}

impl Preconditioner for Ilu0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = self.n;
        // forward solve L y = r (unit diagonal), y stored in z
        for i in 0..n {
            let mut acc = r[i];
            for k in self.row_ptr[i]..self.diag_ptr[i] {
                acc -= self.lu[k] * z[self.col_idx[k] as usize];
            }
            z[i] = acc;
        }
        // backward solve U z = y
        for i in (0..n).rev() {
            let mut acc = z[i];
            for k in (self.diag_ptr[i] + 1)..self.row_ptr[i + 1] {
                acc -= self.lu[k] * z[self.col_idx[k] as usize];
            }
            let d = self.lu[self.diag_ptr[i]];
            z[i] = if d.abs() > 1e-300 { acc / d } else { acc };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ilu0_exact_for_tridiagonal() {
        // for tridiagonal matrices ILU(0) == full LU, so M⁻¹ A x == x
        let a = crate::linsolve::testmat::poisson1d(30);
        let ilu = Ilu0::new(&a);
        let x: Vec<f64> = (0..30).map(|i| (i as f64 * 0.7).sin()).collect();
        let mut ax = vec![0.0; 30];
        a.matvec(&x, &mut ax);
        let mut z = vec![0.0; 30];
        ilu.apply(&ax, &mut z);
        for (zi, xi) in z.iter().zip(&x) {
            assert!((zi - xi).abs() < 1e-10, "{zi} vs {xi}");
        }
    }

    #[test]
    fn jacobi_inverts_diagonal_matrix() {
        let a = crate::sparse::Csr::from_triplets(3, &[(0, 0, 2.0), (1, 1, 4.0), (2, 2, 8.0)]);
        let j = Jacobi::new(&a);
        let mut z = vec![0.0; 3];
        j.apply(&[2.0, 4.0, 8.0], &mut z);
        assert_eq!(z, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn identity_copies() {
        let mut z = vec![0.0; 2];
        Identity.apply(&[3.0, -1.0], &mut z);
        assert_eq!(z, vec![3.0, -1.0]);
    }
}
