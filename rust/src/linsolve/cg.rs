//! Preconditioned conjugate gradient for the (symmetric) pressure system.
//!
//! The pressure matrix has a constant nullspace on all-Neumann/periodic
//! domains; callers pass `project_nullspace = true` so both RHS and iterates
//! stay mean-free, which keeps CG on the consistent subspace (the classic
//! deflation of the constant vector).

use super::precond::Preconditioner;
use super::{debug_check_finite, SolveOpts, SolveStats};
use crate::par::ExecCtx;
use crate::sparse::Csr;

/// Project out the constant-vector nullspace component (shared with
/// `bicgstab` and the mixed-precision refinement wrappers).
pub(crate) fn remove_mean(v: &mut [f64]) {
    let mean = crate::util::det::mean(v);
    v.iter_mut().for_each(|x| *x -= mean);
}

/// Solve A x = b with preconditioned CG; `x` holds the initial guess on
/// entry and the solution on exit. Every kernel (SpMV, BLAS-1,
/// preconditioner apply) runs pool-resident on `ctx`. `opts.transpose`
/// (the adjoint solve Aᵀ x = b) is accepted and solved with the same
/// forward kernel: CG requires symmetric A, so Aᵀ = A and the two systems
/// coincide.
pub fn cg(
    ctx: &ExecCtx,
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    precond: &dyn Preconditioner,
    project_nullspace: bool,
    opts: SolveOpts,
) -> SolveStats {
    let n = a.n;
    // CG is only applicable to symmetric matrices (the pressure system is
    // SPD up to its constant nullspace), and for symmetric A the adjoint
    // system Aᵀ x = b *is* A x = b. `opts.transpose` therefore dispatches to
    // the same row-partitioned gather matvec as the forward solve instead of
    // the slow scatter-style `matvec_transpose` — algebraically identical,
    // and the gather kernel is both cache-friendlier and parallel.
    let apply = |v: &[f64], out: &mut [f64]| ctx.matvec(a, v, out);
    let dot = |a: &[f64], b: &[f64]| ctx.dot(a, b);
    let norm2 = |a: &[f64]| ctx.norm2(a);
    let axpy = |alpha: f64, x: &[f64], y: &mut [f64]| ctx.axpy(alpha, x, y);

    let mut b = b.to_vec();
    if project_nullspace {
        remove_mean(&mut b);
        remove_mean(x);
    }

    let mut r = vec![0.0; n];
    apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    if project_nullspace {
        remove_mean(&mut r);
    }

    let bnorm = norm2(&b).max(1e-300);
    let mut z = vec![0.0; n];
    precond.apply(ctx, &r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];

    let mut res = norm2(&r) / bnorm;
    debug_check_finite("cg", "rhs b", 0, res, &b);
    debug_check_finite("cg", "residual r", 0, res, &r);
    if res < opts.tol {
        return SolveStats { iterations: 0, residual: res, converged: true };
    }

    for it in 1..=opts.max_iter {
        apply(&p, &mut ap);
        if project_nullspace {
            remove_mean(&mut ap);
        }
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            return SolveStats { iterations: it, residual: res, converged: false };
        }
        let alpha = rz / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        res = norm2(&r) / bnorm;
        debug_check_finite("cg", "residual r", it, res, &r);
        if res < opts.tol {
            if project_nullspace {
                remove_mean(x);
            }
            return SolveStats { iterations: it, residual: res, converged: true };
        }
        precond.apply(ctx, &r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    SolveStats { iterations: opts.max_iter, residual: res, converged: false }
}

#[cfg(test)]
mod tests {
    use super::super::precond::{Identity, Jacobi};
    use super::super::testmat::poisson1d;
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn solves_poisson1d() {
        let a = poisson1d(50);
        let xs: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut b = vec![0.0; 50];
        a.matvec(&xs, &mut b);
        let mut x = vec![0.0; 50];
        let st = cg(&ExecCtx::serial(), &a, &b, &mut x, &Identity, false, SolveOpts::default());
        assert!(st.converged, "residual {}", st.residual);
        for (xi, xsi) in x.iter().zip(&xs) {
            assert!((xi - xsi).abs() < 1e-7);
        }
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        let n = 100;
        // badly scaled SPD matrix: D^T poisson D
        let a0 = poisson1d(n);
        let mut trip = Vec::new();
        let scale = |i: usize| 1.0 + 50.0 * (i % 7) as f64;
        for r in 0..n {
            for k in a0.row_ptr[r]..a0.row_ptr[r + 1] {
                let c = a0.col_idx[k] as usize;
                trip.push((r, c, a0.vals[k] * scale(r) * scale(c)));
            }
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let mut x1 = vec![0.0; n];
        let mut x2 = vec![0.0; n];
        let ctx = ExecCtx::serial();
        let st_id = cg(&ctx, &a, &b, &mut x1, &Identity, false, SolveOpts::default());
        let st_j = cg(&ctx, &a, &b, &mut x2, &Jacobi::new(&a), false, SolveOpts::default());
        assert!(st_j.converged);
        assert!(
            st_j.iterations < st_id.iterations,
            "jacobi {} vs identity {}",
            st_j.iterations,
            st_id.iterations
        );
    }

    #[test]
    fn nullspace_projection_handles_singular_system() {
        // periodic Laplacian: singular, constant nullspace
        let n = 32;
        let mut trip = Vec::new();
        for i in 0..n {
            trip.push((i, i, 2.0));
            trip.push((i, (i + 1) % n, -1.0));
            trip.push((i, (i + n - 1) % n, -1.0));
        }
        let a = crate::sparse::Csr::from_triplets(n, &trip);
        // consistent RHS (mean zero)
        let mut b: Vec<f64> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * i as f64 / n as f64).sin())
            .collect();
        let mean = b.iter().sum::<f64>() / n as f64;
        b.iter_mut().for_each(|v| *v -= mean);
        let mut x = vec![0.0; n];
        let st = cg(&ExecCtx::serial(), &a, &b, &mut x, &Identity, true, SolveOpts::default());
        assert!(st.converged, "residual {}", st.residual);
        assert!(a.residual_norm(&x, &b) < 1e-8);
        // solution is mean-free
        assert!(x.iter().sum::<f64>().abs() / (n as f64) < 1e-10);
    }

    #[test]
    fn transpose_mode_solves_transposed_system() {
        // nonsymmetric but SPD-symmetrized test: use SPD matrix, transpose == same
        let a = poisson1d(20);
        let b: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let mut x1 = vec![0.0; 20];
        let mut x2 = vec![0.0; 20];
        cg(&ExecCtx::serial(), &a, &b, &mut x1, &Identity, false, SolveOpts::default());
        cg(
            &ExecCtx::serial(),
            &a,
            &b,
            &mut x2,
            &Identity,
            false,
            SolveOpts { transpose: true, ..Default::default() },
        );
        for (u, v) in x1.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite")]
    fn debug_guard_trips_on_poisoned_rhs() {
        let a = poisson1d(10);
        let mut b = vec![1.0; 10];
        b[3] = f64::NAN;
        let mut x = vec![0.0; 10];
        cg(&ExecCtx::serial(), &a, &b, &mut x, &Identity, false, SolveOpts::default());
    }

    #[test]
    fn prop_cg_residual_small_on_random_spd() {
        Prop::new(12, 0x51D).check("cg_spd", |rng: &mut Rng, _| {
            let n = 5 + rng.below(40);
            // SPD via M Mᵀ + I
            let m = super::super::testmat::random_dd(n, rng);
            let mt = m.transpose();
            // dense product for test construction
            let md = m.to_dense();
            let mtd = mt.to_dense();
            let mut trip = Vec::new();
            for r in 0..n {
                for c in 0..n {
                    let mut v = 0.0;
                    for k in 0..n {
                        v += md[r][k] * mtd[k][c];
                    }
                    if r == c {
                        v += 1.0;
                    }
                    if v.abs() > 1e-14 {
                        trip.push((r, c, v));
                    }
                }
            }
            let a = crate::sparse::Csr::from_triplets(n, &trip);
            let b = rng.normal_vec(n);
            let mut x = vec![0.0; n];
            let st = cg(&ExecCtx::serial(), &a, &b, &mut x, &Identity, false, SolveOpts::default());
            if !st.converged {
                return Err(format!("no convergence, res={}", st.residual));
            }
            let res = a.residual_norm(&x, &b);
            if res > 1e-6 {
                return Err(format!("residual {res}"));
            }
            Ok(())
        });
    }
}
