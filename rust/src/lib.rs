//! # PICT — a differentiable multi-block PISO solver
//!
//! Rust + JAX + Pallas reproduction of *"PICT — A Differentiable,
//! GPU-Accelerated Multi-Block PISO Solver for Simulation-Coupled Learning
//! Tasks in Fluid Dynamics"* (Franz et al., J. Comp. Phys. 2025).
//!
//! Layer 3 (this crate) owns the general solver: multi-block structured
//! meshes, FVM discretization, PISO time stepping, the DtO/OtD hybrid
//! adjoint engine, turbulence statistics, the CNN corrector substrate, the
//! parallel execution substrate ([`par`]) with the batched scenario runner
//! ([`coordinator::scenario`]), and the experiment coordinator. Layers 1–2
//! (python/compile) author Pallas kernels and the JAX PISO graph,
//! AOT-lowered to HLO text executed here via PJRT (the `runtime` module,
//! behind the off-by-default `pjrt` feature — it needs the unvendored
//! `xla`/`anyhow` crates, which the offline build does not ship).
//!
//! See DESIGN.md for the system inventory and experiment index.

pub mod adjoint;
pub mod coordinator;
pub mod fvm;
pub mod linsolve;
pub mod mesh;
pub mod nn;
pub mod par;
pub mod piso;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sparse;
pub mod stats;
pub mod train;
pub mod util;
