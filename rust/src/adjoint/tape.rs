//! Rollout tapes with selectable memory strategy.
//!
//! A [`Tape`] records an `n`-step PISO rollout for the backward sweep.
//! [`TapeStrategy::Full`] keeps every [`StepRecord`] plus every post-step
//! [`State`] — O(n) full-field memory, the limiter on long 3D rollouts.
//! [`TapeStrategy::Checkpoint`] keeps a full [`State`] (and boundary-value
//! snapshot) only every `every` steps and rematerializes the intermediate
//! records during [`Tape::backward`] by re-stepping from the nearest
//! checkpoint — O(n/k + k) fields resident at peak. Forward stepping is
//! deterministic (all Krylov warm starts and the advective-outflow update
//! derive from the checkpointed state and boundary values), so the
//! rematerialized records — and therefore the gradients — are bit-for-bit
//! identical to the full tape's.

use super::rollout::RolloutGrads;
use super::step::{backward_step, GradientPaths};
use crate::mesh::{BcValues, VectorField};
use crate::piso::{PisoSolver, State, StepRecord};

/// How much of the rollout a [`Tape`] keeps resident.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TapeStrategy {
    /// Eager: every step record and state is stored (O(n) fields).
    Full,
    /// Store a state snapshot every `every` steps; recompute the step
    /// records segment-by-segment during the backward sweep (O(n/k + k)
    /// fields, one extra forward pass of compute).
    Checkpoint { every: usize },
}

impl TapeStrategy {
    /// Short label for tables and reports (`full`, `ckpt(8)`).
    pub fn label(&self) -> String {
        match self {
            TapeStrategy::Full => "full".to_string(),
            TapeStrategy::Checkpoint { every } => format!("ckpt({every})"),
        }
    }

    /// Segment length for an `n`-step rollout under this strategy.
    pub fn segment(&self, n: usize) -> usize {
        match *self {
            TapeStrategy::Full => n.max(1),
            TapeStrategy::Checkpoint { every } => {
                assert!(every >= 1, "TapeStrategy::Checkpoint requires every >= 1");
                every
            }
        }
    }
}

/// Peak-memory diagnostics of one backward sweep.
#[derive(Clone, Copy, Debug)]
pub struct TapeBackwardStats {
    /// Largest number of *tape* f64 values resident at any point of the
    /// sweep: the stored fields plus (checkpoint mode) the largest
    /// rematerialized segment. Excludes the gradient outputs being
    /// accumulated (notably the n per-step `dsource` fields of
    /// [`RolloutGrads`]) — those are the caller's requested artifact and
    /// identical under every strategy.
    pub peak_resident_f64: usize,
}

/// Tape of a forward rollout under a [`TapeStrategy`].
pub struct Tape {
    strategy: TapeStrategy,
    n: usize,
    /// `Full`: one record per step. `Checkpoint`: empty (rematerialized).
    records: Vec<StepRecord>,
    /// `Full`: states\[s\] = state after step s (n+1 entries).
    /// `Checkpoint`: the checkpoint states, aligned with `checkpoint_steps`.
    states: Vec<State>,
    /// `Checkpoint`: the step index each entry of `states` precedes
    /// (0, k, 2k, …).
    checkpoint_steps: Vec<usize>,
    /// `Checkpoint`: boundary values at each checkpoint (the advective
    /// outflow update mutates them between steps, so re-stepping needs the
    /// values as they were).
    bc_snaps: Vec<Vec<BcValues>>,
    /// `Checkpoint`: state after the last step (`Full` reads `states[n]`
    /// instead of storing a second copy).
    final_state: Option<State>,
}

impl Tape {
    /// Run `n` steps from `state`, recording under `strategy`.
    /// `source_fn(step, state)` supplies the per-step source (e.g. a
    /// corrector network's output). With `Checkpoint`, `source_fn` must be
    /// a pure function of `(step, state)` — it is called again during
    /// [`Tape::backward`] to rematerialize the skipped records.
    pub fn record(
        solver: &mut PisoSolver,
        state: &mut State,
        n: usize,
        strategy: TapeStrategy,
        mut source_fn: impl FnMut(usize, &State) -> VectorField,
    ) -> Tape {
        let mut tape = Tape {
            strategy,
            n,
            records: Vec::new(),
            states: Vec::new(),
            checkpoint_steps: Vec::new(),
            bc_snaps: Vec::new(),
            final_state: None,
        };
        match strategy {
            TapeStrategy::Full => {
                tape.records.reserve(n);
                tape.states.reserve(n + 1);
                tape.states.push(state.clone());
                for step in 0..n {
                    let src = source_fn(step, state);
                    let mut rec = StepRecord::empty();
                    solver.step(state, &src, Some(&mut rec));
                    tape.records.push(rec);
                    tape.states.push(state.clone());
                }
            }
            TapeStrategy::Checkpoint { every } => {
                assert!(every >= 1, "TapeStrategy::Checkpoint requires every >= 1");
                for step in 0..n {
                    if step % every == 0 {
                        tape.checkpoint_steps.push(step);
                        tape.states.push(state.clone());
                        tape.bc_snaps.push(solver.mesh.bc_values.clone());
                    }
                    let src = source_fn(step, state);
                    solver.step(state, &src, None);
                }
                tape.final_state = Some(state.clone());
            }
        }
        tape
    }

    /// Number of steps recorded.
    pub fn steps(&self) -> usize {
        self.n
    }

    pub fn strategy(&self) -> TapeStrategy {
        self.strategy
    }

    /// State after the last recorded step.
    pub fn final_state(&self) -> &State {
        self.final_state
            .as_ref()
            .or_else(|| self.states.last())
            .expect("Tape::record stores at least the initial state")
    }

    /// Number of f64 values the tape keeps resident between record and
    /// backward (excludes the per-segment rematerialization buffers; see
    /// [`TapeBackwardStats::peak_resident_f64`] for the sweep peak).
    pub fn resident_f64(&self) -> usize {
        let bc: usize = self
            .bc_snaps
            .iter()
            .map(|snap| snap.iter().map(|b| 3 * b.vel.len()).sum::<usize>())
            .sum::<usize>();
        self.records.iter().map(|r| r.len_f64()).sum::<usize>()
            + self.states.iter().map(|s| s.len_f64()).sum::<usize>()
            + self.final_state.as_ref().map_or(0, |s| s.len_f64())
            + bc
    }

    /// Backpropagate through the rollout. `loss_grad(step, state)` returns
    /// the direct per-step cotangent (∂L/∂u, ∂L/∂p) on the state *after*
    /// step `step` (called once for every `step` in `0..n`, last step
    /// first); return zero fields for steps without loss. `source_fn` must
    /// be the function passed to [`Tape::record`] (only called under
    /// `Checkpoint`, to rematerialize). The solver is only mutated for
    /// checkpoint re-stepping and is left at its post-forward boundary
    /// state either way.
    pub fn backward(
        &self,
        solver: &mut PisoSolver,
        paths: GradientPaths,
        source_fn: impl FnMut(usize, &State) -> VectorField,
        loss_grad: impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
    ) -> RolloutGrads {
        self.backward_with_stats(solver, paths, source_fn, loss_grad).0
    }

    /// [`Tape::backward`] plus peak-memory diagnostics.
    pub fn backward_with_stats(
        &self,
        solver: &mut PisoSolver,
        paths: GradientPaths,
        mut source_fn: impl FnMut(usize, &State) -> VectorField,
        mut loss_grad: impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
    ) -> (RolloutGrads, TapeBackwardStats) {
        let mut acc = SweepAcc::new(solver);
        let mut peak_segment = 0usize;
        match self.strategy {
            TapeStrategy::Full => {
                for step in (0..self.n).rev() {
                    acc.sweep_step(
                        solver,
                        &self.records[step],
                        &self.states[step + 1],
                        step,
                        paths,
                        &mut loss_grad,
                    );
                }
            }
            TapeStrategy::Checkpoint { .. } => {
                // NOTE: coordinator::engine::episode carries a parallel copy
                // of this segment-replay scheme (it must also rematerialize
                // CNN activation tapes and couple the network-input gradient
                // into the sweep); keep the bc snapshot/restore order in sync.
                //
                // re-stepping advances the outflow boundary values again;
                // save them so the solver ends where the forward left it
                let final_bc = solver.mesh.bc_values.clone();
                for ci in (0..self.checkpoint_steps.len()).rev() {
                    let seg_start = self.checkpoint_steps[ci];
                    let seg_end = self
                        .checkpoint_steps
                        .get(ci + 1)
                        .copied()
                        .unwrap_or(self.n);
                    solver.mesh.bc_values = self.bc_snaps[ci].clone();
                    let mut st = self.states[ci].clone();
                    let seg_len = seg_end - seg_start;
                    let mut recs = Vec::with_capacity(seg_len);
                    let mut states_after = Vec::with_capacity(seg_len);
                    for step in seg_start..seg_end {
                        let src = source_fn(step, &st);
                        let mut rec = StepRecord::empty();
                        solver.step(&mut st, &src, Some(&mut rec));
                        recs.push(rec);
                        states_after.push(st.clone());
                    }
                    // the full-tape backward runs every step's adjoint with
                    // the solver at its post-forward boundary state; match
                    // it (the dnu/dbc boundary ops read bc values)
                    solver.mesh.bc_values = final_bc.clone();
                    let seg_f64 = recs.iter().map(|r| r.len_f64()).sum::<usize>()
                        + states_after.iter().map(|s| s.len_f64()).sum::<usize>();
                    peak_segment = peak_segment.max(seg_f64);
                    for (i, step) in (seg_start..seg_end).enumerate().rev() {
                        acc.sweep_step(
                            solver,
                            &recs[i],
                            &states_after[i],
                            step,
                            paths,
                            &mut loss_grad,
                        );
                    }
                }
                solver.mesh.bc_values = final_bc;
            }
        }
        let stats = TapeBackwardStats {
            peak_resident_f64: self.resident_f64() + peak_segment,
        };
        (acc.finish(), stats)
    }
}

/// Running accumulator of the backward sweep (shared by both strategies so
/// the chain of operations — and thus the bits — are identical).
struct SweepAcc {
    du: VectorField,
    dp: Vec<f64>,
    /// ∂L/∂S_t pushed in reverse step order.
    dsource_rev: Vec<VectorField>,
    dnu: f64,
    dbc: Vec<Vec<[f64; 3]>>,
}

impl SweepAcc {
    fn new(solver: &PisoSolver) -> SweepAcc {
        let ncells = solver.mesh.ncells;
        SweepAcc {
            du: VectorField::zeros(ncells),
            dp: vec![0.0; ncells],
            dsource_rev: Vec::new(),
            dnu: 0.0,
            dbc: solver
                .mesh
                .bc_values
                .iter()
                .map(|b| vec![[0.0; 3]; b.vel.len()])
                .collect(),
        }
    }

    fn sweep_step(
        &mut self,
        solver: &PisoSolver,
        rec: &StepRecord,
        state_after: &State,
        step: usize,
        paths: GradientPaths,
        loss_grad: &mut impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
    ) {
        // add the direct loss cotangent on the post-step state
        let (lu, lp) = loss_grad(step, state_after);
        assert!(
            lu.ncells() == self.du.ncells() && lp.len() == self.dp.len(),
            "rollout backward: loss_grad returned cotangents sized ({}, {}) for a {}-cell mesh",
            lu.ncells(),
            lp.len(),
            self.dp.len()
        );
        self.du.axpy(1.0, &lu);
        for (d, l) in self.dp.iter_mut().zip(&lp) {
            *d += l;
        }
        let g = backward_step(solver, rec, &self.du, &self.dp, paths);
        self.du = g.du_n;
        self.dp = g.dp_in;
        self.dsource_rev.push(g.dsource);
        self.dnu += g.dnu;
        for (acc, inc) in self.dbc.iter_mut().zip(&g.dbc) {
            for (a, b) in acc.iter_mut().zip(inc) {
                for c in 0..3 {
                    a[c] += b[c];
                }
            }
        }
    }

    fn finish(mut self) -> RolloutGrads {
        self.dsource_rev.reverse();
        RolloutGrads {
            du0: self.du,
            dp0: self.dp,
            dsource: self.dsource_rev,
            dnu: self.dnu,
            dbc: self.dbc,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::par::ExecCtx;
    use crate::piso::PisoConfig;

    fn tg_setup(n: usize) -> (PisoSolver, State) {
        let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.02, ..Default::default() },
            0.05,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).sin();
            state.u.comp[1][i] = -(6.28 * c[0]).sin() * 0.5;
        }
        (solver, state)
    }

    #[test]
    fn full_tape_records_n_steps_and_final_state() {
        let (mut solver, mut state) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let tape = Tape::record(&mut solver, &mut state, 3, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        assert_eq!(tape.steps(), 3);
        assert_eq!(tape.final_state().u, state.u);
        assert!(tape.resident_f64() > 0);
    }

    #[test]
    fn checkpoint_tape_stores_a_fraction_of_the_fields() {
        let (mut solver, state0) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let n = 12;
        let mut s_full = state0.clone();
        let full = Tape::record(&mut solver, &mut s_full, n, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        let mut s_chk = state0.clone();
        let chk = Tape::record(
            &mut solver,
            &mut s_chk,
            n,
            TapeStrategy::Checkpoint { every: 4 },
            |_, _| VectorField::zeros(ncells),
        );
        assert_eq!(s_full.u, s_chk.u, "strategies must not change the forward");
        assert_eq!(chk.checkpoint_steps, vec![0, 4, 8]);
        assert!(
            chk.resident_f64() * 3 < full.resident_f64(),
            "checkpoint {} vs full {}",
            chk.resident_f64(),
            full.resident_f64()
        );
    }

    #[test]
    fn checkpoint_backward_matches_full_bit_for_bit() {
        // uneven final segment on purpose (n=7, every=3 -> 3+3+1)
        let (mut solver, state0) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let n = 7;
        let loss = |step: usize, st: &State| {
            let mut du = VectorField::zeros(ncells);
            if step == n - 1 {
                du.comp[0].clone_from(&st.u.comp[0]);
            }
            (du, vec![0.0; ncells])
        };
        let mut s1 = state0.clone();
        let full = Tape::record(&mut solver, &mut s1, n, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        let g_full = full.backward(
            &mut solver,
            GradientPaths::FULL,
            |_, _| VectorField::zeros(ncells),
            loss,
        );
        let mut s2 = state0.clone();
        let chk = Tape::record(
            &mut solver,
            &mut s2,
            n,
            TapeStrategy::Checkpoint { every: 3 },
            |_, _| VectorField::zeros(ncells),
        );
        let g_chk = chk.backward(
            &mut solver,
            GradientPaths::FULL,
            |_, _| VectorField::zeros(ncells),
            loss,
        );
        assert_eq!(g_full.du0, g_chk.du0);
        assert_eq!(g_full.dp0, g_chk.dp0);
        assert_eq!(g_full.dnu, g_chk.dnu);
        assert_eq!(g_full.dsource.len(), g_chk.dsource.len());
        for (a, b) in g_full.dsource.iter().zip(&g_chk.dsource) {
            assert_eq!(a, b);
        }
    }

    #[test]
    #[should_panic(expected = "every >= 1")]
    fn zero_checkpoint_interval_is_rejected() {
        let (mut solver, mut state) = tg_setup(4);
        let ncells = solver.mesh.ncells;
        let _ = Tape::record(
            &mut solver,
            &mut state,
            2,
            TapeStrategy::Checkpoint { every: 0 },
            |_, _| VectorField::zeros(ncells),
        );
    }
}
