//! Rollout tapes with selectable memory strategy.
//!
//! A [`Tape`] records an `n`-step PISO rollout for the backward sweep.
//! [`TapeStrategy::Full`] keeps every [`StepRecord`] plus every post-step
//! [`State`] — O(n) full-field memory, the limiter on long 3D rollouts.
//! [`TapeStrategy::Checkpoint`] keeps a full [`State`] (and boundary-value
//! snapshot) only every `every` steps — O(n/k + k) fields at peak.
//! [`TapeStrategy::Revolve`] places at most `snapshots` states by the
//! binomial (Griewank–Walther) rule ([`super::revolve`]) — O(s + leaf)
//! fields at a DP-minimal recompute factor, the right trade on long
//! rollouts where even O(n/k) checkpoints do not fit.
//!
//! Both checkpointed strategies rematerialize the skipped records during
//! the backward sweep by re-stepping from stored snapshots, all through the
//! single [`Tape::replay_segments`] hook. Forward stepping is deterministic
//! (all Krylov warm starts and the advective-outflow update derive from the
//! snapshotted state and boundary values), so the rematerialized records —
//! and therefore the gradients — are bit-for-bit identical to the full
//! tape's.

use super::revolve::{Action, Schedule};
use super::rollout::RolloutGrads;
use super::step::{backward_step, GradientPaths};
use crate::mesh::{BcValues, VectorField};
use crate::piso::{PisoSolver, State, StepRecord};

/// How much of the rollout a [`Tape`] keeps resident.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TapeStrategy {
    /// Eager: every step record and state is stored (O(n) fields).
    Full,
    /// Store a state snapshot every `every` steps; recompute the step
    /// records segment-by-segment during the backward sweep (O(n/k + k)
    /// fields, one extra forward pass of compute).
    Checkpoint { every: usize },
    /// Binomial snapshot placement under a hard budget of `snapshots`
    /// resident states; the backward sweep follows a precomputed, validated
    /// [`Schedule`] of restore/advance/snapshot/sweep actions (O(s + leaf)
    /// fields, a bounded number of extra forward steps).
    Revolve { snapshots: usize },
}

impl TapeStrategy {
    /// Validated `Checkpoint` constructor: rejects `every == 0` as an
    /// error instead of panicking later in [`Tape::record`].
    pub fn checkpoint(every: usize) -> Result<TapeStrategy, String> {
        if every == 0 {
            return Err("checkpoint interval must be >= 1 (uniform:K with K >= 1)".to_string());
        }
        Ok(TapeStrategy::Checkpoint { every })
    }

    /// Validated `Revolve` constructor: rejects a zero snapshot budget.
    pub fn revolve(snapshots: usize) -> Result<TapeStrategy, String> {
        if snapshots == 0 {
            return Err("revolve snapshot budget must be >= 1 (revolve:S with S >= 1)".to_string());
        }
        Ok(TapeStrategy::Revolve { snapshots })
    }

    /// Parse a schedule spec: `full`, `uniform:K`, or `revolve:S`.
    /// Malformed specs are an `Err` describing the accepted grammar, never
    /// a panic — this is the CLI/server entry point.
    pub fn parse(spec: &str) -> Result<TapeStrategy, String> {
        let s = spec.trim();
        if s == "full" {
            return Ok(TapeStrategy::Full);
        }
        if let Some(k) = s.strip_prefix("uniform:") {
            let every = k
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("`uniform:K` needs an unsigned integer K, got `{k}`"))?;
            return TapeStrategy::checkpoint(every);
        }
        if let Some(v) = s.strip_prefix("revolve:") {
            let snapshots = v
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("`revolve:S` needs an unsigned integer S, got `{v}`"))?;
            return TapeStrategy::revolve(snapshots);
        }
        Err(format!(
            "unknown schedule `{spec}`: expected `full`, `uniform:K`, or `revolve:S`"
        ))
    }

    /// Check the parameters of an already-constructed strategy (e.g. one
    /// deserialized or built with struct syntax).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            TapeStrategy::Full => Ok(()),
            TapeStrategy::Checkpoint { every } => TapeStrategy::checkpoint(every).map(|_| ()),
            TapeStrategy::Revolve { snapshots } => TapeStrategy::revolve(snapshots).map(|_| ()),
        }
    }

    /// Short label for tables and reports (`full`, `ckpt(8)`,
    /// `revolve(8)`).
    pub fn label(&self) -> String {
        match self {
            TapeStrategy::Full => "full".to_string(),
            TapeStrategy::Checkpoint { every } => format!("ckpt({every})"),
            TapeStrategy::Revolve { snapshots } => format!("revolve({snapshots})"),
        }
    }

    /// Upper bound on the length of one rematerialized segment (the burst
    /// of records the backward sweep holds at once) for an `n`-step
    /// rollout under this strategy.
    pub fn segment(&self, n: usize) -> usize {
        match *self {
            TapeStrategy::Full => n.max(1),
            TapeStrategy::Checkpoint { every } => {
                assert!(every >= 1, "TapeStrategy::Checkpoint requires every >= 1");
                every
            }
            TapeStrategy::Revolve { snapshots } => {
                assert!(snapshots >= 1, "TapeStrategy::Revolve requires snapshots >= 1");
                super::revolve::leaf_for(n)
            }
        }
    }
}

/// Peak-memory diagnostics of one backward sweep.
#[derive(Clone, Copy, Debug)]
pub struct TapeBackwardStats {
    /// Largest number of *tape* f64 values resident at any point of the
    /// sweep: the stored fields plus (checkpointed modes) the live dynamic
    /// snapshots and the largest rematerialized segment. Excludes the
    /// gradient outputs being accumulated (notably the n per-step
    /// `dsource` fields of [`RolloutGrads`]) — those are the caller's
    /// requested artifact and identical under every strategy.
    pub peak_resident_f64: usize,
    /// Forward steps recomputed during the sweep (un-recorded re-advances
    /// plus recorded segment re-steps). 0 for `Full`, n for `Checkpoint`,
    /// schedule-dependent (≤ 2n at the bench shapes) for `Revolve`.
    pub replayed_steps: usize,
}

/// One rematerialized slice of the rollout, handed to the
/// [`Tape::replay_segments`] callback in descending segment order.
/// `records[i]` / `states_after[i]` belong to step `start + i`.
pub struct ReplaySegment<'a> {
    /// First step of the segment.
    pub start: usize,
    /// Step records for `start..start + records.len()`.
    pub records: &'a [StepRecord],
    /// Post-step states aligned with `records`.
    pub states_after: &'a [State],
}

/// Memory/recompute accounting of one [`Tape::replay_segments`] pass.
#[derive(Clone, Copy, Debug)]
pub struct ReplayStats {
    /// See [`TapeBackwardStats::peak_resident_f64`].
    pub peak_resident_f64: usize,
    /// See [`TapeBackwardStats::replayed_steps`].
    pub replayed_steps: usize,
}

/// Tape of a forward rollout under a [`TapeStrategy`].
pub struct Tape {
    strategy: TapeStrategy,
    n: usize,
    /// `Full`: one record per step. Checkpointed modes: empty
    /// (rematerialized).
    records: Vec<StepRecord>,
    /// `Full`: states\[s\] = state after step s (n+1 entries).
    /// Checkpointed modes: the snapshot states, aligned with
    /// `checkpoint_steps`.
    states: Vec<State>,
    /// Checkpointed modes: the step index each entry of `states` precedes
    /// (uniform: 0, k, 2k, …; revolve: the schedule's initial snapshots).
    checkpoint_steps: Vec<usize>,
    /// Checkpointed modes: boundary values at each snapshot (the advective
    /// outflow update mutates them between steps, so re-stepping needs the
    /// values as they were).
    bc_snaps: Vec<Vec<BcValues>>,
    /// Checkpointed modes: state after the last step (`Full` reads
    /// `states[n]` instead of storing a second copy).
    final_state: Option<State>,
    /// Checkpointed modes: the validated backward schedule (uniform layout
    /// for `Checkpoint`, binomial for `Revolve`).
    schedule: Option<Schedule>,
}

impl Tape {
    /// Run `n` steps from `state`, recording under `strategy`.
    /// `source_fn(step, state)` supplies the per-step source (e.g. a
    /// corrector network's output). With the checkpointed strategies,
    /// `source_fn` must be a pure function of `(step, state)` — it is
    /// called again during [`Tape::backward`] to rematerialize the skipped
    /// records.
    ///
    /// Panics on invalid strategy parameters (`every == 0`,
    /// `snapshots == 0`); use [`TapeStrategy::checkpoint`] /
    /// [`TapeStrategy::revolve`] / [`TapeStrategy::parse`] to surface those
    /// as `Err` at configuration time instead.
    pub fn record(
        solver: &mut PisoSolver,
        state: &mut State,
        n: usize,
        strategy: TapeStrategy,
        mut source_fn: impl FnMut(usize, &State) -> VectorField,
    ) -> Tape {
        let mut tape = Tape {
            strategy,
            n,
            records: Vec::new(),
            states: Vec::new(),
            checkpoint_steps: Vec::new(),
            bc_snaps: Vec::new(),
            final_state: None,
            schedule: None,
        };
        match strategy {
            TapeStrategy::Full => {
                tape.records.reserve(n);
                tape.states.reserve(n + 1);
                tape.states.push(state.clone());
                for step in 0..n {
                    let src = source_fn(step, state);
                    let mut rec = StepRecord::empty();
                    solver.step(state, &src, Some(&mut rec));
                    tape.records.push(rec);
                    tape.states.push(state.clone());
                }
            }
            TapeStrategy::Checkpoint { every } => {
                let schedule = Schedule::uniform(n, every).unwrap_or_else(|e| {
                    panic!("TapeStrategy::Checkpoint requires every >= 1: {e}")
                });
                tape.record_scheduled(solver, state, schedule, &mut source_fn);
            }
            TapeStrategy::Revolve { snapshots } => {
                let schedule = Schedule::build(n, snapshots).unwrap_or_else(|e| {
                    panic!("TapeStrategy::Revolve requires snapshots >= 1: {e}")
                });
                tape.record_scheduled(solver, state, schedule, &mut source_fn);
            }
        }
        tape
    }

    /// Forward pass for the checkpointed strategies: store a snapshot at
    /// each of the schedule's initial snapshot steps, discard everything
    /// else.
    fn record_scheduled(
        &mut self,
        solver: &mut PisoSolver,
        state: &mut State,
        schedule: Schedule,
        source_fn: &mut impl FnMut(usize, &State) -> VectorField,
    ) {
        let mut next_snap = 0usize;
        for step in 0..self.n {
            if schedule.init_snaps.get(next_snap) == Some(&step) {
                self.checkpoint_steps.push(step);
                self.states.push(state.clone());
                self.bc_snaps.push(solver.mesh.bc_values.clone());
                next_snap += 1;
            }
            let src = source_fn(step, state);
            solver.step(state, &src, None);
        }
        debug_assert_eq!(next_snap, schedule.init_snaps.len());
        self.final_state = Some(state.clone());
        self.schedule = Some(schedule);
    }

    /// Number of steps recorded.
    pub fn steps(&self) -> usize {
        self.n
    }

    pub fn strategy(&self) -> TapeStrategy {
        self.strategy
    }

    /// The backward schedule (`None` under [`TapeStrategy::Full`]).
    pub fn schedule(&self) -> Option<&Schedule> {
        self.schedule.as_ref()
    }

    /// State after the last recorded step.
    pub fn final_state(&self) -> &State {
        self.final_state
            .as_ref()
            .or_else(|| self.states.last())
            .expect("Tape::record stores at least the initial state")
    }

    /// Number of f64 values the tape keeps resident between record and
    /// backward (excludes the per-segment rematerialization buffers and
    /// dynamic revolve snapshots; see
    /// [`TapeBackwardStats::peak_resident_f64`] for the sweep peak).
    pub fn resident_f64(&self) -> usize {
        let bc: usize = self
            .bc_snaps
            .iter()
            .map(|snap| snap.iter().map(|b| 3 * b.vel.len()).sum::<usize>())
            .sum::<usize>();
        self.records.iter().map(|r| r.len_f64()).sum::<usize>()
            + self.states.iter().map(|s| s.len_f64()).sum::<usize>()
            + self.final_state.as_ref().map_or(0, |s| s.len_f64())
            + bc
    }

    /// Rematerialize the rollout segment by segment (descending) and hand
    /// each segment's records to `on_segment` — THE single place
    /// checkpoint re-stepping happens; every backward consumer (the
    /// gradient sweep below, the training engine's CNN-tape
    /// rematerialization) goes through this hook.
    ///
    /// Under `Full` the stored records are handed over as one segment and
    /// nothing is recomputed. Under the checkpointed strategies the
    /// validated [`Schedule`] drives snapshot restores and re-stepping;
    /// `source_fn` must be the function passed to [`Tape::record`]. Each
    /// segment is swept with the solver's boundary values at their
    /// post-forward state (matching `Full` bit-for-bit), and the solver is
    /// left at that boundary state on return.
    pub fn replay_segments(
        &self,
        solver: &mut PisoSolver,
        mut source_fn: impl FnMut(usize, &State) -> VectorField,
        mut on_segment: impl FnMut(&mut PisoSolver, ReplaySegment<'_>),
    ) -> ReplayStats {
        let schedule = match self.schedule.as_ref() {
            None => {
                // Full: everything is already resident; one segment.
                if self.n > 0 {
                    on_segment(
                        solver,
                        ReplaySegment {
                            start: 0,
                            records: &self.records,
                            states_after: &self.states[1..],
                        },
                    );
                }
                return ReplayStats {
                    peak_resident_f64: self.resident_f64(),
                    replayed_steps: 0,
                };
            }
            Some(schedule) => schedule,
        };
        // re-stepping advances the outflow boundary values again; save
        // them so the solver ends where the forward left it
        let final_bc = solver.mesh.bc_values.clone();
        let state_f64 = self.states.first().map_or(0, |s| s.len_f64());
        let bc_f64 = self
            .bc_snaps
            .first()
            .map_or(0, |snap| snap.iter().map(|b| 3 * b.vel.len()).sum::<usize>());
        let base = self.resident_f64();
        let mut dynamic: Vec<(usize, State, Vec<BcValues>)> = Vec::new();
        let mut peak = base;
        let mut replayed = 0usize;
        let mut cur: Option<State> = None;
        for action in &schedule.actions {
            match *action {
                Action::Restore { step } => {
                    // dynamic first: a dropped initial slot may have been
                    // re-snapshotted at a different point of the recursion
                    if let Some(d) = dynamic.iter().rev().find(|d| d.0 == step) {
                        cur = Some(d.1.clone());
                        solver.mesh.bc_values = d.2.clone();
                    } else {
                        let ci = self
                            .checkpoint_steps
                            .iter()
                            .position(|&c| c == step)
                            .expect("validated schedules restore only live snapshots");
                        cur = Some(self.states[ci].clone());
                        solver.mesh.bc_values = self.bc_snaps[ci].clone();
                    }
                }
                Action::Advance { from, to } => {
                    let st = cur
                        .as_mut()
                        .expect("validated schedules restore a snapshot before re-stepping");
                    for step in from..to {
                        let src = source_fn(step, st);
                        solver.step(st, &src, None);
                    }
                    replayed += to - from;
                }
                Action::Snapshot { step } => {
                    let st = cur
                        .as_ref()
                        .expect("validated schedules restore a snapshot before re-stepping");
                    dynamic.push((step, st.clone(), solver.mesh.bc_values.clone()));
                    peak = peak.max(base + dynamic.len() * (state_f64 + bc_f64));
                }
                Action::Drop { step } => {
                    // initial snapshots are owned by the tape and stay
                    // resident; only dynamic clones are actually freed
                    if let Some(i) = dynamic.iter().rposition(|d| d.0 == step) {
                        dynamic.remove(i);
                    }
                }
                Action::Sweep { from, to } => {
                    let st = cur
                        .as_mut()
                        .expect("validated schedules restore a snapshot before re-stepping");
                    let len = to - from;
                    let mut recs = Vec::with_capacity(len);
                    let mut states_after = Vec::with_capacity(len);
                    for step in from..to {
                        let src = source_fn(step, st);
                        let mut rec = StepRecord::empty();
                        solver.step(st, &src, Some(&mut rec));
                        recs.push(rec);
                        states_after.push(st.clone());
                    }
                    replayed += len;
                    let seg_f64 = recs.iter().map(|r| r.len_f64()).sum::<usize>()
                        + states_after.iter().map(|s| s.len_f64()).sum::<usize>();
                    peak = peak.max(base + dynamic.len() * (state_f64 + bc_f64) + seg_f64);
                    // the full-tape backward runs every step's adjoint with
                    // the solver at its post-forward boundary state; match
                    // it (the dnu/dbc boundary ops read bc values)
                    solver.mesh.bc_values = final_bc.clone();
                    on_segment(
                        solver,
                        ReplaySegment { start: from, records: &recs, states_after: &states_after },
                    );
                }
            }
        }
        solver.mesh.bc_values = final_bc;
        ReplayStats { peak_resident_f64: peak, replayed_steps: replayed }
    }

    /// Backpropagate through the rollout. `loss_grad(step, state)` returns
    /// the direct per-step cotangent (∂L/∂u, ∂L/∂p) on the state *after*
    /// step `step` (called once for every `step` in `0..n`, last step
    /// first); return zero fields for steps without loss. `source_fn` must
    /// be the function passed to [`Tape::record`] (only called under the
    /// checkpointed strategies, to rematerialize). The solver is only
    /// mutated for checkpoint re-stepping and is left at its post-forward
    /// boundary state either way.
    pub fn backward(
        &self,
        solver: &mut PisoSolver,
        paths: GradientPaths,
        source_fn: impl FnMut(usize, &State) -> VectorField,
        loss_grad: impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
    ) -> RolloutGrads {
        self.backward_with_stats(solver, paths, source_fn, loss_grad).0
    }

    /// [`Tape::backward`] plus peak-memory diagnostics.
    pub fn backward_with_stats(
        &self,
        solver: &mut PisoSolver,
        paths: GradientPaths,
        source_fn: impl FnMut(usize, &State) -> VectorField,
        mut loss_grad: impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
    ) -> (RolloutGrads, TapeBackwardStats) {
        let mut acc = SweepAcc::new(solver);
        let replay = self.replay_segments(solver, source_fn, |solver, seg| {
            for (i, step) in (seg.start..seg.start + seg.records.len()).enumerate().rev() {
                acc.sweep_step(
                    solver,
                    &seg.records[i],
                    &seg.states_after[i],
                    step,
                    paths,
                    &mut loss_grad,
                );
            }
        });
        let stats = TapeBackwardStats {
            peak_resident_f64: replay.peak_resident_f64,
            replayed_steps: replay.replayed_steps,
        };
        (acc.finish(), stats)
    }
}

/// Running accumulator of the backward sweep (shared by every strategy so
/// the chain of operations — and thus the bits — are identical).
struct SweepAcc {
    du: VectorField,
    dp: Vec<f64>,
    /// ∂L/∂S_t pushed in reverse step order.
    dsource_rev: Vec<VectorField>,
    dnu: f64,
    dbc: Vec<Vec<[f64; 3]>>,
}

impl SweepAcc {
    fn new(solver: &PisoSolver) -> SweepAcc {
        let ncells = solver.mesh.ncells;
        SweepAcc {
            du: VectorField::zeros(ncells),
            dp: vec![0.0; ncells],
            dsource_rev: Vec::new(),
            dnu: 0.0,
            dbc: solver
                .mesh
                .bc_values
                .iter()
                .map(|b| vec![[0.0; 3]; b.vel.len()])
                .collect(),
        }
    }

    fn sweep_step(
        &mut self,
        solver: &PisoSolver,
        rec: &StepRecord,
        state_after: &State,
        step: usize,
        paths: GradientPaths,
        loss_grad: &mut impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
    ) {
        // add the direct loss cotangent on the post-step state
        let (lu, lp) = loss_grad(step, state_after);
        assert!(
            lu.ncells() == self.du.ncells() && lp.len() == self.dp.len(),
            "rollout backward: loss_grad returned cotangents sized ({}, {}) for a {}-cell mesh",
            lu.ncells(),
            lp.len(),
            self.dp.len()
        );
        self.du.axpy(1.0, &lu);
        for (d, l) in self.dp.iter_mut().zip(&lp) {
            *d += l;
        }
        let g = backward_step(solver, rec, &self.du, &self.dp, paths);
        self.du = g.du_n;
        self.dp = g.dp_in;
        self.dsource_rev.push(g.dsource);
        self.dnu += g.dnu;
        for (acc, inc) in self.dbc.iter_mut().zip(&g.dbc) {
            for (a, b) in acc.iter_mut().zip(inc) {
                for c in 0..3 {
                    a[c] += b[c];
                }
            }
        }
    }

    fn finish(mut self) -> RolloutGrads {
        self.dsource_rev.reverse();
        RolloutGrads {
            du0: self.du,
            dp0: self.dp,
            dsource: self.dsource_rev,
            dnu: self.dnu,
            dbc: self.dbc,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::par::ExecCtx;
    use crate::piso::PisoConfig;

    fn tg_setup(n: usize) -> (PisoSolver, State) {
        let mesh = gen::periodic_box2d(n, n, 1.0, 1.0);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.02, ..Default::default() },
            0.05,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).sin();
            state.u.comp[1][i] = -(6.28 * c[0]).sin() * 0.5;
        }
        (solver, state)
    }

    #[test]
    fn full_tape_records_n_steps_and_final_state() {
        let (mut solver, mut state) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let tape = Tape::record(&mut solver, &mut state, 3, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        assert_eq!(tape.steps(), 3);
        assert_eq!(tape.final_state().u, state.u);
        assert!(tape.resident_f64() > 0);
    }

    #[test]
    fn checkpoint_tape_stores_a_fraction_of_the_fields() {
        let (mut solver, state0) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let n = 12;
        let mut s_full = state0.clone();
        let full = Tape::record(&mut solver, &mut s_full, n, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        let mut s_chk = state0.clone();
        let chk = Tape::record(
            &mut solver,
            &mut s_chk,
            n,
            TapeStrategy::Checkpoint { every: 4 },
            |_, _| VectorField::zeros(ncells),
        );
        assert_eq!(s_full.u, s_chk.u, "strategies must not change the forward");
        assert_eq!(chk.checkpoint_steps, vec![0, 4, 8]);
        assert!(
            chk.resident_f64() * 3 < full.resident_f64(),
            "checkpoint {} vs full {}",
            chk.resident_f64(),
            full.resident_f64()
        );
    }

    fn grads_with(strategy: TapeStrategy, n: usize) -> RolloutGrads {
        let (mut solver, state0) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let loss = move |step: usize, st: &State| {
            let mut du = VectorField::zeros(ncells);
            if step == n - 1 {
                du.comp[0].clone_from(&st.u.comp[0]);
            }
            (du, vec![0.0; ncells])
        };
        let mut s = state0.clone();
        let tape = Tape::record(&mut solver, &mut s, n, strategy, |_, _| {
            VectorField::zeros(ncells)
        });
        tape.backward(
            &mut solver,
            GradientPaths::FULL,
            |_, _| VectorField::zeros(ncells),
            loss,
        )
    }

    fn assert_same_grads(a: &RolloutGrads, b: &RolloutGrads) {
        assert_eq!(a.du0, b.du0);
        assert_eq!(a.dp0, b.dp0);
        assert_eq!(a.dnu, b.dnu);
        assert_eq!(a.dsource.len(), b.dsource.len());
        for (x, y) in a.dsource.iter().zip(&b.dsource) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn checkpoint_backward_matches_full_bit_for_bit() {
        // uneven final segment on purpose (n=7, every=3 -> 3+3+1)
        let g_full = grads_with(TapeStrategy::Full, 7);
        let g_chk = grads_with(TapeStrategy::Checkpoint { every: 3 }, 7);
        assert_same_grads(&g_full, &g_chk);
    }

    #[test]
    fn revolve_backward_matches_full_bit_for_bit() {
        // 24 steps under a 3-snapshot budget (6 macro steps of leaf 4 >
        // budget) exercises dynamic re-snapshotting: the binomial recursion
        // restores, re-advances, and re-places slots during the backward
        let g_full = grads_with(TapeStrategy::Full, 24);
        let g_rev = grads_with(TapeStrategy::Revolve { snapshots: 3 }, 24);
        assert_same_grads(&g_full, &g_rev);
        // an uneven tail (n=11 is not a leaf multiple) must also match
        let g_full_tail = grads_with(TapeStrategy::Full, 11);
        let g_rev_tail = grads_with(TapeStrategy::Revolve { snapshots: 2 }, 11);
        assert_same_grads(&g_full_tail, &g_rev_tail);
    }

    #[test]
    fn revolve_replay_cost_and_peak_are_accounted() {
        let (mut solver, state0) = tg_setup(6);
        let ncells = solver.mesh.ncells;
        let n = 24;
        let mut s = state0.clone();
        let tape = Tape::record(
            &mut solver,
            &mut s,
            n,
            TapeStrategy::Revolve { snapshots: 3 },
            |_, _| VectorField::zeros(ncells),
        );
        let sched = tape.schedule().expect("revolve tapes store their schedule");
        let expected_replay = sched.stats.replay_advances + sched.stats.swept_steps;
        let (_, stats) = tape.backward_with_stats(
            &mut solver,
            GradientPaths::FULL,
            |_, _| VectorField::zeros(ncells),
            |_, _| (VectorField::zeros(ncells), vec![0.0; ncells]),
        );
        assert_eq!(stats.replayed_steps, expected_replay);
        assert!(stats.peak_resident_f64 >= tape.resident_f64());
    }

    #[test]
    fn schedule_specs_parse_and_reject() {
        assert_eq!(TapeStrategy::parse("full"), Ok(TapeStrategy::Full));
        assert_eq!(
            TapeStrategy::parse("uniform:8"),
            Ok(TapeStrategy::Checkpoint { every: 8 })
        );
        assert_eq!(
            TapeStrategy::parse(" revolve:12 "),
            Ok(TapeStrategy::Revolve { snapshots: 12 })
        );
        assert!(TapeStrategy::parse("uniform:0").is_err());
        assert!(TapeStrategy::parse("revolve:0").is_err());
        assert!(TapeStrategy::parse("uniform:eight").is_err());
        assert!(TapeStrategy::parse("binomial:4").is_err());
        assert!(TapeStrategy::checkpoint(0).is_err());
        assert!(TapeStrategy::revolve(0).is_err());
        assert!(TapeStrategy::Checkpoint { every: 0 }.validate().is_err());
        assert!(TapeStrategy::Revolve { snapshots: 2 }.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "every >= 1")]
    fn zero_checkpoint_interval_is_rejected() {
        let (mut solver, mut state) = tg_setup(4);
        let ncells = solver.mesh.ncells;
        let _ = Tape::record(
            &mut solver,
            &mut state,
            2,
            TapeStrategy::Checkpoint { every: 0 },
            |_, _| VectorField::zeros(ncells),
        );
    }

    #[test]
    #[should_panic(expected = "snapshots >= 1")]
    fn zero_revolve_budget_is_rejected() {
        let (mut solver, mut state) = tg_setup(4);
        let ncells = solver.mesh.ncells;
        let _ = Tape::record(
            &mut solver,
            &mut state,
            2,
            TapeStrategy::Revolve { snapshots: 0 },
            |_, _| VectorField::zeros(ncells),
        );
    }
}
