//! Per-operation VJPs (paper A.25–A.48), each the exact adjoint of the
//! corresponding forward routine in `fvm`. Every function here mirrors its
//! forward twin line-by-line with the data flow reversed; the gradcheck
//! integration tests validate them against central finite differences.

use crate::mesh::{face_axis, face_sign, Mesh, NeighRef, VectorField};
use crate::sparse::Csr;

/// Adjoint of [`crate::fvm::pressure_gradient`] (A.26–A.27): given ∂(∇p)
/// return ∂p. Scatter form of the central difference with 0-Neumann ghosts.
pub fn pressure_gradient_adjoint(mesh: &Mesh, dg: &VectorField) -> Vec<f64> {
    let mut dp = vec![0.0; mesh.ncells];
    for cell in 0..mesh.ncells {
        let t = &mesh.t[cell];
        for ax in 0..mesh.dim {
            // w = ∂/∂(dp_face) of g contributions = 0.5 Σ_i T[ax][i] dg_i
            let mut w = 0.0;
            for i in 0..mesh.dim {
                w += t[ax][i] * dg.comp[i][cell];
            }
            w *= 0.5;
            match mesh.topo.at(cell, 2 * ax + 1) {
                NeighRef::Cell(n) => dp[n as usize] += w,
                _ => dp[cell] += w, // ghost = p_P
            }
            match mesh.topo.at(cell, 2 * ax) {
                NeighRef::Cell(n) => dp[n as usize] -= w,
                _ => dp[cell] -= w,
            }
        }
    }
    dp
}

/// Adjoint of [`crate::fvm::divergence_h`] (A.30) w.r.t. the cell field h:
/// given ∂(∇·h) return ∂h.
pub fn divergence_adjoint(mesh: &Mesh, dd: &[f64]) -> VectorField {
    // accumulate ∂(contravariant) then map back through U = J T u
    let mut dhc = vec![[0.0f64; 3]; mesh.ncells];
    for cell in 0..mesh.ncells {
        let w = dd[cell];
        if w == 0.0 {
            continue;
        }
        for face in 0..2 * mesh.dim {
            let ax = face_axis(face);
            let nf = face_sign(face);
            match mesh.topo.at(cell, face) {
                NeighRef::Cell(nb) => {
                    dhc[cell][ax] += nf * 0.5 * w;
                    dhc[nb as usize][ax] += nf * 0.5 * w;
                }
                NeighRef::Dirichlet { .. } => {} // boundary value, not h
                NeighRef::Neumann => {
                    dhc[cell][ax] += nf * w;
                }
            }
        }
    }
    let mut dh = VectorField::zeros(mesh.ncells);
    for cell in 0..mesh.ncells {
        let t = &mesh.t[cell];
        let j = mesh.jac[cell];
        for ax in 0..mesh.dim {
            let w = j * dhc[cell][ax];
            for i in 0..mesh.dim {
                dh.comp[i][cell] += t[ax][i] * w;
            }
        }
    }
    dh
}

/// Adjoint of the Dirichlet boundary flux inside `divergence_h` (A.34 term):
/// given ∂(∇·h), accumulate ∂(u_b) for every Dirichlet value set.
pub fn divergence_bc_adjoint(mesh: &Mesh, dd: &[f64], dbc: &mut [Vec<[f64; 3]>]) {
    for cell in 0..mesh.ncells {
        let w = dd[cell];
        if w == 0.0 {
            continue;
        }
        for face in 0..2 * mesh.dim {
            if let NeighRef::Dirichlet { values, face_cell } = mesh.topo.at(cell, face) {
                let ax = face_axis(face);
                let nf = face_sign(face);
                let t = &mesh.t[cell];
                let j = mesh.jac[cell];
                for i in 0..mesh.dim {
                    dbc[values as usize][face_cell as usize][i] += nf * w * j * t[ax][i];
                }
            }
        }
    }
}

/// Adjoint of [`crate::fvm::assemble_c`] (A.40–A.41): given the sparse
/// gradient ∂C (same layout as `c.vals`), accumulate ∂u_n (through the
/// advective face fluxes) and the global-viscosity gradient ∂ν (A.48-style,
/// assuming spatially uniform ν).
pub fn assemble_c_adjoint(
    mesh: &Mesh,
    c: &Csr,
    dc: &[f64],
    _nu: &[f64],
    du_n: &mut VectorField,
    dnu: &mut f64,
) {
    let mut duc = vec![[0.0f64; 3]; mesh.ncells];
    for cell in 0..mesh.ncells {
        let inv_j = 1.0 / mesh.jac[cell];
        let k_diag = c.find(cell, cell).expect("assembly puts a diagonal in every C row");
        let d_diag = dc[k_diag];
        for face in 0..2 * mesh.dim {
            let ax = face_axis(face);
            let nf = face_sign(face);
            match mesh.topo.at(cell, face) {
                NeighRef::Cell(nb) => {
                    let nb = nb as usize;
                    let k_off = c.find(cell, nb).expect("offdiag in C");
                    let d_off = dc[k_off];
                    // adv = 0.5 nf ūf /J appears in both entries
                    let dadv = d_off + d_diag;
                    let w = 0.5 * (0.5 * nf * inv_j) * dadv;
                    duc[cell][ax] += w;
                    duc[nb][ax] += w;
                    // anu/J appears as −(off) and +(diag)
                    let danu = (d_diag - d_off) * inv_j;
                    // anu = 0.5 (α_P ν_P + α_F ν_F); uniform-ν gradient:
                    *dnu += 0.5
                        * (mesh.alpha[cell][ax][ax] + mesh.alpha[nb][ax][ax])
                        * danu;
                }
                NeighRef::Dirichlet { .. } => {
                    // diag += 2 α ν / J
                    *dnu += 2.0 * mesh.alpha[cell][ax][ax] * inv_j * d_diag;
                }
                NeighRef::Neumann => {
                    // diag += nf U_P / J
                    duc[cell][ax] += nf * inv_j * d_diag;
                }
            }
        }
    }
    // map ∂U back through U^ax = J T[ax]·u
    for cell in 0..mesh.ncells {
        let t = &mesh.t[cell];
        let j = mesh.jac[cell];
        for ax in 0..mesh.dim {
            let w = j * duc[cell][ax];
            for i in 0..mesh.dim {
                du_n.comp[i][cell] += t[ax][i] * w;
            }
        }
    }
}

/// Adjoint of [`crate::fvm::boundary_flux_rhs`] (A.43, A.45): given
/// ∂(rhs_base), accumulate ∂ν (uniform) and ∂u_b per Dirichlet set.
/// The boundary term is quadratic in u_b via the advective flux.
pub fn boundary_flux_adjoint(
    mesh: &Mesh,
    nu: &[f64],
    drhs: &VectorField,
    dnu: &mut f64,
    dbc: &mut [Vec<[f64; 3]>],
) {
    for cell in 0..mesh.ncells {
        let inv_j = 1.0 / mesh.jac[cell];
        for face in 0..2 * mesh.dim {
            if let NeighRef::Dirichlet { values, face_cell } = mesh.topo.at(cell, face) {
                let ax = face_axis(face);
                let nf = face_sign(face);
                let ub = mesh.bc_values[values as usize].vel[face_cell as usize];
                let t = &mesh.t[cell];
                let j = mesh.jac[cell];
                let ubf = j * (t[ax][0] * ub[0] + t[ax][1] * ub[1] + t[ax][2] * ub[2]);
                let coef = (2.0 * mesh.alpha[cell][ax][ax] * nu[cell] - ubf * nf) * inv_j;
                for i in 0..mesh.dim {
                    let d = drhs.comp[i][cell];
                    if d == 0.0 {
                        continue;
                    }
                    // forward: out_i += ub_i · coef(ub)
                    // ∂/∂ub_i (direct): coef
                    dbc[values as usize][face_cell as usize][i] += coef * d;
                    // ∂/∂ub_k through coef: −(J T[ax][k]) nf / J · ub_i
                    for k in 0..mesh.dim {
                        dbc[values as usize][face_cell as usize][k] +=
                            -(j * t[ax][k]) * nf * inv_j * ub[i] * d;
                    }
                    // ∂/∂ν: 2 α / J · ub_i
                    *dnu += 2.0 * mesh.alpha[cell][ax][ax] * inv_j * ub[i] * d;
                }
            }
        }
    }
}

/// Adjoint of [`crate::fvm::assemble_pressure`] (A.29): given ∂M (sparse,
/// layout of `m.vals`, for the *negated* matrix M = −P), accumulate ∂(A⁻¹).
pub fn assemble_pressure_adjoint(mesh: &Mesh, m: &Csr, dm: &[f64], da_inv: &mut [f64]) {
    for cell in 0..mesh.ncells {
        let k_diag = m.find(cell, cell).expect("assembly puts a diagonal in every M row");
        let d_diag = dm[k_diag];
        for face in 0..2 * mesh.dim {
            let ax = face_axis(face);
            if let NeighRef::Cell(nb) = mesh.topo.at(cell, face) {
                let nb = nb as usize;
                let k_off = m.find(cell, nb).expect("offdiag in M");
                // forward: coef = 0.5(α_P aP + α_F aF); M_off −= coef; M_diag += coef
                let dcoef = d_diag - dm[k_off];
                da_inv[cell] += 0.5 * mesh.alpha[cell][ax][ax] * dcoef;
                da_inv[nb] += 0.5 * mesh.alpha[nb][ax][ax] * dcoef;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fvm;
    use crate::mesh::gen;
    use crate::util::rng::Rng;

    /// ⟨G p, w⟩ == ⟨p, Gᵀ w⟩ for random p, w (exact adjoint pairing).
    #[test]
    fn gradient_adjoint_pairing() {
        for mesh in [gen::periodic_box2d(7, 5, 1.3, 0.9), gen::cavity2d(6, 1.0, 1.0, true)] {
            let mut rng = Rng::new(42);
            let p = rng.normal_vec(mesh.ncells);
            let mut w = VectorField::zeros(mesh.ncells);
            for c in 0..2 {
                w.comp[c] = rng.normal_vec(mesh.ncells);
            }
            let g = fvm::pressure_gradient(&mesh, &p);
            let lhs: f64 = (0..2)
                .map(|c| g.comp[c].iter().zip(&w.comp[c]).map(|(a, b)| a * b).sum::<f64>())
                .sum();
            let dp = pressure_gradient_adjoint(&mesh, &w);
            let rhs: f64 = dp.iter().zip(&p).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-10 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        }
    }

    /// ⟨div h, s⟩ == ⟨h, Divᵀ s⟩.
    #[test]
    fn divergence_adjoint_pairing() {
        for mesh in [gen::periodic_box2d(6, 6, 1.0, 1.0), gen::channel2d(5, 7, 1.0, 1.0, 1.1, true)]
        {
            let mut rng = Rng::new(7);
            let mut h = VectorField::zeros(mesh.ncells);
            for c in 0..2 {
                h.comp[c] = rng.normal_vec(mesh.ncells);
            }
            let s = rng.normal_vec(mesh.ncells);
            // remove bc contribution: no-slip walls give zero boundary flux,
            // so div is linear in h here
            let d = fvm::divergence_h(&mesh, &h, None);
            let lhs: f64 = d.iter().zip(&s).map(|(a, b)| a * b).sum();
            let dh = divergence_adjoint(&mesh, &s);
            let rhs: f64 = (0..2)
                .map(|c| dh.comp[c].iter().zip(&h.comp[c]).map(|(a, b)| a * b).sum::<f64>())
                .sum();
            assert!((lhs - rhs).abs() < 1e-10 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        }
    }

    /// Directional FD check of the C-assembly adjoint w.r.t. u_n:
    /// ⟨dC/dε, W⟩ (FD) == ⟨du_n (adjoint of W), direction⟩.
    #[test]
    fn assemble_c_adjoint_matches_fd() {
        let mesh = gen::periodic_box2d(6, 5, 1.0, 1.0);
        let mut rng = Rng::new(3);
        let mut u = VectorField::zeros(mesh.ncells);
        for c in 0..2 {
            u.comp[c] = rng.normal_vec(mesh.ncells);
        }
        let nu = vec![0.05; mesh.ncells];
        let dt = 0.1;
        let mut c0 = fvm::c_structure(&mesh);
        fvm::assemble_c(&crate::par::ExecCtx::serial(), &mesh, &u, &nu, dt, &mut c0);
        // random cotangent on C values
        let w: Vec<f64> = rng.normal_vec(c0.nnz());
        // adjoint
        let mut du = VectorField::zeros(mesh.ncells);
        let mut dnu = 0.0;
        assemble_c_adjoint(&mesh, &c0, &w, &nu, &mut du, &mut dnu);
        // FD in a random direction
        let mut dir = VectorField::zeros(mesh.ncells);
        for c in 0..2 {
            dir.comp[c] = rng.normal_vec(mesh.ncells);
        }
        let eps = 1e-6;
        let mut up = u.clone();
        up.axpy(eps, &dir);
        let mut um = u.clone();
        um.axpy(-eps, &dir);
        let mut cp = c0.clone();
        let mut cm = c0.clone();
        fvm::assemble_c(&crate::par::ExecCtx::serial(), &mesh, &up, &nu, dt, &mut cp);
        fvm::assemble_c(&crate::par::ExecCtx::serial(), &mesh, &um, &nu, dt, &mut cm);
        let fd: f64 = cp
            .vals
            .iter()
            .zip(&cm.vals)
            .zip(&w)
            .map(|((a, b), wi)| (a - b) / (2.0 * eps) * wi)
            .sum();
        let an: f64 = (0..2)
            .map(|c| du.comp[c].iter().zip(&dir.comp[c]).map(|(a, b)| a * b).sum::<f64>())
            .sum();
        assert!((fd - an).abs() < 1e-6 * (1.0 + fd.abs()), "fd {fd} vs adjoint {an}");
    }

    /// FD check of the viscosity gradient through C assembly.
    #[test]
    fn assemble_c_nu_gradient_matches_fd() {
        let mesh = gen::cavity2d(5, 1.0, 1.0, false);
        let mut rng = Rng::new(9);
        let mut u = VectorField::zeros(mesh.ncells);
        for c in 0..2 {
            u.comp[c] = rng.normal_vec(mesh.ncells);
        }
        let nu0 = 0.07;
        let dt = 0.1;
        let mut c0 = fvm::c_structure(&mesh);
        let ctx = crate::par::ExecCtx::serial();
        fvm::assemble_c(&ctx, &mesh, &u, &vec![nu0; mesh.ncells], dt, &mut c0);
        let w: Vec<f64> = rng.normal_vec(c0.nnz());
        let mut du = VectorField::zeros(mesh.ncells);
        let mut dnu = 0.0;
        assemble_c_adjoint(&mesh, &c0, &w, &vec![nu0; mesh.ncells], &mut du, &mut dnu);
        let eps = 1e-6;
        let mut cp = c0.clone();
        let mut cm = c0.clone();
        fvm::assemble_c(&ctx, &mesh, &u, &vec![nu0 + eps; mesh.ncells], dt, &mut cp);
        fvm::assemble_c(&ctx, &mesh, &u, &vec![nu0 - eps; mesh.ncells], dt, &mut cm);
        let fd: f64 = cp
            .vals
            .iter()
            .zip(&cm.vals)
            .zip(&w)
            .map(|((a, b), wi)| (a - b) / (2.0 * eps) * wi)
            .sum();
        assert!((fd - dnu).abs() < 1e-6 * (1.0 + fd.abs()), "fd {fd} vs adjoint {dnu}");
    }

    /// FD check of the pressure-assembly adjoint w.r.t. A⁻¹.
    #[test]
    fn assemble_pressure_adjoint_matches_fd() {
        let mesh = gen::channel2d(5, 6, 1.0, 1.0, 1.1, true);
        let mut rng = Rng::new(11);
        let a_inv: Vec<f64> = (0..mesh.ncells).map(|_| 0.5 + rng.uniform()).collect();
        let mut m0 = fvm::pressure_structure(&mesh);
        fvm::assemble_pressure(&crate::par::ExecCtx::serial(), &mesh, &a_inv, &mut m0);
        let w: Vec<f64> = rng.normal_vec(m0.nnz());
        let mut da = vec![0.0; mesh.ncells];
        assemble_pressure_adjoint(&mesh, &m0, &w, &mut da);
        let dir: Vec<f64> = rng.normal_vec(mesh.ncells);
        let eps = 1e-7;
        let ap: Vec<f64> = a_inv.iter().zip(&dir).map(|(a, d)| a + eps * d).collect();
        let am: Vec<f64> = a_inv.iter().zip(&dir).map(|(a, d)| a - eps * d).collect();
        let mut mp = m0.clone();
        let mut mm = m0.clone();
        fvm::assemble_pressure(&crate::par::ExecCtx::serial(), &mesh, &ap, &mut mp);
        fvm::assemble_pressure(&crate::par::ExecCtx::serial(), &mesh, &am, &mut mm);
        let fd: f64 = mp
            .vals
            .iter()
            .zip(&mm.vals)
            .zip(&w)
            .map(|((a, b), wi)| (a - b) / (2.0 * eps) * wi)
            .sum();
        let an: f64 = da.iter().zip(&dir).map(|(a, b)| a * b).sum();
        assert!((fd - an).abs() < 1e-5 * (1.0 + fd.abs()), "fd {fd} vs adjoint {an}");
    }
}
