//! Backward pass of one full PISO step: chains the per-op VJPs of `ops`
//! with the OtD adjoint linear solves, honoring the selected
//! [`GradientPaths`] (paper §2.4).

use super::ops;
use crate::fvm;
use crate::linsolve::{bicgstab, cg, Jacobi, Precision, SolveOpts};
use crate::mesh::{Mesh, VectorField};
use crate::piso::{PisoSolver, StepRecord};
use crate::util::timer;

/// Which backward linear solves to include (§2.4): `adv` ⇒ J^Adv (transpose
/// BiCGStab through the predictor), `pressure` ⇒ J^P (transpose CG through
/// each corrector). Both false = the cheap `J_none` bypass gradients only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GradientPaths {
    pub adv: bool,
    pub pressure: bool,
}

impl GradientPaths {
    pub const FULL: GradientPaths = GradientPaths { adv: true, pressure: true };
    pub const ADV: GradientPaths = GradientPaths { adv: true, pressure: false };
    pub const P: GradientPaths = GradientPaths { adv: false, pressure: true };
    pub const NONE: GradientPaths = GradientPaths { adv: false, pressure: false };

    pub fn label(&self) -> &'static str {
        match (self.adv, self.pressure) {
            (true, true) => "Adv+P",
            (true, false) => "Adv",
            (false, true) => "P",
            (false, false) => "none",
        }
    }
}

/// Gradients of one PISO step w.r.t. its differentiable inputs.
#[derive(Clone, Debug)]
pub struct StepGrads {
    /// ∂L/∂u^{n-1}.
    pub du_n: VectorField,
    /// ∂L/∂p^{n-1} (the previous pressure feeds the predictor RHS).
    pub dp_in: Vec<f64>,
    /// ∂L/∂S (per-cell source; this is the NN-training signal).
    pub dsource: VectorField,
    /// ∂L/∂ν for spatially uniform viscosity.
    pub dnu: f64,
    /// ∂L/∂(Dirichlet boundary velocities), per bc-value set.
    pub dbc: Vec<Vec<[f64; 3]>>,
}

impl StepGrads {
    pub fn zeros(mesh: &Mesh) -> StepGrads {
        StepGrads {
            du_n: VectorField::zeros(mesh.ncells),
            dp_in: vec![0.0; mesh.ncells],
            dsource: VectorField::zeros(mesh.ncells),
            dnu: 0.0,
            dbc: mesh.bc_values.iter().map(|b| vec![[0.0; 3]; b.vel.len()]).collect(),
        }
    }
}

/// Panic with a clear message if the record's buffers don't match the
/// solver's system sizes (an empty or foreign record would otherwise die
/// deep in the sweep with a bare index panic).
fn validate_record(solver: &PisoSolver, rec: &StepRecord, du_out: &VectorField, dp_out: &[f64]) {
    let n = solver.mesh.ncells;
    let check = |what: &str, got: usize, want: usize| {
        assert!(
            got == want,
            "backward_step: StepRecord {what} has {got} entries, solver expects {want} \
             (was the record filled by PisoSolver::step on this mesh?)"
        );
    };
    assert!(
        rec.dt > 0.0,
        "backward_step: StepRecord.dt = {} — the record was never filled by a forward step",
        rec.dt
    );
    check("c_vals", rec.c_vals.len(), solver.c.nnz());
    check("pmat_vals", rec.pmat_vals.len(), solver.pmat.nnz());
    check("a_inv", rec.a_inv.len(), n);
    check("u_star", rec.u_star.ncells(), n);
    for (r, cr) in rec.correctors.iter().enumerate() {
        check(&format!("correctors[{r}].u_in"), cr.u_in.ncells(), n);
        check(&format!("correctors[{r}].h"), cr.h.ncells(), n);
        check(&format!("correctors[{r}].p"), cr.p.len(), n);
    }
    check("cotangent du_out", du_out.ncells(), n);
    check("cotangent dp_out", dp_out.len(), n);
}

/// Backpropagate `(du_out, dp_out)` through the recorded PISO step.
pub fn backward_step(
    solver: &PisoSolver,
    rec: &StepRecord,
    du_out: &VectorField,
    dp_out: &[f64],
    paths: GradientPaths,
) -> StepGrads {
    validate_record(solver, rec, du_out, dp_out);
    let mesh = &solver.mesh;
    // the adjoint's transposed solves run on the same pool as the forward
    // step: reuse the solver's context
    let ctx = &solver.ctx;
    let dim = mesh.dim;
    let n = mesh.ncells;
    let dt = rec.dt;

    // reconstruct the step's matrices from the tape
    let mut c = solver.c.clone();
    c.vals = rec.c_vals.clone();
    let mut m = solver.pmat.clone();
    m.vals = rec.pmat_vals.clone();
    let a_inv = &rec.a_inv;

    let mut grads = StepGrads::zeros(mesh);
    let mut d_c = vec![0.0; c.nnz()];
    let mut d_m = vec![0.0; m.nnz()];
    let mut d_a_inv = vec![0.0; n];
    let mut d_rhs_base = VectorField::zeros(n);

    // gradient flowing into the velocity entering the current corrector
    let mut du = du_out.clone();
    // gradient on the pressure produced by the current corrector
    let mut dp: Vec<f64> = dp_out.to_vec();

    // ---- correctors, backwards ----
    for r in (0..rec.correctors.len()).rev() {
        let cr = &rec.correctors[r];

        // u_r = h_r − a_inv ⊙ ∇p_r      (A.19/A.25–A.27)
        let g_r = fvm::pressure_gradient(mesh, &cr.p);
        let mut dh = du.clone();
        let mut dg = VectorField::zeros(n);
        for comp in 0..dim {
            for cell in 0..n {
                let d = du.comp[comp][cell];
                d_a_inv[cell] -= g_r.comp[comp][cell] * d;
                dg.comp[comp][cell] = -a_inv[cell] * d;
            }
        }
        let mut dp_r = dp.clone();
        let dp_from_g = ops::pressure_gradient_adjoint(mesh, &dg);
        for cell in 0..n {
            dp_r[cell] += dp_from_g[cell];
        }

        // pressure solve M p = −div  (OtD adjoint: M λ = dp_r, M symmetric)
        let mut dd = vec![0.0; n];
        if paths.pressure {
            let mut lambda = vec![0.0; n];
            let precond = Jacobi::new(&m);
            timer::scoped("adj_p_solve", || {
                cg(
                    ctx,
                    &m,
                    &dp_r,
                    &mut lambda,
                    &precond,
                    true,
                    SolveOpts {
                        tol: solver.cfg.p_opts.tol,
                        max_iter: solver.cfg.p_opts.max_iter,
                        transpose: false,
                        precision: Precision::F64,
                    },
                )
            });
            // rhs was −div ⇒ ∂(div) = −λ ; ∂M = −λ ⊗ p
            for cell in 0..n {
                dd[cell] = -lambda[cell];
            }
            for row in 0..n {
                if lambda[row] == 0.0 {
                    continue;
                }
                for k in m.row_ptr[row]..m.row_ptr[row + 1] {
                    d_m[k] -= lambda[row] * cr.p[m.col_idx[k] as usize];
                }
            }
        }

        // div = ∇·h (+ boundary flux)   (A.30 + A.34-like bc term)
        let dh_from_div = ops::divergence_adjoint(mesh, &dd);
        dh.axpy(1.0, &dh_from_div);
        ops::divergence_bc_adjoint(mesh, &dd, &mut grads.dbc);

        // h = a_inv ⊙ (rhs_base − H u_prev)   (A.17/A.33–A.39)
        let mut du_prev = VectorField::zeros(n);
        for comp in 0..dim {
            for cell in 0..n {
                let d = dh.comp[comp][cell];
                if d == 0.0 {
                    continue;
                }
                // q = rhs_base − H u_prev = h / a_inv
                let q = cr.h.comp[comp][cell] / a_inv[cell];
                d_a_inv[cell] += q * d;
                d_rhs_base.comp[comp][cell] += a_inv[cell] * d;
            }
            // du_prev = −Hᵀ (a_inv ⊙ dh) ; dH = −(a_inv dh) ⊗ u_prev (A.39)
            for row in 0..n {
                let w = a_inv[row] * dh.comp[comp][row];
                if w == 0.0 {
                    continue;
                }
                for k in c.row_ptr[row]..c.row_ptr[row + 1] {
                    let col = c.col_idx[k] as usize;
                    if col != row {
                        du_prev.comp[comp][col] -= c.vals[k] * w;
                        d_c[k] -= w * cr.u_in.comp[comp][col];
                    }
                }
            }
        }

        du = du_prev;
        // earlier correctors' pressures only seeded CG initial guesses —
        // no mathematical dependence, so the pressure cotangent resets
        dp = vec![0.0; n];
    }

    // ---- predictor: C u* = rhs_base − ∇p_in ----
    if paths.adv {
        for comp in 0..dim {
            let mut lambda = vec![0.0; n];
            let precond = Jacobi::new(&c);
            timer::scoped("adj_adv_solve", || {
                bicgstab(
                    ctx,
                    &c,
                    &du.comp[comp],
                    &mut lambda,
                    &precond,
                    false,
                    SolveOpts {
                        tol: solver.cfg.adv_opts.tol,
                        max_iter: solver.cfg.adv_opts.max_iter,
                        transpose: true,
                        precision: Precision::F64,
                    },
                )
            });
            // ∂rhs_pred = λ ; ∂C = −λ ⊗ u*
            for cell in 0..n {
                d_rhs_base.comp[comp][cell] += lambda[cell];
            }
            for row in 0..n {
                if lambda[row] == 0.0 {
                    continue;
                }
                for k in c.row_ptr[row]..c.row_ptr[row + 1] {
                    d_c[k] -= lambda[row] * rec.u_star.comp[comp][c.col_idx[k] as usize];
                }
            }
            // rhs_pred = rhs_base − ∇p_in ⇒ ∂(∇p_in) = −λ
            let mut dg = VectorField::zeros(n);
            dg.comp[comp] = lambda.iter().map(|v| -v).collect();
            let dp_in = ops::pressure_gradient_adjoint(mesh, &dg);
            for cell in 0..n {
                grads.dp_in[cell] += dp_in[cell];
            }
        }
    }

    // ---- M = assemble_pressure(a_inv)  ⇒ d_a_inv ----
    ops::assemble_pressure_adjoint(mesh, &m, &d_m, &mut d_a_inv);

    // ---- a_inv = 1/diag(C)  ⇒ dC_diag −= a_inv² d_a_inv (A.38-like) ----
    for cell in 0..n {
        let k = c.find(cell, cell).expect("assembly puts a diagonal in every C row");
        d_c[k] -= a_inv[cell] * a_inv[cell] * d_a_inv[cell];
    }

    // ---- C = assemble_c(u_n, ν, dt) (A.40–A.41) ----
    ops::assemble_c_adjoint(mesh, &c, &d_c, &solver.nu, &mut grads.du_n, &mut grads.dnu);

    // ---- rhs_base = bflux(ν, bc) + u_n/Δt + S (A.42–A.45) ----
    for comp in 0..dim {
        for cell in 0..n {
            let d = d_rhs_base.comp[comp][cell];
            grads.du_n.comp[comp][cell] += d / dt;
            grads.dsource.comp[comp][cell] += d;
        }
    }
    ops::boundary_flux_adjoint(mesh, &solver.nu, &d_rhs_base, &mut grads.dnu, &mut grads.dbc);

    grads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::par::ExecCtx;
    use crate::piso::{PisoConfig, State};

    /// Backward step runs and produces finite gradients for all paths.
    #[test]
    fn backward_produces_finite_grads() {
        let mesh = gen::periodic_box2d(8, 6, 1.0, 1.0);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.02, ..Default::default() },
            0.02,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).cos() * 0.5;
            state.u.comp[1][i] = (6.28 * c[0]).sin() * 0.3;
        }
        let src = VectorField::zeros(solver.mesh.ncells);
        let mut rec = StepRecord::empty();
        solver.step(&mut state, &src, Some(&mut rec));
        let du_out = {
            let mut f = VectorField::zeros(solver.mesh.ncells);
            f.comp[0].iter_mut().for_each(|v| *v = 1.0);
            f
        };
        let dp_out = vec![0.0; solver.mesh.ncells];
        for paths in [GradientPaths::FULL, GradientPaths::ADV, GradientPaths::P, GradientPaths::NONE]
        {
            let g = backward_step(&solver, &rec, &du_out, &dp_out, paths);
            let s: f64 = g.du_n.comp[0].iter().sum();
            assert!(s.is_finite(), "{}: non-finite grads", paths.label());
            // some gradient must reach the input even for `none`
            let norm: f64 = g.du_n.comp[0].iter().map(|v| v * v).sum();
            assert!(norm > 0.0, "{}: zero gradient", paths.label());
        }
    }

    #[test]
    fn path_labels() {
        assert_eq!(GradientPaths::FULL.label(), "Adv+P");
        assert_eq!(GradientPaths::NONE.label(), "none");
    }

    #[test]
    #[should_panic(expected = "never filled by a forward step")]
    fn empty_record_is_rejected_with_clear_error() {
        let mesh = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let solver = PisoSolver::new(mesh, PisoConfig::default(), 0.01, ExecCtx::from_env());
        let du = VectorField::zeros(solver.mesh.ncells);
        let dp = vec![0.0; solver.mesh.ncells];
        backward_step(&solver, &StepRecord::empty(), &du, &dp, GradientPaths::NONE);
    }

    #[test]
    #[should_panic(expected = "StepRecord a_inv")]
    fn truncated_record_is_rejected_with_clear_error() {
        let mesh = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let mut solver = PisoSolver::new(mesh, PisoConfig::default(), 0.01, ExecCtx::from_env());
        let mut state = State::zeros(&solver.mesh);
        let src = VectorField::zeros(solver.mesh.ncells);
        let mut rec = StepRecord::empty();
        solver.step(&mut state, &src, Some(&mut rec));
        rec.a_inv.pop();
        let du = VectorField::zeros(solver.mesh.ncells);
        let dp = vec![0.0; solver.mesh.ncells];
        backward_step(&solver, &rec, &du, &dp, GradientPaths::NONE);
    }
}
