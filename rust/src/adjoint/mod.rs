//! The DtO/OtD hybrid adjoint engine (paper §2.3–2.4, Appendix A.5).
//!
//! The forward PISO step records every intermediate on a
//! [`StepRecord`](crate::piso::StepRecord) (DtO tape); the backward pass
//! chains hand-derived per-operation VJPs, treating the two embedded linear
//! solves in OtD fashion: for `A x = b`, the incoming gradient ∂x is
//! propagated by solving `Aᵀ ∂b = ∂x` and the matrix gradient is the sparse
//! outer product `∂A = −∂b ⊗ x` (Giles 2008).
//!
//! [`GradientPaths`] selects which backward linear solves participate,
//! reproducing the paper's Adv+P / Adv / P / none variants (§2.4): even
//! with both solves skipped, the `J_none` bypass paths of eq. (8) still
//! deliver per-cell gradients from output to input.
//!
//! Multi-step rollouts record a [`Tape`] whose memory strategy is
//! selectable ([`TapeStrategy`]): eager full-field storage, O(n/k + k)
//! uniform checkpointing, or binomial [`revolve`] schedules under a hard
//! snapshot budget — both checkpointed modes re-step segments during the
//! backward sweep through the single [`Tape::replay_segments`] hook
//! (bit-for-bit equal gradients; see [`tape`]).
//!
//! Omitted (as in the paper, A.29/A.41): gradients of the non-orthogonal
//! deferred-correction terms and of the mesh transformation metrics. The
//! advective-outflow boundary update is treated as an external state
//! transition (no gradient), like the paper's warm-up steps.

pub mod ops;
pub mod revolve;
pub mod rollout;
pub mod step;
pub mod tape;

pub use rollout::{rollout_backward, RolloutGrads};
pub use step::{backward_step, GradientPaths, StepGrads};
pub use tape::{ReplaySegment, ReplayStats, Tape, TapeBackwardStats, TapeStrategy};
