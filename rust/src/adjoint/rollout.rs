//! Multi-step rollout differentiation (paper eq. 5): run n PISO steps
//! recording a tape per step, then backpropagate a terminal (and/or
//! per-step) loss gradient through the whole rollout by chaining
//! [`backward_step`], accumulating gradients for the initial state, the
//! per-step sources (the NN training signal), viscosity, and boundary
//! values.

use super::step::{backward_step, GradientPaths, StepGrads};
use crate::mesh::VectorField;
use crate::piso::{PisoSolver, State, StepRecord};

/// Tape of a forward rollout.
pub struct RolloutTape {
    pub records: Vec<StepRecord>,
    /// State after each step (states\[0\] = initial state).
    pub states: Vec<State>,
}

impl RolloutTape {
    /// Run `n` steps from `state`, recording each. `source_fn(step, state)`
    /// supplies the per-step source (e.g. a corrector network's output).
    pub fn record(
        solver: &mut PisoSolver,
        state: &mut State,
        n: usize,
        mut source_fn: impl FnMut(usize, &State) -> VectorField,
    ) -> RolloutTape {
        let mut records = Vec::with_capacity(n);
        let mut states = Vec::with_capacity(n + 1);
        states.push(state.clone());
        for step in 0..n {
            let src = source_fn(step, state);
            let mut rec = empty_record();
            solver.step(state, &src, Some(&mut rec));
            records.push(rec);
            states.push(state.clone());
        }
        RolloutTape { records, states }
    }
}

pub(crate) fn empty_record() -> StepRecord {
    StepRecord {
        dt: 0.0,
        u_n: VectorField::zeros(0),
        p_in: vec![],
        source: VectorField::zeros(0),
        c_vals: vec![],
        a_inv: vec![],
        pmat_vals: vec![],
        rhs_base: VectorField::zeros(0),
        grad_p_in: VectorField::zeros(0),
        u_star: VectorField::zeros(0),
        correctors: vec![],
    }
}

/// Accumulated gradients of a rollout.
pub struct RolloutGrads {
    /// ∂L/∂u⁰ (initial velocity).
    pub du0: VectorField,
    /// ∂L/∂p⁰.
    pub dp0: Vec<f64>,
    /// ∂L/∂S_t per recorded step.
    pub dsource: Vec<VectorField>,
    /// ∂L/∂ν (uniform).
    pub dnu: f64,
    /// ∂L/∂(boundary velocities), summed over steps.
    pub dbc: Vec<Vec<[f64; 3]>>,
}

/// Backpropagate through the tape. `loss_grad(step, state)` returns the
/// direct per-step cotangent (∂L/∂u_t, ∂L/∂p_t) for the state *after* step
/// `step` (1-based states; called with `step` in `0..n` for `states[step+1]`);
/// return zero fields for steps without loss.
pub fn rollout_backward(
    solver: &PisoSolver,
    tape: &RolloutTape,
    paths: GradientPaths,
    mut loss_grad: impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
) -> RolloutGrads {
    let n = tape.records.len();
    let ncells = solver.mesh.ncells;
    let mut du = VectorField::zeros(ncells);
    let mut dp = vec![0.0; ncells];
    let mut dsource = Vec::with_capacity(n);
    let mut dnu = 0.0;
    let mut dbc: Vec<Vec<[f64; 3]>> =
        solver.mesh.bc_values.iter().map(|b| vec![[0.0; 3]; b.vel.len()]).collect();

    for step in (0..n).rev() {
        // add the direct loss cotangent on the post-step state
        let (lu, lp) = loss_grad(step, &tape.states[step + 1]);
        du.axpy(1.0, &lu);
        for c in 0..ncells {
            dp[c] += lp[c];
        }
        let g: StepGrads = backward_step(solver, &tape.records[step], &du, &dp, paths);
        du = g.du_n;
        dp = g.dp_in;
        dsource.push(g.dsource);
        dnu += g.dnu;
        for (acc, inc) in dbc.iter_mut().zip(&g.dbc) {
            for (a, b) in acc.iter_mut().zip(inc) {
                for c in 0..3 {
                    a[c] += b[c];
                }
            }
        }
    }
    dsource.reverse();
    RolloutGrads { du0: du, dp0: dp, dsource, dnu, dbc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::piso::PisoConfig;

    #[test]
    fn tape_records_n_steps_and_states() {
        let mesh = gen::periodic_box2d(6, 6, 1.0, 1.0);
        let mut solver =
            PisoSolver::new(mesh, PisoConfig { dt: 0.02, ..Default::default() }, 0.05);
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).sin();
        }
        let ncells = solver.mesh.ncells;
        let tape = RolloutTape::record(&mut solver, &mut state, 3, |_, _| {
            VectorField::zeros(ncells)
        });
        assert_eq!(tape.records.len(), 3);
        assert_eq!(tape.states.len(), 4);
        // final tape state matches the advanced state
        assert_eq!(tape.states[3].u, state.u);
    }

    #[test]
    fn rollout_backward_accumulates_per_step_sources() {
        let mesh = gen::periodic_box2d(6, 6, 1.0, 1.0);
        let mut solver =
            PisoSolver::new(mesh, PisoConfig { dt: 0.02, ..Default::default() }, 0.05);
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).sin() * 0.4;
        }
        let ncells = solver.mesh.ncells;
        let tape =
            RolloutTape::record(&mut solver, &mut state, 2, |_, _| VectorField::zeros(ncells));
        // loss only on the last state: L = Σ u_x
        let g = rollout_backward(&solver, &tape, GradientPaths::FULL, |step, _| {
            let mut du = VectorField::zeros(ncells);
            if step == 1 {
                du.comp[0].iter_mut().for_each(|v| *v = 1.0);
            }
            (du, vec![0.0; ncells])
        });
        assert_eq!(g.dsource.len(), 2);
        let n0: f64 = g.dsource[0].comp[0].iter().map(|v| v.abs()).sum();
        let n1: f64 = g.dsource[1].comp[0].iter().map(|v| v.abs()).sum();
        assert!(n0 > 0.0 && n1 > 0.0, "sources receive gradient ({n0}, {n1})");
        let nin: f64 = g.du0.comp[0].iter().map(|v| v.abs()).sum();
        assert!(nin > 0.0, "initial state receives gradient");
    }
}
