//! Multi-step rollout differentiation (paper eq. 5): run n PISO steps
//! recording a [`Tape`](super::Tape), then backpropagate a terminal (and/or
//! per-step) loss gradient through the whole rollout by chaining
//! [`backward_step`](super::backward_step), accumulating gradients for the
//! initial state, the per-step sources (the NN training signal), viscosity,
//! and boundary values. The tape's memory strategy (eager vs checkpointed)
//! lives in [`tape`](super::tape); this module owns the gradient bundle and
//! the one-call convenience wrapper.

use super::step::GradientPaths;
use super::tape::Tape;
use crate::mesh::VectorField;
use crate::piso::{PisoSolver, State};

/// Accumulated gradients of a rollout.
pub struct RolloutGrads {
    /// ∂L/∂u⁰ (initial velocity).
    pub du0: VectorField,
    /// ∂L/∂p⁰.
    pub dp0: Vec<f64>,
    /// ∂L/∂S_t per recorded step.
    pub dsource: Vec<VectorField>,
    /// ∂L/∂ν (uniform).
    pub dnu: f64,
    /// ∂L/∂(boundary velocities), summed over steps.
    pub dbc: Vec<Vec<[f64; 3]>>,
}

/// Backpropagate through a recorded tape — convenience wrapper over
/// [`Tape::backward`]. `source_fn` must be the function the tape was
/// recorded with (re-invoked for checkpointed tapes); `loss_grad(step,
/// state)` returns the direct per-step cotangent (∂L/∂u_t, ∂L/∂p_t) for the
/// state *after* step `step` (called with `step` in `0..n`); return zero
/// fields for steps without loss.
pub fn rollout_backward(
    solver: &mut PisoSolver,
    tape: &Tape,
    paths: GradientPaths,
    source_fn: impl FnMut(usize, &State) -> VectorField,
    loss_grad: impl FnMut(usize, &State) -> (VectorField, Vec<f64>),
) -> RolloutGrads {
    tape.backward(solver, paths, source_fn, loss_grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::TapeStrategy;
    use crate::mesh::gen;
    use crate::par::ExecCtx;
    use crate::piso::PisoConfig;

    #[test]
    fn rollout_backward_accumulates_per_step_sources() {
        let mesh = gen::periodic_box2d(6, 6, 1.0, 1.0);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 0.02, ..Default::default() },
            0.05,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (6.28 * c[1]).sin() * 0.4;
        }
        let ncells = solver.mesh.ncells;
        let tape = Tape::record(&mut solver, &mut state, 2, TapeStrategy::Full, |_, _| {
            VectorField::zeros(ncells)
        });
        // loss only on the last state: L = Σ u_x
        let g = rollout_backward(
            &mut solver,
            &tape,
            GradientPaths::FULL,
            |_, _| VectorField::zeros(ncells),
            |step, _| {
                let mut du = VectorField::zeros(ncells);
                if step == 1 {
                    du.comp[0].iter_mut().for_each(|v| *v = 1.0);
                }
                (du, vec![0.0; ncells])
            },
        );
        assert_eq!(g.dsource.len(), 2);
        let n0: f64 = g.dsource[0].comp[0].iter().map(|v| v.abs()).sum();
        let n1: f64 = g.dsource[1].comp[0].iter().map(|v| v.abs()).sum();
        assert!(n0 > 0.0 && n1 > 0.0, "sources receive gradient ({n0}, {n1})");
        let nin: f64 = g.du0.comp[0].iter().map(|v| v.abs()).sum();
        assert!(nin > 0.0, "initial state receives gradient");
    }
}
