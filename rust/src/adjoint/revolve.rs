//! Binomial (Griewank–Walther "Revolve") checkpoint schedules.
//!
//! A [`Schedule`] is a precomputed action list that tells the tape's
//! backward sweep how to rematerialize an `n`-step rollout while holding at
//! most `snapshots` full states resident: restore a snapshot, re-advance
//! without recording, drop/place snapshots, and sweep short recorded
//! segments in descending order. Schedules are *validated by construction*
//! — [`Schedule::build`] simulates every emitted action and proves that
//! every restore hits a live snapshot, the live-snapshot count never
//! exceeds the budget, and the sweeps cover `0..n` exactly once in
//! descending order — before handing the schedule to the tape.
//!
//! The placement is binomial in macro-steps: the rollout is tiled into
//! leaves of [`Schedule::leaf`] steps (a leaf is re-stepped *with*
//! recording just before its adjoint sweep, so leaf length bounds the
//! segment buffer exactly like `Checkpoint { every }` bounds its segment),
//! and an exact dynamic program over the macro grid picks the split points
//! — the classic C(s+t, t) binomial shape, but optimal for the finite
//! grid rather than asymptotic. Memory is O(s + leaf) fields; recompute is
//! the DP-minimal number of re-forwards (≤ 2 forwards total at the bench
//! point n=64, s=8: 36 re-advances + 64 recorded re-steps = 100 ≤ 2·64).

/// One backward-phase action. Step indices are *real* step numbers
/// (`0..n`); a snapshot at `step` holds the state *before* that step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Load the snapshot at `step` (state + boundary values) as the
    /// current position.
    Restore { step: usize },
    /// Re-step `from..to` without recording (source_fn re-evaluated).
    Advance { from: usize, to: usize },
    /// Store the current position (must equal `step`) as a snapshot.
    Snapshot { step: usize },
    /// Free the snapshot at `step`.
    Drop { step: usize },
    /// Re-step `from..to` with recording, then run the adjoint sweep over
    /// the segment. Sweeps are emitted in descending, exactly-covering
    /// order: the first sweep ends at `n`, each next ends where the
    /// previous began, the last begins at 0.
    Sweep { from: usize, to: usize },
}

/// Cost/shape diagnostics of a schedule, proven by simulation in
/// [`Schedule::build`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ScheduleStats {
    /// Un-recorded re-forward steps ([`Action::Advance`]) in the backward
    /// phase.
    pub replay_advances: usize,
    /// Recorded re-forward steps ([`Action::Sweep`]); equals `n`.
    pub swept_steps: usize,
    /// Peak live snapshot count (initial + dynamic), ≤ the budget.
    pub max_live: usize,
    /// Longest single sweep segment, ≤ [`Schedule::leaf`].
    pub max_sweep_len: usize,
}

/// A validated revolve schedule for reversing `n` steps with at most
/// `snapshots` resident states.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Rollout length the schedule reverses.
    pub n: usize,
    /// Snapshot budget the schedule was built for.
    pub snapshots: usize,
    /// Leaf segment length: sweeps record at most this many steps, so the
    /// rematerialization buffer is bounded by `leaf` records + states.
    pub leaf: usize,
    /// Steps at which the *forward* recording pass must store a snapshot
    /// (sorted ascending, starts at 0). These are the snapshots live when
    /// the backward phase begins; the forward pass is not replayed to
    /// place them.
    pub init_snaps: Vec<usize>,
    /// Backward-phase actions, in execution order.
    pub actions: Vec<Action>,
    /// Proven cost/shape numbers.
    pub stats: ScheduleStats,
}

/// Leaf segment length for an `n`-step rollout: 4 steps (a quarter of the
/// uniform bench default `ckpt(8)`s segment, so revolve's sweep buffer is
/// strictly smaller), stretched only when `n` would overflow the DP grid.
pub fn leaf_for(n: usize) -> usize {
    LEAF_MIN.max(n.div_ceil(DP_MAX_MACRO))
}

const LEAF_MIN: usize = 4;
/// Cap on the macro-grid size so the exact DP stays O(DP_MAX_MACRO² · s)
/// — a few hundred µs, amortized over a rollout of full PISO steps.
const DP_MAX_MACRO: usize = 256;

impl Schedule {
    /// Build and validate the binomial schedule for `n` steps under a
    /// budget of `snapshots` resident states. `snapshots == 0` is
    /// rejected; `n == 0` yields an empty (trivially valid) schedule.
    pub fn build(n: usize, snapshots: usize) -> Result<Schedule, String> {
        if snapshots == 0 {
            return Err("revolve schedule requires snapshots >= 1".to_string());
        }
        if n == 0 {
            return Ok(Schedule {
                n,
                snapshots,
                leaf: LEAF_MIN,
                init_snaps: Vec::new(),
                actions: Vec::new(),
                stats: ScheduleStats::default(),
            });
        }
        let leaf = leaf_for(n);
        let nm = n.div_ceil(leaf); // macro-step count
        let s_eff = snapshots.min(nm);

        // Exact DP over the macro grid: cost[m][k] = minimal re-forward
        // macro-steps (advances + recorded sweeps) to reverse m macro
        // steps with k snapshot slots; split[m][k] = argmin left-part
        // length. k == 1 degenerates to the quadratic one-snapshot sweep.
        let mut cost = vec![vec![0usize; s_eff + 1]; nm + 1];
        let mut split = vec![vec![0usize; s_eff + 1]; nm + 1];
        for m in 1..=nm {
            for k in 1..=s_eff {
                if m == 1 {
                    cost[m][k] = 1;
                } else if k == 1 {
                    cost[m][k] = m * (m - 1) / 2 + m;
                } else {
                    let mut best = usize::MAX;
                    let mut best_mid = 1;
                    for mid in 1..m {
                        let v = mid + cost[m - mid][k - 1] + cost[mid][k];
                        if v < best {
                            best = v;
                            best_mid = mid;
                        }
                    }
                    cost[m][k] = best;
                    split[m][k] = best_mid;
                }
            }
        }

        // Emit raw actions in macro units. The top-level descent is later
        // absorbed into `init_snaps` (the forward pass stores those
        // snapshots as it goes), so the first restore/advance chain up to
        // the deepest pre-sweep snapshot costs nothing at run time.
        let mut raw: Vec<MacroAction> = vec![MacroAction::Snap(0)];
        emit(&split, &mut raw, 0, nm, s_eff);
        raw.push(MacroAction::Drop(0));

        // Macro → real steps: macro i covers real steps i*leaf .. min(n,
        // (i+1)*leaf); the last leaf may be short.
        let real = |i: usize| (i * leaf).min(n);
        let mut actions: Vec<Action> = Vec::with_capacity(raw.len());
        for a in &raw {
            actions.push(match *a {
                MacroAction::Snap(i) => Action::Snapshot { step: real(i) },
                MacroAction::Drop(i) => Action::Drop { step: real(i) },
                MacroAction::Restore(i) => Action::Restore { step: real(i) },
                MacroAction::Adv(b, e) => Action::Advance { from: real(b), to: real(e) },
                MacroAction::Sweep(b, e) => Action::Sweep { from: real(b), to: real(e) },
            });
        }

        // Absorb the initial descent: every Snapshot before the first
        // Sweep is placed by the forward pass, and the Restore/Advance
        // chain that positions them is the forward pass itself.
        let first_sweep = actions
            .iter()
            .position(|a| matches!(a, Action::Sweep { .. }))
            .ok_or_else(|| "revolve schedule emitted no sweeps".to_string())?;
        let last_snap = actions[..first_sweep]
            .iter()
            .rposition(|a| matches!(a, Action::Snapshot { .. }))
            .ok_or_else(|| "revolve schedule has no pre-sweep snapshot".to_string())?;
        let init_snaps: Vec<usize> = actions[..=last_snap]
            .iter()
            .filter_map(|a| match *a {
                Action::Snapshot { step } => Some(step),
                _ => None,
            })
            .collect();
        let actions: Vec<Action> = actions[last_snap + 1..].to_vec();

        let stats = validate(n, snapshots, &init_snaps, &actions)?;
        debug_assert!(stats.max_sweep_len <= leaf);
        Ok(Schedule { n, snapshots, leaf, init_snaps, actions, stats })
    }

    /// The uniform `Checkpoint { every }` layout expressed as a schedule,
    /// so one executor serves both strategies: snapshots at 0, k, 2k, …
    /// during the forward pass, then per segment (last first) restore →
    /// sweep → drop, with no re-advances.
    pub fn uniform(n: usize, every: usize) -> Result<Schedule, String> {
        if every == 0 {
            return Err("uniform schedule requires every >= 1".to_string());
        }
        let init_snaps: Vec<usize> = (0..n).step_by(every).collect();
        let mut actions = Vec::with_capacity(3 * init_snaps.len());
        for ci in (0..init_snaps.len()).rev() {
            let from = init_snaps[ci];
            let to = init_snaps.get(ci + 1).copied().unwrap_or(n);
            actions.push(Action::Restore { step: from });
            actions.push(Action::Sweep { from, to });
            actions.push(Action::Drop { step: from });
        }
        let stats = if n == 0 {
            ScheduleStats::default()
        } else {
            validate(n, init_snaps.len(), &init_snaps, &actions)?
        };
        Ok(Schedule { n, snapshots: init_snaps.len(), leaf: every, init_snaps, actions, stats })
    }
}

enum MacroAction {
    Snap(usize),
    Drop(usize),
    Restore(usize),
    Adv(usize, usize),
    Sweep(usize, usize),
}

/// Recursive emission over macro range `b..e` with `k` snapshot slots.
/// Precondition: a snapshot is live at `b`. Postcondition: every macro
/// step in `b..e` swept (descending), snapshot at `b` still live, no
/// other snapshots leaked.
fn emit(split: &[Vec<usize>], raw: &mut Vec<MacroAction>, b: usize, e: usize, k: usize) {
    let m = e - b;
    if m == 0 {
        return;
    }
    if m == 1 {
        raw.push(MacroAction::Restore(b));
        raw.push(MacroAction::Sweep(b, e));
        return;
    }
    if k <= 1 {
        // one slot: quadratic re-advance from b for each leaf, last first
        for i in (b..e).rev() {
            raw.push(MacroAction::Restore(b));
            if i > b {
                raw.push(MacroAction::Adv(b, i));
            }
            raw.push(MacroAction::Sweep(i, i + 1));
        }
        return;
    }
    let mid = b + split[m][k];
    raw.push(MacroAction::Restore(b));
    raw.push(MacroAction::Adv(b, mid));
    raw.push(MacroAction::Snap(mid));
    emit(split, raw, mid, e, k - 1);
    raw.push(MacroAction::Drop(mid));
    emit(split, raw, b, mid, k);
}

/// Simulate a schedule and prove its invariants: restores hit live
/// snapshots, advances/sweeps start at the current position, the live
/// count stays within `snapshots`, and the sweeps tile `0..n` exactly
/// once, descending. Returns the measured stats or a description of the
/// first violated invariant.
fn validate(
    n: usize,
    snapshots: usize,
    init_snaps: &[usize],
    actions: &[Action],
) -> Result<ScheduleStats, String> {
    let mut live = vec![false; n + 1];
    let mut live_count = 0usize;
    if init_snaps.first() != Some(&0) {
        return Err("schedule must snapshot step 0 during the forward pass".to_string());
    }
    for w in init_snaps.windows(2) {
        if w[1] <= w[0] {
            return Err(format!("initial snapshots not ascending: {} then {}", w[0], w[1]));
        }
    }
    for &p in init_snaps {
        if p >= n.max(1) {
            return Err(format!("initial snapshot at {p} is past the last step"));
        }
        live[p] = true;
        live_count += 1;
    }
    let mut stats = ScheduleStats { max_live: live_count, ..ScheduleStats::default() };
    if live_count > snapshots {
        return Err(format!("{live_count} initial snapshots exceed budget {snapshots}"));
    }
    let mut pos = n; // forward pass leaves the solver after step n-1
    let mut next_sweep_end = n;
    for (i, a) in actions.iter().enumerate() {
        match *a {
            Action::Restore { step } => {
                if step > n || !live[step] {
                    return Err(format!("action {i}: restore of dead snapshot {step}"));
                }
                pos = step;
            }
            Action::Advance { from, to } => {
                if pos != from || from >= to || to > n {
                    return Err(format!("action {i}: advance {from}..{to} from position {pos}"));
                }
                stats.replay_advances += to - from;
                pos = to;
            }
            Action::Snapshot { step } => {
                if pos != step || step > n {
                    return Err(format!("action {i}: snapshot at {step} from position {pos}"));
                }
                if live[step] {
                    return Err(format!("action {i}: duplicate snapshot at {step}"));
                }
                live[step] = true;
                live_count += 1;
                stats.max_live = stats.max_live.max(live_count);
                if live_count > snapshots {
                    return Err(format!(
                        "action {i}: {live_count} live snapshots exceed budget {snapshots}"
                    ));
                }
            }
            Action::Drop { step } => {
                if step > n || !live[step] {
                    return Err(format!("action {i}: drop of dead snapshot {step}"));
                }
                live[step] = false;
                live_count -= 1;
            }
            Action::Sweep { from, to } => {
                if pos != from {
                    return Err(format!("action {i}: sweep {from}..{to} from position {pos}"));
                }
                if to != next_sweep_end || from >= to {
                    return Err(format!(
                        "action {i}: sweep {from}..{to} breaks descending coverage (expected end {next_sweep_end})"
                    ));
                }
                next_sweep_end = from;
                stats.swept_steps += to - from;
                stats.max_sweep_len = stats.max_sweep_len.max(to - from);
                // a sweep hands the solver to the adjoint with *final*
                // boundary values; poison the position so any further
                // re-stepping must go through a Restore (which reloads
                // the matching bc snapshot) first
                pos = usize::MAX;
            }
        }
    }
    if next_sweep_end != 0 {
        return Err(format!("sweeps stop at {next_sweep_end}, steps below are never reversed"));
    }
    if live_count != 0 {
        return Err(format!("{live_count} snapshots leaked past the last action"));
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_snapshot_budget_is_an_error() {
        assert!(Schedule::build(10, 0).is_err());
        assert!(Schedule::uniform(10, 0).is_err());
    }

    #[test]
    fn empty_rollout_yields_empty_schedule() {
        let s = Schedule::build(0, 4).expect("n=0 is trivially schedulable");
        assert!(s.init_snaps.is_empty() && s.actions.is_empty());
    }

    #[test]
    fn bench_point_meets_the_two_forward_budget() {
        // the acceptance point: n=64 under 8 snapshots must reverse with
        // at most 2n re-forward steps (advances + recorded sweeps)
        let s = Schedule::build(64, 8).expect("DP schedule for (64, 8)");
        assert_eq!(s.stats.swept_steps, 64);
        assert!(
            s.stats.replay_advances + s.stats.swept_steps <= 128,
            "backward forwards {} + {} exceed 2n",
            s.stats.replay_advances,
            s.stats.swept_steps
        );
        assert!(s.stats.max_live <= 8);
        assert!(s.stats.max_sweep_len <= 4);
        assert_eq!(s.init_snaps.len(), 8);
    }

    #[test]
    fn schedules_are_valid_across_an_n_s_grid() {
        // Schedule::build re-validates internally; this locks the public
        // contract over awkward shapes (n < s, n = 1, prime n, leaf
        // stretching past the DP cap).
        for n in [1usize, 2, 3, 5, 7, 13, 31, 64, 100, 257, 1025, 2000] {
            for s in [1usize, 2, 3, 8, 16] {
                let sched = Schedule::build(n, s)
                    .unwrap_or_else(|e| panic!("build({n}, {s}) failed: {e}"));
                assert_eq!(sched.stats.swept_steps, n, "({n}, {s}) sweep coverage");
                assert!(sched.stats.max_live <= s, "({n}, {s}) live {}", sched.stats.max_live);
                assert!(sched.stats.max_sweep_len <= sched.leaf);
                assert!(sched.init_snaps.len() <= s);
            }
        }
    }

    #[test]
    fn uniform_layout_matches_checkpoint_semantics() {
        let s = Schedule::uniform(7, 3).expect("uniform layout is always valid");
        assert_eq!(s.init_snaps, vec![0, 3, 6]);
        assert_eq!(s.stats.replay_advances, 0);
        assert_eq!(s.stats.swept_steps, 7);
        assert_eq!(s.stats.max_sweep_len, 3);
    }

    #[test]
    fn more_snapshots_never_cost_more_recompute() {
        let mut prev = usize::MAX;
        for s in [1usize, 2, 4, 8, 16, 32] {
            let sched = Schedule::build(64, s).expect("valid budget");
            let cost = sched.stats.replay_advances;
            assert!(cost <= prev, "s={s} advances {cost} > previous {prev}");
            prev = cost;
        }
    }
}
