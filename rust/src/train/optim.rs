//! Gradient-descent optimizers over flat parameter vectors: plain SGD (used
//! by the paper's direct optimization tasks) and Adam (used for the network
//! trainings), plus decoupled weight decay (eq. 10).

pub trait Optimizer {
    /// In-place parameter update from the gradient.
    fn step(&mut self, params: &mut [f64], grads: &[f64]);
}

/// Plain gradient descent without momentum.
pub struct Sgd {
    pub lr: f64,
    /// L2 weight-decay coefficient λ_WD (0 = off).
    pub weight_decay: f64,
}

impl Sgd {
    pub fn new(lr: f64) -> Sgd {
        Sgd { lr, weight_decay: 0.0 }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        for (p, g) in params.iter_mut().zip(grads) {
            *p -= self.lr * (g + 2.0 * self.weight_decay * *p);
        }
    }
}

/// Adam (Kingma & Ba) with optional decoupled weight decay.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f64, nparams: usize) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: vec![0.0; nparams],
            v: vec![0.0; nparams],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -=
                self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both optimizers minimize a convex quadratic.
    #[test]
    fn optimizers_minimize_quadratic() {
        let target = [3.0, -1.0, 0.5];
        let loss_grad = |p: &[f64]| -> (f64, Vec<f64>) {
            let l: f64 = p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum();
            (l, p.iter().zip(&target).map(|(a, b)| 2.0 * (a - b)).collect())
        };
        for use_adam in [false, true] {
            let mut p = vec![0.0; 3];
            let mut sgd = Sgd::new(0.1);
            let mut adam = Adam::new(0.2, 3);
            for _ in 0..300 {
                let (_, g) = loss_grad(&p);
                if use_adam {
                    adam.step(&mut p, &g);
                } else {
                    sgd.step(&mut p, &g);
                }
            }
            let (l, _) = loss_grad(&p);
            assert!(l < 1e-6, "adam={use_adam}: residual loss {l}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut sgd = Sgd { lr: 0.1, weight_decay: 0.5 };
        let mut p = vec![1.0];
        sgd.step(&mut p, &[0.0]);
        assert!((p[0] - 0.9).abs() < 1e-12); // 1 − 0.1·2·0.5·1
    }
}
