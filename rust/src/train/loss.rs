//! Differentiable losses: velocity MSE (2D corrector training, §5.1–5.2),
//! turbulence-statistics losses (eq. 12/13, the TCF SGS training signal),
//! and the divergence gradient modification (eq. 11) that projects the
//! learning signal onto divergence-free corrections.

use crate::fvm;
use crate::linsolve::{cg, Jacobi, SolveOpts};
use crate::mesh::{Mesh, VectorField};
use crate::stats::profiles::{channel_profiles, STRESS_PAIRS};

/// Velocity MSE against a reference: `L = (1/(dim·N)) Σ |u − û|²`; returns
/// the loss and ∂L/∂u.
pub fn mse_loss_grad(dim: usize, u: &VectorField, u_ref: &VectorField) -> (f64, VectorField) {
    let n = u.ncells();
    let norm = 1.0 / (dim * n) as f64;
    let mut loss = 0.0;
    let mut grad = VectorField::zeros(n);
    for c in 0..dim {
        for cell in 0..n {
            let d = u.comp[c][cell] - u_ref.comp[c][cell];
            loss += d * d * norm;
            grad.comp[c][cell] = 2.0 * d * norm;
        }
    }
    (loss, grad)
}

/// Reference statistics for the channel losses: wall-normal profiles of the
/// mean velocity and the four stress pairs of `STRESS_PAIRS`.
#[derive(Clone, Debug)]
pub struct StatsTarget {
    pub mean: [Vec<f64>; 3],
    pub stress: [Vec<f64>; 4],
    /// λ weights for the mean terms (per component) and stress terms.
    pub w_mean: [f64; 3],
    pub w_stress: [f64; 4],
}

/// Per-frame statistics loss (the per-frame part of eq. 13): mean and
/// second-order profile mismatches, with the exact gradient w.r.t. the
/// instantaneous velocity field.
pub fn stats_loss_grad(mesh: &Mesh, u: &VectorField, target: &StatsTarget) -> (f64, VectorField) {
    let prof = channel_profiles(mesh, u);
    let b = &mesh.blocks[0];
    let (nx, ny, nz) = (b.shape[0], b.shape[1], b.shape[2]);
    let nh = (nx * nz) as f64;
    let y_norm = 1.0 / ny as f64;
    let mut loss = 0.0;
    let mut grad = VectorField::zeros(mesh.ncells);
    // mean terms: L = (1/Y) Σ_y w_i (ū_i(y) − target)²
    let mut dmean = [vec![0.0; ny], vec![0.0; ny], vec![0.0; ny]];
    for c in 0..mesh.dim {
        if target.w_mean[c] == 0.0 {
            continue;
        }
        for j in 0..ny {
            let d = prof.mean[c][j] - target.mean[c][j];
            loss += target.w_mean[c] * d * d * y_norm;
            dmean[c][j] += 2.0 * target.w_mean[c] * d * y_norm;
        }
    }
    // stress terms: s_ab(y) = ⟨u_a u_b⟩ − ū_a ū_b
    let mut dstress = [vec![0.0; ny], vec![0.0; ny], vec![0.0; ny], vec![0.0; ny]];
    for (s, _) in STRESS_PAIRS.iter().enumerate() {
        if target.w_stress[s] == 0.0 {
            continue;
        }
        for j in 0..ny {
            let d = prof.stress[s][j] - target.stress[s][j];
            loss += target.w_stress[s] * d * d * y_norm;
            dstress[s][j] = 2.0 * target.w_stress[s] * d * y_norm;
        }
    }
    // chain to cells: ∂ū_c(y)/∂u_c[cell] = 1/nh;
    // ∂s_ab(y)/∂u_a[cell] = (u_b[cell] − ū_b(y))/nh (+ symmetric)
    for j in 0..ny {
        for k in 0..nz {
            for i in 0..nx {
                let cell = b.offset + b.lidx(i, j, k);
                let uv = u.get(cell);
                for c in 0..mesh.dim {
                    grad.comp[c][cell] += dmean[c][j] / nh;
                }
                for (s, (a, bb)) in STRESS_PAIRS.iter().enumerate() {
                    let ds = dstress[s][j];
                    if ds == 0.0 {
                        continue;
                    }
                    grad.comp[*a][cell] += ds * (uv[*bb] - prof.mean[*bb][j]) / nh;
                    grad.comp[*bb][cell] += ds * (uv[*a] - prof.mean[*a][j]) / nh;
                }
            }
        }
    }
    (loss, grad)
}

/// Divergence gradient modification (eq. 11): solve an auxiliary pressure
/// system `∇²p_θ = ∇·u_θ` for the network output `u_θ` (here the corrector
/// source S_θ) and add `λ ∇p_θ` to the incoming gradient, steering the
/// optimization toward divergence-free outputs with a *globally* correct
/// signal. Returns the modified gradient.
pub fn div_gradient_modification(
    ctx: &crate::par::ExecCtx,
    mesh: &Mesh,
    s_theta: &VectorField,
    dl_ds: &VectorField,
    lambda: f64,
) -> VectorField {
    // unit-coefficient Laplacian (A⁻¹ ≡ 1): M p = −∇·S
    let mut m = fvm::pressure_structure(mesh);
    let ones = vec![1.0; mesh.ncells];
    fvm::assemble_pressure(ctx, mesh, &ones, &mut m);
    // divergence of the corrector output; Dirichlet boundary fluxes do not
    // involve S, so pass an explicit zero override
    let n_bc: usize = mesh
        .bc_values
        .iter()
        .map(|b| b.vel.len())
        .sum::<usize>()
        .max(1);
    let zeros = vec![[0.0; 3]; n_bc * 8];
    let div = fvm::divergence_h(mesh, s_theta, Some(&zeros));
    let rhs: Vec<f64> = div.iter().map(|v| -v).collect();
    let mut p = vec![0.0; mesh.ncells];
    let precond = Jacobi::new(&m);
    let opts = SolveOpts { tol: 1e-8, max_iter: 4000, transpose: false, ..SolveOpts::default() };
    cg(ctx, &m, &rhs, &mut p, &precond, true, opts);
    let gp = fvm::pressure_gradient(mesh, &p);
    let mut out = dl_ds.clone();
    out.axpy(lambda, &gp);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::util::rng::Rng;

    #[test]
    fn mse_grad_matches_fd() {
        let mut rng = Rng::new(1);
        let n = 24;
        let mut u = VectorField::zeros(n);
        let mut r = VectorField::zeros(n);
        for c in 0..2 {
            u.comp[c] = rng.normal_vec(n);
            r.comp[c] = rng.normal_vec(n);
        }
        let (_, g) = mse_loss_grad(2, &u, &r);
        let eps = 1e-6;
        for probe in 0..4 {
            let c = probe % 2;
            let cell = (probe * 7) % n;
            let mut up = u.clone();
            up.comp[c][cell] += eps;
            let mut um = u.clone();
            um.comp[c][cell] -= eps;
            let fd = (mse_loss_grad(2, &up, &r).0 - mse_loss_grad(2, &um, &r).0) / (2.0 * eps);
            assert!((fd - g.comp[c][cell]).abs() < 1e-8, "{fd} vs {}", g.comp[c][cell]);
        }
    }

    #[test]
    fn stats_loss_zero_at_target() {
        let mesh = gen::channel3d([6, 8, 4], [1.0, 2.0, 1.0], 1.0);
        let mut rng = Rng::new(2);
        let mut u = VectorField::zeros(mesh.ncells);
        for c in 0..3 {
            u.comp[c] = rng.normal_vec(mesh.ncells);
        }
        let prof = channel_profiles(&mesh, &u);
        let target = StatsTarget {
            mean: prof.mean.clone(),
            stress: prof.stress.clone(),
            w_mean: [1.0, 0.5, 0.5],
            w_stress: [1.0, 1.0, 1.0, 1.0],
        };
        let (loss, grad) = stats_loss_grad(&mesh, &u, &target);
        assert!(loss < 1e-20);
        assert!(grad.comp[0].iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn stats_grad_matches_fd() {
        let mesh = gen::channel3d([4, 6, 4], [1.0, 2.0, 1.0], 1.0);
        let mut rng = Rng::new(3);
        let mut u = VectorField::zeros(mesh.ncells);
        for c in 0..3 {
            u.comp[c] = rng.normal_vec(mesh.ncells);
        }
        let ny = 6;
        let target = StatsTarget {
            mean: [vec![1.0; ny], vec![0.0; ny], vec![0.0; ny]],
            stress: [vec![0.1; ny], vec![0.05; ny], vec![0.05; ny], vec![-0.01; ny]],
            w_mean: [1.0, 0.5, 0.5],
            w_stress: [1.0, 1.0, 1.0, 1.0],
        };
        let (_, g) = stats_loss_grad(&mesh, &u, &target);
        let eps = 1e-6;
        for probe in 0..6 {
            let c = probe % 3;
            let cell = (probe * 13) % mesh.ncells;
            let mut up = u.clone();
            up.comp[c][cell] += eps;
            let mut um = u.clone();
            um.comp[c][cell] -= eps;
            let fd = (stats_loss_grad(&mesh, &up, &target).0
                - stats_loss_grad(&mesh, &um, &target).0)
                / (2.0 * eps);
            assert!(
                (fd - g.comp[c][cell]).abs() < 1e-7 * (1.0 + fd.abs()),
                "[{c}][{cell}]: {fd} vs {}",
                g.comp[c][cell]
            );
        }
    }

    /// The modification leaves divergence-free outputs untouched and pushes
    /// divergent outputs toward lower divergence.
    #[test]
    fn div_modification_targets_divergent_part() {
        let mesh = gen::periodic_box2d(16, 16, 1.0, 1.0);
        let tau = 2.0 * std::f64::consts::PI;
        // divergence-free field (curl form)
        let mut s_free = VectorField::zeros(mesh.ncells);
        for (i, c) in mesh.centers.iter().enumerate() {
            s_free.comp[0][i] = (tau * c[1]).cos();
            s_free.comp[1][i] = (tau * c[0]).sin() * 0.0;
        }
        let dl = VectorField::zeros(mesh.ncells);
        let ctx = crate::par::ExecCtx::serial();
        let g_free = div_gradient_modification(&ctx, &mesh, &s_free, &dl, 1.0);
        let gn: f64 = g_free.comp[0].iter().chain(&g_free.comp[1]).map(|v| v * v).sum();
        assert!(gn < 1e-10, "div-free output should get ~zero modification: {gn}");
        // divergent field: gradient points along the irrotational part
        let mut s_div = VectorField::zeros(mesh.ncells);
        for (i, c) in mesh.centers.iter().enumerate() {
            s_div.comp[0][i] = (tau * c[0]).sin();
        }
        let g_div = div_gradient_modification(&ctx, &mesh, &s_div, &dl, 1.0);
        // descent step S − η g reduces ‖∇·S‖
        let mut s_new = s_div.clone();
        s_new.axpy(-0.5, &g_div);
        let d0: f64 =
            fvm::divergence_h(&mesh, &s_div, None).iter().map(|v| v * v).sum::<f64>();
        let d1: f64 =
            fvm::divergence_h(&mesh, &s_new, None).iter().map(|v| v * v).sum::<f64>();
        assert!(d1 < d0, "divergence should decrease: {d0} -> {d1}");
    }
}
