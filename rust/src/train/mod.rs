//! Training substrate (paper §3): optimizers over flat parameter vectors,
//! MSE and turbulence-statistics losses with analytic gradients, weight
//! decay (eq. 10), and the physically-informed divergence gradient
//! modification (eq. 11).

pub mod loss;
pub mod optim;

pub use loss::{div_gradient_modification, mse_loss_grad, stats_loss_grad, StatsTarget};
pub use optim::{Adam, Optimizer, Sgd};
