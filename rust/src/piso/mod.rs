//! The PISO time stepper (paper §2.1, Appendix A.2): implicit-Euler
//! predictor solve `C u* = u^n/Δt − ∇p^n + S` followed by (typically two)
//! pressure correctors `∇²(A⁻¹p) = ∇·h`, `u ← h − A⁻¹∇p`, with optional
//! non-orthogonal deferred-correction iterations and the non-reflecting
//! advective outflow update (A.24) between steps.

pub mod stepper;

pub use stepper::{PisoConfig, PisoSolver, State, StepRecord, StepStats};
