//! Forward PISO step, with optional recording of every intermediate needed
//! by the adjoint (DtO tape; see `adjoint`).

use crate::fvm;
use crate::linsolve::{
    bicgstab, cg, refined_bicgstab, refined_cg, Ilu0, Jacobi, Precision, Preconditioner,
    SolveOpts,
};
use crate::mesh::{face_axis, face_sign, Mesh, NeighRef, VectorField};
use crate::par::ExecCtx;
use crate::sparse::{Csr, Csr32};
use crate::util::timer;

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct PisoConfig {
    /// Base time step (used directly unless `target_cfl` is set).
    pub dt: f64,
    /// If set, the step size adapts to `dt = CFL · min_cells(J / max_j |U^j|)`,
    /// capped at `dt`.
    pub target_cfl: Option<f64>,
    /// Number of pressure correctors (paper: 2).
    pub n_correctors: usize,
    /// Extra non-orthogonal corrector iterations (per linear solve).
    pub n_nonorth: usize,
    /// Advection solve (BiCGStab) options.
    pub adv_opts: SolveOpts,
    /// Pressure solve (CG) options.
    pub p_opts: SolveOpts,
    /// ILU(0) preconditioning for the advection solve (Jacobi otherwise).
    pub use_ilu: bool,
    /// Storage precision of the forward Krylov hot path. `Mixed` routes the
    /// advection and pressure solves through f32-storage iterative
    /// refinement (see [`crate::linsolve::refine`]) against solver-owned
    /// [`Csr32`] mirrors; adjoint solves always stay f64. Per-solve
    /// `adv_opts.precision` / `p_opts.precision` override individually.
    pub precision: Precision,
}

impl Default for PisoConfig {
    fn default() -> Self {
        PisoConfig {
            dt: 0.01,
            target_cfl: None,
            n_correctors: 2,
            n_nonorth: 1,
            adv_opts: SolveOpts {
                tol: 1e-8,
                max_iter: 1000,
                transpose: false,
                precision: Precision::F64,
            },
            p_opts: SolveOpts {
                tol: 1e-8,
                max_iter: 4000,
                transpose: false,
                precision: Precision::F64,
            },
            use_ilu: false,
            precision: Precision::F64,
        }
    }
}

/// Simulation state advanced by the solver.
#[derive(Clone, Debug)]
pub struct State {
    pub u: VectorField,
    pub p: Vec<f64>,
    pub time: f64,
    pub step: usize,
}

impl State {
    pub fn zeros(mesh: &Mesh) -> State {
        State { u: VectorField::zeros(mesh.ncells), p: vec![0.0; mesh.ncells], time: 0.0, step: 0 }
    }

    /// Number of f64 values this state keeps resident (tape memory accounting).
    pub fn len_f64(&self) -> usize {
        self.u.comp.iter().map(|c| c.len()).sum::<usize>() + self.p.len()
    }
}

/// Per-step diagnostics.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub dt: f64,
    pub adv_iters: usize,
    pub p_iters: usize,
    pub adv_residual: f64,
    pub p_residual: f64,
    pub max_divergence: f64,
}

/// Record of one corrector round (for the adjoint).
#[derive(Clone, Debug)]
pub struct CorrectorRecord {
    /// Velocity entering this corrector (u* or u**).
    pub u_in: VectorField,
    pub h: VectorField,
    pub p: Vec<f64>,
}

/// DtO tape of one PISO step — exactly what the backward pass reads, and
/// nothing more. Every field here is resident once per step on the tape
/// (O(n) copies on a full tape), so the pairing between this struct and
/// `adjoint::backward_step` is enforced by the analyze gate: a field the
/// backward sweep never reads is dead checkpoint weight and gets flagged.
/// Inputs the sweep can recompute (u^n/Δt, ∇p^n, the assembled RHS) are
/// deliberately *not* stored — the adjoint rebuilds their cotangents from
/// the matrices and corrector intermediates below.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub dt: f64,
    pub c_vals: Vec<f64>,
    pub a_inv: Vec<f64>,
    pub pmat_vals: Vec<f64>,
    pub u_star: VectorField,
    pub correctors: Vec<CorrectorRecord>,
}

impl StepRecord {
    /// An unsized record for [`PisoSolver::step`] to fill in.
    pub fn empty() -> StepRecord {
        StepRecord {
            dt: 0.0,
            c_vals: vec![],
            a_inv: vec![],
            pmat_vals: vec![],
            u_star: VectorField::zeros(0),
            correctors: vec![],
        }
    }

    /// Number of f64 values this record keeps resident (tape memory
    /// accounting; the dominant O(ncells) and O(nnz) buffers).
    pub fn len_f64(&self) -> usize {
        let vf = |f: &VectorField| f.comp.iter().map(|c| c.len()).sum::<usize>();
        self.c_vals.len()
            + self.a_inv.len()
            + self.pmat_vals.len()
            + vf(&self.u_star)
            + self
                .correctors
                .iter()
                .map(|cr| vf(&cr.u_in) + vf(&cr.h) + cr.p.len())
                .sum::<usize>()
    }
}

impl Default for StepRecord {
    fn default() -> Self {
        StepRecord::empty()
    }
}

/// Advection-solve preconditioner slot, owned by the solver so the
/// factorization storage (and, for ILU(0), the symbolic level schedules)
/// persists across steps: each step runs a numeric-only
/// [`Jacobi::refresh`] / [`Ilu0::refactor`] instead of a fresh `new`.
enum AdvPrecond {
    Jacobi(Jacobi),
    Ilu(Ilu0),
}

impl AdvPrecond {
    fn as_dyn(&self) -> &dyn Preconditioner {
        match self {
            AdvPrecond::Jacobi(p) => p,
            AdvPrecond::Ilu(p) => p,
        }
    }
}

/// Step-persistent scratch: the per-step hot-loop buffers of
/// [`PisoSolver::step`] (momentum RHS, inverted momentum diagonal,
/// boundary-flux base RHS), allocated once per solver and refilled in
/// place each step.
struct StepScratch {
    rhs: Vec<f64>,
    a_inv: Vec<f64>,
    rhs_base: VectorField,
}

impl StepScratch {
    fn new(ncells: usize) -> StepScratch {
        StepScratch {
            rhs: vec![0.0; ncells],
            a_inv: vec![0.0; ncells],
            rhs_base: VectorField::zeros(ncells),
        }
    }
}

/// Refresh a solver-owned [`Csr32`] mirror from the freshly assembled f64
/// matrix: values-only renarrow once allocated, full clone-and-narrow the
/// first time a mixed-precision step needs it.
fn refresh_mirror(slot: &mut Option<Csr32>, a: &Csr) {
    if let Some(m) = slot.as_mut() {
        m.refresh(a);
    } else {
        *slot = Some(Csr32::from_f64(a));
    }
}

/// The PISO solver: owns the mesh, viscosity field, reusable matrix
/// structures, and the execution context its kernels run on. One instance
/// per mesh; `step` advances a [`State`].
pub struct PisoSolver {
    pub mesh: Mesh,
    pub cfg: PisoConfig,
    /// Per-cell kinematic viscosity.
    pub nu: Vec<f64>,
    pub c: Csr,
    pub pmat: Csr,
    /// Cross-step advection preconditioner (numeric refresh per step).
    adv_precond: AdvPrecond,
    /// Cross-step pressure Jacobi preconditioner (numeric refresh per step).
    p_precond: Jacobi,
    /// f32 mirror of `c` for mixed-precision advection solves; allocated on
    /// the first mixed step, values-refreshed afterward.
    c32: Option<Csr32>,
    /// f32 mirror of `pmat` for mixed-precision pressure solves.
    pmat32: Option<Csr32>,
    /// Hoisted per-step allocations.
    scratch: StepScratch,
    /// Execution context threaded through assembly, Krylov solves, and
    /// preconditioner applies (and reused by the adjoint for the transposed
    /// solves). Constructors take it explicitly: contexts are only built at
    /// entry points (`par/`, `coordinator/` — the analyze gate enforces
    /// this), so a whole run shares one pool topology instead of each
    /// solver forking its own. Embedders sharing one pool across solvers
    /// (e.g. the batch runner) swap in a clone via [`PisoSolver::with_ctx`].
    pub ctx: ExecCtx,
}

impl PisoSolver {
    pub fn new(mesh: Mesh, cfg: PisoConfig, nu_uniform: f64, ctx: ExecCtx) -> PisoSolver {
        let nu = vec![nu_uniform; mesh.ncells];
        PisoSolver::with_viscosity_field(mesh, cfg, nu, ctx)
    }

    pub fn with_viscosity_field(
        mesh: Mesh,
        cfg: PisoConfig,
        nu: Vec<f64>,
        ctx: ExecCtx,
    ) -> PisoSolver {
        let c = fvm::c_structure(&mesh);
        let pmat = fvm::pressure_structure(&mesh);
        // Factorize the preconditioners once on the zero-valued structures
        // (both guard zero pivots); every step refreshes them numerically.
        let adv_precond = if cfg.use_ilu {
            AdvPrecond::Ilu(Ilu0::new(&c))
        } else {
            AdvPrecond::Jacobi(Jacobi::new(&c))
        };
        let p_precond = Jacobi::new(&pmat);
        let scratch = StepScratch::new(mesh.ncells);
        PisoSolver {
            mesh,
            cfg,
            nu,
            c,
            pmat,
            adv_precond,
            p_precond,
            c32: None,
            pmat32: None,
            scratch,
            ctx,
        }
    }

    /// Replace the execution context (builder-style), sharing its pool.
    pub fn with_ctx(mut self, ctx: ExecCtx) -> PisoSolver {
        self.ctx = ctx;
        self
    }

    /// CFL-limited time step for the current velocity.
    pub fn cfl_dt(&self, u: &VectorField) -> f64 {
        let cfl = self.cfg.target_cfl.unwrap_or(1.0);
        let mut dt = self.cfg.dt;
        for cell in 0..self.mesh.ncells {
            let uc = fvm::contravariant(&self.mesh, u, cell);
            let mut umax = 0.0f64;
            for a in 0..self.mesh.dim {
                umax = umax.max(uc[a].abs());
            }
            if umax > 1e-12 {
                dt = dt.min(cfl * self.mesh.jac[cell] / umax);
            }
        }
        dt
    }

    /// Advance one PISO step. `source` is the external force S (e.g. channel
    /// forcing or the learned corrector output). If `record` is given, every
    /// intermediate is stored for the backward pass.
    pub fn step(
        &mut self,
        state: &mut State,
        source: &VectorField,
        mut record: Option<&mut StepRecord>,
    ) -> StepStats {
        let dt = if self.cfg.target_cfl.is_some() { self.cfl_dt(&state.u) } else { self.cfg.dt };
        let mut stats = StepStats { dt, ..Default::default() };
        let _ = &mut record;

        // --- advective outflow update + global mass balance (A.24) ---
        self.update_outflow_bcs(&state.u, dt);

        let mesh = &self.mesh;
        let dim = mesh.dim;
        let n = mesh.ncells;

        // --- assemble C and the momentum RHS ---
        let ctx = &self.ctx;
        timer::scoped("assemble_c", || {
            fvm::assemble_c(ctx, mesh, &state.u, &self.nu, dt, &mut self.c)
        });

        // cross-step setup reuse: numeric-only refresh of the persistent
        // advection preconditioner (the ILU(0) symbolic structure and level
        // schedules carry over), plus a values-only renarrow of the f32
        // matrix mirror when this step solves in mixed precision
        let mixed_adv = self.cfg.precision.is_mixed() || self.cfg.adv_opts.precision.is_mixed();
        let mixed_p = self.cfg.precision.is_mixed() || self.cfg.p_opts.precision.is_mixed();
        timer::scoped("adv_precond", || {
            match (&mut self.adv_precond, self.cfg.use_ilu) {
                (AdvPrecond::Ilu(p), true) => p.refactor(&self.c),
                (AdvPrecond::Jacobi(p), false) => p.refresh(&self.c),
                // cfg.use_ilu toggled since construction: rebuild the slot
                (slot, use_ilu) => {
                    *slot = if use_ilu {
                        AdvPrecond::Ilu(Ilu0::new(&self.c))
                    } else {
                        AdvPrecond::Jacobi(Jacobi::new(&self.c))
                    };
                }
            }
        });
        if mixed_adv {
            refresh_mirror(&mut self.c32, &self.c);
        }

        let StepScratch { rhs, a_inv, rhs_base } = &mut self.scratch;
        fvm::boundary_flux_rhs_into(mesh, &self.nu, rhs_base);
        for comp in 0..dim {
            for cell in 0..n {
                rhs_base.comp[comp][cell] +=
                    state.u.comp[comp][cell] / dt + source.comp[comp][cell];
            }
        }
        let grad_p_in = fvm::pressure_gradient(mesh, &state.p);

        // --- predictor solve: C u* = rhs_base − ∇p^n  (per component) ---
        let mut u_star = state.u.clone();
        let n_nonorth = if mesh.non_orthogonal { self.cfg.n_nonorth } else { 0 };
        let adv_opts = self.cfg.adv_opts;
        for comp in 0..dim {
            for i in 0..n {
                rhs[i] = rhs_base.comp[comp][i] - grad_p_in.comp[comp][i];
            }
            for no in 0..=n_nonorth {
                if no > 0 {
                    // deferred cross-diffusion of the current iterate
                    let cross = fvm::cross_diffusion(mesh, &self.nu, &u_star.comp[comp]);
                    for i in 0..n {
                        rhs[i] = rhs_base.comp[comp][i] - grad_p_in.comp[comp][i]
                            + cross[i] / mesh.jac[i];
                    }
                }
                let st = timer::scoped("adv_solve", || {
                    let u = &mut u_star.comp[comp];
                    let precond = self.adv_precond.as_dyn();
                    match (mixed_adv, self.c32.as_ref()) {
                        (true, Some(c32)) => {
                            refined_bicgstab(ctx, &self.c, c32, rhs, u, precond, false, adv_opts)
                        }
                        _ => bicgstab(ctx, &self.c, rhs, u, precond, false, adv_opts),
                    }
                });
                stats.adv_iters += st.iterations;
                stats.adv_residual = stats.adv_residual.max(st.residual);
            }
        }

        // --- correctors ---
        for r in 0..n {
            let d = self.c.find(r, r).map(|k| self.c.vals[k]).unwrap_or(0.0);
            a_inv[r] = 1.0 / d;
        }
        timer::scoped("assemble_p", || {
            fvm::assemble_pressure(ctx, mesh, a_inv, &mut self.pmat)
        });
        self.p_precond.refresh(&self.pmat);
        if mixed_p {
            refresh_mirror(&mut self.pmat32, &self.pmat);
        }
        let p_precond = &self.p_precond;
        let p_opts = self.cfg.p_opts;
        // pure-Neumann/periodic pressure ⇒ constant nullspace unless any
        // Dirichlet velocity boundary fixes the level through the RHS; the
        // matrix never has Dirichlet pressure rows, so always project.
        let project = true;

        let mut records = Vec::new();
        let mut u_cur = u_star.clone();
        let mut p_new = state.p.clone();
        for _ in 0..self.cfg.n_correctors {
            let h = fvm::h_field(mesh, &self.c, a_inv, &u_cur, rhs_base);
            let div = fvm::divergence_h(mesh, &h, None);
            let mut p = p_new.clone();
            let mut rhs_p: Vec<f64> = div.iter().map(|v| -v).collect();
            for no in 0..=n_nonorth {
                if no > 0 {
                    let cross = fvm::cross_diffusion(mesh, a_inv, &p);
                    for i in 0..n {
                        rhs_p[i] = -div[i] + cross[i];
                    }
                }
                let st = timer::scoped("p_solve", || {
                    match (mixed_p, self.pmat32.as_ref()) {
                        (true, Some(m32)) => refined_cg(
                            ctx,
                            &self.pmat,
                            m32,
                            &rhs_p,
                            &mut p,
                            p_precond,
                            project,
                            p_opts,
                        ),
                        _ => cg(ctx, &self.pmat, &rhs_p, &mut p, p_precond, project, p_opts),
                    }
                });
                stats.p_iters += st.iterations;
                stats.p_residual = stats.p_residual.max(st.residual);
            }
            // u** = h − A⁻¹ ∇p
            let gp = fvm::pressure_gradient(mesh, &p);
            let mut u_next = h.clone();
            for comp in 0..dim {
                for cell in 0..n {
                    u_next.comp[comp][cell] -= a_inv[cell] * gp.comp[comp][cell];
                }
            }
            records.push(CorrectorRecord { u_in: u_cur.clone(), h, p: p.clone() });
            u_cur = u_next;
            p_new = p;
        }

        if let Some(rec) = record.take() {
            *rec = StepRecord {
                dt,
                c_vals: self.c.vals.clone(),
                a_inv: a_inv.clone(),
                pmat_vals: self.pmat.vals.clone(),
                u_star,
                correctors: records,
            };
        }

        let div_final = fvm::divergence_h(mesh, &u_cur, None);
        stats.max_divergence = div_final
            .iter()
            .zip(&mesh.jac)
            .map(|(d, j)| (d / j).abs())
            .fold(0.0, f64::max);

        state.u = u_cur;
        state.p = p_new;
        state.time += dt;
        state.step += 1;
        stats
    }

    /// A.24: advect Dirichlet outflow values with the characteristic
    /// velocity, then rescale outflow faces for global mass balance.
    fn update_outflow_bcs(&mut self, u: &VectorField, dt: f64) {
        let mesh = &self.mesh;
        let has_outflow = mesh.bc_values.iter().any(|b| b.advective_outflow.is_some());
        if !has_outflow {
            return;
        }
        // 1) advect boundary values: u_b ← u_b − (2λ/(1+2λ))(u_b − u_P)
        let mut updates: Vec<(usize, usize, [f64; 3])> = Vec::new();
        for cell in 0..mesh.ncells {
            for face in 0..2 * mesh.dim {
                if let NeighRef::Dirichlet { values, face_cell } = mesh.topo.at(cell, face) {
                    let bc = &mesh.bc_values[values as usize];
                    if let Some(um) = bc.advective_outflow {
                        let ax = face_axis(face);
                        let nf = face_sign(face);
                        let t = &mesh.t[cell];
                        let tum = t[ax][0] * um[0] + t[ax][1] * um[1] + t[ax][2] * um[2];
                        let lambda = (dt * nf * tum).max(0.0);
                        let f = 2.0 * lambda / (1.0 + 2.0 * lambda);
                        let ub = bc.vel[face_cell as usize];
                        let up = u.get(cell);
                        let mut nb = ub;
                        for c in 0..mesh.dim {
                            nb[c] = ub[c] - f * (ub[c] - up[c]);
                        }
                        updates.push((values as usize, face_cell as usize, nb));
                    }
                }
            }
        }
        for (vi, fc, nb) in updates {
            self.mesh.bc_values[vi].vel[fc] = nb;
        }
        // 2) global mass balance: scale outflow faces so Σ fluxes = 0
        let mesh = &self.mesh;
        let mut flux_fixed = 0.0;
        let mut flux_out = 0.0;
        for cell in 0..mesh.ncells {
            for face in 0..2 * mesh.dim {
                if let NeighRef::Dirichlet { values, face_cell } = mesh.topo.at(cell, face) {
                    let ax = face_axis(face);
                    let nf = face_sign(face);
                    let bc = &mesh.bc_values[values as usize];
                    let ub = bc.vel[face_cell as usize];
                    let f = nf * fvm::contravariant_bc(mesh, cell, ub, ax);
                    if bc.advective_outflow.is_some() {
                        flux_out += f;
                    } else {
                        flux_fixed += f;
                    }
                }
            }
        }
        if flux_out.abs() > 1e-12 {
            let scale = -flux_fixed / flux_out;
            for bc in self.mesh.bc_values.iter_mut() {
                if bc.advective_outflow.is_some() {
                    for v in bc.vel.iter_mut() {
                        for c in v.iter_mut() {
                            *c *= scale;
                        }
                    }
                }
            }
        }
    }

    /// Run `n` steps with a fixed source, returning the last stats.
    pub fn run(&mut self, state: &mut State, source: &VectorField, n: usize) -> StepStats {
        let mut last = StepStats::default();
        for _ in 0..n {
            last = self.step(state, source, None);
        }
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;

    #[test]
    fn step_preserves_divergence_free() {
        let mesh = gen::periodic_box2d(16, 16, 1.0, 1.0);
        let mut solver = PisoSolver::new(mesh, PisoConfig::default(), 0.01, ExecCtx::from_env());
        let mut state = State::zeros(&solver.mesh);
        // Taylor-Green-like initial velocity (divergence free)
        let tau = 2.0 * std::f64::consts::PI;
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (tau * c[0]).sin() * (tau * c[1]).cos();
            state.u.comp[1][i] = -(tau * c[0]).cos() * (tau * c[1]).sin();
        }
        let src = VectorField::zeros(solver.mesh.ncells);
        let stats = solver.step(&mut state, &src, None);
        assert!(stats.adv_residual < 1e-6);
        assert!(stats.p_residual < 1e-6);
        // The collocated central scheme leaves a small wide-vs-compact
        // operator mismatch (the paper's checkerboard-proneness, §5.1):
        // require the final divergence to be small relative to the velocity
        // gradient scale (~2π·2π here) and much smaller than div(u*).
        let mut rec_state = State::zeros(&solver.mesh);
        rec_state.u = state.u.clone();
        assert!(stats.max_divergence < 0.1, "div {}", stats.max_divergence);
    }

    #[test]
    fn taylor_green_decays_at_viscous_rate() {
        // TG vortex on [0,1]²: u ∝ exp(−2 ν (2π)² t); check the decay rate
        // to ~5% over a short horizon.
        let nu = 0.05;
        let mesh = gen::periodic_box2d(32, 32, 1.0, 1.0);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 2e-3, n_correctors: 2, ..Default::default() },
            nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        let tau = 2.0 * std::f64::consts::PI;
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[0][i] = (tau * c[0]).sin() * (tau * c[1]).cos();
            state.u.comp[1][i] = -(tau * c[0]).cos() * (tau * c[1]).sin();
        }
        let e0: f64 = state.u.comp[0].iter().map(|v| v * v).sum::<f64>()
            + state.u.comp[1].iter().map(|v| v * v).sum::<f64>();
        let src = VectorField::zeros(solver.mesh.ncells);
        let nsteps = 20;
        solver.run(&mut state, &src, nsteps);
        let e1: f64 = state.u.comp[0].iter().map(|v| v * v).sum::<f64>()
            + state.u.comp[1].iter().map(|v| v * v).sum::<f64>();
        let t = 2e-3 * nsteps as f64;
        let expect = (-4.0 * nu * tau * tau * t).exp();
        let measured = e1 / e0;
        assert!(
            (measured - expect).abs() < 0.05 * expect,
            "decay {measured} vs {expect}"
        );
    }

    #[test]
    fn record_captures_intermediates() {
        let mesh = gen::periodic_box2d(8, 8, 1.0, 1.0);
        let mut solver = PisoSolver::new(mesh, PisoConfig::default(), 0.01, ExecCtx::from_env());
        let mut state = State::zeros(&solver.mesh);
        state.u.comp[0].iter_mut().enumerate().for_each(|(i, v)| *v = (i as f64 * 0.1).sin());
        let src = VectorField::zeros(solver.mesh.ncells);
        let mut rec = StepRecord::empty();
        solver.step(&mut state, &src, Some(&mut rec));
        assert_eq!(rec.correctors.len(), 2);
        assert!(rec.len_f64() > 0);
        assert_eq!(rec.u_star.ncells(), solver.mesh.ncells);
        assert_eq!(rec.c_vals.len(), solver.c.nnz());
        // final corrector output is the state velocity
        let last = rec.correctors.last().unwrap();
        let gp = crate::fvm::pressure_gradient(&solver.mesh, &last.p);
        for cell in 0..solver.mesh.ncells {
            let expect = last.h.comp[0][cell] - rec.a_inv[cell] * gp.comp[0][cell];
            assert!((state.u.comp[0][cell] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn cfl_dt_scales_with_velocity() {
        let mesh = gen::periodic_box2d(8, 8, 1.0, 1.0);
        let mut solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: 1.0, target_cfl: Some(0.8), ..Default::default() },
            0.01,
            ExecCtx::from_env(),
        );
        let mut u = VectorField::zeros(solver.mesh.ncells);
        u.comp[0].iter_mut().for_each(|v| *v = 2.0);
        let dt_fast = solver.cfl_dt(&u);
        u.comp[0].iter_mut().for_each(|v| *v = 4.0);
        let dt_faster = solver.cfl_dt(&u);
        assert!((dt_fast / dt_faster - 2.0).abs() < 1e-9);
        // Δx = 1/8, CFL 0.8 → dt = 0.8·(1/8)/2 = 0.05
        assert!((dt_fast - 0.05).abs() < 1e-9);
        solver.cfg.target_cfl = None;
        let mut state = State::zeros(&solver.mesh);
        state.u = u;
        let src = VectorField::zeros(solver.mesh.ncells);
        solver.cfg.dt = 0.01;
        let stats = solver.step(&mut state, &src, None);
        assert_eq!(stats.dt, 0.01);
    }
}
