//! Smagorinsky SGS baseline (paper §5.3): eddy viscosity
//! `ν_t = (C_s Δ)² |S̄|` with van-Driest damping `(1 − exp(−y⁺/A⁺))²`
//! toward the walls to avoid excessive near-wall friction.

use crate::fvm;
use crate::mesh::{Mesh, VectorField};

pub const A_PLUS: f64 = 26.0;

/// Per-cell eddy viscosity. `wall_dist` is the distance to the nearest wall
/// per cell (pass `None` for unbounded flows ⇒ no damping); `u_tau`/`nu` set
/// the viscous scaling for y⁺.
pub fn smagorinsky_nu_t(
    mesh: &Mesh,
    u: &VectorField,
    cs: f64,
    wall_dist: Option<&[f64]>,
    u_tau: f64,
    nu: f64,
) -> Vec<f64> {
    // velocity gradients per component (central differences via the
    // transform-aware scalar gradient)
    let grads: Vec<VectorField> =
        (0..mesh.dim).map(|c| fvm::pressure_gradient(mesh, &u.comp[c])).collect();
    let mut nu_t = vec![0.0; mesh.ncells];
    for cell in 0..mesh.ncells {
        // |S| = sqrt(2 S_ij S_ij), S_ij = ½(∂u_i/∂x_j + ∂u_j/∂x_i)
        let mut s2 = 0.0;
        for i in 0..mesh.dim {
            for j in 0..mesh.dim {
                let sij = 0.5 * (grads[i].comp[j][cell] + grads[j].comp[i][cell]);
                s2 += sij * sij;
            }
        }
        let smag = (2.0 * s2).sqrt();
        let delta = mesh.jac[cell].powf(1.0 / mesh.dim as f64);
        let mut damp = 1.0;
        if let Some(d) = wall_dist {
            let y_plus = d[cell] * u_tau / nu.max(1e-300);
            damp = (1.0 - (-y_plus / A_PLUS).exp()).powi(2);
        }
        nu_t[cell] = (cs * delta).powi(2) * smag * damp;
    }
    nu_t
}

/// Wall distance for a plane channel with walls at y=0 and y=ly.
pub fn channel_wall_distance(mesh: &Mesh, ly: f64) -> Vec<f64> {
    mesh.centers.iter().map(|c| c[1].min(ly - c[1])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;

    #[test]
    fn nu_t_zero_for_uniform_flow() {
        let mesh = gen::periodic_box2d(8, 8, 1.0, 1.0);
        let mut u = VectorField::zeros(mesh.ncells);
        u.comp[0].iter_mut().for_each(|v| *v = 1.0);
        let nu_t = smagorinsky_nu_t(&mesh, &u, 0.1, None, 0.0, 1.0);
        assert!(nu_t.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn nu_t_scales_with_shear_and_cs() {
        let mesh = gen::channel3d([6, 8, 6], [1.0, 2.0, 1.0], 1.0);
        let mut u = VectorField::zeros(mesh.ncells);
        for (cell, c) in mesh.centers.iter().enumerate() {
            u.comp[0][cell] = 2.0 * c[1]; // |S| = 2 (du/dy = 2)
        }
        let a = smagorinsky_nu_t(&mesh, &u, 0.1, None, 0.0, 1.0);
        let b = smagorinsky_nu_t(&mesh, &u, 0.2, None, 0.0, 1.0);
        // interior cells: ν_t(Cs=0.2) = 4× ν_t(Cs=0.1)
        let mid = mesh.blocks[0].lidx(3, 4, 3);
        assert!(a[mid] > 0.0);
        assert!((b[mid] / a[mid] - 4.0).abs() < 1e-9);
        // analytic: (CsΔ)²·|S| with |S|=2
        let delta = mesh.jac[mid].powf(1.0 / 3.0);
        assert!((a[mid] - (0.1 * delta).powi(2) * 2.0).abs() < 1e-9 * a[mid]);
    }

    #[test]
    fn van_driest_suppresses_near_wall() {
        let mesh = gen::channel3d([4, 16, 4], [1.0, 2.0, 1.0], 1.08);
        let mut u = VectorField::zeros(mesh.ncells);
        for (cell, c) in mesh.centers.iter().enumerate() {
            u.comp[0][cell] = c[1] * (2.0 - c[1]); // parabolic
        }
        let dist = channel_wall_distance(&mesh, 2.0);
        let nu = 1e-3;
        let damped = smagorinsky_nu_t(&mesh, &u, 0.1, Some(&dist), 0.05, nu);
        let undamped = smagorinsky_nu_t(&mesh, &u, 0.1, None, 0.0, nu);
        let b = &mesh.blocks[0];
        let wall_cell = b.lidx(1, 0, 1);
        assert!(
            damped[wall_cell] < 0.5 * undamped[wall_cell],
            "{} vs {}",
            damped[wall_cell],
            undamped[wall_cell]
        );
    }
}
