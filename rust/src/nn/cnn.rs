//! A small CNN built from multi-block conv layers + ReLU, with a manual
//! forward tape and backward pass — the corrector architecture of paper §5
//! (7-layer net for the 2D cases, 8-layer 3³-kernel net for the TCF SGS).

use super::conv::{ConvTable, MultiBlockConv};
use crate::mesh::Mesh;
use crate::util::rng::Rng;

/// Configuration of one conv layer.
#[derive(Clone, Copy, Debug)]
pub struct LayerCfg {
    pub cout: usize,
    pub radius: usize,
    pub relu: bool,
}

/// One mesh's worth of neighborhood tables for a [`Cnn`]: the per-radius
/// [`ConvTable`]s plus the layer → table mapping. The network's weights
/// depend only on (cin, cout, taps), so the *same* parameters evaluate on
/// any mesh of the same dimension through that mesh's `CnnTables` — the
/// per-mesh cache that lets one shared corrector train across a mixed-mesh
/// scenario batch.
pub struct CnnTables {
    /// Tables deduplicated by radius.
    pub tables: Vec<ConvTable>,
    /// Table index per layer.
    pub table_of: Vec<usize>,
}

impl CnnTables {
    /// Build the deduplicated tables for `layers` on `mesh`.
    fn build(mesh: &Mesh, layers: &[LayerCfg]) -> CnnTables {
        let mut tables: Vec<ConvTable> = Vec::new();
        let mut table_of = Vec::with_capacity(layers.len());
        for l in layers {
            let ti = match tables.iter().position(|t| t.radius == l.radius) {
                Some(i) => i,
                None => {
                    tables.push(ConvTable::build(mesh, l.radius));
                    tables.len() - 1
                }
            };
            table_of.push(ti);
        }
        CnnTables { tables, table_of }
    }
}

/// The CNN: layer configs, home-mesh conv tables, flat parameters.
pub struct Cnn {
    pub cin: usize,
    pub layers: Vec<LayerCfg>,
    pub convs: Vec<MultiBlockConv>,
    /// Tables of the mesh the network was built on; [`Cnn::forward`] /
    /// [`Cnn::backward`] use these. For other meshes build a set with
    /// [`Cnn::tables_for`] and use the `*_with` variants.
    pub tables: CnnTables,
    pub params: Vec<f64>,
    /// Parameter offset of each layer in `params`.
    pub offsets: Vec<usize>,
}

/// Forward activations, kept for the backward pass.
pub struct CnnTape {
    /// Pre-activation outputs per layer.
    pub pre: Vec<Vec<Vec<f64>>>,
    /// Post-activation outputs per layer (aliases pre when no ReLU).
    pub post: Vec<Vec<Vec<f64>>>,
}

impl Cnn {
    /// Build with He-initialized weights (deterministic via `seed`).
    pub fn new(mesh: &Mesh, cin: usize, layers: Vec<LayerCfg>, seed: u64) -> Cnn {
        let tables = CnnTables::build(mesh, &layers);
        let mut convs = Vec::new();
        let mut offsets = Vec::new();
        let mut nparams = 0;
        let mut prev_c = cin;
        for (li, l) in layers.iter().enumerate() {
            let conv = MultiBlockConv {
                cin: prev_c,
                cout: l.cout,
                taps: tables.tables[tables.table_of[li]].taps,
            };
            offsets.push(nparams);
            nparams += conv.nweights();
            convs.push(conv);
            prev_c = l.cout;
        }
        let mut rng = Rng::new(seed);
        let mut params = vec![0.0; nparams];
        for (li, conv) in convs.iter().enumerate() {
            let fan_in = (conv.cin * conv.taps) as f64;
            let std = (2.0 / fan_in).sqrt();
            let w_end = offsets[li] + conv.cout * conv.cin * conv.taps;
            for p in params[offsets[li]..w_end].iter_mut() {
                *p = std * rng.normal();
            }
            // biases stay zero
        }
        Cnn { cin, layers, convs, tables, params, offsets }
    }

    pub fn nparams(&self) -> usize {
        self.params.len()
    }

    /// Build this network's neighborhood tables for another mesh, so the
    /// shared weights evaluate there ([`Cnn::forward_with`] /
    /// [`Cnn::backward_with`]). Errs if the mesh is tap-incompatible with
    /// the weights (a different dimension changes the window size
    /// (2r+1)^dim and therefore the weight count).
    pub fn tables_for(&self, mesh: &Mesh) -> Result<CnnTables, String> {
        let tables = CnnTables::build(mesh, &self.layers);
        for (li, conv) in self.convs.iter().enumerate() {
            let got = tables.tables[tables.table_of[li]].taps;
            if got != conv.taps {
                return Err(format!(
                    "layer {li}: mesh gives {got} taps but the weights were built \
                     for {} (mesh dim {} vs the network's home mesh)",
                    conv.taps, mesh.dim
                ));
            }
        }
        Ok(tables)
    }

    /// Forward pass; returns the output channels and the tape.
    pub fn forward(&self, input: &[Vec<f64>]) -> (Vec<Vec<f64>>, CnnTape) {
        self.forward_with(&self.tables, input)
    }

    /// [`Cnn::forward`] through an explicit table set (see
    /// [`Cnn::tables_for`]); `input` channels must be sized for that
    /// table's mesh.
    pub fn forward_with(&self, tables: &CnnTables, input: &[Vec<f64>]) -> (Vec<Vec<f64>>, CnnTape) {
        let ncells = input[0].len();
        let mut cur: Vec<Vec<f64>> = input.to_vec();
        let mut pre = Vec::with_capacity(self.layers.len());
        let mut post = Vec::with_capacity(self.layers.len());
        for (li, conv) in self.convs.iter().enumerate() {
            let mut out = vec![vec![0.0; ncells]; conv.cout];
            conv.forward(
                &tables.tables[tables.table_of[li]],
                &self.params[self.offsets[li]..],
                &cur,
                &mut out,
            );
            pre.push(out.clone());
            if self.layers[li].relu {
                for ch in out.iter_mut() {
                    for v in ch.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            post.push(out.clone());
            cur = out;
        }
        (cur, CnnTape { pre, post })
    }

    /// Backward pass: given ∂L/∂output, return (∂L/∂params, ∂L/∂input).
    pub fn backward(
        &self,
        input: &[Vec<f64>],
        tape: &CnnTape,
        doutput: &[Vec<f64>],
    ) -> (Vec<f64>, Vec<Vec<f64>>) {
        self.backward_with(&self.tables, input, tape, doutput)
    }

    /// [`Cnn::backward`] through an explicit table set; `tape` must come
    /// from a [`Cnn::forward_with`] on the same tables.
    pub fn backward_with(
        &self,
        tables: &CnnTables,
        input: &[Vec<f64>],
        tape: &CnnTape,
        doutput: &[Vec<f64>],
    ) -> (Vec<f64>, Vec<Vec<f64>>) {
        let ncells = input[0].len();
        let mut dparams = vec![0.0; self.params.len()];
        let mut dout: Vec<Vec<f64>> = doutput.to_vec();
        for li in (0..self.convs.len()).rev() {
            let conv = &self.convs[li];
            // ReLU backward on the pre-activations
            if self.layers[li].relu {
                for (ch, pre_ch) in dout.iter_mut().zip(&tape.pre[li]) {
                    for (d, p) in ch.iter_mut().zip(pre_ch) {
                        if *p <= 0.0 {
                            *d = 0.0;
                        }
                    }
                }
            }
            let layer_in: &[Vec<f64>] = if li == 0 { input } else { &tape.post[li - 1] };
            let mut dinput = vec![vec![0.0; ncells]; conv.cin];
            let w_slice = &self.params[self.offsets[li]..];
            conv.backward(
                &tables.tables[tables.table_of[li]],
                w_slice,
                layer_in,
                &dout,
                &mut dparams[self.offsets[li]..],
                &mut dinput,
            );
            dout = dinput;
        }
        (dparams, dout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;

    fn tiny_net(mesh: &Mesh) -> Cnn {
        Cnn::new(
            mesh,
            2,
            vec![
                LayerCfg { cout: 4, radius: 1, relu: true },
                LayerCfg { cout: 2, radius: 1, relu: false },
            ],
            7,
        )
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let mesh = gen::periodic_box2d(6, 6, 1.0, 1.0);
        let net = tiny_net(&mesh);
        let input: Vec<Vec<f64>> =
            (0..2).map(|c| (0..mesh.ncells).map(|i| (i + c) as f64 * 0.01).collect()).collect();
        let (out1, _) = net.forward(&input);
        let (out2, _) = net.forward(&input);
        assert_eq!(out1.len(), 2);
        assert_eq!(out1[0].len(), mesh.ncells);
        assert_eq!(out1, out2);
        // same seed → same params
        let net2 = tiny_net(&mesh);
        assert_eq!(net.params, net2.params);
    }

    #[test]
    fn backward_matches_fd() {
        let mesh = gen::periodic_box2d(5, 5, 1.0, 1.0);
        let net = tiny_net(&mesh);
        let mut rng = Rng::new(11);
        let input: Vec<Vec<f64>> = (0..2).map(|_| rng.normal_vec(mesh.ncells)).collect();
        let cot: Vec<Vec<f64>> = (0..2).map(|_| rng.normal_vec(mesh.ncells)).collect();
        let loss = |net: &Cnn, inp: &[Vec<f64>]| -> f64 {
            let (out, _) = net.forward(inp);
            out.iter()
                .zip(&cot)
                .map(|(o, c)| o.iter().zip(c).map(|(a, b)| a * b).sum::<f64>())
                .sum()
        };
        let (_, tape) = net.forward(&input);
        let (dp, din) = net.backward(&input, &tape, &cot);
        let eps = 1e-6;
        // probe a few weights across both layers
        let mut net_mut = Cnn::new(&mesh, 2, net.layers.clone(), 7);
        for probe in 0..8 {
            let k = (probe * 131) % net.nparams();
            net_mut.params.copy_from_slice(&net.params);
            net_mut.params[k] += eps;
            let lp = loss(&net_mut, &input);
            net_mut.params[k] -= 2.0 * eps;
            let lm = loss(&net_mut, &input);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - dp[k]).abs() < 2e-6 * (1.0 + fd.abs()),
                "param {k}: fd {fd} vs {}",
                dp[k]
            );
        }
        // input gradient
        for probe in 0..4 {
            let ci = probe % 2;
            let cell = (probe * 5) % mesh.ncells;
            let mut ip = input.clone();
            ip[ci][cell] += eps;
            let mut im = input.clone();
            im[ci][cell] -= eps;
            let fd = (loss(&net, &ip) - loss(&net, &im)) / (2.0 * eps);
            assert!(
                (fd - din[ci][cell]).abs() < 2e-6 * (1.0 + fd.abs()),
                "input[{ci}][{cell}]: fd {fd} vs {}",
                din[ci][cell]
            );
        }
    }

    #[test]
    fn shared_weights_evaluate_on_a_second_mesh() {
        // one set of weights, two 2D meshes with different topology: the
        // per-mesh table cache must route each forward/backward through
        // its own neighbor tables while gradients flow to the shared params
        let home = gen::periodic_box2d(6, 6, 1.0, 1.0);
        let other = gen::cavity2d(5, 1.0, 1.0, false);
        let net = tiny_net(&home);
        let tables = net.tables_for(&other).expect("same-dim meshes are tap-compatible");
        let input: Vec<Vec<f64>> =
            (0..2).map(|c| (0..other.ncells).map(|i| (i + c) as f64 * 0.01).collect()).collect();
        let (out, tape) = net.forward_with(&tables, &input);
        assert_eq!(out[0].len(), other.ncells);
        let cot: Vec<Vec<f64>> = (0..2).map(|_| vec![1.0; other.ncells]).collect();
        let (dp, din) = net.backward_with(&tables, &input, &tape, &cot);
        assert_eq!(dp.len(), net.nparams());
        assert_eq!(din[0].len(), other.ncells);
        assert!(dp.iter().any(|v| *v != 0.0), "gradients must reach the shared params");
        // the home tables keep working through the plain entry points
        let home_input: Vec<Vec<f64>> = (0..2).map(|_| vec![0.1; home.ncells]).collect();
        let (home_out, _) = net.forward(&home_input);
        assert_eq!(home_out[0].len(), home.ncells);
    }

    #[test]
    fn tap_incompatible_mesh_is_rejected() {
        let home = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let net = tiny_net(&home);
        // a 3D mesh changes (2r+1)^dim: 9 taps -> 27, weights cannot apply
        let m3 = gen::channel3d([3, 4, 3], [1.0, 1.0, 1.0], 1.0);
        let err = net.tables_for(&m3).expect_err("3D mesh must be tap-incompatible");
        assert!(err.contains("taps"), "unexpected error: {err}");
    }

    #[test]
    fn relu_kills_negative_gradients() {
        let mesh = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let mut net = Cnn::new(
            &mesh,
            1,
            vec![LayerCfg { cout: 1, radius: 0, relu: true }],
            3,
        );
        // radius 0: 1 tap; set w = 1, b = 0
        net.params[0] = 1.0;
        net.params[1] = 0.0;
        let input = vec![(0..mesh.ncells)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect::<Vec<f64>>()];
        let (out, tape) = net.forward(&input);
        assert!(out[0].iter().all(|v| *v >= 0.0));
        let cot = vec![vec![1.0; mesh.ncells]];
        let (_, din) = net.backward(&input, &tape, &cot);
        for (i, d) in din[0].iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(*d, 1.0);
            } else {
                assert_eq!(*d, 0.0);
            }
        }
    }
}
