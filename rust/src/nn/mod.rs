//! Neural-network substrate for the learned correctors (paper §3, §5):
//! multi-block convolutions that pad across block connections (§2.2/A.6),
//! a small CNN with hand-written forward/backward, and the Smagorinsky SGS
//! baseline with van-Driest wall damping (§5.3).

pub mod cnn;
pub mod conv;
pub mod smagorinsky;

pub use cnn::{Cnn, CnnTables, CnnTape, LayerCfg};
pub use conv::{ConvTable, MultiBlockConv};
pub use smagorinsky::smagorinsky_nu_t;
