//! Multi-block convolution (paper §2.2, A.6): the neighborhood of each cell
//! is resolved through the mesh topology, so the convolution window crosses
//! block connections (including periodic wraps) seamlessly — the paper's
//! "custom padding with values or features of connected blocks". Physical
//! boundaries are zero-padded.
//!
//! The neighborhood table is precomputed once per (mesh, radius) and shared
//! by all conv layers; entries of `u32::MAX` mark out-of-domain taps.

use crate::mesh::{Mesh, NeighRef};

pub const OUT_OF_DOMAIN: u32 = u32::MAX;

/// Precomputed Chebyshev-ball neighborhood per cell.
pub struct ConvTable {
    pub radius: usize,
    pub dim: usize,
    /// taps per cell: (2r+1)^dim entries, x-fastest offset ordering.
    pub taps: usize,
    pub idx: Vec<u32>,
}

impl ConvTable {
    /// Walk the topology from `cell` by `offset` (per-axis steps), returning
    /// the reached cell or None if a physical boundary blocks the walk.
    fn walk(mesh: &Mesh, cell: usize, offset: [isize; 3]) -> Option<usize> {
        let mut cur = cell;
        for ax in 0..mesh.dim {
            let steps = offset[ax];
            let face = if steps < 0 { 2 * ax } else { 2 * ax + 1 };
            for _ in 0..steps.unsigned_abs() {
                match mesh.topo.at(cur, face) {
                    NeighRef::Cell(n) => cur = n as usize,
                    _ => return None,
                }
            }
        }
        Some(cur)
    }

    pub fn build(mesh: &Mesh, radius: usize) -> ConvTable {
        let dim = mesh.dim;
        let w = 2 * radius + 1;
        let taps = w.pow(dim as u32);
        let mut idx = vec![OUT_OF_DOMAIN; mesh.ncells * taps];
        for cell in 0..mesh.ncells {
            let mut t = 0;
            let kz_range: Vec<isize> = if dim == 3 {
                (-(radius as isize)..=radius as isize).collect()
            } else {
                vec![0]
            };
            for kz in &kz_range {
                for ky in -(radius as isize)..=radius as isize {
                    for kx in -(radius as isize)..=radius as isize {
                        if let Some(n) = Self::walk(mesh, cell, [kx, ky, *kz]) {
                            idx[cell * taps + t] = n as u32;
                        }
                        t += 1;
                    }
                }
            }
        }
        ConvTable { radius, dim, taps, idx }
    }
}

/// One multi-block convolution layer: `cout × cin × taps` weights + bias.
pub struct MultiBlockConv {
    pub cin: usize,
    pub cout: usize,
    pub taps: usize,
}

impl MultiBlockConv {
    pub fn nweights(&self) -> usize {
        self.cout * self.cin * self.taps + self.cout
    }

    /// Forward: `out[co] = bias[co] + Σ_ci Σ_t w[co][ci][t] · in[ci][tap t]`.
    /// `input`/`output` are channel-major `[channels][ncells]`.
    pub fn forward(
        &self,
        table: &ConvTable,
        params: &[f64],
        input: &[Vec<f64>],
        output: &mut [Vec<f64>],
    ) {
        let ncells = input[0].len();
        let taps = self.taps;
        let wsz = self.cin * taps;
        let bias_off = self.cout * wsz;
        for co in 0..self.cout {
            let b = params[bias_off + co];
            let wrow = &params[co * wsz..(co + 1) * wsz];
            let out = &mut output[co];
            for cell in 0..ncells {
                let tap_base = cell * taps;
                let mut acc = b;
                for ci in 0..self.cin {
                    let w = &wrow[ci * taps..(ci + 1) * taps];
                    let inp = &input[ci];
                    for t in 0..taps {
                        let n = table.idx[tap_base + t];
                        if n != OUT_OF_DOMAIN {
                            acc += w[t] * inp[n as usize];
                        }
                    }
                }
                out[cell] = acc;
            }
        }
    }

    /// Backward: accumulate `dparams` and `dinput` from `doutput`.
    #[allow(clippy::too_many_arguments)]
    pub fn backward(
        &self,
        table: &ConvTable,
        params: &[f64],
        input: &[Vec<f64>],
        doutput: &[Vec<f64>],
        dparams: &mut [f64],
        dinput: &mut [Vec<f64>],
    ) {
        let ncells = input[0].len();
        let taps = self.taps;
        let wsz = self.cin * taps;
        let bias_off = self.cout * wsz;
        for co in 0..self.cout {
            let wrow = &params[co * wsz..(co + 1) * wsz];
            let dout = &doutput[co];
            for cell in 0..ncells {
                let d = dout[cell];
                if d == 0.0 {
                    continue;
                }
                dparams[bias_off + co] += d;
                let tap_base = cell * taps;
                for ci in 0..self.cin {
                    let w = &wrow[ci * taps..(ci + 1) * taps];
                    let dwr = &mut dparams[co * wsz + ci * taps..co * wsz + (ci + 1) * taps];
                    let inp = &input[ci];
                    let dinp = &mut dinput[ci];
                    for t in 0..taps {
                        let n = table.idx[tap_base + t];
                        if n != OUT_OF_DOMAIN {
                            let n = n as usize;
                            dwr[t] += d * inp[n];
                            dinp[n] += d * w[t];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::util::rng::Rng;

    #[test]
    fn table_periodic_wrap() {
        let mesh = gen::periodic_box2d(4, 4, 1.0, 1.0);
        let t = ConvTable::build(&mesh, 1);
        assert_eq!(t.taps, 9);
        // cell (0,0): tap (-1,-1) wraps to (3,3)
        let cell = mesh.gid(0, 0, 0, 0);
        let wrap = mesh.gid(0, 3, 3, 0);
        assert_eq!(t.idx[cell * 9], wrap as u32);
        // no out-of-domain taps on a periodic box
        assert!(t.idx.iter().all(|v| *v != OUT_OF_DOMAIN));
    }

    #[test]
    fn table_zero_pads_at_walls() {
        let mesh = gen::cavity2d(4, 1.0, 1.0, false);
        let t = ConvTable::build(&mesh, 1);
        let corner = mesh.gid(0, 0, 0, 0);
        // tap (-1,-1) is out of domain
        assert_eq!(t.idx[corner * 9], OUT_OF_DOMAIN);
        // tap (+1,+1) is in
        assert_eq!(t.idx[corner * 9 + 8], mesh.gid(0, 1, 1, 0) as u32);
    }

    #[test]
    fn conv_crosses_block_connection_seamlessly() {
        // identity-like kernel picking the +x neighbor must cross the block
        // boundary of the two-block channel exactly like a single block
        let m2 = gen::two_block_channel2d(4, 4, 0);
        let t = ConvTable::build(&m2, 1);
        let conv = MultiBlockConv { cin: 1, cout: 1, taps: 9 };
        let mut params = vec![0.0; conv.nweights()];
        params[5] = 1.0; // tap (+1, 0)
        let input = vec![(0..m2.ncells).map(|i| i as f64).collect::<Vec<f64>>()];
        let mut out = vec![vec![0.0; m2.ncells]];
        conv.forward(&t, &params, &input, &mut out);
        // cell at block-0 right edge picks block-1 left cell
        let edge = m2.gid(0, 3, 1, 0);
        let other = m2.gid(1, 0, 1, 0);
        assert_eq!(out[0][edge], other as f64);
    }

    #[test]
    fn conv_backward_matches_fd() {
        let mesh = gen::periodic_box2d(5, 4, 1.0, 1.0);
        let table = ConvTable::build(&mesh, 1);
        let conv = MultiBlockConv { cin: 2, cout: 2, taps: 9 };
        let mut rng = Rng::new(3);
        let params = rng.normal_vec(conv.nweights());
        let input: Vec<Vec<f64>> = (0..2).map(|_| rng.normal_vec(mesh.ncells)).collect();
        let cot: Vec<Vec<f64>> = (0..2).map(|_| rng.normal_vec(mesh.ncells)).collect();
        let loss = |p: &[f64], inp: &[Vec<f64>]| -> f64 {
            let mut out = vec![vec![0.0; mesh.ncells]; 2];
            conv.forward(&table, p, inp, &mut out);
            out.iter()
                .zip(&cot)
                .map(|(o, c)| o.iter().zip(c).map(|(a, b)| a * b).sum::<f64>())
                .sum()
        };
        let mut dparams = vec![0.0; conv.nweights()];
        let mut dinput = vec![vec![0.0; mesh.ncells]; 2];
        conv.backward(&table, &params, &input, &cot, &mut dparams, &mut dinput);
        let eps = 1e-6;
        for probe in 0..6 {
            let k = (probe * 37) % conv.nweights();
            let mut pp = params.clone();
            pp[k] += eps;
            let mut pm = params.clone();
            pm[k] -= eps;
            let fd = (loss(&pp, &input) - loss(&pm, &input)) / (2.0 * eps);
            assert!((fd - dparams[k]).abs() < 1e-7 * (1.0 + fd.abs()), "w[{k}]: {fd} vs {}", dparams[k]);
        }
        for probe in 0..4 {
            let ci = probe % 2;
            let cell = (probe * 7) % mesh.ncells;
            let mut ip = input.clone();
            ip[ci][cell] += eps;
            let mut im = input.clone();
            im[ci][cell] -= eps;
            let fd = (loss(&params, &ip) - loss(&params, &im)) / (2.0 * eps);
            assert!(
                (fd - dinput[ci][cell]).abs() < 1e-7 * (1.0 + fd.abs()),
                "in[{ci}][{cell}]: {fd} vs {}",
                dinput[ci][cell]
            );
        }
    }
}
