//! Embedded reference data: Ghia et al. (1982) lid-driven-cavity centerline
//! profiles and the law-of-the-wall used to sanity-check channel statistics
//! (the roles the spectral Hoyas–Jiménez data plays in the paper; see
//! DESIGN.md §5 for the substitution rationale).

/// Ghia Re=100, u on the vertical centerline: (y, u).
pub const GHIA_RE100_U: [(f64, f64); 15] = [
    (0.0547, -0.03717),
    (0.0625, -0.04192),
    (0.0703, -0.04775),
    (0.1016, -0.06434),
    (0.1719, -0.10150),
    (0.2813, -0.15662),
    (0.4531, -0.21090),
    (0.5000, -0.20581),
    (0.6172, -0.13641),
    (0.7344, 0.00332),
    (0.8516, 0.23151),
    (0.9531, 0.68717),
    (0.9609, 0.73722),
    (0.9688, 0.78871),
    (0.9766, 0.84123),
];

/// Ghia Re=100, v on the horizontal centerline: (x, v).
pub const GHIA_RE100_V: [(f64, f64); 14] = [
    (0.0625, 0.09233),
    (0.0703, 0.10091),
    (0.0781, 0.10890),
    (0.0938, 0.12317),
    (0.1563, 0.16077),
    (0.2266, 0.17507),
    (0.2344, 0.17527),
    (0.5000, 0.05454),
    (0.8047, -0.24533),
    (0.8594, -0.22445),
    (0.9063, -0.16914),
    (0.9453, -0.10313),
    (0.9531, -0.08864),
    (0.9609, -0.07391),
];

/// Ghia Re=1000, u on the vertical centerline: (y, u).
pub const GHIA_RE1000_U: [(f64, f64); 14] = [
    (0.0547, -0.08186),
    (0.0625, -0.09266),
    (0.0703, -0.10338),
    (0.1016, -0.14612),
    (0.1719, -0.24299),
    (0.2813, -0.32726),
    (0.4531, -0.17119),
    (0.5000, -0.11477),
    (0.6172, 0.02135),
    (0.7344, 0.16256),
    (0.8516, 0.29093),
    (0.9531, 0.55892),
    (0.9609, 0.61756),
    (0.9688, 0.68439),
];

/// Law of the wall: u⁺ = y⁺ (viscous sublayer) / log law with κ=0.41, B=5.2.
pub fn law_of_the_wall(y_plus: f64) -> f64 {
    if y_plus < 11.0 {
        y_plus
    } else {
        (1.0 / 0.41) * y_plus.ln() + 5.2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn law_of_wall_continuity_region() {
        // the two branches cross near y+ ≈ 11
        let a = law_of_the_wall(10.9);
        let b = law_of_the_wall(11.1);
        assert!((a - b).abs() < 0.6, "{a} vs {b}");
    }

    #[test]
    fn ghia_tables_monotone_in_coordinate() {
        for w in GHIA_RE100_U.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        for w in GHIA_RE1000_U.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
    }
}
