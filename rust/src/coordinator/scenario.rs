//! Scenario registry + batched multi-scenario runner.
//!
//! A [`Scenario`] packages everything needed to start a simulation — mesh,
//! solver configuration, initial state, and source field — behind one
//! `build()` call, unifying the setup code that used to be duplicated
//! across `examples/*.rs` and `benches/*.rs`. The built-in registry covers
//! the paper's forward workloads (Taylor–Green box, lid-driven cavity,
//! plane Poiseuille, 3D turbulent channel, vortex street).
//!
//! [`BatchRunner`] advances many independent scenario runs concurrently on
//! one persistent [`par`](crate::par) pool — e.g. a cavity Reynolds sweep in
//! one call — claiming runs off a shared counter so long and short scenarios
//! load-balance. Scenario-level tasks and kernel-level chunks share the same
//! workers: each built solver gets a clone of the runner's
//! [`ExecCtx`](crate::par::ExecCtx), so its inner SpMV/assembly/precondition
//! kernels submit nested jobs to the pool instead of being forced serial —
//! a 3-scenario batch on 16 cores keeps the remaining cores busy with kernel
//! chunks. Per-scenario results are unchanged by the sharing (each
//! scenario's kernels see the same context width either way) and come back
//! in input order.

use crate::mesh::{gen, Mesh, VectorField};
use crate::par::ExecCtx;
use crate::piso::{PisoConfig, PisoSolver, State, StepStats};
use std::sync::Mutex;
use std::time::Instant;

/// A ready-to-advance simulation: solver + state + (fixed) source field.
pub struct ScenarioRun {
    pub label: String,
    pub solver: PisoSolver,
    pub state: State,
    pub source: VectorField,
}

/// A named, parameterized simulation setup.
pub trait Scenario: Send + Sync {
    /// Registry key of the scenario family (e.g. `"cavity"`).
    fn kind(&self) -> &'static str;
    /// Human-readable label including the distinguishing parameters.
    fn label(&self) -> String;
    /// Construct the mesh, solver, initial state, and source.
    fn build(&self) -> ScenarioRun;
}

/// Divergence-free Taylor–Green vortex velocity on the unit box.
pub fn taylor_green_init(mesh: &Mesh) -> VectorField {
    let tau = 2.0 * std::f64::consts::PI;
    let mut u = VectorField::zeros(mesh.ncells);
    for (i, c) in mesh.centers.iter().enumerate() {
        u.comp[0][i] = (tau * c[0]).sin() * (tau * c[1]).cos();
        u.comp[1][i] = -(tau * c[0]).cos() * (tau * c[1]).sin();
    }
    u
}

/// Decaying Taylor–Green vortex on a periodic 2D box (the quickstart /
/// viscous-decay validation flow).
#[derive(Clone, Debug)]
pub struct TaylorGreen {
    pub n: usize,
    pub nu: f64,
    pub dt: f64,
}

impl Default for TaylorGreen {
    fn default() -> Self {
        TaylorGreen { n: 32, nu: 0.01, dt: 0.01 }
    }
}

impl Scenario for TaylorGreen {
    fn kind(&self) -> &'static str {
        "taylor-green"
    }

    fn label(&self) -> String {
        format!("taylor-green {0}x{0} nu={1}", self.n, self.nu)
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::periodic_box2d(self.n, self.n, 1.0, 1.0);
        let solver =
            PisoSolver::new(mesh, PisoConfig { dt: self.dt, ..Default::default() }, self.nu);
        let mut state = State::zeros(&solver.mesh);
        state.u = taylor_green_init(&solver.mesh);
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Lid-driven cavity at a given Reynolds number (paper Fig 3 / B.16).
#[derive(Clone, Debug)]
pub struct LidDrivenCavity {
    pub n: usize,
    pub re: f64,
    pub dt: f64,
    pub refined: bool,
}

impl Default for LidDrivenCavity {
    fn default() -> Self {
        LidDrivenCavity { n: 32, re: 100.0, dt: 0.02, refined: false }
    }
}

impl Scenario for LidDrivenCavity {
    fn kind(&self) -> &'static str {
        "cavity"
    }

    fn label(&self) -> String {
        format!(
            "cavity {0}x{0} Re={1}{2}",
            self.n,
            self.re,
            if self.refined { " refined" } else { "" }
        )
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::cavity2d(self.n, 1.0, 1.0, self.refined);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: self.dt, ..Default::default() },
            1.0 / self.re,
        );
        let state = State::zeros(&solver.mesh);
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Plane Poiseuille channel driven by a unit body force at ν = 1 (paper
/// Fig B.15; the steady profile is the analytic `y(1−y)/2`).
#[derive(Clone, Debug)]
pub struct Poiseuille {
    pub nx: usize,
    pub ny: usize,
    pub wall_ratio: f64,
    pub refined: bool,
    pub dt: f64,
}

impl Default for Poiseuille {
    fn default() -> Self {
        Poiseuille { nx: 6, ny: 16, wall_ratio: 1.12, refined: false, dt: 0.05 }
    }
}

impl Scenario for Poiseuille {
    fn kind(&self) -> &'static str {
        "poiseuille"
    }

    fn label(&self) -> String {
        format!(
            "poiseuille {}x{}{}",
            self.nx,
            self.ny,
            if self.refined { " refined" } else { "" }
        )
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::channel2d(self.nx, self.ny, 1.0, 1.0, self.wall_ratio, self.refined);
        let solver =
            PisoSolver::new(mesh, PisoConfig { dt: self.dt, ..Default::default() }, 1.0);
        let state = State::zeros(&solver.mesh);
        let mut source = VectorField::zeros(solver.mesh.ncells);
        source.comp[0].iter_mut().for_each(|v| *v = 1.0);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Forced 3D turbulent channel (the §5.3 SGS workload at mini scale).
#[derive(Clone, Debug)]
pub struct TurbulentChannel {
    pub n: [usize; 3],
    pub l: [f64; 3],
    pub nu: f64,
    pub forcing: f64,
    pub dt: f64,
    pub perturbation: f64,
    pub seed: u64,
}

impl Default for TurbulentChannel {
    fn default() -> Self {
        TurbulentChannel {
            n: [12, 12, 6],
            l: [4.0, 2.0, 2.0],
            nu: 0.004,
            forcing: 0.01,
            dt: 0.08,
            perturbation: 0.4,
            seed: 1,
        }
    }
}

impl Scenario for TurbulentChannel {
    fn kind(&self) -> &'static str {
        "channel"
    }

    fn label(&self) -> String {
        format!("channel {}x{}x{} nu={}", self.n[0], self.n[1], self.n[2], self.nu)
    }

    fn build(&self) -> ScenarioRun {
        use super::experiments::tcf_sgs::{forcing_field, perturbed_channel_init};
        let mesh = gen::channel3d(self.n, self.l, 1.08);
        let solver =
            PisoSolver::new(mesh, PisoConfig { dt: self.dt, ..Default::default() }, self.nu);
        let mut state = State::zeros(&solver.mesh);
        state.u = perturbed_channel_init(&solver.mesh, self.l[1], self.perturbation, self.seed);
        let source = forcing_field(&solver.mesh, self.forcing);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Vortex street past the square obstacle on the 8-block grid-with-hole
/// (paper §5.1 geometry), with the symmetry-breaking perturbation that
/// triggers shedding onset within a short run.
#[derive(Clone, Debug)]
pub struct VortexStreet {
    pub nx: [usize; 3],
    pub ny: [usize; 3],
    pub re: f64,
    pub dt: f64,
    pub target_cfl: f64,
}

impl Default for VortexStreet {
    fn default() -> Self {
        VortexStreet { nx: [8, 6, 16], ny: [10, 6, 10], re: 500.0, dt: 0.05, target_cfl: 0.8 }
    }
}

impl VortexStreet {
    /// The grid geometry this scenario builds with (single source of truth
    /// for probe placement in examples/diagnostics).
    pub fn geometry(&self) -> gen::VortexStreetCfg {
        gen::VortexStreetCfg { nx: self.nx, ny: self.ny, ..Default::default() }
    }
}

impl Scenario for VortexStreet {
    fn kind(&self) -> &'static str {
        "vortex-street"
    }

    fn label(&self) -> String {
        format!("vortex-street Re={}", self.re)
    }

    fn build(&self) -> ScenarioRun {
        let cfg = self.geometry();
        let mesh = gen::vortex_street(&cfg);
        let nu = cfg.u_in * cfg.obs_h / self.re;
        let solver = PisoSolver::new(
            mesh,
            PisoConfig {
                dt: self.dt,
                target_cfl: Some(self.target_cfl),
                use_ilu: true,
                ..Default::default()
            },
            nu,
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[1][i] = 0.05 * (1.3 * c[0]).sin() * (0.9 * c[1]).cos();
        }
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// All built-in scenarios at their default parameters.
pub fn builtin_scenarios() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(TaylorGreen::default()),
        Box::new(LidDrivenCavity::default()),
        Box::new(Poiseuille::default()),
        Box::new(TurbulentChannel::default()),
        Box::new(VortexStreet::default()),
    ]
}

/// Look up a scenario family by its registry key (default parameters).
pub fn scenario_by_kind(kind: &str) -> Option<Box<dyn Scenario>> {
    builtin_scenarios().into_iter().find(|s| s.kind() == kind)
}

/// A cavity Reynolds sweep: one scenario per requested Re.
pub fn cavity_reynolds_sweep(n: usize, res: &[f64]) -> Vec<Box<dyn Scenario>> {
    res.iter()
        .map(|&re| Box::new(LidDrivenCavity { n, re, ..Default::default() }) as Box<dyn Scenario>)
        .collect()
}

/// Outcome of one scenario advanced by the [`BatchRunner`]: final state plus
/// aggregated per-step diagnostics.
pub struct BatchResult {
    pub label: String,
    pub state: State,
    /// Number of steps taken.
    pub steps: usize,
    /// Total Krylov iterations across all steps.
    pub adv_iters: usize,
    pub p_iters: usize,
    /// Worst per-step residuals / divergence over the run.
    pub adv_residual: f64,
    pub p_residual: f64,
    pub max_divergence: f64,
    /// Stats of the final step.
    pub last: StepStats,
    /// Wall-clock seconds spent building + advancing this scenario.
    pub wall_s: f64,
}

/// Advances many independent scenario runs concurrently on one shared
/// worker pool: scenario-level tasks and each scenario's inner kernel
/// chunks draw from the same workers (see module docs).
pub struct BatchRunner {
    pub steps: usize,
    ctx: ExecCtx,
}

impl BatchRunner {
    /// Runner advancing each scenario by `steps` steps on a pool sized by
    /// `PICT_THREADS` (read now, not from a process-wide cache).
    pub fn new(steps: usize) -> BatchRunner {
        BatchRunner { steps, ctx: ExecCtx::from_env() }
    }

    /// Use a pool of exactly `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> BatchRunner {
        self.ctx = ExecCtx::with_threads(threads);
        self
    }

    /// Share an existing pool (e.g. with other runners or solvers).
    pub fn with_ctx(mut self, ctx: ExecCtx) -> BatchRunner {
        self.ctx = ctx;
        self
    }

    /// Width of the pool scenarios (and their kernels) run on.
    pub fn threads(&self) -> usize {
        self.ctx.width()
    }

    /// Build and advance every scenario; results come back in input order.
    pub fn run(&self, scenarios: &[Box<dyn Scenario>]) -> Vec<BatchResult> {
        self.drive(scenarios.len(), |i| scenarios[i].build())
    }

    /// Advance pre-built runs (e.g. mid-simulation states).
    pub fn advance(&self, runs: Vec<ScenarioRun>) -> Vec<BatchResult> {
        let slots: Vec<Mutex<Option<ScenarioRun>>> =
            runs.into_iter().map(|r| Mutex::new(Some(r))).collect();
        self.drive(slots.len(), |i| slots[i].lock().unwrap().take().expect("run taken twice"))
    }

    fn drive<F>(&self, count: usize, make: F) -> Vec<BatchResult>
    where
        F: Fn(usize) -> ScenarioRun + Sync,
    {
        let steps = self.steps;
        let results: Vec<Mutex<Option<BatchResult>>> =
            (0..count).map(|_| Mutex::new(None)).collect();
        // one pool job per scenario; each scenario's solver gets a clone of
        // the same context, so its inner kernels submit nested jobs to the
        // very workers that are not busy advancing other scenarios
        self.ctx.run_tasks(count, |i| {
            let t0 = Instant::now();
            let mut run = make(i);
            run.solver.ctx = self.ctx.clone();
            let mut adv_iters = 0;
            let mut p_iters = 0;
            let mut adv_residual = 0.0f64;
            let mut p_residual = 0.0f64;
            let mut max_divergence = 0.0f64;
            let mut last = StepStats::default();
            for _ in 0..steps {
                let st = run.solver.step(&mut run.state, &run.source, None);
                adv_iters += st.adv_iters;
                p_iters += st.p_iters;
                adv_residual = adv_residual.max(st.adv_residual);
                p_residual = p_residual.max(st.p_residual);
                max_divergence = max_divergence.max(st.max_divergence);
                last = st;
            }
            *results[i].lock().unwrap() = Some(BatchResult {
                label: run.label,
                state: run.state,
                steps,
                adv_iters,
                p_iters,
                adv_residual,
                p_residual,
                max_divergence,
                last,
                wall_s: t0.elapsed().as_secs_f64(),
            });
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("batch worker skipped a run"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_distinct_kinds() {
        let all = builtin_scenarios();
        assert!(all.len() >= 4);
        let mut kinds: Vec<&str> = all.iter().map(|s| s.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), all.len(), "duplicate scenario kinds");
        assert!(scenario_by_kind("cavity").is_some());
        assert!(scenario_by_kind("no-such-flow").is_none());
    }

    #[test]
    fn cavity_sweep_builds_one_per_re() {
        let sweep = cavity_reynolds_sweep(8, &[50.0, 100.0, 200.0]);
        assert_eq!(sweep.len(), 3);
        assert!(sweep.iter().all(|s| s.kind() == "cavity"));
        let labels: Vec<String> = sweep.iter().map(|s| s.label()).collect();
        assert!(labels[0] != labels[1]);
    }

    #[test]
    fn batch_runner_advances_small_scenarios() {
        let scenarios: Vec<Box<dyn Scenario>> = vec![
            Box::new(TaylorGreen { n: 8, ..Default::default() }),
            Box::new(LidDrivenCavity { n: 8, ..Default::default() }),
            Box::new(Poiseuille { nx: 4, ny: 8, ..Default::default() }),
        ];
        let results = BatchRunner::new(2).with_threads(3).run(&scenarios);
        assert_eq!(results.len(), 3);
        for (r, s) in results.iter().zip(&scenarios) {
            assert_eq!(r.label, s.label());
            assert_eq!(r.state.step, 2);
            assert!(r.state.time > 0.0);
            assert!(r.p_iters > 0);
        }
    }

    #[test]
    fn advance_resumes_prebuilt_runs() {
        let runs: Vec<ScenarioRun> =
            vec![TaylorGreen { n: 8, ..Default::default() }.build()];
        let runner = BatchRunner::new(1);
        let first = runner.advance(runs);
        assert_eq!(first[0].state.step, 1);
    }
}
