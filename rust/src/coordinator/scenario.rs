//! Scenario registry + batched multi-scenario runner.
//!
//! A [`Scenario`] packages everything needed to start a simulation — mesh,
//! solver configuration, initial state, and source field — behind one
//! `build()` call, unifying the setup code that used to be duplicated
//! across `examples/*.rs` and `benches/*.rs`. The built-in registry covers
//! the paper's forward workloads (Taylor–Green box, lid-driven cavity,
//! plane Poiseuille, 3D turbulent channel, vortex street).
//!
//! [`BatchRunner`] advances many independent scenario runs concurrently on
//! one persistent [`par`](crate::par) pool — e.g. a cavity Reynolds sweep in
//! one call — claiming runs off a shared counter so long and short scenarios
//! load-balance. Scenario-level tasks and kernel-level chunks share the same
//! workers: each built solver gets a clone of the runner's
//! [`ExecCtx`](crate::par::ExecCtx), so its inner SpMV/assembly/precondition
//! kernels submit nested jobs to the pool instead of being forced serial —
//! a 3-scenario batch on 16 cores keeps the remaining cores busy with kernel
//! chunks. Per-scenario results are unchanged by the sharing (each
//! scenario's kernels see the same context width either way) and come back
//! in input order.
//!
//! Beyond forward advancement, [`BatchRunner::run_gradients`] adds the
//! record/backward phases of simulation-coupled training: every scenario
//! records a rollout [`Tape`] (full or checkpointed) and backpropagates a
//! [`BatchLoss`] through it on the same pool, yielding per-scenario
//! [`RolloutGrads`] plus, via [`reduce_shared`], batch-reduced gradients
//! for parameters shared across the batch (ν, source fields, initial
//! states).
//!
//! Every batch is **fault-isolated**: the per-scenario task bodies catch
//! panics (a poisoned Krylov vector tripping the debug non-finite guard, a
//! bad mesh spec) and non-finite blowups (divergent residuals, NaN states)
//! at the task boundary, so one diverging scenario costs exactly its own
//! slot. The `*_checked` entry points ([`BatchRunner::run_checked`],
//! [`BatchRunner::advance_checked`], [`BatchRunner::run_gradients_checked`])
//! surface this as `Result<_, ScenarioError>` per slot in input order; the
//! plain entry points keep the old all-or-nothing contract by panicking on
//! the first failed slot. The sweep layer
//! ([`sweep`](crate::coordinator::sweep)) builds its resumable shard
//! execution on the checked variants.

use crate::adjoint::{GradientPaths, RolloutGrads, Tape, TapeStrategy};
use crate::linsolve::Precision;
use crate::mesh::{gen, Mesh, VectorField};
use crate::par::ExecCtx;
use crate::piso::{PisoConfig, PisoSolver, State, StepStats};
use std::sync::Mutex;
use std::time::Instant;

/// A ready-to-advance simulation: solver + state + (fixed) source field.
pub struct ScenarioRun {
    pub label: String,
    pub solver: PisoSolver,
    pub state: State,
    pub source: VectorField,
}

/// A named, parameterized simulation setup.
pub trait Scenario: Send + Sync {
    /// Registry key of the scenario family (e.g. `"cavity"`).
    fn kind(&self) -> &'static str;
    /// Human-readable label including the distinguishing parameters.
    fn label(&self) -> String;
    /// Construct the mesh, solver, initial state, and source.
    fn build(&self) -> ScenarioRun;
}

/// Divergence-free Taylor–Green vortex velocity on the unit box.
pub fn taylor_green_init(mesh: &Mesh) -> VectorField {
    let tau = 2.0 * std::f64::consts::PI;
    let mut u = VectorField::zeros(mesh.ncells);
    for (i, c) in mesh.centers.iter().enumerate() {
        u.comp[0][i] = (tau * c[0]).sin() * (tau * c[1]).cos();
        u.comp[1][i] = -(tau * c[0]).cos() * (tau * c[1]).sin();
    }
    u
}

/// Decaying Taylor–Green vortex on a periodic 2D box (the quickstart /
/// viscous-decay validation flow).
#[derive(Clone, Debug)]
pub struct TaylorGreen {
    pub n: usize,
    pub nu: f64,
    pub dt: f64,
}

impl Default for TaylorGreen {
    fn default() -> Self {
        TaylorGreen { n: 32, nu: 0.01, dt: 0.01 }
    }
}

impl Scenario for TaylorGreen {
    fn kind(&self) -> &'static str {
        "taylor-green"
    }

    fn label(&self) -> String {
        format!("taylor-green {0}x{0} nu={1}", self.n, self.nu)
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::periodic_box2d(self.n, self.n, 1.0, 1.0);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: self.dt, ..Default::default() },
            self.nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        state.u = taylor_green_init(&solver.mesh);
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// The 2D Gaussian u-velocity bump of the §4.2 gradient-path task
/// (centred at (0.5, 0.5), σ = 0.18).
pub fn gaussian_bump_init(mesh: &Mesh) -> VectorField {
    let mut f = VectorField::zeros(mesh.ncells);
    let (cx, cy, sigma) = (0.5, 0.5, 0.18);
    for (i, c) in mesh.centers.iter().enumerate() {
        let r2 = (c[0] - cx).powi(2) + (c[1] - cy).powi(2);
        f.comp[0][i] = (-r2 / (2.0 * sigma * sigma)).exp();
    }
    f
}

/// Periodic box seeded with the scaled Gaussian bump — the E4 gradient-path
/// ablation flow (paper §4.2, fig. 6 / table 1).
#[derive(Clone, Debug)]
pub struct GaussianBox {
    pub nx: usize,
    pub ny: usize,
    pub nu: f64,
    pub dt: f64,
    /// Scale θ applied to the bump (the recovered parameter; reference 1.0).
    pub theta: f64,
}

impl Default for GaussianBox {
    fn default() -> Self {
        GaussianBox { nx: 18, ny: 16, nu: 0.01, dt: 0.05, theta: 1.0 }
    }
}

impl Scenario for GaussianBox {
    fn kind(&self) -> &'static str {
        "gauss-box"
    }

    fn label(&self) -> String {
        format!("gauss-box {}x{} theta={}", self.nx, self.ny, self.theta)
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::periodic_box2d(self.nx, self.ny, 1.0, 1.0);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: self.dt, ..Default::default() },
            self.nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        state.u = gaussian_bump_init(&solver.mesh);
        state.u.scale(self.theta);
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Lid-driven cavity at a given Reynolds number (paper Fig 3 / B.16).
#[derive(Clone, Debug)]
pub struct LidDrivenCavity {
    pub n: usize,
    pub re: f64,
    pub dt: f64,
    pub refined: bool,
    /// Lid velocity (the C.22 direct-optimization parameter).
    pub lid: f64,
    /// Direct viscosity override; `None` uses `1/re`.
    pub nu: Option<f64>,
}

impl Default for LidDrivenCavity {
    fn default() -> Self {
        LidDrivenCavity { n: 32, re: 100.0, dt: 0.02, refined: false, lid: 1.0, nu: None }
    }
}

impl Scenario for LidDrivenCavity {
    fn kind(&self) -> &'static str {
        "cavity"
    }

    fn label(&self) -> String {
        format!(
            "cavity {0}x{0} Re={1}{2}",
            self.n,
            self.re,
            if self.refined { " refined" } else { "" }
        )
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::cavity2d(self.n, 1.0, self.lid, self.refined);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: self.dt, ..Default::default() },
            self.nu.unwrap_or(1.0 / self.re),
            ExecCtx::from_env(),
        );
        let state = State::zeros(&solver.mesh);
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Plane Poiseuille channel driven by a unit body force at ν = 1 (paper
/// Fig B.15; the steady profile is the analytic `y(1−y)/2`).
#[derive(Clone, Debug)]
pub struct Poiseuille {
    pub nx: usize,
    pub ny: usize,
    pub wall_ratio: f64,
    pub refined: bool,
    pub dt: f64,
}

impl Default for Poiseuille {
    fn default() -> Self {
        Poiseuille { nx: 6, ny: 16, wall_ratio: 1.12, refined: false, dt: 0.05 }
    }
}

impl Scenario for Poiseuille {
    fn kind(&self) -> &'static str {
        "poiseuille"
    }

    fn label(&self) -> String {
        format!(
            "poiseuille {}x{}{}",
            self.nx,
            self.ny,
            if self.refined { " refined" } else { "" }
        )
    }

    fn build(&self) -> ScenarioRun {
        let mesh = gen::channel2d(self.nx, self.ny, 1.0, 1.0, self.wall_ratio, self.refined);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: self.dt, ..Default::default() },
            1.0,
            ExecCtx::from_env(),
        );
        let state = State::zeros(&solver.mesh);
        let mut source = VectorField::zeros(solver.mesh.ncells);
        source.comp[0].iter_mut().for_each(|v| *v = 1.0);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Forced 3D turbulent channel (the §5.3 SGS workload at mini scale).
#[derive(Clone, Debug)]
pub struct TurbulentChannel {
    pub n: [usize; 3],
    pub l: [f64; 3],
    pub nu: f64,
    pub forcing: f64,
    pub dt: f64,
    pub perturbation: f64,
    pub seed: u64,
}

impl Default for TurbulentChannel {
    fn default() -> Self {
        TurbulentChannel {
            n: [12, 12, 6],
            l: [4.0, 2.0, 2.0],
            nu: 0.004,
            forcing: 0.01,
            dt: 0.08,
            perturbation: 0.4,
            seed: 1,
        }
    }
}

impl Scenario for TurbulentChannel {
    fn kind(&self) -> &'static str {
        "channel"
    }

    fn label(&self) -> String {
        format!("channel {}x{}x{} nu={}", self.n[0], self.n[1], self.n[2], self.nu)
    }

    fn build(&self) -> ScenarioRun {
        use super::experiments::tcf_sgs::{forcing_field, perturbed_channel_init};
        let mesh = gen::channel3d(self.n, self.l, 1.08);
        let solver = PisoSolver::new(
            mesh,
            PisoConfig { dt: self.dt, ..Default::default() },
            self.nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        state.u = perturbed_channel_init(&solver.mesh, self.l[1], self.perturbation, self.seed);
        let source = forcing_field(&solver.mesh, self.forcing);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// Vortex street past the square obstacle on the 8-block grid-with-hole
/// (paper §5.1 geometry), with the symmetry-breaking perturbation that
/// triggers shedding onset within a short run.
#[derive(Clone, Debug)]
pub struct VortexStreet {
    pub nx: [usize; 3],
    pub ny: [usize; 3],
    pub re: f64,
    pub dt: f64,
    pub target_cfl: f64,
}

impl Default for VortexStreet {
    fn default() -> Self {
        VortexStreet { nx: [8, 6, 16], ny: [10, 6, 10], re: 500.0, dt: 0.05, target_cfl: 0.8 }
    }
}

impl VortexStreet {
    /// The grid geometry this scenario builds with (single source of truth
    /// for probe placement in examples/diagnostics).
    pub fn geometry(&self) -> gen::VortexStreetCfg {
        gen::VortexStreetCfg { nx: self.nx, ny: self.ny, ..Default::default() }
    }
}

impl Scenario for VortexStreet {
    fn kind(&self) -> &'static str {
        "vortex-street"
    }

    fn label(&self) -> String {
        format!("vortex-street Re={}", self.re)
    }

    fn build(&self) -> ScenarioRun {
        let cfg = self.geometry();
        let mesh = gen::vortex_street(&cfg);
        let nu = cfg.u_in * cfg.obs_h / self.re;
        let solver = PisoSolver::new(
            mesh,
            PisoConfig {
                dt: self.dt,
                target_cfl: Some(self.target_cfl),
                use_ilu: true,
                ..Default::default()
            },
            nu,
            ExecCtx::from_env(),
        );
        let mut state = State::zeros(&solver.mesh);
        for (i, c) in solver.mesh.centers.iter().enumerate() {
            state.u.comp[1][i] = 0.05 * (1.3 * c[0]).sin() * (0.9 * c[1]).cos();
        }
        let source = VectorField::zeros(solver.mesh.ncells);
        ScenarioRun { label: self.label(), solver, state, source }
    }
}

/// All built-in scenarios at their default parameters.
pub fn builtin_scenarios() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(TaylorGreen::default()),
        Box::new(GaussianBox::default()),
        Box::new(LidDrivenCavity::default()),
        Box::new(Poiseuille::default()),
        Box::new(TurbulentChannel::default()),
        Box::new(VortexStreet::default()),
    ]
}

/// Look up a scenario family by its registry key (default parameters).
pub fn scenario_by_kind(kind: &str) -> Option<Box<dyn Scenario>> {
    builtin_scenarios().into_iter().find(|s| s.kind() == kind)
}

/// A cavity Reynolds sweep: one scenario per requested Re.
pub fn cavity_reynolds_sweep(n: usize, res: &[f64]) -> Vec<Box<dyn Scenario>> {
    res.iter()
        .map(|&re| Box::new(LidDrivenCavity { n, re, ..Default::default() }) as Box<dyn Scenario>)
        .collect()
}

/// A turbulent-channel viscosity (Re_τ) sweep: one scenario per requested ν.
pub fn channel_nu_sweep(n: [usize; 3], nus: &[f64]) -> Vec<Box<dyn Scenario>> {
    nus.iter()
        .map(|&nu| Box::new(TurbulentChannel { n, nu, ..Default::default() }) as Box<dyn Scenario>)
        .collect()
}

/// A Taylor–Green viscosity sweep on a fixed grid (same mesh across the
/// batch, so per-scenario gradients reduce into shared-parameter gradients).
pub fn taylor_green_nu_sweep(n: usize, nus: &[f64]) -> Vec<Box<dyn Scenario>> {
    nus.iter()
        .map(|&nu| Box::new(TaylorGreen { n, nu, ..Default::default() }) as Box<dyn Scenario>)
        .collect()
}

/// Why one scenario slot of a batch failed while the other slots completed.
#[derive(Clone, Debug)]
pub enum ScenarioError {
    /// The scenario's build or one of its steps panicked (e.g. the debug
    /// builds' non-finite Krylov guard, or an invalid mesh spec); the
    /// original panic message survives the task boundary.
    Panicked { label: String, message: String },
    /// The solver diverged without panicking: a step produced a non-finite
    /// residual/divergence, or the state/gradients contain non-finite
    /// values. `step` is the step count reached when it was detected.
    NonFinite { label: String, step: usize, what: String },
}

impl ScenarioError {
    /// Label of the scenario that failed.
    pub fn label(&self) -> &str {
        match self {
            ScenarioError::Panicked { label, .. } => label,
            ScenarioError::NonFinite { label, .. } => label,
        }
    }
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::Panicked { label, message } => {
                write!(f, "{label}: panicked: {message}")
            }
            ScenarioError::NonFinite { label, step, what } => {
                write!(f, "{label}: non-finite {what} at step {step}")
            }
        }
    }
}

impl std::error::Error for ScenarioError {}

/// Best-effort text of a caught panic payload (`panic!` with a string
/// literal or format arguments covers every panic in this crate).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// First non-finite entry of a state, named for the error message.
fn state_nonfinite(state: &State) -> Option<String> {
    for (c, comp) in state.u.comp.iter().enumerate() {
        if let Some(i) = comp.iter().position(|v| !v.is_finite()) {
            return Some(format!("state u[{c}][{i}]"));
        }
    }
    if let Some(i) = state.p.iter().position(|v| !v.is_finite()) {
        return Some(format!("state p[{i}]"));
    }
    None
}

/// First non-finite per-step diagnostic, if any.
fn stats_nonfinite(st: &StepStats) -> Option<&'static str> {
    if !st.adv_residual.is_finite() {
        return Some("advection residual");
    }
    if !st.p_residual.is_finite() {
        return Some("pressure residual");
    }
    if !st.max_divergence.is_finite() {
        return Some("divergence");
    }
    None
}

/// First non-finite gradient entry, named for the error message.
fn grads_nonfinite(grads: &RolloutGrads) -> Option<String> {
    if !grads.dnu.is_finite() {
        return Some("dnu".to_string());
    }
    for (c, comp) in grads.du0.comp.iter().enumerate() {
        if comp.iter().any(|v| !v.is_finite()) {
            return Some(format!("du0[{c}]"));
        }
    }
    if grads.dp0.iter().any(|v| !v.is_finite()) {
        return Some("dp0".to_string());
    }
    for (s, f) in grads.dsource.iter().enumerate() {
        if f.comp.iter().any(|comp| comp.iter().any(|v| !v.is_finite())) {
            return Some(format!("dsource[{s}]"));
        }
    }
    None
}

/// Collapse checked per-slot results for the panic-on-failure convenience
/// APIs (the pre-fault-isolation contract).
fn unwrap_batch<T>(results: Vec<Result<T, ScenarioError>>) -> Vec<T> {
    results
        .into_iter()
        .map(|r| match r {
            Ok(v) => v,
            Err(e) => panic!("batch scenario failed: {e}"),
        })
        .collect()
}

/// Outcome of one scenario advanced by the [`BatchRunner`]: final state plus
/// aggregated per-step diagnostics.
pub struct BatchResult {
    pub label: String,
    pub state: State,
    /// Number of steps taken.
    pub steps: usize,
    /// Total Krylov iterations across all steps.
    pub adv_iters: usize,
    pub p_iters: usize,
    /// Worst per-step residuals / divergence over the run.
    pub adv_residual: f64,
    pub p_residual: f64,
    pub max_divergence: f64,
    /// Stats of the final step.
    pub last: StepStats,
    /// Wall-clock seconds spent building + advancing this scenario.
    pub wall_s: f64,
}

/// Advances many independent scenario runs concurrently on one shared
/// worker pool: scenario-level tasks and each scenario's inner kernel
/// chunks draw from the same workers (see module docs).
pub struct BatchRunner {
    pub steps: usize,
    ctx: ExecCtx,
    precision: Precision,
}

impl BatchRunner {
    /// Runner advancing each scenario by `steps` steps on a pool sized by
    /// `PICT_THREADS` (read now, not from a process-wide cache).
    pub fn new(steps: usize) -> BatchRunner {
        BatchRunner { steps, ctx: ExecCtx::from_env(), precision: Precision::F64 }
    }

    /// Use a pool of exactly `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> BatchRunner {
        self.ctx = ExecCtx::with_threads(threads);
        self
    }

    /// Share an existing pool (e.g. with other runners or solvers).
    pub fn with_ctx(mut self, ctx: ExecCtx) -> BatchRunner {
        self.ctx = ctx;
        self
    }

    /// Krylov storage precision for *forward* batches ([`BatchRunner::run`]
    /// / [`BatchRunner::advance`]): `Mixed` overrides every scenario's
    /// solver config so the hot path runs f32-storage iterative refinement.
    /// Gradient batches ([`BatchRunner::run_gradients`]) ignore this — the
    /// training/adjoint path always solves in f64.
    pub fn with_precision(mut self, precision: Precision) -> BatchRunner {
        self.precision = precision;
        self
    }

    /// Width of the pool scenarios (and their kernels) run on.
    pub fn threads(&self) -> usize {
        self.ctx.width()
    }

    /// The runner's execution context (e.g. for embedding the pool in a
    /// training loop that interleaves its own pool tasks).
    pub fn ctx(&self) -> &ExecCtx {
        &self.ctx
    }

    /// Build and advance every scenario; results come back in input order.
    /// Panics on the first failed scenario — the fault-isolating variant is
    /// [`BatchRunner::run_checked`].
    pub fn run(&self, scenarios: &[Box<dyn Scenario>]) -> Vec<BatchResult> {
        unwrap_batch(self.run_checked(scenarios))
    }

    /// Fault-isolated batch: build and advance every scenario, catching
    /// panics and non-finite blowups at each scenario's task boundary. One
    /// divergent run costs exactly its own slot (`Err`); every other slot
    /// completes. Results come back in input order.
    pub fn run_checked(
        &self,
        scenarios: &[Box<dyn Scenario>],
    ) -> Vec<Result<BatchResult, ScenarioError>> {
        self.drive_checked(scenarios.len(), |i| scenarios[i].label(), |i| scenarios[i].build())
    }

    /// Advance pre-built runs (e.g. mid-simulation states). Panics on the
    /// first failed run — see [`BatchRunner::advance_checked`].
    pub fn advance(&self, runs: Vec<ScenarioRun>) -> Vec<BatchResult> {
        unwrap_batch(self.advance_checked(runs))
    }

    /// Fault-isolated [`BatchRunner::advance`]: per-slot results in input
    /// order, failed runs as `Err` without aborting the batch.
    pub fn advance_checked(
        &self,
        runs: Vec<ScenarioRun>,
    ) -> Vec<Result<BatchResult, ScenarioError>> {
        let labels: Vec<String> = runs.iter().map(|r| r.label.clone()).collect();
        let slots: Vec<Mutex<Option<ScenarioRun>>> =
            runs.into_iter().map(|r| Mutex::new(Some(r))).collect();
        self.drive_checked(
            slots.len(),
            |i| labels[i].clone(),
            |i| {
                slots[i]
                    .lock()
                    .expect("slot mutex held once per task index")
                    .take()
                    .expect("each run is taken exactly once, by its own task")
            },
        )
    }

    fn drive_checked<L, F>(
        &self,
        count: usize,
        label: L,
        make: F,
    ) -> Vec<Result<BatchResult, ScenarioError>>
    where
        L: Fn(usize) -> String + Sync,
        F: Fn(usize) -> ScenarioRun + Sync,
    {
        let steps = self.steps;
        let results: Vec<Mutex<Option<Result<BatchResult, ScenarioError>>>> =
            (0..count).map(|_| Mutex::new(None)).collect();
        // one pool job per scenario; each scenario's solver gets a clone of
        // the same context, so its inner kernels submit nested jobs to the
        // very workers that are not busy advancing other scenarios
        self.ctx.run_tasks(count, |i| {
            // the catch_unwind is the fault boundary: a panic in build or
            // step (including one rethrown by a nested kernel job) unwinds
            // to here and is converted into this slot's Err — it never
            // reaches the pool's job-level panic propagation
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || -> Result<BatchResult, ScenarioError> {
                    let t0 = Instant::now();
                    let mut run = make(i);
                    run.solver.ctx = self.ctx.clone();
                    if self.precision.is_mixed() {
                        run.solver.cfg.precision = Precision::Mixed;
                    }
                    let mut adv_iters = 0;
                    let mut p_iters = 0;
                    let mut adv_residual = 0.0f64;
                    let mut p_residual = 0.0f64;
                    let mut max_divergence = 0.0f64;
                    let mut last = StepStats::default();
                    for _ in 0..steps {
                        let st = run.solver.step(&mut run.state, &run.source, None);
                        if let Some(what) = stats_nonfinite(&st) {
                            return Err(ScenarioError::NonFinite {
                                label: run.label,
                                step: run.state.step,
                                what: what.to_string(),
                            });
                        }
                        adv_iters += st.adv_iters;
                        p_iters += st.p_iters;
                        adv_residual = adv_residual.max(st.adv_residual);
                        p_residual = p_residual.max(st.p_residual);
                        max_divergence = max_divergence.max(st.max_divergence);
                        last = st;
                    }
                    // residuals can stay finite while the state drifts to
                    // NaN on the very last step; scan it before declaring
                    // the slot healthy
                    if let Some(what) = state_nonfinite(&run.state) {
                        return Err(ScenarioError::NonFinite {
                            label: run.label,
                            step: run.state.step,
                            what,
                        });
                    }
                    Ok(BatchResult {
                        label: run.label,
                        state: run.state,
                        steps,
                        adv_iters,
                        p_iters,
                        adv_residual,
                        p_residual,
                        max_divergence,
                        last,
                        wall_s: t0.elapsed().as_secs_f64(),
                    })
                },
            ));
            let res = match outcome {
                Ok(r) => r,
                Err(payload) => Err(ScenarioError::Panicked {
                    label: label(i),
                    message: panic_message(payload),
                }),
            };
            *results[i].lock().expect("slot mutex held once per task index") = Some(res);
        });
        results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("slot mutex unpoisoned: task bodies catch their own panics")
                    .expect("batch worker skipped a run")
            })
            .collect()
    }
}

/// Per-step loss for a gradient batch: scalar contribution + cotangent of
/// scenario `i`'s state after step `step` (return zeros for steps without
/// loss). Implementations must be `Sync` — one instance serves the whole
/// batch concurrently.
pub trait BatchLoss: Sync {
    fn loss(&self, scenario: usize, step: usize, state: &State) -> f64;
    fn grad(&self, scenario: usize, step: usize, state: &State) -> (VectorField, Vec<f64>);
}

/// L_i = Σ_cells |u|² on the final state — a probe loss every scenario
/// supports without reference data (used by `pict train --probe` and the
/// gradient smoke paths).
pub struct TerminalKineticEnergy {
    /// Index of the last step of the rollout (`steps - 1`).
    pub final_step: usize,
}

impl BatchLoss for TerminalKineticEnergy {
    fn loss(&self, _scenario: usize, step: usize, state: &State) -> f64 {
        if step != self.final_step {
            return 0.0;
        }
        state.u.comp.iter().map(|c| c.iter().map(|v| v * v).sum::<f64>()).sum()
    }

    fn grad(&self, _scenario: usize, step: usize, state: &State) -> (VectorField, Vec<f64>) {
        let ncells = state.u.ncells();
        let mut du = VectorField::zeros(ncells);
        if step == self.final_step {
            for c in 0..3 {
                for i in 0..ncells {
                    du.comp[c][i] = 2.0 * state.u.comp[c][i];
                }
            }
        }
        (du, vec![0.0; state.p.len()])
    }
}

/// L_i = Σ_cells |u − target_i|² on the final state (per-scenario targets).
pub struct TerminalMse {
    pub final_step: usize,
    /// One reference velocity field per scenario in the batch.
    pub targets: Vec<VectorField>,
}

impl BatchLoss for TerminalMse {
    fn loss(&self, scenario: usize, step: usize, state: &State) -> f64 {
        if step != self.final_step {
            return 0.0;
        }
        let t = &self.targets[scenario];
        state
            .u
            .comp
            .iter()
            .zip(&t.comp)
            .map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>())
            .sum()
    }

    fn grad(&self, scenario: usize, step: usize, state: &State) -> (VectorField, Vec<f64>) {
        let ncells = state.u.ncells();
        let mut du = VectorField::zeros(ncells);
        if step == self.final_step {
            let t = &self.targets[scenario];
            for c in 0..3 {
                for i in 0..ncells {
                    du.comp[c][i] = 2.0 * (state.u.comp[c][i] - t.comp[c][i]);
                }
            }
        }
        (du, vec![0.0; state.p.len()])
    }
}

/// Outcome of one scenario's record+backward pass in a gradient batch.
pub struct GradBatchResult {
    pub label: String,
    /// Final forward state (after all recorded steps).
    pub state: State,
    /// Scalar loss accumulated by the [`BatchLoss`] over the rollout.
    pub loss: f64,
    pub grads: RolloutGrads,
    /// Fingerprint of the scenario's mesh geometry (cell count, dimension,
    /// cell centers) — [`reduce_shared`] only sums field gradients across
    /// scenarios whose fingerprints match.
    pub mesh_fp: u64,
    /// Peak resident f64 count of this scenario's backward sweep.
    pub peak_resident_f64: usize,
    /// Wall-clock seconds for build + record + backward.
    pub wall_s: f64,
}

/// FNV-1a over the mesh geometry (cell count, dimension, center bits):
/// scenarios on byte-identical geometry — the precondition for treating
/// per-cell gradients as gradients of one shared field, and the cache key
/// for per-mesh conv-table sets in mixed-mesh training batches
/// ([`train_corrector_batch`](super::engine::train_corrector_batch)).
pub fn mesh_fingerprint(mesh: &Mesh) -> u64 {
    const P: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    h = (h ^ mesh.ncells as u64).wrapping_mul(P);
    h = (h ^ mesh.dim as u64).wrapping_mul(P);
    for c in &mesh.centers {
        for x in c {
            h = (h ^ x.to_bits()).wrapping_mul(P);
        }
    }
    h
}

/// Batch-reduced gradients for parameters shared across scenarios.
pub struct SharedGrads {
    /// Σ_i ∂L/∂ν — viscosity as a shared physical parameter.
    pub dnu: f64,
    /// Σ_i ∂L/∂S_t per step when every scenario ran on the same mesh
    /// geometry and rollout length (a shared source/corrector signal);
    /// `None` for mixed-mesh or mixed-length batches.
    pub dsource: Option<Vec<VectorField>>,
    /// Σ_i ∂L/∂u⁰ under the same mesh condition.
    pub du0: Option<VectorField>,
}

/// Reduce per-scenario rollout gradients into shared-parameter gradients.
pub fn reduce_shared(results: &[GradBatchResult]) -> SharedGrads {
    let refs: Vec<&GradBatchResult> = results.iter().collect();
    reduce_shared_refs(&refs)
}

/// [`reduce_shared`] over borrowed results — the sweep merge reduces
/// gradients it holds inside per-slot enums without cloning whole states.
/// The accumulation order is identical to the owned variant (input order,
/// left fold), so both produce bit-identical sums.
pub fn reduce_shared_refs(results: &[&GradBatchResult]) -> SharedGrads {
    let dnu = results.iter().map(|r| r.grads.dnu).sum();
    // field gradients only reduce across byte-identical mesh geometry
    // (equal cell counts are not enough: a box and a cavity of the same
    // size would sum gradients of physically incompatible fields)
    let same_mesh = !results.is_empty()
        && results.windows(2).all(|w| {
            w[0].mesh_fp == w[1].mesh_fp
                && w[0].grads.dsource.len() == w[1].grads.dsource.len()
        });
    if !same_mesh {
        return SharedGrads { dnu, dsource: None, du0: None };
    }
    let mut du0 = results[0].grads.du0.clone();
    let mut dsource = results[0].grads.dsource.clone();
    for r in &results[1..] {
        du0.axpy(1.0, &r.grads.du0);
        for (a, b) in dsource.iter_mut().zip(&r.grads.dsource) {
            a.axpy(1.0, b);
        }
    }
    SharedGrads { dnu, dsource: Some(dsource), du0: Some(du0) }
}

impl BatchRunner {
    /// The record/backward phases of a training step: build every scenario,
    /// record a rollout [`Tape`] under `strategy` (each scenario advancing
    /// with its own source field), and backpropagate `loss` through each
    /// tape — all scenarios concurrently on the shared pool, results in
    /// input order. Combine with [`reduce_shared`] for batch gradients of
    /// shared parameters.
    pub fn run_gradients(
        &self,
        scenarios: &[Box<dyn Scenario>],
        strategy: TapeStrategy,
        paths: GradientPaths,
        loss: &dyn BatchLoss,
    ) -> Vec<GradBatchResult> {
        unwrap_batch(self.run_gradients_checked(scenarios, strategy, paths, loss))
    }

    /// Fault-isolated [`BatchRunner::run_gradients`]: panics and non-finite
    /// losses/states/gradients are caught at each scenario's task boundary,
    /// so a diverging rollout or a poisoned adjoint costs its own slot
    /// (`Err`) while every other scenario's gradients come back intact.
    pub fn run_gradients_checked(
        &self,
        scenarios: &[Box<dyn Scenario>],
        strategy: TapeStrategy,
        paths: GradientPaths,
        loss: &dyn BatchLoss,
    ) -> Vec<Result<GradBatchResult, ScenarioError>> {
        let steps = self.steps;
        let results: Vec<Mutex<Option<Result<GradBatchResult, ScenarioError>>>> =
            (0..scenarios.len()).map(|_| Mutex::new(None)).collect();
        self.ctx.run_tasks(scenarios.len(), |i| {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || -> Result<GradBatchResult, ScenarioError> {
                    let t0 = Instant::now();
                    let ScenarioRun { label, mut solver, mut state, source } =
                        scenarios[i].build();
                    solver.ctx = self.ctx.clone();
                    let mesh_fp = mesh_fingerprint(&solver.mesh);
                    // record phase
                    let tape = Tape::record(&mut solver, &mut state, steps, strategy, |_, _| {
                        source.clone()
                    });
                    // backward phase
                    let mut total = 0.0;
                    let (grads, stats) = tape.backward_with_stats(
                        &mut solver,
                        paths,
                        |_, _| source.clone(),
                        |step, st| {
                            total += loss.loss(i, step, st);
                            loss.grad(i, step, st)
                        },
                    );
                    if !total.is_finite() {
                        return Err(ScenarioError::NonFinite {
                            label,
                            step: steps,
                            what: "loss".to_string(),
                        });
                    }
                    if let Some(what) = state_nonfinite(&state) {
                        return Err(ScenarioError::NonFinite { label, step: steps, what });
                    }
                    if let Some(what) = grads_nonfinite(&grads) {
                        return Err(ScenarioError::NonFinite { label, step: steps, what });
                    }
                    Ok(GradBatchResult {
                        label,
                        state,
                        loss: total,
                        grads,
                        mesh_fp,
                        peak_resident_f64: stats.peak_resident_f64,
                        wall_s: t0.elapsed().as_secs_f64(),
                    })
                },
            ));
            let res = match outcome {
                Ok(r) => r,
                Err(payload) => Err(ScenarioError::Panicked {
                    label: scenarios[i].label(),
                    message: panic_message(payload),
                }),
            };
            *results[i].lock().expect("slot mutex held once per task index") = Some(res);
        });
        results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("slot mutex unpoisoned: task bodies catch their own panics")
                    .expect("gradient batch skipped a scenario")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_distinct_kinds() {
        let all = builtin_scenarios();
        assert!(all.len() >= 4);
        let mut kinds: Vec<&str> = all.iter().map(|s| s.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), all.len(), "duplicate scenario kinds");
        assert!(scenario_by_kind("cavity").is_some());
        assert!(scenario_by_kind("no-such-flow").is_none());
    }

    #[test]
    fn cavity_sweep_builds_one_per_re() {
        let sweep = cavity_reynolds_sweep(8, &[50.0, 100.0, 200.0]);
        assert_eq!(sweep.len(), 3);
        assert!(sweep.iter().all(|s| s.kind() == "cavity"));
        let labels: Vec<String> = sweep.iter().map(|s| s.label()).collect();
        assert!(labels[0] != labels[1]);
    }

    #[test]
    fn batch_runner_advances_small_scenarios() {
        let scenarios: Vec<Box<dyn Scenario>> = vec![
            Box::new(TaylorGreen { n: 8, ..Default::default() }),
            Box::new(LidDrivenCavity { n: 8, ..Default::default() }),
            Box::new(Poiseuille { nx: 4, ny: 8, ..Default::default() }),
        ];
        let results = BatchRunner::new(2).with_threads(3).run(&scenarios);
        assert_eq!(results.len(), 3);
        for (r, s) in results.iter().zip(&scenarios) {
            assert_eq!(r.label, s.label());
            assert_eq!(r.state.step, 2);
            assert!(r.state.time > 0.0);
            assert!(r.p_iters > 0);
        }
    }

    /// Scenario whose build panics — the "bad config" failure mode.
    struct PanicOnBuild;

    impl Scenario for PanicOnBuild {
        fn kind(&self) -> &'static str {
            "panic-on-build"
        }
        fn label(&self) -> String {
            "panic-on-build".to_string()
        }
        fn build(&self) -> ScenarioRun {
            panic!("injected build failure")
        }
    }

    /// Taylor–Green with a NaN seeded into the initial velocity: the first
    /// step either trips the debug non-finite Krylov guard (a panic) or
    /// surfaces non-finite residuals/state (release builds). Either way the
    /// slot must come back `Err`.
    struct NanSeed;

    impl Scenario for NanSeed {
        fn kind(&self) -> &'static str {
            "nan-seed"
        }
        fn label(&self) -> String {
            "nan-seed".to_string()
        }
        fn build(&self) -> ScenarioRun {
            let mut run = TaylorGreen { n: 8, ..Default::default() }.build();
            run.state.u.comp[0][3] = f64::NAN;
            run.label = self.label();
            run
        }
    }

    #[test]
    fn failing_scenarios_cost_only_their_slot() {
        let scenarios: Vec<Box<dyn Scenario>> = vec![
            Box::new(TaylorGreen { n: 8, ..Default::default() }),
            Box::new(PanicOnBuild),
            Box::new(NanSeed),
            Box::new(LidDrivenCavity { n: 8, ..Default::default() }),
        ];
        let results = BatchRunner::new(2).with_threads(4).run_checked(&scenarios);
        assert_eq!(results.len(), 4);
        let healthy = results[0].as_ref().expect("healthy leading slot completes");
        assert_eq!(healthy.state.step, 2);
        match &results[1] {
            Err(ScenarioError::Panicked { label, message }) => {
                assert_eq!(label, "panic-on-build");
                assert!(message.contains("injected build failure"), "{message}");
            }
            Err(e) => panic!("slot 1: wrong error kind: {e}"),
            Ok(_) => panic!("slot 1 must fail"),
        }
        match &results[2] {
            Err(e) => assert_eq!(e.label(), "nan-seed"),
            Ok(_) => panic!("NaN-seeded scenario must fail its slot"),
        }
        let trailing = results[3].as_ref().expect("healthy trailing slot completes");
        assert_eq!(trailing.state.step, 2);
        assert_eq!(trailing.label, scenarios[3].label());
    }

    #[test]
    fn unchecked_run_panics_on_failed_slot_with_context() {
        let scenarios: Vec<Box<dyn Scenario>> = vec![Box::new(PanicOnBuild)];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            BatchRunner::new(1).with_threads(1).run(&scenarios);
        }));
        let msg = panic_message(result.expect_err("run() keeps the all-or-nothing contract"));
        assert!(msg.contains("batch scenario failed"), "{msg}");
        assert!(msg.contains("injected build failure"), "{msg}");
    }

    #[test]
    fn gradient_batch_isolates_a_failing_scenario() {
        let scenarios: Vec<Box<dyn Scenario>> = vec![
            Box::new(TaylorGreen { n: 6, nu: 0.02, ..Default::default() }),
            Box::new(NanSeed),
        ];
        let steps = 2;
        let loss = TerminalKineticEnergy { final_step: steps - 1 };
        let results = BatchRunner::new(steps).with_threads(2).run_gradients_checked(
            &scenarios,
            TapeStrategy::Full,
            GradientPaths::NONE,
            &loss,
        );
        assert_eq!(results.len(), 2);
        let ok = results[0].as_ref().expect("healthy scenario keeps its gradients");
        assert!(ok.loss.is_finite());
        assert!(results[1].is_err(), "NaN-seeded gradient slot must fail alone");
    }

    #[test]
    fn advance_resumes_prebuilt_runs() {
        let runs: Vec<ScenarioRun> =
            vec![TaylorGreen { n: 8, ..Default::default() }.build()];
        let runner = BatchRunner::new(1);
        let first = runner.advance(runs);
        assert_eq!(first[0].state.step, 1);
    }

    #[test]
    fn gradient_batch_produces_grads_per_scenario() {
        let scenarios = taylor_green_nu_sweep(6, &[0.02, 0.05]);
        let steps = 3;
        let runner = BatchRunner::new(steps).with_threads(2);
        let loss = TerminalKineticEnergy { final_step: steps - 1 };
        let results = runner.run_gradients(
            &scenarios,
            TapeStrategy::Checkpoint { every: 2 },
            GradientPaths::NONE,
            &loss,
        );
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.state.step, steps);
            assert!(r.loss > 0.0);
            assert_eq!(r.grads.dsource.len(), steps);
            let n: f64 = r.grads.du0.comp[0].iter().map(|v| v.abs()).sum();
            assert!(n.is_finite() && n > 0.0, "{}: no du0 gradient", r.label);
        }
        let shared = reduce_shared(&results);
        assert!(shared.dnu.is_finite());
        let ds = shared.dsource.expect("same-mesh batch reduces sources");
        assert_eq!(ds.len(), steps);
        // reduction really is the sum of the per-scenario fields
        let want = results[0].grads.dsource[0].comp[0][1] + results[1].grads.dsource[0].comp[0][1];
        assert_eq!(ds[0].comp[0][1], want);

        // TerminalMse with zero-field targets is the kinetic-energy loss:
        // identical loss values and cotangents, bit-for-bit
        let ncells = results[0].state.u.ncells();
        let mse = TerminalMse {
            final_step: steps - 1,
            targets: vec![VectorField::zeros(ncells), VectorField::zeros(ncells)],
        };
        let mse_results = runner.run_gradients(
            &scenarios,
            TapeStrategy::Checkpoint { every: 2 },
            GradientPaths::NONE,
            &mse,
        );
        for (a, b) in results.iter().zip(&mse_results) {
            assert_eq!(a.loss, b.loss, "{}: MSE-vs-zero must equal KE", a.label);
            assert_eq!(a.grads.du0, b.grads.du0);
        }
    }
}
