//! The experiment coordinator: reference data, experiment drivers for every
//! table/figure in the paper's evaluation (see DESIGN.md §4), the scenario
//! registry + batched multi-scenario runner ([`scenario`]), and the
//! reporting layer shared by the CLI and the bench harness.

pub mod engine;
pub mod experiments;
pub mod references;
pub mod scenario;
pub mod sweep;

pub use engine::{train_corrector_batch, BatchTrainResult};
pub use experiments::*;
pub use scenario::{
    builtin_scenarios, reduce_shared, reduce_shared_refs, scenario_by_kind, BatchLoss,
    BatchResult, BatchRunner, GradBatchResult, Scenario, ScenarioError, SharedGrads,
};
pub use sweep::{MergedSweep, ShardOutcome, ShardReport, ShardStatus, SweepEntry, SweepSpec};
