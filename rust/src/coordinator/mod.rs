//! The experiment coordinator: reference data, experiment drivers for every
//! table/figure in the paper's evaluation (see DESIGN.md §4), and the
//! reporting layer shared by the CLI and the bench harness.

pub mod experiments;
pub mod references;

pub use experiments::*;
