//! Scenario-driven training engine: one shared corrector network trained
//! across a *batch* of registered scenarios per optimizer step, with
//! checkpointed unrolled-episode tapes.
//!
//! This generalizes the single-flow corrector training of
//! [`experiments::corrector2d`](super::experiments::corrector2d): each
//! optimizer step runs one unrolled episode per scenario concurrently on the
//! [`BatchRunner`]'s pool, sums the per-scenario parameter gradients
//! (scenarios share the network), and takes one Adam step. Episode memory
//! follows the episode's [`TapeStrategy`](crate::adjoint::TapeStrategy):
//! under `Checkpoint { every }` the
//! forward pass stores only every `every`-th state and the backward sweep
//! rematerializes each segment — solver [`StepRecord`]s *and* CNN
//! activation tapes — by re-stepping from the nearest checkpoint, so a
//! length-n episode holds O(n/k + k) instead of O(n) full-field tapes while
//! producing bit-for-bit the gradients of the eager episode (forward
//! stepping and the network are deterministic).

use crate::adjoint::backward_step;
use crate::mesh::{BcValues, VectorField};
use crate::nn::Cnn;
use crate::piso::{PisoSolver, State, StepRecord};
use crate::train::{mse_loss_grad, Adam, Optimizer};
use crate::util::rng::Rng;
use std::sync::Mutex;

use super::experiments::corrector2d::{corrector_net, net_input, Corrector2dCfg};
use super::scenario::{BatchRunner, Scenario, ScenarioRun};

/// Outcome of a batched corrector training run.
pub struct BatchTrainResult {
    pub net: Cnn,
    /// Batch-mean episode loss per optimizer step.
    pub losses: Vec<f64>,
}

/// One unrolled training episode against coarse-aligned reference frames,
/// with tape memory governed by `cfg.strategy`: forward from
/// `frames[start]`, per-step MSE loss vs `frames[start + t + 1]`, backward
/// through solver and network. Returns `(mean loss, ∂L/∂params)`.
pub fn episode(
    solver: &mut PisoSolver,
    net: &Cnn,
    base_source: &VectorField,
    frames: &[VectorField],
    start: usize,
    unroll: usize,
    cfg: &Corrector2dCfg,
) -> (f64, Vec<f64>) {
    assert!(unroll >= 1, "episode: unroll must be at least 1 step");
    assert!(
        start + unroll < frames.len(),
        "episode: start {start} + unroll {unroll} needs {} frames, have {}",
        start + unroll + 1,
        frames.len()
    );
    let ncells = solver.mesh.ncells;
    let every = cfg.strategy.segment(unroll);

    let mut state = State::zeros(&solver.mesh);
    state.u = frames[start].clone();

    // skeleton forward: store only the checkpoint states (+ boundary
    // values, which the advective-outflow update advances). With a single
    // segment (Full, or every >= unroll) the backward's rematerialization
    // IS the forward, so no skeleton pass is needed at all.
    //
    // NOTE: this mirrors adjoint::Tape's Checkpoint backward (which cannot
    // be reused directly: the sweep here must also rematerialize CNN
    // activation tapes and add the network-input path to the state
    // cotangent); keep the bc snapshot/restore order in sync with tape.rs.
    let mut checkpoints: Vec<(usize, State, Vec<BcValues>)> =
        vec![(0, state.clone(), solver.mesh.bc_values.clone())];
    if every < unroll {
        for t in 0..unroll {
            if t % every == 0 && t > 0 {
                checkpoints.push((t, state.clone(), solver.mesh.bc_values.clone()));
            }
            let src = source_for(solver, net, base_source, &state, cfg);
            solver.step(&mut state, &src, None);
        }
    }
    // with a skeleton pass the solver's boundary values have advanced to
    // their end-of-episode state; each segment's backward_steps must see
    // them there (like the eager episode's did), not mid-trajectory
    let final_bc =
        if every < unroll { Some(solver.mesh.bc_values.clone()) } else { None };

    // backward: segments last-to-first; rematerialize records + CNN tapes
    // per segment, then sweep it in reverse.
    let mut total_loss = 0.0;
    let mut dparams = vec![0.0; net.nparams()];
    let mut du = VectorField::zeros(ncells);
    let mut dp = vec![0.0; ncells];
    for ci in (0..checkpoints.len()).rev() {
        let (seg_start, cp_state, cp_bc) = &checkpoints[ci];
        let seg_start = *seg_start;
        let seg_end =
            checkpoints.get(ci + 1).map(|c| c.0).unwrap_or(unroll);
        solver.mesh.bc_values = cp_bc.clone();
        let mut st = cp_state.clone();
        let seg = seg_end - seg_start;
        let mut recs = Vec::with_capacity(seg);
        let mut inputs = Vec::with_capacity(seg);
        let mut tapes = Vec::with_capacity(seg);
        let mut sources = Vec::with_capacity(seg);
        let mut states_after = Vec::with_capacity(seg);
        for _t in seg_start..seg_end {
            let input = net_input(&st.u);
            let (out, tape) = net.forward(&input);
            let mut s_theta = VectorField::zeros(ncells);
            let mut src = base_source.clone();
            for c in 0..2 {
                for i in 0..ncells {
                    let v = cfg.output_scale * out[c][i];
                    s_theta.comp[c][i] = v;
                    src.comp[c][i] += v;
                }
            }
            let mut rec = StepRecord::empty();
            solver.step(&mut st, &src, Some(&mut rec));
            recs.push(rec);
            inputs.push(input);
            tapes.push(tape);
            sources.push(s_theta);
            states_after.push(st.clone());
        }
        if let Some(fb) = &final_bc {
            solver.mesh.bc_values = fb.clone();
        }
        for (i, t) in (seg_start..seg_end).enumerate().rev() {
            let (l, mut cot) = mse_loss_grad(2, &states_after[i].u, &frames[start + t + 1]);
            total_loss += l;
            cot.axpy(1.0, &du);
            let g = backward_step(solver, &recs[i], &cot, &dp, cfg.paths);
            // source gradient → CNN (with optional divergence modification)
            let ds = if cfg.lambda_div > 0.0 {
                crate::train::div_gradient_modification(
                    &solver.ctx,
                    &solver.mesh,
                    &sources[i],
                    &g.dsource,
                    cfg.lambda_div,
                )
            } else {
                g.dsource.clone()
            };
            let dout: Vec<Vec<f64>> = (0..2)
                .map(|c| ds.comp[c].iter().map(|v| cfg.output_scale * v).collect())
                .collect();
            let (dpar, dins) = net.backward(&inputs[i], &tapes[i], &dout);
            for (a, b) in dparams.iter_mut().zip(&dpar) {
                *a += b;
            }
            // state gradient: solver path + network-input path
            du = g.du_n;
            for c in 0..2 {
                for cell in 0..ncells {
                    du.comp[c][cell] += dins[c][cell];
                }
            }
            dp = g.dp_in;
        }
    }
    (total_loss / unroll as f64, dparams)
}

/// The corrector source for one step: base forcing + scaled network output
/// (activation tape discarded — used by the skeleton forward and
/// evaluation, where no backward follows).
fn source_for(
    solver: &PisoSolver,
    net: &Cnn,
    base_source: &VectorField,
    state: &State,
    cfg: &Corrector2dCfg,
) -> VectorField {
    let ncells = solver.mesh.ncells;
    let (out, _) = net.forward(&net_input(&state.u));
    let mut src = base_source.clone();
    for c in 0..2 {
        for i in 0..ncells {
            src.comp[c][i] += cfg.output_scale * out[c][i];
        }
    }
    src
}

/// Train one shared corrector across a scenario batch: per optimizer step,
/// one episode per scenario runs concurrently on the runner's pool (each
/// scenario against its own reference frames), the parameter gradients are
/// summed, and a single Adam step updates the shared network. All
/// scenarios must share the coarse mesh (the network's conv tables are
/// built on it); pair with
/// [`cavity_reynolds_sweep`](super::scenario::cavity_reynolds_sweep)-style
/// sweeps. Results are independent of the pool width (episodes only read
/// shared state; the reduction is in scenario order).
pub fn train_corrector_batch(
    runner: &BatchRunner,
    scenarios: &[Box<dyn Scenario>],
    frames: &[Vec<VectorField>],
    cfg: &Corrector2dCfg,
) -> BatchTrainResult {
    assert_eq!(
        scenarios.len(),
        frames.len(),
        "one reference-frame sequence per scenario"
    );
    assert!(!scenarios.is_empty(), "empty scenario batch");
    let ctx = runner.ctx();
    let runs: Vec<Mutex<ScenarioRun>> = scenarios
        .iter()
        .map(|s| {
            let mut r = s.build();
            r.solver.ctx = ctx.clone();
            Mutex::new(r)
        })
        .collect();
    {
        // the shared network's conv tables are built on scenario 0's mesh:
        // every scenario must provide the *same* mesh geometry, not merely
        // the same cell count (a periodic box and a cavity of equal size
        // would silently convolve with the wrong neighbor tables)
        let first = runs[0].lock().expect("run mutex unpoisoned: pool rethrows worker panics");
        for r in &runs[1..] {
            let other = r.lock().expect("run mutex unpoisoned: pool rethrows worker panics");
            assert!(
                other.solver.mesh.ncells == first.solver.mesh.ncells
                    && other.solver.mesh.dim == first.solver.mesh.dim
                    && other.solver.mesh.centers == first.solver.mesh.centers,
                "batched scenarios must share the coarse mesh ({} vs {})",
                other.label,
                first.label
            );
        }
    }

    let mut net = corrector_net(
        &runs[0].lock().expect("run mutex unpoisoned: pool rethrows worker panics").solver.mesh,
        cfg.seed,
    );
    let mut opt = Adam::new(cfg.lr, net.nparams());
    let mut rng = Rng::new(cfg.seed ^ 0x55);
    let mut losses = Vec::new();
    let nscen = scenarios.len();
    for &unroll in &cfg.curriculum {
        for _ in 0..cfg.opt_steps_per_stage {
            // per-scenario episode starts (drawn serially: deterministic
            // regardless of pool width)
            let starts: Vec<usize> = (0..nscen)
                .map(|i| rng.below(frames[i].len().saturating_sub(unroll + 1)))
                .collect();
            let slots: Vec<Mutex<Option<(f64, Vec<f64>)>>> =
                (0..nscen).map(|_| Mutex::new(None)).collect();
            {
                let net_ref = &net;
                let cfg_ref = cfg;
                let frames_ref = frames;
                let starts_ref = &starts;
                ctx.run_tasks(nscen, |i| {
                    let mut run =
                        runs[i].lock().expect("run mutex held once per task index");
                    let ScenarioRun { ref mut solver, ref source, .. } = *run;
                    let got = episode(
                        solver,
                        net_ref,
                        source,
                        &frames_ref[i],
                        starts_ref[i],
                        unroll,
                        cfg_ref,
                    );
                    *slots[i].lock().expect("slot mutex held once per task index") = Some(got);
                });
            }
            // reduce in scenario order (deterministic sum)
            let mut batch_loss = 0.0;
            let mut dparams = vec![0.0; net.nparams()];
            for slot in &slots {
                let (l, dp) = slot
                    .lock()
                    .expect("slot mutex unpoisoned: pool rethrows worker panics")
                    .take()
                    .expect("every episode task fills its slot before the batch reduce");
                batch_loss += l;
                for (a, b) in dparams.iter_mut().zip(&dp) {
                    *a += b;
                }
            }
            let mut params = std::mem::take(&mut net.params);
            opt.step(&mut params, &dparams);
            net.params = params;
            losses.push(batch_loss / nscen as f64);
        }
    }
    BatchTrainResult { net, losses }
}

/// Generate coarse-aligned reference frames for every fine scenario of a
/// batch, concurrently on the runner's pool: each fine scenario is built
/// from the registry, warmed up, and resampled onto `coarse_mesh` every
/// `t_ratio` steps (see
/// [`make_reference_frames`](super::experiments::corrector2d::make_reference_frames)).
pub fn scenario_reference_frames(
    runner: &BatchRunner,
    fine: &[Box<dyn Scenario>],
    coarse_mesh: &crate::mesh::Mesh,
    cfg: &Corrector2dCfg,
) -> Vec<Vec<VectorField>> {
    use super::experiments::corrector2d::make_reference_frames;
    let ctx = runner.ctx();
    let slots: Vec<Mutex<Option<Vec<VectorField>>>> =
        (0..fine.len()).map(|_| Mutex::new(None)).collect();
    ctx.run_tasks(fine.len(), |i| {
        let mut run = fine[i].build();
        run.solver.ctx = ctx.clone();
        let frames = make_reference_frames(&mut run.solver, &mut run.state, coarse_mesh, cfg);
        *slots[i].lock().expect("slot mutex held once per task index") = Some(frames);
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("slot mutex unpoisoned: pool rethrows worker panics")
                .expect("frame generation skipped a scenario")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::{GradientPaths, TapeStrategy};
    use crate::coordinator::scenario::TaylorGreen;

    fn tiny_cfg(strategy: TapeStrategy) -> Corrector2dCfg {
        Corrector2dCfg {
            t_ratio: 1,
            n_frames: 8,
            fine_warmup: 2,
            curriculum: vec![3],
            opt_steps_per_stage: 2,
            lr: 1e-3,
            paths: GradientPaths::NONE,
            lambda_div: 0.0,
            output_scale: 0.05,
            strategy,
            seed: 0xE2E,
        }
    }

    /// Checkpointed episodes must reproduce the eager episode's loss and
    /// parameter gradients exactly (re-stepping is deterministic).
    #[test]
    fn checkpointed_episode_matches_full_bit_for_bit() {
        let scen = TaylorGreen { n: 8, nu: 0.02, dt: 0.02 };
        let cfg_full = tiny_cfg(TapeStrategy::Full);
        let cfg_chk = tiny_cfg(TapeStrategy::Checkpoint { every: 2 });
        // reference frames: a short rollout of the same flow
        let mut run = scen.build();
        let mut frames = vec![run.state.u.clone()];
        for _ in 0..6 {
            let src = run.source.clone();
            run.solver.step(&mut run.state, &src, None);
            frames.push(run.state.u.clone());
        }
        let net = corrector_net(&run.solver.mesh, 7);
        let mut s1 = scen.build();
        let (l_full, g_full) =
            episode(&mut s1.solver, &net, &s1.source, &frames, 0, 5, &cfg_full);
        let mut s2 = scen.build();
        let (l_chk, g_chk) =
            episode(&mut s2.solver, &net, &s2.source, &frames, 0, 5, &cfg_chk);
        assert_eq!(l_full, l_chk);
        assert_eq!(g_full, g_chk);
    }

    /// The same equality on an outflow mesh: the episode's bc
    /// snapshot/restore copy (see the sync note in `episode`) must keep
    /// matching `adjoint::Tape`'s on the one mesh class it exists for.
    #[test]
    fn checkpointed_episode_matches_full_with_outflow_bcs() {
        use crate::coordinator::scenario::VortexStreet;
        let scen = VortexStreet {
            nx: [4, 3, 6],
            ny: [4, 3, 4],
            re: 200.0,
            dt: 0.05,
            target_cfl: 0.8,
        };
        let mut run = scen.build();
        let mut frames = vec![run.state.u.clone()];
        for _ in 0..5 {
            let src = run.source.clone();
            run.solver.step(&mut run.state, &src, None);
            frames.push(run.state.u.clone());
        }
        let net = corrector_net(&run.solver.mesh, 11);
        let mut s1 = scen.build();
        let (l_full, g_full) = episode(
            &mut s1.solver,
            &net,
            &s1.source,
            &frames,
            0,
            4,
            &tiny_cfg(TapeStrategy::Full),
        );
        let mut s2 = scen.build();
        let (l_chk, g_chk) = episode(
            &mut s2.solver,
            &net,
            &s2.source,
            &frames,
            0,
            4,
            &tiny_cfg(TapeStrategy::Checkpoint { every: 2 }),
        );
        assert_eq!(l_full, l_chk);
        assert_eq!(g_full, g_chk);
    }

    /// A 1-scenario batch equals two optimizer steps of plain episodes, and
    /// batch training across 2 scenarios runs and returns finite losses.
    #[test]
    fn batch_training_runs_across_two_scenarios() {
        let scens: Vec<Box<dyn Scenario>> = vec![
            Box::new(TaylorGreen { n: 8, nu: 0.02, dt: 0.02 }),
            Box::new(TaylorGreen { n: 8, nu: 0.05, dt: 0.02 }),
        ];
        let frames: Vec<Vec<VectorField>> = scens
            .iter()
            .map(|s| {
                let mut run = s.build();
                let mut fs = vec![run.state.u.clone()];
                for _ in 0..6 {
                    let src = run.source.clone();
                    run.solver.step(&mut run.state, &src, None);
                    fs.push(run.state.u.clone());
                }
                fs
            })
            .collect();
        let cfg = tiny_cfg(TapeStrategy::Checkpoint { every: 2 });
        let runner = BatchRunner::new(0).with_threads(2);
        let result = train_corrector_batch(&runner, &scens, &frames, &cfg);
        assert_eq!(result.losses.len(), 2);
        assert!(result.losses.iter().all(|l| l.is_finite()));
    }
}
