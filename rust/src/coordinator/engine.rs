//! Scenario-driven training engine: one shared corrector network trained
//! across a *batch* of registered scenarios per optimizer step, with
//! checkpointed unrolled-episode tapes.
//!
//! This generalizes the single-flow corrector training of
//! [`experiments::corrector2d`](super::experiments::corrector2d): each
//! optimizer step runs one unrolled episode per scenario concurrently on the
//! [`BatchRunner`]'s pool, sums the per-scenario parameter gradients
//! (scenarios share the network), and takes one Adam step. Scenarios may
//! run on *different* meshes: the shared weights evaluate through per-mesh
//! neighbor tables ([`CnnTables`]), cached once per distinct mesh
//! fingerprint.
//!
//! Episode memory follows the episode's
//! [`TapeStrategy`](crate::adjoint::TapeStrategy) through
//! [`Tape`](crate::adjoint::Tape) — the engine owns **no** replay logic of
//! its own. Segment rematerialization (solver
//! [`StepRecord`](crate::piso::StepRecord)s *and* CNN
//! activation tapes) happens inside
//! [`Tape::replay_segments`](crate::adjoint::Tape::replay_segments): the
//! episode's `source_fn` recomputes the network forward per re-stepped
//! step and stashes the activations the sweep callback consumes, so a
//! length-n episode holds O(n/k + k) (uniform) or O(s + leaf) (revolve)
//! full-field tapes instead of O(n), while producing bit-for-bit the
//! gradients of the eager episode (forward stepping and the network are
//! deterministic).

use crate::adjoint::{backward_step, Tape, TapeStrategy};
use crate::mesh::VectorField;
use crate::nn::{Cnn, CnnTables, CnnTape};
use crate::piso::{PisoSolver, State};
use crate::train::{mse_loss_grad, Adam, Optimizer};
use crate::util::rng::Rng;
use std::cell::RefCell;
use std::sync::Mutex;

use super::experiments::corrector2d::{corrector_net, net_input, Corrector2dCfg};
use super::scenario::{mesh_fingerprint, BatchRunner, Scenario, ScenarioRun};

/// Outcome of a batched corrector training run.
pub struct BatchTrainResult {
    pub net: Cnn,
    /// Batch-mean episode loss per optimizer step.
    pub losses: Vec<f64>,
}

/// Per-step network artifacts rematerialized alongside the solver records:
/// the featurized input, the activation tape, and the scaled output S_θ.
struct StepAux {
    input: Vec<Vec<f64>>,
    tape: CnnTape,
    s_theta: VectorField,
}

/// One unrolled training episode against coarse-aligned reference frames,
/// with tape memory governed by `cfg.strategy`: forward from
/// `frames[start]`, per-step MSE loss vs `frames[start + t + 1]`, backward
/// through solver and network. `tables` are the network's neighbor tables
/// on `solver`'s mesh (`&net.tables` when the scenario runs on the
/// network's home mesh, else [`Cnn::tables_for`]). Returns
/// `(mean loss, ∂L/∂params)`.
pub fn episode(
    solver: &mut PisoSolver,
    net: &Cnn,
    tables: &CnnTables,
    base_source: &VectorField,
    frames: &[VectorField],
    start: usize,
    unroll: usize,
    cfg: &Corrector2dCfg,
) -> (f64, Vec<f64>) {
    assert!(unroll >= 1, "episode: unroll must be at least 1 step");
    assert!(
        start + unroll < frames.len(),
        "episode: start {start} + unroll {unroll} needs {} frames, have {}",
        start + unroll + 1,
        frames.len()
    );
    let ncells = solver.mesh.ncells;

    // The tape re-evaluates `source_fn` whenever it (re)steps; that is the
    // single place the network runs forward, so each call also stashes the
    // activations the backward sweep will need. The stash is bounded by
    // the strategy's segment length (under `Full` the backward never
    // re-steps, so the recording pass itself must keep all `unroll`
    // entries — exactly the eager episode's footprint); sweeps always
    // refill it right before the segment callback consumes it.
    let cap = cfg.strategy.segment(unroll).max(1);
    let stash: RefCell<Vec<(usize, StepAux)>> = RefCell::new(Vec::new());
    let source_fn = |step: usize, st: &State| -> VectorField {
        let input = net_input(&st.u);
        let (out, tape) = net.forward_with(tables, &input);
        let mut s_theta = VectorField::zeros(ncells);
        let mut src = base_source.clone();
        for c in 0..2 {
            for i in 0..ncells {
                let v = cfg.output_scale * out[c][i];
                s_theta.comp[c][i] = v;
                src.comp[c][i] += v;
            }
        }
        let mut stash = stash.borrow_mut();
        if stash.len() == cap {
            stash.remove(0);
        }
        stash.push((step, StepAux { input, tape, s_theta }));
        src
    };

    let mut state = State::zeros(&solver.mesh);
    state.u = frames[start].clone();
    let tape = Tape::record(solver, &mut state, unroll, cfg.strategy, &source_fn);

    // backward: the tape replays segments last-to-first; this sweep adds
    // the per-step loss cotangent, routes the source gradient through the
    // network (coupling the network-input path back into the state
    // cotangent), and chains du/dp across segments.
    let mut total_loss = 0.0;
    let mut dparams = vec![0.0; net.nparams()];
    let mut du = VectorField::zeros(ncells);
    let mut dp = vec![0.0; ncells];
    tape.replay_segments(solver, &source_fn, |solver, seg| {
        let stash = stash.borrow();
        for (i, t) in (seg.start..seg.start + seg.records.len()).enumerate().rev() {
            let aux = stash
                .iter()
                .rev()
                .find(|(s, _)| *s == t)
                .map(|(_, a)| a)
                .expect("replay rematerializes a step's activations before its sweep");
            let (l, mut cot) = mse_loss_grad(2, &seg.states_after[i].u, &frames[start + t + 1]);
            total_loss += l;
            cot.axpy(1.0, &du);
            let g = backward_step(solver, &seg.records[i], &cot, &dp, cfg.paths);
            // source gradient → CNN (with optional divergence modification)
            let ds = if cfg.lambda_div > 0.0 {
                crate::train::div_gradient_modification(
                    &solver.ctx,
                    &solver.mesh,
                    &aux.s_theta,
                    &g.dsource,
                    cfg.lambda_div,
                )
            } else {
                g.dsource.clone()
            };
            let dout: Vec<Vec<f64>> = (0..2)
                .map(|c| ds.comp[c].iter().map(|v| cfg.output_scale * v).collect())
                .collect();
            let (dpar, dins) = net.backward_with(tables, &aux.input, &aux.tape, &dout);
            for (a, b) in dparams.iter_mut().zip(&dpar) {
                *a += b;
            }
            // state gradient: solver path + network-input path
            du = g.du_n;
            for c in 0..2 {
                for cell in 0..ncells {
                    du.comp[c][cell] += dins[c][cell];
                }
            }
            dp = g.dp_in;
        }
    });
    (total_loss / unroll as f64, dparams)
}

/// Train one shared corrector across a scenario batch: per optimizer step,
/// one episode per scenario runs concurrently on the runner's pool (each
/// scenario against its own reference frames), the parameter gradients are
/// summed, and a single Adam step updates the shared network. Scenarios
/// may run on different meshes (a cavity + channel mixed curriculum): the
/// network is seeded on scenario 0's mesh and evaluates elsewhere through
/// per-mesh [`CnnTables`], built once per distinct mesh fingerprint. Every
/// mesh must be tap-compatible with the shared weights (same dimension).
/// Results are independent of the pool width (episodes only read shared
/// state; the reduction is in scenario order).
pub fn train_corrector_batch(
    runner: &BatchRunner,
    scenarios: &[Box<dyn Scenario>],
    frames: &[Vec<VectorField>],
    cfg: &Corrector2dCfg,
) -> BatchTrainResult {
    assert_eq!(
        scenarios.len(),
        frames.len(),
        "one reference-frame sequence per scenario"
    );
    assert!(!scenarios.is_empty(), "empty scenario batch");
    let ctx = runner.ctx();
    let runs: Vec<Mutex<ScenarioRun>> = scenarios
        .iter()
        .map(|s| {
            let mut r = s.build();
            r.solver.ctx = ctx.clone();
            Mutex::new(r)
        })
        .collect();

    let mut net = corrector_net(
        &runs[0].lock().expect("run mutex unpoisoned: pool rethrows worker panics").solver.mesh,
        cfg.seed,
    );
    // per-mesh conv-table cache: one table set per distinct mesh geometry
    // (fingerprint over cell count, dimension, center bits), shared by all
    // scenarios on that mesh
    let mut fp_keys: Vec<u64> = Vec::new();
    let mut table_sets: Vec<CnnTables> = Vec::new();
    let mut table_idx: Vec<usize> = Vec::with_capacity(runs.len());
    for r in &runs {
        let run = r.lock().expect("run mutex unpoisoned: pool rethrows worker panics");
        let fp = mesh_fingerprint(&run.solver.mesh);
        match fp_keys.iter().position(|k| *k == fp) {
            Some(j) => table_idx.push(j),
            None => {
                let tables = net.tables_for(&run.solver.mesh).unwrap_or_else(|e| {
                    panic!(
                        "scenario `{}` cannot share the batch corrector: {e}",
                        run.label
                    )
                });
                fp_keys.push(fp);
                table_sets.push(tables);
                table_idx.push(fp_keys.len() - 1);
            }
        }
    }

    let mut opt = Adam::new(cfg.lr, net.nparams());
    let mut rng = Rng::new(cfg.seed ^ 0x55);
    let mut losses = Vec::new();
    let nscen = scenarios.len();
    for &unroll in &cfg.curriculum {
        for _ in 0..cfg.opt_steps_per_stage {
            // per-scenario episode starts (drawn serially: deterministic
            // regardless of pool width)
            let starts: Vec<usize> = (0..nscen)
                .map(|i| rng.below(frames[i].len().saturating_sub(unroll + 1)))
                .collect();
            let slots: Vec<Mutex<Option<(f64, Vec<f64>)>>> =
                (0..nscen).map(|_| Mutex::new(None)).collect();
            {
                let net_ref = &net;
                let cfg_ref = cfg;
                let frames_ref = frames;
                let starts_ref = &starts;
                let tables_ref = &table_sets;
                let tidx_ref = &table_idx;
                ctx.run_tasks(nscen, |i| {
                    let mut run =
                        runs[i].lock().expect("run mutex held once per task index");
                    let ScenarioRun { ref mut solver, ref source, .. } = *run;
                    let got = episode(
                        solver,
                        net_ref,
                        &tables_ref[tidx_ref[i]],
                        source,
                        &frames_ref[i],
                        starts_ref[i],
                        unroll,
                        cfg_ref,
                    );
                    *slots[i].lock().expect("slot mutex held once per task index") = Some(got);
                });
            }
            // reduce in scenario order (deterministic sum)
            let mut batch_loss = 0.0;
            let mut dparams = vec![0.0; net.nparams()];
            for slot in &slots {
                let (l, dp) = slot
                    .lock()
                    .expect("slot mutex unpoisoned: pool rethrows worker panics")
                    .take()
                    .expect("every episode task fills its slot before the batch reduce");
                batch_loss += l;
                for (a, b) in dparams.iter_mut().zip(&dp) {
                    *a += b;
                }
            }
            let mut params = std::mem::take(&mut net.params);
            opt.step(&mut params, &dparams);
            net.params = params;
            losses.push(batch_loss / nscen as f64);
        }
    }
    BatchTrainResult { net, losses }
}

/// Generate coarse-aligned reference frames for every fine scenario of a
/// batch, concurrently on the runner's pool: each fine scenario is built
/// from the registry, warmed up, and resampled onto its own coarse mesh
/// (`coarse_meshes[i]`, one per fine scenario — mixed-mesh batches resample
/// each flow onto its own training grid) every `t_ratio` steps (see
/// [`make_reference_frames`](super::experiments::corrector2d::make_reference_frames)).
pub fn scenario_reference_frames(
    runner: &BatchRunner,
    fine: &[Box<dyn Scenario>],
    coarse_meshes: &[crate::mesh::Mesh],
    cfg: &Corrector2dCfg,
) -> Vec<Vec<VectorField>> {
    use super::experiments::corrector2d::make_reference_frames;
    assert_eq!(
        fine.len(),
        coarse_meshes.len(),
        "one coarse mesh per fine scenario"
    );
    let ctx = runner.ctx();
    let slots: Vec<Mutex<Option<Vec<VectorField>>>> =
        (0..fine.len()).map(|_| Mutex::new(None)).collect();
    ctx.run_tasks(fine.len(), |i| {
        let mut run = fine[i].build();
        run.solver.ctx = ctx.clone();
        let frames =
            make_reference_frames(&mut run.solver, &mut run.state, &coarse_meshes[i], cfg);
        *slots[i].lock().expect("slot mutex held once per task index") = Some(frames);
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("slot mutex unpoisoned: pool rethrows worker panics")
                .expect("frame generation skipped a scenario")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::GradientPaths;
    use crate::coordinator::scenario::TaylorGreen;

    fn tiny_cfg(strategy: TapeStrategy) -> Corrector2dCfg {
        Corrector2dCfg {
            t_ratio: 1,
            n_frames: 8,
            fine_warmup: 2,
            curriculum: vec![3],
            opt_steps_per_stage: 2,
            lr: 1e-3,
            paths: GradientPaths::NONE,
            lambda_div: 0.0,
            output_scale: 0.05,
            strategy,
            seed: 0xE2E,
        }
    }

    fn rollout_frames(scen: &dyn Scenario, steps: usize) -> (ScenarioRun, Vec<VectorField>) {
        let mut run = scen.build();
        let mut frames = vec![run.state.u.clone()];
        for _ in 0..steps {
            let src = run.source.clone();
            run.solver.step(&mut run.state, &src, None);
            frames.push(run.state.u.clone());
        }
        (run, frames)
    }

    /// Checkpointed and revolve episodes must reproduce the eager
    /// episode's loss and parameter gradients exactly (re-stepping is
    /// deterministic); this is the engine-level guarantee inherited from
    /// `Tape::replay_segments` after the port.
    #[test]
    fn scheduled_episodes_match_full_bit_for_bit() {
        let scen = TaylorGreen { n: 8, nu: 0.02, dt: 0.02 };
        let (run, frames) = rollout_frames(&scen, 6);
        let net = corrector_net(&run.solver.mesh, 7);
        let mut results = Vec::new();
        for strategy in [
            TapeStrategy::Full,
            TapeStrategy::Checkpoint { every: 2 },
            TapeStrategy::Revolve { snapshots: 2 },
        ] {
            let mut s = scen.build();
            results.push(episode(
                &mut s.solver,
                &net,
                &net.tables,
                &s.source,
                &frames,
                0,
                5,
                &tiny_cfg(strategy),
            ));
        }
        let (l_full, g_full) = &results[0];
        for (l, g) in &results[1..] {
            assert_eq!(l_full, l);
            assert_eq!(g_full, g);
        }
    }

    /// The same equality on an outflow mesh: the advective-outflow update
    /// mutates boundary values between steps, so the tape's bc
    /// snapshot/restore discipline is what keeps rematerialized segments
    /// bit-for-bit — the hard determinism case for both schedules.
    #[test]
    fn scheduled_episodes_match_full_with_outflow_bcs() {
        use crate::coordinator::scenario::VortexStreet;
        let scen = VortexStreet {
            nx: [4, 3, 6],
            ny: [4, 3, 4],
            re: 200.0,
            dt: 0.05,
            target_cfl: 0.8,
        };
        let (run, frames) = rollout_frames(&scen, 5);
        let net = corrector_net(&run.solver.mesh, 11);
        let mut results = Vec::new();
        for strategy in [
            TapeStrategy::Full,
            TapeStrategy::Checkpoint { every: 2 },
            TapeStrategy::Revolve { snapshots: 2 },
        ] {
            let mut s = scen.build();
            results.push(episode(
                &mut s.solver,
                &net,
                &net.tables,
                &s.source,
                &frames,
                0,
                4,
                &tiny_cfg(strategy),
            ));
        }
        let (l_full, g_full) = &results[0];
        for (l, g) in &results[1..] {
            assert_eq!(l_full, l);
            assert_eq!(g_full, g);
        }
    }

    /// Batch training across 2 same-mesh scenarios runs and returns finite
    /// losses.
    #[test]
    fn batch_training_runs_across_two_scenarios() {
        let scens: Vec<Box<dyn Scenario>> = vec![
            Box::new(TaylorGreen { n: 8, nu: 0.02, dt: 0.02 }),
            Box::new(TaylorGreen { n: 8, nu: 0.05, dt: 0.02 }),
        ];
        let frames: Vec<Vec<VectorField>> =
            scens.iter().map(|s| rollout_frames(s.as_ref(), 6).1).collect();
        let cfg = tiny_cfg(TapeStrategy::Checkpoint { every: 2 });
        let runner = BatchRunner::new(0).with_threads(2);
        let result = train_corrector_batch(&runner, &scens, &frames, &cfg);
        assert_eq!(result.losses.len(), 2);
        assert!(result.losses.iter().all(|l| l.is_finite()));
    }

    /// A *mixed-mesh* batch — cavity + periodic box, different cell counts
    /// and topologies — trains one shared corrector through per-mesh conv
    /// tables (the one-mesh-per-batch restriction is gone).
    #[test]
    fn mixed_mesh_batch_trains_one_shared_corrector() {
        use crate::coordinator::scenario::LidDrivenCavity;
        let scens: Vec<Box<dyn Scenario>> = vec![
            Box::new(LidDrivenCavity { n: 6, re: 100.0, ..Default::default() }),
            Box::new(TaylorGreen { n: 8, nu: 0.02, dt: 0.02 }),
        ];
        let frames: Vec<Vec<VectorField>> =
            scens.iter().map(|s| rollout_frames(s.as_ref(), 6).1).collect();
        let cfg = tiny_cfg(TapeStrategy::Revolve { snapshots: 2 });
        let runner = BatchRunner::new(0).with_threads(2);
        let result = train_corrector_batch(&runner, &scens, &frames, &cfg);
        assert_eq!(result.losses.len(), 2);
        assert!(
            result.losses.iter().all(|l| l.is_finite()),
            "mixed-mesh batch produced non-finite losses: {:?}",
            result.losses
        );
    }
}
