//! Experiment drivers, one per paper table/figure group (DESIGN.md §4):
//! E4 gradient-path ablation (Fig 6 / Table 1), E9 direct optimizations
//! (Fig C.22/C.23), E5/E6 2D corrector training (Tables 2–4, Figs 7–10),
//! E7 TCF SGS training (Figs 11–13, Table B.5), and the §5.4 runtime
//! comparison. Each driver is callable from both the CLI and the benches.

pub mod corrector2d;
pub mod gradient_paths;
pub mod lid_opt;
pub mod tcf_sgs;

pub use corrector2d::{
    evaluate_corrector, make_reference_frames, train_corrector2d, vorticity, Corrector2dCfg,
};
pub use gradient_paths::{gradient_path_ablation, GradPathCfg, GradPathResult};
pub use lid_opt::{optimize_cavity_params, CavityOptCfg, CavityOptResult};
pub use tcf_sgs::{
    eval_sgs, eval_smagorinsky, reference_statistics, train_tcf_sgs, TcfSgsCfg, TcfSgsResult,
};
