//! E7 — learned SGS model for the 3D turbulent channel flow (paper §5.3):
//! a CNN corrector is trained *purely from reference statistics* (eq. 13
//! losses on mean + Reynolds-stress profiles, no paired frames), with
//! warm-up steps before backpropagation, the eq. 11 divergence gradient
//! modification, and forcing regularization (eq. 15). Baselines: no-SGS and
//! the van-Driest-damped Smagorinsky model.
//!
//! Scaled-down per DESIGN.md §5/§7: a mini-channel at coarse resolution
//! with the fine run of our own solver providing the reference statistics
//! (the Hoyas–Jiménez role).

use crate::adjoint::{backward_step, GradientPaths};
use crate::coordinator::scenario::{Scenario, ScenarioRun, TurbulentChannel};
use crate::mesh::{gen, Mesh, VectorField};
use crate::nn::{Cnn, LayerCfg};
use crate::piso::{PisoSolver, StepRecord};
use crate::train::{stats_loss_grad, Adam, Optimizer, StatsTarget};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TcfSgsCfg {
    /// Coarse grid (the learned-SGS resolution).
    pub coarse_n: [usize; 3],
    /// Channel size (δ = ly/2).
    pub l: [f64; 3],
    pub nu: f64,
    /// Body-force magnitude driving the flow (constant streamwise forcing;
    /// the dynamic wall-shear forcing is applied on top).
    pub forcing: f64,
    pub dt: f64,
    /// Warm-up (non-differentiable) step range and unroll length.
    pub max_warmup: usize,
    pub unroll: usize,
    pub opt_steps: usize,
    pub lr: f64,
    /// λ_S forcing regularization (eq. 15) and λ_∇·u (eq. 11).
    pub lambda_s: f64,
    pub lambda_div: f64,
    /// Raw-output scale (keeps early corrections small; clamp still applies).
    pub output_scale: f64,
    pub seed: u64,
}

impl Default for TcfSgsCfg {
    fn default() -> Self {
        TcfSgsCfg {
            coarse_n: [12, 10, 6],
            l: [4.0, 2.0, 2.0],
            nu: 0.004,
            forcing: 0.01,
            dt: 0.08,
            max_warmup: 30,
            unroll: 4,
            opt_steps: 150,
            lr: 1.5e-3,
            lambda_s: 0.1,
            lambda_div: 1e-3,
            output_scale: 0.01,
            seed: 0x7CF,
        }
    }
}

pub struct TcfSgsResult {
    pub net: Cnn,
    pub train_losses: Vec<f64>,
    pub target: StatsTarget,
}

/// SGS corrector network: velocity + wall-distance input (4 channels),
/// 3 velocity-source outputs (paper §5.3 architecture, scaled down).
pub fn sgs_net(mesh: &Mesh, seed: u64) -> Cnn {
    Cnn::new(
        mesh,
        4,
        vec![
            LayerCfg { cout: 12, radius: 1, relu: true },
            LayerCfg { cout: 12, radius: 1, relu: true },
            LayerCfg { cout: 3, radius: 0, relu: false },
        ],
        seed,
    )
}

/// Network input: instantaneous velocity + normalized wall distance 1−|y/δ|.
pub fn sgs_input(mesh: &Mesh, u: &VectorField, delta: f64) -> Vec<Vec<f64>> {
    let wall: Vec<f64> =
        mesh.centers.iter().map(|c| 1.0 - ((c[1] - delta) / delta).abs()).collect();
    vec![u.comp[0].clone(), u.comp[1].clone(), u.comp[2].clone(), wall]
}

/// The coarse channel as a registry scenario (init seeded by `seed_salt`
/// so training / evaluation / pool states draw distinct initial flows).
pub fn coarse_scenario(cfg: &TcfSgsCfg, seed_salt: u64) -> TurbulentChannel {
    TurbulentChannel {
        n: cfg.coarse_n,
        l: cfg.l,
        nu: cfg.nu,
        forcing: cfg.forcing,
        dt: cfg.dt,
        perturbation: 0.4,
        seed: cfg.seed ^ seed_salt,
    }
}

/// Build the coarse channel solver (via the scenario registry).
pub fn coarse_solver(cfg: &TcfSgsCfg) -> PisoSolver {
    coarse_scenario(cfg, 0).build().solver
}

/// Constant streamwise forcing field.
pub fn forcing_field(mesh: &Mesh, f: f64) -> VectorField {
    let mut s = VectorField::zeros(mesh.ncells);
    s.comp[0].iter_mut().for_each(|v| *v = f);
    s
}

/// Initial condition: parabolic-ish profile + divergence-free perturbations
/// (the paper's Reichardt + perturbation initialization, simplified).
pub fn perturbed_channel_init(mesh: &Mesh, ly: f64, amp: f64, seed: u64) -> VectorField {
    let mut rng = Rng::new(seed);
    let mut u = VectorField::zeros(mesh.ncells);
    let tau = 2.0 * std::f64::consts::PI;
    let (ax, az) = (rng.range(1.0, 2.0), rng.range(1.0, 2.0));
    for (i, c) in mesh.centers.iter().enumerate() {
        let eta = c[1] / ly;
        let base = 4.0 * eta * (1.0 - eta);
        // curl-based perturbation: u' = ∂ψ/∂y, v' = −∂ψ/∂x (div-free in 2D
        // slices), plus a spanwise mode
        let psi = (tau * ax * c[0]).sin() * (tau * c[1] / ly).sin() * (tau * az * c[2]).cos();
        u.comp[0][i] = base + amp * psi * (tau / ly) * (tau * c[1] / ly).cos().signum();
        u.comp[1][i] = amp * (tau * ax * c[0]).cos() * (tau * c[1] / ly).sin();
        u.comp[2][i] = amp * (tau * az * c[2]).sin() * (tau * c[1] / ly).sin();
    }
    u
}

/// Accumulate reference statistics from a finer-resolution run of the same
/// channel (the "high-res reference" role of §5.3), resampled to the coarse
/// wall-normal layers by nearest-layer matching.
pub fn reference_statistics(cfg: &TcfSgsCfg, fine_n: [usize; 3], steps: usize) -> StatsTarget {
    // the fine reference is the same registry scenario at finer resolution
    // and half the time step (the "high-res reference" role of §5.3)
    let fine = TurbulentChannel { n: fine_n, dt: cfg.dt * 0.5, ..coarse_scenario(cfg, 0) };
    let ScenarioRun { mut solver, mut state, source: src, .. } = fine.build();
    // develop, then accumulate
    solver.run(&mut state, &src, steps / 2);
    let mut stats = crate::stats::ChannelStats::new(&solver.mesh, cfg.nu);
    for _ in 0..steps / 2 {
        solver.step(&mut state, &src, None);
        stats.push(&solver.mesh, &state.u);
    }
    let (um, uu, vv, ww, uv) = stats.profiles();
    // resample fine layers onto coarse layers (nearest y)
    let coarse_mesh = gen::channel3d(cfg.coarse_n, cfg.l, 1.08);
    let cb = &coarse_mesh.blocks[0];
    let ny_c = cb.shape[1];
    let fine_y = stats.y.clone();
    let pick = |prof: &[f64], y: f64| -> f64 {
        let mut best = 0;
        let mut bd = f64::INFINITY;
        for (j, fy) in fine_y.iter().enumerate() {
            let d = (fy - y).abs();
            if d < bd {
                bd = d;
                best = j;
            }
        }
        prof[best]
    };
    let mut mean = [vec![0.0; ny_c], vec![0.0; ny_c], vec![0.0; ny_c]];
    let mut stress = [vec![0.0; ny_c], vec![0.0; ny_c], vec![0.0; ny_c], vec![0.0; ny_c]];
    for j in 0..ny_c {
        let y = cb.centers[cb.lidx(0, j, 0)][1];
        mean[0][j] = pick(&um, y);
        stress[0][j] = pick(&uu, y);
        stress[1][j] = pick(&vv, y);
        stress[2][j] = pick(&ww, y);
        stress[3][j] = pick(&uv, y);
    }
    StatsTarget {
        mean,
        stress,
        w_mean: [1.0, 0.5, 0.5],
        w_stress: [1.0, 1.0, 1.0, 1.0],
    }
}

/// Train the SGS corrector from statistics only (no paired frames).
pub fn train_tcf_sgs(cfg: &TcfSgsCfg, target: &StatsTarget) -> TcfSgsResult {
    let ScenarioRun { mut solver, state: mut pool_state, source: src_base, .. } =
        coarse_scenario(cfg, 1).build();
    let ncells = solver.mesh.ncells;
    let delta = cfg.l[1] / 2.0;
    let mut net = sgs_net(&solver.mesh, cfg.seed);
    let mut opt = Adam::new(cfg.lr, net.nparams());
    let mut rng = Rng::new(cfg.seed ^ 0x99);

    // starting pool: develop the un-modeled coarse flow
    solver.run(&mut pool_state, &src_base, 30);

    let mut losses = Vec::new();
    for _ in 0..cfg.opt_steps {
        // warm-up: non-differentiable rollout with the current corrector
        let mut state = pool_state.clone();
        let warm = rng.below(cfg.max_warmup + 1);
        for _ in 0..warm {
            let (o, _) = net.forward(&sgs_input(&solver.mesh, &state.u, delta));
            let mut src = src_base.clone();
            for c in 0..3 {
                for i in 0..ncells {
                    src.comp[c][i] += (cfg.output_scale * o[c][i]).clamp(-2.0, 2.0);
                }
            }
            solver.step(&mut state, &src, None);
        }
        // differentiable unroll
        let mut recs = Vec::new();
        let mut inputs = Vec::new();
        let mut tapes = Vec::new();
        let mut sources = Vec::new();
        let mut states = vec![state.clone()];
        for _ in 0..cfg.unroll {
            let input = sgs_input(&solver.mesh, &state.u, delta);
            let (o, tape) = net.forward(&input);
            let mut src = src_base.clone();
            let mut s_theta = VectorField::zeros(ncells);
            for c in 0..3 {
                for i in 0..ncells {
                    let v = (cfg.output_scale * o[c][i]).clamp(-2.0, 2.0);
                    s_theta.comp[c][i] = v;
                    src.comp[c][i] += v;
                }
            }
            let mut rec = StepRecord::empty();
            solver.step(&mut state, &src, Some(&mut rec));
            recs.push(rec);
            inputs.push(input);
            tapes.push(tape);
            sources.push(s_theta);
            states.push(state.clone());
        }
        // per-frame statistics loss on every unrolled state (eq. 13's
        // per-frame part) + forcing regularization (eq. 15)
        let mut total = 0.0;
        let mut dparams = vec![0.0; net.nparams()];
        let mut du = VectorField::zeros(ncells);
        let mut dp = vec![0.0; ncells];
        for t in (0..cfg.unroll).rev() {
            let (l, mut cot) = stats_loss_grad(&solver.mesh, &states[t + 1].u, target);
            total += l;
            cot.axpy(1.0, &du);
            let g = backward_step(&solver, &recs[t], &cot, &dp, GradientPaths::NONE);
            let mut ds = g.dsource.clone();
            // + λ_S ∂‖S‖²/∂S = 2 λ_S S / (N · unroll)
            let wreg = 2.0 * cfg.lambda_s / (ncells * cfg.unroll) as f64;
            for c in 0..3 {
                for i in 0..ncells {
                    total += cfg.lambda_s * sources[t].comp[c][i].powi(2)
                        / (ncells * cfg.unroll) as f64;
                    ds.comp[c][i] += wreg * sources[t].comp[c][i];
                }
            }
            let ds = if cfg.lambda_div > 0.0 {
                crate::train::div_gradient_modification(
                    &solver.ctx,
                    &solver.mesh,
                    &sources[t],
                    &ds,
                    cfg.lambda_div,
                )
            } else {
                ds
            };
            // clamp backward: zero gradient where the clamp saturated
            let mut dout = vec![vec![0.0; ncells]; 3];
            for c in 0..3 {
                for i in 0..ncells {
                    let raw = sources[t].comp[c][i];
                    dout[c][i] = if raw.abs() >= 2.0 {
                        0.0
                    } else {
                        cfg.output_scale * ds.comp[c][i]
                    };
                }
            }
            let (dpar, dins) = net.backward(&inputs[t], &tapes[t], &dout);
            for (a, b) in dparams.iter_mut().zip(&dpar) {
                *a += b;
            }
            du = g.du_n;
            for c in 0..3 {
                for i in 0..ncells {
                    du.comp[c][i] += dins[c][i];
                }
            }
            dp = g.dp_in;
        }
        let mut params = std::mem::take(&mut net.params);
        opt.step(&mut params, &dparams);
        net.params = params;
        losses.push(total / cfg.unroll as f64);
        // advance the pool so episodes see fresh states
        solver.step(&mut pool_state, &src_base, None);
    }
    TcfSgsResult { net, train_losses: losses, target: target.clone() }
}

/// Evaluate per-frame statistics loss over a rollout with a given model.
/// `model`: None = no-SGS; Some((net, None)) = learned; None + smag handled
/// by `eval_smagorinsky`.
pub fn eval_sgs(
    cfg: &TcfSgsCfg,
    net: Option<&Cnn>,
    target: &StatsTarget,
    steps: usize,
) -> Vec<f64> {
    let ScenarioRun { mut solver, mut state, source: src_base, .. } =
        coarse_scenario(cfg, 7).build();
    let ncells = solver.mesh.ncells;
    let delta = cfg.l[1] / 2.0;
    // develop without any model first so all variants start from the same
    // (un-modeled, statistically wrong) state — the figure-13 protocol
    solver.run(&mut state, &src_base, 30);
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let src = match net {
            Some(n) => {
                let (o, _) = n.forward(&sgs_input(&solver.mesh, &state.u, delta));
                let mut s = src_base.clone();
                for c in 0..3 {
                    for i in 0..ncells {
                        s.comp[c][i] += (cfg.output_scale * o[c][i]).clamp(-2.0, 2.0);
                    }
                }
                s
            }
            None => src_base.clone(),
        };
        solver.step(&mut state, &src, None);
        let (l, _) = stats_loss_grad(&solver.mesh, &state.u, target);
        out.push(l);
    }
    out
}

/// Same rollout with the Smagorinsky baseline (eddy viscosity added to ν).
pub fn eval_smagorinsky(cfg: &TcfSgsCfg, target: &StatsTarget, steps: usize, cs: f64) -> Vec<f64> {
    let ScenarioRun { mut solver, mut state, source: src, .. } =
        coarse_scenario(cfg, 7).build();
    solver.run(&mut state, &src, 30);
    let dist = crate::nn::smagorinsky::channel_wall_distance(&solver.mesh, cfg.l[1]);
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let nu_t = crate::nn::smagorinsky_nu_t(
            &solver.mesh,
            &state.u,
            cs,
            Some(&dist),
            0.05,
            cfg.nu,
        );
        for i in 0..solver.mesh.ncells {
            solver.nu[i] = cfg.nu + nu_t[i];
        }
        solver.step(&mut state, &src, None);
        let (l, _) = stats_loss_grad(&solver.mesh, &state.u, target);
        out.push(l);
    }
    out
}
