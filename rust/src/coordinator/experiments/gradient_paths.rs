//! E4 — gradient-path ablation (paper §4.2–4.3, figure 6 + table 1).
//!
//! An 18×16 periodic box is initialised with a 2D Gaussian u-velocity
//! profile scaled by an unknown factor θ; θ is recovered by gradient
//! descent on an L2 velocity loss after n PISO steps, backpropagating
//! through the full rollout with each of the four gradient-path variants
//! (Adv+P / Adv / P / none).

use crate::adjoint::{rollout_backward, GradientPaths, Tape, TapeStrategy};
use crate::coordinator::scenario::{gaussian_bump_init, GaussianBox, Scenario};
use crate::mesh::{Mesh, VectorField};
use crate::piso::{PisoSolver, State};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct GradPathCfg {
    /// Rollout length n (paper: 1, 10, 100).
    pub n_steps: usize,
    /// Learning rate (paper: 0.01, and 0.001 for the long-rollout case).
    pub lr: f64,
    /// Optimization iterations (paper: 60, or 600 for lr=0.001).
    pub opt_iters: usize,
    /// Stop early when the loss crosses this (table 1 reports wall-clock to 1e-4).
    pub target_loss: f64,
    pub paths: GradientPaths,
    /// Initial guess for the scale (reference is 1.0).
    pub theta0: f64,
    pub nu: f64,
    pub dt: f64,
    /// Rollout tape memory (the long-rollout cases are exactly where
    /// checkpointing pays).
    pub strategy: TapeStrategy,
}

impl Default for GradPathCfg {
    fn default() -> Self {
        GradPathCfg {
            n_steps: 10,
            lr: 0.01,
            opt_iters: 60,
            target_loss: 1e-4,
            paths: GradientPaths::FULL,
            theta0: 2.0,
            nu: 0.01,
            dt: 0.05,
            strategy: TapeStrategy::Full,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GradPathResult {
    pub label: &'static str,
    pub losses: Vec<f64>,
    /// Cumulative wall-clock seconds after each optimizer iteration.
    pub times: Vec<f64>,
    /// Wall-clock seconds to reach `target_loss` (None if never reached).
    pub time_to_target: Option<f64>,
    pub final_theta: f64,
    pub diverged: bool,
}

/// The Gaussian initial u-profile of the task (the registry scenario's
/// initializer, re-exported under the historical name).
pub fn gauss_profile(mesh: &Mesh) -> VectorField {
    gaussian_bump_init(mesh)
}

/// The E4 flow as a registry scenario (θ stays at the registry default:
/// the ablation scales the initial profile per optimizer iterate itself,
/// reusing one solver across iterations).
fn solver_for(cfg: &GradPathCfg) -> PisoSolver {
    GaussianBox { nu: cfg.nu, dt: cfg.dt, ..Default::default() }.build().solver
}

/// Run the ablation for one configuration.
pub fn gradient_path_ablation(cfg: &GradPathCfg) -> GradPathResult {
    let mut solver = solver_for(cfg);
    let ncells = solver.mesh.ncells;
    let profile = gauss_profile(&solver.mesh);
    let zero_src = VectorField::zeros(ncells);

    // reference trajectory at θ* = 1
    let mut ref_state = State::zeros(&solver.mesh);
    ref_state.u = profile.clone();
    solver.run(&mut ref_state, &zero_src, cfg.n_steps);
    let u_ref = ref_state.u.clone();
    let norm = 1.0; // paper's L2 loss is a sum over cells

    let mut theta = cfg.theta0;
    let mut losses = Vec::with_capacity(cfg.opt_iters);
    let mut times = Vec::with_capacity(cfg.opt_iters);
    let mut time_to_target = None;
    let mut diverged = false;
    let t0 = Instant::now();

    for _ in 0..cfg.opt_iters {
        let mut state = State::zeros(&solver.mesh);
        state.u = profile.clone();
        state.u.scale(theta);
        let tape = Tape::record(&mut solver, &mut state, cfg.n_steps, cfg.strategy, |_, _| {
            VectorField::zeros(ncells)
        });
        // L = norm Σ |u_n − u_ref|² ; cotangent 2 norm (u_n − u_ref)
        let mut loss = 0.0;
        let mut cot = VectorField::zeros(ncells);
        for c in 0..2 {
            for i in 0..ncells {
                let d = state.u.comp[c][i] - u_ref.comp[c][i];
                loss += norm * d * d;
                cot.comp[c][i] = 2.0 * norm * d;
            }
        }
        let g = rollout_backward(
            &mut solver,
            &tape,
            cfg.paths,
            |_, _| VectorField::zeros(ncells),
            |step, _| {
                if step + 1 == cfg.n_steps {
                    (cot.clone(), vec![0.0; ncells])
                } else {
                    (VectorField::zeros(ncells), vec![0.0; ncells])
                }
            },
        );
        let dtheta: f64 = (0..2)
            .map(|c| {
                g.du0.comp[c]
                    .iter()
                    .zip(&profile.comp[c])
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .sum();
        theta -= cfg.lr * dtheta;
        let elapsed = t0.elapsed().as_secs_f64();
        losses.push(loss);
        times.push(elapsed);
        if loss < cfg.target_loss && time_to_target.is_none() {
            time_to_target = Some(elapsed);
        }
        if !loss.is_finite() || loss > 1e6 || !theta.is_finite() {
            diverged = true;
            break;
        }
    }
    GradPathResult {
        label: cfg.paths.label(),
        losses,
        times,
        time_to_target,
        final_theta: theta,
        diverged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_paths_converge_to_reference_scale() {
        let cfg = GradPathCfg {
            n_steps: 3,
            opt_iters: 40,
            lr: 0.02,
            ..Default::default()
        };
        let r = gradient_path_ablation(&cfg);
        assert!(!r.diverged);
        assert!(
            (r.final_theta - 1.0).abs() < 0.05,
            "theta {} losses {:?}",
            r.final_theta,
            &r.losses[r.losses.len().saturating_sub(3)..]
        );
        // loss decreases monotonically (convex-ish 1D problem)
        assert!(r.losses.last().unwrap() < &r.losses[0]);
    }

    #[test]
    fn none_path_still_optimizes_short_rollouts() {
        let cfg = GradPathCfg {
            n_steps: 2,
            opt_iters: 40,
            lr: 0.02,
            paths: GradientPaths::NONE,
            // per-step checkpoints: the degenerate-interval edge case
            strategy: TapeStrategy::Checkpoint { every: 1 },
            ..Default::default()
        };
        let r = gradient_path_ablation(&cfg);
        assert!(!r.diverged);
        assert!(r.losses.last().unwrap() < &(r.losses[0] * 0.1), "{:?}", r.losses.last());
    }
}
