//! E9 — direct optimization of lid velocity and viscosity in a lid-driven
//! cavity (paper Appendix C.1, figures C.22/C.23): no neural network, the
//! optimized quantities are physical parameters of the simulation, with
//! gradients backpropagated through the complete rollout (including the
//! pressure solves).

use crate::adjoint::{rollout_backward, GradientPaths, Tape, TapeStrategy};
use crate::coordinator::scenario::{LidDrivenCavity, Scenario, ScenarioRun};
use crate::mesh::VectorField;
use crate::piso::{PisoSolver, State};

#[derive(Clone, Debug)]
pub struct CavityOptCfg {
    pub n: usize,
    pub steps: usize,
    pub opt_iters: usize,
    /// (initial, target, learning rate) for the lid velocity.
    pub lid: (f64, f64, f64),
    /// (initial, target, learning rate) for the viscosity.
    pub nu: (f64, f64, f64),
    /// Optimize lid, viscosity, or both jointly (C.22 vs C.23).
    pub opt_lid: bool,
    pub opt_nu: bool,
    /// Rollout tape memory (checkpointing enables long-horizon variants).
    pub strategy: TapeStrategy,
}

impl Default for CavityOptCfg {
    fn default() -> Self {
        CavityOptCfg {
            n: 16,
            steps: 12,
            opt_iters: 60,
            lid: (1.0, 0.2, 40.0),
            nu: (5e-3, 1e-3, 2e-4),
            opt_lid: true,
            opt_nu: false,
            strategy: TapeStrategy::Full,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CavityOptResult {
    pub losses: Vec<f64>,
    pub lid_history: Vec<f64>,
    pub nu_history: Vec<f64>,
    pub final_loss: f64,
}

/// The cavity at `(lid, ν)` as a registry scenario (direct ν override; the
/// C.1 task varies physical parameters, not Reynolds number).
fn scenario_for(cfg: &CavityOptCfg, lid: f64, nu: f64) -> LidDrivenCavity {
    LidDrivenCavity { n: cfg.n, dt: 0.05, lid, nu: Some(nu), ..Default::default() }
}

fn run_forward(cfg: &CavityOptCfg, lid: f64, nu: f64) -> (PisoSolver, State) {
    let ScenarioRun { mut solver, mut state, source, .. } = scenario_for(cfg, lid, nu).build();
    solver.run(&mut state, &source, cfg.steps);
    (solver, state)
}

/// Gradient-descent recovery of the reference lid velocity / viscosity from
/// an L2 loss on the final velocity field.
pub fn optimize_cavity_params(cfg: &CavityOptCfg) -> CavityOptResult {
    // reference simulation at the target parameters
    let (_, ref_state) = run_forward(cfg, cfg.lid.1, cfg.nu.1);
    let u_ref = ref_state.u;

    // parameters that are NOT optimized stay at their true (target) values
    let mut lid = if cfg.opt_lid { cfg.lid.0 } else { cfg.lid.1 };
    let mut nu = if cfg.opt_nu { cfg.nu.0 } else { cfg.nu.1 };
    let mut losses = Vec::new();
    let mut lid_history = vec![lid];
    let mut nu_history = vec![nu];

    for _ in 0..cfg.opt_iters {
        let ScenarioRun { mut solver, mut state, .. } = scenario_for(cfg, lid, nu).build();
        let ncells = solver.mesh.ncells;
        let tape = Tape::record(&mut solver, &mut state, cfg.steps, cfg.strategy, |_, _| {
            VectorField::zeros(ncells)
        });
        let norm = 1.0; // sum-based L2 loss (paper Appendix C)
        let mut loss = 0.0;
        let mut cot = VectorField::zeros(ncells);
        for c in 0..2 {
            for i in 0..ncells {
                let d = state.u.comp[c][i] - u_ref.comp[c][i];
                loss += norm * d * d;
                cot.comp[c][i] = 2.0 * norm * d;
            }
        }
        losses.push(loss);
        let g = rollout_backward(
            &mut solver,
            &tape,
            GradientPaths::FULL,
            |_, _| VectorField::zeros(ncells),
            |step, _| {
                if step + 1 == cfg.steps {
                    (cot.clone(), vec![0.0; ncells])
                } else {
                    (VectorField::zeros(ncells), vec![0.0; ncells])
                }
            },
        );
        if cfg.opt_lid {
            // lid = bc set 3, x-component
            let dlid: f64 = g.dbc[3].iter().map(|v| v[0]).sum();
            lid -= cfg.lid.2 * dlid;
        }
        if cfg.opt_nu {
            nu = (nu - cfg.nu.2 * g.dnu).max(1e-6);
        }
        lid_history.push(lid);
        nu_history.push(nu);
    }
    let final_loss = *losses.last().unwrap_or(&f64::NAN);
    CavityOptResult { losses, lid_history, nu_history, final_loss }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lid_velocity_recovers_target() {
        let cfg = CavityOptCfg {
            n: 8,
            steps: 6,
            opt_iters: 60,
            ..Default::default()
        };
        let r = optimize_cavity_params(&cfg);
        let lid = *r.lid_history.last().unwrap();
        assert!((lid - 0.2).abs() < 0.05, "lid {lid}, losses {:?}", r.losses.last());
        assert!(r.final_loss < r.losses[0] * 1e-2);
    }

    #[test]
    fn viscosity_recovers_target() {
        let cfg = CavityOptCfg {
            n: 8,
            steps: 6,
            opt_iters: 80,
            lid: (0.5, 0.5, 0.0),
            nu: (5e-3, 1e-3, 2e-4),
            opt_lid: false,
            opt_nu: true,
            // checkpointed rollout memory: gradients are bit-for-bit the
            // full tape's, so recovery is unchanged
            strategy: TapeStrategy::Checkpoint { every: 3 },
        };
        let r = optimize_cavity_params(&cfg);
        let nu = *r.nu_history.last().unwrap();
        assert!(
            (nu - 1e-3).abs() < 5e-4,
            "nu {nu}, loss {} -> {}",
            r.losses[0],
            r.final_loss
        );
        assert!(r.final_loss < r.losses[0]);
    }
}
