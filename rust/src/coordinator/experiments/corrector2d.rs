//! E5/E6 — learned corrector training for the 2D scenarios (vortex street
//! §5.1, backward-facing step §5.2): a multi-block CNN estimates a
//! correcting force S_θ that pulls a coarse simulation toward the
//! coordinate-resampled trajectory of a fine reference simulation, trained
//! by backpropagating an unrolled MSE loss through the PISO solver and the
//! network (curriculum over the unroll length as in the paper).

use crate::adjoint::{GradientPaths, TapeStrategy};
use crate::coordinator::engine;
use crate::fvm;
use crate::mesh::{field, Mesh, VectorField};
use crate::nn::{Cnn, LayerCfg};
use crate::piso::{PisoSolver, State};
use crate::train::{mse_loss_grad, Adam, Optimizer};
use crate::util;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Corrector2dCfg {
    /// Fine steps per coarse step (temporal downsampling factor).
    pub t_ratio: usize,
    /// Number of coarse-aligned reference frames to generate.
    pub n_frames: usize,
    /// Warm-up fine steps before recording (let the flow develop).
    pub fine_warmup: usize,
    /// Unroll lengths of the curriculum (e.g. [4, 8] — paper: 4→8→16).
    pub curriculum: Vec<usize>,
    /// Optimizer steps per curriculum stage.
    pub opt_steps_per_stage: usize,
    pub lr: f64,
    /// Gradient paths for backprop through the solver (the paper's base
    /// trainings use the cheap `none` variant, fine-tunings add Adv).
    pub paths: GradientPaths,
    /// λ for the divergence gradient modification (eq. 11); 0 = off.
    pub lambda_div: f64,
    /// Output scale applied to the raw network output (keeps early-training
    /// corrections small relative to the dynamics; the paper clamps the
    /// forcing instead).
    pub output_scale: f64,
    /// Episode tape memory: eager, or checkpointed with segment recompute
    /// (bit-for-bit equal gradients; enables long unrolls).
    pub strategy: TapeStrategy,
    pub seed: u64,
}

impl Default for Corrector2dCfg {
    fn default() -> Self {
        Corrector2dCfg {
            t_ratio: 2,
            n_frames: 60,
            fine_warmup: 80,
            curriculum: vec![2, 4],
            opt_steps_per_stage: 40,
            lr: 3e-3,
            paths: GradientPaths::NONE,
            lambda_div: 1e-3,
            output_scale: 0.05,
            strategy: TapeStrategy::Full,
            seed: 0xC0DE,
        }
    }
}

pub struct Corrector2dResult {
    pub net: Cnn,
    pub train_losses: Vec<f64>,
    /// (step, mse_no_model, mse_nn, corr_no_model, corr_nn) at checkpoints.
    pub eval: Vec<(usize, f64, f64, f64, f64)>,
}

/// Reference frames: run the fine solver and resample every `t_ratio`-th
/// state onto the coarse mesh.
pub fn make_reference_frames(
    fine: &mut PisoSolver,
    fine_state: &mut State,
    coarse_mesh: &Mesh,
    cfg: &Corrector2dCfg,
) -> Vec<VectorField> {
    let src = VectorField::zeros(fine.mesh.ncells);
    fine.run(fine_state, &src, cfg.fine_warmup);
    let mut frames = Vec::with_capacity(cfg.n_frames);
    for _ in 0..cfg.n_frames {
        let mut coarse_u = VectorField::zeros(coarse_mesh.ncells);
        for c in 0..2 {
            coarse_u.comp[c] =
                field::resample(&fine.mesh, &fine_state.u.comp[c], coarse_mesh);
        }
        frames.push(coarse_u);
        fine.run(fine_state, &src, cfg.t_ratio);
    }
    frames
}

/// Default corrector architecture (scaled-down version of the paper's
/// 7-layer net; kernel radii 1 keep the conv tables small).
pub fn corrector_net(mesh: &Mesh, seed: u64) -> Cnn {
    Cnn::new(
        mesh,
        2,
        vec![
            LayerCfg { cout: 12, radius: 2, relu: true },
            LayerCfg { cout: 16, radius: 1, relu: true },
            LayerCfg { cout: 8, radius: 1, relu: true },
            LayerCfg { cout: 2, radius: 0, relu: false },
        ],
        seed,
    )
}

/// The 2D corrector's input featurization (shared by training in
/// [`engine`] and evaluation here — keep the two in lockstep by keeping
/// one copy).
pub(crate) fn net_input(u: &VectorField) -> Vec<Vec<f64>> {
    vec![u.comp[0].clone(), u.comp[1].clone()]
}

/// Train a corrector on pre-generated reference frames for one flow. The
/// unrolled episodes run on the shared engine
/// ([`engine::episode`]) under `cfg.strategy`'s tape memory model; for
/// training one network across a *batch* of scenarios per optimizer step
/// see [`engine::train_corrector_batch`].
pub fn train_corrector2d(
    solver: &mut PisoSolver,
    frames: &[VectorField],
    cfg: &Corrector2dCfg,
) -> (Cnn, Vec<f64>) {
    let mut net = corrector_net(&solver.mesh, cfg.seed);
    let mut opt = Adam::new(cfg.lr, net.nparams());
    let mut rng = Rng::new(cfg.seed ^ 0x55);
    let mut losses = Vec::new();
    let zero_src = VectorField::zeros(solver.mesh.ncells);
    for &unroll in &cfg.curriculum {
        for _ in 0..cfg.opt_steps_per_stage {
            let start = rng.below(frames.len().saturating_sub(unroll + 1));
            let (loss, dparams) =
                engine::episode(solver, &net, &net.tables, &zero_src, frames, start, unroll, cfg);
            let mut params = std::mem::take(&mut net.params);
            opt.step(&mut params, &dparams);
            net.params = params;
            losses.push(loss);
        }
    }
    (net, losses)
}

/// Vorticity ω = ∂v/∂x − ∂u/∂y of a 2D field.
pub fn vorticity(mesh: &Mesh, u: &VectorField) -> Vec<f64> {
    let gu = fvm::pressure_gradient(mesh, &u.comp[0]);
    let gv = fvm::pressure_gradient(mesh, &u.comp[1]);
    (0..mesh.ncells).map(|i| gv.comp[0][i] - gu.comp[1][i]).collect()
}

/// Evaluate No-Model vs NN-corrected rollouts against the reference frames:
/// returns (frame index, mse_no_model, mse_nn, corr_no_model, corr_nn).
pub fn evaluate_corrector(
    solver: &mut PisoSolver,
    net: Option<&Cnn>,
    output_scale: f64,
    frames: &[VectorField],
    checkpoints: &[usize],
) -> Vec<(usize, f64, f64)> {
    let ncells = solver.mesh.ncells;
    let mut state = State::zeros(&solver.mesh);
    state.u = frames[0].clone();
    let mut out = Vec::new();
    let maxstep = *checkpoints.iter().max().unwrap_or(&0);
    for step in 1..=maxstep.min(frames.len() - 1) {
        let src = match net {
            Some(n) => {
                let (o, _) = n.forward(&net_input(&state.u));
                let mut s = VectorField::zeros(ncells);
                for c in 0..2 {
                    s.comp[c] = o[c].iter().map(|v| output_scale * v).collect();
                }
                s
            }
            None => VectorField::zeros(ncells),
        };
        solver.step(&mut state, &src, None);
        if checkpoints.contains(&step) {
            let (mse, _) = mse_loss_grad(2, &state.u, &frames[step]);
            let w_sim = vorticity(&solver.mesh, &state.u);
            let w_ref = vorticity(&solver.mesh, &frames[step]);
            let corr = util::correlation(&w_sim, &w_ref);
            out.push((step, mse, corr));
        }
    }
    out
}
