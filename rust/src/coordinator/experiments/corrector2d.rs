//! E5/E6 — learned corrector training for the 2D scenarios (vortex street
//! §5.1, backward-facing step §5.2): a multi-block CNN estimates a
//! correcting force S_θ that pulls a coarse simulation toward the
//! coordinate-resampled trajectory of a fine reference simulation, trained
//! by backpropagating an unrolled MSE loss through the PISO solver and the
//! network (curriculum over the unroll length as in the paper).

use crate::adjoint::{backward_step, GradientPaths};
use crate::adjoint::rollout::empty_record;
use crate::fvm;
use crate::mesh::{field, Mesh, VectorField};
use crate::nn::{Cnn, LayerCfg};
use crate::piso::{PisoSolver, State};
use crate::train::{mse_loss_grad, Adam, Optimizer};
use crate::util;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Corrector2dCfg {
    /// Fine steps per coarse step (temporal downsampling factor).
    pub t_ratio: usize,
    /// Number of coarse-aligned reference frames to generate.
    pub n_frames: usize,
    /// Warm-up fine steps before recording (let the flow develop).
    pub fine_warmup: usize,
    /// Unroll lengths of the curriculum (e.g. [4, 8] — paper: 4→8→16).
    pub curriculum: Vec<usize>,
    /// Optimizer steps per curriculum stage.
    pub opt_steps_per_stage: usize,
    pub lr: f64,
    /// Gradient paths for backprop through the solver (the paper's base
    /// trainings use the cheap `none` variant, fine-tunings add Adv).
    pub paths: GradientPaths,
    /// λ for the divergence gradient modification (eq. 11); 0 = off.
    pub lambda_div: f64,
    /// Output scale applied to the raw network output (keeps early-training
    /// corrections small relative to the dynamics; the paper clamps the
    /// forcing instead).
    pub output_scale: f64,
    pub seed: u64,
}

impl Default for Corrector2dCfg {
    fn default() -> Self {
        Corrector2dCfg {
            t_ratio: 2,
            n_frames: 60,
            fine_warmup: 80,
            curriculum: vec![2, 4],
            opt_steps_per_stage: 40,
            lr: 3e-3,
            paths: GradientPaths::NONE,
            lambda_div: 1e-3,
            output_scale: 0.05,
            seed: 0xC0DE,
        }
    }
}

pub struct Corrector2dResult {
    pub net: Cnn,
    pub train_losses: Vec<f64>,
    /// (step, mse_no_model, mse_nn, corr_no_model, corr_nn) at checkpoints.
    pub eval: Vec<(usize, f64, f64, f64, f64)>,
}

/// Reference frames: run the fine solver and resample every `t_ratio`-th
/// state onto the coarse mesh.
pub fn make_reference_frames(
    fine: &mut PisoSolver,
    fine_state: &mut State,
    coarse_mesh: &Mesh,
    cfg: &Corrector2dCfg,
) -> Vec<VectorField> {
    let src = VectorField::zeros(fine.mesh.ncells);
    fine.run(fine_state, &src, cfg.fine_warmup);
    let mut frames = Vec::with_capacity(cfg.n_frames);
    for _ in 0..cfg.n_frames {
        let mut coarse_u = VectorField::zeros(coarse_mesh.ncells);
        for c in 0..2 {
            coarse_u.comp[c] =
                field::resample(&fine.mesh, &fine_state.u.comp[c], coarse_mesh);
        }
        frames.push(coarse_u);
        fine.run(fine_state, &src, cfg.t_ratio);
    }
    frames
}

/// Default corrector architecture (scaled-down version of the paper's
/// 7-layer net; kernel radii 1 keep the conv tables small).
pub fn corrector_net(mesh: &Mesh, seed: u64) -> Cnn {
    Cnn::new(
        mesh,
        2,
        vec![
            LayerCfg { cout: 12, radius: 2, relu: true },
            LayerCfg { cout: 16, radius: 1, relu: true },
            LayerCfg { cout: 8, radius: 1, relu: true },
            LayerCfg { cout: 2, radius: 0, relu: false },
        ],
        seed,
    )
}

fn net_input(u: &VectorField) -> Vec<Vec<f64>> {
    vec![u.comp[0].clone(), u.comp[1].clone()]
}

/// One unrolled training episode: returns (loss, dparams).
#[allow(clippy::too_many_arguments)]
fn episode(
    solver: &mut PisoSolver,
    net: &Cnn,
    frames: &[VectorField],
    start: usize,
    unroll: usize,
    paths: GradientPaths,
    lambda_div: f64,
    output_scale: f64,
) -> (f64, Vec<f64>) {
    let ncells = solver.mesh.ncells;
    let mut state = State::zeros(&solver.mesh);
    state.u = frames[start].clone();

    // forward: record solver tapes + CNN tapes
    let mut recs = Vec::with_capacity(unroll);
    let mut net_ins = Vec::with_capacity(unroll);
    let mut net_tapes = Vec::with_capacity(unroll);
    let mut sources = Vec::with_capacity(unroll);
    let mut states = vec![state.clone()];
    for _ in 0..unroll {
        let input = net_input(&state.u);
        let (out, tape) = net.forward(&input);
        let mut src = VectorField::zeros(ncells);
        for c in 0..2 {
            src.comp[c] = out[c].iter().map(|v| output_scale * v).collect();
        }
        let mut rec = empty_record();
        solver.step(&mut state, &src, Some(&mut rec));
        recs.push(rec);
        net_ins.push(input);
        net_tapes.push(tape);
        sources.push(src);
        states.push(state.clone());
    }

    // losses on every step vs the aligned reference frame
    let mut total_loss = 0.0;
    let mut dparams = vec![0.0; net.nparams()];
    let mut du = VectorField::zeros(ncells);
    let mut dp = vec![0.0; ncells];
    for t in (0..unroll).rev() {
        let (l, mut cot) = mse_loss_grad(2, &states[t + 1].u, &frames[start + t + 1]);
        total_loss += l;
        cot.axpy(1.0, &du);
        let g = backward_step(solver, &recs[t], &cot, &dp, paths);
        // source gradient → CNN (with optional divergence modification)
        let ds = if lambda_div > 0.0 {
            crate::train::div_gradient_modification(
                &solver.ctx,
                &solver.mesh,
                &sources[t],
                &g.dsource,
                lambda_div,
            )
        } else {
            g.dsource.clone()
        };
        let dout: Vec<Vec<f64>> = (0..2)
            .map(|c| ds.comp[c].iter().map(|v| output_scale * v).collect())
            .collect();
        let (dpar, dins) = net.backward(&net_ins[t], &net_tapes[t], &dout);
        for (a, b) in dparams.iter_mut().zip(&dpar) {
            *a += b;
        }
        // state gradient: solver path + network-input path
        du = g.du_n;
        for c in 0..2 {
            for i in 0..ncells {
                du.comp[c][i] += dins[c][i];
            }
        }
        dp = g.dp_in;
    }
    (total_loss / unroll as f64, dparams)
}

/// Train a corrector on pre-generated reference frames.
pub fn train_corrector2d(
    solver: &mut PisoSolver,
    frames: &[VectorField],
    cfg: &Corrector2dCfg,
) -> (Cnn, Vec<f64>) {
    let mut net = corrector_net(&solver.mesh, cfg.seed);
    let mut opt = Adam::new(cfg.lr, net.nparams());
    let mut rng = Rng::new(cfg.seed ^ 0x55);
    let mut losses = Vec::new();
    for &unroll in &cfg.curriculum {
        for _ in 0..cfg.opt_steps_per_stage {
            let start = rng.below(frames.len().saturating_sub(unroll + 1));
            let (loss, dparams) = episode(
                solver, &net, frames, start, unroll, cfg.paths, cfg.lambda_div,
                cfg.output_scale,
            );
            let mut params = std::mem::take(&mut net.params);
            opt.step(&mut params, &dparams);
            net.params = params;
            losses.push(loss);
        }
    }
    (net, losses)
}

/// Vorticity ω = ∂v/∂x − ∂u/∂y of a 2D field.
pub fn vorticity(mesh: &Mesh, u: &VectorField) -> Vec<f64> {
    let gu = fvm::pressure_gradient(mesh, &u.comp[0]);
    let gv = fvm::pressure_gradient(mesh, &u.comp[1]);
    (0..mesh.ncells).map(|i| gv.comp[0][i] - gu.comp[1][i]).collect()
}

/// Evaluate No-Model vs NN-corrected rollouts against the reference frames:
/// returns (frame index, mse_no_model, mse_nn, corr_no_model, corr_nn).
pub fn evaluate_corrector(
    solver: &mut PisoSolver,
    net: Option<&Cnn>,
    output_scale: f64,
    frames: &[VectorField],
    checkpoints: &[usize],
) -> Vec<(usize, f64, f64)> {
    let ncells = solver.mesh.ncells;
    let mut state = State::zeros(&solver.mesh);
    state.u = frames[0].clone();
    let mut out = Vec::new();
    let maxstep = *checkpoints.iter().max().unwrap_or(&0);
    for step in 1..=maxstep.min(frames.len() - 1) {
        let src = match net {
            Some(n) => {
                let (o, _) = n.forward(&net_input(&state.u));
                let mut s = VectorField::zeros(ncells);
                for c in 0..2 {
                    s.comp[c] = o[c].iter().map(|v| output_scale * v).collect();
                }
                s
            }
            None => VectorField::zeros(ncells),
        };
        solver.step(&mut state, &src, None);
        if checkpoints.contains(&step) {
            let (mse, _) = mse_loss_grad(2, &state.u, &frames[step]);
            let w_sim = vorticity(&solver.mesh, &state.u);
            let w_ref = vorticity(&solver.mesh, &frames[step]);
            let corr = util::correlation(&w_sim, &w_ref);
            out.push((step, mse, corr));
        }
    }
    out
}
