//! Sharded, resumable sweep execution (ROADMAP item 2).
//!
//! A [`SweepSpec`] fixes a scenario grid in canonical order plus the run
//! configuration (steps, pool width, forward vs gradient mode). The grid is
//! split into contiguous, near-equal shards by the same deterministic
//! [`partition`] the kernels use for rows; every invocation with the same
//! spec plans the same shards, which is what makes independent shard
//! invocations ("run shard 3 of 8 on this host") and resume possible.
//!
//! Each shard runs through the fault-isolated checked batch entry points
//! ([`BatchRunner::run_checked`] / [`BatchRunner::run_gradients_checked`]),
//! so one diverging scenario costs exactly its own slot of its own shard.
//! Shard results — complete per-scenario states, stats, and gradients,
//! serialized through the bit-exact [`Json`] float round-trip — are written
//! as one artifact per shard via [`write_json_atomic`] (temp file + atomic
//! rename): a crashed or interrupted sweep leaves either a valid complete
//! artifact or none, never a truncated one that reads as done.
//!
//! On re-invocation, [`run_shards`] validates each shard artifact (schema,
//! spec fingerprint, entry count and labels) and skips the valid ones;
//! missing, truncated, or mismatched artifacts are recomputed. [`merge`]
//! reloads all shards, reconstructs the full result list in grid order, and
//! reduces [`SharedGrads`] over that list with the same left fold a
//! single-process batch uses — so the merged result is bit-for-bit equal to
//! running the whole grid in one process at the same pool width.
//!
//! Within one invocation, shards are claimed off the pool's shared task
//! counter exactly like scenarios and kernel chunks are — the pool's
//! work-stealing lifted one level up — and the per-shard scenario batches
//! nest on the same workers.

use super::scenario::{
    cavity_reynolds_sweep, channel_nu_sweep, reduce_shared_refs, taylor_green_nu_sweep,
    BatchResult, BatchRunner, GradBatchResult, Scenario, ScenarioError, SharedGrads,
    TerminalKineticEnergy,
};
use crate::adjoint::{GradientPaths, TapeStrategy};
use crate::mesh::VectorField;
use crate::par::partition;
use crate::piso::{State, StepStats};
use crate::util::bench::write_json_atomic;
use crate::util::json::Json;
use std::io;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Artifact schema tag for per-shard documents.
const SHARD_SCHEMA: &str = "pict-sweep-shard-v1";
/// Artifact schema tag for the merged document. Merged documents exclude
/// wall-clock fields and any shard-count-dependent fields, so merging the
/// same grid from any shard count produces byte-identical files.
const MERGED_SCHEMA: &str = "pict-sweep-merged-v1";

/// A deterministic sweep plan: the full scenario grid in canonical order
/// plus everything that must match between the invocation that wrote a
/// shard artifact and the one that wants to reuse it.
pub struct SweepSpec {
    /// The scenario grid. Order is part of the contract: shard ranges and
    /// merge order index into it.
    pub scenarios: Vec<Box<dyn Scenario>>,
    /// Steps each scenario advances (forward) or records (gradient mode).
    pub steps: usize,
    /// Number of shards requested; the effective count is
    /// `shard_ranges().len()` (fewer when the grid is smaller).
    pub shards: usize,
    /// Pool width every shard runs at. Part of the fingerprint: results are
    /// deterministic *per width*, so artifacts from one width must not be
    /// merged as if produced at another.
    pub threads: usize,
    /// Gradient sweep: record + backward with the terminal-kinetic-energy
    /// probe loss (full tape, all gradient paths) instead of forward
    /// advancement.
    pub grad: bool,
}

impl SweepSpec {
    /// Contiguous, near-equal shard ranges over the grid — deterministic,
    /// and never more shards than scenarios.
    pub fn shard_ranges(&self) -> Vec<Range<usize>> {
        partition(self.scenarios.len(), self.shards.max(1))
    }

    /// FNV-1a over everything a shard artifact must agree on to be reused:
    /// schema, steps, shard/thread counts, mode, and every scenario label
    /// in order. Changing any of these invalidates existing artifacts.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        fnv(&mut h, SHARD_SCHEMA.as_bytes());
        for v in [
            self.steps as u64,
            self.shards as u64,
            self.threads as u64,
            u64::from(self.grad),
            self.scenarios.len() as u64,
        ] {
            fnv(&mut h, &v.to_le_bytes());
        }
        for s in &self.scenarios {
            fnv(&mut h, s.label().as_bytes());
            fnv(&mut h, &[0xff]); // label separator: ["ab","c"] != ["a","bc"]
        }
        h
    }
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    const P: u64 = 0x0100_0000_01b3;
    for &b in bytes {
        *h = (*h ^ u64::from(b)).wrapping_mul(P);
    }
}

/// Path of shard `s`'s artifact under the sweep directory.
pub fn shard_path(dir: &Path, s: usize) -> PathBuf {
    dir.join(format!("shard-{s:04}.json"))
}

/// Build the canonical sweep grid for a registry kind + parameter list (the
/// CLI's `--kind`/`--params` surface). Same arguments, same grid, same
/// order — the precondition for shard planning and resume across
/// invocations.
pub fn grid_for_kind(kind: &str, n: usize, params: &[f64]) -> Result<Vec<Box<dyn Scenario>>, String> {
    match kind {
        "cavity" => Ok(cavity_reynolds_sweep(n, params)),
        "taylor-green" => Ok(taylor_green_nu_sweep(n, params)),
        "channel" => Ok(channel_nu_sweep([n.max(2), n.max(2), n.max(2) / 2 + 1], params)),
        other => Err(format!(
            "unsupported sweep kind `{other}` (expected cavity | taylor-green | channel)"
        )),
    }
}

/// One scenario slot of a sweep: a completed forward result, a completed
/// gradient result, or the isolated failure that cost exactly this slot.
pub enum SweepEntry {
    Forward(BatchResult),
    Gradient(GradBatchResult),
    Failed { label: String, error: String },
}

impl SweepEntry {
    pub fn label(&self) -> &str {
        match self {
            SweepEntry::Forward(r) => &r.label,
            SweepEntry::Gradient(g) => &g.label,
            SweepEntry::Failed { label, .. } => label,
        }
    }
}

/// Validity of one shard artifact on disk.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardStatus {
    /// Parses, matches the spec fingerprint, and carries one entry per
    /// scenario of its range — safe to skip on resume.
    Valid,
    Missing,
    /// Present but unusable (truncated, wrong fingerprint/shape); the
    /// reason travels along for `pict sweep status`.
    Invalid(String),
}

/// Per-shard outcome of one [`run_shards`] invocation.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardOutcome {
    /// A valid artifact already existed; the shard was skipped (resume).
    Skipped,
    /// The shard was (re)computed and its artifact written; `failures`
    /// counts slots that came back [`SweepEntry::Failed`].
    Computed { failures: usize },
}

#[derive(Clone, Debug)]
pub struct ShardReport {
    pub shard: usize,
    pub outcome: ShardOutcome,
}

/// The fully merged sweep: every scenario slot in grid order, plus the
/// batch-reduced shared-parameter gradients for gradient sweeps.
pub struct MergedSweep {
    pub entries: Vec<SweepEntry>,
    /// [`reduce_shared_refs`] over the `Gradient` entries in grid order
    /// (gradient sweeps only; `None` in forward mode).
    pub shared: Option<SharedGrads>,
    /// Number of `Failed` slots across the whole grid.
    pub failures: usize,
}

/// Run (or resume) the sweep's shards under `dir`. With `only = Some(s)`
/// exactly shard `s` runs — the N-invocations-on-N-hosts mode; with `None`
/// all shards run, claimed off the pool's shared task counter so long and
/// short shards load-balance within this host. Shards whose artifact
/// validates against the spec are skipped ([`ShardOutcome::Skipped`]);
/// missing/invalid ones are computed and durably written.
pub fn run_shards(
    spec: &SweepSpec,
    dir: &Path,
    only: Option<usize>,
) -> io::Result<Vec<ShardReport>> {
    let ranges = spec.shard_ranges();
    let targets: Vec<usize> = match only {
        Some(s) => {
            if s >= ranges.len() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("shard {s} out of range: effective shard count is {}", ranges.len()),
                ));
            }
            vec![s]
        }
        None => (0..ranges.len()).collect(),
    };
    let fp = spec.fingerprint();
    let runner = BatchRunner::new(spec.steps).with_threads(spec.threads);
    let slots: Vec<Mutex<Option<io::Result<ShardReport>>>> =
        targets.iter().map(|_| Mutex::new(None)).collect();
    // shard-level tasks nest the per-scenario batch jobs on the same pool;
    // the artifact write keeps each shard's I/O inside its own task
    runner.ctx().run_tasks(targets.len(), |k| {
        let report = run_one_shard(spec, &runner, &ranges, fp, dir, targets[k]);
        *slots[k].lock().expect("shard slot mutex held once per task index") = Some(report);
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("shard slot mutex unpoisoned: shard bodies return Results")
                .expect("every claimed shard task fills its slot")
        })
        .collect()
}

/// Skip-or-compute one shard: reuse a valid artifact, otherwise run its
/// scenario range and durably write the result.
fn run_one_shard(
    spec: &SweepSpec,
    runner: &BatchRunner,
    ranges: &[Range<usize>],
    fp: u64,
    dir: &Path,
    s: usize,
) -> io::Result<ShardReport> {
    if validate_shard(spec, fp, dir, s) == ShardStatus::Valid {
        return Ok(ShardReport { shard: s, outcome: ShardOutcome::Skipped });
    }
    let entries = run_shard_entries(spec, runner, ranges[s].clone());
    let failures = entries.iter().filter(|e| matches!(e, SweepEntry::Failed { .. })).count();
    let doc = shard_json(spec, fp, s, ranges.len(), &ranges[s], &entries);
    write_json_atomic(&shard_path(dir, s), &doc)?;
    Ok(ShardReport { shard: s, outcome: ShardOutcome::Computed { failures } })
}

/// Execute one shard's scenario range through the checked batch drives.
fn run_shard_entries(spec: &SweepSpec, runner: &BatchRunner, range: Range<usize>) -> Vec<SweepEntry> {
    let indices: Vec<usize> = range.clone().collect();
    let subset = &spec.scenarios[range];
    let results: Vec<Result<SweepEntry, ScenarioError>> = if spec.grad {
        let loss = TerminalKineticEnergy { final_step: spec.steps.saturating_sub(1) };
        runner
            .run_gradients_checked(subset, TapeStrategy::Full, GradientPaths::FULL, &loss)
            .into_iter()
            .map(|r| r.map(SweepEntry::Gradient))
            .collect()
    } else {
        runner.run_checked(subset).into_iter().map(|r| r.map(SweepEntry::Forward)).collect()
    };
    results
        .into_iter()
        .zip(indices)
        .map(|(r, i)| match r {
            Ok(e) => e,
            // the planned label (not the error's) keys resume validation,
            // so a failed slot still lines up with the grid on reload
            Err(e) => SweepEntry::Failed {
                label: spec.scenarios[i].label(),
                error: e.to_string(),
            },
        })
        .collect()
}

/// Validate every shard artifact of the sweep (for `pict sweep status`).
pub fn sweep_status(spec: &SweepSpec, dir: &Path) -> Vec<(usize, ShardStatus)> {
    let fp = spec.fingerprint();
    (0..spec.shard_ranges().len()).map(|s| (s, validate_shard(spec, fp, dir, s))).collect()
}

fn validate_shard(spec: &SweepSpec, fp: u64, dir: &Path, s: usize) -> ShardStatus {
    let path = shard_path(dir, s);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return ShardStatus::Missing,
        Err(e) => return ShardStatus::Invalid(format!("unreadable: {e}")),
    };
    let doc = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => return ShardStatus::Invalid(format!("parse failed (truncated?): {e}")),
    };
    match shard_matches(spec, fp, s, &doc) {
        Ok(()) => ShardStatus::Valid,
        Err(why) => ShardStatus::Invalid(why),
    }
}

fn shard_matches(spec: &SweepSpec, fp: u64, s: usize, doc: &Json) -> Result<(), String> {
    if doc.get("schema").and_then(Json::as_str) != Some(SHARD_SCHEMA) {
        return Err("wrong or missing schema tag".to_string());
    }
    let want_fp = format!("{fp:016x}");
    if doc.get("fingerprint").and_then(Json::as_str) != Some(want_fp.as_str()) {
        return Err("fingerprint mismatch (different grid, steps, threads, shards, or mode)"
            .to_string());
    }
    if doc.get("shard").and_then(Json::as_f64) != Some(s as f64) {
        return Err("shard index mismatch".to_string());
    }
    let ranges = spec.shard_ranges();
    let range = &ranges[s];
    let entries = doc
        .get("scenarios")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing scenarios array".to_string())?;
    if entries.len() != range.len() {
        return Err(format!("expected {} scenario entries, found {}", range.len(), entries.len()));
    }
    for (e, i) in entries.iter().zip(range.clone()) {
        let want = spec.scenarios[i].label();
        if e.get("label").and_then(Json::as_str) != Some(want.as_str()) {
            return Err(format!("entry label mismatch at grid index {i}"));
        }
    }
    Ok(())
}

/// Load every shard artifact and fold the sweep back together in grid
/// order. `SharedGrads` are reduced over the reconstructed full result list
/// with the same left fold a single-process batch uses — summing per-shard
/// partial sums instead would change float association and break bit-for-bit
/// equality with the single-process run.
pub fn merge(spec: &SweepSpec, dir: &Path) -> Result<MergedSweep, String> {
    let ranges = spec.shard_ranges();
    let fp = spec.fingerprint();
    let mut entries: Vec<SweepEntry> = Vec::with_capacity(spec.scenarios.len());
    for s in 0..ranges.len() {
        match validate_shard(spec, fp, dir, s) {
            ShardStatus::Valid => {}
            ShardStatus::Missing => {
                return Err(format!("shard {s} artifact missing — run `pict sweep run` first"));
            }
            ShardStatus::Invalid(why) => {
                return Err(format!("shard {s} artifact invalid ({why}) — re-run to recompute"));
            }
        }
        let path = shard_path(dir, s);
        let text = std::fs::read_to_string(&path).map_err(|e| format!("shard {s}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("shard {s}: {e}"))?;
        let slots = doc
            .get("scenarios")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("shard {s}: missing scenarios array"))?;
        for slot in slots {
            entries.push(entry_from_json(slot, spec.grad).map_err(|e| format!("shard {s}: {e}"))?);
        }
    }
    let failures = entries.iter().filter(|e| matches!(e, SweepEntry::Failed { .. })).count();
    let shared = if spec.grad {
        let ok: Vec<&GradBatchResult> = entries
            .iter()
            .filter_map(|e| match e {
                SweepEntry::Gradient(g) => Some(g),
                _ => None,
            })
            .collect();
        Some(reduce_shared_refs(&ok))
    } else {
        None
    };
    Ok(MergedSweep { entries, shared, failures })
}

/// Durably write the merged sweep. The document is deterministic by
/// construction — wall-clock and shard-count-dependent fields are excluded —
/// so the CI resume job can byte-compare merged files across shard counts.
pub fn write_merged(spec: &SweepSpec, merged: &MergedSweep, path: &Path) -> io::Result<()> {
    let mut fields = vec![
        ("schema", Json::Str(MERGED_SCHEMA.to_string())),
        ("mode", Json::Str(mode_tag(spec.grad).to_string())),
        ("steps", Json::Num(spec.steps as f64)),
        ("threads", Json::Num(spec.threads as f64)),
        ("n_scenarios", Json::Num(merged.entries.len() as f64)),
        ("failures", Json::Num(merged.failures as f64)),
        (
            "scenarios",
            Json::Arr(merged.entries.iter().map(|e| entry_json(e, false)).collect()),
        ),
    ];
    if let Some(shared) = &merged.shared {
        fields.push(("shared", shared_to_json(shared)));
    }
    write_json_atomic(path, &Json::obj(fields))
}

fn mode_tag(grad: bool) -> &'static str {
    if grad {
        "gradient"
    } else {
        "forward"
    }
}

fn shard_json(
    spec: &SweepSpec,
    fp: u64,
    s: usize,
    nshards: usize,
    range: &Range<usize>,
    entries: &[SweepEntry],
) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(SHARD_SCHEMA.to_string())),
        ("fingerprint", Json::Str(format!("{fp:016x}"))),
        ("shard", Json::Num(s as f64)),
        ("shards", Json::Num(nshards as f64)),
        ("start", Json::Num(range.start as f64)),
        ("end", Json::Num(range.end as f64)),
        ("steps", Json::Num(spec.steps as f64)),
        ("threads", Json::Num(spec.threads as f64)),
        ("mode", Json::Str(mode_tag(spec.grad).to_string())),
        ("scenarios", Json::Arr(entries.iter().map(|e| entry_json(e, true)).collect())),
    ])
}

// ---- per-entry serialization --------------------------------------------
//
// Shard artifacts carry complete per-scenario results (full states and
// gradients, not summaries): merge must be able to reconstruct exactly what
// a single-process batch would have returned. `with_wall` distinguishes the
// per-shard artifact (keeps wall_s for diagnostics) from the merged
// document (drops it for byte-determinism).

fn entry_json(e: &SweepEntry, with_wall: bool) -> Json {
    match e {
        SweepEntry::Forward(r) => {
            let mut fields = vec![
                ("label", Json::Str(r.label.clone())),
                ("ok", Json::Bool(true)),
                ("steps", Json::Num(r.steps as f64)),
                ("adv_iters", Json::Num(r.adv_iters as f64)),
                ("p_iters", Json::Num(r.p_iters as f64)),
                ("adv_residual", Json::Num(r.adv_residual)),
                ("p_residual", Json::Num(r.p_residual)),
                ("max_divergence", Json::Num(r.max_divergence)),
                ("last", stats_to_json(&r.last)),
                ("state", state_to_json(&r.state)),
            ];
            if with_wall {
                fields.push(("wall_s", Json::Num(r.wall_s)));
            }
            Json::obj(fields)
        }
        SweepEntry::Gradient(g) => {
            let mut fields = vec![
                ("label", Json::Str(g.label.clone())),
                ("ok", Json::Bool(true)),
                ("loss", Json::Num(g.loss)),
                ("mesh_fp", Json::Str(format!("{:016x}", g.mesh_fp))),
                ("peak_resident_f64", Json::Num(g.peak_resident_f64 as f64)),
                ("state", state_to_json(&g.state)),
                ("grads", grads_to_json(&g.grads)),
            ];
            if with_wall {
                fields.push(("wall_s", Json::Num(g.wall_s)));
            }
            Json::obj(fields)
        }
        SweepEntry::Failed { label, error } => Json::obj(vec![
            ("label", Json::Str(label.clone())),
            ("ok", Json::Bool(false)),
            ("error", Json::Str(error.clone())),
        ]),
    }
}

fn entry_from_json(j: &Json, grad: bool) -> Result<SweepEntry, String> {
    let label = j
        .get("label")
        .and_then(Json::as_str)
        .ok_or_else(|| "entry missing label".to_string())?
        .to_string();
    if j.get("ok") != Some(&Json::Bool(true)) {
        let error =
            j.get("error").and_then(Json::as_str).unwrap_or("unrecorded failure").to_string();
        return Ok(SweepEntry::Failed { label, error });
    }
    let state = state_from_json(j.get("state").ok_or_else(|| "entry missing state".to_string())?)?;
    let wall_s = j.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0);
    if grad {
        let mesh_fp_hex = j
            .get("mesh_fp")
            .and_then(Json::as_str)
            .ok_or_else(|| "entry missing mesh_fp".to_string())?;
        Ok(SweepEntry::Gradient(GradBatchResult {
            label,
            state,
            loss: f64_field(j, "loss")?,
            grads: grads_from_json(
                j.get("grads").ok_or_else(|| "entry missing grads".to_string())?,
            )?,
            mesh_fp: u64::from_str_radix(mesh_fp_hex, 16)
                .map_err(|e| format!("bad mesh_fp `{mesh_fp_hex}`: {e}"))?,
            peak_resident_f64: usize_field(j, "peak_resident_f64")?,
            wall_s,
        }))
    } else {
        Ok(SweepEntry::Forward(BatchResult {
            label,
            state,
            steps: usize_field(j, "steps")?,
            adv_iters: usize_field(j, "adv_iters")?,
            p_iters: usize_field(j, "p_iters")?,
            adv_residual: f64_field(j, "adv_residual")?,
            p_residual: f64_field(j, "p_residual")?,
            max_divergence: f64_field(j, "max_divergence")?,
            last: stats_from_json(j.get("last").ok_or_else(|| "entry missing last".to_string())?)?,
            wall_s,
        }))
    }
}

fn f64_field(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing numeric field {key}"))
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    Ok(f64_field(j, key)? as usize)
}

fn state_to_json(s: &State) -> Json {
    Json::obj(vec![
        ("step", Json::Num(s.step as f64)),
        ("time", Json::Num(s.time)),
        ("u", field_to_json(&s.u)),
        ("p", Json::arr_f64(&s.p)),
    ])
}

fn state_from_json(j: &Json) -> Result<State, String> {
    Ok(State {
        u: field_from_json(j.get("u").ok_or_else(|| "state missing u".to_string())?)?,
        p: f64s_from_json(j.get("p").ok_or_else(|| "state missing p".to_string())?)?,
        time: f64_field(j, "time")?,
        step: usize_field(j, "step")?,
    })
}

fn stats_to_json(st: &StepStats) -> Json {
    Json::obj(vec![
        ("dt", Json::Num(st.dt)),
        ("adv_iters", Json::Num(st.adv_iters as f64)),
        ("p_iters", Json::Num(st.p_iters as f64)),
        ("adv_residual", Json::Num(st.adv_residual)),
        ("p_residual", Json::Num(st.p_residual)),
        ("max_divergence", Json::Num(st.max_divergence)),
    ])
}

fn stats_from_json(j: &Json) -> Result<StepStats, String> {
    Ok(StepStats {
        dt: f64_field(j, "dt")?,
        adv_iters: usize_field(j, "adv_iters")?,
        p_iters: usize_field(j, "p_iters")?,
        adv_residual: f64_field(j, "adv_residual")?,
        p_residual: f64_field(j, "p_residual")?,
        max_divergence: f64_field(j, "max_divergence")?,
    })
}

fn grads_to_json(g: &crate::adjoint::RolloutGrads) -> Json {
    Json::obj(vec![
        ("dnu", Json::Num(g.dnu)),
        ("du0", field_to_json(&g.du0)),
        ("dp0", Json::arr_f64(&g.dp0)),
        ("dsource", Json::Arr(g.dsource.iter().map(field_to_json).collect())),
        (
            "dbc",
            Json::Arr(
                g.dbc
                    .iter()
                    .map(|patch| Json::Arr(patch.iter().map(|v| Json::arr_f64(&v[..])).collect()))
                    .collect(),
            ),
        ),
    ])
}

fn grads_from_json(j: &Json) -> Result<crate::adjoint::RolloutGrads, String> {
    let dsource = j
        .get("dsource")
        .and_then(Json::as_arr)
        .ok_or_else(|| "grads missing dsource".to_string())?
        .iter()
        .map(field_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let mut dbc = Vec::new();
    for patch in j
        .get("dbc")
        .and_then(Json::as_arr)
        .ok_or_else(|| "grads missing dbc array".to_string())?
    {
        let rows = patch.as_arr().ok_or_else(|| "dbc patch must be an array".to_string())?;
        let mut out = Vec::with_capacity(rows.len());
        for row in rows {
            let v = f64s_from_json(row)?;
            if v.len() != 3 {
                return Err("dbc row must have 3 components".to_string());
            }
            out.push([v[0], v[1], v[2]]);
        }
        dbc.push(out);
    }
    Ok(crate::adjoint::RolloutGrads {
        du0: field_from_json(j.get("du0").ok_or_else(|| "grads missing du0".to_string())?)?,
        dp0: f64s_from_json(j.get("dp0").ok_or_else(|| "grads missing dp0".to_string())?)?,
        dsource,
        dnu: f64_field(j, "dnu")?,
        dbc,
    })
}

fn shared_to_json(s: &SharedGrads) -> Json {
    let mut fields = vec![("dnu", Json::Num(s.dnu))];
    if let Some(du0) = &s.du0 {
        fields.push(("du0", field_to_json(du0)));
    }
    if let Some(ds) = &s.dsource {
        fields.push(("dsource", Json::Arr(ds.iter().map(field_to_json).collect())));
    }
    Json::obj(fields)
}

fn field_to_json(f: &VectorField) -> Json {
    Json::Arr(f.comp.iter().map(|c| Json::arr_f64(&c[..])).collect())
}

fn field_from_json(j: &Json) -> Result<VectorField, String> {
    let comps = j
        .as_arr()
        .ok_or_else(|| "vector field must be an array of 3 component arrays".to_string())?;
    if comps.len() != 3 {
        return Err(format!("vector field has {} components, expected 3", comps.len()));
    }
    let mut out = VectorField { comp: [Vec::new(), Vec::new(), Vec::new()] };
    for (c, comp) in comps.iter().enumerate() {
        out.comp[c] = f64s_from_json(comp)?;
    }
    if out.comp[1].len() != out.comp[0].len() || out.comp[2].len() != out.comp[0].len() {
        return Err("vector field component lengths differ".to_string());
    }
    Ok(out)
}

fn f64s_from_json(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or_else(|| "expected an array of numbers".to_string())?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "non-numeric array entry".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::TaylorGreen;

    fn spec_of(nus: &[f64], shards: usize) -> SweepSpec {
        SweepSpec {
            scenarios: taylor_green_nu_sweep(8, nus),
            steps: 2,
            shards,
            threads: 2,
            grad: false,
        }
    }

    #[test]
    fn shard_ranges_cover_the_grid_exactly_once() {
        let spec = spec_of(&[0.01, 0.02, 0.03, 0.04, 0.05], 3);
        let ranges = spec.shard_ranges();
        assert_eq!(ranges.len(), 3);
        let mut covered = Vec::new();
        for r in &ranges {
            covered.extend(r.clone());
        }
        assert_eq!(covered, vec![0, 1, 2, 3, 4]);
        // more shards than scenarios degrades to one scenario per shard
        assert_eq!(spec_of(&[0.01, 0.02], 8).shard_ranges().len(), 2);
    }

    #[test]
    fn fingerprint_tracks_everything_resume_depends_on() {
        let base = spec_of(&[0.01, 0.02], 2);
        let fp = base.fingerprint();
        let mut other = spec_of(&[0.01, 0.02], 2);
        assert_eq!(fp, other.fingerprint(), "same spec must fingerprint identically");
        other.steps = 3;
        assert_ne!(fp, other.fingerprint(), "steps must invalidate artifacts");
        other.steps = 2;
        other.threads = 4;
        assert_ne!(fp, other.fingerprint(), "pool width must invalidate artifacts");
        other.threads = 2;
        other.grad = true;
        assert_ne!(fp, other.fingerprint(), "mode must invalidate artifacts");
        assert_ne!(
            fp,
            spec_of(&[0.01, 0.03], 2).fingerprint(),
            "grid labels must invalidate artifacts"
        );
    }

    #[test]
    fn forward_entry_round_trips_bit_for_bit() {
        let run = TaylorGreen { n: 4, ..Default::default() }.build();
        let mut state = run.state;
        // awkward values: negative zero, thirds, subnormal, large magnitude
        state.u.comp[0][0] = -0.0;
        state.u.comp[1][1] = 1.0 / 3.0;
        state.p[0] = 5e-324;
        state.p[1] = -1.234567890123456e300;
        state.time = 0.30000000000000004;
        state.step = 7;
        let entry = SweepEntry::Forward(BatchResult {
            label: "round-trip".to_string(),
            state,
            steps: 7,
            adv_iters: 21,
            p_iters: 34,
            adv_residual: 1.0e-9 / 3.0,
            p_residual: 2.5e-11,
            max_divergence: 7.7e-13,
            last: StepStats { dt: 0.01, adv_iters: 3, p_iters: 5, ..Default::default() },
            wall_s: 0.125,
        });
        let text = entry_json(&entry, true).to_string_pretty();
        let back = entry_from_json(&Json::parse(&text).expect("artifact text parses"), false)
            .expect("entry deserializes");
        let (orig, back) = match (&entry, &back) {
            (SweepEntry::Forward(a), SweepEntry::Forward(b)) => (a, b),
            _ => panic!("round trip changed the entry kind"),
        };
        assert_eq!(orig.label, back.label);
        assert_eq!(orig.state.u, back.state.u, "velocity must survive bit-for-bit");
        for (a, b) in orig.state.p.iter().zip(&back.state.p) {
            assert_eq!(a.to_bits(), b.to_bits(), "pressure must survive bit-for-bit");
        }
        assert_eq!(orig.state.time.to_bits(), back.state.time.to_bits());
        assert_eq!(orig.state.step, back.state.step);
        assert_eq!(orig.adv_iters, back.adv_iters);
        assert_eq!(orig.adv_residual.to_bits(), back.adv_residual.to_bits());
        assert_eq!(orig.last.dt.to_bits(), back.last.dt.to_bits());
        assert_eq!(orig.wall_s.to_bits(), back.wall_s.to_bits());
    }

    #[test]
    fn failed_entry_round_trips_label_and_error() {
        let entry = SweepEntry::Failed {
            label: "cavity 8x8 Re=1e9".to_string(),
            error: "cavity 8x8 Re=1e9: non-finite divergence at step 3".to_string(),
        };
        let text = entry_json(&entry, true).to_string_pretty();
        match entry_from_json(&Json::parse(&text).expect("artifact text parses"), false)
            .expect("failed entry deserializes")
        {
            SweepEntry::Failed { label, error } => {
                assert_eq!(label, "cavity 8x8 Re=1e9");
                assert!(error.contains("non-finite divergence"), "{error}");
            }
            _ => panic!("failed entry must stay failed"),
        }
    }

    #[test]
    fn grid_for_kind_rejects_unknown_kinds() {
        assert!(grid_for_kind("cavity", 8, &[100.0, 200.0]).is_ok());
        assert!(grid_for_kind("taylor-green", 8, &[0.01]).is_ok());
        let err = grid_for_kind("warp-drive", 8, &[1.0]).expect_err("unknown kind must error");
        assert!(err.contains("warp-drive"), "{err}");
    }
}
