//! Turbulent-energy-budget terms (paper §2.5, figure 12): production,
//! dissipation, turbulent transport, viscous diffusion, and the
//! velocity–pressure-gradient term, accumulated online per wall-normal
//! layer against a frozen mean profile (two-pass: means first, then
//! budgets — the standard a-posteriori evaluation).

use crate::fvm;
use crate::mesh::{Mesh, VectorField};

/// Per-layer budget terms for the streamwise normal stress (i=j=0) unless
/// noted; `k_*` entries are for the turbulent kinetic energy (half-trace).
#[derive(Clone, Debug)]
pub struct Budgets {
    pub y: Vec<f64>,
    pub production: Vec<f64>,
    pub dissipation: Vec<f64>,
    pub transport: Vec<f64>,
    pub visc_diffusion: Vec<f64>,
    pub pressure_term: Vec<f64>,
    frames: usize,
    /// accumulated ⟨u'u'v'⟩ per layer (for transport, differenced at the end)
    acc_uuv: Vec<f64>,
    /// accumulated ⟨u'u'⟩ per layer (for viscous diffusion)
    acc_uu: Vec<f64>,
    nu: f64,
}

impl Budgets {
    pub fn new(mesh: &Mesh, nu: f64) -> Budgets {
        let b = &mesh.blocks[0];
        let ny = b.shape[1];
        let y = (0..ny).map(|j| b.centers[b.lidx(0, j, 0)][1]).collect();
        Budgets {
            y,
            production: vec![0.0; ny],
            dissipation: vec![0.0; ny],
            transport: vec![0.0; ny],
            visc_diffusion: vec![0.0; ny],
            pressure_term: vec![0.0; ny],
            frames: 0,
            acc_uuv: vec![0.0; ny],
            acc_uu: vec![0.0; ny],
            nu,
        }
    }

    /// Accumulate one frame against the frozen mean profile `u_mean(y)`
    /// (streamwise component per layer) and its wall-normal derivative.
    pub fn push(&mut self, mesh: &Mesh, u: &VectorField, p: &[f64], u_mean: &[f64]) {
        let b = &mesh.blocks[0];
        let (nx, ny, nz) = (b.shape[0], b.shape[1], b.shape[2]);
        let nh = (nx * nz) as f64;
        self.frames += 1;
        // dŪ/dy per layer (central differences on the profile)
        let dumean: Vec<f64> = (0..ny)
            .map(|j| {
                let jm = j.saturating_sub(1);
                let jp = (j + 1).min(ny - 1);
                (u_mean[jp] - u_mean[jm]) / (self.y[jp] - self.y[jm]).max(1e-300)
            })
            .collect();
        // fluctuation fields
        let mut uf = u.clone();
        for k in 0..nz {
            for j in 0..ny {
                for i in 0..nx {
                    let cell = b.offset + b.lidx(i, j, k);
                    uf.comp[0][cell] -= u_mean[j];
                }
            }
        }
        // gradients of the fluctuating components and pressure
        let gu: Vec<VectorField> =
            (0..3).map(|c| fvm::pressure_gradient(mesh, &uf.comp[c])).collect();
        let gp = fvm::pressure_gradient(mesh, p);
        let inv_n = 1.0 / self.frames as f64;
        for j in 0..ny {
            let mut prod = 0.0;
            let mut diss = 0.0;
            let mut uuv = 0.0;
            let mut uu = 0.0;
            let mut press = 0.0;
            for k in 0..nz {
                for i in 0..nx {
                    let cell = b.offset + b.lidx(i, j, k);
                    let up = uf.comp[0][cell];
                    let vp = uf.comp[1][cell];
                    // P_00 = −2 ⟨u'v'⟩ dŪ/dy
                    prod += -2.0 * up * vp * dumean[j] / nh;
                    // ε_00 = 2ν ⟨(∂u'/∂x_k)²⟩
                    let mut g2 = 0.0;
                    for kk in 0..mesh.dim {
                        g2 += gu[0].comp[kk][cell] * gu[0].comp[kk][cell];
                    }
                    diss += 2.0 * self.nu * g2 / nh;
                    // transport: −∂⟨u'u'v'⟩/∂y, accumulated then differenced
                    uuv += up * up * vp / nh;
                    uu += up * up / nh;
                    // Π_00 = −2 ⟨u' ∂p/∂x⟩
                    press += -2.0 * up * gp.comp[0][cell] / nh;
                }
            }
            // running averages
            self.production[j] += (prod - self.production[j]) * inv_n;
            self.dissipation[j] += (diss - self.dissipation[j]) * inv_n;
            self.pressure_term[j] += (press - self.pressure_term[j]) * inv_n;
            self.acc_uuv[j] += (uuv - self.acc_uuv[j]) * inv_n;
            self.acc_uu[j] += (uu - self.acc_uu[j]) * inv_n;
        }
        // final differenced terms
        let ny1 = ny;
        for j in 0..ny1 {
            let jm = j.saturating_sub(1);
            let jp = (j + 1).min(ny1 - 1);
            let dy = (self.y[jp] - self.y[jm]).max(1e-300);
            self.transport[j] = -(self.acc_uuv[jp] - self.acc_uuv[jm]) / dy;
            // ν d²⟨u'u'⟩/dy² via second difference of the profile
            if j > 0 && j + 1 < ny1 {
                let d1 = (self.acc_uu[j + 1] - self.acc_uu[j])
                    / (self.y[j + 1] - self.y[j]).max(1e-300);
                let d0 =
                    (self.acc_uu[j] - self.acc_uu[j - 1]) / (self.y[j] - self.y[j - 1]).max(1e-300);
                self.visc_diffusion[j] =
                    self.nu * (d1 - d0) / (0.5 * (self.y[j + 1] - self.y[j - 1])).max(1e-300);
            }
        }
    }
}

/// Convenience: run means + budgets over a recorded set of frames.
pub fn energy_budgets(
    mesh: &Mesh,
    frames: &[(VectorField, Vec<f64>)],
    nu: f64,
) -> Budgets {
    // pass 1: mean streamwise profile
    let b = &mesh.blocks[0];
    let ny = b.shape[1];
    let mut mean = vec![0.0; ny];
    for (u, _) in frames {
        let prof = super::profiles::channel_profiles(mesh, u);
        for j in 0..ny {
            mean[j] += prof.mean[0][j] / frames.len() as f64;
        }
    }
    // pass 2: budgets
    let mut budgets = Budgets::new(mesh, nu);
    for (u, p) in frames {
        budgets.push(mesh, u, p, &mean);
    }
    budgets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::util::rng::Rng;

    /// Production of a synthetic field with known ⟨u'v'⟩ and shear matches
    /// −2⟨u'v'⟩ dŪ/dy.
    #[test]
    fn production_of_synthetic_shear() {
        let mesh = gen::channel3d([24, 6, 24], [2.0, 2.0, 1.0], 1.0);
        let mut rng = Rng::new(7);
        let shear = 1.5;
        let mut frames = Vec::new();
        for _ in 0..8 {
            let mut u = VectorField::zeros(mesh.ncells);
            for (cell, c) in mesh.centers.iter().enumerate() {
                let a = rng.normal();
                u.comp[0][cell] = shear * c[1] + 0.3 * a; // u' = 0.3a
                u.comp[1][cell] = 0.2 * a + 0.1 * rng.normal(); // corr(u',v') > 0
            }
            frames.push((u, vec![0.0; mesh.ncells]));
        }
        let budgets = energy_budgets(&mesh, &frames, 0.01);
        // ⟨u'v'⟩ = 0.3·0.2 = 0.06 ⇒ P_00 ≈ −2·0.06·1.5 = −0.18
        for j in 1..5 {
            assert!(
                (budgets.production[j] + 0.18).abs() < 0.05,
                "P[{j}] = {}",
                budgets.production[j]
            );
        }
    }

    /// Dissipation is non-negative and zero for a uniform field.
    #[test]
    fn dissipation_sign_and_zero_case() {
        let mesh = gen::channel3d([8, 4, 8], [1.0, 2.0, 1.0], 1.0);
        let u = VectorField::zeros(mesh.ncells);
        let frames = vec![(u, vec![0.0; mesh.ncells])];
        let budgets = energy_budgets(&mesh, &frames, 0.01);
        for j in 0..4 {
            assert!(budgets.dissipation[j].abs() < 1e-14);
        }
    }
}
