//! Wall-normal channel-flow statistics: per-frame profiles (differentiable
//! inputs to the statistics losses, §3.2) and long-run accumulation over
//! time + homogeneous directions (figures 4/11).

use super::moments::{CoMoments, OnlineMoments};
use crate::mesh::{Mesh, VectorField};

/// Instantaneous profiles of one frame: mean velocity and second-order
/// (co)moments per wall-normal layer, averaged over homogeneous directions.
#[derive(Clone, Debug)]
pub struct WallProfiles {
    /// Wall-normal coordinate of each layer (cell centers).
    pub y: Vec<f64>,
    /// Mean velocity per layer: `mean[i][j_layer]`, i in 0..3.
    pub mean: [Vec<f64>; 3],
    /// Reynolds stresses u_i'u_j' per layer for pairs
    /// (0,0), (1,1), (2,2), (0,1) in that order.
    pub stress: [Vec<f64>; 4],
}

pub const STRESS_PAIRS: [(usize, usize); 4] = [(0, 0), (1, 1), (2, 2), (0, 1)];

/// Compute instantaneous wall-normal profiles on a single-block channel mesh
/// (wall-normal = axis 1, homogeneous = axes 0 and 2).
pub fn channel_profiles(mesh: &Mesh, u: &VectorField) -> WallProfiles {
    assert_eq!(mesh.blocks.len(), 1, "channel statistics expect a single block");
    let b = &mesh.blocks[0];
    let (nx, ny, nz) = (b.shape[0], b.shape[1], b.shape[2]);
    let nh = (nx * nz) as f64;
    let mut y = vec![0.0; ny];
    let mut mean: [Vec<f64>; 3] = [vec![0.0; ny], vec![0.0; ny], vec![0.0; ny]];
    let mut second: [Vec<f64>; 4] = [vec![0.0; ny], vec![0.0; ny], vec![0.0; ny], vec![0.0; ny]];
    for j in 0..ny {
        y[j] = b.centers[b.lidx(0, j, 0)][1];
        for k in 0..nz {
            for i in 0..nx {
                let cell = b.offset + b.lidx(i, j, k);
                let uv = u.get(cell);
                for c in 0..3 {
                    mean[c][j] += uv[c] / nh;
                }
                for (s, (a, bb)) in STRESS_PAIRS.iter().enumerate() {
                    second[s][j] += uv[*a] * uv[*bb] / nh;
                }
            }
        }
    }
    // central moments: ⟨u_a u_b⟩ − ⟨u_a⟩⟨u_b⟩
    let mut stress = second;
    for j in 0..ny {
        for (s, (a, bb)) in STRESS_PAIRS.iter().enumerate() {
            stress[s][j] -= mean[*a][j] * mean[*bb][j];
        }
    }
    WallProfiles { y, mean, stress }
}

/// Long-run accumulator over frames: per-layer online moments over all
/// (x, z, t) samples.
pub struct ChannelStats {
    pub y: Vec<f64>,
    pub u: Vec<OnlineMoments>,
    pub v: Vec<OnlineMoments>,
    pub w: Vec<OnlineMoments>,
    pub uv: Vec<CoMoments>,
    /// Running mean of the wall-shear velocity u_τ = √(ν |∂ū/∂y|_wall).
    pub u_tau_acc: OnlineMoments,
    nu: f64,
}

impl ChannelStats {
    pub fn new(mesh: &Mesh, nu: f64) -> ChannelStats {
        let b = &mesh.blocks[0];
        let ny = b.shape[1];
        let y = (0..ny).map(|j| b.centers[b.lidx(0, j, 0)][1]).collect();
        ChannelStats {
            y,
            u: vec![OnlineMoments::default(); ny],
            v: vec![OnlineMoments::default(); ny],
            w: vec![OnlineMoments::default(); ny],
            uv: vec![CoMoments::default(); ny],
            u_tau_acc: OnlineMoments::default(),
            nu,
        }
    }

    /// Push one frame.
    pub fn push(&mut self, mesh: &Mesh, u: &VectorField) {
        let b = &mesh.blocks[0];
        let (nx, ny, nz) = (b.shape[0], b.shape[1], b.shape[2]);
        for j in 0..ny {
            for k in 0..nz {
                for i in 0..nx {
                    let cell = b.offset + b.lidx(i, j, k);
                    let uv = u.get(cell);
                    self.u[j].push(uv[0]);
                    self.v[j].push(uv[1]);
                    self.w[j].push(uv[2]);
                    self.uv[j].push(uv[0], uv[1]);
                }
            }
        }
        // u_τ from both walls: one-sided dū/dy at first/last layer
        let prof = channel_profiles(mesh, u);
        let y0 = prof.y[0];
        let y1 = prof.y[ny - 1];
        let ly = y1 + y0; // walls at 0 and y1+y0 (symmetric grading)
        let dudy_lo = prof.mean[0][0] / y0;
        let dudy_hi = prof.mean[0][ny - 1] / (ly - y1);
        let u_tau = (self.nu * 0.5 * (dudy_lo.abs() + dudy_hi.abs())).sqrt();
        self.u_tau_acc.push(u_tau);
    }

    pub fn u_tau(&self) -> f64 {
        self.u_tau_acc.mean
    }

    /// Mean profiles and stresses: (ū, ⟨u'u'⟩, ⟨v'v'⟩, ⟨w'w'⟩, ⟨u'v'⟩).
    #[allow(clippy::type_complexity)]
    pub fn profiles(&self) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let ny = self.y.len();
        let mut um = vec![0.0; ny];
        let mut uu = vec![0.0; ny];
        let mut vv = vec![0.0; ny];
        let mut ww = vec![0.0; ny];
        let mut uv = vec![0.0; ny];
        for j in 0..ny {
            um[j] = self.u[j].mean;
            uu[j] = self.u[j].variance();
            vv[j] = self.v[j].variance();
            ww[j] = self.w[j].variance();
            uv[j] = self.uv[j].covariance();
        }
        (um, uu, vv, ww, uv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::gen;
    use crate::util::rng::Rng;

    #[test]
    fn profiles_of_uniform_shear() {
        let mesh = gen::channel3d([6, 8, 4], [2.0, 2.0, 1.0], 1.0);
        let mut u = VectorField::zeros(mesh.ncells);
        for (cell, c) in mesh.centers.iter().enumerate() {
            u.comp[0][cell] = 3.0 * c[1]; // pure shear, no fluctuations
        }
        let p = channel_profiles(&mesh, &u);
        for j in 0..8 {
            assert!((p.mean[0][j] - 3.0 * p.y[j]).abs() < 1e-12);
            for s in 0..4 {
                assert!(p.stress[s][j].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn stresses_capture_fluctuations() {
        let mesh = gen::channel3d([16, 4, 16], [2.0, 2.0, 1.0], 1.0);
        let mut rng = Rng::new(5);
        let mut u = VectorField::zeros(mesh.ncells);
        for cell in 0..mesh.ncells {
            u.comp[0][cell] = 1.0 + 0.5 * rng.normal();
            u.comp[1][cell] = 0.2 * rng.normal();
        }
        let p = channel_profiles(&mesh, &u);
        for j in 0..4 {
            assert!((p.stress[0][j] - 0.25).abs() < 0.06, "u'u' {}", p.stress[0][j]);
            assert!((p.stress[1][j] - 0.04).abs() < 0.02, "v'v' {}", p.stress[1][j]);
            assert!(p.stress[3][j].abs() < 0.05, "u'v' {}", p.stress[3][j]);
        }
    }

    #[test]
    fn accumulator_converges_over_frames() {
        let mesh = gen::channel3d([8, 4, 8], [1.0, 2.0, 1.0], 1.0);
        let mut stats = ChannelStats::new(&mesh, 0.01);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let mut u = VectorField::zeros(mesh.ncells);
            for cell in 0..mesh.ncells {
                u.comp[0][cell] = 2.0 + 0.3 * rng.normal();
            }
            stats.push(&mesh, &u);
        }
        let (um, uu, _, _, _) = stats.profiles();
        for j in 0..4 {
            assert!((um[j] - 2.0).abs() < 0.02);
            assert!((uu[j] - 0.09).abs() < 0.01);
        }
        assert!(stats.u_tau() > 0.0);
    }
}
