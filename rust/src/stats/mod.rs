//! Differentiable turbulence statistics (paper §2.5): online arbitrary-order
//! central (co)moments after Pébay et al., wall-normal profile averaging
//! over homogeneous directions, and the turbulent-energy-budget terms
//! (production, dissipation, turbulent transport, viscous diffusion,
//! velocity–pressure-gradient).

pub mod budgets;
pub mod moments;
pub mod profiles;

pub use budgets::{energy_budgets, Budgets};
pub use moments::{CoMoments, OnlineMoments};
pub use profiles::{channel_profiles, ChannelStats, WallProfiles};
