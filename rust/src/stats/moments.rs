//! Numerically stable online central moments (Pébay/Terriberry/Welford
//! update formulas, paper ref. \[55\]): accumulate mean and p-th order central
//! moments of streaming samples without storing the sequence, plus pairwise
//! co-moments (covariances) for the Reynolds-stress tensor.

/// Online accumulator of mean and central moments up to order 4 for one
/// scalar stream.
#[derive(Clone, Debug, Default)]
pub struct OnlineMoments {
    pub n: u64,
    pub mean: f64,
    /// Σ (x−mean)² … Σ (x−mean)⁴ (M2..M4 in Pébay's notation).
    pub m2: f64,
    pub m3: f64,
    pub m4: f64,
}

impl OnlineMoments {
    pub fn push(&mut self, x: f64) {
        let n1 = self.n as f64;
        self.n += 1;
        let n = self.n as f64;
        let delta = x - self.mean;
        let delta_n = delta / n;
        let delta_n2 = delta_n * delta_n;
        let term1 = delta * delta_n * n1;
        self.mean += delta_n;
        self.m4 += term1 * delta_n2 * (n * n - 3.0 * n + 3.0) + 6.0 * delta_n2 * self.m2
            - 4.0 * delta_n * self.m3;
        self.m3 += term1 * delta_n * (n - 2.0) - 3.0 * delta_n * self.m2;
        self.m2 += term1;
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn skewness(&self) -> f64 {
        let v = self.variance();
        if v <= 0.0 {
            0.0
        } else {
            (self.m3 / self.n as f64) / v.powf(1.5)
        }
    }

    pub fn kurtosis(&self) -> f64 {
        let v = self.variance();
        if v <= 0.0 {
            0.0
        } else {
            (self.m4 / self.n as f64) / (v * v)
        }
    }

    /// Merge two accumulators (parallel/pairwise combination).
    pub fn merge(&self, other: &OnlineMoments) -> OnlineMoments {
        if other.n == 0 {
            return self.clone();
        }
        if self.n == 0 {
            return other.clone();
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let n = na + nb;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * nb / n;
        let m2 = self.m2 + other.m2 + delta * delta * na * nb / n;
        let m3 = self.m3
            + other.m3
            + delta.powi(3) * na * nb * (na - nb) / (n * n)
            + 3.0 * delta * (na * other.m2 - nb * self.m2) / n;
        let m4 = self.m4
            + other.m4
            + delta.powi(4) * na * nb * (na * na - na * nb + nb * nb) / n.powi(3)
            + 6.0 * delta * delta * (na * na * other.m2 + nb * nb * self.m2) / (n * n)
            + 4.0 * delta * (na * other.m3 - nb * self.m3) / n;
        OnlineMoments { n: self.n + other.n, mean, m2, m3, m4 }
    }
}

/// Online co-moment (covariance) accumulator for a pair of streams.
#[derive(Clone, Debug, Default)]
pub struct CoMoments {
    pub n: u64,
    pub mean_x: f64,
    pub mean_y: f64,
    /// Σ (x−mean_x)(y−mean_y).
    pub c2: f64,
}

impl CoMoments {
    pub fn push(&mut self, x: f64, y: f64) {
        self.n += 1;
        let n = self.n as f64;
        let dx = x - self.mean_x;
        self.mean_x += dx / n;
        self.mean_y += (y - self.mean_y) / n;
        // uses updated mean_y (Welford cross form)
        self.c2 += dx * (y - self.mean_y);
    }

    pub fn covariance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.c2 / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn batch_moments(xs: &[f64]) -> (f64, f64, f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let mut m2 = 0.0;
        let mut m3 = 0.0;
        let mut m4 = 0.0;
        for x in xs {
            let d = x - mean;
            m2 += d * d;
            m3 += d * d * d;
            m4 += d * d * d * d;
        }
        (mean, m2 / n, (m3 / n) / (m2 / n).powf(1.5), (m4 / n) / (m2 / n).powi(2))
    }

    #[test]
    fn online_matches_batch() {
        let mut rng = Rng::new(1);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal() * 2.0 + 1.0).collect();
        let mut om = OnlineMoments::default();
        for x in &xs {
            om.push(*x);
        }
        let (mean, var, skew, kurt) = batch_moments(&xs);
        assert!((om.mean - mean).abs() < 1e-10);
        assert!((om.variance() - var).abs() < 1e-9);
        assert!((om.skewness() - skew).abs() < 1e-9);
        assert!((om.kurtosis() - kurt).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..2000).map(|_| rng.uniform() * 3.0).collect();
        let mut a = OnlineMoments::default();
        let mut b = OnlineMoments::default();
        let mut all = OnlineMoments::default();
        for (i, x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(*x)
            } else {
                b.push(*x)
            }
            all.push(*x);
        }
        let merged = a.merge(&b);
        assert_eq!(merged.n, all.n);
        assert!((merged.mean - all.mean).abs() < 1e-10);
        assert!((merged.m2 - all.m2).abs() < 1e-7);
        assert!((merged.m3 - all.m3).abs() < 1e-6);
        assert!((merged.m4 - all.m4).abs() < 1e-5);
    }

    #[test]
    fn covariance_matches_batch() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..3000).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.7 * x + 0.3 * rng.normal()).collect();
        let mut cm = CoMoments::default();
        for (x, y) in xs.iter().zip(&ys) {
            cm.push(*x, *y);
        }
        let mx = xs.iter().sum::<f64>() / 3000.0;
        let my = ys.iter().sum::<f64>() / 3000.0;
        let cov: f64 =
            xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / 3000.0;
        assert!((cm.covariance() - cov).abs() < 1e-9);
    }

    #[test]
    fn gaussian_kurtosis_near_three() {
        let mut rng = Rng::new(4);
        let mut om = OnlineMoments::default();
        for _ in 0..200_000 {
            om.push(rng.normal());
        }
        assert!((om.kurtosis() - 3.0).abs() < 0.1, "{}", om.kurtosis());
    }
}
