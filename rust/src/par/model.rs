//! Schedule-perturbation model checking for the pool's concurrency
//! protocol.
//!
//! The real `loom` crate cannot be vendored into this offline build, so
//! this module provides the same *shape* of tool: instrumented stand-ins
//! for the sync primitives ([`sync`], [`thread`]) with the exact std API,
//! plus a [`model`] harness that re-runs a scenario across many seeded
//! schedules. Every lock acquisition, condvar wait, notify, and atomic RMW
//! passes through [`schedule_point`], which (only while a [`model`] run is
//! active) injects yields and short sleeps decided by a per-seed hash — so
//! each iteration drives the pool through a different interleaving of
//! claiming, parking, and wakeup. A watchdog thread converts a deadlocked
//! schedule (lost wakeup, claim-counter livelock, stuck nested submission)
//! into a test failure instead of a hung suite.
//!
//! This is bounded randomized exploration, not loom's exhaustive DPOR — but
//! the API boundary is loom's, so swapping the real crate in later is a
//! one-line change in [`shim`](super::shim). `pool.rs` compiles against
//! these wrappers under `RUSTFLAGS="--cfg loom"` (see the CI loom job) and
//! against plain `std::sync` otherwise; the wrappers and harness themselves
//! compile (and smoke-test) in every cfg so they cannot rot.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as StdOrdering};

static ACTIVE: AtomicBool = AtomicBool::new(false);
static SEED: AtomicU64 = AtomicU64::new(0);
static CLOCK: AtomicU64 = AtomicU64::new(0);

/// splitmix64 finalizer: cheap, stateless, good enough to decorrelate
/// (seed, event-index) pairs into yield/sleep decisions.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// A potential preemption point. No-op outside [`model`] runs; inside one,
/// the (global event clock, run seed) hash picks between continuing,
/// yielding the OS slice, or sleeping long enough to force another thread
/// through the protocol window that follows this call.
pub fn schedule_point() {
    if !ACTIVE.load(StdOrdering::Relaxed) {
        return;
    }
    let t = CLOCK.fetch_add(1, StdOrdering::Relaxed);
    match mix(t ^ SEED.load(StdOrdering::Relaxed)) % 64 {
        0 => std::thread::sleep(std::time::Duration::from_micros(100)),
        1..=7 => std::thread::yield_now(),
        _ => {}
    }
}

/// Run `scenario` under many perturbed schedules (more under `--cfg loom`,
/// a few in the default-cfg smoke tests), failing the test on the first
/// seed that panics — and, via a watchdog timeout, on the first seed that
/// stops making progress (deadlock/livelock).
pub fn model<F>(name: &str, scenario: F)
where
    F: Fn() + Send + Sync + 'static,
{
    // ACTIVE/SEED/CLOCK are process globals: serialize model runs so two
    // tests cannot fuzz each other's schedules
    static MODEL_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _gate = MODEL_GATE.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let scenario = std::sync::Arc::new(scenario);
    let iters: u64 = if cfg!(loom) { 64 } else { 4 };
    for seed in 0..iters {
        SEED.store(mix(seed), StdOrdering::Relaxed);
        CLOCK.store(0, StdOrdering::Relaxed);
        ACTIVE.store(true, StdOrdering::Relaxed);
        let run = scenario.clone();
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let handle = std::thread::Builder::new()
            .name(format!("model-{name}-{seed}"))
            .spawn(move || {
                let result =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run()));
                drop(tx); // completion signal: the receiver sees a disconnect
                result
            })
            .expect("spawning the model scenario thread cannot fail here");
        let waited = rx.recv_timeout(std::time::Duration::from_secs(30));
        if matches!(waited, Err(std::sync::mpsc::RecvTimeoutError::Timeout)) {
            ACTIVE.store(false, StdOrdering::Relaxed);
            panic!(
                "model '{name}' seed {seed}: no completion within 30s — \
                 this schedule likely deadlocked the protocol under test"
            );
        }
        let result = handle
            .join()
            .expect("scenario panics are caught inside the thread; join always succeeds");
        ACTIVE.store(false, StdOrdering::Relaxed);
        if let Err(payload) = result {
            eprintln!("model '{name}' failed at seed {seed}/{iters}");
            std::panic::resume_unwind(payload);
        }
    }
}

/// Instrumented `std::sync` stand-ins (same API subset the pool uses).
pub mod sync {
    pub use std::sync::{LockResult, MutexGuard};

    /// `std::sync::Mutex` with a [`schedule_point`](super::schedule_point)
    /// before each acquisition.
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub const fn new(value: T) -> Mutex<T> {
            Mutex(std::sync::Mutex::new(value))
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            super::schedule_point();
            self.0.lock()
        }

        pub fn get_mut(&mut self) -> LockResult<&mut T> {
            self.0.get_mut()
        }
    }

    /// `std::sync::Condvar` with schedule points around parking and
    /// notification (the classic lost-wakeup window).
    pub struct Condvar(std::sync::Condvar);

    impl Default for Condvar {
        fn default() -> Condvar {
            Condvar::new()
        }
    }

    impl Condvar {
        pub const fn new() -> Condvar {
            Condvar(std::sync::Condvar::new())
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            super::schedule_point();
            self.0.wait(guard)
        }

        pub fn notify_one(&self) {
            super::schedule_point();
            self.0.notify_one();
        }

        pub fn notify_all(&self) {
            super::schedule_point();
            self.0.notify_all();
        }
    }

    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        /// `std::sync::atomic::AtomicUsize` with schedule points around
        /// every RMW (the claim/pending counters' contention windows).
        pub struct AtomicUsize(std::sync::atomic::AtomicUsize);

        impl AtomicUsize {
            pub const fn new(value: usize) -> AtomicUsize {
                AtomicUsize(std::sync::atomic::AtomicUsize::new(value))
            }

            pub fn load(&self, order: Ordering) -> usize {
                super::super::schedule_point();
                self.0.load(order)
            }

            pub fn store(&self, value: usize, order: Ordering) {
                super::super::schedule_point();
                self.0.store(value, order);
            }

            pub fn fetch_add(&self, value: usize, order: Ordering) -> usize {
                super::super::schedule_point();
                let got = self.0.fetch_add(value, order);
                super::super::schedule_point();
                got
            }

            pub fn fetch_sub(&self, value: usize, order: Ordering) -> usize {
                super::super::schedule_point();
                let got = self.0.fetch_sub(value, order);
                super::super::schedule_point();
                got
            }
        }
    }
}

/// Instrumented `std::thread` stand-ins (the `Builder` path the pool uses
/// to spawn named workers).
pub mod thread {
    pub use std::thread::JoinHandle;

    pub struct Builder(std::thread::Builder);

    impl Default for Builder {
        fn default() -> Builder {
            Builder::new()
        }
    }

    impl Builder {
        pub fn new() -> Builder {
            Builder(std::thread::Builder::new())
        }

        pub fn name(self, name: String) -> Builder {
            Builder(self.0.name(name))
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            self.0.spawn(move || {
                super::schedule_point();
                f()
            })
        }
    }
}

// Default-cfg smoke tests: keep the wrappers and the harness compiled and
// behaving in every ordinary `cargo test` run, so the loom-cfg world cannot
// drift out of sync with a green tier-1 suite. The full pool model lives in
// `pool.rs` under `#[cfg(all(test, loom))]`.
#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::{Condvar, Mutex};
    use super::*;

    #[test]
    fn model_smoke_wrappers_relay_a_condvar_handoff() {
        model("smoke-handoff", || {
            let ready = std::sync::Arc::new((Mutex::new(false), Condvar::new()));
            let hits = std::sync::Arc::new(AtomicUsize::new(0));
            let (r2, h2) = (ready.clone(), hits.clone());
            let worker = thread::Builder::new()
                .name("model-smoke".to_string())
                .spawn(move || {
                    let (lock, cv) = &*r2;
                    let mut go = lock.lock().expect("smoke mutex is never poisoned");
                    while !*go {
                        go = cv.wait(go).expect("smoke condvar wait cannot fail");
                    }
                    h2.fetch_add(1, Ordering::SeqCst);
                })
                .expect("smoke worker spawn succeeds");
            {
                let (lock, cv) = &*ready;
                *lock.lock().expect("smoke mutex is never poisoned") = true;
                cv.notify_all();
            }
            worker.join().expect("smoke worker does not panic");
            assert_eq!(hits.load(Ordering::SeqCst), 1);
        });
    }

    #[test]
    fn model_smoke_atomic_rmw_stays_exact_under_fuzz() {
        model("smoke-counter", || {
            let n = std::sync::Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for _ in 0..3 {
                let n = n.clone();
                let h = thread::Builder::new()
                    .spawn(move || {
                        for _ in 0..50 {
                            n.fetch_add(2, Ordering::SeqCst);
                            n.fetch_sub(1, Ordering::SeqCst);
                        }
                    })
                    .expect("smoke counter thread spawn succeeds");
                handles.push(h);
            }
            for h in handles {
                h.join().expect("smoke counter thread does not panic");
            }
            assert_eq!(n.load(Ordering::SeqCst), 3 * 50);
        });
    }

    #[test]
    fn model_reports_scenario_panics_with_the_original_payload() {
        let result = std::panic::catch_unwind(|| {
            model("smoke-panic", || panic!("seeded failure"));
        });
        let payload = result.expect_err("the scenario panic must surface through model()");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"seeded failure"));
    }
}
