//! Parallel execution substrate: a persistent worker [`Pool`] behind an
//! explicit [`ExecCtx`] handle, with row-partitioned sparse kernels and
//! chunked BLAS-1 primitives.
//!
//! The build is fully offline (no rayon — see `util`'s vendoring note), so
//! parallelism is built from a parked-thread pool ([`pool`]): workers are
//! spawned once, sleep on a condvar between jobs, and wake to claim
//! contiguous-chunk tasks. Waking parked workers costs ~1–2 µs per job
//! versus ~10–20 µs for the previous spawn-per-call scoped threads, which
//! makes parallel SpMV profitable down to ~64×64 systems.
//!
//! There is no process-global pool and no thread-local serial switch: every
//! layer that runs parallel kernels takes an [`ExecCtx`] — a cheap-clone
//! handle sharing one pool — threaded explicitly from the owner downwards
//! (`BatchRunner`/`PisoSolver` → `fvm` assembly → `linsolve` Krylov loops →
//! preconditioner applies). The pool width is a property of the constructed
//! context: `PICT_THREADS` is read when [`ExecCtx::from_env`] is called,
//! never cached process-wide, so tests and embedders can build contexts of
//! any width at any time.
//!
//! Determinism contract (all relative to the *context width*, never to how
//! many workers happen to be idle):
//! - [`ExecCtx::matvec`] partitions *rows*; per-row accumulation order is
//!   identical to [`Csr::matvec`], so results are bit-for-bit equal to
//!   serial at any width.
//! - [`ExecCtx::matvec_transpose`], [`ExecCtx::dot`] and [`ExecCtx::norm2`]
//!   combine per-chunk partials in chunk order: deterministic for a fixed
//!   width, but the grouping differs from the serial left-to-right sum, so
//!   results may differ from serial in the last ulps.
//! - [`ExecCtx::axpy`] is elementwise and bit-for-bit equal to serial.
//! - The f32-storage kernels ([`ExecCtx::matvec32`], [`ExecCtx::dot32`],
//!   [`ExecCtx::norm2_32`], [`ExecCtx::axpy32`]) reuse the same row/chunk
//!   partitioning and accumulate in f64, so the same contract holds per
//!   (width, precision) config: `matvec32`/`axpy32` are bit-for-bit serial-
//!   equal, `dot32`/`norm2_32` combine partials in chunk order.
//! - Work below the per-chunk minima stays on the serial path, so small
//!   systems (most unit tests) are bit-identical at any width.
//!
//! Outer-level parallelism (one task per scenario in
//! [`BatchRunner`](crate::coordinator::scenario::BatchRunner)) and
//! inner-kernel parallelism share the same pool: scenario tasks run as pool
//! jobs and their solver kernels submit nested jobs to the same workers, so
//! a 3-scenario batch on 16 cores keeps the remaining cores busy with
//! kernel chunks instead of idling them.

pub mod model;
pub mod pool;
pub(crate) mod shim;

pub use pool::Pool;

use crate::sparse::{Csr, Csr32};
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::Arc;

/// Minimum matrix nonzeros per chunk before a sparse kernel goes parallel.
pub const MIN_NNZ_PER_THREAD: usize = 2048;
/// Minimum vector elements per chunk before a BLAS-1 kernel goes parallel.
pub const MIN_VEC_PER_THREAD: usize = 32768;
/// Minimum rows per chunk before one ILU level-set sweep goes parallel.
pub const MIN_LEVEL_ROWS_PER_THREAD: usize = 256;

/// Requested pool width from the environment: `PICT_THREADS` if set (≥ 1;
/// `0` reads as "disable", same as `1`), else the machine's available
/// parallelism. Read fresh on every call — never cached — so the value is
/// bound into whichever [`ExecCtx`] is being constructed, not the process.
pub fn env_threads() -> usize {
    std::env::var("PICT_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Shared-slice handle for pool tasks that write disjoint index ranges of
/// one buffer. The unsafe accessors hand out `&mut` views without
/// synchronization; callers guarantee concurrent tasks touch disjoint
/// indices (row partitions, level sets, chunk ranges).
pub(crate) struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is only through the unsafe accessors, whose contract
// (disjoint indices across concurrent tasks) restores exclusive ownership
// per element; T: Send makes cross-thread element access sound.
unsafe impl<T: Send> Send for DisjointMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> DisjointMut<'a, T> {
        DisjointMut { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// # Safety
    /// Concurrent callers must use non-overlapping ranges.
    pub unsafe fn range(&self, r: Range<usize>) -> &mut [T] {
        debug_assert!(r.start <= r.end && r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }

    /// # Safety
    /// No concurrent task may write index `i`.
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// # Safety
    /// No concurrent task may read or write index `i`.
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

/// Split `0..n` into `parts` contiguous, near-equal ranges (fewer if
/// `n < parts`; empty input yields no ranges).
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split rows into `parts` contiguous ranges balanced by nonzero count
/// (each boundary snaps to the row whose prefix-nnz first reaches the
/// target), so graded stencils still load-balance.
pub fn partition_rows(row_ptr: &[usize], parts: usize) -> Vec<Range<usize>> {
    let n = row_ptr.len().saturating_sub(1);
    if n == 0 {
        return Vec::new();
    }
    let nnz = row_ptr[n];
    let parts = parts.clamp(1, n);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        if start >= n {
            break;
        }
        let end = if p + 1 == parts {
            n
        } else {
            let target = nnz / parts * (p + 1);
            let mut e = row_ptr.partition_point(|&v| v < target);
            if e <= start {
                e = start + 1;
            }
            e.min(n)
        };
        out.push(start..end);
        start = end;
    }
    out
}

/// Execution context: a cheap-clone handle on one persistent [`Pool`],
/// passed explicitly through every layer that runs parallel kernels. Clones
/// share the pool (and its width); dropping the last clone shuts the
/// workers down.
#[derive(Clone)]
pub struct ExecCtx {
    pool: Arc<Pool>,
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx::from_env()
    }
}

impl ExecCtx {
    /// Width-1 context: every kernel takes the serial path, no threads are
    /// ever spawned.
    pub fn serial() -> ExecCtx {
        ExecCtx::with_threads(1)
    }

    /// Context over a pool of exactly `threads` workers (including the
    /// submitting thread; `0` reads as `1`).
    pub fn with_threads(threads: usize) -> ExecCtx {
        ExecCtx { pool: Arc::new(Pool::new(threads)) }
    }

    /// Context sized by [`env_threads`] (`PICT_THREADS`, read now — not from
    /// a process-wide cache).
    pub fn from_env() -> ExecCtx {
        ExecCtx::with_threads(env_threads())
    }

    /// Pool width: the number of workers kernels may chunk across (1 =
    /// serial). Chunk counts derive from this, never from runtime worker
    /// availability, so results are deterministic for a fixed width.
    pub fn width(&self) -> usize {
        self.pool.width()
    }

    /// The shared pool (crate-internal; external callers submit through
    /// [`ExecCtx::run_tasks`] / [`ExecCtx::run_chunks`]).
    pub(crate) fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Run `f(t)` for `t` in `0..n_tasks` on the pool (reentrant; the
    /// calling thread participates).
    pub fn run_tasks<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        self.pool.run(n_tasks, &f);
    }

    /// Chunked dispatch: split `0..len` into width-bounded ranges of at
    /// least `min_per_thread` elements and run `f(chunk_index, range)` per
    /// chunk; below the threshold, one inline `f(0, 0..len)` call.
    pub fn run_chunks<F: Fn(usize, Range<usize>) + Sync>(
        &self,
        len: usize,
        min_per_thread: usize,
        f: F,
    ) {
        let nt = self.effective(len, min_per_thread);
        if nt <= 1 {
            f(0, 0..len);
            return;
        }
        let ranges = partition(len, nt);
        let rf = &f;
        self.pool.run(ranges.len(), &|t| rf(t, ranges[t].clone()));
    }

    /// Effective chunk count for `work` units with a per-chunk minimum:
    /// 1 (serial) unless at least two chunks can be fed.
    fn effective(&self, work: usize, min_per_thread: usize) -> usize {
        let w = self.width();
        if w <= 1 {
            return 1;
        }
        let by_work = work / min_per_thread.max(1);
        if by_work < 2 {
            1
        } else {
            w.min(by_work)
        }
    }

    /// y = A x, row-partitioned across the pool. Bit-for-bit equal to the
    /// serial [`Csr::matvec`] at any width.
    pub fn matvec(&self, a: &Csr, x: &[f64], y: &mut [f64]) {
        let nt = self.effective(a.nnz(), MIN_NNZ_PER_THREAD);
        if nt <= 1 {
            a.matvec(x, y);
        } else {
            self.matvec_chunks(a, x, y, nt);
        }
    }

    /// The partitioned gather kernel itself, always run at `parts` chunks
    /// (no serial fallback). Public so tests and benches can pin the
    /// chunking.
    pub fn matvec_chunks(&self, a: &Csr, x: &[f64], y: &mut [f64], parts: usize) {
        assert_eq!(x.len(), a.n);
        assert_eq!(y.len(), a.n);
        let ranges = partition_rows(&a.row_ptr, parts);
        let (row_ptr, col_idx, vals) = (&a.row_ptr, &a.col_idx, &a.vals);
        let ys = DisjointMut::new(y);
        self.run_tasks(ranges.len(), |t| {
            let r = ranges[t].clone();
            // SAFETY: row ranges are disjoint, one task per range
            let chunk = unsafe { ys.range(r.clone()) };
            for (row, yi) in r.zip(chunk.iter_mut()) {
                let mut acc = 0.0;
                for k in row_ptr[row]..row_ptr[row + 1] {
                    acc += vals[k] * x[col_idx[k] as usize];
                }
                *yi = acc;
            }
        });
    }

    /// y = Aᵀ x: each chunk scatters its row range into a private buffer,
    /// then buffers are combined in chunk order (deterministic for a fixed
    /// width; may differ from serial in the last ulps).
    pub fn matvec_transpose(&self, a: &Csr, x: &[f64], y: &mut [f64]) {
        let nt = self.effective(a.nnz(), MIN_NNZ_PER_THREAD);
        if nt <= 1 {
            a.matvec_transpose(x, y);
        } else {
            self.matvec_transpose_chunks(a, x, y, nt);
        }
    }

    /// The partitioned scatter-reduce kernel, always run at `parts` chunks.
    pub fn matvec_transpose_chunks(&self, a: &Csr, x: &[f64], y: &mut [f64], parts: usize) {
        assert_eq!(x.len(), a.n);
        assert_eq!(y.len(), a.n);
        let n = a.n;
        let ranges = partition_rows(&a.row_ptr, parts);
        let (row_ptr, col_idx, vals) = (&a.row_ptr, &a.col_idx, &a.vals);
        let mut partials: Vec<Vec<f64>> = vec![Vec::new(); ranges.len()];
        {
            let ps = DisjointMut::new(&mut partials);
            self.run_tasks(ranges.len(), |t| {
                let mut local = vec![0.0; n];
                for row in ranges[t].clone() {
                    let xr = x[row];
                    if xr == 0.0 {
                        continue;
                    }
                    for k in row_ptr[row]..row_ptr[row + 1] {
                        local[col_idx[k] as usize] += vals[k] * xr;
                    }
                }
                // SAFETY: slot t is written by task t only
                unsafe { ps.range(t..t + 1) }[0] = local;
            });
        }
        // Combine in parallel too — a serial combine would cost
        // O(parts·n) on this crate's low-density stencil matrices,
        // rivaling the scatter itself. Each chunk owns an output range and
        // sums the partials in chunk order, so the result is deterministic
        // for a fixed `parts`.
        let partials = &partials;
        let out_ranges = partition(n, partials.len());
        let ys = DisjointMut::new(y);
        self.run_tasks(out_ranges.len(), |t| {
            let r = out_ranges[t].clone();
            // SAFETY: output ranges are disjoint, one task per range
            let chunk = unsafe { ys.range(r.clone()) };
            for (off, yi) in chunk.iter_mut().enumerate() {
                let i = r.start + off;
                let mut acc = 0.0;
                for local in partials {
                    acc += local[i];
                }
                *yi = acc;
            }
        });
    }

    /// Chunked parallel dot product; partials combined in chunk order.
    pub fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        let nt = self.effective(a.len(), MIN_VEC_PER_THREAD);
        if nt <= 1 {
            return a.iter().zip(b).map(|(x, y)| x * y).sum();
        }
        let ranges = partition(a.len(), nt);
        let mut partials = vec![0.0; ranges.len()];
        {
            let ps = DisjointMut::new(&mut partials);
            self.run_tasks(ranges.len(), |t| {
                let r = ranges[t].clone();
                let s: f64 = a[r.clone()].iter().zip(&b[r]).map(|(x, y)| x * y).sum();
                // SAFETY: slot t is written by task t only
                unsafe { ps.set(t, s) };
            });
        }
        partials.iter().sum()
    }

    /// Parallel 2-norm (via [`ExecCtx::dot`]).
    pub fn norm2(&self, a: &[f64]) -> f64 {
        self.dot(a, a).sqrt()
    }

    /// y += alpha * x, chunk-partitioned; bit-for-bit equal to serial.
    pub fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), y.len());
        let ys = DisjointMut::new(y);
        self.run_chunks(x.len(), MIN_VEC_PER_THREAD, |_, r| {
            // SAFETY: chunk ranges are disjoint
            let chunk = unsafe { ys.range(r.clone()) };
            for (yi, xi) in chunk.iter_mut().zip(&x[r]) {
                *yi += alpha * xi;
            }
        });
    }

    /// y = A x over an f32-storage mirror, row-partitioned with the exact
    /// partitioner the f64 [`ExecCtx::matvec`] uses (nnz-balanced row
    /// ranges); each row accumulates in f64 inside
    /// [`Csr32::matvec_rows`], so results are bit-for-bit equal to the
    /// serial [`Csr32::matvec`] at any width.
    pub fn matvec32(&self, a: &Csr32, x: &[f32], y: &mut [f32]) {
        let nt = self.effective(a.nnz(), MIN_NNZ_PER_THREAD);
        if nt <= 1 {
            a.matvec(x, y);
        } else {
            self.matvec32_chunks(a, x, y, nt);
        }
    }

    /// The partitioned f32 gather kernel, always run at `parts` chunks
    /// (no serial fallback). Public so tests and benches can pin the
    /// chunking.
    pub fn matvec32_chunks(&self, a: &Csr32, x: &[f32], y: &mut [f32], parts: usize) {
        assert_eq!(x.len(), a.n);
        assert_eq!(y.len(), a.n);
        let ranges = partition_rows(&a.row_ptr, parts);
        let ys = DisjointMut::new(y);
        self.run_tasks(ranges.len(), |t| {
            let r = ranges[t].clone();
            // SAFETY: row ranges are disjoint, one task per range
            let chunk = unsafe { ys.range(r.clone()) };
            a.matvec_rows(x, chunk, r);
        });
    }

    /// Chunked f32 dot product with f64 accumulation; per-chunk partials
    /// combined in chunk order (deterministic for a fixed width).
    pub fn dot32(&self, a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        let nt = self.effective(a.len(), MIN_VEC_PER_THREAD);
        if nt <= 1 {
            let mut acc = 0.0f64;
            for (x, y) in a.iter().zip(b) {
                acc += f64::from(*x) * f64::from(*y);
            }
            return acc;
        }
        let ranges = partition(a.len(), nt);
        let mut partials = vec![0.0; ranges.len()];
        {
            let ps = DisjointMut::new(&mut partials);
            self.run_tasks(ranges.len(), |t| {
                let r = ranges[t].clone();
                let mut s = 0.0f64;
                for (x, y) in a[r.clone()].iter().zip(&b[r]) {
                    s += f64::from(*x) * f64::from(*y);
                }
                // SAFETY: slot t is written by task t only
                unsafe { ps.set(t, s) };
            });
        }
        partials.iter().sum()
    }

    /// Parallel 2-norm of an f32 vector (via [`ExecCtx::dot32`]); the
    /// result stays in f64 for the refinement loop's convergence tests.
    pub fn norm2_32(&self, a: &[f32]) -> f64 {
        self.dot32(a, a).sqrt()
    }

    /// y += alpha * x on f32 storage: each element updates through one f64
    /// fused expression before narrowing back, chunk-partitioned and
    /// bit-for-bit equal to serial (elementwise).
    pub fn axpy32(&self, alpha: f64, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        let ys = DisjointMut::new(y);
        self.run_chunks(x.len(), MIN_VEC_PER_THREAD, |_, r| {
            // SAFETY: chunk ranges are disjoint
            let chunk = unsafe { ys.range(r.clone()) };
            for (yi, xi) in chunk.iter_mut().zip(&x[r]) {
                *yi = (f64::from(*yi) + alpha * f64::from(*xi)) as f32;
            }
        });
    }

    /// Visit every CSR row with mutable access to its value slice,
    /// row-partitioned across the pool: `f(row, row_cols, row_vals)`. Rows
    /// map to disjoint `vals` ranges, so chunks write without
    /// synchronization. Used by the FVM assembly hot path.
    pub fn for_each_row<F>(&self, row_ptr: &[usize], col_idx: &[u32], vals: &mut [f64], f: F)
    where
        F: Fn(usize, &[u32], &mut [f64]) + Sync,
    {
        let n = row_ptr.len().saturating_sub(1);
        assert_eq!(vals.len(), if n == 0 { 0 } else { row_ptr[n] });
        assert_eq!(col_idx.len(), vals.len());
        let nt = self.effective(vals.len(), MIN_NNZ_PER_THREAD);
        if nt <= 1 {
            for row in 0..n {
                let (lo, hi) = (row_ptr[row], row_ptr[row + 1]);
                f(row, &col_idx[lo..hi], &mut vals[lo..hi]);
            }
            return;
        }
        let ranges = partition_rows(row_ptr, nt);
        let vs = DisjointMut::new(vals);
        self.run_tasks(ranges.len(), |t| {
            for row in ranges[t].clone() {
                let (lo, hi) = (row_ptr[row], row_ptr[row + 1]);
                // SAFETY: rows are disjoint value ranges, row ranges are
                // disjoint across tasks
                let row_vals = unsafe { vs.range(lo..hi) };
                f(row, &col_idx[lo..hi], row_vals);
            }
        });
    }
}

/// The pre-pool spawn-per-call kernels, kept as the benchmark baseline so
/// `benches/par_scaling.rs` can quantify what the persistent pool saves.
/// Not used by any solver path.
pub mod spawn {
    use super::partition_rows;
    use crate::sparse::Csr;

    /// y = A x at `parts` chunks, spawning (and joining) one scoped thread
    /// per chunk — the old kernel this crate's pool replaced.
    pub fn matvec_partitioned(a: &Csr, x: &[f64], y: &mut [f64], parts: usize) {
        assert_eq!(x.len(), a.n);
        assert_eq!(y.len(), a.n);
        let ranges = partition_rows(&a.row_ptr, parts);
        let (row_ptr, col_idx, vals) = (&a.row_ptr, &a.col_idx, &a.vals);
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = y;
            let mut consumed = 0usize;
            for r in ranges {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.end - consumed);
                rest = tail;
                consumed = r.end;
                s.spawn(move || {
                    for (row, yi) in r.zip(chunk.iter_mut()) {
                        let mut acc = 0.0;
                        for k in row_ptr[row]..row_ptr[row + 1] {
                            acc += vals[k] * x[col_idx[k] as usize];
                        }
                        *yi = acc;
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_csr(n: usize, density: f64, rng: &mut Rng) -> Csr {
        let mut trip = Vec::new();
        for r in 0..n {
            for c in 0..n {
                if rng.uniform() < density {
                    trip.push((r, c, rng.normal()));
                }
            }
            trip.push((r, r, 1.0 + rng.uniform()));
        }
        Csr::from_triplets(n, &trip)
    }

    #[test]
    fn partition_covers_range() {
        for (n, p) in [(10, 3), (7, 7), (1, 4), (100, 8)] {
            let ranges = partition(n, p);
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(!w[1].is_empty());
            }
        }
        assert!(partition(0, 4).is_empty());
    }

    #[test]
    fn partition_rows_covers_and_balances() {
        // 100 rows of 5 nnz each
        let row_ptr: Vec<usize> = (0..=100).map(|r| 5 * r).collect();
        let ranges = partition_rows(&row_ptr, 4);
        assert_eq!(ranges.first().unwrap().start, 0);
        assert_eq!(ranges.last().unwrap().end, 100);
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        for r in &ranges {
            assert_eq!(r.len(), 25);
        }
    }

    #[test]
    fn pool_matvec_bit_for_bit_equals_serial() {
        let mut rng = Rng::new(0xFA11);
        let a = random_csr(150, 0.2, &mut rng);
        let x = rng.normal_vec(150);
        let mut y_serial = vec![0.0; 150];
        a.matvec(&x, &mut y_serial);
        for nt in [2, 3, 4, 8] {
            let ctx = ExecCtx::with_threads(nt);
            let mut y_par = vec![0.0; 150];
            ctx.matvec_chunks(&a, &x, &mut y_par, nt);
            assert_eq!(y_serial, y_par, "nt={nt}");
        }
    }

    #[test]
    fn pool_transpose_matches_explicit_transpose() {
        let mut rng = Rng::new(0x7A2);
        let a = random_csr(120, 0.25, &mut rng);
        let x = rng.normal_vec(120);
        let at = a.transpose();
        let mut want = vec![0.0; 120];
        at.matvec(&x, &mut want);
        let ctx = ExecCtx::with_threads(5);
        for nt in [2, 5] {
            let mut got = vec![0.0; 120];
            ctx.matvec_transpose_chunks(&a, &x, &mut got, nt);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn pool_matvec32_bit_for_bit_equals_serial() {
        let mut rng = Rng::new(0xF32);
        let a = random_csr(150, 0.2, &mut rng);
        let a32 = Csr32::from_f64(&a);
        let x32: Vec<f32> = rng.normal_vec(150).iter().map(|&v| v as f32).collect();
        let mut y_serial = vec![0.0f32; 150];
        a32.matvec(&x32, &mut y_serial);
        for nt in [2, 3, 4, 8] {
            let ctx = ExecCtx::with_threads(nt);
            let mut y_par = vec![0.0f32; 150];
            ctx.matvec32_chunks(&a32, &x32, &mut y_par, nt);
            assert_eq!(y_serial, y_par, "nt={nt}");
        }
    }

    #[test]
    fn dot32_and_axpy32_match_f64_reference() {
        let mut rng = Rng::new(0x3F2);
        let n = 2 * MIN_VEC_PER_THREAD + 11;
        let a32: Vec<f32> = rng.normal_vec(n).iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = rng.normal_vec(n).iter().map(|&v| v as f32).collect();
        let mut want = 0.0f64;
        for (x, y) in a32.iter().zip(&b32) {
            want += f64::from(*x) * f64::from(*y);
        }
        let ctx = ExecCtx::with_threads(4);
        let par = ctx.dot32(&a32, &b32);
        assert!((par - want).abs() < 1e-9 * (1.0 + want.abs()));
        assert!((ctx.norm2_32(&a32) - ctx.dot32(&a32, &a32).sqrt()).abs() < 1e-12);
        let mut y1 = b32.clone();
        let mut y2 = b32.clone();
        ExecCtx::serial().axpy32(0.37, &a32, &mut y1);
        ctx.axpy32(0.37, &a32, &mut y2);
        assert_eq!(y1, y2); // elementwise: exactly equal
    }

    #[test]
    fn dot_and_axpy_match_serial_above_threshold() {
        let mut rng = Rng::new(77);
        let n = 2 * MIN_VEC_PER_THREAD + 17;
        let a = rng.normal_vec(n);
        let b = rng.normal_vec(n);
        let serial: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let ctx = ExecCtx::with_threads(4);
        let par = ctx.dot(&a, &b);
        assert!((par - serial).abs() < 1e-9 * (1.0 + serial.abs()));
        let mut y1 = b.clone();
        let mut y2 = b.clone();
        ExecCtx::serial().axpy(0.37, &a, &mut y1);
        ctx.axpy(0.37, &a, &mut y2);
        assert_eq!(y1, y2); // elementwise: exactly equal
    }

    #[test]
    fn serial_ctx_width_is_one_and_runs_inline() {
        let ctx = ExecCtx::serial();
        assert_eq!(ctx.width(), 1);
        assert_eq!(ctx.effective(usize::MAX / 2, 1), 1);
    }

    #[test]
    fn clones_share_one_pool() {
        let ctx = ExecCtx::with_threads(3);
        let other = ctx.clone();
        assert_eq!(other.width(), 3);
        assert!(std::ptr::eq(ctx.pool(), other.pool()));
    }

    #[test]
    fn for_each_row_writes_disjoint_rows() {
        let mut rng = Rng::new(3);
        let a = random_csr(40, 0.3, &mut rng);
        let mut got = a.clone();
        got.zero_values();
        let want_vals = a.vals.clone();
        let (row_ptr, col_idx) = (a.row_ptr.clone(), a.col_idx.clone());
        let ctx = ExecCtx::with_threads(4);
        ctx.for_each_row(&row_ptr, &col_idx, &mut got.vals, |row, _cols, row_vals| {
            let lo = row_ptr[row];
            for (k, v) in row_vals.iter_mut().enumerate() {
                *v = want_vals[lo + k];
            }
        });
        assert_eq!(got.vals, a.vals);
    }

    #[test]
    fn spawn_baseline_matches_serial() {
        let mut rng = Rng::new(0x5BA);
        let a = random_csr(90, 0.3, &mut rng);
        let x = rng.normal_vec(90);
        let mut y_serial = vec![0.0; 90];
        let mut y_spawn = vec![0.0; 90];
        a.matvec(&x, &mut y_serial);
        spawn::matvec_partitioned(&a, &x, &mut y_spawn, 4);
        assert_eq!(y_serial, y_spawn);
    }

    #[test]
    fn env_threads_is_at_least_one() {
        assert!(env_threads() >= 1);
    }

    #[test]
    fn miri_disjoint_mut_halves_do_not_alias() {
        // Fast Miri target for DisjointMut: two pool tasks write disjoint
        // halves of one buffer through the raw-pointer accessors.
        let mut buf = vec![0.0f64; 16];
        {
            let dm = DisjointMut::new(&mut buf);
            let ctx = ExecCtx::with_threads(2);
            ctx.run_tasks(2, |t| {
                // SAFETY: the two tasks write disjoint halves
                let half = unsafe { dm.range(8 * t..8 * (t + 1)) };
                for (i, v) in half.iter_mut().enumerate() {
                    *v = (8 * t + i) as f64;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn miri_matvec_chunks_sound_at_forced_width() {
        // Forced 2-way row partition on a small system: the DisjointMut row
        // ranges and the erased task borrow must pass Miri's aliasing
        // checks and still be bit-for-bit serial.
        let mut rng = Rng::new(0x31AB);
        let a = random_csr(12, 0.4, &mut rng);
        let x = rng.normal_vec(12);
        let mut y_serial = vec![0.0; 12];
        a.matvec(&x, &mut y_serial);
        let ctx = ExecCtx::with_threads(2);
        let mut y_par = vec![0.0; 12];
        ctx.matvec_chunks(&a, &x, &mut y_par, 2);
        assert_eq!(y_serial, y_par);
    }
}
