//! Parallel execution substrate: a `std::thread::scope`-based worker pool
//! with row-partitioned sparse kernels and chunked BLAS-1 primitives.
//!
//! The build is fully offline (no rayon — see `util`'s vendoring note), so
//! parallelism is built from scoped threads: every parallel call spawns its
//! workers, distributes contiguous chunks, and joins before returning. Work
//! below the per-thread minimum stays on the serial path, so small systems
//! (most unit tests) are bit-identical with and without the pool.
//!
//! Thread count: `PICT_THREADS=<n>` overrides; the default is
//! `std::thread::available_parallelism()`. `PICT_THREADS=1` (or `0`)
//! disables the pool entirely.
//!
//! Determinism contract:
//! - [`matvec`] partitions *rows*; per-row accumulation order is identical
//!   to [`Csr::matvec`], so results are bit-for-bit equal to serial at any
//!   thread count.
//! - [`matvec_transpose`], [`dot`] and [`norm2`] combine per-chunk partials
//!   in chunk order: deterministic for a fixed thread count, but the
//!   grouping differs from the serial left-to-right sum, so results may
//!   differ from serial in the last ulps.
//! - [`axpy`] is elementwise and bit-for-bit equal to serial.
//!
//! Nested parallelism is suppressed: code running inside [`with_serial`]
//! (e.g. each scenario advanced by
//! [`BatchRunner`](crate::coordinator::scenario::BatchRunner), which already
//! owns one thread per scenario) keeps every inner kernel on the serial
//! path instead of oversubscribing the machine.

use crate::sparse::Csr;
use std::cell::Cell;
use std::ops::Range;
use std::sync::OnceLock;

/// Minimum matrix nonzeros per worker before a sparse kernel goes parallel.
pub const MIN_NNZ_PER_THREAD: usize = 4096;
/// Minimum vector elements per worker before a BLAS-1 kernel goes parallel.
pub const MIN_VEC_PER_THREAD: usize = 32768;

/// Pool width: `PICT_THREADS` if set (≥ 1), else the machine's available
/// parallelism. Read once and cached for the process lifetime.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("PICT_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            // 0 reads as "disable the pool", same as 1 — not "all cores"
            .map(|n| n.max(1))
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

thread_local! {
    static SERIAL_SCOPE: Cell<bool> = const { Cell::new(false) };
}

/// True while the current thread runs inside [`with_serial`].
pub fn in_serial_scope() -> bool {
    SERIAL_SCOPE.with(|s| s.get())
}

/// Run `f` with all `par` kernels forced onto the serial path on this
/// thread. Used by outer-level parallelism (one thread per scenario) so the
/// inner solver kernels don't oversubscribe the machine.
pub fn with_serial<T>(f: impl FnOnce() -> T) -> T {
    SERIAL_SCOPE.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// Effective worker count for `work` units with a per-thread minimum:
/// 1 (serial) unless at least two workers can be fed.
fn effective_threads(requested: usize, work: usize, min_per_thread: usize) -> usize {
    if requested <= 1 || in_serial_scope() {
        return 1;
    }
    let by_work = work / min_per_thread.max(1);
    if by_work < 2 {
        1
    } else {
        requested.min(by_work)
    }
}

/// Split `0..n` into `parts` contiguous, near-equal ranges (fewer if
/// `n < parts`; empty input yields no ranges).
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split rows into `parts` contiguous ranges balanced by nonzero count
/// (each boundary snaps to the row whose prefix-nnz first reaches the
/// target), so graded stencils still load-balance.
pub fn partition_rows(row_ptr: &[usize], parts: usize) -> Vec<Range<usize>> {
    let n = row_ptr.len().saturating_sub(1);
    if n == 0 {
        return Vec::new();
    }
    let nnz = row_ptr[n];
    let parts = parts.clamp(1, n);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        if start >= n {
            break;
        }
        let end = if p + 1 == parts {
            n
        } else {
            let target = nnz / parts * (p + 1);
            let mut e = row_ptr.partition_point(|&v| v < target);
            if e <= start {
                e = start + 1;
            }
            e.min(n)
        };
        out.push(start..end);
        start = end;
    }
    out
}

/// y = A x, row-partitioned across the default pool. Bit-for-bit equal to
/// the serial [`Csr::matvec`] at any thread count.
pub fn matvec(a: &Csr, x: &[f64], y: &mut [f64]) {
    matvec_with(a, x, y, num_threads());
}

/// [`matvec`] with an explicit thread-count request (benchmarks, tests).
/// The request is still capped by the work threshold; use
/// [`matvec_partitioned`] to force the partitioned path on small systems.
pub fn matvec_with(a: &Csr, x: &[f64], y: &mut [f64], threads: usize) {
    let nt = effective_threads(threads, a.nnz(), MIN_NNZ_PER_THREAD);
    if nt <= 1 {
        a.matvec(x, y);
    } else {
        matvec_partitioned(a, x, y, nt);
    }
}

/// The partitioned gather kernel itself, always run at `parts` chunks (no
/// serial fallback). Public so tests and benches can pin the chunking.
pub fn matvec_partitioned(a: &Csr, x: &[f64], y: &mut [f64], parts: usize) {
    assert_eq!(x.len(), a.n);
    assert_eq!(y.len(), a.n);
    let ranges = partition_rows(&a.row_ptr, parts);
    let (row_ptr, col_idx, vals) = (&a.row_ptr, &a.col_idx, &a.vals);
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = y;
        let mut consumed = 0usize;
        for r in ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.end - consumed);
            rest = tail;
            consumed = r.end;
            s.spawn(move || {
                for (row, yi) in r.zip(chunk.iter_mut()) {
                    let mut acc = 0.0;
                    for k in row_ptr[row]..row_ptr[row + 1] {
                        acc += vals[k] * x[col_idx[k] as usize];
                    }
                    *yi = acc;
                }
            });
        }
    });
}

/// y = Aᵀ x: each worker scatters its row range into a thread-local buffer,
/// then buffers are combined in worker order (deterministic for a fixed
/// thread count; may differ from serial in the last ulps).
pub fn matvec_transpose(a: &Csr, x: &[f64], y: &mut [f64]) {
    matvec_transpose_with(a, x, y, num_threads());
}

/// [`matvec_transpose`] with an explicit thread-count request.
pub fn matvec_transpose_with(a: &Csr, x: &[f64], y: &mut [f64], threads: usize) {
    let nt = effective_threads(threads, a.nnz(), MIN_NNZ_PER_THREAD);
    if nt <= 1 {
        a.matvec_transpose(x, y);
        return;
    }
    matvec_transpose_partitioned(a, x, y, nt);
}

/// The partitioned scatter-reduce kernel, always run at `parts` chunks.
pub fn matvec_transpose_partitioned(a: &Csr, x: &[f64], y: &mut [f64], parts: usize) {
    assert_eq!(x.len(), a.n);
    assert_eq!(y.len(), a.n);
    let ranges = partition_rows(&a.row_ptr, parts);
    let (row_ptr, col_idx, vals) = (&a.row_ptr, &a.col_idx, &a.vals);
    let n = a.n;
    let mut partials: Vec<Vec<f64>> = Vec::with_capacity(ranges.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                s.spawn(move || {
                    let mut local = vec![0.0; n];
                    for row in r {
                        let xr = x[row];
                        if xr == 0.0 {
                            continue;
                        }
                        for k in row_ptr[row]..row_ptr[row + 1] {
                            local[col_idx[k] as usize] += vals[k] * xr;
                        }
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            partials.push(h.join().expect("par worker panicked"));
        }
    });
    // Combine in parallel too — a serial combine would cost O(parts·n) on
    // this crate's low-density stencil matrices, rivaling the scatter
    // itself. Each worker owns an output chunk and sums the partials in
    // worker order, so the result is deterministic for a fixed `parts`.
    let partials = &partials;
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = y;
        let mut consumed = 0usize;
        for r in partition(n, partials.len()) {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.end - consumed);
            rest = tail;
            consumed = r.end;
            s.spawn(move || {
                for (off, yi) in chunk.iter_mut().enumerate() {
                    let i = r.start + off;
                    let mut acc = 0.0;
                    for local in partials {
                        acc += local[i];
                    }
                    *yi = acc;
                }
            });
        }
    });
}

/// Chunked parallel dot product; partials combined in chunk order.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_with(a, b, num_threads())
}

/// [`dot`] with an explicit thread-count request.
pub fn dot_with(a: &[f64], b: &[f64], threads: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    let nt = effective_threads(threads, a.len(), MIN_VEC_PER_THREAD);
    if nt <= 1 {
        return a.iter().zip(b).map(|(x, y)| x * y).sum();
    }
    let ranges = partition(a.len(), nt);
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                s.spawn(move || {
                    a[r.clone()].iter().zip(&b[r]).map(|(x, y)| x * y).sum::<f64>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("par worker panicked")).sum()
    })
}

/// Parallel 2-norm (via [`dot`]).
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x, chunk-partitioned; bit-for-bit equal to serial.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_with(alpha, x, y, num_threads());
}

/// [`axpy`] with an explicit thread-count request.
pub fn axpy_with(alpha: f64, x: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(x.len(), y.len());
    let nt = effective_threads(threads, y.len(), MIN_VEC_PER_THREAD);
    if nt <= 1 {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
        return;
    }
    let ranges = partition(y.len(), nt);
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = y;
        let mut consumed = 0usize;
        for r in ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.end - consumed);
            rest = tail;
            consumed = r.end;
            s.spawn(move || {
                for (yi, xi) in chunk.iter_mut().zip(&x[r]) {
                    *yi += alpha * xi;
                }
            });
        }
    });
}

/// Visit every CSR row with mutable access to its value slice,
/// row-partitioned across the pool: `f(row, row_cols, row_vals)`. Rows map
/// to disjoint `vals` ranges, so workers write without synchronization.
/// Used by the FVM assembly hot path.
pub fn for_each_row<F>(row_ptr: &[usize], col_idx: &[u32], vals: &mut [f64], f: F)
where
    F: Fn(usize, &[u32], &mut [f64]) + Sync,
{
    let n = row_ptr.len().saturating_sub(1);
    let nt = effective_threads(num_threads(), vals.len(), MIN_NNZ_PER_THREAD);
    if nt <= 1 {
        for row in 0..n {
            let (lo, hi) = (row_ptr[row], row_ptr[row + 1]);
            f(row, &col_idx[lo..hi], &mut vals[lo..hi]);
        }
        return;
    }
    let ranges = partition_rows(row_ptr, nt);
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest: &mut [f64] = vals;
        let mut consumed = 0usize;
        for r in ranges {
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut(row_ptr[r.end] - consumed);
            rest = tail;
            consumed = row_ptr[r.end];
            s.spawn(move || {
                let mut chunk = chunk;
                for row in r {
                    let len = row_ptr[row + 1] - row_ptr[row];
                    let (row_vals, tail) = std::mem::take(&mut chunk).split_at_mut(len);
                    chunk = tail;
                    fr(row, &col_idx[row_ptr[row]..row_ptr[row + 1]], row_vals);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_csr(n: usize, density: f64, rng: &mut Rng) -> Csr {
        let mut trip = Vec::new();
        for r in 0..n {
            for c in 0..n {
                if rng.uniform() < density {
                    trip.push((r, c, rng.normal()));
                }
            }
            trip.push((r, r, 1.0 + rng.uniform()));
        }
        Csr::from_triplets(n, &trip)
    }

    #[test]
    fn partition_covers_range() {
        for (n, p) in [(10, 3), (7, 7), (1, 4), (100, 8)] {
            let ranges = partition(n, p);
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(!w[1].is_empty());
            }
        }
        assert!(partition(0, 4).is_empty());
    }

    #[test]
    fn partition_rows_covers_and_balances() {
        // 100 rows of 5 nnz each
        let row_ptr: Vec<usize> = (0..=100).map(|r| 5 * r).collect();
        let ranges = partition_rows(&row_ptr, 4);
        assert_eq!(ranges.first().unwrap().start, 0);
        assert_eq!(ranges.last().unwrap().end, 100);
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        for r in &ranges {
            assert_eq!(r.len(), 25);
        }
    }

    #[test]
    fn parallel_matvec_bit_for_bit_equals_serial() {
        let mut rng = Rng::new(0xFA11);
        let a = random_csr(150, 0.2, &mut rng);
        let x = rng.normal_vec(150);
        let mut y_serial = vec![0.0; 150];
        a.matvec(&x, &mut y_serial);
        for nt in [2, 3, 4, 8] {
            let mut y_par = vec![0.0; 150];
            matvec_partitioned(&a, &x, &mut y_par, nt);
            assert_eq!(y_serial, y_par, "nt={nt}");
        }
    }

    #[test]
    fn parallel_transpose_matches_explicit_transpose() {
        let mut rng = Rng::new(0x7A2);
        let a = random_csr(120, 0.25, &mut rng);
        let x = rng.normal_vec(120);
        let at = a.transpose();
        let mut want = vec![0.0; 120];
        at.matvec(&x, &mut want);
        for nt in [2, 5] {
            let mut got = vec![0.0; 120];
            matvec_transpose_partitioned(&a, &x, &mut got, nt);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn dot_and_axpy_match_serial_above_threshold() {
        let mut rng = Rng::new(77);
        let n = 2 * MIN_VEC_PER_THREAD + 17;
        let a = rng.normal_vec(n);
        let b = rng.normal_vec(n);
        let serial: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let par = dot_with(&a, &b, 4);
        assert!((par - serial).abs() < 1e-9 * (1.0 + serial.abs()));
        let mut y1 = b.clone();
        let mut y2 = b.clone();
        axpy_with(0.37, &a, &mut y1, 1);
        axpy_with(0.37, &a, &mut y2, 4);
        assert_eq!(y1, y2); // elementwise: exactly equal
    }

    #[test]
    fn serial_scope_suppresses_parallelism() {
        assert!(!in_serial_scope());
        with_serial(|| {
            assert!(in_serial_scope());
            assert_eq!(effective_threads(8, usize::MAX / 2, 1), 1);
        });
        assert!(!in_serial_scope());
    }

    #[test]
    fn for_each_row_writes_disjoint_rows() {
        let mut rng = Rng::new(3);
        let a = random_csr(40, 0.3, &mut rng);
        let mut got = a.clone();
        got.zero_values();
        let want_vals = a.vals.clone();
        let (row_ptr, col_idx) = (a.row_ptr.clone(), a.col_idx.clone());
        for_each_row(&row_ptr, &col_idx, &mut got.vals, |row, _cols, row_vals| {
            let lo = row_ptr[row];
            for (k, v) in row_vals.iter_mut().enumerate() {
                *v = want_vals[lo + k];
            }
        });
        assert_eq!(got.vals, a.vals);
    }

    #[test]
    fn num_threads_is_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
