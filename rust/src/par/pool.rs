//! Persistent worker pool: threads spawned once (lazily, on the first
//! parallel job) and parked on a condvar between jobs, replacing the
//! previous spawn-per-call scoped threads whose ~10–20 µs setup tax made
//! parallel kernels unprofitable below large systems.
//!
//! A job is a task function plus a task count. Workers — and the submitting
//! thread, which always helps — claim task indices from a shared counter,
//! so work keyed by task index lands deterministically no matter which
//! worker executes it. Multiple threads may submit concurrently (jobs queue
//! up and drain in order), and submission is reentrant: a task already
//! running on a pool worker may submit a nested job, which is exactly what
//! the scenario-level tasks of
//! [`BatchRunner`](crate::coordinator::scenario::BatchRunner) do for their
//! inner solver kernels. Because the submitter executes its own job's tasks
//! while waiting, nested submission cannot deadlock even when every worker
//! is busy: tasks never block on anything but their own nested jobs, so the
//! wait graph stays acyclic.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Erased reference to a job's task function. [`Pool::run`] blocks until
/// every task has finished before returning, so the pointee outlives every
/// dereference despite the erased lifetime.
struct TaskRef(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointee is `Sync` (shared calls are safe) and the erased
// borrow is kept alive by the submitter until the job completes.
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

struct Job {
    task: TaskRef,
    n_tasks: usize,
    /// Claim counter: next unclaimed task index (may overshoot `n_tasks`).
    next: AtomicUsize,
    /// Tasks not yet finished (claimed or not).
    pending: AtomicUsize,
    /// First panic payload caught on a task; resumed on the submitting
    /// thread once the job completes, so the original assertion message
    /// and backtrace context survive the pool boundary.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
    done: Mutex<()>,
    done_cv: Condvar,
}

impl Job {
    /// Claim and execute tasks until the claim counter is exhausted.
    fn help(&self) {
        loop {
            let t = self.next.fetch_add(1, Ordering::SeqCst);
            if t >= self.n_tasks {
                return;
            }
            // SAFETY: tasks are only claimed while the submitter is blocked
            // in `Pool::run`, which keeps the borrow alive (see `TaskRef`).
            let task = unsafe { &*self.task.0 };
            let flag = TaskFlagGuard::enter();
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(t)))
            {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            drop(flag);
            if self.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                // last finisher: take the lock so the notify cannot race
                // between the waiter's predicate check and its wait()
                let _guard = self.done.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::SeqCst) >= self.n_tasks
    }

    fn wait(&self) {
        let mut guard = self.done.lock().unwrap();
        while self.pending.load(Ordering::SeqCst) != 0 {
            guard = self.done_cv.wait(guard).unwrap();
        }
    }
}

struct Gate {
    queue: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct PoolInner {
    gate: Mutex<Gate>,
    work_cv: Condvar,
}

thread_local! {
    /// True while the current thread is executing a pool task (on a worker,
    /// on a submitter helping its own job, or on an inline fast path):
    /// nested jobs submitted from inside a task jump the queue, so inner
    /// kernel chunks run before not-yet-started outer tasks instead of
    /// queueing behind them.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Sets [`IN_POOL_TASK`] for the current scope; restores the previous value
/// on drop, including during unwinding (the inline paths run tasks without
/// a `catch_unwind`).
struct TaskFlagGuard(bool);

impl TaskFlagGuard {
    fn enter() -> TaskFlagGuard {
        TaskFlagGuard(IN_POOL_TASK.with(|w| w.replace(true)))
    }
}

impl Drop for TaskFlagGuard {
    fn drop(&mut self) {
        IN_POOL_TASK.with(|w| w.set(self.0));
    }
}

fn worker_loop(inner: &PoolInner) {
    let mut gate = inner.gate.lock().unwrap();
    loop {
        if gate.shutdown {
            return;
        }
        while gate.queue.front().map(|j| j.exhausted()).unwrap_or(false) {
            // fully claimed: stragglers finish on the threads that claimed
            // the tasks; nothing left for a new worker to pick up
            gate.queue.pop_front();
        }
        match gate.queue.front() {
            Some(job) => {
                let job = job.clone();
                drop(gate);
                job.help();
                gate = inner.gate.lock().unwrap();
            }
            None => gate = inner.work_cv.wait(gate).unwrap(),
        }
    }
}

/// A persistent pool of `width − 1` parked worker threads (the submitting
/// thread is always the width-th worker). `width ≤ 1` never spawns anything
/// and runs every job inline; otherwise the workers start lazily on the
/// first parallel job and shut down when the pool is dropped.
pub struct Pool {
    width: usize,
    inner: OnceLock<Arc<PoolInner>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    pub fn new(width: usize) -> Pool {
        Pool { width: width.max(1), inner: OnceLock::new(), handles: Mutex::new(Vec::new()) }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    fn spawned(&self) -> &Arc<PoolInner> {
        self.inner.get_or_init(|| {
            let inner = Arc::new(PoolInner {
                gate: Mutex::new(Gate { queue: VecDeque::new(), shutdown: false }),
                work_cv: Condvar::new(),
            });
            let mut handles = self.handles.lock().unwrap();
            for i in 0..self.width - 1 {
                let worker = inner.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("pict-par-{i}"))
                    .spawn(move || worker_loop(&worker))
                    .expect("failed to spawn pool worker");
                handles.push(handle);
            }
            inner
        })
    }

    /// Run `task(t)` for every `t` in `0..n_tasks` across the pool,
    /// returning once all tasks have finished. Reentrant: may be called
    /// from inside a pool task (the nested job jumps the queue).
    pub fn run<'a>(&self, n_tasks: usize, task: &'a (dyn Fn(usize) + Sync + 'a)) {
        if n_tasks == 0 {
            return;
        }
        if self.width <= 1 || n_tasks == 1 {
            // the inline paths are still pool-task execution: mark the
            // scope so jobs nested under them keep jumping the queue
            let _flag = TaskFlagGuard::enter();
            for t in 0..n_tasks {
                task(t);
            }
            return;
        }
        let inner = self.spawned();
        // SAFETY: `run` blocks below until `pending` hits zero, i.e. until
        // the last dereference of the erased task reference has completed,
        // so the fake 'static never outlives the real borrow.
        let task: &'static (dyn Fn(usize) + Sync + 'static) = unsafe {
            std::mem::transmute::<
                &'a (dyn Fn(usize) + Sync + 'a),
                &'static (dyn Fn(usize) + Sync + 'static),
            >(task)
        };
        let job = Arc::new(Job {
            task: TaskRef(task as *const _),
            n_tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_tasks),
            panic: Mutex::new(None),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            let mut gate = inner.gate.lock().unwrap();
            if IN_POOL_TASK.with(|w| w.get()) {
                gate.queue.push_front(job.clone());
            } else {
                gate.queue.push_back(job.clone());
            }
        }
        // wake just enough parked workers to cover the tasks the submitter
        // cannot take itself; busy workers re-check the queue before they
        // park, so under-waking cannot strand the job (and notify_all here
        // would thundering-herd every parked worker through the gate lock
        // on each small kernel dispatch)
        for _ in 0..(n_tasks - 1).min(self.width - 1) {
            inner.work_cv.notify_one();
        }
        job.help();
        job.wait();
        if let Some(payload) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.get() {
            inner.gate.lock().unwrap().shutdown = true;
            inner.work_cv.notify_all();
            for handle in self.handles.get_mut().unwrap().drain(..) {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        });
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {t}");
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = Pool::new(1);
        let sum = AtomicUsize::new(0);
        pool.run(10, &|t| {
            sum.fetch_add(t, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
        assert!(pool.inner.get().is_none(), "width-1 pool must not spawn workers");
    }

    #[test]
    fn nested_submission_from_worker_tasks() {
        // outer tasks submit inner jobs on the same pool — the BatchRunner
        // shape. Must complete without deadlock and cover all inner work.
        let pool = Pool::new(3);
        let total = AtomicUsize::new(0);
        pool.run(4, &|_outer| {
            pool.run(8, &|inner| {
                total.fetch_add(inner + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 36);
    }

    #[test]
    fn concurrent_external_submitters() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    pool.run(16, &|t| {
                        total.fetch_add(t, Ordering::SeqCst);
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 3 * 120);
    }

    #[test]
    fn task_panic_reaches_the_submitter() {
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|t| {
                if t == 3 {
                    panic!("boom");
                }
            });
        }));
        let payload = result.expect_err("panic must propagate to the submitter");
        // the original payload is resumed, not a generic pool error
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // the pool survives a poisoned job
        let sum = AtomicUsize::new(0);
        pool.run(4, &|t| {
            sum.fetch_add(t, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 6);
    }
}
