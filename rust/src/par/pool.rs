//! Persistent worker pool: threads spawned once (lazily, on the first
//! parallel job) and parked on a condvar between jobs, replacing the
//! previous spawn-per-call scoped threads whose ~10–20 µs setup tax made
//! parallel kernels unprofitable below large systems.
//!
//! A job is a task function plus a task count. Workers — and the submitting
//! thread, which always helps — claim task indices from a shared counter,
//! so work keyed by task index lands deterministically no matter which
//! worker executes it. Multiple threads may submit concurrently (jobs queue
//! up and drain in order), and submission is reentrant: a task already
//! running on a pool worker may submit a nested job, which is exactly what
//! the scenario-level tasks of
//! [`BatchRunner`](crate::coordinator::scenario::BatchRunner) do for their
//! inner solver kernels. Because the submitter executes its own job's tasks
//! while waiting, nested submission cannot deadlock even when every worker
//! is busy: tasks never block on anything but their own nested jobs, so the
//! wait graph stays acyclic.

// Sync primitives come through the `shim` re-exports: plain `std::sync` in
// ordinary builds, the instrumented model wrappers under `--cfg loom` (see
// `par::model`) — same source, both worlds.
use super::shim::atomic::{AtomicUsize, Ordering};
use super::shim::thread::{self, JoinHandle};
use super::shim::{Arc, Condvar, Mutex, OnceLock};
use std::collections::VecDeque;

/// Erased reference to a job's task function. [`Pool::run`] blocks until
/// every task has finished before returning, so the pointee outlives every
/// dereference despite the erased lifetime.
struct TaskRef(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointee is `Sync` (shared calls are safe) and the erased
// borrow is kept alive by the submitter until the job completes.
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

struct Job {
    task: TaskRef,
    n_tasks: usize,
    /// Claim counter: next unclaimed task index (may overshoot `n_tasks`).
    next: AtomicUsize,
    /// Tasks not yet finished (claimed or not).
    pending: AtomicUsize,
    /// First panic payload caught on a task; resumed on the submitting
    /// thread once the job completes, so the original assertion message
    /// and backtrace context survive the pool boundary.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
    done: Mutex<()>,
    done_cv: Condvar,
}

impl Job {
    /// Claim and execute tasks until the claim counter is exhausted.
    fn help(&self) {
        loop {
            let t = self.next.fetch_add(1, Ordering::SeqCst);
            if t >= self.n_tasks {
                return;
            }
            // SAFETY: tasks are only claimed while the submitter is blocked
            // in `Pool::run`, which keeps the borrow alive (see `TaskRef`).
            let task = unsafe { &*self.task.0 };
            let flag = TaskFlagGuard::enter();
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(t)))
            {
                let mut slot =
                    self.panic.lock().expect("pool mutexes: no code panics while holding them");
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            drop(flag);
            if self.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                // last finisher: take the lock so the notify cannot race
                // between the waiter's predicate check and its wait()
                let _guard =
                    self.done.lock().expect("pool mutexes: no code panics while holding them");
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::SeqCst) >= self.n_tasks
    }

    fn wait(&self) {
        let mut guard = self.done.lock().expect("pool mutexes: no code panics while holding them");
        while self.pending.load(Ordering::SeqCst) != 0 {
            guard = self
                .done_cv
                .wait(guard)
                .expect("pool mutexes: no code panics while holding them");
        }
    }
}

struct Gate {
    queue: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct PoolInner {
    gate: Mutex<Gate>,
    work_cv: Condvar,
}

thread_local! {
    /// True while the current thread is executing a pool task (on a worker,
    /// on a submitter helping its own job, or on an inline fast path):
    /// nested jobs submitted from inside a task jump the queue, so inner
    /// kernel chunks run before not-yet-started outer tasks instead of
    /// queueing behind them.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Sets [`IN_POOL_TASK`] for the current scope; restores the previous value
/// on drop, including during unwinding (the inline paths run tasks without
/// a `catch_unwind`).
struct TaskFlagGuard(bool);

impl TaskFlagGuard {
    fn enter() -> TaskFlagGuard {
        TaskFlagGuard(IN_POOL_TASK.with(|w| w.replace(true)))
    }
}

impl Drop for TaskFlagGuard {
    fn drop(&mut self) {
        IN_POOL_TASK.with(|w| w.set(self.0));
    }
}

fn worker_loop(inner: &PoolInner) {
    let mut gate = inner.gate.lock().expect("pool mutexes: no code panics while holding them");
    loop {
        if gate.shutdown {
            return;
        }
        while gate.queue.front().map(|j| j.exhausted()).unwrap_or(false) {
            // fully claimed: stragglers finish on the threads that claimed
            // the tasks; nothing left for a new worker to pick up
            gate.queue.pop_front();
        }
        match gate.queue.front() {
            Some(job) => {
                let job = job.clone();
                drop(gate);
                job.help();
                gate = inner
                    .gate
                    .lock()
                    .expect("pool mutexes: no code panics while holding them");
            }
            None => {
                gate = inner
                    .work_cv
                    .wait(gate)
                    .expect("pool mutexes: no code panics while holding them");
            }
        }
    }
}

/// A persistent pool of `width − 1` parked worker threads (the submitting
/// thread is always the width-th worker). `width ≤ 1` never spawns anything
/// and runs every job inline; otherwise the workers start lazily on the
/// first parallel job and shut down when the pool is dropped.
pub struct Pool {
    width: usize,
    inner: OnceLock<Arc<PoolInner>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    pub fn new(width: usize) -> Pool {
        Pool { width: width.max(1), inner: OnceLock::new(), handles: Mutex::new(Vec::new()) }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    fn spawned(&self) -> &Arc<PoolInner> {
        self.inner.get_or_init(|| {
            let inner = Arc::new(PoolInner {
                gate: Mutex::new(Gate { queue: VecDeque::new(), shutdown: false }),
                work_cv: Condvar::new(),
            });
            let mut handles =
                self.handles.lock().expect("pool mutexes: no code panics while holding them");
            for i in 0..self.width - 1 {
                let worker = inner.clone();
                let handle = thread::Builder::new()
                    .name(format!("pict-par-{i}"))
                    .spawn(move || worker_loop(&worker))
                    .expect("failed to spawn pool worker");
                handles.push(handle);
            }
            inner
        })
    }

    /// Run `task(t)` for every `t` in `0..n_tasks` across the pool,
    /// returning once all tasks have finished. Reentrant: may be called
    /// from inside a pool task (the nested job jumps the queue).
    pub fn run<'a>(&self, n_tasks: usize, task: &'a (dyn Fn(usize) + Sync + 'a)) {
        if n_tasks == 0 {
            return;
        }
        if self.width <= 1 || n_tasks == 1 {
            // the inline paths are still pool-task execution: mark the
            // scope so jobs nested under them keep jumping the queue
            let _flag = TaskFlagGuard::enter();
            for t in 0..n_tasks {
                task(t);
            }
            return;
        }
        let inner = self.spawned();
        // SAFETY: `run` blocks below until `pending` hits zero, i.e. until
        // the last dereference of the erased task reference has completed,
        // so the fake 'static never outlives the real borrow.
        let task: &'static (dyn Fn(usize) + Sync + 'static) = unsafe {
            std::mem::transmute::<
                &'a (dyn Fn(usize) + Sync + 'a),
                &'static (dyn Fn(usize) + Sync + 'static),
            >(task)
        };
        let job = Arc::new(Job {
            task: TaskRef(task as *const _),
            n_tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_tasks),
            panic: Mutex::new(None),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            let mut gate =
                inner.gate.lock().expect("pool mutexes: no code panics while holding them");
            if IN_POOL_TASK.with(|w| w.get()) {
                gate.queue.push_front(job.clone());
            } else {
                gate.queue.push_back(job.clone());
            }
        }
        // wake just enough parked workers to cover the tasks the submitter
        // cannot take itself; busy workers re-check the queue before they
        // park, so under-waking cannot strand the job (and notify_all here
        // would thundering-herd every parked worker through the gate lock
        // on each small kernel dispatch)
        for _ in 0..(n_tasks - 1).min(self.width - 1) {
            inner.work_cv.notify_one();
        }
        job.help();
        job.wait();
        let payload =
            job.panic.lock().expect("pool mutexes: no code panics while holding them").take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.get() {
            inner
                .gate
                .lock()
                .expect("pool mutexes: no code panics while holding them")
                .shutdown = true;
            inner.work_cv.notify_all();
            let handles = self
                .handles
                .get_mut()
                .expect("pool mutexes: no code panics while holding them");
            for handle in handles.drain(..) {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        });
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {t}");
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = Pool::new(1);
        let sum = AtomicUsize::new(0);
        pool.run(10, &|t| {
            sum.fetch_add(t, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
        assert!(pool.inner.get().is_none(), "width-1 pool must not spawn workers");
    }

    #[test]
    fn nested_submission_from_worker_tasks() {
        // outer tasks submit inner jobs on the same pool — the BatchRunner
        // shape. Must complete without deadlock and cover all inner work.
        let pool = Pool::new(3);
        let total = AtomicUsize::new(0);
        pool.run(4, &|_outer| {
            pool.run(8, &|inner| {
                total.fetch_add(inner + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 36);
    }

    #[test]
    fn concurrent_external_submitters() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    pool.run(16, &|t| {
                        total.fetch_add(t, Ordering::SeqCst);
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 3 * 120);
    }

    #[test]
    fn task_panic_reaches_the_submitter() {
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|t| {
                if t == 3 {
                    panic!("boom");
                }
            });
        }));
        let payload = result.expect_err("panic must propagate to the submitter");
        // the original payload is resumed, not a generic pool error
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // the pool survives a poisoned job
        let sum = AtomicUsize::new(0);
        pool.run(4, &|t| {
            sum.fetch_add(t, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 6);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 50 contended rounds: correct but too slow under Miri
    fn stress_panicking_tasks_under_contention() {
        // Repeated rounds of a panic-injecting job racing a clean job from
        // another submitter: each panic must reach exactly its own
        // submitter, the clean job must be unaffected, and no worker may
        // hang or die — the pool must stay fully serviceable afterwards.
        let pool = Pool::new(4);
        for round in 0..50usize {
            std::thread::scope(|s| {
                let pool = &pool;
                s.spawn(move || {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        pool.run(32, &|t| {
                            if t % 7 == round % 7 {
                                panic!("injected failure");
                            }
                        });
                    }));
                    assert!(result.is_err(), "round {round}: panic must propagate");
                });
                s.spawn(move || {
                    let sum = AtomicUsize::new(0);
                    pool.run(32, &|t| {
                        sum.fetch_add(t, Ordering::SeqCst);
                    });
                    assert_eq!(sum.load(Ordering::SeqCst), 32 * 31 / 2, "round {round}");
                });
            });
        }
        // every worker still answers after 50 poisoned rounds
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn miri_erased_taskref_borrow_is_sound() {
        // Fast Miri target: the lifetime-erased TaskRef dereference and the
        // claim-counter handshake, at a size Miri finishes quickly.
        let pool = Pool::new(2);
        let data: Vec<usize> = (0..8).collect();
        let out: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.run(out.len(), &|t| {
            out[t].store(data[t] * 3 + 1, Ordering::SeqCst);
        });
        for (t, o) in out.iter().enumerate() {
            assert_eq!(o.load(Ordering::SeqCst), t * 3 + 1);
        }
    }
}

// The pool's concurrency protocol model-checked under perturbed schedules:
// build and run with RUSTFLAGS="--cfg loom" so `shim` swaps the sync
// primitives for the instrumented wrappers in `par::model`. Covers the four
// contract-critical behaviors: condvar parking/wakeup, shared-counter task
// claiming, reentrant nested submission, and panic propagation.
#[cfg(all(test, loom))]
mod loom_model {
    use super::*;
    use crate::par::model::model;

    #[test]
    fn loom_condvar_parking_and_wakeup() {
        // Back-to-back jobs force workers through the full park/wake cycle
        // between jobs; a lost wakeup deadlocks and trips the watchdog.
        model("condvar-parking", || {
            let pool = Pool::new(3);
            for _ in 0..3 {
                let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
                pool.run(hits.len(), &|t| {
                    hits[t].fetch_add(1, Ordering::SeqCst);
                });
                assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
            }
        });
    }

    #[test]
    fn loom_shared_counter_claims_every_task_exactly_once() {
        model("task-claiming", || {
            let pool = Pool::new(4);
            let hits: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), &|t| {
                hits[t].fetch_add(1, Ordering::SeqCst);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "task {t}");
            }
        });
    }

    #[test]
    fn loom_reentrant_nested_submission_completes() {
        model("nested-submission", || {
            let pool = Pool::new(3);
            let total = AtomicUsize::new(0);
            pool.run(3, &|_outer| {
                pool.run(4, &|inner| {
                    total.fetch_add(inner + 1, Ordering::SeqCst);
                });
            });
            assert_eq!(total.load(Ordering::SeqCst), 3 * 10);
        });
    }

    #[test]
    fn loom_panic_propagation_leaves_no_hung_worker() {
        model("panic-propagation", || {
            let pool = Pool::new(2);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(6, &|t| {
                    if t == 2 {
                        panic!("boom");
                    }
                });
            }));
            let payload = result.expect_err("panic must reach the submitter");
            assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
            // pool (and its workers) must remain serviceable
            let sum = AtomicUsize::new(0);
            pool.run(4, &|t| {
                sum.fetch_add(t, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), 6);
        });
    }
}
