//! cfg-switched sync primitives for the pool.
//!
//! Default builds re-export `std::sync` so [`pool`](super::pool) compiles
//! to exactly the code it always did. Under `RUSTFLAGS="--cfg loom"` the
//! same names resolve to the instrumented wrappers in [`model`](super::model),
//! which inject schedule perturbation points at every lock acquisition,
//! condvar wait, and atomic RMW — the pool's source is identical in both
//! worlds, so what the model checks is what ships.
//!
//! `Arc` and `OnceLock` are deliberately not instrumented: the pool uses
//! them only for refcounted ownership and once-only lazy spawn, whose
//! interleavings are not interesting to perturb. The contended state the
//! model explores lives entirely behind `Mutex`/`Condvar`/`AtomicUsize`.

#[cfg(not(loom))]
pub(crate) use std::sync::atomic;
#[cfg(not(loom))]
pub(crate) use std::sync::{Condvar, Mutex};
#[cfg(not(loom))]
pub(crate) use std::thread;

#[cfg(loom)]
pub(crate) use super::model::sync::atomic;
#[cfg(loom)]
pub(crate) use super::model::sync::{Condvar, Mutex};
#[cfg(loom)]
pub(crate) use super::model::thread;

pub(crate) use std::sync::{Arc, OnceLock};
