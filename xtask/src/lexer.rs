//! Minimal Rust token scanner for the lint pass.
//!
//! `syn` cannot be vendored into the offline build, so the lint rules run on
//! this hand-rolled lexer instead of a real AST. It only needs to be precise
//! about the things that make naive `grep`-style linting wrong: comments
//! (line, nested block, doc), string/char literals (including raw strings
//! and escapes), and lifetimes vs char literals. Everything else is emitted
//! as identifiers and punctuation with 1-based line numbers, which is enough
//! for the path/method-call patterns the rules match.

/// One lexed token.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    /// String literal (normal, raw, or byte); payload is the raw contents
    /// between the delimiters, escapes untouched.
    Str(String),
    /// Char or byte-char literal.
    Char,
    /// Numeric literal; payload is the literal text (digits, `_`, radix
    /// prefix, exponent, suffix) so rules can tell float from integer
    /// literals (e.g. the float-determinism `fold` seed check).
    Num(String),
    Lifetime,
    /// The `::` path separator (collapsed into one token for rule matching).
    PathSep,
    Punct(char),
    /// Comment including its delimiters (`// …`, `/* … */`, doc forms).
    Comment(String),
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    /// 1-based line of the token's first character.
    pub line: usize,
    /// 1-based line of the token's last character (differs from `line` only
    /// for block comments and multi-line strings).
    pub end_line: usize,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }
}

pub fn lex(src: &str) -> Vec<Token> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: usize,
    out: Vec<Token>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, tok: Tok, line: usize) {
        self.out.push(Token { tok, line, end_line: self.line });
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(c) = self.peek(0) {
            let start = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(start),
                '/' if self.peek(1) == Some('*') => self.block_comment(start),
                '"' => {
                    self.bump();
                    let s = self.string_body();
                    self.push(Tok::Str(s), start);
                }
                '\'' => self.char_or_lifetime(start),
                c if c.is_ascii_digit() => self.number(start),
                c if c == '_' || c.is_alphabetic() => self.ident_or_prefixed(start),
                ':' if self.peek(1) == Some(':') => {
                    self.bump();
                    self.bump();
                    self.push(Tok::PathSep, start);
                }
                c => {
                    self.bump();
                    self.push(Tok::Punct(c), start);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self, start: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push(Tok::Comment(text), start);
    }

    fn block_comment(&mut self, start: usize) {
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.push(Tok::Comment(text), start);
    }

    /// Body of a normal (escaped) string; the opening `"` is consumed.
    fn string_body(&mut self) -> String {
        let mut s = String::new();
        while let Some(c) = self.bump() {
            match c {
                '"' => break,
                '\\' => {
                    s.push('\\');
                    if let Some(e) = self.bump() {
                        s.push(e);
                    }
                }
                c => s.push(c),
            }
        }
        s
    }

    /// Raw string after the `r`/`br` prefix: `#…#"` then contents until
    /// `"#…#` with the same hash count.
    fn raw_string_body(&mut self) -> String {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        let mut s = String::new();
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                for a in 0..hashes {
                    if self.peek(a) != Some('#') {
                        s.push('"');
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            s.push(c);
        }
        s
    }

    fn char_or_lifetime(&mut self, start: usize) {
        self.bump(); // the opening quote
        match (self.peek(0), self.peek(1)) {
            // lifetime: 'ident not closed by a quote ('a, 'static — but 'a'
            // with a closing quote is a char)
            (Some(c), after) if (c == '_' || c.is_alphabetic()) && after != Some('\'') => {
                while let Some(c) = self.peek(0) {
                    if c == '_' || c.is_alphanumeric() {
                        self.bump();
                    } else {
                        break;
                    }
                }
                self.push(Tok::Lifetime, start);
            }
            // char literal, escaped or plain: consume to the closing quote
            _ => {
                while let Some(c) = self.bump() {
                    match c {
                        '\\' => {
                            self.bump();
                        }
                        '\'' => break,
                        _ => {}
                    }
                }
                self.push(Tok::Char, start);
            }
        }
    }

    fn number(&mut self, start: usize) {
        let mut text = String::new();
        // a digit run lexed right after a `.` is a tuple index (`t.0.1`):
        // it never absorbs a further decimal point of its own
        let after_dot = matches!(self.out.last(), Some(Token { tok: Tok::Punct('.'), .. }));
        let radix_prefix = self.peek(0) == Some('0')
            && matches!(self.peek(1), Some('x' | 'X' | 'o' | 'O' | 'b' | 'B'));
        while let Some(c) = self.peek(0) {
            if c.is_ascii_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else if c == '.'
                && !after_dot
                && !text.contains('.')
                && self.peek(1).map(|d| d.is_ascii_digit()).unwrap_or(false)
            {
                // decimal point only when followed by a digit, so `0..n`
                // range syntax is left as two `.` puncts
                text.push(c);
                self.bump();
            } else if (c == '+' || c == '-')
                && !radix_prefix
                && matches!(text.chars().last(), Some('e' | 'E'))
                && self.peek(1).map(|d| d.is_ascii_digit()).unwrap_or(false)
            {
                // signed exponent: `1.5e-3` is one literal (`0x1e - 3` is
                // not: hex digits never grow an exponent)
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(Tok::Num(text), start);
    }

    fn ident_or_prefixed(&mut self, start: usize) {
        let mut name = String::new();
        while let Some(c) = self.peek(0) {
            if c == '_' || c.is_alphanumeric() {
                name.push(c);
                self.bump();
            } else {
                break;
            }
        }
        // string/char-literal prefixes
        match (name.as_str(), self.peek(0)) {
            ("r" | "br" | "cr", Some('"')) => {
                let s = self.raw_string_body();
                self.push(Tok::Str(s), start);
            }
            ("r" | "br" | "cr", Some('#')) => {
                // raw string r#"…"# — or a raw identifier r#keyword
                let mut a = 0usize;
                while self.peek(a) == Some('#') {
                    a += 1;
                }
                if self.peek(a) == Some('"') {
                    let s = self.raw_string_body();
                    self.push(Tok::Str(s), start);
                } else {
                    self.bump(); // the #
                    self.ident_or_prefixed(start);
                }
            }
            ("b" | "c", Some('"')) => {
                self.bump();
                let s = self.string_body();
                self.push(Tok::Str(s), start);
            }
            ("b", Some('\'')) => {
                self.char_or_lifetime(start);
                // re-tag: a byte char is a char literal even though
                // char_or_lifetime pushed it already
            }
            _ => self.push(Tok::Ident(name), start),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| t.ident().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_code() {
        let src = r##"
            // thread::spawn in a comment
            /* unwrap() in /* a nested */ block */
            let s = "thread::spawn(unwrap())";
            let r = r#"env::var("X")"#;
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"spawn".to_string()), "{ids:?}");
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"var".to_string()), "{ids:?}");
        assert!(ids.contains(&"let".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes = toks.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let chars = toks.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 1);
        // the identifiers survive
        let ids = toks.iter().filter_map(|t| t.ident()).collect::<Vec<_>>();
        assert!(ids.contains(&"str"));
    }

    #[test]
    fn path_sep_is_one_token() {
        let toks = lex("std::thread::spawn");
        let kinds: Vec<&Tok> = toks.iter().map(|t| &t.tok).collect();
        assert_eq!(
            kinds,
            vec![
                &Tok::Ident("std".into()),
                &Tok::PathSep,
                &Tok::Ident("thread".into()),
                &Tok::PathSep,
                &Tok::Ident("spawn".into()),
            ]
        );
    }

    #[test]
    fn ranges_are_not_floats() {
        let toks = lex("for i in 0..n { a[i] = 1.5; }");
        let puncts = toks.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(puncts, 2, "the `..` of the range must remain two puncts");
    }

    #[test]
    fn line_numbers_track_block_comments() {
        let src = "a\n/* one\ntwo\nthree */\nunsafe";
        let toks = lex(src);
        let c = toks.iter().find(|t| matches!(t.tok, Tok::Comment(_))).expect("comment token");
        assert_eq!((c.line, c.end_line), (2, 4));
        let u = toks.iter().find(|t| t.ident() == Some("unsafe")).expect("unsafe token");
        assert_eq!(u.line, 5);
    }

    #[test]
    fn raw_strings_respect_hash_counts() {
        // a `"#` inside an `r##"…"##` body must not close the literal
        let src = r####"let a = r##"x "# y"##; let b = unwrap;"####;
        let toks = lex(src);
        let strs: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec![r##"x "# y"##]);
        // the identifier after the literal is real code again
        let ids: Vec<&str> = toks.iter().filter_map(|t| t.ident()).collect();
        assert_eq!(ids, vec!["let", "a", "let", "b", "unwrap"]);
    }

    #[test]
    fn raw_string_spans_track_lines() {
        let src = "r#\"one\ntwo\nthree\"#\nunsafe";
        let toks = lex(src);
        let s = toks.iter().find(|t| matches!(t.tok, Tok::Str(_))).expect("raw string token");
        assert_eq!((s.line, s.end_line), (1, 3));
        let u = toks.iter().find(|t| t.ident() == Some("unsafe")).expect("unsafe token");
        assert_eq!(u.line, 4);
    }

    #[test]
    fn nested_block_comments_close_at_matching_depth() {
        // the inner `*/`s must not end the outer comment — only the one
        // matching the outermost `/*` does, at the right end line
        let src = "/* a /* b\n/* c */ d */ e */\nfn after() {}";
        let toks = lex(src);
        let c = toks.iter().find(|t| matches!(t.tok, Tok::Comment(_))).expect("comment token");
        assert_eq!((c.line, c.end_line), (1, 2));
        let ids: Vec<&str> = toks.iter().filter_map(|t| t.ident()).collect();
        assert_eq!(ids, vec!["fn", "after"]);
        let f = toks.iter().find(|t| t.ident() == Some("fn")).expect("fn token");
        assert_eq!(f.line, 3);
    }

    #[test]
    fn byte_and_raw_byte_literals() {
        let toks = lex(r##"let x = b"bytes"; let y = br#"raw"#; let z = b'q';"##);
        let strs = toks.iter().filter(|t| matches!(t.tok, Tok::Str(_))).count();
        assert_eq!(strs, 2);
        let chars = toks.iter().filter(|t| matches!(t.tok, Tok::Char)).count();
        assert_eq!(chars, 1);
        let ids: Vec<&str> = toks.iter().filter_map(|t| t.ident()).collect();
        assert_eq!(ids, vec!["let", "x", "let", "y", "let", "z"]);
    }

    #[test]
    fn tuple_access_is_not_a_float() {
        let toks = lex("let v = t.0.1;");
        let nums: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["0", "1"], "tuple indices must stay separate integer tokens");
        let dots = toks.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(dots, 2);
    }

    #[test]
    fn exponent_floats_are_one_token() {
        let toks = lex("let a = 1.5e-3; let b = 2E+7; let c = 0x1e - 3; let d = 1e10;");
        let nums: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["1.5e-3", "2E+7", "0x1e", "3", "1e10"]);
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let toks = lex(r#"let s = "a\"b"; let t = 'c';"#);
        let strs = toks.iter().filter(|t| matches!(t.tok, Tok::Str(_))).count();
        assert_eq!(strs, 1);
        let ids = toks.iter().filter_map(|t| t.ident()).collect::<Vec<_>>();
        assert_eq!(ids, vec!["let", "s", "let", "t"]);
    }
}
