//! Item-level recursive-descent parse over the lexer's token stream.
//!
//! The analyze pass needs more structure than the token-pattern lint rules:
//! which fn a token belongs to, what parameters a fn takes, which fields a
//! struct declares, where loop bodies begin and end. This parser recovers
//! exactly that — items, fn signatures, struct fields, use-trees, and
//! loop/block extents — without attempting a full expression grammar. It is
//! approximate by design (no type inference, no macro expansion); every
//! consumer documents how it copes with the approximation.
//!
//! All token indices below refer to the *comment-free* stream the caller
//! passes in (comments are stripped before parsing so indices line up with
//! the rule masks).

use crate::lexer::{Tok, Token};

/// One `name: Type` function parameter (patterns collapse to their last
/// binding ident; `self` receivers get the name `self` and no type idents).
#[derive(Debug, Clone)]
pub struct Param {
    pub name: String,
    /// Identifiers appearing in the parameter's type, in order
    /// (`&mut par::ExecCtx` → `["par", "ExecCtx"]`).
    pub ty_idents: Vec<String>,
}

/// A `fn` item (free fn, method, or bodyless trait declaration).
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    pub params: Vec<Param>,
    /// Inclusive token-index range of the body `{ … }` braces; `None` for
    /// trait-method declarations without a default body.
    pub body: Option<(usize, usize)>,
}

impl FnItem {
    /// Whether token index `i` falls inside this fn's body.
    pub fn contains(&self, i: usize) -> bool {
        self.body.map(|(s, e)| s <= i && i <= e).unwrap_or(false)
    }
}

/// A `struct` item with named fields (tuple/unit structs keep an empty
/// field list).
#[derive(Debug, Clone)]
pub struct StructItem {
    pub name: String,
    pub line: usize,
    /// `(field name, 1-based line)` in declaration order.
    pub fields: Vec<(String, usize)>,
}

/// Everything the analyzer extracts from one file.
#[derive(Debug, Default)]
pub struct ParsedFile {
    pub fns: Vec<FnItem>,
    pub structs: Vec<StructItem>,
    /// Flattened `use` paths: one segment vector per imported leaf
    /// (`use a::{b, c::d};` → `[a,b]`, `[a,c,d]`).
    pub uses: Vec<Vec<String>>,
    /// Inclusive token ranges of `for`/`while`/`loop` bodies (nested loops
    /// each get their own range).
    pub loops: Vec<(usize, usize)>,
}

impl ParsedFile {
    /// Whether token index `i` is inside any loop body.
    pub fn in_loop(&self, i: usize) -> bool {
        self.loops.iter().any(|&(s, e)| s <= i && i <= e)
    }

    /// The fn whose body contains token index `i` (innermost not needed:
    /// nested fns are rare and the first match is the enclosing item).
    pub fn enclosing_fn(&self, i: usize) -> Option<&FnItem> {
        self.fns.iter().find(|f| f.contains(i))
    }
}

/// Parse a comment-free token stream.
pub fn parse(code: &[Token]) -> ParsedFile {
    let mut out = ParsedFile::default();
    // `impl Trait for Type {` — the `for` in an impl header is not a loop
    let mut in_impl_header = false;
    let mut i = 0;
    while i < code.len() {
        match code[i].ident() {
            Some("impl") => in_impl_header = true,
            Some("fn") => {
                if let Some(f) = parse_fn(code, i) {
                    out.fns.push(f);
                }
                // do not skip the body: nested loops/structs are found by
                // continuing the walk
            }
            Some("struct") => {
                if let Some(s) = parse_struct(code, i) {
                    out.structs.push(s);
                }
            }
            Some("use") => {
                let (paths, next) = parse_use(code, i + 1);
                out.uses.extend(paths);
                i = next;
                continue;
            }
            Some("for" | "while" | "loop") => {
                let hrtb = code.get(i + 1).map(|t| t.is_punct('<')).unwrap_or(false);
                if !in_impl_header && !hrtb {
                    if let Some(range) = loop_body(code, i) {
                        out.loops.push(range);
                    }
                }
            }
            _ => {
                if code[i].is_punct('{') {
                    in_impl_header = false;
                }
            }
        }
        i += 1;
    }
    out
}

/// Skip a `<…>` generic-parameter list starting at `i` (which must point at
/// the `<`), tolerating `->` inside `Fn(…) -> T` bounds. Returns the index
/// one past the closing `>`.
fn skip_generics(code: &[Token], i: usize) -> usize {
    let mut depth = 0usize;
    let mut j = i;
    while j < code.len() {
        if code[j].is_punct('<') {
            depth += 1;
        } else if code[j].is_punct('>') {
            // the `>` of a `->` return arrow does not close a generic
            let arrow = j >= 1 && code[j - 1].is_punct('-');
            if !arrow {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
        }
        j += 1;
    }
    j
}

/// Parse the fn whose `fn` keyword is at index `i`.
fn parse_fn(code: &[Token], i: usize) -> Option<FnItem> {
    let name = code.get(i + 1)?.ident()?.to_string();
    let line = code[i].line;
    let mut j = i + 2;
    if code.get(j).map(|t| t.is_punct('<')).unwrap_or(false) {
        j = skip_generics(code, j);
    }
    if !code.get(j).map(|t| t.is_punct('(')).unwrap_or(false) {
        return None;
    }
    // --- parameters: split at top-level commas inside ( … ) ---
    let mut params = Vec::new();
    let mut depth = 1usize;
    let mut seg: Vec<usize> = Vec::new();
    j += 1;
    while j < code.len() && depth > 0 {
        match &code[j].tok {
            Tok::Punct('(' | '[' | '{') => depth += 1,
            Tok::Punct(')' | ']' | '}') => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            Tok::Punct(',') if depth == 1 => {
                if let Some(p) = parse_param(code, &seg) {
                    params.push(p);
                }
                seg.clear();
                j += 1;
                continue;
            }
            _ => {}
        }
        seg.push(j);
        j += 1;
    }
    if let Some(p) = parse_param(code, &seg) {
        params.push(p);
    }
    // --- return type / where clause, then body or `;` ---
    let mut depth = 0usize;
    while j < code.len() {
        match &code[j].tok {
            Tok::Punct('(' | '[') => depth += 1,
            Tok::Punct(')' | ']') => depth = depth.saturating_sub(1),
            Tok::Punct(';') if depth == 0 => {
                return Some(FnItem { name, line, params, body: None });
            }
            Tok::Punct('{') if depth == 0 => {
                let end = match_brace(code, j)?;
                return Some(FnItem { name, line, params, body: Some((j, end)) });
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// One parameter segment (token indices between top-level commas):
/// the name is the last ident before the top-level `:` (so `mut x: T`
/// binds `x`), the type idents are everything after it.
fn parse_param(code: &[Token], seg: &[usize]) -> Option<Param> {
    if seg.is_empty() {
        return None;
    }
    let mut colon = None;
    let mut depth = 0usize;
    for (k, &idx) in seg.iter().enumerate() {
        match &code[idx].tok {
            Tok::Punct('(' | '[' | '<') => depth += 1,
            Tok::Punct(')' | ']' | '>') => depth = depth.saturating_sub(1),
            Tok::Punct(':') if depth == 0 => {
                colon = Some(k);
                break;
            }
            _ => {}
        }
    }
    match colon {
        Some(k) => {
            let name = seg[..k]
                .iter()
                .rev()
                .find_map(|&idx| code[idx].ident())?
                .to_string();
            let ty_idents = seg[k + 1..]
                .iter()
                .filter_map(|&idx| code[idx].ident().map(str::to_string))
                .collect();
            Some(Param { name, ty_idents })
        }
        // `self` / `&self` / `&mut self` receivers have no `:`
        None => {
            let name = seg.iter().rev().find_map(|&idx| code[idx].ident())?.to_string();
            (name == "self").then_some(Param { name, ty_idents: vec![] })
        }
    }
}

/// Parse the struct whose `struct` keyword is at index `i`.
fn parse_struct(code: &[Token], i: usize) -> Option<StructItem> {
    let name = code.get(i + 1)?.ident()?.to_string();
    let line = code[i].line;
    let mut j = i + 2;
    if code.get(j).map(|t| t.is_punct('<')).unwrap_or(false) {
        j = skip_generics(code, j);
    }
    // where clause before the body
    while j < code.len() && !code[j].is_punct('{') && !code[j].is_punct('(') && !code[j].is_punct(';')
    {
        j += 1;
    }
    let mut fields = Vec::new();
    if code.get(j).map(|t| t.is_punct('{')).unwrap_or(false) {
        let end = match_brace(code, j)?;
        let mut depth = 0usize;
        for k in j..=end.min(code.len() - 1) {
            match &code[k].tok {
                Tok::Punct('{' | '(' | '[') => depth += 1,
                Tok::Punct('}' | ')' | ']') => depth = depth.saturating_sub(1),
                // a field name is an ident directly followed by `:` at
                // brace depth 1 (`::` is a distinct PathSep token, and
                // generic bounds never put a bare `:` at this depth)
                Tok::Ident(f) if depth == 1 => {
                    if code.get(k + 1).map(|t| t.is_punct(':')).unwrap_or(false) {
                        fields.push((f.clone(), code[k].line));
                    }
                }
                _ => {}
            }
        }
    }
    Some(StructItem { name, line, fields })
}

/// Flatten the use-tree starting right after the `use` keyword at `start`.
/// Returns the flattened paths and the index one past the closing `;`.
fn parse_use(code: &[Token], start: usize) -> (Vec<Vec<String>>, usize) {
    fn tree(code: &[Token], mut j: usize, prefix: &[String], out: &mut Vec<Vec<String>>) -> usize {
        let mut path = prefix.to_vec();
        while j < code.len() {
            match &code[j].tok {
                Tok::Ident(s) => {
                    // `as alias` renames the leaf: record the alias instead
                    if s == "as" {
                        if let Some(alias) = code.get(j + 1).and_then(|t| t.ident()) {
                            if let Some(last) = path.last_mut() {
                                *last = alias.to_string();
                            }
                            j += 1;
                        }
                    } else {
                        path.push(s.clone());
                    }
                    j += 1;
                }
                Tok::Punct('*') => {
                    path.push("*".to_string());
                    j += 1;
                }
                Tok::PathSep => {
                    if code.get(j + 1).map(|t| t.is_punct('{')).unwrap_or(false) {
                        // group: recurse per branch
                        j += 2;
                        loop {
                            j = tree(code, j, &path, out);
                            match code.get(j).map(|t| &t.tok) {
                                Some(Tok::Punct(',')) => j += 1,
                                Some(Tok::Punct('}')) => {
                                    j += 1;
                                    break;
                                }
                                _ => break,
                            }
                        }
                        return j;
                    }
                    j += 1;
                }
                _ => break,
            }
        }
        if path.len() > prefix.len() {
            out.push(path);
        }
        j
    }
    let mut out = Vec::new();
    let mut j = tree(code, start, &[], &mut out);
    while j < code.len() && !code[j].is_punct(';') {
        j += 1;
    }
    (out, j + 1)
}

/// Body range of the loop whose keyword is at index `i`: the first `{` at
/// paren/bracket depth 0 after the keyword opens the body (Rust forbids
/// bare struct literals in loop-header expressions, and closure bodies in a
/// header sit inside call parens).
fn loop_body(code: &[Token], i: usize) -> Option<(usize, usize)> {
    let mut depth = 0usize;
    let mut j = i + 1;
    while j < code.len() {
        match &code[j].tok {
            Tok::Punct('(' | '[') => depth += 1,
            Tok::Punct(')' | ']') => depth = depth.saturating_sub(1),
            Tok::Punct('{') if depth == 0 => {
                let end = match_brace(code, j)?;
                return Some((j, end));
            }
            Tok::Punct(';') if depth == 0 => return None,
            _ => {}
        }
        j += 1;
    }
    None
}

/// Index of the `}` matching the `{` at `open`.
fn match_brace(code: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in code.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::{lex, Tok, Token};

    fn code(src: &str) -> Vec<Token> {
        lex(src).into_iter().filter(|t| !matches!(t.tok, Tok::Comment(_))).collect()
    }

    #[test]
    fn fn_signature_and_body() {
        let toks = code("pub fn solve(ctx: &ExecCtx, mut rhs: Vec<f64>) -> f64 { rhs[0] }");
        let p = parse(&toks);
        assert_eq!(p.fns.len(), 1);
        let f = &p.fns[0];
        assert_eq!(f.name, "solve");
        assert_eq!(f.params.len(), 2);
        assert_eq!(f.params[0].name, "ctx");
        assert_eq!(f.params[0].ty_idents, vec!["ExecCtx"]);
        assert_eq!(f.params[1].name, "rhs");
        assert_eq!(f.params[1].ty_idents, vec!["Vec", "f64"]);
        assert!(f.body.is_some());
    }

    #[test]
    fn generic_fn_with_closure_bound_parses() {
        let toks = code("fn sum_by<F: Fn(usize) -> f64>(n: usize, f: F) -> f64 { f(n) }");
        let p = parse(&toks);
        assert_eq!(p.fns.len(), 1);
        assert_eq!(p.fns[0].name, "sum_by");
        assert_eq!(p.fns[0].params.len(), 2);
        assert_eq!(p.fns[0].params[1].name, "f");
    }

    #[test]
    fn self_receivers_and_trait_decls() {
        let toks = code(
            "trait P { fn apply(&self, ctx: &ExecCtx, r: &[f64]); }\n\
             impl P for J { fn apply(&self, ctx: &ExecCtx, r: &[f64]) { ctx.run(r); } }",
        );
        let p = parse(&toks);
        assert_eq!(p.fns.len(), 2);
        assert!(p.fns[0].body.is_none(), "trait decl has no body");
        assert!(p.fns[1].body.is_some());
        assert_eq!(p.fns[1].params[0].name, "self");
        assert_eq!(p.fns[1].params[1].name, "ctx");
    }

    #[test]
    fn struct_fields_with_lines() {
        let toks = code("pub struct StepRecord {\n    pub dt: f64,\n    pub vals: Vec<f64>,\n}");
        let p = parse(&toks);
        assert_eq!(p.structs.len(), 1);
        let s = &p.structs[0];
        assert_eq!(s.name, "StepRecord");
        assert_eq!(
            s.fields,
            vec![("dt".to_string(), 2), ("vals".to_string(), 3)]
        );
    }

    #[test]
    fn struct_literal_fields_are_not_declarations() {
        // the literal inside the fn must not register as a struct item
        let toks = code("struct A { x: f64 }\nfn mk() -> A { A { x: 1.0 } }");
        let p = parse(&toks);
        assert_eq!(p.structs.len(), 1);
        assert_eq!(p.structs[0].fields.len(), 1);
    }

    #[test]
    fn loops_are_found_and_impl_for_is_not_a_loop() {
        let toks = code(
            "impl Trait for Thing {\n\
               fn go(&self, n: usize) {\n\
                 for i in 0..n { work(i); }\n\
                 while n > 0 { step(); }\n\
                 loop { break; }\n\
               }\n\
             }",
        );
        let p = parse(&toks);
        assert_eq!(p.loops.len(), 3, "for/while/loop each get a body range");
        // all loop ranges sit inside the fn body
        let f = &p.fns[0];
        for &(s, e) in &p.loops {
            assert!(f.contains(s) && f.contains(e));
        }
    }

    #[test]
    fn loop_header_closures_do_not_open_the_body_early() {
        let toks = code("fn f(v: &[f64]) { for x in v.iter().map(|a| a * 2.0) { use_it(x); } }");
        let p = parse(&toks);
        assert_eq!(p.loops.len(), 1);
        let (s, _) = p.loops[0];
        // the body must start after the closing paren of .map(...)
        let use_it = toks.iter().position(|t| t.ident() == Some("use_it")).expect("use_it call");
        assert!(s < use_it);
        let map_call = toks.iter().position(|t| t.ident() == Some("map")).expect("map call");
        assert!(s > map_call);
    }

    #[test]
    fn use_trees_flatten() {
        let toks = code("use crate::linsolve::{bicgstab, cg, Ilu0 as Ilu};\nuse std::path::Path;");
        let p = parse(&toks);
        let paths: Vec<String> = p.uses.iter().map(|u| u.join("::")).collect();
        assert_eq!(
            paths,
            vec![
                "crate::linsolve::bicgstab",
                "crate::linsolve::cg",
                "crate::linsolve::Ilu",
                "std::path::Path",
            ]
        );
    }
}
