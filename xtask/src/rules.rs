//! The project-invariant lint rules.
//!
//! PICT's training contract is *bit-for-bit*: pool kernels equal serial,
//! checkpointed gradients equal full-tape gradients. Those guarantees are
//! properties of code discipline — all parallelism flows through
//! [`ExecCtx`], reductions combine partials in fixed chunk order, numeric
//! paths never iterate hash containers — and this pass makes the discipline
//! machine-checked instead of review-checked. Each rule below names the
//! invariant it protects; the fixture tests in this file prove every rule
//! fires on a seeded violation (no rule is vacuously green).

use crate::lexer::{lex, Tok, Token};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to `rust/src`.
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rust/src/{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Modules whose per-element arithmetic feeds gradients: hash-order
/// iteration or ad-hoc cross-thread state here breaks the bit-for-bit
/// determinism contract.
pub(crate) const NUMERIC_MODULES: &[&str] =
    &["sparse/", "linsolve/", "fvm/", "piso/", "adjoint/", "stats/", "nn/", "train/", "mesh/"];

/// Identifiers that mean "hash-ordered container".
const HASH_IDENTS: &[&str] = &["HashMap", "HashSet", "hash_map", "hash_set"];

/// Sync primitives that enable ad-hoc (claim-order, hence nondeterministic)
/// parallel reductions when used outside `par`'s fixed-chunk helpers.
const SYNC_IDENTS: &[&str] = &[
    "Mutex",
    "RwLock",
    "Condvar",
    "AtomicBool",
    "AtomicU32",
    "AtomicU64",
    "AtomicUsize",
    "AtomicI32",
    "AtomicI64",
    "AtomicIsize",
    "AtomicPtr",
    "mpsc",
];

pub(crate) fn in_module(file: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| file.starts_with(p))
}

/// Lint one file; `file` is the path relative to `rust/src` with `/`
/// separators (e.g. `linsolve/cg.rs`).
pub fn check_file(file: &str, src: &str) -> Vec<Violation> {
    let tokens = lex(src);
    // comments feed only the SAFETY rule; rules that match token sequences
    // run on the comment-free stream. Contiguous `//` lines form one logical
    // comment (a SAFETY argument often spans several lines, with the keyword
    // on the first), so adjacent comment tokens are merged into runs.
    let mut comments: Vec<(usize, usize, bool)> = Vec::new();
    for t in &tokens {
        if let Tok::Comment(text) = &t.tok {
            let safety = text.contains("SAFETY") || text.contains("# Safety");
            match comments.last_mut() {
                Some((_, end, has_safety)) if t.line <= *end + 1 => {
                    *end = t.end_line.max(*end);
                    *has_safety |= safety;
                }
                _ => comments.push((t.line, t.end_line, safety)),
            }
        }
    }
    let code: Vec<Token> =
        tokens.into_iter().filter(|t| !matches!(t.tok, Tok::Comment(_))).collect();
    let test = test_mask(&code);

    let mut out = Vec::new();
    rule_thread(file, &code, &test, &mut out);
    rule_pool_construction(file, &code, &test, &mut out);
    rule_env(file, &code, &test, &mut out);
    rule_hash_iteration(file, &code, &test, &mut out);
    rule_adhoc_sync(file, &code, &test, &mut out);
    rule_unwrap(file, &code, &test, &mut out);
    rule_expect_message(file, &code, &test, &mut out);
    rule_unsafe_safety(file, &code, &comments, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
    out
}

/// Mark every token belonging to a `#[test]`- or `#[cfg(test)]`-attributed
/// item (including the whole `#[cfg(test)] mod tests { … }` body). The lint
/// rules police shipped solver code; tests are free to unwrap, spawn
/// helper threads, and so on.
pub(crate) fn test_mask(code: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        if !(code[i].is_punct('#') && code.get(i + 1).map(|t| t.is_punct('[')).unwrap_or(false)) {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + 2;
        let mut depth = 1usize; // the opening [
        let mut idents: Vec<&str> = Vec::new();
        while j < code.len() && depth > 0 {
            match &code[j].tok {
                Tok::Punct('[') => depth += 1,
                Tok::Punct(']') => depth -= 1,
                Tok::Ident(s) => idents.push(s),
                _ => {}
            }
            j += 1;
        }
        let is_test_attr = match idents.first() {
            Some(&"test") => true,
            Some(&"cfg") => idents.iter().any(|s| *s == "test"),
            _ => false,
        };
        if !is_test_attr {
            i = j;
            continue;
        }
        // skip further attributes stacked on the same item
        let mut k = j;
        while k < code.len()
            && code[k].is_punct('#')
            && code.get(k + 1).map(|t| t.is_punct('[')).unwrap_or(false)
        {
            let mut d = 0usize;
            k += 1;
            while k < code.len() {
                if code[k].is_punct('[') {
                    d += 1;
                }
                if code[k].is_punct(']') {
                    d -= 1;
                    if d == 0 {
                        k += 1;
                        break;
                    }
                }
                k += 1;
            }
        }
        // consume the attributed item: ends at `;` at bracket depth 0
        // (use/const/extern items) or at the `}` closing its first
        // depth-0 `{` (fn/mod/impl bodies)
        let mut d = 0i64;
        let mut body_seen = false;
        while k < code.len() {
            match &code[k].tok {
                Tok::Punct('{') => {
                    d += 1;
                    body_seen = true;
                }
                Tok::Punct('(') | Tok::Punct('[') => d += 1,
                Tok::Punct('}') | Tok::Punct(')') | Tok::Punct(']') => {
                    d -= 1;
                    if body_seen && d == 0 {
                        k += 1;
                        break;
                    }
                }
                Tok::Punct(';') if d == 0 => {
                    k += 1;
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        for m in mask.iter_mut().take(k.min(code.len())).skip(attr_start) {
            *m = true;
        }
        i = k;
    }
    mask
}

/// All parallelism flows through `par::ExecCtx` — raw thread creation
/// anywhere else bypasses the width/determinism contract (and the loom
/// model, which only covers `par::Pool`).
fn rule_thread(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if in_module(file, &["par/"]) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if test[i] || t.ident() != Some("thread") {
            continue;
        }
        let after_std = i >= 2
            && code[i - 1].tok == Tok::PathSep
            && code[i - 2].ident() == Some("std");
        let calls_primitive = code.get(i + 1).map(|t| t.tok == Tok::PathSep).unwrap_or(false)
            && matches!(
                code.get(i + 2).and_then(|t| t.ident()),
                Some("spawn" | "scope" | "Builder")
            );
        if after_std || calls_primitive {
            out.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: "thread-outside-par",
                msg: "raw std::thread use outside par/: all parallelism must flow through \
                      par::ExecCtx (run_tasks/run_chunks) so pool width, determinism, and \
                      panic propagation stay under the one modeled implementation"
                    .to_string(),
            });
        }
    }
}

/// `Pool::new` outside `par/` creates a second pool per call site;
/// `ExecCtx::with_threads` / `from_env` are the sanctioned constructors so
/// every layer shares (and threads through) one pool handle.
fn rule_pool_construction(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if in_module(file, &["par/"]) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if test[i] || t.ident() != Some("Pool") {
            continue;
        }
        if code.get(i + 1).map(|t| t.tok == Tok::PathSep).unwrap_or(false)
            && code.get(i + 2).and_then(|t| t.ident()) == Some("new")
        {
            out.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: "pool-outside-par",
                msg: "direct Pool construction outside par/: build an ExecCtx \
                      (with_threads/from_env) and pass it down instead"
                    .to_string(),
            });
        }
    }
}

/// Environment reads concentrate in `util` and the single documented
/// `par::env_threads` (`PICT_THREADS`): scattered `env::var` calls are how
/// hidden global state sneaks back into kernels whose results must be a
/// function of the ExecCtx alone.
fn rule_env(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if in_module(file, &["util/"]) {
        return;
    }
    // par/mod.rs owns exactly one sanctioned read: env_threads()
    let budget = if file == "par/mod.rs" { 1usize } else { 0 };
    let mut seen = 0usize;
    for (i, t) in code.iter().enumerate() {
        if test[i] || t.ident() != Some("env") {
            continue;
        }
        if code.get(i + 1).map(|t| t.tok == Tok::PathSep).unwrap_or(false)
            && code.get(i + 2).and_then(|t| t.ident()) == Some("var")
        {
            seen += 1;
            if seen > budget {
                out.push(Violation {
                    file: file.to_string(),
                    line: t.line,
                    rule: "env-outside-util",
                    msg: "env::var outside util/ (and the single par::env_threads read): \
                          solver behavior must be a function of explicit config + ExecCtx, \
                          not ambient process state"
                        .to_string(),
                });
            }
        }
    }
}

/// Hash iteration order varies across runs/platforms; in modules whose
/// loops feed residuals or gradients that breaks bit-for-bit
/// reproducibility. Use BTreeMap/BTreeSet or index-keyed Vecs.
fn rule_hash_iteration(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if !in_module(file, NUMERIC_MODULES) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if test[i] {
            continue;
        }
        if let Some(id) = t.ident() {
            if HASH_IDENTS.contains(&id) {
                out.push(Violation {
                    file: file.to_string(),
                    line: t.line,
                    rule: "hash-order-in-numeric",
                    msg: format!(
                        "{id} in a numeric module: hash iteration order is unstable and \
                         breaks bit-for-bit gradients — use BTreeMap/BTreeSet or an \
                         index-keyed Vec"
                    ),
                });
            }
        }
    }
}

/// Parallel float reductions must go through `par`'s fixed-chunk helpers
/// (ExecCtx::dot / run_chunks + DisjointMut slots combined in chunk order).
/// Raw sync primitives in numeric modules are the building blocks of
/// claim-order reductions, which are deterministic only by luck.
fn rule_adhoc_sync(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if !in_module(file, NUMERIC_MODULES) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if test[i] {
            continue;
        }
        let hit = match t.ident() {
            Some(id) if SYNC_IDENTS.contains(&id) => true,
            Some("sync") => {
                i >= 2
                    && code[i - 1].tok == Tok::PathSep
                    && code[i - 2].ident() == Some("std")
            }
            _ => false,
        };
        if hit {
            out.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: "adhoc-sync-in-numeric",
                msg: "sync primitive in a numeric module: parallel reductions must use \
                      par's fixed-chunk deterministic helpers (ExecCtx::dot/run_chunks \
                      with per-chunk slots combined in chunk order), never ad-hoc \
                      shared-state accumulation"
                    .to_string(),
            });
        }
    }
}

/// Solver-core code paths surface failures as typed errors or panics with
/// invariant messages; a bare `unwrap()` turns a physics/config bug into
/// an anonymous `Option::unwrap` line number.
fn rule_unwrap(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if in_module(file, &["util/"]) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if test[i] || !t.is_punct('.') {
            continue;
        }
        if code.get(i + 1).and_then(|t| t.ident()) == Some("unwrap")
            && code.get(i + 2).map(|t| t.is_punct('(')).unwrap_or(false)
            && code.get(i + 3).map(|t| t.is_punct(')')).unwrap_or(false)
        {
            out.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: "unwrap-in-core",
                msg: "bare unwrap() in solver-core code: return a typed error or use \
                      expect(\"<invariant that makes this infallible>\")"
                    .to_string(),
            });
        }
    }
}

/// `expect` is the sanctioned unwrap — but only with a literal message long
/// enough to state the invariant being relied on.
fn rule_expect_message(file: &str, code: &[Token], test: &[bool], out: &mut Vec<Violation>) {
    if in_module(file, &["util/"]) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if test[i] || !t.is_punct('.') {
            continue;
        }
        if code.get(i + 1).and_then(|t| t.ident()) != Some("expect")
            || !code.get(i + 2).map(|t| t.is_punct('(')).unwrap_or(false)
        {
            continue;
        }
        let ok = matches!(code.get(i + 3), Some(Token { tok: Tok::Str(s), .. }) if s.len() >= 10);
        if !ok {
            out.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: "expect-message",
                msg: "expect() needs a string literal (>= 10 chars) naming the invariant \
                      that makes the failure impossible"
                    .to_string(),
            });
        }
    }
}

/// Every `unsafe` (block, fn, impl) must be justified by a `// SAFETY:`
/// comment (or a `/// # Safety` doc section) ending within the 3 lines
/// above it — the audit trail Miri/TSan runs are cross-checked against.
fn rule_unsafe_safety(
    file: &str,
    code: &[Token],
    comments: &[(usize, usize, bool)],
    out: &mut Vec<Violation>,
) {
    for t in code {
        if t.ident() != Some("unsafe") {
            continue;
        }
        let justified = comments.iter().any(|&(start, end, has_safety)| {
            has_safety && end + 3 >= t.line && start <= t.line
        });
        if !justified {
            out.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: "unsafe-needs-safety-comment",
                msg: "unsafe without a `// SAFETY:` comment within the 3 preceding lines: \
                      state the aliasing/lifetime argument the compiler cannot check"
                    .to_string(),
            });
        }
    }
}

/// Lint every `.rs` file under `src_root` (rust/src), returning all
/// violations in deterministic (path, line) order.
pub fn lint_tree(src_root: &Path) -> std::io::Result<(usize, Vec<Violation>)> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)?;
        out.extend(check_file(&rel, &src));
    }
    Ok((files.len(), out))
}

pub(crate) fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(file: &str, src: &str) -> Vec<&'static str> {
        check_file(file, src).into_iter().map(|v| v.rule).collect()
    }

    // --- each rule fires on a seeded violation ---

    #[test]
    fn thread_rule_fires_outside_par() {
        let src = "pub fn f() { std::thread::spawn(|| {}); }";
        assert_eq!(rules_hit("fvm/assemble.rs", src), vec!["thread-outside-par"]);
        // and on the use-imported form
        let src2 = "use std::thread;\nfn g() { thread::scope(|s| {}); }";
        assert!(rules_hit("piso/stepper.rs", src2).contains(&"thread-outside-par"));
    }

    #[test]
    fn thread_rule_allows_par_and_tests() {
        let src = "pub fn f() { std::thread::spawn(|| {}); }";
        assert!(rules_hit("par/pool.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod tests { fn f() { std::thread::spawn(|| {}); } }";
        assert!(rules_hit("fvm/assemble.rs", test_src).is_empty());
    }

    #[test]
    fn pool_rule_fires_outside_par() {
        let src = "fn f() { let p = Pool::new(4); }";
        assert_eq!(rules_hit("coordinator/engine.rs", src), vec!["pool-outside-par"]);
        assert!(rules_hit("par/mod.rs", src).is_empty());
    }

    #[test]
    fn env_rule_fires_outside_util_and_budgets_par_mod() {
        let src = "fn f() -> bool { std::env::var(\"X\").is_ok() }";
        assert_eq!(rules_hit("piso/stepper.rs", src), vec!["env-outside-util"]);
        assert!(rules_hit("util/cli.rs", src).is_empty());
        // par/mod.rs: the single env_threads read is sanctioned, a second is not
        assert!(rules_hit("par/mod.rs", src).is_empty());
        let two = "fn a() -> bool { std::env::var(\"X\").is_ok() }\n\
                   fn b() -> bool { std::env::var(\"Y\").is_ok() }";
        assert_eq!(rules_hit("par/mod.rs", two), vec!["env-outside-util"]);
    }

    #[test]
    fn hash_rule_fires_in_numeric_modules_only() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, f64>) {}";
        let hits = rules_hit("linsolve/precond.rs", src);
        assert!(hits.iter().all(|r| *r == "hash-order-in-numeric"), "{hits:?}");
        assert!(!hits.is_empty());
        // coordinator/util are outside the numeric set
        assert!(rules_hit("coordinator/scenario.rs", src).is_empty());
    }

    #[test]
    fn sync_rule_fires_in_numeric_modules_only() {
        let src = "use std::sync::Mutex;\nfn f() { let m = Mutex::new(0.0f64); }";
        let hits = rules_hit("adjoint/tape.rs", src);
        assert!(hits.contains(&"adhoc-sync-in-numeric"), "{hits:?}");
        // par and coordinator own the sanctioned uses
        assert!(rules_hit("par/pool.rs", src).is_empty());
        assert!(rules_hit("coordinator/engine.rs", src).is_empty());
    }

    #[test]
    fn unwrap_rule_fires_and_spares_tests_and_util() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(rules_hit("mesh/gen.rs", src), vec!["unwrap-in-core"]);
        assert!(rules_hit("util/json.rs", src).is_empty());
        let test_src = "#[test]\nfn t() { Some(1).unwrap(); }";
        assert!(rules_hit("mesh/gen.rs", test_src).is_empty());
        // unwrap_or and friends are fine
        let src2 = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }";
        assert!(rules_hit("mesh/gen.rs", src2).is_empty());
    }

    #[test]
    fn expect_rule_requires_informative_literal() {
        let short = "fn f(x: Option<u32>) -> u32 { x.expect(\"diag\") }";
        assert_eq!(rules_hit("adjoint/ops.rs", short), vec!["expect-message"]);
        let nonliteral = "fn f(x: Option<u32>, m: &str) -> u32 { x.expect(m) }";
        assert_eq!(rules_hit("adjoint/ops.rs", nonliteral), vec!["expect-message"]);
        let good = "fn f(x: Option<u32>) -> u32 { x.expect(\"diagonal present: every \
                    assembled row carries its cell's own coefficient\") }";
        assert!(rules_hit("adjoint/ops.rs", good).is_empty());
    }

    #[test]
    fn unsafe_rule_wants_nearby_safety_comment() {
        let bare = "fn f(p: *const u32) -> u32 { unsafe { *p } }";
        assert_eq!(rules_hit("sparse/csr.rs", bare), vec!["unsafe-needs-safety-comment"]);
        let justified = "fn f(p: *const u32) -> u32 {\n    // SAFETY: caller passes a \
                         valid, aligned pointer\n    unsafe { *p }\n}";
        assert!(rules_hit("sparse/csr.rs", justified).is_empty());
        let doc = "/// # Safety\n/// `p` must be valid.\npub unsafe fn f(p: *const u32) \
                   -> u32 { *p }";
        assert!(rules_hit("sparse/csr.rs", doc).is_empty());
        // a SAFETY comment too far above does not count
        let far = "// SAFETY: stale justification\nfn a() {}\nfn b() {}\nfn c() {}\n\
                   fn f(p: *const u32) -> u32 { unsafe { *p } }";
        assert_eq!(rules_hit("sparse/csr.rs", far), vec!["unsafe-needs-safety-comment"]);
        // contiguous `//` lines are one comment: a multi-line SAFETY argument
        // counts from its *last* line even when the keyword is on the first
        let run = "fn f(p: *const u32) -> u32 {\n\
                   // SAFETY: the pointer is valid because the caller\n\
                   // constructed it from a live &u32 two frames up and\n\
                   // nothing frees it before we return; alignment comes\n\
                   // from the reference it was cast from, and the read\n\
                   // does not outlive the borrow.\n\
                   unsafe { *p }\n}";
        assert!(rules_hit("sparse/csr.rs", run).is_empty());
        // ...but a gap of blank/code lines breaks the run
        let broken = "// SAFETY: detached justification\n\nfn a() {}\nfn b() {}\n\
                      fn f(p: *const u32) -> u32 { unsafe { *p } }";
        assert_eq!(rules_hit("sparse/csr.rs", broken), vec!["unsafe-needs-safety-comment"]);
    }

    #[test]
    fn comments_and_strings_never_trip_rules() {
        let src = "// std::thread::spawn, env::var, HashMap, unwrap()\n\
                   fn f() -> &'static str { \"std::thread::spawn(HashMap.unwrap())\" }";
        assert!(rules_hit("fvm/assemble.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_mod_masks_its_whole_body() {
        let src = "fn shipped(x: Option<u32>) -> u32 { x.expect(\"value present by \
                   construction\") }\n\
                   #[cfg(test)]\nmod tests {\n  use std::sync::Mutex;\n  #[test]\n  fn t() \
                   { let _ = Some(1).unwrap(); std::thread::spawn(|| {}); }\n}";
        assert!(rules_hit("adjoint/rollout.rs", src).is_empty());
    }

    // --- the real tree stays clean (the CI acceptance gate, enforced from
    // the default `cargo test` run as well) ---

    #[test]
    fn repo_rust_src_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask sits one level under the workspace root")
            .join("rust")
            .join("src");
        let (nfiles, violations) =
            lint_tree(&root).expect("rust/src must be readable from the xtask test");
        assert!(nfiles > 30, "expected the full solver tree, found {nfiles} files");
        assert!(
            violations.is_empty(),
            "rust/src has lint violations:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
