//! Approximate call graph over the symbol table.
//!
//! Call sites are syntactic: an identifier directly followed by `(` that is
//! neither a keyword, a macro invocation (`name!`), nor a declaration
//! (`fn name(`). Resolution is by name — same-file fns win, otherwise a
//! *unique* global candidate resolves and ambiguous names stay unresolved.
//! That keeps the graph conservative: the hot-path allocation rule only
//! propagates through edges it is sure about, so an ambiguous name can hide
//! an allocation but never invent one.

use crate::symbols::{SourceFile, SymbolTable};

/// Idents that look like calls (`if (…)`, `match (…)`) but are control flow.
const KEYWORDS: &[&str] = &[
    "return", "match", "if", "while", "for", "loop", "in", "as", "let", "else", "move", "break",
    "continue",
];

/// One syntactic call site.
#[derive(Debug, Clone)]
pub struct CallSite {
    /// Index into `SymbolTable::files` of the calling file.
    pub file: usize,
    /// Token index of the callee identifier in that file's `code`.
    pub token: usize,
    pub callee: String,
    pub line: usize,
    /// `true` for `.name(…)` method syntax (receiver type unknown, so
    /// method calls only resolve when the name is globally unique).
    pub method: bool,
    /// Resolved target as `(file index, fn index)`; `None` when the name
    /// matched zero or several candidate fns.
    pub target: Option<(usize, usize)>,
}

pub struct CallGraph {
    pub sites: Vec<CallSite>,
}

impl CallGraph {
    pub fn build(table: &SymbolTable) -> CallGraph {
        // name → [(file, fn)] across the whole tree
        let mut by_name: std::collections::BTreeMap<&str, Vec<(usize, usize)>> =
            std::collections::BTreeMap::new();
        for (fi, f) in table.files.iter().enumerate() {
            for (ni, item) in f.parsed.fns.iter().enumerate() {
                by_name.entry(item.name.as_str()).or_default().push((fi, ni));
            }
        }
        let mut sites = Vec::new();
        for (fi, f) in table.files.iter().enumerate() {
            collect_sites(fi, f, &by_name, &mut sites);
        }
        CallGraph { sites }
    }

    /// Call sites whose token index lies inside the given fn body.
    pub fn sites_in<'a>(
        &'a self,
        file: usize,
        body: (usize, usize),
    ) -> impl Iterator<Item = &'a CallSite> {
        self.sites
            .iter()
            .filter(move |s| s.file == file && body.0 <= s.token && s.token <= body.1)
    }
}

fn collect_sites(
    fi: usize,
    f: &SourceFile,
    by_name: &std::collections::BTreeMap<&str, Vec<(usize, usize)>>,
    out: &mut Vec<CallSite>,
) {
    let code = &f.code;
    for (i, t) in code.iter().enumerate() {
        let Some(name) = t.ident() else { continue };
        if KEYWORDS.contains(&name) {
            continue;
        }
        if !code.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false) {
            continue;
        }
        // declarations are not calls
        if i >= 1 && code[i - 1].ident() == Some("fn") {
            continue;
        }
        let method = i >= 1 && code[i - 1].is_punct('.');
        // resolve: same-file fn by name first, else a unique global match
        let candidates = by_name.get(name).map(Vec::as_slice).unwrap_or(&[]);
        let local: Vec<&(usize, usize)> = candidates.iter().filter(|&&(cf, _)| cf == fi).collect();
        let target = match (local.as_slice(), candidates) {
            ([one], _) => Some(**one),
            ([], [one]) => Some(*one),
            _ => None,
        };
        out.push(CallSite { file: fi, token: i, callee: name.to_string(), line: t.line, method, target });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbols::SymbolTable;

    fn table(files: &[(&str, &str)]) -> SymbolTable {
        SymbolTable::build(
            files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect(),
        )
    }

    #[test]
    fn calls_resolve_same_file_first() {
        let t = table(&[(
            "a.rs",
            "fn helper(x: f64) -> f64 { x }\nfn driver(x: f64) -> f64 { helper(x) }",
        )]);
        let g = CallGraph::build(&t);
        let call = g.sites.iter().find(|s| s.callee == "helper").expect("call site");
        assert_eq!(call.target, Some((0, 0)));
        assert!(!call.method);
    }

    #[test]
    fn unique_cross_file_calls_resolve() {
        let t = table(&[
            ("a.rs", "pub fn kernel(n: usize) -> usize { n }"),
            ("b.rs", "fn run(n: usize) -> usize { kernel(n) }"),
        ]);
        let g = CallGraph::build(&t);
        let call = g.sites.iter().find(|s| s.callee == "kernel").expect("call site");
        assert_eq!(call.target, Some((0, 0)));
    }

    #[test]
    fn ambiguous_names_stay_unresolved() {
        let t = table(&[
            ("a.rs", "pub fn apply(n: usize) -> usize { n }"),
            ("b.rs", "pub fn apply(n: usize) -> usize { n + 1 }"),
            ("c.rs", "fn run(n: usize) -> usize { apply(n) }"),
        ]);
        let g = CallGraph::build(&t);
        let call = g.sites.iter().find(|s| s.callee == "apply").expect("call site");
        assert_eq!(call.target, None, "two candidates: must not guess");
    }

    #[test]
    fn keywords_macros_and_declarations_are_not_calls() {
        let t = table(&[(
            "a.rs",
            "fn f(n: usize) -> usize { if (n > 0) { return (n); } vec![0; n].len() }",
        )]);
        let g = CallGraph::build(&t);
        assert!(
            g.sites.iter().all(|s| s.callee != "if" && s.callee != "return" && s.callee != "vec"),
            "{:?}",
            g.sites.iter().map(|s| s.callee.as_str()).collect::<Vec<_>>()
        );
        // the fn declaration itself is not a site
        assert!(g.sites.iter().all(|s| s.callee != "f"));
    }

    #[test]
    fn method_calls_are_flagged() {
        let t = table(&[("a.rs", "fn f(v: &[f64]) -> usize { v.len() }")]);
        let g = CallGraph::build(&t);
        let call = g.sites.iter().find(|s| s.callee == "len").expect("method site");
        assert!(call.method);
    }
}
