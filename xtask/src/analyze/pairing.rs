//! Rule `adjoint-pairing`: forward/backward tape payloads must stay paired.
//!
//! The checkpointed-adjoint contract is that every field a `*Record` struct
//! carries is (a) actually filled by the forward step and (b) actually
//! consumed by the backward sweep. A field that fails (a) is dead weight in
//! every checkpoint; a field that fails (b) is worse — it silently rots
//! until someone resurrects it with stale semantics. This rule extracts the
//! record structs declared in `piso/stepper.rs`, computes the forward
//! write-set (struct literals and `.field =` assignments in stepper fns)
//! and the backward read-set (`.field` accesses in `adjoint/step.rs` +
//! `adjoint/tape.rs`), and reports any declared field missing from either.
//!
//! Approximations, by construction:
//! - zero-fill constructors (`empty`, `default`) and size accounting
//!   (`len_f64`) do not count as forward writes — they touch every field
//!   whether it is live or not;
//! - `validate*` fns do not count as backward reads — entry validation
//!   touches fields without consuming their values;
//! - reads match on the field *name*, so an unrelated `.dt` access in the
//!   adjoint also satisfies `StepRecord::dt`. Record fields are named
//!   distinctively enough that this has not mattered; keep it that way.

use crate::lexer::Tok;
use crate::rules::Violation;
use crate::symbols::{SourceFile, SymbolTable};
use std::collections::BTreeSet;

const FORWARD_FILE: &str = "piso/stepper.rs";
const BACKWARD_FILES: &[&str] = &["adjoint/step.rs", "adjoint/tape.rs"];
/// Fns whose field mentions are bookkeeping, not forward writes.
const NON_WRITE_FNS: &[&str] = &["empty", "default", "len_f64"];

pub fn check(table: &SymbolTable, out: &mut Vec<Violation>) {
    let Some(fwd) = table.file(FORWARD_FILE) else { return };
    // the record structs under contract: every `*Record` in the stepper
    let records: Vec<_> =
        fwd.parsed.structs.iter().filter(|s| s.name.ends_with("Record")).collect();
    if records.is_empty() {
        return;
    }
    let declared: Vec<(&str, usize)> = records
        .iter()
        .flat_map(|s| s.fields.iter().map(|(f, line)| (f.as_str(), *line)))
        .collect();
    let field_names: BTreeSet<&str> = declared.iter().map(|&(f, _)| f).collect();

    let written = forward_writes(fwd, &records, &field_names);
    let mut read = BTreeSet::new();
    for path in BACKWARD_FILES {
        if let Some(f) = table.file(path) {
            backward_reads(f, &field_names, &mut read);
        }
    }

    for &(field, line) in &declared {
        if !written.contains(field) {
            out.push(Violation {
                file: FORWARD_FILE.to_string(),
                line,
                rule: "adjoint-pairing",
                msg: format!(
                    "record field `{field}` is declared but never written by the forward \
                     step: delete it or fill it where the tape entry is built"
                ),
            });
        } else if !read.contains(field) {
            out.push(Violation {
                file: FORWARD_FILE.to_string(),
                line,
                rule: "adjoint-pairing",
                msg: format!(
                    "record field `{field}` is written by the forward step but never read \
                     by the backward sweep (adjoint/step.rs, adjoint/tape.rs): it bloats \
                     every checkpoint — delete it or consume it in backward_step"
                ),
            });
        }
    }
}

/// Fields written by non-test stepper fns (excluding zero-fill/bookkeeping
/// fns): struct-literal fields plus `.field =` assignments.
fn forward_writes<'a>(
    f: &SourceFile,
    records: &[&crate::parse::StructItem],
    fields: &BTreeSet<&'a str>,
) -> BTreeSet<String> {
    let record_names: BTreeSet<&str> = records.iter().map(|s| s.name.as_str()).collect();
    let code = &f.code;
    let mut written = BTreeSet::new();
    for (i, t) in code.iter().enumerate() {
        if f.test[i] {
            continue;
        }
        let Some(enclosing) = f.parsed.enclosing_fn(i) else { continue };
        if NON_WRITE_FNS.contains(&enclosing.name.as_str()) {
            continue;
        }
        // `.field = value` assignment (but not `==` comparison)
        if t.is_punct('.') {
            if let Some(name) = code.get(i + 1).and_then(|n| n.ident()) {
                if fields.contains(name)
                    && code.get(i + 2).map(|n| n.is_punct('=')).unwrap_or(false)
                    && !code.get(i + 3).map(|n| n.is_punct('=')).unwrap_or(false)
                {
                    written.insert(name.to_string());
                }
            }
            continue;
        }
        // `RecordName { field: …, shorthand, … }` struct literal
        let Some(name) = t.ident() else { continue };
        if !record_names.contains(name)
            || !code.get(i + 1).map(|n| n.is_punct('{')).unwrap_or(false)
        {
            continue;
        }
        literal_fields(f, i + 1, fields, &mut written);
    }
    written
}

/// Field names initialized by the struct literal whose `{` is at `open`:
/// idents at brace depth 1 (paren/bracket depth 0) preceded by `{`/`,` and
/// followed by `:` (explicit), `,` or `}` (shorthand).
fn literal_fields(
    f: &SourceFile,
    open: usize,
    fields: &BTreeSet<&str>,
    written: &mut BTreeSet<String>,
) {
    let code = &f.code;
    let mut brace = 0i64;
    let mut inner = 0i64; // parens + brackets inside the literal
    for k in open..code.len() {
        match &code[k].tok {
            Tok::Punct('{') => brace += 1,
            Tok::Punct('}') => {
                brace -= 1;
                if brace == 0 {
                    return;
                }
            }
            Tok::Punct('(' | '[') => inner += 1,
            Tok::Punct(')' | ']') => inner -= 1,
            Tok::Ident(name) if brace == 1 && inner == 0 && fields.contains(name.as_str()) => {
                let before = k >= 1
                    && (code[k - 1].is_punct('{') || code[k - 1].is_punct(','));
                let after = matches!(
                    code.get(k + 1).map(|n| &n.tok),
                    Some(Tok::Punct(':' | ',' | '}'))
                );
                if before && after {
                    written.insert(name.clone());
                }
            }
            _ => {}
        }
    }
}

/// `.field` accesses in non-test, non-`validate*` fns.
fn backward_reads(f: &SourceFile, fields: &BTreeSet<&str>, read: &mut BTreeSet<String>) {
    let code = &f.code;
    for (i, t) in code.iter().enumerate() {
        if f.test[i] || !t.is_punct('.') {
            continue;
        }
        let Some(name) = code.get(i + 1).and_then(|n| n.ident()) else { continue };
        if !fields.contains(name) {
            continue;
        }
        if let Some(enclosing) = f.parsed.enclosing_fn(i) {
            if enclosing.name.starts_with("validate") {
                continue;
            }
        }
        read.insert(name.to_string());
    }
}
