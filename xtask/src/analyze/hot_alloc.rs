//! Rule `hot-loop-alloc`: kernel inner loops must not allocate.
//!
//! The solver's per-step cost is dominated by the sparse kernels and the
//! Krylov iterations; an allocation inside those loops turns an O(nnz)
//! sweep into an allocator benchmark and (worse) makes runtime depend on
//! heap state. Scratch buffers are sized once per solve and reused —
//! `dd.fill(0.0)` inside the loop, `vec![0.0; n]` above it.
//!
//! The rule flags `Vec::new` / `Vec::with_capacity` / `vec![…]` /
//! `.collect(…)` / `.clone(…)` inside any loop body of the kernel files,
//! unless an `// ALLOC:` comment within 3 lines justifies it. On top of the
//! syntactic check, the call graph propagates one level: a loop-body call
//! that resolves uniquely to a kernel-file fn whose body allocates is
//! flagged at the call site (the allocation is per-iteration even though
//! the `vec!` sits elsewhere). Ambiguous names do not propagate — the
//! graph is conservative by design.

use crate::callgraph::CallGraph;
use crate::lexer::Tok;
use crate::rules::Violation;
use crate::symbols::{SourceFile, SymbolTable};

/// The hot files: sparse matvec/transpose, Krylov iterations,
/// preconditioner applies, and FVM assembly.
const KERNEL_FILES: &[&str] = &[
    "sparse/csr.rs",
    "linsolve/cg.rs",
    "linsolve/bicgstab.rs",
    "linsolve/precond.rs",
    "fvm/assemble.rs",
];

pub fn check(table: &SymbolTable, graph: &CallGraph, out: &mut Vec<Violation>) {
    let kernel_idx: Vec<usize> = table
        .files
        .iter()
        .enumerate()
        .filter(|(_, f)| KERNEL_FILES.contains(&f.path.as_str()))
        .map(|(i, _)| i)
        .collect();
    for &fi in &kernel_idx {
        let f = &table.files[fi];
        // --- direct allocations inside loop bodies ---
        for (i, t) in f.code.iter().enumerate() {
            if f.test[i] || !f.parsed.in_loop(i) {
                continue;
            }
            if let Some(what) = alloc_at(f, i) {
                if !f.alloc_justified(t.line) {
                    out.push(Violation {
                        file: f.path.clone(),
                        line: t.line,
                        rule: "hot-loop-alloc",
                        msg: format!(
                            "{what} inside a kernel loop: hoist the buffer out of the loop \
                             and reuse it (fill/copy_from_slice), or justify with an \
                             `// ALLOC:` comment within 3 lines"
                        ),
                    });
                }
            }
        }
        // --- one-level call-graph propagation ---
        for loop_range in &f.parsed.loops {
            for site in graph.sites_in(fi, *loop_range) {
                if f.test[site.token] || f.alloc_justified(site.line) {
                    continue;
                }
                let Some((tf, tn)) = site.target else { continue };
                if !kernel_idx.contains(&tf) {
                    continue;
                }
                let callee_file = &table.files[tf];
                let callee = &callee_file.parsed.fns[tn];
                if let Some(alloc_line) = fn_allocates(callee_file, callee) {
                    out.push(Violation {
                        file: f.path.clone(),
                        line: site.line,
                        rule: "hot-loop-alloc",
                        msg: format!(
                            "call to `{}` inside a kernel loop allocates per iteration \
                             ({}:{} allocates): hoist the buffer to the caller or \
                             justify with `// ALLOC:`",
                            site.callee, callee_file.path, alloc_line
                        ),
                    });
                }
            }
        }
    }
}

/// If token `i` starts an allocation pattern, a short description of it.
fn alloc_at(f: &SourceFile, i: usize) -> Option<&'static str> {
    let code = &f.code;
    match &code[i].tok {
        Tok::Ident(s) if s == "Vec" => {
            let ctor = code.get(i + 1).map(|n| n.tok == Tok::PathSep).unwrap_or(false)
                && matches!(code.get(i + 2).and_then(|n| n.ident()), Some("new" | "with_capacity"));
            ctor.then_some("Vec construction")
        }
        Tok::Ident(s) if s == "vec" => {
            code.get(i + 1).map(|n| n.is_punct('!')).unwrap_or(false).then_some("vec![…]")
        }
        Tok::Punct('.') => match code.get(i + 1).and_then(|n| n.ident()) {
            Some("collect") => Some(".collect()"),
            Some("clone") if code.get(i + 2).map(|n| n.is_punct('(')).unwrap_or(false) => {
                Some(".clone()")
            }
            _ => None,
        },
        _ => None,
    }
}

/// First unjustified allocation line in a fn body (test code excluded).
fn fn_allocates(f: &SourceFile, item: &crate::parse::FnItem) -> Option<usize> {
    let (bs, be) = item.body?;
    for i in bs..=be.min(f.code.len() - 1) {
        if f.test[i] {
            continue;
        }
        if alloc_at(f, i).is_some() && !f.alloc_justified(f.code[i].line) {
            return Some(f.code[i].line);
        }
    }
    None
}
