//! Rules `float-reduction`, `lossy-cast`, and `precision-boundary`.
//!
//! Float addition is not associative, so any reduction whose combine order
//! is an iterator-implementation detail (`.sum()`, a `fold` seeded with a
//! float) can drift between serial and pool execution — exactly the drift
//! the bit-for-bit contract forbids. In the kernel modules (`sparse/`,
//! `linsolve/`, `fvm/`, `adjoint/`) reductions must go through the blessed
//! helpers (`ExecCtx::dot`, `util::det::{sum, sum_by, norm2}`) whose
//! combine order is fixed by construction. Integer `.sum::<usize>()` and
//! friends stay legal — integer addition is associative.
//!
//! Lossy `as` casts are the second drift channel: a silent `usize as u32`
//! truncates on >4G-cell meshes, `f64 as f32` rounds. Narrowing must go
//! through `util::det::index_u32` (debug-asserted) or carry an explicit
//! justification in code review; widening (`as f64`, `as usize`, `as u64`,
//! `as i64`) is always exact for our index/value domains.
//!
//! The mixed-precision Krylov path adds a third channel: f32 storage is
//! legal only inside the blessed boundary files (`sparse/csr32.rs`,
//! `linsolve/refine.rs`). There `as f32` narrowing is the file's purpose
//! and stays quiet; everywhere else in `sparse/` and `linsolve/` an
//! `as f64` widening is evidence of f32 values circulating outside the
//! boundary and fires `precision-boundary` — re-entry must go through
//! `f64::from` inside the boundary files so provenance stays explicit.

use crate::lexer::Tok;
use crate::rules::{in_module, Violation};
use crate::symbols::SymbolTable;

/// Modules under the float-determinism contract. `piso/` is deliberately
/// absent: the stepper's `fold(0.0, f64::max)` CFL scan is order-independent
/// (max is associative and commutative).
const FLOAT_MODULES: &[&str] = &["sparse/", "linsolve/", "fvm/", "adjoint/"];

/// Integer element types for which `.sum::<T>()` is associative and legal.
const INT_TYPES: &[&str] =
    &["usize", "u128", "u64", "u32", "u16", "u8", "isize", "i128", "i64", "i32", "i16", "i8"];

/// Cast targets that can truncate or round our index/value domains.
const LOSSY_TARGETS: &[&str] = &["f32", "u32", "i32", "u16", "i16", "u8", "i8"];

/// The only files allowed to cross the f32/f64 storage boundary: the
/// f32-storage CSR mirror and the iterative-refinement driver. `as f32`
/// narrowing is their purpose; index truncation stays illegal even here.
const PRECISION_BOUNDARY: &[&str] = &["sparse/csr32.rs", "linsolve/refine.rs"];

/// Modules where f32 values must not circulate outside the boundary files.
const PRECISION_MODULES: &[&str] = &["sparse/", "linsolve/"];

pub fn check(table: &SymbolTable, out: &mut Vec<Violation>) {
    for f in &table.files {
        if !in_module(&f.path, FLOAT_MODULES) {
            continue;
        }
        let code = &f.code;
        for (i, t) in code.iter().enumerate() {
            if f.test[i] {
                continue;
            }
            // --- `as <lossy type>` / precision-boundary widening ---
            if t.ident() == Some("as") {
                let blessed = PRECISION_BOUNDARY.iter().any(|b| f.path.ends_with(b));
                let precision_scope = !blessed && in_module(&f.path, PRECISION_MODULES);
                if let Some(target) = code.get(i + 1).and_then(|n| n.ident()) {
                    if LOSSY_TARGETS.contains(&target) && !(blessed && target == "f32") {
                        out.push(Violation {
                            file: f.path.clone(),
                            line: t.line,
                            rule: "lossy-cast",
                            msg: format!(
                                "lossy `as {target}` in a kernel module: narrowing must go \
                                 through util::det (index_u32 debug-asserts the range) so \
                                 truncation on large meshes fails loudly instead of \
                                 corrupting indices"
                            ),
                        });
                    } else if target == "f64" && precision_scope {
                        out.push(Violation {
                            file: f.path.clone(),
                            line: t.line,
                            rule: "precision-boundary",
                            msg: "`as f64` in a precision module outside the blessed \
                                  boundary files (sparse/csr32.rs, linsolve/refine.rs): \
                                  f32 values must widen back through f64::from inside the \
                                  boundary so reduced precision cannot silently leak into \
                                  the f64 solvers; integer-to-float conversions belong in \
                                  util::det"
                                .to_string(),
                        });
                    }
                }
                continue;
            }
            if !t.is_punct('.') {
                continue;
            }
            match code.get(i + 1).and_then(|n| n.ident()) {
                // --- `.sum()` / `.sum::<T>()` ---
                Some("sum") => {
                    let bare = code.get(i + 2).map(|n| n.is_punct('(')).unwrap_or(false);
                    let turbofish_float = code.get(i + 2).map(|n| n.tok == Tok::PathSep).unwrap_or(false)
                        && code.get(i + 3).map(|n| n.is_punct('<')).unwrap_or(false)
                        && code
                            .get(i + 4)
                            .and_then(|n| n.ident())
                            .map(|ty| !INT_TYPES.contains(&ty))
                            .unwrap_or(false);
                    if bare || turbofish_float {
                        out.push(Violation {
                            file: f.path.clone(),
                            line: t.line,
                            rule: "float-reduction",
                            msg: "iterator .sum() over floats in a kernel module: combine \
                                  order is an implementation detail — use util::det::sum / \
                                  sum_by (serial, index order) or ExecCtx::dot (fixed \
                                  chunk order)"
                                .to_string(),
                        });
                    }
                }
                // --- `.fold(<float literal>, …)` ---
                Some("fold") => {
                    if !code.get(i + 2).map(|n| n.is_punct('(')).unwrap_or(false) {
                        continue;
                    }
                    let mut j = i + 3;
                    if code.get(j).map(|n| n.is_punct('-')).unwrap_or(false) {
                        j += 1;
                    }
                    let seed_is_float = matches!(
                        code.get(j).map(|n| &n.tok),
                        Some(Tok::Num(text)) if is_float_literal(text)
                    );
                    if seed_is_float {
                        out.push(Violation {
                            file: f.path.clone(),
                            line: t.line,
                            rule: "float-reduction",
                            msg: "float-seeded fold in a kernel module: if this is a sum, \
                                  use util::det::sum_by; if the combine is associative \
                                  (min/max), seed it through util::det or document why \
                                  order cannot matter"
                                .to_string(),
                        });
                    }
                }
                _ => {}
            }
        }
    }
}

/// Whether a numeric-literal token text denotes a float. Integer suffixes
/// are checked first because `0usize` contains an `e`.
fn is_float_literal(text: &str) -> bool {
    if text.starts_with("0x")
        || text.starts_with("0X")
        || text.starts_with("0o")
        || text.starts_with("0O")
        || text.starts_with("0b")
        || text.starts_with("0B")
    {
        return false;
    }
    if INT_TYPES.iter().any(|s| text.ends_with(s)) {
        return false;
    }
    text.contains('.')
        || text.ends_with("f32")
        || text.ends_with("f64")
        || text.contains('e')
        || text.contains('E')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_literal_classification() {
        for float in ["1.0", "0.5f64", "1e10", "1.5e-3", "2E+7", "3f32"] {
            assert!(is_float_literal(float), "{float}");
        }
        for int in ["0", "42", "0usize", "7u32", "0x1e", "0b101", "10_000", "3i64"] {
            assert!(!is_float_literal(int), "{int}");
        }
    }
}
