//! Rule `replay-containment`.
//!
//! The checkpoint-replay contract (PR 9) is that segment rematerialization
//! lives behind exactly one hook: `Tape::replay_segments` in
//! `adjoint/tape.rs`. Every backward consumer — the gradient sweep, the
//! training engine's CNN-tape rematerialization — drives re-stepping
//! through that hook instead of hand-rolling its own restore/re-step loop.
//!
//! The signature of a hand-rolled replay is a single fn that both
//! *assigns the solver's boundary state* (`….bc_values = …`, the
//! snapshot-restore half) and *steps the solver* (`.step(…)`, the
//! re-advance half). Each alone is fine — scenario builders assign
//! boundary values, drivers step solvers — but together outside the tape
//! they duplicate the replay scheme, and duplicated replays drift: the
//! engine's pre-PR-9 copy had to carry a keep-in-sync comment aimed at
//! tape.rs. `piso/` is exempt (the forward stepper owns the boundary
//! update itself), as is test code (gold-value rollouts legitimately
//! re-step).

use crate::rules::Violation;
use crate::symbols::SymbolTable;

/// Files allowed to restore-and-restep: the single replay hook and the
/// forward stepper that owns the boundary update.
const REPLAY_ALLOWED: &[&str] = &["adjoint/tape.rs", "piso/"];

pub fn check(table: &SymbolTable, out: &mut Vec<Violation>) {
    for f in &table.files {
        if REPLAY_ALLOWED.iter().any(|p| f.path.starts_with(p)) {
            continue;
        }
        let code = &f.code;
        for item in &f.parsed.fns {
            let Some((bs, be)) = item.body else { continue };
            if f.test[bs] {
                continue;
            }
            let be = be.min(code.len() - 1);
            let mut assigns_bc = false;
            let mut steps = false;
            for i in bs..=be {
                // `.bc_values =` (field assignment; `==`/`!=`/`let
                // bc_values` do not count)
                if code[i].ident() == Some("bc_values")
                    && i > 0
                    && code[i - 1].is_punct('.')
                    && code.get(i + 1).map(|t| t.is_punct('=')).unwrap_or(false)
                    && !code.get(i + 2).map(|t| t.is_punct('=')).unwrap_or(false)
                {
                    assigns_bc = true;
                }
                // `.step(` — a solver step call
                if code[i].ident() == Some("step")
                    && i > 0
                    && code[i - 1].is_punct('.')
                    && code.get(i + 1).map(|t| t.is_punct('(')).unwrap_or(false)
                {
                    steps = true;
                }
            }
            if assigns_bc && steps {
                out.push(Violation {
                    file: f.path.clone(),
                    line: item.line,
                    rule: "replay-containment",
                    msg: format!(
                        "fn `{}` restores boundary state and re-steps the solver — a \
                         hand-rolled checkpoint replay outside adjoint/tape.rs; drive \
                         rematerialization through Tape::replay_segments so there is \
                         one replay scheme to keep correct",
                        item.name
                    ),
                });
            }
        }
    }
}
