//! Rules `execctx-construction` and `execctx-unused-param`.
//!
//! The threading contract (PR 3) is that exactly one `ExecCtx` flows down
//! from the entry point: construction belongs to `par/` (the implementation)
//! and `coordinator/` (the composition root). A constructor call anywhere
//! else forks the pool topology — two pools, double width, nondeterministic
//! interleaving with the batch runner.
//!
//! The dual failure mode is a fn that *accepts* `&ExecCtx` but ignores it:
//! the signature claims pool participation while the body runs serial (or
//! builds its own context), so callers reasonably assume work they hand it
//! lands on the shared pool. Either use the parameter, forward it, or
//! underscore-prefix it where a trait signature forces the argument.

use crate::rules::{in_module, Violation, NUMERIC_MODULES};
use crate::symbols::SymbolTable;

/// Modules allowed to construct `ExecCtx`: the implementation and the
/// composition root.
const CONSTRUCTION_ALLOWED: &[&str] = &["par/", "coordinator/"];

pub fn check(table: &SymbolTable, out: &mut Vec<Violation>) {
    for f in &table.files {
        let code = &f.code;
        // --- construction sites ---
        if !in_module(&f.path, CONSTRUCTION_ALLOWED) {
            for (i, t) in code.iter().enumerate() {
                if f.test[i] || t.ident() != Some("ExecCtx") {
                    continue;
                }
                let is_ctor = code.get(i + 1).map(|n| n.tok == crate::lexer::Tok::PathSep).unwrap_or(false)
                    && code.get(i + 2).and_then(|n| n.ident()).is_some()
                    && code.get(i + 3).map(|n| n.is_punct('(')).unwrap_or(false);
                if is_ctor {
                    out.push(Violation {
                        file: f.path.clone(),
                        line: t.line,
                        rule: "execctx-construction",
                        msg: "ExecCtx constructed outside par/ and coordinator/: accept a \
                              ctx (or &ExecCtx) from the caller so the whole run shares \
                              one pool instead of forking topology per call site"
                            .to_string(),
                    });
                }
            }
        }
        // --- unused &ExecCtx params in solver-core fns ---
        if !in_module(&f.path, NUMERIC_MODULES) {
            continue;
        }
        for item in &f.parsed.fns {
            let Some((bs, be)) = item.body else { continue };
            if f.test[bs] {
                continue;
            }
            for p in &item.params {
                if p.name == "self"
                    || p.name.starts_with('_')
                    || !p.ty_idents.iter().any(|t| t == "ExecCtx")
                {
                    continue;
                }
                let used = code[bs..=be.min(code.len() - 1)]
                    .iter()
                    .any(|t| t.ident() == Some(p.name.as_str()));
                if !used {
                    out.push(Violation {
                        file: f.path.clone(),
                        line: item.line,
                        rule: "execctx-unused-param",
                        msg: format!(
                            "fn `{}` accepts `{}: &ExecCtx` but never uses or forwards it: \
                             the signature promises pool participation the body does not \
                             deliver — use it, drop it, or rename to `_{}` where a trait \
                             signature forces the argument",
                            item.name, p.name, p.name
                        ),
                    });
                }
            }
        }
    }
}
