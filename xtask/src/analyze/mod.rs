//! The semantic analyze pass (`cargo run -p xtask -- analyze`).
//!
//! Where `lint` matches token patterns file-by-file, `analyze` builds a
//! symbol table and an approximate call graph over the whole tree (see
//! [`crate::parse`], [`crate::symbols`], [`crate::callgraph`]) and runs the
//! cross-file rule families on top:
//!
//! - [`pairing`] — `adjoint-pairing`: forward-written record fields must be
//!   backward-read and vice versa;
//! - [`ctx_flow`] — `execctx-construction` / `execctx-unused-param`: one
//!   ExecCtx flows down, nobody forks or drops it;
//! - [`float_det`] — `float-reduction` / `lossy-cast` /
//!   `precision-boundary`: kernel reductions and narrowing casts go through
//!   blessed deterministic helpers, and f32 storage stays confined to the
//!   mixed-precision boundary files;
//! - [`hot_alloc`] — `hot-loop-alloc`: kernel loops do not allocate,
//!   call-graph-propagated one level;
//! - [`replay`] — `replay-containment`: checkpoint re-stepping (restore
//!   boundary state + step the solver in one fn) is confined to the
//!   `Tape::replay_segments` hook in `adjoint/tape.rs`.
//!
//! Like the lint pass, the whole thing also runs from `cargo test` via
//! `repo_rust_src_is_analyze_clean`, so the tree cannot drift out of
//! compliance between CI configurations.

mod ctx_flow;
mod float_det;
mod hot_alloc;
mod pairing;
mod replay;

use crate::callgraph::CallGraph;
use crate::rules::{collect_rs, Violation};
use crate::symbols::SymbolTable;
use std::path::Path;

/// Analyze result: tree-level stats plus the sorted violation list. The
/// stats make regressions in the parser itself visible — a refactor that
/// silently stops finding fns would otherwise look like a very clean tree.
pub struct Report {
    pub files: usize,
    pub fns: usize,
    pub call_sites: usize,
    pub resolved_edges: usize,
    pub violations: Vec<Violation>,
}

/// Analyze `(relative path, source)` pairs as one tree.
pub fn analyze_files(sources: Vec<(String, String)>) -> Report {
    let table = SymbolTable::build(sources);
    let graph = CallGraph::build(&table);
    let mut violations = Vec::new();
    pairing::check(&table, &mut violations);
    ctx_flow::check(&table, &mut violations);
    float_det::check(&table, &mut violations);
    hot_alloc::check(&table, &graph, &mut violations);
    replay::check(&table, &mut violations);
    violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    violations.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule);
    Report {
        files: table.files.len(),
        fns: table.files.iter().map(|f| f.parsed.fns.len()).sum(),
        call_sites: graph.sites.len(),
        resolved_edges: graph.sites.iter().filter(|s| s.target.is_some()).count(),
        violations,
    }
}

/// Analyze every `.rs` file under `src_root`.
pub fn analyze_tree(src_root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut sources = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        sources.push((rel, std::fs::read_to_string(path)?));
    }
    Ok(analyze_files(sources))
}

/// Machine-readable report for the CI artifact: stable key order, 2-space
/// indentation, violations in the same deterministic order the human
/// output uses.
pub fn to_json(r: &Report) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"files\": {},\n", r.files));
    s.push_str(&format!("  \"fns\": {},\n", r.fns));
    s.push_str(&format!("  \"call_sites\": {},\n", r.call_sites));
    s.push_str(&format!("  \"resolved_edges\": {},\n", r.resolved_edges));
    if r.violations.is_empty() {
        s.push_str("  \"violations\": []\n");
    } else {
        s.push_str("  \"violations\": [\n");
        for (i, v) in r.violations.iter().enumerate() {
            s.push_str("    {\n");
            s.push_str(&format!("      \"file\": \"{}\",\n", json_escape(&v.file)));
            s.push_str(&format!("      \"line\": {},\n", v.line));
            s.push_str(&format!("      \"rule\": \"{}\",\n", json_escape(v.rule)));
            s.push_str(&format!("      \"msg\": \"{}\"\n", json_escape(&v.msg)));
            s.push_str(if i + 1 == r.violations.len() { "    }\n" } else { "    },\n" });
        }
        s.push_str("  ]\n");
    }
    s.push_str("}\n");
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(files: &[(&str, &str)]) -> Vec<(String, usize, &'static str)> {
        analyze_files(files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect())
            .violations
            .into_iter()
            .map(|v| (v.file, v.line, v.rule))
            .collect()
    }

    fn rules(files: &[(&str, &str)]) -> Vec<&'static str> {
        hits(files).into_iter().map(|(_, _, r)| r).collect()
    }

    // --- adjoint pairing ---

    const BACKWARD_READS_DT_USTAR: &str = "pub fn backward_step(rec: &StepRecord) -> f64 {\n\
         rec.dt * rec.u_star[0]\n}";

    #[test]
    fn pairing_catches_field_written_but_not_read() {
        // `stale` goes into the tape literal but the backward sweep never
        // touches it — the acceptance-criteria scenario
        let stepper = "pub struct StepRecord {\n    pub dt: f64,\n    pub u_star: Vec<f64>,\n\
                       pub stale: Vec<f64>,\n}\n\
                       pub fn step(dt: f64, u_star: Vec<f64>) -> StepRecord {\n\
                       let stale = u_star.clone();\n\
                       StepRecord { dt, u_star, stale }\n}";
        let h = hits(&[("piso/stepper.rs", stepper), ("adjoint/step.rs", BACKWARD_READS_DT_USTAR)]);
        assert_eq!(h, vec![("piso/stepper.rs".to_string(), 4, "adjoint-pairing")]);
    }

    #[test]
    fn pairing_catches_field_declared_but_not_written() {
        let stepper = "pub struct StepRecord {\n    pub dt: f64,\n    pub u_star: Vec<f64>,\n\
                       pub ghost: f64,\n}\n\
                       pub fn step(dt: f64, u_star: Vec<f64>, ghost: f64) -> StepRecord {\n\
                       let _ = ghost;\n\
                       StepRecord { dt, u_star, ghost: 0.0 }\n}";
        // ghost IS written here — quiet; then remove it from the literal
        let ok = hits(&[("piso/stepper.rs", stepper), ("adjoint/step.rs",
            "pub fn backward_step(rec: &StepRecord) -> f64 { rec.dt * rec.u_star[0] * rec.ghost }")]);
        assert!(ok.is_empty(), "{ok:?}");
        let stepper_unwritten = stepper.replace(", ghost: 0.0", "");
        let h = hits(&[
            ("piso/stepper.rs", stepper_unwritten.as_str()),
            ("adjoint/step.rs",
             "pub fn backward_step(rec: &StepRecord) -> f64 { rec.dt * rec.u_star[0] * rec.ghost }"),
        ]);
        assert_eq!(h, vec![("piso/stepper.rs".to_string(), 4, "adjoint-pairing")]);
    }

    #[test]
    fn pairing_is_quiet_when_forward_and_backward_agree() {
        let stepper = "pub struct StepRecord {\n    pub dt: f64,\n    pub u_star: Vec<f64>,\n}\n\
                       pub fn step(dt: f64, u_star: Vec<f64>) -> StepRecord {\n\
                       StepRecord { dt, u_star }\n}";
        assert!(rules(&[("piso/stepper.rs", stepper), ("adjoint/step.rs", BACKWARD_READS_DT_USTAR)])
            .is_empty());
    }

    #[test]
    fn pairing_ignores_zero_fill_ctors_and_validation_reads() {
        // `empty()` writes every field and `validate_record` reads every
        // field — neither may satisfy the pairing requirement, or the rule
        // is vacuous
        let stepper = "pub struct StepRecord {\n    pub dt: f64,\n    pub dead: f64,\n}\n\
                       impl StepRecord {\n\
                       pub fn empty() -> StepRecord { StepRecord { dt: 0.0, dead: 0.0 } }\n}\n\
                       pub fn step(dt: f64) -> StepRecord {\n\
                       let mut r = StepRecord::empty();\n  r.dt = dt;\n  r\n}";
        let backward = "pub fn validate_record(rec: &StepRecord) { let _ = rec.dead; }\n\
                        pub fn backward_step(rec: &StepRecord) -> f64 { rec.dt }";
        let h = hits(&[("piso/stepper.rs", stepper), ("adjoint/step.rs", backward)]);
        assert_eq!(h, vec![("piso/stepper.rs".to_string(), 3, "adjoint-pairing")]);
    }

    // --- ExecCtx flow ---

    #[test]
    fn execctx_construction_confined_to_par_and_coordinator() {
        let src = "pub fn f() -> usize { let ctx = ExecCtx::from_env(); ctx.threads() }";
        assert_eq!(rules(&[("fvm/assemble.rs", src)]), vec!["execctx-construction"]);
        assert!(rules(&[("par/mod.rs", src)]).is_empty());
        assert!(rules(&[("coordinator/scenario.rs", src)]).is_empty());
        let test_src = "#[test]\nfn t() { let _ = ExecCtx::serial(); }";
        assert!(rules(&[("fvm/assemble.rs", test_src)]).is_empty());
    }

    #[test]
    fn unused_execctx_param_is_flagged_until_used_or_underscored() {
        let unused = "pub fn apply(ctx: &ExecCtx, v: &mut [f64]) { v[0] = 1.0; }";
        assert_eq!(rules(&[("linsolve/precond.rs", unused)]), vec!["execctx-unused-param"]);
        let used = "pub fn apply(ctx: &ExecCtx, v: &mut [f64]) { ctx.run_chunks(v); }";
        assert!(rules(&[("linsolve/precond.rs", used)]).is_empty());
        let underscored = "pub fn apply(_ctx: &ExecCtx, v: &mut [f64]) { v[0] = 1.0; }";
        assert!(rules(&[("linsolve/precond.rs", underscored)]).is_empty());
        // coordinator is outside the numeric module set
        assert!(rules(&[("coordinator/engine.rs", unused)]).is_empty());
    }

    // --- float determinism ---

    #[test]
    fn float_sum_is_flagged_but_integer_sum_is_not() {
        let float = "pub fn r(v: &[f64]) -> f64 { v.iter().sum() }";
        assert_eq!(rules(&[("sparse/csr.rs", float)]), vec!["float-reduction"]);
        let turbofish = "pub fn r(v: &[f64]) -> f64 { v.iter().sum::<f64>() }";
        assert_eq!(rules(&[("linsolve/cg.rs", turbofish)]), vec!["float-reduction"]);
        let int = "pub fn n(v: &[Vec<f64>]) -> usize { v.iter().map(|r| r.len()).sum::<usize>() }";
        assert!(rules(&[("linsolve/cg.rs", int)]).is_empty());
        // piso/ is deliberately outside the float-determinism scope
        assert!(rules(&[("piso/stepper.rs", float)]).is_empty());
    }

    #[test]
    fn float_seeded_fold_is_flagged() {
        let fold = "pub fn m(v: &[f64]) -> f64 { v.iter().fold(0.0, |a, b| a + b) }";
        assert_eq!(rules(&[("adjoint/step.rs", fold)]), vec!["float-reduction"]);
        let neg = "pub fn m(v: &[f64]) -> f64 { v.iter().fold(-1.0, |a, &b| a.max(b)) }";
        assert_eq!(rules(&[("adjoint/step.rs", neg)]), vec!["float-reduction"]);
        let int_fold = "pub fn m(v: &[usize]) -> usize { v.iter().fold(0, |a, b| a + b) }";
        assert!(rules(&[("adjoint/step.rs", int_fold)]).is_empty());
    }

    #[test]
    fn lossy_casts_are_flagged_and_widening_is_not() {
        let lossy = "pub fn idx(i: usize) -> u32 { i as u32 }";
        assert_eq!(rules(&[("sparse/csr.rs", lossy)]), vec!["lossy-cast"]);
        let f32_cast = "pub fn shrink(x: f64) -> f32 { x as f32 }";
        assert_eq!(rules(&[("fvm/mod.rs", f32_cast)]), vec!["lossy-cast"]);
        // index widening is exact everywhere; `as f64` is legal in float
        // modules outside the precision scope (fvm/ carries no f32 values)
        assert!(rules(&[("sparse/csr.rs", "pub fn idx(i: u32) -> usize { i as usize }")])
            .is_empty());
        let widen = "pub fn idx(i: u32) -> usize { i as usize }\n\
                     pub fn up(x: f32) -> f64 { x as f64 }";
        assert!(rules(&[("fvm/assemble.rs", widen)]).is_empty());
    }

    #[test]
    fn precision_casts_confined_to_boundary_files() {
        // the blessed boundary files narrow and widen freely...
        let narrow = "pub fn shrink(x: f64) -> f32 { x as f32 }";
        assert!(rules(&[("sparse/csr32.rs", narrow)]).is_empty());
        assert!(rules(&[("linsolve/refine.rs", narrow)]).is_empty());
        let widen_back = "pub fn mean(v: &[f32]) -> f64 { v.len() as f64 }";
        assert!(rules(&[("linsolve/refine.rs", widen_back)]).is_empty());
        // ...but get no pass on index truncation
        let trunc = "pub fn idx(i: usize) -> u32 { i as u32 }";
        assert_eq!(rules(&[("sparse/csr32.rs", trunc)]), vec!["lossy-cast"]);
        // outside the boundary, narrowing stays a lossy-cast and widening
        // back is evidence of f32 values circulating where they must not
        assert_eq!(rules(&[("sparse/csr.rs", narrow)]), vec!["lossy-cast"]);
        assert_eq!(
            rules(&[("linsolve/cg.rs", "pub fn up(x: f32) -> f64 { x as f64 }")]),
            vec!["precision-boundary"]
        );
        // tests are exempt, as for every float_det rule
        let test_src = "#[test]\nfn t() { let x = 1.5_f64; let _ = (x as f32) as f64; }";
        assert!(rules(&[("linsolve/cg.rs", test_src)]).is_empty());
    }

    // --- hot-path allocation ---

    #[test]
    fn loop_allocation_is_flagged_until_hoisted_or_justified() {
        let hot = "pub fn solve(n: usize) {\n  for _ in 0..n {\n    let v = vec![0.0; n];\n    \
                   let _ = v;\n  }\n}";
        assert_eq!(rules(&[("linsolve/cg.rs", hot)]), vec!["hot-loop-alloc"]);
        let hoisted = "pub fn solve(n: usize) {\n  let mut v = vec![0.0; n];\n  for _ in 0..n \
                       {\n    v.fill(0.0);\n  }\n}";
        assert!(rules(&[("linsolve/cg.rs", hoisted)]).is_empty());
        let justified = "pub fn solve(n: usize) {\n  for _ in 0..n {\n    \
                         // ALLOC: restart path, runs at most once per solve\n    \
                         let v = vec![0.0; n];\n    let _ = v;\n  }\n}";
        assert!(rules(&[("linsolve/cg.rs", justified)]).is_empty());
        // non-kernel files may allocate in loops
        assert!(rules(&[("coordinator/engine.rs", hot)]).is_empty());
    }

    #[test]
    fn collect_and_clone_count_as_loop_allocations() {
        let src = "pub fn f(rows: &[Vec<f64>]) -> f64 {\n  let mut acc = 0.0;\n  \
                   for r in rows {\n    let c: Vec<f64> = r.iter().map(|x| x * 2.0).collect();\n    \
                   acc += c[0];\n  }\n  acc\n}";
        assert_eq!(rules(&[("sparse/csr.rs", src)]), vec!["hot-loop-alloc"]);
        let clone = "pub fn f(rows: &[Vec<f64>]) -> usize {\n  let mut n = 0;\n  \
                     for r in rows {\n    let c = r.clone();\n    n += c.len();\n  }\n  n\n}";
        assert_eq!(rules(&[("sparse/csr.rs", clone)]), vec!["hot-loop-alloc"]);
    }

    #[test]
    fn allocation_propagates_one_call_level() {
        // iterate()'s loop calls fresh(), which allocates: the call site is
        // per-iteration allocation even though the vec! sits elsewhere
        let src = "pub fn fresh(n: usize) -> Vec<f64> { vec![0.0; n] }\n\
                   pub fn iterate(n: usize) -> f64 {\n  let mut acc = 0.0;\n  \
                   for _ in 0..n {\n    let v = fresh(n);\n    acc += v[0];\n  }\n  acc\n}";
        let h = hits(&[("linsolve/bicgstab.rs", src)]);
        assert_eq!(h, vec![("linsolve/bicgstab.rs".to_string(), 5, "hot-loop-alloc")]);
    }

    // --- replay containment ---

    const HAND_ROLLED_REPLAY: &str = "pub fn episode(solver: &mut PisoSolver) {\n\
        solver.mesh.bc_values = saved.clone();\n\
        let mut st = cp.clone();\n\
        for _ in 0..4 { solver.step(&mut st, &src, None); }\n}";

    #[test]
    fn hand_rolled_replay_outside_the_tape_is_flagged() {
        let h = hits(&[("coordinator/engine.rs", HAND_ROLLED_REPLAY)]);
        assert_eq!(h, vec![("coordinator/engine.rs".to_string(), 1, "replay-containment")]);
        // the hook itself and the forward stepper are exempt
        assert!(rules(&[("adjoint/tape.rs", HAND_ROLLED_REPLAY)]).is_empty());
        assert!(rules(&[("piso/stepper.rs", HAND_ROLLED_REPLAY)]).is_empty());
        // test fns may re-step against gold values
        let in_test = format!("#[cfg(test)]\nmod tests {{\n{HAND_ROLLED_REPLAY}\n}}");
        assert!(rules(&[("coordinator/engine.rs", in_test.as_str())]).is_empty());
    }

    #[test]
    fn replay_rule_needs_both_halves_in_one_fn() {
        // restoring alone (a scenario builder) is fine
        let restore_only = "pub fn build(solver: &mut PisoSolver) {\n\
            solver.mesh.bc_values = init.clone();\n}";
        assert!(rules(&[("coordinator/scenario.rs", restore_only)]).is_empty());
        // stepping alone (a driver loop) is fine
        let step_only = "pub fn advance(solver: &mut PisoSolver, st: &mut State) {\n\
            for _ in 0..4 { solver.step(st, &src, None); }\n}";
        assert!(rules(&[("coordinator/scenario.rs", step_only)]).is_empty());
        // comparing boundary values is not an assignment
        let compare = "pub fn same(solver: &mut PisoSolver, st: &mut State) -> bool {\n\
            solver.step(st, &src, None);\n\
            solver.mesh.bc_values == saved\n}";
        assert!(rules(&[("coordinator/scenario.rs", compare)]).is_empty());
        // a local named bc_values is not the solver's boundary state
        let local = "pub fn gen(solver: &mut PisoSolver, st: &mut State) {\n\
            let bc_values = vec![0.0];\n\
            let _ = bc_values;\n\
            solver.step(st, &src, None);\n}";
        assert!(rules(&[("coordinator/scenario.rs", local)]).is_empty());
    }

    // --- report plumbing ---

    #[test]
    fn json_report_shape_is_stable() {
        let r = analyze_files(vec![(
            "sparse/csr.rs".to_string(),
            "pub fn idx(i: usize) -> u32 { i as u32 }".to_string(),
        )]);
        let json = to_json(&r);
        assert!(json.starts_with("{\n  \"files\": 1,\n"));
        assert!(json.contains("\"rule\": \"lossy-cast\""));
        assert!(json.ends_with("}\n"));
        let clean = analyze_files(vec![("a.rs".to_string(), "pub fn f() {}".to_string())]);
        assert!(to_json(&clean).contains("\"violations\": []"));
    }

    // --- the real tree is analyze-clean (CI acceptance gate, also enforced
    // from plain `cargo test`) ---

    #[test]
    fn repo_rust_src_is_analyze_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask sits one level under the workspace root")
            .join("rust")
            .join("src");
        let report = analyze_tree(&root).expect("rust/src must be readable from the xtask test");
        assert!(report.files > 30, "expected the full solver tree, found {} files", report.files);
        assert!(report.fns > 100, "parser regression: only {} fns found", report.fns);
        assert!(
            report.violations.is_empty(),
            "rust/src has analyze violations:\n{}",
            report.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
