//! Repo tooling entry point (cargo-xtask pattern).
//!
//! `cargo run -p xtask -- lint [--root <dir>]` runs the determinism &
//! concurrency contract lint over `rust/src` and exits nonzero if any rule
//! fires. `cargo run -p xtask -- analyze [--root <dir>] [--json]` runs the
//! semantic analyzer (parser + symbol table + call graph + the
//! adjoint-pairing / ExecCtx-flow / float-determinism / hot-allocation
//! rules); `--json` emits the machine-readable report CI archives as an
//! artifact. Both passes are also wired into the default test suite
//! (`repo_rust_src_is_lint_clean`, `repo_rust_src_is_analyze_clean`).

mod analyze;
mod callgraph;
mod lexer;
mod parse;
mod rules;
mod symbols;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(&args[1..]),
        Some("analyze") => analyze_cmd(&args[1..]),
        Some(other) => {
            eprintln!("unknown xtask subcommand `{other}`");
            usage();
            ExitCode::from(2)
        }
        None => {
            usage();
            ExitCode::from(2)
        }
    }
}

fn usage() {
    eprintln!(
        "usage: cargo run -p xtask -- lint [--root <workspace-root>]\n       \
         cargo run -p xtask -- analyze [--root <workspace-root>] [--json]"
    );
}

/// `--root` defaults to the workspace root one level above this crate.
fn resolve_root(root: Option<PathBuf>) -> PathBuf {
    root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask sits one level under the workspace root")
            .to_path_buf()
    })
}

fn lint(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a path");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown lint flag `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    let src_root = resolve_root(root).join("rust").join("src");
    match rules::lint_tree(&src_root) {
        Ok((nfiles, violations)) => {
            if violations.is_empty() {
                println!("xtask lint: {nfiles} files clean under {}", src_root.display());
                ExitCode::SUCCESS
            } else {
                for v in &violations {
                    println!("{v}");
                }
                println!(
                    "xtask lint: {} violation(s) across {nfiles} files",
                    violations.len()
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: cannot walk {}: {e}", src_root.display());
            ExitCode::from(2)
        }
    }
}

fn analyze_cmd(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--json" => json = true,
            other => {
                eprintln!("unknown analyze flag `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    let src_root = resolve_root(root).join("rust").join("src");
    match analyze::analyze_tree(&src_root) {
        Ok(report) => {
            if json {
                print!("{}", analyze::to_json(&report));
            } else if report.violations.is_empty() {
                println!(
                    "xtask analyze: {} files clean under {} ({} fns, {} call sites, {} resolved)",
                    report.files,
                    src_root.display(),
                    report.fns,
                    report.call_sites,
                    report.resolved_edges
                );
            } else {
                for v in &report.violations {
                    println!("{v}");
                }
                println!(
                    "xtask analyze: {} violation(s) across {} files",
                    report.violations.len(),
                    report.files
                );
            }
            if report.violations.is_empty() { ExitCode::SUCCESS } else { ExitCode::FAILURE }
        }
        Err(e) => {
            eprintln!("xtask analyze: cannot walk {}: {e}", src_root.display());
            ExitCode::from(2)
        }
    }
}
