//! Repo tooling entry point (cargo-xtask pattern).
//!
//! `cargo run -p xtask -- lint [--root <dir>]` runs the determinism &
//! concurrency contract lint over `rust/src` and exits nonzero if any rule
//! fires. The same pass is wired into the default test suite
//! (`rules::tests::repo_rust_src_is_lint_clean`) and CI.

mod lexer;
mod rules;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(&args[1..]),
        Some(other) => {
            eprintln!("unknown xtask subcommand `{other}`");
            usage();
            ExitCode::from(2)
        }
        None => {
            usage();
            ExitCode::from(2)
        }
    }
}

fn usage() {
    eprintln!("usage: cargo run -p xtask -- lint [--root <workspace-root>]");
}

fn lint(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a path");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown lint flag `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    // default: the workspace root is one level above this crate
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask sits one level under the workspace root")
            .to_path_buf()
    });
    let src_root = root.join("rust").join("src");
    match rules::lint_tree(&src_root) {
        Ok((nfiles, violations)) => {
            if violations.is_empty() {
                println!("xtask lint: {nfiles} files clean under {}", src_root.display());
                ExitCode::SUCCESS
            } else {
                for v in &violations {
                    println!("{v}");
                }
                println!(
                    "xtask lint: {} violation(s) across {nfiles} files",
                    violations.len()
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: cannot walk {}: {e}", src_root.display());
            ExitCode::from(2)
        }
    }
}
